"""L1 pallas kernels for SparseServe (interpret=True on CPU PJRT).

Kernel inventory:
- block_meta:        metadata construction (mean / cuboid) per KV block
- block_select:      block criticality scoring against metadata
- sparse_attention:  decode attention over gathered top-k blocks
- prefill_attention: tiled causal attention for prefill segments
- ref:               pure-jnp oracle for all of the above
"""

from . import ref  # noqa: F401
from .block_meta import block_meta_cuboid, block_meta_mean  # noqa: F401
from .block_select import score_blocks_cuboid, score_blocks_mean  # noqa: F401
from .prefill_attention import prefill_causal_attention  # noqa: F401
from .sparse_attention import sparse_decode_attention  # noqa: F401
