"""Pallas kernels for KV-block metadata construction (paper §2.2 / §3.1).

DSAs represent each KV block with compact metadata used to estimate the
block's criticality for a query token. SparseServe's default is the
cuboid metadata of ArkVale (bounding box of the block's keys); the mean
metadata of InfLLM is also provided.

TPU mapping (DESIGN.md §Hardware-Adaptation): one grid step per
(head, block); the [Bs, D] key tile is staged HBM->VMEM by the BlockSpec
and reduced along the token axis on the VPU. Bs*D floats (16*32 here, up
to 32*128 at paper scale = 16 KB) fits VMEM trivially, so the kernel is
HBM-bandwidth-bound: one pass over the keys, 1/Bs (mean) or 2/Bs (cuboid)
of the input volume written back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _meta_mean_kernel(keys_ref, meta_ref):
    # keys_ref: [1, 1, Bs, D] tile for one (head, block); reduce tokens.
    meta_ref[...] = jnp.mean(keys_ref[...], axis=2)


def _meta_cuboid_kernel(keys_ref, lo_ref, hi_ref):
    k = keys_ref[...]
    lo_ref[...] = jnp.min(k, axis=2)
    hi_ref[...] = jnp.max(k, axis=2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_meta_mean(keys: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Mean-pool metadata. keys: [H, NB, Bs, D] -> [H, NB, D]."""
    h, nb, bs, d = keys.shape
    return pl.pallas_call(
        _meta_mean_kernel,
        grid=(h, nb),
        in_specs=[pl.BlockSpec((1, 1, bs, d), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((h, nb, d), keys.dtype),
        interpret=interpret,
    )(keys)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_meta_cuboid(
    keys: jnp.ndarray, interpret: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bounding-cuboid metadata. keys: [H, NB, Bs, D] -> (lo, hi) [H, NB, D]."""
    h, nb, bs, d = keys.shape
    spec = pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        _meta_cuboid_kernel,
        grid=(h, nb),
        in_specs=[pl.BlockSpec((1, 1, bs, d), lambda i, j: (i, j, 0, 0))],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((h, nb, d), keys.dtype),
            jax.ShapeDtypeStruct((h, nb, d), keys.dtype),
        ),
        interpret=interpret,
    )(keys)
