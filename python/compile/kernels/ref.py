"""Pure-jnp reference oracle for every L1 pallas kernel.

These functions define the *semantics* the kernels must match; pytest
(`python/tests/`) asserts allclose between each kernel (interpret=True)
and its oracle across hypothesis-driven shape/dtype sweeps.

Conventions
-----------
- ``H``  : number of (query) attention heads
- ``NB`` : number of KV blocks (padded to a static maximum)
- ``Bs`` : tokens per KV block
- ``S``  : gathered KV length for sparse decode, ``S = K * Bs``
- ``T``  : prompt/segment length for prefill
- ``D``  : head dimension
- Masks are additive: 0.0 for valid positions, ``NEG_INF`` for invalid.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def block_meta_mean(keys: jnp.ndarray) -> jnp.ndarray:
    """Mean-pool block metadata (InfLLM-style).

    keys: [H, NB, Bs, D] -> meta [H, NB, D]
    """
    return jnp.mean(keys, axis=2)


def block_meta_cuboid(keys: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bounding-cuboid block metadata (ArkVale-style).

    keys: [H, NB, Bs, D] -> (lo, hi) each [H, NB, D]
    """
    return jnp.min(keys, axis=2), jnp.max(keys, axis=2)


def score_blocks_mean(q: jnp.ndarray, meta: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Approximate criticality score of each block: q . mean(K_block).

    q: [B, H, D], meta: [B, H, NB, D], mask: [B, H, NB] (additive)
    -> scores [B, H, NB]
    """
    scores = jnp.einsum("bhd,bhnd->bhn", q, meta)
    return scores + mask


def score_blocks_cuboid(
    q: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Upper bound of q . k over the block's bounding cuboid.

    For each dim, the max of q_d * k_d over k_d in [lo_d, hi_d] is
    max(q_d * lo_d, q_d * hi_d); summing dims gives a tight upper bound
    used as the criticality estimate (ArkVale's cuboid score).

    q: [B, H, D], lo/hi: [B, H, NB, D], mask: [B, H, NB] -> [B, H, NB]
    """
    ql = jnp.einsum("bhd,bhnd->bhnd", q, lo)
    qh = jnp.einsum("bhd,bhnd->bhnd", q, hi)
    return jnp.sum(jnp.maximum(ql, qh), axis=-1) + mask


def sparse_decode_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Decode attention over gathered (selected) KV blocks.

    q: [B, H, D], k/v: [B, H, S, D], mask: [B, H, S] (additive)
    -> out [B, H, D]
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s = s + mask.astype(jnp.float32)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bhsd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def prefill_causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kvmask: jnp.ndarray | None = None,
    kv_offset: int = 0,
) -> jnp.ndarray:
    """Causal self-attention over a prompt segment.

    q: [H, T, D], k/v: [H, Tk, D]. ``kv_offset`` is the absolute position of
    q[0] minus the absolute position of k[0]; for plain prefill it is 0 and
    Tk == T, for chunked prefill the chunk attends to all preceding KV so
    kv_offset = Tk - T. Query i may attend to kv j iff j <= i + kv_offset.
    -> out [H, T, D]
    """
    d = q.shape[-1]
    t, tk = q.shape[1], k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    s = jnp.einsum("htd,hsd->hts", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if kvmask is not None:
        s = s + kvmask.astype(jnp.float32)[None, None, :]
    qi = jnp.arange(t)[:, None]
    kj = jnp.arange(tk)[None, :]
    causal = kj <= qi + kv_offset
    s = jnp.where(causal[None, :, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("hts,hsd->htd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def topk_blocks(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """Indices of the top-k critical blocks per head. scores: [B, H, NB]."""
    import jax

    _, idx = jax.lax.top_k(scores, k)
    return idx
