"""Pallas tiled causal attention for prefill segments.

Used by both prefill strategies: layer-segmented prefill runs it once per
layer over the whole prompt (kv_offset=0, Tk=T); the chunked-prefill
baseline runs it per chunk with the accumulated KV of preceding chunks
(kv_offset = Tk - T), which is exactly the repeated-KV-reload cost the
paper's Fig. 16b charges against chunking.

TPU mapping: flash-attention tiling. Grid (H, T/QT, Tk/KT); each step
stages one q-tile (reused across the inner kv loop — BlockSpec maps it
independently of t_kv, so it stays VMEM-resident) and one kv-tile, and
folds into per-row online-softmax accumulators in VMEM scratch. The
causal predicate is computed from absolute tile indices with iota, so
fully-masked tiles cost one predicated VPU pass (Mosaic skips the MXU
work when the whole tile folds to NEG_INF). VMEM footprint per step:
QT*D + 2*KT*D inputs + QT*(D+2) accumulators — with QT=KT=128, D=128
that is ~200 KB, comfortably double-buffered in 16 MB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _prefill_kernel(
    q_ref, k_ref, v_ref, kvmask_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, kv_offset, q_tile, k_tile, n_kv
):
    tq = pl.program_id(1)
    tk = pl.program_id(2)

    @pl.when(tk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, :].astype(jnp.float32)  # [QT, D]
    k = k_ref[0, :, :].astype(jnp.float32)  # [KT, D]
    v = v_ref[0, :, :].astype(jnp.float32)  # [KT, D]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [QT, KT]
    s = s + kvmask_ref[0, :].astype(jnp.float32)[None, :]  # padded-KV mask

    # Causal mask on absolute positions: query row i (abs qi = tq*QT + i)
    # may attend to kv col j (abs kj = tk*KT + j) iff kj <= qi + kv_offset.
    qi = tq * q_tile + jax.lax.broadcasted_iota(jnp.int32, (q_tile, k_tile), 0)
    kj = tk * k_tile + jax.lax.broadcasted_iota(jnp.int32, (q_tile, k_tile), 1)
    s = jnp.where(kj <= qi + kv_offset, s, NEG_INF)

    m_prev = m_ref[...]  # [QT]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # Guard fully-masked rows (can only happen transiently before any valid
    # kv tile has been seen): keep exp args finite.
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])  # [QT, KT]
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_new = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(tk == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kv_offset", "q_tile", "k_tile", "interpret"))
def prefill_causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kvmask: jnp.ndarray | None = None,
    kv_offset: int = 0,
    q_tile: int = 16,
    k_tile: int = 16,
    interpret: bool = True,
) -> jnp.ndarray:
    """Tiled causal attention. q: [H, T, D], k/v: [H, Tk, D] -> [H, T, D].

    ``kv_offset`` shifts the causal diagonal for chunked prefill (the chunk's
    first query sits at absolute position ``kv_offset`` relative to k[0]).
    ``kvmask`` [Tk] is additive (NEG_INF for padded past-KV slots; chunked
    prefill pads the accumulated past to a static bucket).
    T and Tk must be multiples of the tile sizes (the model pads segments).
    """
    h, t, d = q.shape
    tk_len = k.shape[1]
    if kvmask is None:
        kvmask = jnp.zeros((tk_len,), dtype=jnp.float32)
    if t % q_tile or tk_len % k_tile:
        raise ValueError(f"T={t}/Tk={tk_len} not multiples of tiles {q_tile}/{k_tile}")
    n_q, n_kv = t // q_tile, tk_len // k_tile
    scale = 1.0 / (d**0.5)

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(
        _prefill_kernel,
        scale=scale,
        kv_offset=kv_offset,
        q_tile=q_tile,
        k_tile=k_tile,
        n_kv=n_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=(h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_tile, d), lambda i, tq, tk: (i, tq, 0)),
            pl.BlockSpec((1, k_tile, d), lambda i, tq, tk: (i, tk, 0)),
            pl.BlockSpec((1, k_tile, d), lambda i, tq, tk: (i, tk, 0)),
            pl.BlockSpec((1, k_tile), lambda i, tq, tk: (0, tk)),
        ],
        out_specs=pl.BlockSpec((1, q_tile, d), lambda i, tq, tk: (i, tq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_tile,), jnp.float32),
            pltpu.VMEM((q_tile,), jnp.float32),
            pltpu.VMEM((q_tile, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kvmask.reshape(1, tk_len))
