"""Pallas sparse decode attention — the paper's compute hot-spot.

One decode step attends only to the KV blocks the DSA selected (gathered
by the rust coordinator via FlashH2D into a contiguous [B, H, S, D]
staging tensor, S = top_k * block_size, plus an additive mask for padded
or partially-filled blocks).

TPU mapping (DESIGN.md §Hardware-Adaptation): the CUDA original streams
16 KB KV blocks through SRAM per threadblock. Here the grid is
(B, H, S/S_TILE); each step the BlockSpec stages one (kv-tile) pair
HBM->VMEM and the kernel folds it into an online-softmax accumulator
(m, l, acc) held in VMEM scratch — the flash-attention recurrence:

    m' = max(m, max(s));  l' = l*e^(m-m') + sum(e^(s-m'))
    acc' = acc*e^(m-m') + e^(s-m') @ V

VMEM per step: 2 * S_TILE * D * 4 B of KV + D accumulator — a few KB, so
double-buffering the HBM->VMEM stream is free and the kernel is
bandwidth-bound exactly like the paper's (the point of DSA is to shrink
that bandwidth by S/ctx_len).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _sparse_attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, n_tiles):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)  # [D]
    k = k_ref[0, 0, :, :].astype(jnp.float32)  # [S_TILE, D]
    v = v_ref[0, 0, :, :].astype(jnp.float32)  # [S_TILE, D]
    mask = mask_ref[0, 0, :].astype(jnp.float32)  # [S_TILE]

    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale + mask  # [S_TILE]

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # [S_TILE]
    l_new = l_ref[0] * alpha + jnp.sum(p)
    acc_new = acc_ref[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)

    m_ref[0] = m_new
    l_ref[0] = l_new
    acc_ref[...] = acc_new

    @pl.when(t == n_tiles - 1)
    def _finish():
        o_ref[0, 0, :] = (acc_ref[...] / l_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("s_tile", "interpret"))
def sparse_decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    s_tile: int = 16,
    interpret: bool = True,
) -> jnp.ndarray:
    """Online-softmax attention over gathered KV blocks.

    q: [B, H, D], k/v: [B, H, S, D], mask: [B, H, S] (additive; NEG_INF for
    padded slots) -> out [B, H, D]. S must be a multiple of ``s_tile``
    (rust pads the gather to whole blocks, so S = top_k * block_size).
    """
    b, h, d = q.shape
    s = k.shape[2]
    if s % s_tile != 0:
        raise ValueError(f"S={s} not a multiple of s_tile={s_tile}")
    n_tiles = s // s_tile
    scale = 1.0 / (d**0.5)

    kernel = functools.partial(_sparse_attn_kernel, scale=scale, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j, t: (i, j, 0)),
            pl.BlockSpec((1, 1, s_tile, d), lambda i, j, t: (i, j, t, 0)),
            pl.BlockSpec((1, 1, s_tile, d), lambda i, j, t: (i, j, t, 0)),
            pl.BlockSpec((1, 1, s_tile), lambda i, j, t: (i, j, t)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j, t: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu_scratch((1,), jnp.float32),
            pltpu_scratch((1,), jnp.float32),
            pltpu_scratch((d,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)


def pltpu_scratch(shape, dtype):
    """VMEM scratch shape (works under interpret mode on CPU too)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
