"""Pallas kernels for block criticality scoring (the DSA "select" step).

For every query token, DSAs estimate each KV block's importance from its
metadata and pick the top-k. The scoring is the compute-regular half
(done here, on-device); the top-k and the residency decision (HBM hit or
DRAM load) belong to the rust coordinator, which is why these kernels
return dense per-block scores rather than indices.

TPU mapping: scoring is a [NB, D] x [D] matvec per (batch, head) — at
paper scale (NB=1024, D=128) a 512 KB tile that sits in VMEM and feeds
the MXU as a skinny matmul; the cuboid variant is two VPU elementwise
passes + a row reduction. The additive mask folds padding blocks to
NEG_INF so rust's top-k never selects them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_mean_kernel(q_ref, meta_ref, mask_ref, out_ref):
    # q: [1, 1, D], meta: [1, 1, NB, D], mask/out: [1, 1, NB]
    q = q_ref[0, 0, :].astype(jnp.float32)
    meta = meta_ref[0, 0, :, :].astype(jnp.float32)
    scores = jnp.dot(meta, q, preferred_element_type=jnp.float32)
    out_ref[0, 0, :] = (scores + mask_ref[0, 0, :].astype(jnp.float32)).astype(
        out_ref.dtype
    )


def _score_cuboid_kernel(q_ref, lo_ref, hi_ref, mask_ref, out_ref):
    q = q_ref[0, 0, :].astype(jnp.float32)
    lo = lo_ref[0, 0, :, :].astype(jnp.float32)
    hi = hi_ref[0, 0, :, :].astype(jnp.float32)
    ql = lo * q[None, :]
    qh = hi * q[None, :]
    scores = jnp.sum(jnp.maximum(ql, qh), axis=-1)
    out_ref[0, 0, :] = (scores + mask_ref[0, 0, :].astype(jnp.float32)).astype(
        out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def score_blocks_mean(
    q: jnp.ndarray, meta: jnp.ndarray, mask: jnp.ndarray, interpret: bool = True
) -> jnp.ndarray:
    """q: [B, H, D], meta: [B, H, NB, D], mask: [B, H, NB] -> scores [B, H, NB]."""
    b, h, d = q.shape
    nb = meta.shape[2]
    return pl.pallas_call(
        _score_mean_kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, nb, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, nb), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, nb), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nb), jnp.float32),
        interpret=interpret,
    )(q, meta, mask)


@functools.partial(jax.jit, static_argnames=("interpret",))
def score_blocks_cuboid(
    q: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    mask: jnp.ndarray,
    interpret: bool = True,
) -> jnp.ndarray:
    """q: [B, H, D], lo/hi: [B, H, NB, D], mask: [B, H, NB] -> scores [B, H, NB]."""
    b, h, d = q.shape
    nb = lo.shape[2]
    meta_spec = pl.BlockSpec((1, 1, nb, d), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _score_cuboid_kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
            meta_spec,
            meta_spec,
            pl.BlockSpec((1, 1, nb), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, nb), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nb), jnp.float32),
        interpret=interpret,
    )(q, lo, hi, mask)
