"""Build-time python mirror of the rust request pipeline.

Runs the *exact* split dataflow the rust coordinator executes —
per-layer prefill, then per-layer decode_qkv -> top-k -> gather ->
decode_attend — entirely in python. Two uses:

1. pytest parity: with a DSA budget covering all blocks, the split
   pipeline must reproduce ``model.reference_forward`` (dense oracle).
2. golden generation: ``aot.py`` dumps prompt/step tokens produced here;
   the rust integration tests assert the PJRT pipeline emits the same
   tokens (bitwise-deterministic greedy decode).

Conventions shared with rust (rust/src/engine/pjrt_backend.rs):
- prompt segments are padded up to a static bucket; padded tail is
  masked with NEG_INF via seg_mask and padded k/v rows are discarded.
- the open (partially filled) KV block is ALWAYS part of the gather set
  (its in-block padding masked); sealed blocks are chosen by cuboid
  score, top-(budget_blocks - 1).
- gather order: selected sealed blocks by descending score, then the
  open block last; invalid selection slots fully masked.
"""

from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from . import model as M

NEG_INF = M.NEG_INF


def pad_to_bucket(n: int, buckets: List[int]) -> int:
    for b in sorted(buckets):
        if n <= b:
            return b
    raise ValueError(f"length {n} exceeds largest bucket {max(buckets)}")


class KvState:
    """Per-request, per-layer KV store at block granularity (numpy).

    Mirrors rust's DRAM-resident block layout: [Hkv, NB, Bs, Dh] for keys
    and values, plus cuboid metadata for sealed blocks.
    """

    def __init__(self, cfg: M.ModelConfig):
        self.cfg = cfg
        hkv, nb, bs, dh = cfg.n_kv_heads, cfg.max_blocks, cfg.block_size, cfg.head_dim
        self.k = np.zeros((hkv, nb, bs, dh), dtype=np.float32)
        self.v = np.zeros((hkv, nb, bs, dh), dtype=np.float32)
        self.lo = np.zeros((hkv, nb, dh), dtype=np.float32)
        self.hi = np.zeros((hkv, nb, dh), dtype=np.float32)
        self.len = 0  # tokens stored

    @property
    def n_sealed(self) -> int:
        return self.len // self.cfg.block_size

    @property
    def open_fill(self) -> int:
        return self.len % self.cfg.block_size

    def append(self, k_t: np.ndarray, v_t: np.ndarray) -> None:
        """Append one token's k/v ([Hkv, Dh] each), sealing blocks as they fill."""
        bs = self.cfg.block_size
        blk, off = divmod(self.len, bs)
        self.k[:, blk, off, :] = k_t
        self.v[:, blk, off, :] = v_t
        self.len += 1
        if self.len % bs == 0:  # sealed: build cuboid metadata
            self.lo[:, blk, :] = self.k[:, blk].min(axis=1)
            self.hi[:, blk, :] = self.k[:, blk].max(axis=1)

    def append_prefill(self, k_seg: np.ndarray, v_seg: np.ndarray) -> None:
        """Append a whole segment ([Hkv, T, Dh]) token by token."""
        for t in range(k_seg.shape[1]):
            self.append(k_seg[:, t, :], v_seg[:, t, :])


def gather_blocks(state: KvState, scores: np.ndarray, budget_blocks: int):
    """Select and gather blocks for one (request, layer) decode step.

    scores: [Hkv, NB] (group-aggregated, NEG_INF for absent). Returns
    (kv_k, kv_v, kv_mask, selected) with kv_k/kv_v [Hkv, S, Dh],
    kv_mask [Hkv, S], S = budget_blocks * Bs, and selected the per-head
    list of gathered block ids (for working-set accounting / Fig. 8).
    """
    cfg = state.cfg
    hkv, bs, dh = cfg.n_kv_heads, cfg.block_size, cfg.head_dim
    s_len = budget_blocks * bs
    kv_k = np.zeros((hkv, s_len, dh), dtype=np.float32)
    kv_v = np.zeros((hkv, s_len, dh), dtype=np.float32)
    kv_mask = np.full((hkv, s_len), NEG_INF, dtype=np.float32)
    selected: List[List[int]] = []

    n_sealed = state.n_sealed
    open_blk = n_sealed  # index of the open block (may be empty)
    open_fill = state.open_fill

    for h in range(hkv):
        n_pick = min(budget_blocks - 1, n_sealed)
        order = np.argsort(-scores[h, :n_sealed], kind="stable")[:n_pick]
        sel = [int(b) for b in order]
        for slot, b in enumerate(sel):
            kv_k[h, slot * bs : (slot + 1) * bs] = state.k[h, b]
            kv_v[h, slot * bs : (slot + 1) * bs] = state.v[h, b]
            kv_mask[h, slot * bs : (slot + 1) * bs] = 0.0
        # open block in the last slot (always included; padding masked)
        if open_fill > 0:
            slot = budget_blocks - 1
            kv_k[h, slot * bs : slot * bs + open_fill] = state.k[h, open_blk, :open_fill]
            kv_v[h, slot * bs : slot * bs + open_fill] = state.v[h, open_blk, :open_fill]
            kv_mask[h, slot * bs : slot * bs + open_fill] = 0.0
            sel.append(open_blk)
        selected.append(sel)
    return kv_k, kv_v, kv_mask, selected


def run_pipeline(
    cfg: M.ModelConfig,
    weights: Dict[str, np.ndarray],
    prompt: np.ndarray,
    n_steps: int,
    budget_blocks: int | None = None,
    seg_buckets: List[int] | None = None,
    use_pallas: bool = True,
    record_selected: bool = False,
):
    """Prefill + greedy decode through the split entry points.

    budget_blocks=None means full budget (DSA degenerates to dense —
    parity case). Returns (tokens [n_steps], selected_trace) where
    selected_trace[step][layer][head] is the gathered block-id list
    (empty unless record_selected).
    """
    seg_buckets = seg_buckets or [64, 256, 1024, 2048]
    w = {k: jnp.asarray(v) for k, v in weights.items()}
    lw = lambda i, n: w[f"l{i}.{n}"]

    states = [KvState(cfg) for _ in range(cfg.n_layers)]

    # ---- prefill (layer-segmented: whole prompt, one layer at a time) ----
    t_real = len(prompt)
    t_pad = pad_to_bucket(t_real, seg_buckets)
    toks = np.zeros((t_pad,), dtype=np.int32)
    toks[:t_real] = prompt
    seg_mask = np.where(np.arange(t_pad) < t_real, 0.0, NEG_INF).astype(np.float32)

    (x,) = M.embed(jnp.asarray(toks), w["embedding"])
    empty_k = jnp.zeros((cfg.n_kv_heads, 0, cfg.head_dim), dtype=jnp.float32)
    empty_mask = jnp.zeros((0,), dtype=jnp.float32)
    for i in range(cfg.n_layers):
        k, v, x = M.prefill_layer(
            cfg, x, jnp.int32(0), jnp.asarray(seg_mask),
            empty_k, empty_k, empty_mask,
            *(lw(i, n) for n in M.LAYER_WEIGHT_NAMES),
            interpret=use_pallas,
        )
        states[i].append_prefill(np.asarray(k)[:, :t_real], np.asarray(v)[:, :t_real])

    next_tok, _ = M.lm_head(x[t_real - 1 : t_real], w["final_norm"], w["lm_head"])
    cur = int(np.asarray(next_tok)[0])

    # ---- decode ----
    out_tokens = [cur]
    selected_trace: List[List[List[int]]] = []
    nb = cfg.max_blocks
    for step in range(n_steps - 1):
        pos = states[0].len  # absolute position of the new token
        (x,) = M.embed(jnp.asarray([cur], dtype=jnp.int32), w["embedding"])
        step_selected: List[List[int]] = []
        for i in range(cfg.n_layers):
            st = states[i]
            n_sealed = st.n_sealed
            meta_mask = np.full((1, cfg.n_kv_heads, nb), NEG_INF, dtype=np.float32)
            meta_mask[:, :, :n_sealed] = 0.0
            q, k, v, scores = M.decode_qkv(
                cfg, x, jnp.asarray([pos], dtype=jnp.int32),
                jnp.asarray(st.lo)[None], jnp.asarray(st.hi)[None],
                jnp.asarray(meta_mask),
                lw(i, "attn_norm"), lw(i, "wq"), lw(i, "wk"), lw(i, "wv"),
                interpret=use_pallas,
            )
            st.append(np.asarray(k)[0], np.asarray(v)[0])

            budget = budget_blocks if budget_blocks is not None else nb
            budget = min(budget, nb)
            kv_k, kv_v, kv_mask, sel = gather_blocks(st, np.asarray(scores)[0], budget)
            if record_selected:
                step_selected.append(sel[0] if cfg.n_kv_heads == 1 else [b for s in sel for b in s])
            (x,) = M.decode_attend(
                cfg, x, q,
                jnp.asarray(kv_k)[None], jnp.asarray(kv_v)[None],
                jnp.asarray(kv_mask)[None],
                lw(i, "wo"), lw(i, "ffn_norm"),
                lw(i, "w_gate"), lw(i, "w_up"), lw(i, "w_down"),
                interpret=use_pallas,
            )
        next_tok, _ = M.lm_head(x, w["final_norm"], w["lm_head"])
        cur = int(np.asarray(next_tok)[0])
        out_tokens.append(cur)
        if record_selected:
            selected_trace.append(step_selected)
    return np.asarray(out_tokens, dtype=np.int32), selected_trace
