"""AOT compiler: lower every L2 entry point to HLO *text* artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
results via ``HloModuleProto::from_text_file`` and executes them on the
PJRT CPU client. Python never runs on the request path.

Interchange format is HLO text, NOT ``lowered.compiler_ir("hlo")`` proto
serialization: jax >= 0.5 emits 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Outputs under ``<out>/<config-name>/``:
- ``<entry>.hlo.txt``   one per entry point x shape bucket
- ``weights.bin``       all weights, f32 little-endian, manifest order
- ``manifest.json``     model config + weight layout + entry signatures
- ``golden.json``       greedy-decode token traces for rust parity tests

Usage: python -m compile.aot --out ../artifacts [--config tiny-llm]
       [--fast] [--skip-golden]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import pipeline as P

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Artifact:
    def __init__(self, name, kind, bucket, fn, specs):
        self.name = name
        self.kind = kind
        self.bucket = bucket
        self.fn = fn
        self.specs = specs

    def describe(self):
        return {
            "name": self.name,
            "file": f"{self.name}.hlo.txt",
            "kind": self.kind,
            "bucket": self.bucket,
            "params": [
                {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}
                for s in self.specs
            ],
        }


def build_artifacts(cfg: M.ModelConfig, buckets) -> list[Artifact]:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    f, v, nb, bs = cfg.ffn_dim, cfg.vocab, cfg.max_blocks, cfg.block_size
    lw_specs = {
        "attn_norm": spec([d]),
        "wq": spec([d, hq * dh]),
        "wk": spec([d, hkv * dh]),
        "wv": spec([d, hkv * dh]),
        "wo": spec([hq * dh, d]),
        "ffn_norm": spec([d]),
        "w_gate": spec([d, f]),
        "w_up": spec([d, f]),
        "w_down": spec([f, d]),
    }
    arts: list[Artifact] = []

    # ---- embed: one bucket per token-count we ever embed ----
    embed_ns = sorted(set(buckets["decode_b"]) | set(buckets["prefill_t"]))
    for n in embed_ns:
        arts.append(
            Artifact(
                f"embed_{n}", "embed", {"n": n},
                lambda tokens, emb: M.embed(tokens, emb),
                [spec([n], I32), spec([v, d])],
            )
        )

    # ---- prefill_layer (layer-segmented / plain: no past) ----
    attn_names = ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down")
    for t in buckets["prefill_t"]:
        def pf(x, pos_offset, seg_mask, *ws, _t=t):
            empty = jnp.zeros((hkv, 0, dh), dtype=F32)
            emask = jnp.zeros((0,), dtype=F32)
            return M.prefill_layer(cfg, x, pos_offset, seg_mask, empty, empty, emask, *ws)

        arts.append(
            Artifact(
                f"prefill_layer_{t}", "prefill_layer", {"t": t},
                pf,
                [spec([t, d]), spec([], I32), spec([t])] + [lw_specs[n] for n in attn_names],
            )
        )

    # ---- prefill_chunk (chunked-prefill baseline: padded past) ----
    p_max = buckets["chunk_past"]
    for t in buckets["chunk_t"]:
        def pfc(x, pos_offset, seg_mask, past_k, past_v, past_mask, *ws):
            return M.prefill_layer(cfg, x, pos_offset, seg_mask, past_k, past_v, past_mask, *ws)

        arts.append(
            Artifact(
                f"prefill_chunk_{t}", "prefill_chunk", {"t": t, "past": p_max},
                pfc,
                [
                    spec([t, d]), spec([], I32), spec([t]),
                    spec([hkv, p_max, dh]), spec([hkv, p_max, dh]), spec([p_max]),
                ]
                + [lw_specs[n] for n in attn_names],
            )
        )

    # ---- block metadata over a layer's prefill keys ----
    for t in buckets["prefill_t"]:
        if t % bs:
            continue
        arts.append(
            Artifact(
                f"block_meta_{t}", "block_meta", {"t": t},
                lambda k_layer: M.build_block_metadata(cfg, k_layer),
                [spec([hkv, t, dh])],
            )
        )

    # ---- decode_qkv / decode_attend per batch bucket ----
    for b in buckets["decode_b"]:
        arts.append(
            Artifact(
                f"decode_qkv_{b}", "decode_qkv", {"b": b},
                lambda x, pos, lo, hi, mm, an, wq, wk, wv: M.decode_qkv(
                    cfg, x, pos, lo, hi, mm, an, wq, wk, wv
                ),
                [
                    spec([b, d]), spec([b], I32),
                    spec([b, hkv, nb, dh]), spec([b, hkv, nb, dh]), spec([b, hkv, nb]),
                    lw_specs["attn_norm"], lw_specs["wq"], lw_specs["wk"], lw_specs["wv"],
                ],
            )
        )
        for k in buckets["budget_k"]:
            s = k * bs
            arts.append(
                Artifact(
                    f"decode_attend_{b}_{k}", "decode_attend", {"b": b, "k": k},
                    lambda x, q, kk, kv, km, wo, fn_, wg, wu, wd: M.decode_attend(
                        cfg, x, q, kk, kv, km, wo, fn_, wg, wu, wd
                    ),
                    [
                        spec([b, d]), spec([b, hq, dh]),
                        spec([b, hkv, s, dh]), spec([b, hkv, s, dh]), spec([b, hkv, s]),
                        lw_specs["wo"], lw_specs["ffn_norm"],
                        lw_specs["w_gate"], lw_specs["w_up"], lw_specs["w_down"],
                    ],
                )
            )
        arts.append(
            Artifact(
                f"lm_head_{b}", "lm_head", {"b": b},
                M.lm_head,
                [spec([b, d]), spec([d]), spec([d, v])],
            )
        )
    return arts


def default_buckets(cfg: M.ModelConfig, fast: bool):
    if fast:
        return {
            "prefill_t": [64, 256],
            "chunk_t": [64],
            "chunk_past": 256,
            "decode_b": [1, 2],
            "budget_k": [4, cfg.max_blocks],
        }
    return {
        "prefill_t": [64, 256, 1024, 2048],
        "chunk_t": [64, 256],
        "chunk_past": 2048,
        "decode_b": [1, 2, 4, 8],
        "budget_k": [4, 16, cfg.max_blocks],
    }


def make_goldens(cfg, weights, buckets):
    """Greedy-token traces the rust pipeline must reproduce exactly."""
    rng = np.random.default_rng(42)
    cases = []
    specs = [
        ("full_budget_short", 50, 8, None),
        ("sparse_budget4", 100, 8, 4),
        ("sparse_budget16", 150, 6, 16),
    ]
    for name, plen, steps, budget in specs:
        if budget is not None and budget not in buckets["budget_k"]:
            continue
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        toks, _ = P.run_pipeline(
            cfg, weights, prompt, steps,
            budget_blocks=budget, seg_buckets=buckets["prefill_t"],
        )
        cases.append(
            {
                "name": name,
                "prompt": prompt.tolist(),
                "n_steps": steps,
                "budget_blocks": budget,
                "tokens": toks.tolist(),
            }
        )
    return cases


def compile_config(cfg: M.ModelConfig, out_dir: str, seed: int, fast: bool, skip_golden: bool):
    os.makedirs(out_dir, exist_ok=True)
    buckets = default_buckets(cfg, fast)
    arts = build_artifacts(cfg, buckets)

    weights = M.init_weights(cfg, seed=seed)
    shapes = M.weight_shapes(cfg)
    offset = 0
    weight_entries = []
    with open(os.path.join(out_dir, "weights.bin"), "wb") as fbin:
        for name, shape in shapes.items():
            arr = weights[name]
            assert arr.shape == tuple(shape) and arr.dtype == np.float32
            fbin.write(arr.tobytes())
            weight_entries.append(
                {"name": name, "shape": list(shape), "offset_f32": offset}
            )
            offset += arr.size

    entries = []
    for art in arts:
        t0 = time.time()
        text = to_hlo_text(art.fn, *art.specs)
        path = os.path.join(out_dir, f"{art.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries.append(art.describe())
        print(f"  {art.name}: {len(text)} chars in {time.time() - t0:.1f}s")

    manifest = {
        "model": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "ffn_dim": cfg.ffn_dim,
            "block_size": cfg.block_size,
            "max_ctx": cfg.max_ctx,
            "rope_theta": cfg.rope_theta,
        },
        "seed": seed,
        "buckets": buckets,
        "weights_bin": "weights.bin",
        "total_f32": offset,
        "weights": weight_entries,
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if not skip_golden:
        t0 = time.time()
        goldens = make_goldens(cfg, weights, buckets)
        with open(os.path.join(out_dir, "golden.json"), "w") as f:
            json.dump(goldens, f)
        print(f"  goldens: {len(goldens)} cases in {time.time() - t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", default="tiny-llm", choices=sorted(M.CONFIGS))
    ap.add_argument("--all-configs", action="store_true")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--fast", action="store_true", help="small bucket set (tests)")
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()

    names = sorted(M.CONFIGS) if args.all_configs else [args.config]
    for name in names:
        cfg = M.CONFIGS[name]
        out_dir = os.path.join(args.out, name)
        print(f"[aot] {name} -> {out_dir}")
        compile_config(cfg, out_dir, args.seed, args.fast, args.skip_golden)
    print("[aot] done")


if __name__ == "__main__":
    main()
