"""L2: the jax model — a llama-style transformer wired for SparseServe.

The model is *deliberately split* into per-layer / per-phase entry points so
the rust coordinator (L3) owns the control loop the paper's system
contribution lives in: layer-segmented prefill calls ``prefill_layer`` once
per layer over the whole prompt; decode calls ``decode_qkv`` (projection +
RoPE + DSA block scoring), hands control back to rust for top-k selection and
HBM/DRAM block residency (FlashH2D), then calls ``decode_attend`` (sparse
attention over the gathered blocks + output projection + FFN).

Every entry point is a pure function of arrays (weights are parameters, not
constants) so a single AOT-lowered executable serves all layers.

Python runs only at build time: ``aot.py`` lowers these functions to HLO
text; the rust runtime loads and executes them via PJRT.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import (
    block_meta_cuboid,
    block_meta_mean,
    prefill_causal_attention,
    ref,
    score_blocks_cuboid,
    score_blocks_mean,
    sparse_decode_attention,
)

NEG_INF = ref.NEG_INF
RMS_EPS = 1e-5


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (llama-family)."""

    name: str = "tiny-llm"
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 32
    ffn_dim: int = 512
    block_size: int = 16  # tokens per KV block (the DSA selection unit)
    max_ctx: int = 2048
    rope_theta: float = 10000.0

    @property
    def max_blocks(self) -> int:
        return self.max_ctx // self.block_size

    @property
    def group(self) -> int:
        """Query heads per KV head (GQA group size)."""
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads


TINY_LLM = ModelConfig()
TINY_GQA = ModelConfig(name="tiny-gqa", n_kv_heads=2)

CONFIGS = {c.name: c for c in (TINY_LLM, TINY_GQA)}

LAYER_WEIGHT_NAMES = (
    "attn_norm",
    "wq",
    "wk",
    "wv",
    "wo",
    "ffn_norm",
    "w_gate",
    "w_up",
    "w_down",
)
GLOBAL_WEIGHT_NAMES = ("embedding", "final_norm", "lm_head")


def weight_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    """Name -> shape for every weight tensor, in a stable order."""
    d, hq, hkv, dh, f = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.ffn_dim
    shapes: Dict[str, tuple] = {"embedding": (cfg.vocab, d)}
    per_layer = {
        "attn_norm": (d,),
        "wq": (d, hq * dh),
        "wk": (d, hkv * dh),
        "wv": (d, hkv * dh),
        "wo": (hq * dh, d),
        "ffn_norm": (d,),
        "w_gate": (d, f),
        "w_up": (d, f),
        "w_down": (f, d),
    }
    for i in range(cfg.n_layers):
        for n, s in per_layer.items():
            shapes[f"l{i}.{n}"] = s
    shapes["final_norm"] = (d,)
    shapes["lm_head"] = (d, cfg.vocab)
    return shapes


def init_weights(cfg: ModelConfig, seed: int = 1234) -> Dict[str, np.ndarray]:
    """Deterministic random weights (the repo ships no pretrained model;
    serving correctness/perf does not depend on weight values)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    for name, shape in weight_shapes(cfg).items():
        if name.endswith("norm"):
            w = np.ones(shape, dtype=np.float32)
        else:
            fan_in = shape[0]
            w = (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(np.float32)
        out[name] = w
    return out


def layer_weights(weights: Dict[str, np.ndarray], i: int) -> list:
    """The per-layer weight list in LAYER_WEIGHT_NAMES order."""
    return [weights[f"l{i}.{n}"] for n in LAYER_WEIGHT_NAMES]


# --------------------------------------------------------------------------
# Primitive blocks
# --------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + RMS_EPS) * w).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float, pos_axis: int = -2) -> jnp.ndarray:
    """Rotary embedding. ``positions`` runs along ``pos_axis`` of x
    (default -2: x is [H, T, D] or [B, H, D] with positions [T] / [B] —
    for the decode case pass pos_axis=0)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) * 2.0 / d))
    ang = positions.astype(jnp.float32)[:, None] * freqs  # [L, half]
    # reshape so L lands on pos_axis and half on the last axis
    pos_axis = pos_axis % x.ndim
    shape = [1] * x.ndim
    shape[pos_axis] = ang.shape[0]
    shape[-1] = half
    ang = ang.reshape(shape)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(
        x.dtype
    )


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    g = x @ w_gate
    return (jax.nn.silu(g) * (x @ w_up)) @ w_down


def _pick_tile(n: int) -> int:
    """Largest flash tile in {128, 64, 32, 16, 8} dividing n.

    128x128 q/kv tiles keep the VMEM footprint at ~200 KB (q + k + v +
    accumulators at Dh<=128) while quartering the grid-loop trip count
    versus 64 — the dominant prefill cost under interpret mode and a
    better MXU shape on real TPUs.
    """
    for t in (128, 64, 32, 16, 8):
        if n % t == 0:
            return t
    return n  # tiny odd segment: single tile


def repeat_kv(x: jnp.ndarray, group: int, axis: int = 1) -> jnp.ndarray:
    """Expand a KV-head axis to the query-head count (GQA)."""
    if group == 1:
        return x
    return jnp.repeat(x, group, axis=axis)


# --------------------------------------------------------------------------
# Entry points (AOT-lowered; every array argument becomes an HLO parameter)
# --------------------------------------------------------------------------


def embed(tokens: jnp.ndarray, embedding: jnp.ndarray):
    """tokens [T] i32 -> hidden [T, d]."""
    return (jnp.take(embedding, tokens, axis=0),)


def prefill_layer(
    cfg: ModelConfig,
    x: jnp.ndarray,  # [T, d]
    pos_offset: jnp.ndarray,  # scalar i32: absolute position of x[0]
    seg_mask: jnp.ndarray,  # [T] additive; NEG_INF on padded tail slots
    past_k: jnp.ndarray,  # [Hkv, P, Dh] roped keys of preceding chunks (P may be 0)
    past_v: jnp.ndarray,  # [Hkv, P, Dh]
    past_mask: jnp.ndarray,  # [P] additive; NEG_INF on unused past slots
    attn_norm, wq, wk, wv, wo, ffn_norm, w_gate, w_up, w_down,
    interpret: bool = True,
):
    """One transformer layer over a prompt segment.

    Layer-segmented prefill passes P=0 (no past) and the full prompt as one
    segment; the chunked-prefill baseline passes the accumulated past KV
    (padded to a static bucket) and the current chunk as the segment.

    Returns (k [Hkv, T, Dh] roped, v [Hkv, T, Dh], x_out [T, d]).
    The caller (rust) saves k/v into DRAM KV blocks via FlashD2H and
    computes block metadata from k.
    """
    t = x.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = past_k.shape[1]

    h = rmsnorm(x, attn_norm)
    q = (h @ wq).reshape(t, hq, dh).transpose(1, 0, 2)  # [Hq, T, Dh]
    k = (h @ wk).reshape(t, hkv, dh).transpose(1, 0, 2)  # [Hkv, T, Dh]
    v = (h @ wv).reshape(t, hkv, dh).transpose(1, 0, 2)

    positions = pos_offset + jnp.arange(t, dtype=jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if p > 0:
        kv_k = jnp.concatenate([past_k, k], axis=1)  # [Hkv, P+T, Dh]
        kv_v = jnp.concatenate([past_v, v], axis=1)
        kvmask = jnp.concatenate([past_mask, seg_mask], axis=0)
        kv_offset = p
    else:
        kv_k, kv_v, kvmask, kv_offset = k, v, seg_mask, 0

    g = cfg.group
    attn = prefill_causal_attention(
        q,
        repeat_kv(kv_k, g, axis=0),
        repeat_kv(kv_v, g, axis=0),
        kvmask,
        kv_offset=kv_offset,
        q_tile=_pick_tile(t),
        k_tile=_pick_tile(kv_k.shape[1]),
        interpret=interpret,
    )  # [Hq, T, Dh]

    attn = attn.transpose(1, 0, 2).reshape(t, hq * dh)
    x1 = x + attn @ wo
    x2 = x1 + swiglu(rmsnorm(x1, ffn_norm), w_gate, w_up, w_down)
    return k, v, x2


def decode_qkv(
    cfg: ModelConfig,
    x: jnp.ndarray,  # [B, d] layer input hidden
    positions: jnp.ndarray,  # [B] i32 absolute positions of the new token
    meta_lo: jnp.ndarray,  # [B, Hkv, NB, Dh] cuboid-lo (roped-key space)
    meta_hi: jnp.ndarray,  # [B, Hkv, NB, Dh] cuboid-hi
    meta_mask: jnp.ndarray,  # [B, Hkv, NB] additive; NEG_INF for absent blocks
    attn_norm, wq, wk, wv,
    interpret: bool = True,
):
    """Projection + RoPE + DSA block scoring for one decode step.

    Returns (q [B, Hq, Dh], k [B, Hkv, Dh], v [B, Hkv, Dh],
    scores [B, Hkv, NB]). Scores are group-aggregated (max over the query
    heads of each KV head) so rust selects and gathers at KV-head
    granularity; rust performs top-k and block residency (FlashH2D).
    """
    b = x.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    h = rmsnorm(x, attn_norm)
    q = (h @ wq).reshape(b, hq, dh)
    k = (h @ wk).reshape(b, hkv, dh)
    v = (h @ wv).reshape(b, hkv, dh)
    q = rope(q, positions, cfg.rope_theta, pos_axis=0)
    k = rope(k, positions, cfg.rope_theta, pos_axis=0)

    g = cfg.group
    lo = repeat_kv(meta_lo, g, axis=1)  # [B, Hq, NB, Dh]
    hi = repeat_kv(meta_hi, g, axis=1)
    m = repeat_kv(meta_mask, g, axis=1)
    scores_q = score_blocks_cuboid(q, lo, hi, m, interpret=interpret)  # [B, Hq, NB]
    scores = jnp.max(scores_q.reshape(b, hkv, g, -1), axis=2)  # [B, Hkv, NB]
    return q, k, v, scores


def decode_attend(
    cfg: ModelConfig,
    x: jnp.ndarray,  # [B, d] residual (layer input)
    q: jnp.ndarray,  # [B, Hq, Dh] from decode_qkv
    kv_k: jnp.ndarray,  # [B, Hkv, S, Dh] gathered selected blocks (roped keys)
    kv_v: jnp.ndarray,  # [B, Hkv, S, Dh]
    kv_mask: jnp.ndarray,  # [B, Hkv, S] additive; NEG_INF on invalid slots
    wo, ffn_norm, w_gate, w_up, w_down,
    interpret: bool = True,
):
    """Sparse attention over the gathered blocks + out-proj + FFN.

    Returns (x_out [B, d],).
    """
    b = x.shape[0]
    hq, dh = cfg.n_heads, cfg.head_dim
    g = cfg.group

    # s_tile: pack several KV blocks per grid step — fewer while-loop
    # iterations in the lowered HLO (the dominant decode cost on CPU; on
    # TPU the larger tile also feeds the MXU better). Must divide S and be
    # a multiple of block_size so the mask layout stays block-aligned.
    s = kv_k.shape[2]
    s_tile = next(t for t in (128, 64, 32, 16, 8) if s % t == 0 and t % min(cfg.block_size, t) == 0)
    attn = sparse_decode_attention(
        q,
        repeat_kv(kv_k, g, axis=1),
        repeat_kv(kv_v, g, axis=1),
        repeat_kv(kv_mask, g, axis=1),
        s_tile=min(s_tile, s),
        interpret=interpret,
    )  # [B, Hq, Dh]

    x1 = x + attn.reshape(b, hq * dh) @ wo
    x2 = x1 + swiglu(rmsnorm(x1, ffn_norm), w_gate, w_up, w_down)
    return (x2,)


def lm_head(x: jnp.ndarray, final_norm: jnp.ndarray, w_lm: jnp.ndarray):
    """hidden [B, d] -> (greedy next token [B] i32, logits [B, V])."""
    h = rmsnorm(x, final_norm)
    logits = h @ w_lm
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits


def build_block_metadata(
    cfg: ModelConfig, k_layer: jnp.ndarray, interpret: bool = True
):
    """Cuboid metadata for whole blocks of a layer's roped keys.

    k_layer: [Hkv, T, Dh] with T a multiple of block_size ->
    (lo, hi) each [Hkv, NB, Dh]. Exposed as an AOT entry point so rust can
    (re)build metadata after prefill; decode-time incremental metadata is
    maintained by rust directly (running min/max over the open block).
    """
    hkv, t, dh = k_layer.shape
    bs = cfg.block_size
    nb = t // bs
    blocks = k_layer.reshape(hkv, nb, bs, dh)
    return block_meta_cuboid(blocks, interpret=interpret)


# --------------------------------------------------------------------------
# Dense reference (golden generator + parity oracle for the split pipeline)
# --------------------------------------------------------------------------


def reference_forward(
    cfg: ModelConfig, weights: Dict[str, np.ndarray], tokens: np.ndarray
) -> np.ndarray:
    """Full dense forward over a token sequence -> logits [T, V].

    Straight-line jnp implementation (no pallas, no splitting); the oracle
    the AOT pipeline must reproduce when the DSA budget covers all blocks.
    """
    x = jnp.take(jnp.asarray(weights["embedding"]), jnp.asarray(tokens), axis=0)
    t = x.shape[0]
    positions = jnp.arange(t, dtype=jnp.int32)
    for i in range(cfg.n_layers):
        aw = {n: jnp.asarray(weights[f"l{i}.{n}"]) for n in LAYER_WEIGHT_NAMES}
        h = rmsnorm(x, aw["attn_norm"])
        q = (h @ aw["wq"]).reshape(t, cfg.n_heads, cfg.head_dim).transpose(1, 0, 2)
        k = (h @ aw["wk"]).reshape(t, cfg.n_kv_heads, cfg.head_dim).transpose(1, 0, 2)
        v = (h @ aw["wv"]).reshape(t, cfg.n_kv_heads, cfg.head_dim).transpose(1, 0, 2)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        attn = ref.prefill_causal_attention(
            q, repeat_kv(k, cfg.group, 0), repeat_kv(v, cfg.group, 0)
        )
        attn = attn.transpose(1, 0, 2).reshape(t, cfg.n_heads * cfg.head_dim)
        x1 = x + attn @ aw["wo"]
        x = x1 + swiglu(rmsnorm(x1, aw["ffn_norm"]), aw["w_gate"], aw["w_up"], aw["w_down"])
    h = rmsnorm(x, jnp.asarray(weights["final_norm"]))
    return np.asarray(h @ jnp.asarray(weights["lm_head"]))


def reference_generate(
    cfg: ModelConfig,
    weights: Dict[str, np.ndarray],
    prompt: np.ndarray,
    n_steps: int,
) -> np.ndarray:
    """Greedy generation by repeated dense forward (golden tokens)."""
    toks = list(int(t) for t in prompt)
    out = []
    for _ in range(n_steps):
        logits = reference_forward(cfg, weights, np.asarray(toks, dtype=np.int32))
        nxt = int(np.argmax(logits[-1]))
        toks.append(nxt)
        out.append(nxt)
    return np.asarray(out, dtype=np.int32)
