"""AOT artifact sanity: manifest consistency, HLO text validity, goldens."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny-llm")


def test_to_hlo_text_produces_parseable_module():
    import jax.numpy as jnp

    txt = aot.to_hlo_text(
        lambda x, y: (jnp.matmul(x, y) + 1.0,),
        aot.spec([4, 4]), aot.spec([4, 4]),
    )
    assert txt.startswith("HloModule")
    assert "parameter(0)" in txt and "parameter(1)" in txt


def test_default_buckets_cover_max_ctx():
    cfg = M.TINY_LLM
    b = aot.default_buckets(cfg, fast=False)
    assert max(b["prefill_t"]) == cfg.max_ctx
    assert cfg.max_blocks in b["budget_k"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_every_entry_file_exists_and_is_hlo(self, manifest):
        for e in manifest["entries"]:
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), e["file"]
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), e["file"]

    def test_weights_bin_size_matches_manifest(self, manifest):
        size = os.path.getsize(os.path.join(ART, manifest["weights_bin"]))
        assert size == manifest["total_f32"] * 4
        total = sum(int(np.prod(w["shape"])) for w in manifest["weights"])
        assert total == manifest["total_f32"]

    def test_weights_reproducible_from_seed(self, manifest):
        cfg = M.CONFIGS[manifest["model"]["name"]]
        w = M.init_weights(cfg, seed=manifest["seed"])
        raw = np.fromfile(os.path.join(ART, manifest["weights_bin"]), dtype=np.float32)
        first = manifest["weights"][0]
        got = raw[first["offset_f32"]: first["offset_f32"] + int(np.prod(first["shape"]))]
        np.testing.assert_array_equal(got, w[first["name"]].ravel())

    def test_goldens_match_fresh_pipeline(self, manifest):
        """Regenerating one golden case from scratch yields identical tokens
        (determinism of the whole python stack)."""
        from compile import pipeline as P

        with open(os.path.join(ART, "golden.json")) as f:
            goldens = json.load(f)
        cfg = M.CONFIGS[manifest["model"]["name"]]
        w = M.init_weights(cfg, seed=manifest["seed"])
        case = goldens[0]
        toks, _ = P.run_pipeline(
            cfg, w, np.asarray(case["prompt"], dtype=np.int32), case["n_steps"],
            budget_blocks=case["budget_blocks"],
            seg_buckets=manifest["buckets"]["prefill_t"],
        )
        assert toks.tolist() == case["tokens"]

    def test_entry_coverage(self, manifest):
        kinds = {e["kind"] for e in manifest["entries"]}
        assert kinds == {
            "embed", "prefill_layer", "prefill_chunk", "block_meta",
            "decode_qkv", "decode_attend", "lm_head",
        }
