"""L2 model correctness: the split AOT pipeline vs the dense oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import pipeline as P


@pytest.fixture(scope="module")
def small_cfg():
    return M.ModelConfig(name="test-small", n_layers=2, max_ctx=256)


@pytest.fixture(scope="module")
def small_weights(small_cfg):
    return M.init_weights(small_cfg, seed=7)


def test_weight_shapes_complete(small_cfg, small_weights):
    shapes = M.weight_shapes(small_cfg)
    assert set(shapes) == set(small_weights)
    # embedding + lm_head + final_norm + 9 weights x 2 layers
    assert len(shapes) == 3 + 9 * small_cfg.n_layers


def test_rmsnorm_unit_scale():
    x = jnp.asarray([[3.0, -4.0]])
    out = np.asarray(M.rmsnorm(x, jnp.ones(2)))
    # rms = sqrt((9+16)/2) = sqrt(12.5)
    np.testing.assert_allclose(out, np.asarray(x) / np.sqrt(12.5), rtol=1e-4)


def test_rope_preserves_norm_and_zero_pos_identity():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), dtype=jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)
    out = M.rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(x[:, 0]), rtol=1e-6)


def test_rope_relative_property():
    """q(m).k(n) must depend only on m-n (the RoPE invariant)."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, 16)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 16)), dtype=jnp.float32)

    def dot(m, n):
        qm = M.rope(q, jnp.asarray([m], dtype=jnp.int32), 10000.0)
        kn = M.rope(k, jnp.asarray([n], dtype=jnp.int32), 10000.0)
        return float(jnp.sum(qm * kn))

    assert abs(dot(5, 3) - dot(10, 8)) < 1e-4
    assert abs(dot(7, 0) - dot(107, 100)) < 1e-3


@pytest.mark.parametrize("plen,steps", [(20, 4), (70, 5)])
def test_pipeline_full_budget_matches_dense(small_cfg, small_weights, plen, steps):
    rng = np.random.default_rng(plen)
    prompt = rng.integers(0, small_cfg.vocab, size=plen).astype(np.int32)
    golden = M.reference_generate(small_cfg, small_weights, prompt, steps)
    toks, _ = P.run_pipeline(
        small_cfg, small_weights, prompt, steps, budget_blocks=None,
        seg_buckets=[64, 256],
    )
    assert (toks == golden).all()


def test_pipeline_gqa_full_budget_matches_dense():
    cfg = M.ModelConfig(name="test-gqa", n_layers=2, n_kv_heads=2, max_ctx=256)
    w = M.init_weights(cfg, seed=8)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=40).astype(np.int32)
    golden = M.reference_generate(cfg, w, prompt, 4)
    toks, _ = P.run_pipeline(cfg, w, prompt, 4, seg_buckets=[64, 256])
    assert (toks == golden).all()


def test_pipeline_chunked_prefill_matches_dense(small_cfg, small_weights):
    """Chunked prefill (chunks + padded past) must equal one-shot prefill."""
    cfg, w = small_cfg, small_weights
    rng = np.random.default_rng(3)
    plen = 96
    prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
    wj = {k: jnp.asarray(v) for k, v in w.items()}

    # one-shot oracle
    logits = M.reference_forward(cfg, w, prompt)
    want = int(np.argmax(logits[-1]))

    # chunked: 3 chunks of 32, padded to segment bucket 32, past bucket 128
    chunk, p_max = 32, 128
    (x_all,) = M.embed(jnp.asarray(prompt), wj["embedding"])
    xs = [x_all[i : i + chunk] for i in range(0, plen, chunk)]
    past_k = [np.zeros((cfg.n_kv_heads, p_max, cfg.head_dim), np.float32) for _ in range(cfg.n_layers)]
    past_v = [np.zeros((cfg.n_kv_heads, p_max, cfg.head_dim), np.float32) for _ in range(cfg.n_layers)]
    past_len = 0
    x_last = None
    for ci, xc in enumerate(xs):
        seg_mask = jnp.zeros((chunk,), dtype=jnp.float32)
        pmask = np.full((p_max,), M.NEG_INF, np.float32)
        pmask[:past_len] = 0.0
        x = xc
        for i in range(cfg.n_layers):
            k, v, x = M.prefill_layer(
                cfg, x, jnp.int32(ci * chunk), seg_mask,
                jnp.asarray(past_k[i]), jnp.asarray(past_v[i]), jnp.asarray(pmask),
                *(wj[f"l{i}.{n}"] for n in M.LAYER_WEIGHT_NAMES),
            )
            past_k[i][:, past_len : past_len + chunk] = np.asarray(k)
            past_v[i][:, past_len : past_len + chunk] = np.asarray(v)
        past_len += chunk
        x_last = x
    nxt, _ = M.lm_head(x_last[chunk - 1 : chunk], wj["final_norm"], wj["lm_head"])
    assert int(np.asarray(nxt)[0]) == want


def test_sparse_budget_degrades_gracefully(small_cfg, small_weights):
    """A sparse budget must still produce valid tokens (and differ from the
    dense trace only after the budget actually binds)."""
    cfg, w = small_cfg, small_weights
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, size=100).astype(np.int32)
    toks, trace = P.run_pipeline(
        cfg, w, prompt, 5, budget_blocks=3, record_selected=True,
        seg_buckets=[64, 256],
    )
    assert ((0 <= toks) & (toks < cfg.vocab)).all()
    # selection respects the budget: <= budget blocks gathered per head
    for step in trace:
        for layer_sel in step:
            assert len(layer_sel) <= 3 * cfg.n_kv_heads


def test_selection_has_temporal_locality(small_cfg, small_weights):
    """Fig. 8's premise: consecutive steps select overlapping block sets."""
    cfg, w = small_cfg, small_weights
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab, size=200).astype(np.int32)
    _, trace = P.run_pipeline(
        cfg, w, prompt, 8, budget_blocks=4, record_selected=True,
        seg_buckets=[64, 256],
    )
    overlaps = []
    for s in range(1, len(trace)):
        prev = set(trace[s - 1][0])
        cur = set(trace[s][0])
        if cur:
            overlaps.append(len(prev & cur) / len(cur))
    assert sum(overlaps) / len(overlaps) > 0.3  # weak bound; real models ~0.9


def test_kv_state_seal_and_metadata(small_cfg):
    st = P.KvState(small_cfg)
    rng = np.random.default_rng(0)
    bs = small_cfg.block_size
    for t in range(bs + 3):
        st.append(
            rng.standard_normal((small_cfg.n_kv_heads, small_cfg.head_dim)).astype(np.float32),
            rng.standard_normal((small_cfg.n_kv_heads, small_cfg.head_dim)).astype(np.float32),
        )
    assert st.n_sealed == 1 and st.open_fill == 3
    np.testing.assert_array_equal(st.lo[:, 0], st.k[:, 0].min(axis=1))
    np.testing.assert_array_equal(st.hi[:, 0], st.k[:, 0].max(axis=1))
