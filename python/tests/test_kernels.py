"""L1 kernel correctness: every pallas kernel (interpret=True) vs the
pure-jnp oracle in ref.py, swept over shapes and dtypes with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-6)


def randf(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32).astype(dtype)


def randmask(rng, shape, p=0.25):
    return jnp.where(jnp.asarray(rng.random(shape) < p), ref.NEG_INF, 0.0).astype(jnp.float32)


# ---------------------------------------------------------------- block_meta


@settings(**SETTINGS)
@given(
    h=st.integers(1, 4),
    nb=st.integers(1, 8),
    bs=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_block_meta_mean(h, nb, bs, d, seed):
    rng = np.random.default_rng(seed)
    keys = randf(rng, (h, nb, bs, d))
    np.testing.assert_allclose(
        K.block_meta_mean(keys), ref.block_meta_mean(keys), rtol=1e-6, atol=1e-6
    )


@settings(**SETTINGS)
@given(
    h=st.integers(1, 4),
    nb=st.integers(1, 8),
    bs=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_block_meta_cuboid(h, nb, bs, d, seed):
    rng = np.random.default_rng(seed)
    keys = randf(rng, (h, nb, bs, d))
    lo, hi = K.block_meta_cuboid(keys)
    rlo, rhi = ref.block_meta_cuboid(keys)
    np.testing.assert_array_equal(lo, rlo)
    np.testing.assert_array_equal(hi, rhi)
    assert (np.asarray(lo) <= np.asarray(hi)).all()


def test_block_meta_single_token_block():
    # Bs=1: mean == lo == hi == the key itself
    rng = np.random.default_rng(0)
    keys = randf(rng, (2, 3, 1, 8))
    lo, hi = K.block_meta_cuboid(keys)
    np.testing.assert_array_equal(lo, keys[:, :, 0])
    np.testing.assert_array_equal(hi, keys[:, :, 0])
    np.testing.assert_allclose(K.block_meta_mean(keys), keys[:, :, 0], rtol=1e-6)


# -------------------------------------------------------------- block_select


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    nb=st.sampled_from([1, 4, 16, 64]),
    d=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**16),
)
def test_score_blocks_mean(b, h, nb, d, seed):
    rng = np.random.default_rng(seed)
    q = randf(rng, (b, h, d))
    meta = randf(rng, (b, h, nb, d))
    mask = randmask(rng, (b, h, nb))
    np.testing.assert_allclose(
        K.score_blocks_mean(q, meta, mask),
        ref.score_blocks_mean(q, meta, mask),
        rtol=1e-4, atol=1e-4,
    )


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    nb=st.sampled_from([1, 4, 16, 64]),
    d=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**16),
)
def test_score_blocks_cuboid(b, h, nb, d, seed):
    rng = np.random.default_rng(seed)
    q = randf(rng, (b, h, d))
    lo = randf(rng, (b, h, nb, d))
    hi = lo + jnp.abs(randf(rng, (b, h, nb, d)))
    mask = randmask(rng, (b, h, nb))
    np.testing.assert_allclose(
        K.score_blocks_cuboid(q, lo, hi, mask),
        ref.score_blocks_cuboid(q, lo, hi, mask),
        rtol=1e-4, atol=1e-4,
    )


def test_cuboid_score_is_upper_bound():
    """The cuboid score must upper-bound q.k for every key inside the cuboid
    (this is the property ArkVale's selection correctness rests on)."""
    rng = np.random.default_rng(5)
    h, nb, bs, d = 2, 6, 8, 16
    keys = randf(rng, (h, nb, bs, d))
    lo, hi = ref.block_meta_cuboid(keys)
    q = randf(rng, (1, h, d))
    mask = jnp.zeros((1, h, nb), dtype=jnp.float32)
    scores = np.asarray(
        K.score_blocks_cuboid(q, lo[None], hi[None], mask)
    )  # [1, h, nb]
    exact = np.einsum("hd,hnsd->hns", np.asarray(q)[0], np.asarray(keys))
    assert (scores[0] >= exact.max(axis=-1) - 1e-4).all()


# ---------------------------------------------------------- sparse attention


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    n_tiles=st.integers(1, 6),
    s_tile=st.sampled_from([8, 16]),
    d=st.sampled_from([8, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_sparse_decode_attention(b, h, n_tiles, s_tile, d, dtype, seed):
    rng = np.random.default_rng(seed)
    s = n_tiles * s_tile
    q = randf(rng, (b, h, d), dtype)
    k = randf(rng, (b, h, s, d), dtype)
    v = randf(rng, (b, h, s, d), dtype)
    mask = randmask(rng, (b, h, s))
    # guarantee at least one valid slot per (b, h)
    mask = mask.at[:, :, 0].set(0.0)
    out = K.sparse_decode_attention(q, k, v, mask, s_tile=s_tile)
    want = ref.sparse_decode_attention(q, k, v, mask)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(want, dtype=np.float32), **tol(dtype)
    )


def test_sparse_attention_fully_masked_row_is_finite():
    """A padded batch slot (all KV masked) must not produce NaN/Inf."""
    b, h, s, d = 1, 1, 16, 8
    q = jnp.ones((b, h, d), dtype=jnp.float32)
    k = jnp.zeros((b, h, s, d), dtype=jnp.float32)
    v = jnp.zeros((b, h, s, d), dtype=jnp.float32)
    mask = jnp.full((b, h, s), ref.NEG_INF, dtype=jnp.float32)
    out = np.asarray(K.sparse_decode_attention(q, k, v, mask, s_tile=8))
    assert np.isfinite(out).all()


def test_sparse_attention_matches_dense_softmax():
    """With no mask, sparse decode attention == plain softmax attention."""
    rng = np.random.default_rng(11)
    b, h, s, d = 2, 2, 48, 16
    q, k, v = (randf(rng, sh) for sh in [(b, h, d), (b, h, s, d), (b, h, s, d)])
    mask = jnp.zeros((b, h, s), dtype=jnp.float32)
    out = np.asarray(K.sparse_decode_attention(q, k, v, mask, s_tile=16))
    want = np.asarray(ref.sparse_decode_attention(q, k, v, mask))
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-6)


# --------------------------------------------------------- prefill attention


@settings(**SETTINGS)
@given(
    h=st.integers(1, 4),
    n_q=st.integers(1, 4),
    tile=st.sampled_from([8, 16]),
    d=st.sampled_from([8, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_prefill_causal(h, n_q, tile, d, dtype, seed):
    rng = np.random.default_rng(seed)
    t = n_q * tile
    q, k, v = (randf(rng, (h, t, d), dtype) for _ in range(3))
    out = K.prefill_causal_attention(q, k, v, q_tile=tile, k_tile=tile)
    want = ref.prefill_causal_attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(want, dtype=np.float32), **tol(dtype)
    )


@settings(**SETTINGS)
@given(
    h=st.integers(1, 3),
    n_chunk=st.integers(1, 3),
    n_past=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_prefill_chunked_offset(h, n_chunk, n_past, seed):
    """Chunk attending to accumulated past == the same rows of full causal."""
    rng = np.random.default_rng(seed)
    tile, d = 8, 16
    t_past, t_chunk = n_past * tile, n_chunk * tile
    t = t_past + t_chunk
    q, k, v = (randf(rng, (h, t, d)) for _ in range(3))
    full = ref.prefill_causal_attention(q, k, v)
    out = K.prefill_causal_attention(
        q[:, t_past:], k, v, kv_offset=t_past, q_tile=tile, k_tile=tile
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(full[:, t_past:]), rtol=3e-5, atol=3e-6
    )


def test_prefill_kvmask_padded_past():
    """NEG_INF kvmask slots (padded past) must be ignored entirely."""
    rng = np.random.default_rng(9)
    h, d, tile = 2, 16, 8
    t_chunk, p_valid, p_pad = 16, 8, 24  # past padded from 8 to 24
    q = randf(rng, (h, t_chunk, d))
    past_k, past_v = randf(rng, (h, p_valid, d)), randf(rng, (h, p_valid, d))
    new_k, new_v = randf(rng, (h, t_chunk, d)), randf(rng, (h, t_chunk, d))

    # padded layout: [valid past | garbage | chunk]
    garbage = randf(rng, (h, p_pad - p_valid, d)) * 100.0
    k_pad = jnp.concatenate([past_k, garbage, new_k], axis=1)
    v_pad = jnp.concatenate([past_v, garbage, new_v], axis=1)
    kvmask = jnp.concatenate(
        [
            jnp.zeros((p_valid,)),
            jnp.full((p_pad - p_valid,), ref.NEG_INF),
            jnp.zeros((t_chunk,)),
        ]
    ).astype(jnp.float32)
    out = K.prefill_causal_attention(
        q, k_pad, v_pad, kvmask, kv_offset=p_pad, q_tile=tile, k_tile=tile
    )

    # oracle: compact layout without padding
    k_c = jnp.concatenate([past_k, new_k], axis=1)
    v_c = jnp.concatenate([past_v, new_v], axis=1)
    want = ref.prefill_causal_attention(q, k_c, v_c, kv_offset=p_valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-6)


# ------------------------------------------------------------------- topk


def test_topk_blocks_masked_never_selected():
    scores = jnp.asarray(
        [[[1.0, ref.NEG_INF, 3.0, 2.0, ref.NEG_INF]]], dtype=jnp.float32
    )
    idx = np.asarray(ref.topk_blocks(scores, 3))
    assert set(idx[0, 0].tolist()) == {0, 2, 3}
