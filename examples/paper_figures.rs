//! Regenerate every table and figure of the paper's evaluation section.
//!
//!     cargo run --release --example paper_figures            # everything
//!     cargo run --release --example paper_figures -- --only fig10
//!
//! Simulated experiments (Figs 1, 4, 10-16) run at paper scale on the
//! A100 testbed substitute; Fig 8 and Table 1 execute the REAL tiny-llm
//! artifacts (skipped with a notice if `make artifacts` hasn't run).

use std::sync::Arc;

use anyhow::Result;
use sparseserve::figures::{self, sim_exp};
use sparseserve::runtime::Runtime;
use sparseserve::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let only = args.get("only").map(str::to_string);
    let want = |name: &str| only.as_deref().map(|o| name.starts_with(o)).unwrap_or(true);

    if want("fig1") {
        println!("{}", sim_exp::fig1());
    }
    if want("fig4") {
        println!("{}", sim_exp::fig4());
    }
    if want("fig8") || want("table1") {
        let dir = Runtime::default_dir("tiny-llm");
        if dir.join("manifest.json").exists() {
            let rt = Arc::new(Runtime::load(dir)?);
            if want("fig8") {
                println!("{}", figures::fig8_overlap(rt.clone())?);
            }
            if want("table1") {
                println!("{}", figures::table1_accuracy(rt)?);
            }
        } else {
            println!("(fig8/table1 skipped: run `make artifacts` first)\n");
        }
    }
    if want("fig10") || want("fig11") || want("fig12") {
        for model in ["lwm-7b", "llama3-8b"] {
            println!("{}", sim_exp::fig10_11_12(model, &sim_exp::default_rates(model)));
        }
    }
    if want("fig13") {
        println!("{}", sim_exp::fig13("lwm-7b"));
        println!("{}", sim_exp::fig13("llama3-8b"));
    }
    if want("fig14") {
        println!("{}", sim_exp::fig14a());
        println!("{}", sim_exp::fig14b());
    }
    if want("fig15") {
        println!("{}", sim_exp::fig15(&[0.1, 0.2, 0.3, 0.4, 0.5]));
    }
    if want("fig16") {
        println!("{}", sim_exp::fig16a(&[0.05, 0.15, 0.25, 0.35]));
        println!("{}", sim_exp::fig16b());
    }
    if want("prefetch") {
        println!("{}", sim_exp::fig_prefetch(&[0.2, 0.35]));
    }
    if want("layer-model") {
        println!("{}", sim_exp::fig_layer_model(&[0.2, 0.35]));
    }
    if want("layer-skew") {
        println!("{}", sim_exp::fig_layer_skew(&[0.2, 0.35]));
    }
    Ok(())
}
