//! Quickstart: bring up the SparseServe coordinator on the real PJRT
//! backend, stream tokens for a couple of prompts, and exercise the
//! request lifecycle (priorities, timing report, cancellation).
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;
use sparseserve::config::ServingConfig;
use sparseserve::coordinator::{Server, SubmitRequest};
use sparseserve::engine::PjrtBackend;
use sparseserve::figures::real::demo_prompt;
use sparseserve::runtime::Runtime;
use sparseserve::scheduler::Scheduler;

fn main() -> Result<()> {
    // The engine (PJRT client + scheduler) lives on its own thread.
    let server = Server::start(|| {
        let rt = Arc::new(Runtime::load(Runtime::default_dir("tiny-llm"))?);
        let spec = rt.manifest.model.clone();
        // SparseServe config: 256-token DSA budget (16 blocks), offloaded
        // KV with FlashH2D/FlashD2H transfers, layer-segmented prefill.
        let mut cfg = ServingConfig::sparseserve(256, 64, spec.n_layers);
        cfg.max_inject_tokens = spec.max_ctx * spec.n_layers;
        let hbm = 8 << 20; // scaled-down "HBM" KV cache
        let backend = PjrtBackend::new(rt, cfg.clone(), hbm, 512 << 20);
        let sched = Scheduler::new(cfg, spec, hbm);
        Ok((sched, Box::new(backend) as _))
    });

    println!("submitting two prompts (one interactive, one batch)...");
    let h1 = server.submit(
        SubmitRequest::new(demo_prompt(120, 256, 1))
            .max_new(8)
            .interactive()
            .ttft_slo(5.0),
    );
    let h2 = server.submit(SubmitRequest::new(demo_prompt(400, 256, 2)).max_new(8));
    // a long request we abandon immediately: its KV state is freed
    let h3 = server.submit(SubmitRequest::new(demo_prompt(200, 256, 3)).max_new(512));
    server.cancel(h3.id);

    let (t1, timing1) = h1.collect()?;
    let (t2, timing2) = h2.collect()?;
    println!("request 1 -> {t1:?}");
    println!("  ttft {:.3}s, mean tbt {:.4}s", timing1.ttft_s.unwrap_or(0.0), timing1.tbt_mean_s);
    println!("request 2 -> {t2:?}");
    println!("  ttft {:.3}s, mean tbt {:.4}s", timing2.ttft_s.unwrap_or(0.0), timing2.tbt_mean_s);
    match h3.collect() {
        Err(e) => println!("request 3 -> cancelled as expected: {e}"),
        Ok((t, _)) => println!("request 3 -> finished before cancel: {t:?}"),
    }

    let metrics = server.shutdown()?;
    println!("run metrics: {}", metrics.summary());
    println!("quickstart OK");
    Ok(())
}
