//! Quickstart: bring up the SparseServe coordinator on the real PJRT
//! backend and stream tokens for a couple of prompts.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;
use sparseserve::config::ServingConfig;
use sparseserve::coordinator::Server;
use sparseserve::engine::PjrtBackend;
use sparseserve::figures::real::demo_prompt;
use sparseserve::runtime::Runtime;
use sparseserve::scheduler::Scheduler;

fn main() -> Result<()> {
    // The engine (PJRT client + scheduler) lives on its own thread.
    let server = Server::start(|| {
        let rt = Arc::new(Runtime::load(Runtime::default_dir("tiny-llm"))?);
        let spec = rt.manifest.model.clone();
        // SparseServe config: 256-token DSA budget (16 blocks), offloaded
        // KV with FlashH2D/FlashD2H transfers, layer-segmented prefill.
        let mut cfg = ServingConfig::sparseserve(256, 64, spec.n_layers);
        cfg.max_inject_tokens = spec.max_ctx * spec.n_layers;
        let hbm = 8 << 20; // scaled-down "HBM" KV cache
        let backend = PjrtBackend::new(rt, cfg.clone(), hbm, 512 << 20);
        let sched = Scheduler::new(cfg, spec, hbm);
        Ok((sched, Box::new(backend) as _))
    });

    println!("submitting two prompts...");
    let h1 = server.submit(demo_prompt(120, 256, 1), 8);
    let h2 = server.submit(demo_prompt(400, 256, 2), 8);

    let t1 = h1.collect_tokens().map_err(|e| anyhow::anyhow!(e))?;
    let t2 = h2.collect_tokens().map_err(|e| anyhow::anyhow!(e))?;
    println!("request 1 -> {t1:?}");
    println!("request 2 -> {t2:?}");

    server.shutdown()?;
    println!("quickstart OK");
    Ok(())
}
