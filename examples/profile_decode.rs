// temp profiling driver: where does a real decode step spend time?
use std::collections::HashMap;
use std::sync::Arc;
use sparseserve::config::ServingConfig;
use sparseserve::engine::{drive_step, Backend, PjrtBackend, StageHints};
use sparseserve::runtime::Runtime;
use sparseserve::scheduler::{Batch, Phase, PrefillWork, Request};

fn main() {
    let rt = Arc::new(Runtime::load(Runtime::default_dir("tiny-llm")).unwrap());
    let spec = rt.manifest.model.clone();
    let mut cfg = ServingConfig::sparseserve(256, 64, spec.n_layers);
    cfg.max_inject_tokens = spec.max_ctx * spec.n_layers;
    let mut backend = PjrtBackend::new(rt.clone(), cfg, 8 << 20, 512 << 20);
    let prompt = sparseserve::figures::real::demo_prompt(300, spec.vocab, 5);
    let mut req = Request::with_prompt(1, prompt.clone(), 4096, 0.0);
    req.phase = Phase::Prefill;
    backend.register(&req).unwrap();
    let mut requests = HashMap::new();
    requests.insert(1u32, req);
    let pf = Batch { decodes: vec![], prefill: Some(PrefillWork::LayerSegment{
        req:1, layer_start:0, layer_end: spec.n_layers, tok_start:0, tok_len: prompt.len(), is_last:true}) };
    let hints = StageHints::default();
    drive_step(&mut backend, &pf, &requests, &hints).unwrap();
    requests.get_mut(&1).unwrap().phase = Phase::Decode;
    let db = Batch { decodes: vec![1], prefill: None };
    let t0 = std::time::Instant::now();
    let n = 100;
    for _ in 0..n { drive_step(&mut backend, &db, &requests, &hints).unwrap(); }
    let total = t0.elapsed().as_secs_f64();
    println!("decode step mean: {:.3} ms", total / n as f64 * 1e3);
    println!("{:<22} {:>6} {:>10} {:>10}", "entry", "calls", "total_s", "ms/call");
    let mut pjrt_total = 0.0;
    for (name, calls, secs) in rt.exec_stats() {
        println!("{:<22} {:>6} {:>10.3} {:>10.3}", name, calls, secs, secs / calls as f64 * 1e3);
        pjrt_total += secs;
    }
    println!("PJRT total: {:.3}s of {:.3}s wall ({:.1}% — rest is L3 host work)", pjrt_total, total, 100.0*pjrt_total/total);
}
