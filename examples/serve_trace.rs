//! End-to-end validation run (EXPERIMENTS.md §E2E): serve a Poisson
//! trace of mixed-length requests on the REAL three-layer stack
//! (tiny-llm artifacts via PJRT) and report TTFT / TBT / throughput.
//!
//!     make artifacts && cargo run --release --example serve_trace -- \
//!         --requests 8 --rate 2.0 --system sparseserve
//!
//! `--system vllm|vllm-s|vllm-so|sparseserve` switches the serving policy
//! (same comparison set as the paper's §4.2, at tiny scale).

use std::sync::Arc;

use anyhow::{anyhow, Result};
use sparseserve::baselines;
use sparseserve::engine::{Engine, PjrtBackend};
use sparseserve::runtime::Runtime;
use sparseserve::scheduler::Scheduler;
use sparseserve::util::cli::Args;
use sparseserve::workload::{generate_with_tokens, WorkloadSpec};

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.usize("requests", 8);
    let rate = args.f64("rate", 2.0);
    let system = args.get_or("system", "sparseserve");
    let seed = args.usize("seed", 7) as u64;

    let rt = Arc::new(Runtime::load(Runtime::default_dir("tiny-llm"))?);
    let spec = rt.manifest.model.clone();
    let mut cfg = baselines::by_name(&system, 256, 64, spec.n_layers)
        .ok_or_else(|| anyhow!("unknown system '{system}'"))?;
    cfg.max_inject_tokens = spec.max_ctx * spec.n_layers;
    cfg.chunk_tokens = 64;
    cfg.t_max = 512;

    let hbm = args.usize("hbm-bytes", 8 << 20);
    let backend = PjrtBackend::new(rt.clone(), cfg.clone(), hbm, 512 << 20);
    let sched = Scheduler::new(cfg, spec.clone(), hbm);
    let engine = Engine::new(sched, Box::new(backend));

    let wl = WorkloadSpec::tiny(rate, seed);
    let trace = generate_with_tokens(&wl, n, 1, spec.vocab);
    println!("[serve_trace] system={system} backend=pjrt model={} n={n} rate={rate}rps", spec.name);
    for r in &trace {
        println!("  req {}: prompt={} max_new={} arrival={:.2}s", r.id, r.prompt_len, r.max_new_tokens, r.arrival_s);
    }

    let t0 = std::time::Instant::now();
    let report = engine.run_trace(trace, 1e6)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("[serve_trace] wall time {wall:.1}s, {} PJRT executions", rt.exec_count.load(std::sync::atomic::Ordering::Relaxed));
    println!("[serve_trace] {}", report.metrics.summary());
    println!(
        "[serve_trace] TTFT p50={:.3}s | TBT p50={:.4}s p99={:.4}s | loads/iter p99={:.0}",
        report.metrics.ttft.p50(),
        report.metrics.tbt.p50(),
        report.metrics.tbt.p99(),
        report.metrics.blocks_loaded_per_iter.p99(),
    );
    for id in 1..=n as u32 {
        if let Some(r) = report.requests.get(&id) {
            println!("  req {id}: generated {:?}", &r.generated);
        }
    }
    Ok(())
}
