//! Request-lifecycle end-to-end tests on the simulated backend: the
//! online coordinator over `EngineCore` (metrics, backpressure,
//! cancellation, SLO accounting) without needing `make artifacts`,
//! plus regression tests for DRAM-oversubscription backpressure,
//! typed memory-pressure eviction and the WS starvation guard.

use std::collections::HashMap;
use std::time::Duration;

use sparseserve::config::{HardwareSpec, ModelSpec, ServingConfig};
use sparseserve::coordinator::{ServeError, Server, SubmitRequest};
use sparseserve::engine::{
    Backend, BatchOutcome, EngineCore, MemStats, PhaseEvent, SimBackend, StageHints, StepSession,
};
use sparseserve::memory::{MemoryError, ReqId};
use sparseserve::scheduler::{Batch, Request, Scheduler};

fn build_sim() -> anyhow::Result<(Scheduler, Box<dyn sparseserve::engine::Backend>)> {
    let cfg = ServingConfig::sparseserve(2048, 2048, 32);
    let spec = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
    let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
    Ok((sched, Box::new(backend) as _))
}

#[test]
fn online_run_metrics_exposed_at_shutdown() {
    let server = Server::start(build_sim);
    let h1 = server.submit(SubmitRequest::synthetic(8192).max_new(4));
    let h2 = server.submit(SubmitRequest::synthetic(4096).max_new(2).interactive());
    let (t1, tm1) = h1.collect().expect("stream 1");
    assert!(t1.is_empty(), "sim backend emits no token ids");
    assert_eq!(tm1.n_tokens, 4);
    assert!(tm1.ttft_s.expect("ttft present") > 0.0);
    let (_, tm2) = h2.collect().expect("stream 2");
    assert_eq!(tm2.n_tokens, 2);
    // the online path now aggregates RunMetrics too
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_finished, 2);
    assert_eq!(m.tokens_generated, 6);
    assert!(m.iterations > 0);
    assert!(m.makespan_s > 0.0);
    assert_eq!(m.requests_cancelled, 0);
}

#[test]
fn queue_cap_rejects_with_typed_backpressure() {
    // Gate engine bring-up until all three submissions are enqueued, so
    // they are drained in one message pump before any scheduling step
    // runs (deterministic queue occupancy: the first waits, the rest
    // bounce).
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    let server = Server::start_with(Some(1), move || {
        let _ = ready_rx.recv_timeout(Duration::from_secs(30));
        build_sim()
    });
    let ha = server.submit(SubmitRequest::synthetic(8192).max_new(2));
    let hb = server.submit(SubmitRequest::synthetic(8192).max_new(2));
    let hc = server.submit(SubmitRequest::synthetic(8192).max_new(2));
    ready_tx.send(()).expect("engine waiting");
    let (_, tma) = ha.collect().expect("first request runs");
    assert_eq!(tma.n_tokens, 2);
    match hb.collect() {
        Err(ServeError::QueueFull { cap: 1 }) => {}
        other => panic!("expected QueueFull, got {other:?}"),
    }
    match hc.collect() {
        Err(ServeError::QueueFull { .. }) => {}
        other => panic!("expected QueueFull, got {other:?}"),
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_finished, 1);
}

#[test]
fn cancel_over_server_reports_cancelled() {
    let server = Server::start(build_sim);
    let h = server.submit(SubmitRequest::synthetic(30_000).max_new(10_000));
    server.cancel(h.id);
    match h.collect() {
        Err(ServeError::Cancelled) => {}
        other => panic!("expected Cancelled, got {other:?}"),
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_cancelled, 1);
    assert_eq!(m.requests_finished, 0);
}

#[test]
fn inadmissible_request_rejected_not_hung() {
    // Non-offload config with an HBM too small for any reservation: the
    // online server must fail the doomed request with a typed error and
    // keep serving, not spin forever (the offline driver bails instead).
    let server = Server::start(|| {
        let cfg = ServingConfig::vllm(2048);
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
        let sched = Scheduler::new(cfg, spec, 1 << 20); // 1 MiB: nothing fits
        Ok((sched, Box::new(backend) as _))
    });
    let h = server.submit(SubmitRequest::synthetic(8192).max_new(64));
    match h.collect() {
        Err(ServeError::AdmissionRejected { reason }) => {
            assert!(reason.contains("HBM capacity"), "reason: {reason}");
        }
        other => panic!("expected AdmissionRejected, got {other:?}"),
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_rejected, 1);
    assert_eq!(m.requests_cancelled, 0, "rejection is not a client cancel");
}

#[test]
fn cancel_unknown_id_is_harmless() {
    let server = Server::start(build_sim);
    server.cancel(999);
    let h = server.submit(SubmitRequest::synthetic(2048).max_new(1));
    let (_, tm) = h.collect().unwrap();
    assert_eq!(tm.n_tokens, 1);
    server.shutdown().unwrap();
}

#[test]
fn ttft_slo_violations_counted() {
    let server = Server::start(build_sim);
    // an impossible SLO: any positive TTFT violates it
    let h = server.submit(SubmitRequest::synthetic(8192).max_new(2).ttft_slo(0.0));
    h.collect().unwrap();
    let m = server.shutdown().unwrap();
    assert_eq!(m.ttft_slo_violations, 1);
}

// ------------------------------------------------------------------------
// DRAM-exhaustion & starvation regression tests (ISSUE 2)

/// Deterministic test backend: instant iterations, scripted working-set
/// sizes, per-request KV append counters (so tests can assert rollback
/// leaves batch-mates' state untouched), and an optional request whose
/// decode trips a typed `MemoryError` mid-batch — AFTER earlier
/// batch-mates already appended, the exact shape rollback exists for.
struct MockBackend {
    ws: HashMap<ReqId, usize>,
    fail_on: Option<(ReqId, MemoryError)>,
    /// Failure trigger; tests can disarm it until the interesting batch
    /// shape has formed.
    armed: std::sync::Arc<std::sync::atomic::AtomicBool>,
    /// Abort charge accumulated by rolled-back sessions (a fixed 0.05 s
    /// per rollback), surfaced as `BatchOutcome::abort_time_s` — tests
    /// assert the engine charges it to the serving clock.
    aborted_s: f64,
    /// Appended-KV counter per registered request, shared with the test
    /// (the backend is boxed into the engine, this stays observable).
    kv: std::sync::Arc<std::sync::Mutex<HashMap<ReqId, usize>>>,
}

/// Wall time a rolled-back mock session pretends to have burnt.
const MOCK_ABORT_S: f64 = 0.05;

impl MockBackend {
    fn new(ws: HashMap<ReqId, usize>, fail_on: Option<(ReqId, MemoryError)>) -> Self {
        Self {
            ws,
            fail_on,
            armed: std::sync::Arc::new(std::sync::atomic::AtomicBool::new(true)),
            aborted_s: 0.0,
            kv: Default::default(),
        }
    }

    fn kv_handle(&self) -> std::sync::Arc<std::sync::Mutex<HashMap<ReqId, usize>>> {
        self.kv.clone()
    }

    fn armed_handle(&self) -> std::sync::Arc<std::sync::atomic::AtomicBool> {
        self.armed.clone()
    }
}

struct MockSession<'s> {
    be: &'s mut MockBackend,
    batch: &'s Batch,
    /// Pre-step KV counters of the batch (rollback restore).
    snap: HashMap<ReqId, usize>,
}

impl StepSession for MockSession<'_> {
    fn stage(&mut self, _hints: &StageHints) -> usize {
        0
    }

    fn prefill_segment(&mut self, l0: usize, l1: usize) -> anyhow::Result<PhaseEvent> {
        Ok(PhaseEvent { layer_start: l0, layer_end: l1, ..Default::default() })
    }

    fn decode_layer(&mut self, layer: usize) -> anyhow::Result<PhaseEvent> {
        // mid-batch failure shape: iterate decodes in order, mutate each
        // one's KV, and only THEN fail on the victim
        let armed = self.be.armed.load(std::sync::atomic::Ordering::SeqCst);
        let mut kv = self.be.kv.lock().unwrap();
        for &id in &self.batch.decodes {
            *kv.entry(id).or_insert(0) += 1;
            if let Some((victim, err)) = self.be.fail_on {
                if armed && id == victim {
                    return Err(err.into());
                }
            }
        }
        Ok(PhaseEvent { layer_start: layer, layer_end: layer + 1, ..Default::default() })
    }

    fn commit(mut self: Box<Self>) -> anyhow::Result<BatchOutcome> {
        let mut out = BatchOutcome {
            iter_time_s: 0.01,
            abort_time_s: std::mem::take(&mut self.be.aborted_s),
            ..Default::default()
        };
        for &id in &self.batch.decodes {
            out.tokens.push((id, None));
        }
        if let Some(w) = &self.batch.prefill {
            if w.is_last() {
                out.tokens.push((w.req(), None));
            }
        }
        Ok(out)
    }

    fn rollback(mut self: Box<Self>) {
        self.be.aborted_s += MOCK_ABORT_S;
        let mut kv = self.be.kv.lock().unwrap();
        for (id, n) in self.snap {
            kv.insert(id, n);
        }
    }
}

impl Backend for MockBackend {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn abort_iteration(&mut self) -> f64 {
        // hand the abandoned-iteration charge to the engine instead of
        // leaking it into the next committed step's abort_time_s
        std::mem::take(&mut self.aborted_s)
    }

    fn n_layers(&self) -> usize {
        1
    }

    fn register(&mut self, req: &Request) -> anyhow::Result<()> {
        self.kv.lock().unwrap().insert(req.id, 0);
        Ok(())
    }

    fn release(&mut self, req: ReqId) {
        self.kv.lock().unwrap().remove(&req);
    }

    fn decode_ws_bytes(&mut self, req: ReqId) -> usize {
        self.ws.get(&req).copied().unwrap_or(0)
    }

    fn mem_stats(&self) -> MemStats {
        MemStats::default()
    }

    fn begin_step<'s>(
        &'s mut self,
        batch: &'s Batch,
        _requests: &'s HashMap<ReqId, Request>,
    ) -> anyhow::Result<Box<dyn StepSession + 's>> {
        let snap = {
            let kv = self.kv.lock().unwrap();
            batch
                .decodes
                .iter()
                .filter_map(|id| kv.get(id).map(|n| (*id, *n)))
                .collect()
        };
        Ok(Box::new(MockSession { be: self, batch, snap }))
    }
}

#[test]
fn hbm_oversubscribed_sim_run_charges_nonzero_abort_time() {
    // Regression test for the always-zero abort-time bug: the simulator's
    // decode is now mid-phase fallible (per-layer-band selection touches
    // the cache as each band runs), so a pure-sim HBM-oversubscribed run
    // must evict typed AND report nonzero abort_time_total_s — the
    // burnt compute of the rolled-back attempts, charged to the serving
    // clock. No MockBackend involved: this exercises the real SimBackend
    // rollback/retry path end to end.
    let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
    cfg.ws_batch_control = false; // let the oversized batch form
    cfg.prefetch = false; // pure demand traffic
    let spec = ModelSpec::lwm_7b();
    let mut hw = HardwareSpec::a100_40gb();
    // HBM of 40 iteration-granular groups (160 band slots): three
    // decodes' per-band working sets (3 x 64 = 192) cannot fit
    hw.hbm_kv_bytes = 40 * spec.n_layers * spec.n_kv_heads * spec.block_bytes();
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw);
    let sched = Scheduler::new(cfg, spec, 1 << 40); // admission unconstrained
    let mut core = EngineCore::new(sched, Box::new(backend));
    // long enough completions that all three decodes coexist (prefills
    // are staggered one at a time, so short decodes would drain before
    // the oversized batch ever forms)
    for _ in 0..3 {
        core.submit(SubmitRequest::synthetic(8192).max_new(64), 0.0).unwrap();
    }

    let mut evicted = Vec::new();
    let mut now = 0.0;
    let mut steps = 0;
    while core.has_work() {
        steps += 1;
        assert!(steps < 400, "engine must keep making progress under HBM pressure");
        let out = core.step(now).unwrap(); // typed evictions, never a panic
        evicted.extend(out.evicted.iter().map(|(id, _)| *id));
        for (_, err) in &out.evicted {
            assert!(matches!(err, ServeError::Evicted { .. }));
            assert!(err.to_string().contains("HBM exhausted"), "{err}");
        }
        now += out.iter_time_s.max(1e-3);
    }
    let m = core.metrics();
    assert!(m.requests_evicted > 0, "oversubscription must evict typed");
    assert_eq!(m.requests_evicted, evicted.len());
    assert!(
        m.abort_time_total_s > 0.0,
        "mid-decode rollback must charge burnt compute to the serving clock"
    );
    assert!(
        m.requests_finished >= 1,
        "survivors must still finish: {} finished",
        m.requests_finished
    );
    assert_eq!(m.requests_finished + m.requests_evicted, 3);
}

#[test]
fn dram_oversubscribed_workload_survives_with_rejections() {
    // A whale that can never fit DRAM plus more normal requests than
    // DRAM holds at once: the server must reject the whale with a typed
    // error, backpressure the rest, finish everything — and never panic.
    let cfg = ServingConfig::sparseserve(2048, 2048, 32);
    let spec = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
    let one = Scheduler::new(cfg.clone(), spec.clone(), hw.hbm_kv_bytes)
        .full_kv_bytes(8192, 16);
    let dram_cap = 2 * one + one / 2; // two requests fit, the third waits
    let sched =
        Scheduler::new(cfg, spec, hw.hbm_kv_bytes).with_dram_capacity(dram_cap);
    let mut core = EngineCore::new(sched, Box::new(backend));

    let whale = core
        .submit(SubmitRequest::synthetic(4_000_000).max_new(16), 0.0)
        .unwrap();
    for _ in 0..4 {
        core.submit(SubmitRequest::synthetic(8192).max_new(16), 0.0).unwrap();
    }

    let mut rejected = Vec::new();
    let mut now = 0.0;
    let mut steps = 0;
    while core.has_work() {
        steps += 1;
        assert!(steps < 2000, "livelock under DRAM oversubscription");
        let out = core.step(now).unwrap(); // typed errors, never a panic
        rejected.extend(out.rejected.iter().map(|(id, _)| *id));
        for (_, err) in &out.rejected {
            assert!(matches!(err, ServeError::AdmissionRejected { .. }));
        }
        now += out.iter_time_s.max(1e-3);
        // admission reservations never exceed the DRAM budget
        assert!(core.sched().reserved_bytes() <= dram_cap);
    }
    assert_eq!(rejected, vec![whale]);
    assert_eq!(core.metrics().requests_rejected, 1);
    assert_eq!(core.metrics().requests_finished, 4);
}

#[test]
fn memory_exhaustion_evicts_typed_and_engine_survives() {
    // A backend hitting DRAM exhaustion mid-decode must surface a typed
    // Evicted error for that request only; the engine keeps serving.
    let cfg = ServingConfig::sparseserve(2048, 2048, 32);
    let spec = ModelSpec::lwm_7b();
    let sched = Scheduler::new(cfg, spec, 1 << 40);
    let backend =
        MockBackend::new(HashMap::new(), Some((2, MemoryError::DramExhausted { req: 2 })));
    let mut core = EngineCore::new(sched, Box::new(backend));
    let ok_id = core.submit(SubmitRequest::synthetic(64).max_new(5), 0.0).unwrap();
    let doomed = core.submit(SubmitRequest::synthetic(64).max_new(5), 0.0).unwrap();
    assert_eq!(doomed, 2);

    let mut evicted = Vec::new();
    let mut now = 0.0;
    let mut steps = 0;
    while core.has_work() {
        steps += 1;
        assert!(steps < 100, "engine must keep making progress");
        let out = core.step(now).unwrap(); // Ok even on memory pressure
        evicted.extend(out.evicted.clone());
        now += out.iter_time_s.max(1e-3);
    }
    assert_eq!(evicted.len(), 1);
    assert_eq!(evicted[0].0, doomed);
    assert!(matches!(evicted[0].1, ServeError::Evicted { .. }));
    assert!(evicted[0].1.to_string().contains("DRAM exhausted"));
    assert_eq!(core.metrics().requests_evicted, 1);
    assert_eq!(core.metrics().requests_finished, 1);
    let report = core.into_report(now);
    assert!(report.requests[&ok_id].is_done());
}

#[test]
fn mid_batch_hbm_exhaustion_rolls_back_and_retries_same_iteration() {
    // Acceptance criterion: a mid-batch HbmExhausted — raised AFTER an
    // earlier batch-mate already appended KV this step — must roll the
    // step back, evict only the victim, and re-run the surviving
    // batch-mates in the SAME EngineCore::step call with unchanged KV
    // state (each survivor's KV advances exactly once, not twice).
    let cfg = ServingConfig::sparseserve(2048, 2048, 32);
    let spec = ModelSpec::lwm_7b();
    let sched = Scheduler::new(cfg, spec, 1 << 40);
    let backend =
        MockBackend::new(HashMap::new(), Some((2, MemoryError::HbmExhausted { req: 2 })));
    let kv = backend.kv_handle();
    let armed = backend.armed_handle();
    armed.store(false, std::sync::atomic::Ordering::SeqCst); // no failure yet
    let mut core = EngineCore::new(sched, Box::new(backend));
    for _ in 0..3 {
        core.submit(SubmitRequest::synthetic(64).max_new(8), 0.0).unwrap();
    }
    // drive all three through prefill into decode (one prefill slot)
    let mut now = 0.0;
    for _ in 0..3 {
        let out = core.step(now).unwrap();
        assert!(out.ran_batch);
        now += out.iter_time_s.max(1e-3);
    }
    armed.store(true, std::sync::atomic::Ordering::SeqCst);
    let decoding = core.sched().decoding();
    assert_eq!(decoding, vec![1, 2, 3], "all three must be decoding");
    let gen_before: Vec<usize> =
        decoding.iter().map(|id| core.sched().requests[id].n_generated).collect();
    let kv_before = kv.lock().unwrap().clone();

    // ONE step: decode batch [1, 2, 3]; request 2 trips HbmExhausted
    // after request 1 already appended
    let out = core.step(now).unwrap();
    assert!(out.ran_batch, "survivors must run in the same iteration");
    // the rolled-back attempt's burnt time is charged to the serving
    // clock on top of the committed retry (0.01 s commit + 0.05 s abort)
    assert!(
        (out.iter_time_s - (0.01 + MOCK_ABORT_S)).abs() < 1e-9,
        "abort time must be charged: iter_time_s = {}",
        out.iter_time_s
    );
    assert!(
        (core.metrics().abort_time_total_s - MOCK_ABORT_S).abs() < 1e-9,
        "metrics must record the aborted-attempt time"
    );
    assert_eq!(out.evicted.len(), 1);
    assert_eq!(out.evicted[0].0, 2);
    assert!(matches!(out.evicted[0].1, ServeError::Evicted { .. }));
    assert!(out.evicted[0].1.to_string().contains("HBM exhausted"));
    let emitted: Vec<ReqId> = out.emitted.iter().map(|e| e.req).collect();
    assert_eq!(emitted, vec![1, 3], "both survivors emit in the same step");
    // unchanged KV state: the rollback restored the aborted attempt, so
    // each survivor's KV advanced exactly once across abort + retry
    {
        let kv_after = kv.lock().unwrap();
        assert_eq!(kv_after[&1], kv_before[&1] + 1, "req 1 appends exactly once");
        assert_eq!(kv_after[&3], kv_before[&3] + 1, "req 3 appends exactly once");
        assert!(!kv_after.contains_key(&2), "victim's KV must be released");
    }
    for (&id, &before) in decoding.iter().zip(&gen_before) {
        if id == 2 {
            continue;
        }
        assert_eq!(
            core.sched().requests[&id].n_generated,
            before + 1,
            "request {id} must advance exactly one token"
        );
    }
    assert_eq!(core.metrics().requests_evicted, 1);

    // the engine keeps serving the survivors to completion
    let mut steps = 0;
    while core.has_work() {
        steps += 1;
        assert!(steps < 100, "engine must keep making progress");
        let out = core.step(now).unwrap();
        now += out.iter_time_s.max(1e-3);
    }
    assert_eq!(core.metrics().requests_finished, 2);
}

#[test]
fn starved_decode_makes_progress_with_guard() {
    // A large-WS decode behind one short small-WS request and ahead of
    // two long small-WS requests: without the guard the young pair packs
    // past it every iteration; with the guard it finishes well before
    // them.
    let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
    cfg.ws_starvation_k = 3;
    let spec = ModelSpec::lwm_7b();
    let sched = Scheduler::new(cfg, spec, 40 << 20); // m_avl = 36 MiB
    let mut ws = HashMap::new();
    ws.insert(1, 12 << 20);
    ws.insert(2, 26 << 20); // fits alone, never with request 1
    ws.insert(3, 12 << 20);
    ws.insert(4, 12 << 20);
    let backend = MockBackend::new(ws, None);
    let mut core = EngineCore::new(sched, Box::new(backend));
    core.submit(SubmitRequest::synthetic(64).max_new(6), 0.0).unwrap(); // 1: short
    core.submit(SubmitRequest::synthetic(64).max_new(3), 0.0).unwrap(); // 2: big WS
    core.submit(SubmitRequest::synthetic(64).max_new(30), 0.0).unwrap(); // 3: long
    core.submit(SubmitRequest::synthetic(64).max_new(30), 0.0).unwrap(); // 4: long

    let mut finish_order = Vec::new();
    let mut now = 0.0;
    let mut steps = 0;
    while core.has_work() {
        steps += 1;
        assert!(steps < 500, "starved request livelocked");
        let out = core.step(now).unwrap();
        finish_order.extend(out.finished.iter().map(|(id, _)| *id));
        now += out.iter_time_s.max(1e-3);
    }
    assert_eq!(core.metrics().requests_finished, 4);
    let pos = |id: ReqId| finish_order.iter().position(|&x| x == id).unwrap();
    assert!(
        pos(2) < pos(3) && pos(2) < pos(4),
        "starved request must not finish last: {finish_order:?}"
    );
    assert!(core.sched().ws_starvation_stops > 0, "guard must have engaged");
    assert!(core.sched().ws_rejections > 0, "WS control must have skipped it first");
}
