//! Request-lifecycle end-to-end tests on the simulated backend: the
//! online coordinator over `EngineCore` (metrics, backpressure,
//! cancellation, SLO accounting) without needing `make artifacts`.

use std::time::Duration;

use sparseserve::config::{HardwareSpec, ModelSpec, ServingConfig};
use sparseserve::coordinator::{ServeError, Server, SubmitRequest};
use sparseserve::engine::SimBackend;
use sparseserve::scheduler::Scheduler;

fn build_sim() -> anyhow::Result<(Scheduler, Box<dyn sparseserve::engine::Backend>)> {
    let cfg = ServingConfig::sparseserve(2048, 2048, 32);
    let spec = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
    let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
    Ok((sched, Box::new(backend) as _))
}

#[test]
fn online_run_metrics_exposed_at_shutdown() {
    let server = Server::start(build_sim);
    let h1 = server.submit(SubmitRequest::synthetic(8192).max_new(4));
    let h2 = server.submit(SubmitRequest::synthetic(4096).max_new(2).interactive());
    let (t1, tm1) = h1.collect().expect("stream 1");
    assert!(t1.is_empty(), "sim backend emits no token ids");
    assert_eq!(tm1.n_tokens, 4);
    assert!(tm1.ttft_s.expect("ttft present") > 0.0);
    let (_, tm2) = h2.collect().expect("stream 2");
    assert_eq!(tm2.n_tokens, 2);
    // the online path now aggregates RunMetrics too
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_finished, 2);
    assert_eq!(m.tokens_generated, 6);
    assert!(m.iterations > 0);
    assert!(m.makespan_s > 0.0);
    assert_eq!(m.requests_cancelled, 0);
}

#[test]
fn queue_cap_rejects_with_typed_backpressure() {
    // Gate engine bring-up until all three submissions are enqueued, so
    // they are drained in one message pump before any scheduling step
    // runs (deterministic queue occupancy: the first waits, the rest
    // bounce).
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    let server = Server::start_with(Some(1), move || {
        let _ = ready_rx.recv_timeout(Duration::from_secs(30));
        build_sim()
    });
    let ha = server.submit(SubmitRequest::synthetic(8192).max_new(2));
    let hb = server.submit(SubmitRequest::synthetic(8192).max_new(2));
    let hc = server.submit(SubmitRequest::synthetic(8192).max_new(2));
    ready_tx.send(()).expect("engine waiting");
    let (_, tma) = ha.collect().expect("first request runs");
    assert_eq!(tma.n_tokens, 2);
    match hb.collect() {
        Err(ServeError::QueueFull { cap: 1 }) => {}
        other => panic!("expected QueueFull, got {other:?}"),
    }
    match hc.collect() {
        Err(ServeError::QueueFull { .. }) => {}
        other => panic!("expected QueueFull, got {other:?}"),
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_finished, 1);
}

#[test]
fn cancel_over_server_reports_cancelled() {
    let server = Server::start(build_sim);
    let h = server.submit(SubmitRequest::synthetic(30_000).max_new(10_000));
    server.cancel(h.id);
    match h.collect() {
        Err(ServeError::Cancelled) => {}
        other => panic!("expected Cancelled, got {other:?}"),
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_cancelled, 1);
    assert_eq!(m.requests_finished, 0);
}

#[test]
fn inadmissible_request_rejected_not_hung() {
    // Non-offload config with an HBM too small for any reservation: the
    // online server must fail the doomed request with a typed error and
    // keep serving, not spin forever (the offline driver bails instead).
    let server = Server::start(|| {
        let cfg = ServingConfig::vllm(2048);
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
        let sched = Scheduler::new(cfg, spec, 1 << 20); // 1 MiB: nothing fits
        Ok((sched, Box::new(backend) as _))
    });
    let h = server.submit(SubmitRequest::synthetic(8192).max_new(64));
    match h.collect() {
        Err(ServeError::AdmissionRejected { reason }) => {
            assert!(reason.contains("HBM capacity"), "reason: {reason}");
        }
        other => panic!("expected AdmissionRejected, got {other:?}"),
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_rejected, 1);
    assert_eq!(m.requests_cancelled, 0, "rejection is not a client cancel");
}

#[test]
fn cancel_unknown_id_is_harmless() {
    let server = Server::start(build_sim);
    server.cancel(999);
    let h = server.submit(SubmitRequest::synthetic(2048).max_new(1));
    let (_, tm) = h.collect().unwrap();
    assert_eq!(tm.n_tokens, 1);
    server.shutdown().unwrap();
}

#[test]
fn ttft_slo_violations_counted() {
    let server = Server::start(build_sim);
    // an impossible SLO: any positive TTFT violates it
    let h = server.submit(SubmitRequest::synthetic(8192).max_new(2).ttft_slo(0.0));
    h.collect().unwrap();
    let m = server.shutdown().unwrap();
    assert_eq!(m.ttft_slo_violations, 1);
}
