//! Golden-fixture suite for `sparselint` (src/lint/) plus the
//! repo-cleanliness meta-test.
//!
//! Each fixture is a small source file with a known violation: the
//! test pins the pass name, file, and 1-based line of the diagnostic,
//! then shows the repaired (or suppressed) variant is silent. The
//! final test replicates the `sparselint` binary's file walk over the
//! real tree with the checked-in `rust/lint.toml` and asserts zero
//! findings — the same gate CI runs via `cargo run --bin sparselint`.

use sparseserve::lint::{analyze, Config, Diagnostic, SourceFile};

fn file(path: &str, src: &str) -> SourceFile {
    SourceFile { path: path.into(), src: src.into() }
}

fn run(cfg_toml: &str, files: &[SourceFile]) -> Vec<Diagnostic> {
    let cfg = Config::from_toml(cfg_toml).expect("fixture config parses");
    analyze(files, &cfg)
}

/// `(pass, line)` pairs of every diagnostic in `file_path`.
fn hits(diags: &[Diagnostic], file_path: &str) -> Vec<(String, u32)> {
    diags
        .iter()
        .filter(|d| d.file == file_path)
        .map(|d| (d.pass.clone(), d.line))
        .collect()
}

// ---------------------------------------------------------------------------
// txn-pairing
// ---------------------------------------------------------------------------

const TXN_CFG: &str = "\
[txn]
driver = \"drive_step\"
step_begin = \"begin_step\"

[[txn.pair]]
begin = \"begin_txn\"
commit = \"commit_txn\"
rollback = \"rollback_txn\"
";

#[test]
fn txn_only_driver_may_begin_step() {
    let src = "\
fn sneaky(b: &mut B) {
    b.begin_step();
}
fn drive_step(b: &mut B) {
    b.begin_step();
}
";
    let d = run(TXN_CFG, &[file("src/engine/x.rs", src)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("txn-pairing".into(), 2)], "{d:?}");
    assert!(d[0].msg.contains("drive_step"), "{}", d[0].msg);
}

const TXN_MULTI_CFG: &str = "\
[txn]
driver = \"drive_step\"
drivers = [\"drive_step\", \"drive_step_pipelined\"]
step_begin = \"begin_step\"

[[txn.pair]]
begin = \"begin_txn\"
commit = \"commit_txn\"
rollback = \"rollback_txn\"
";

#[test]
fn txn_every_configured_driver_may_begin_step() {
    // Both executors open sessions legitimately; anything else still
    // fires, and the message names the whole sanctioned set.
    let src = "\
fn drive_step(b: &mut B) {
    b.begin_step();
}
fn drive_step_pipelined(b: &mut B) {
    b.begin_step();
}
fn sneaky(b: &mut B) {
    b.begin_step();
}
";
    let d = run(TXN_MULTI_CFG, &[file("src/engine/x.rs", src)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("txn-pairing".into(), 8)], "{d:?}");
    assert!(d[0].msg.contains("drive_step_pipelined"), "{}", d[0].msg);

    // Under the singular-driver config the pipelined twin is NOT
    // exempt — the drivers array is what sanctions it.
    let d = run(TXN_CFG, &[file("src/engine/x.rs", src)]);
    assert_eq!(
        hits(&d, "src/engine/x.rs"),
        vec![("txn-pairing".into(), 5), ("txn-pairing".into(), 8)],
        "{d:?}"
    );
}

#[test]
fn txn_delegation_to_any_configured_driver_is_clean() {
    let src = "\
fn outer(s: &mut S) {
    s.begin_txn();
    drive_step_pipelined(s);
}
";
    let d = run(TXN_MULTI_CFG, &[file("src/engine/x.rs", src)]);
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn txn_escape_between_begin_and_commit_fires() {
    let src = "\
fn risky(s: &mut S) -> R {
    s.begin_txn();
    s.step()?;
    s.commit_txn();
    done()
}
";
    let d = run(TXN_CFG, &[file("src/engine/x.rs", src)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("txn-pairing".into(), 3)], "{d:?}");

    // Repaired: the fallible work happens before the transaction opens.
    let fixed = "\
fn safe(s: &mut S) -> R {
    s.step()?;
    s.begin_txn();
    s.commit_txn();
    done()
}
";
    let d = run(TXN_CFG, &[file("src/engine/x.rs", fixed)]);
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn txn_unsettled_begin_fires_even_next_to_commit_and_rollback_fns() {
    let src = "\
fn open_only(s: &mut S) {
    s.begin_txn();
}
";
    let d = run(TXN_CFG, &[file("src/engine/x.rs", src)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("txn-pairing".into(), 2)], "{d:?}");
    assert!(d[0].msg.contains("no caller chain settles"), "{}", d[0].msg);

    // v1 had a same-file escape hatch: commit and rollback existing
    // ANYWHERE in the file excused an unsettled begin, even with no
    // caller connecting them. v2 demands an actual call-graph path,
    // so this file now (correctly) fires: nothing calls open_only.
    let split = "\
fn open_only(s: &mut S) {
    s.begin_txn();
}
fn finish_ok(s: &mut S) {
    s.commit_txn();
}
fn finish_err(s: &mut S) {
    s.rollback_txn();
}
";
    let d = run(TXN_CFG, &[file("src/engine/x.rs", split)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("txn-pairing".into(), 2)], "{d:?}");
}

#[test]
fn txn_split_phase_settled_through_cross_file_caller_is_clean() {
    // The split-phase shape the call graph exists to resolve: the
    // begin lives in one file, and a driver in ANOTHER file calls it
    // and settles both ways. v1's same-file heuristic could not see
    // this; v2 accepts it because the driver is an ancestor of
    // `open_only` that reaches both commit_txn and rollback_txn.
    let opener = "\
pub fn open_only(s: &mut S) {
    s.begin_txn();
}
";
    let driver = "\
fn settle(s: &mut S, ok: bool) {
    open_only(s);
    if ok {
        s.commit_txn();
    } else {
        s.rollback_txn();
    }
}
";
    let d = run(
        TXN_CFG,
        &[file("src/engine/open.rs", opener), file("src/engine/settle.rs", driver)],
    );
    assert!(d.is_empty(), "{d:?}");

    // A caller that only ever commits is NOT a settlement: the
    // rollback half of the obligation is unreachable.
    let commit_only = "\
fn settle(s: &mut S) {
    open_only(s);
    s.commit_txn();
}
";
    let d = run(
        TXN_CFG,
        &[file("src/engine/open.rs", opener), file("src/engine/settle.rs", commit_only)],
    );
    assert_eq!(hits(&d, "src/engine/open.rs"), vec![("txn-pairing".into(), 2)], "{d:?}");
    assert!(d[0].msg.contains("rollback_txn"), "{}", d[0].msg);
}

#[test]
fn txn_delegation_to_driver_is_clean() {
    let src = "\
fn outer(s: &mut S) {
    s.begin_txn();
    drive_step(s);
}
";
    let d = run(TXN_CFG, &[file("src/engine/x.rs", src)]);
    assert!(d.is_empty(), "{d:?}");
}

// ---------------------------------------------------------------------------
// pin-conservation
// ---------------------------------------------------------------------------

const PINS_CFG: &str = "\
[[pins.scope]]
file = \"src/mem/stage.rs\"
acquire = [\"pin\"]
release = [\"unpin\"]
trackers = [\"pins_out\"]
delegates = [\"mark_staged\"]

[[pins.defs]]
file = \"src/mem/drain.rs\"
must_define = [\"mark_staged\", \"end_iteration\"]
";

const DRAIN_OK: &str = "\
fn mark_staged(k: K) {}
fn end_iteration() {}
";

#[test]
fn pin_leak_fires_and_each_conservation_shape_is_clean() {
    let src = "\
fn leak(c: &mut C, k: K) {
    c.pin(k);
}
fn ok_release(c: &mut C, k: K) {
    c.pin(k);
    c.unpin(k);
}
fn ok_tracker(c: &mut C, k: K, pins_out: &mut V) {
    c.pin(k);
    pins_out.push(k);
}
fn ok_delegate(c: &mut C, k: K) {
    c.pin(k);
    mark_staged(k);
}
#[test]
fn test_pins_are_exempt(c: &mut C, k: K) {
    c.pin(k);
}
";
    let d = run(PINS_CFG, &[file("src/mem/stage.rs", src), file("src/mem/drain.rs", DRAIN_OK)]);
    assert_eq!(hits(&d, "src/mem/stage.rs"), vec![("pin-conservation".into(), 2)], "{d:?}");
    assert!(d[0].msg.contains("leak"), "{}", d[0].msg);
}

#[test]
fn pin_drain_side_must_define_its_api() {
    let drain_missing = "fn mark_staged(k: K) {}\n";
    let d = run(PINS_CFG, &[file("src/mem/drain.rs", drain_missing)]);
    assert_eq!(hits(&d, "src/mem/drain.rs"), vec![("pin-conservation".into(), 1)], "{d:?}");
    assert!(d[0].msg.contains("end_iteration"), "{}", d[0].msg);

    // The configured drain file being absent from the scan set is a
    // finding in its own right, attributed to the configured path.
    let d = run(PINS_CFG, &[file("src/mem/other.rs", "fn f() {}\n")]);
    assert_eq!(hits(&d, "src/mem/drain.rs"), vec![("pin-conservation".into(), 1)], "{d:?}");
    assert!(d[0].msg.contains("not found"), "{}", d[0].msg);
}

#[test]
fn pin_delegation_through_cross_file_helper_is_conserving() {
    // v2: acquiring here and settling in a callee — even one defined
    // in another file — conserves. A helper that merely logs does not.
    let stage = "\
fn ok_cross(c: &mut C, k: K) {
    c.pin(k);
    hand_off(c, k);
}
fn still_leaks(c: &mut C, k: K) {
    c.pin(k);
    log_it(k);
}
";
    let helper = "\
pub fn hand_off(c: &mut C, k: K) {
    mark_staged(k);
}
pub fn log_it(k: K) {
    let _ = k;
}
";
    let d = run(
        PINS_CFG,
        &[
            file("src/mem/stage.rs", stage),
            file("src/mem/helper.rs", helper),
            file("src/mem/drain.rs", DRAIN_OK),
        ],
    );
    assert_eq!(hits(&d, "src/mem/stage.rs"), vec![("pin-conservation".into(), 6)], "{d:?}");
    assert!(d[0].msg.contains("still_leaks"), "{}", d[0].msg);
}

// ---------------------------------------------------------------------------
// no-panic
// ---------------------------------------------------------------------------

const NO_PANIC_CFG: &str = "[no_panic]\nmodules = [\"engine\"]\n";

#[test]
fn no_panic_fires_on_unwrap_expect_panic_and_literal_index() {
    let src = "\
fn f(x: Option<u32>, msg: &str) -> u32 {
    let a = x.unwrap();
    let b = x.expect(msg);
    a + b
}
fn g(v: &[u32]) -> u32 {
    v[0]
}
fn h() {
    panic!()
}
";
    let d = run(NO_PANIC_CFG, &[file("src/engine/x.rs", src)]);
    let expect: Vec<(String, u32)> = [(2u32), 3, 7, 10]
        .iter()
        .map(|&l| ("no-panic".to_string(), l))
        .collect();
    assert_eq!(hits(&d, "src/engine/x.rs"), expect, "{d:?}");
}

#[test]
fn no_panic_repaired_code_and_out_of_scope_modules_are_clean() {
    let fixed = "\
fn f(x: Option<u32>) -> Result<u32, E> {
    x.ok_or(E::Missing)
}
fn g(v: &[u32]) -> Option<u32> {
    v.first().copied()
}
fn range_slices_are_fine(v: &[u32], n: usize) -> &[u32] {
    &v[..n]
}
";
    let d = run(NO_PANIC_CFG, &[file("src/engine/x.rs", fixed)]);
    assert!(d.is_empty(), "{d:?}");

    // Same panicky code outside the configured module set: no finding.
    let panicky = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    let d = run(NO_PANIC_CFG, &[file("src/figures/x.rs", panicky)]);
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn no_panic_test_code_is_exempt() {
    let src = "\
fn live(x: Option<u32>) -> Option<u32> {
    x
}
#[cfg(test)]
mod tests {
    fn t(x: Option<u32>) -> u32 {
        x.unwrap()
    }
}
";
    let d = run(NO_PANIC_CFG, &[file("src/engine/x.rs", src)]);
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn no_panic_trailing_allow_suppresses_in_place() {
    let src = "\
fn f(x: Option<u32>) -> u32 {
    x.unwrap() // sparselint: allow(no-panic) -- caller proved Some
}
";
    let d = run(NO_PANIC_CFG, &[file("src/engine/x.rs", src)]);
    assert!(d.is_empty(), "{d:?}");
}

// ---------------------------------------------------------------------------
// hot-path
// ---------------------------------------------------------------------------

const HOT_CFG: &str = "\
[hot]
banned_methods = [\"clone\", \"to_vec\"]
banned_ctors = [\"Vec\", \"vec\"]
";

#[test]
fn hot_marker_bans_clones_and_fresh_containers() {
    let src = "\
// sparselint: hot
fn hot_fn(xs: &[u32]) {
    let a = xs.to_vec();
    let b = Vec::new();
    let c = vec![];
}
fn cold(xs: &[u32]) {
    let a = xs.to_vec();
}
";
    let d = run(HOT_CFG, &[file("src/engine/x.rs", src)]);
    let expect: Vec<(String, u32)> =
        [(3u32), 4, 5].iter().map(|&l| ("hot-path".to_string(), l)).collect();
    assert_eq!(hits(&d, "src/engine/x.rs"), expect, "{d:?}");
    assert!(d[0].msg.contains("hot_fn"), "{}", d[0].msg);
}

#[test]
fn hot_allow_comment_suppresses_one_line() {
    let src = "\
// sparselint: hot
fn hot_fn(xs: &[u32]) {
    // sparselint: allow(hot-path) -- grows once, then amortized
    let a = xs.to_vec();
    let b = Vec::new();
}
";
    let d = run(HOT_CFG, &[file("src/engine/x.rs", src)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("hot-path".into(), 5)], "{d:?}");
}

// ---------------------------------------------------------------------------
// panic-path (interprocedural)
// ---------------------------------------------------------------------------

const PANIC_PATH_CFG: &str = "[panic_path]\nmodules = [\"engine\"]\n";

#[test]
fn panic_path_fires_at_the_serving_frontier_call_site() {
    // The panic lives in util/ (out of scope for the direct no-panic
    // pass); the serving fn that *reaches* it is flagged at its call
    // site, with the chain down to the marker in the message.
    let engine = "\
fn step_once(v: &[f64]) -> f64 {
    helper_mean(v)
}
";
    let util = "\
pub fn helper_mean(v: &[f64]) -> f64 {
    *v.first().unwrap()
}
";
    let d = run(
        PANIC_PATH_CFG,
        &[file("src/engine/core.rs", engine), file("src/util/stats2.rs", util)],
    );
    assert_eq!(hits(&d, "src/engine/core.rs"), vec![("panic-path".into(), 2)], "{d:?}");
    assert!(d[0].msg.contains("helper_mean"), "{}", d[0].msg);
    assert!(d[0].msg.contains("can panic"), "{}", d[0].msg);
    assert!(d[0].msg.contains(".unwrap()"), "{}", d[0].msg);

    // Repaired: the callee handles the miss; nothing propagates.
    let fixed = "\
pub fn helper_mean(v: &[f64]) -> f64 {
    v.first().copied().unwrap_or(0.0)
}
";
    let d = run(
        PANIC_PATH_CFG,
        &[file("src/engine/core.rs", engine), file("src/util/stats2.rs", fixed)],
    );
    assert!(d.is_empty(), "{d:?}");

    // Suppressed at the SOURCE: a justified allow on the marker line
    // stops propagation for every transitive caller at once.
    let allowed = "\
pub fn helper_mean(v: &[f64]) -> f64 {
    *v.first().unwrap() // sparselint: allow(panic-path) -- callers check non-empty
}
";
    let d = run(
        PANIC_PATH_CFG,
        &[file("src/engine/core.rs", engine), file("src/util/stats2.rs", allowed)],
    );
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn panic_path_traces_multi_hop_chains() {
    let engine = "\
fn top(x: u32) -> u32 {
    mid(x)
}
";
    let util = "\
pub fn mid(x: u32) -> u32 {
    deep(x)
}
pub fn deep(x: u32) -> u32 {
    if x == 0 { panic!() }
    x
}
";
    let d = run(
        PANIC_PATH_CFG,
        &[file("src/engine/core.rs", engine), file("src/util/helpers.rs", util)],
    );
    assert_eq!(hits(&d, "src/engine/core.rs"), vec![("panic-path".into(), 2)], "{d:?}");
    assert!(d[0].msg.contains("mid"), "{}", d[0].msg);
    assert!(d[0].msg.contains("deep"), "{}", d[0].msg);
    assert!(d[0].msg.contains("src/util/helpers.rs:5"), "{}", d[0].msg);
}

// ---------------------------------------------------------------------------
// hot-path-reach (interprocedural)
// ---------------------------------------------------------------------------

const HOT_REACH_CFG: &str = "\
[hot]
banned_methods = [\"clone\", \"to_vec\"]
banned_ctors = [\"Vec\", \"vec\"]

[hot_reach]
enabled = true
";

#[test]
fn hot_reach_closes_the_helper_loophole() {
    // The clone hides inside a method; the hot loop only sees a tidy
    // `snapshot()` call. The call graph types `s` by its parameter
    // annotation and follows the edge to the impl.
    let src = "\
struct S {
    xs: Vec<u32>,
}
impl S {
    fn snapshot(&self) -> Vec<u32> {
        self.xs.clone()
    }
}
// sparselint: hot
fn hot_loop(s: &S) {
    let a = s.snapshot();
}
";
    let d = run(HOT_REACH_CFG, &[file("src/engine/x.rs", src)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("hot-path-reach".into(), 11)], "{d:?}");
    assert!(d[0].msg.contains("hot_loop"), "{}", d[0].msg);
    assert!(d[0].msg.contains("can allocate"), "{}", d[0].msg);
    assert!(d[0].msg.contains(".clone()"), "{}", d[0].msg);

    // A justified allow at the allocation site clears every hot
    // caller — the helper's amortization argument is made once.
    let allowed = "\
struct S {
    xs: Vec<u32>,
}
impl S {
    fn snapshot(&self) -> Vec<u32> {
        // sparselint: allow(hot-path-reach) -- snapshot is once-per-epoch, not per-step
        self.xs.clone()
    }
}
// sparselint: hot
fn hot_loop(s: &S) {
    let a = s.snapshot();
}
";
    let d = run(HOT_REACH_CFG, &[file("src/engine/x.rs", allowed)]);
    assert!(d.is_empty(), "{d:?}");
}

// ---------------------------------------------------------------------------
// step-typestate
// ---------------------------------------------------------------------------

const STEP_CFG: &str = "\
[step_session]
begin = \"begin_step\"
stage = \"stage\"
prefill = \"prefill_segment\"
decode = \"decode_layer\"
commit = \"commit\"
rollback = \"rollback\"
";

#[test]
fn step_typestate_accepts_the_canonical_order() {
    let src = "\
fn good(b: &mut B) {
    b.begin_step();
    b.stage();
    b.prefill_segment();
    b.decode_layer();
    b.decode_layer();
    if ok() {
        b.commit();
    } else {
        b.rollback();
    }
}
";
    let d = run(STEP_CFG, &[file("src/engine/x.rs", src)]);
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn step_typestate_rejects_phase_calls_after_settle() {
    let src = "\
fn bad(b: &mut B) {
    b.begin_step();
    b.stage();
    b.decode_layer();
    b.commit();
    b.decode_layer();
}
";
    let d = run(STEP_CFG, &[file("src/engine/x.rs", src)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("step-typestate".into(), 6)], "{d:?}");
    assert!(d[0].msg.contains("outside an open session"), "{}", d[0].msg);
}

#[test]
fn step_typestate_rejects_double_stage_and_unsettled_sessions() {
    let double = "\
fn twice(b: &mut B) {
    b.begin_step();
    b.stage();
    b.stage();
    b.commit();
}
";
    let d = run(STEP_CFG, &[file("src/engine/x.rs", double)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("step-typestate".into(), 4)], "{d:?}");
    assert!(d[0].msg.contains("twice in one session"), "{}", d[0].msg);

    let leaky = "\
fn leaky(b: &mut B) {
    b.begin_step();
    b.stage();
    b.decode_layer();
}
";
    let d = run(STEP_CFG, &[file("src/engine/x.rs", leaky)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("step-typestate".into(), 2)], "{d:?}");
    assert!(d[0].msg.contains("never committed or rolled back"), "{}", d[0].msg);
}

#[test]
fn step_typestate_forbids_interleaved_sessions() {
    // The pipelined executor overlaps the SCHEDULER's plan/stage with
    // the backend's compute — it never holds two backend sessions at
    // once. A second `begin_step` while one is open is exactly the
    // interleaving the exclusive borrow forbids; machine-check it.
    let overlapped = "\
fn interleaved(b: &mut B) {
    b.begin_step();
    b.stage();
    b.decode_layer();
    b.begin_step();
    b.stage();
    b.decode_layer();
    b.commit();
    b.commit();
}
";
    let d = run(STEP_CFG, &[file("src/engine/x.rs", overlapped)]);
    let got = hits(&d, "src/engine/x.rs");
    assert!(
        got.contains(&("step-typestate".into(), 5)),
        "second begin while open must fire: {d:?}"
    );
    assert!(d.iter().any(|x| x.msg.contains("already open")), "{d:?}");

    // Back-to-back sessions — settle, then reopen — are the sanctioned
    // pipelined shape and stay clean.
    let sequential = "\
fn two_iterations(b: &mut B) {
    b.begin_step();
    b.stage();
    b.decode_layer();
    b.commit();
    b.begin_step();
    b.stage();
    b.decode_layer();
    b.rollback();
}
";
    let d = run(STEP_CFG, &[file("src/engine/x.rs", sequential)]);
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn step_typestate_rejects_prefill_after_decode() {
    let src = "\
fn reordered(b: &mut B) {
    b.begin_step();
    b.stage();
    b.decode_layer();
    b.prefill_segment();
    b.commit();
}
";
    let d = run(STEP_CFG, &[file("src/engine/x.rs", src)]);
    assert_eq!(hits(&d, "src/engine/x.rs"), vec![("step-typestate".into(), 5)], "{d:?}");
    assert!(d[0].msg.contains("prefill precedes decode"), "{}", d[0].msg);
}

// ---------------------------------------------------------------------------
// unit-dim
// ---------------------------------------------------------------------------

const UNIT_CFG: &str = "\
[units]
files = [\"src/sim/cost.rs\", \"src/metrics/\"]
converter = \"secs_to_us\"
";

#[test]
fn unit_dim_rejects_seconds_plus_bytes() {
    let src = "\
fn bad_add(stall_s: f64, demand_bytes: f64) -> f64 {
    stall_s + demand_bytes
}
";
    let d = run(UNIT_CFG, &[file("src/sim/cost.rs", src)]);
    assert_eq!(hits(&d, "src/sim/cost.rs"), vec![("unit-dim".into(), 2)], "{d:?}");
    assert!(d[0].msg.contains("S") && d[0].msg.contains("BYTES"), "{}", d[0].msg);
}

#[test]
fn unit_dim_knows_the_cost_model_algebra() {
    // bytes / bytes_per_s = s; `* 1e6` and `secs_to_us(..)` are the
    // sanctioned s -> us conversions; same-dim sums stay legal.
    let src = "\
fn ok_conversions(total_bytes: f64, link_bytes_per_s: f64, also_us: f64) -> f64 {
    let wait_s = total_bytes / link_bytes_per_s;
    let wait_us = wait_s * 1e6;
    let conv_us = secs_to_us(wait_s);
    let sum_us = wait_us + also_us;
    sum_us + conv_us
}
";
    let d = run(UNIT_CFG, &[file("src/sim/cost.rs", src)]);
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn unit_dim_rejects_unconverted_assignment_and_mixed_comparisons() {
    let src = "\
fn bad_assign(wait_s: f64) -> f64 {
    let mut out_us = 0.0;
    out_us = wait_s;
    out_us
}
fn cmp_bad(stall_s: f64, cap_bytes: f64) -> bool {
    stall_s < cap_bytes
}
fn eq_bad(stall_s: f64, cap_bytes: f64) -> bool {
    stall_s == cap_bytes
}
";
    let d = run(UNIT_CFG, &[file("src/sim/cost.rs", src)]);
    let got = hits(&d, "src/sim/cost.rs");
    assert_eq!(
        got,
        vec![("unit-dim".into(), 3), ("unit-dim".into(), 7), ("unit-dim".into(), 10)],
        "{d:?}"
    );
    assert!(d[0].msg.contains("assigns S expression to US lvalue"), "{}", d[0].msg);
    assert!(d[1].msg.contains("comparison mixes"), "{}", d[1].msg);
    assert!(d[2].msg.contains("`==` mixes"), "{}", d[2].msg);
}

#[test]
fn unit_dim_stays_silent_on_generics_and_unknown_terms() {
    // `<` and `>` in generic position see undimensioned idents; calls
    // and parenthesized expressions make the rhs unknown — the pass
    // never claims what it cannot prove.
    let src = "\
fn generics_ok(xs: Vec<f64>) -> usize {
    let m: HashMap<String, Vec<f64>> = HashMap::new();
    let total_s = compute(xs);
    m.len() + total_s as usize
}
";
    let d = run(UNIT_CFG, &[file("src/metrics/agg.rs", src)]);
    assert!(d.is_empty(), "{d:?}");

    // Out of the configured file scope: the same mixing is silent.
    let bad = "\
fn bad_add(stall_s: f64, demand_bytes: f64) -> f64 {
    stall_s + demand_bytes
}
";
    let d = run(UNIT_CFG, &[file("src/engine/x.rs", bad)]);
    assert!(d.is_empty(), "{d:?}");
}

// ---------------------------------------------------------------------------
// dead-knob
// ---------------------------------------------------------------------------

const DEAD_KNOB_CFG: &str = "\
[dead_knob]
struct_file = \"src/config/knobs.rs\"
struct_name = \"Knobs\"
exclude_dir = \"src/config\"
";

#[test]
fn unread_knob_fires_at_its_field_line() {
    let knobs = "\
pub struct Knobs {
    pub used: u32,
    pub dead: u32,
}
";
    // A read inside the excluded config dir does not make `dead` live.
    let config_side = "fn d(k: &Knobs) -> u32 { k.dead }\n";
    let consumer = "fn f(k: &Knobs) -> u32 { k.used }\n";
    let d = run(
        DEAD_KNOB_CFG,
        &[
            file("src/config/knobs.rs", knobs),
            file("src/config/defaults.rs", config_side),
            file("src/engine/x.rs", consumer),
        ],
    );
    assert_eq!(hits(&d, "src/config/knobs.rs"), vec![("dead-knob".into(), 3)], "{d:?}");
    assert!(d[0].msg.contains("dead"), "{}", d[0].msg);
}

// ---------------------------------------------------------------------------
// dead-counter
// ---------------------------------------------------------------------------

const DEAD_COUNTER_CFG: &str = "\
[dead_counter]
struct_file = \"src/stats.rs\"
struct_name = \"Metrics\"
report_dirs = [\"src/report\"]
report_fns = [\"summary\"]
";

#[test]
fn counters_must_be_written_and_reported() {
    let stats = "\
pub struct Metrics {
    pub hits: u64,
    pub ghost_w: u64,
    pub ghost_r: u64,
    pub log: Vec<u64>,
}
impl Metrics {
    pub fn summary(&self) -> u64 {
        self.hits + self.ghost_r
    }
}
";
    // `hits` and `log` are written in the engine and read by a
    // reporting surface; `ghost_w` is write-only measurement theater;
    // `ghost_r` is reported but never incremented.
    let writer = "\
fn w(m: &mut Metrics, x: u64) {
    m.hits += 1;
    m.ghost_w += 1;
    m.log.push(x);
}
";
    let reporter = "fn p(m: &Metrics) -> usize { m.log.len() }\n";
    let d = run(
        DEAD_COUNTER_CFG,
        &[
            file("src/stats.rs", stats),
            file("src/engine/x.rs", writer),
            file("src/report/out.rs", reporter),
        ],
    );
    let got = hits(&d, "src/stats.rs");
    assert_eq!(
        got,
        vec![("dead-counter".into(), 3), ("dead-counter".into(), 4)],
        "{d:?}"
    );
    let msgs: Vec<&str> = d.iter().map(|x| x.msg.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("ghost_w") && m.contains("never read")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("ghost_r") && m.contains("never written")), "{msgs:?}");
}

// ---------------------------------------------------------------------------
// allow-grammar
// ---------------------------------------------------------------------------

#[test]
fn malformed_allows_are_reported_and_do_not_suppress() {
    let src = "\
// sparselint: allow(no-panic)
fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}
// sparselint: allow(bogus-pass) -- justified at length
// sparselint: frobnicate
fn g() {}
";
    let d = run(NO_PANIC_CFG, &[file("src/engine/x.rs", src)]);
    let got = hits(&d, "src/engine/x.rs");
    assert!(got.contains(&("no-panic".into(), 3)), "bare allow must not suppress: {d:?}");
    assert!(got.contains(&("allow-grammar".into(), 1)), "{d:?}");
    assert!(got.contains(&("allow-grammar".into(), 5)), "{d:?}");
    assert!(got.contains(&("allow-grammar".into(), 6)), "{d:?}");
    assert_eq!(got.len(), 4, "{d:?}");
}

#[test]
fn config_allowlist_requires_a_reason() {
    let toml = "\
[no_panic]
modules = [\"engine\"]

[[allow]]
pass = \"no-panic\"
file = \"src/engine/x.rs\"
";
    let err = Config::from_toml(toml).expect_err("bare allowlist entry must be rejected");
    assert!(err.contains("no reason"), "{err}");
}

// ---------------------------------------------------------------------------
// Repo cleanliness: the same walk the sparselint binary does.
// ---------------------------------------------------------------------------

fn collect_rs(dir: &std::path::Path, out: &mut Vec<std::path::PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

#[test]
fn repo_is_lint_clean() {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots: [(&str, &str); 4] = [
        ("src", "rust/src"),
        ("tests", "rust/tests"),
        ("benches", "rust/benches"),
        ("../examples", "examples"),
    ];
    let mut files = Vec::new();
    for (rel, display) in roots {
        let root = manifest.join(rel);
        let mut paths = Vec::new();
        collect_rs(&root, &mut paths);
        paths.sort();
        for p in &paths {
            let src = std::fs::read_to_string(p).expect("source file readable");
            let rel_path = p.strip_prefix(&root).expect("under root");
            let shown = format!("{display}/{}", rel_path.display()).replace('\\', "/");
            files.push(file(&shown, &src));
        }
    }
    assert!(files.len() > 30, "walk found only {} files", files.len());

    let cfg = Config::repo_default();
    let diags = analyze(&files, &cfg);
    let listing: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(
        diags.is_empty(),
        "sparselint found {} violation(s) at HEAD:\n{}",
        diags.len(),
        listing.join("\n")
    );
}
