//! End-to-end serving on the REAL backend: multiple concurrent requests,
//! hybrid batches, working-set control, plus the threaded coordinator.

use std::sync::Arc;

use sparseserve::config::ServingConfig;
use sparseserve::coordinator::Server;
use sparseserve::engine::{Engine, EngineCore, PjrtBackend, SubmitRequest};
use sparseserve::runtime::Runtime;
use sparseserve::scheduler::Scheduler;
use sparseserve::workload::{generate_with_tokens, WorkloadSpec};

fn artifacts_ready() -> bool {
    Runtime::default_dir("tiny-llm").join("manifest.json").exists()
}

fn tiny_cfg(spec: &sparseserve::config::ModelSpec) -> ServingConfig {
    let mut cfg = ServingConfig::sparseserve(256, 64, spec.n_layers);
    cfg.max_inject_tokens = spec.max_ctx * spec.n_layers;
    cfg.t_max = 512;
    cfg
}

#[test]
fn serve_trace_on_real_backend() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Arc::new(Runtime::load(Runtime::default_dir("tiny-llm")).unwrap());
    let spec = rt.manifest.model.clone();
    let cfg = tiny_cfg(&spec);
    let hbm = 8 << 20;
    let backend = PjrtBackend::new(rt.clone(), cfg.clone(), hbm, 512 << 20);
    let sched = Scheduler::new(cfg, spec.clone(), hbm);
    let engine = Engine::new(sched, Box::new(backend));

    let wl = WorkloadSpec { max_prompt: 200, max_output: 6, prompt_scale: 200.0 / 32_768.0, output_scale: 0.05, rate_rps: 50.0, seed: 3 };
    let trace = generate_with_tokens(&wl, 5, 1, spec.vocab);
    let expect_tokens: usize = trace.iter().map(|r| r.max_new_tokens).sum();

    let report = engine.run_trace(trace, 1e6).unwrap();
    assert_eq!(report.metrics.requests_finished, 5);
    assert_eq!(report.metrics.tokens_generated, expect_tokens);
    assert!(report.metrics.ttft.len() == 5);
    // every request produced in-vocab tokens
    for r in report.requests.values() {
        assert!(r.is_done());
        assert!(r.generated.iter().all(|&t| (0..spec.vocab as i32).contains(&t)));
        assert_eq!(r.generated.len(), r.max_new_tokens);
    }
}

#[test]
fn decode_batching_produces_same_tokens_as_sequential() {
    // Batching must not change greedy outputs: run two identical prompts
    // concurrently and compare against a solo run.
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Arc::new(Runtime::load(Runtime::default_dir("tiny-llm")).unwrap());
    let spec = rt.manifest.model.clone();
    let prompt: Vec<i32> = (0..40).map(|i| i * 7 % spec.vocab as i32).collect();

    let run = |prompts: Vec<Vec<i32>>| -> Vec<Vec<i32>> {
        let cfg = tiny_cfg(&spec);
        let hbm = 8 << 20;
        let backend = PjrtBackend::new(rt.clone(), cfg.clone(), hbm, 512 << 20);
        let sched = Scheduler::new(cfg, spec.clone(), hbm);
        let engine = Engine::new(sched, Box::new(backend));
        let trace: Vec<_> = prompts
            .into_iter()
            .enumerate()
            .map(|(i, p)| sparseserve::scheduler::Request::with_prompt(i as u32 + 1, p, 5, 0.0))
            .collect();
        let report = engine.run_trace(trace, 1e6).unwrap();
        let mut out: Vec<(u32, Vec<i32>)> = report
            .requests
            .into_iter()
            .map(|(id, r)| (id, r.generated))
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out.into_iter().map(|(_, g)| g).collect()
    };

    let solo = run(vec![prompt.clone()]);
    let duo = run(vec![prompt.clone(), prompt.clone()]);
    assert_eq!(duo[0], solo[0], "batched decode diverged from solo");
    assert_eq!(duo[1], solo[0], "second batched request diverged");
}

#[test]
fn coordinator_server_streams_tokens() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let server = Server::start(|| {
        let rt = Arc::new(Runtime::load(Runtime::default_dir("tiny-llm"))?);
        let spec = rt.manifest.model.clone();
        let cfg = tiny_cfg(&spec);
        let hbm = 8 << 20;
        let backend = PjrtBackend::new(rt, cfg.clone(), hbm, 512 << 20);
        let sched = Scheduler::new(cfg, spec, hbm);
        Ok((sched, Box::new(backend) as Box<dyn sparseserve::engine::Backend>))
    });

    let h1 = server.submit(SubmitRequest::new((0..30).map(|i| i % 250).collect()).max_new(4));
    let h2 = server.submit(
        SubmitRequest::new((0..50).map(|i| (i * 3) % 250).collect())
            .max_new(3)
            .interactive(),
    );
    let (t1, timing1) = h1.collect().expect("stream 1");
    let (t2, timing2) = h2.collect().expect("stream 2");
    assert_eq!(t1.len(), 4);
    assert_eq!(t2.len(), 3);
    // Done must count exactly the tokens the stream delivered (a
    // prefill-only step must not inflate the count)
    assert_eq!(timing1.n_tokens, t1.len());
    assert_eq!(timing2.n_tokens, t2.len());
    assert!(timing1.ttft_s.expect("ttft") > 0.0);
    // the online path aggregates RunMetrics now
    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.requests_finished, 2);
    assert_eq!(metrics.tokens_generated, 7);
}

#[test]
fn cancellation_frees_kv_blocks_on_real_backend() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Arc::new(Runtime::load(Runtime::default_dir("tiny-llm")).unwrap());
    let spec = rt.manifest.model.clone();
    let cfg = tiny_cfg(&spec);
    let hbm = 8 << 20;
    let backend = PjrtBackend::new(rt.clone(), cfg.clone(), hbm, 512 << 20);
    let sched = Scheduler::new(cfg, spec.clone(), hbm);
    let mut core = EngineCore::new(sched, Box::new(backend));

    let prompt: Vec<i32> = (0..64).map(|i| i * 5 % spec.vocab as i32).collect();
    let id = core.submit(SubmitRequest::new(prompt).max_new(64), 0.0).unwrap();

    // drive prefill + a few decode steps so KV blocks exist in both tiers
    let mut now = 0.0;
    while core.sched().requests[&id].n_generated < 3 {
        let out = core.step(now).unwrap();
        assert!(out.ran_batch, "engine stalled mid-request");
        now += out.iter_time_s;
    }
    let before = core.mem_stats();
    assert!(before.dram_bytes_used > 0, "decode must hold DRAM KV");
    assert_eq!(before.n_registered, 1);

    assert!(core.cancel(id));
    let after = core.mem_stats();
    assert_eq!(after.dram_bytes_used, 0, "cancel must free DRAM blocks");
    assert_eq!(after.hbm_bytes_used, 0, "cancel must free HBM residency");
    assert_eq!(after.n_registered, 0);
    assert!(!core.has_work());

    let report = core.into_report(now);
    assert_eq!(report.metrics.requests_cancelled, 1);
    assert_eq!(report.metrics.requests_finished, 0);
    assert!(report.requests[&id].is_cancelled());
}

#[test]
fn mixed_buffer_execution_matches_literal_path() {
    // Device-resident weight buffers (§Perf) must be reusable across
    // executions and numerically identical to the literal path.
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    use sparseserve::runtime::{HostTensor, MixedInput};
    let rt = Runtime::load(Runtime::default_dir("tiny-llm")).unwrap();
    let toks = HostTensor::i32(vec![1], vec![42]);
    let lit = rt
        .execute("embed_1", &[&toks, rt.weights.get("embedding")])
        .unwrap();
    for _ in 0..3 {
        let mixed = rt
            .execute_mixed(
                "embed_1",
                &[MixedInput::Tensor(&toks), MixedInput::Weight("embedding")],
            )
            .unwrap();
        assert_eq!(mixed[0], lit[0]);
    }
}
