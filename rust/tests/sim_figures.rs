//! Shape-level validation of the paper-scale simulator: the qualitative
//! claims behind each figure must hold before the benches print them.

use std::collections::HashMap;

use sparseserve::config::{HardwareSpec, ModelSpec, ServingConfig};
use sparseserve::engine::{drive_step, Backend, Engine, SimBackend, StageHints};
use sparseserve::scheduler::{Batch, Phase, PrefillWork, Request, Scheduler};
use sparseserve::workload::{generate, WorkloadSpec};

fn lwm() -> (ModelSpec, HardwareSpec) {
    (ModelSpec::lwm_7b(), HardwareSpec::a100_40gb())
}

/// Fixed-batch decode throughput + loads/iter (the Fig. 1 experiment:
/// offloaded DSA serving WITHOUT batch size control).
fn fixed_batch_decode(cfg: ServingConfig, batch_size: usize, ctx: usize, iters: usize) -> (f64, f64) {
    let (spec, hw) = lwm();
    let mut b = SimBackend::new(cfg, spec, hw);
    let hints = StageHints::default();
    let mut requests = HashMap::new();
    for id in 0..batch_size as u32 {
        let mut r = Request::new(id, ctx, 1024, 0.0);
        r.phase = Phase::Prefill;
        b.register(&r).unwrap();
        requests.insert(id, r);
        let batch = Batch {
            decodes: vec![],
            prefill: Some(PrefillWork::Chunk { req: id, start: 0, len: ctx, is_last: true }),
        };
        drive_step(&mut b, &batch, &requests, &hints).unwrap();
        requests.get_mut(&id).unwrap().phase = Phase::Decode;
    }
    let batch = Batch { decodes: (0..batch_size as u32).collect(), prefill: None };
    // warm-up to steady state, then measure
    for _ in 0..10 {
        drive_step(&mut b, &batch, &requests, &hints).unwrap();
    }
    let mut time = 0.0;
    let mut loads = 0usize;
    for _ in 0..iters {
        let out = drive_step(&mut b, &batch, &requests, &hints).unwrap();
        time += out.iter_time_s;
        loads += out.blocks_loaded;
    }
    let throughput = (batch_size * iters) as f64 / time;
    (throughput, loads as f64 / iters as f64)
}

#[test]
fn fig1_throughput_peaks_then_declines_with_batch_size() {
    // SparseServe-style offloaded serving with fast transfers but NO batch
    // size control: batching helps until the aggregate working set
    // outgrows the HBM cache, then loads blow up and throughput collapses.
    let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
    cfg.ws_batch_control = false;
    cfg.r_max = 64;
    cfg.prefetch = false; // Fig. 1 isolates the raw demand-load dynamics
    let ctx = 31_000;
    let (t2, l2) = fixed_batch_decode(cfg.clone(), 2, ctx, 30);
    let (t8, l8) = fixed_batch_decode(cfg.clone(), 8, ctx, 30);
    let (t32, l32) = fixed_batch_decode(cfg.clone(), 32, ctx, 30);
    assert!(t8 > 1.5 * t2, "batching must help initially: {t2} -> {t8}");
    assert!(t32 < t8, "oversized batches must thrash: {t8} -> {t32}");
    assert!(
        l32 > 10.0 * (l8 + 1.0),
        "loads must blow up (paper: 21x): {l2} {l8} {l32}"
    );
}

fn run_system(cfg: ServingConfig, rate: f64, n: usize) -> sparseserve::metrics::RunMetrics {
    let (spec, hw) = lwm();
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
    let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
    let engine = Engine::new(sched, Box::new(backend));
    let trace = generate(&WorkloadSpec::paper_lwm(rate, 11), n, 0);
    engine.run_trace(trace, 1e7).unwrap().metrics
}

#[test]
fn fig10_11_system_ordering_at_high_rate() {
    let n = 30;
    let rate = 0.25;
    let v = run_system(ServingConfig::vllm(2048), rate, n);
    let s = run_system(ServingConfig::vllm_s(2048, 2048), rate, n);
    let ss = run_system(ServingConfig::sparseserve(2048, 2048, 32), rate, n);

    // Fig. 10: vLLM queues explode; SparseServe keeps TTFT low
    assert!(
        ss.ttft.mean() < v.ttft.mean() / 2.0,
        "SparseServe TTFT {} must be well below vLLM {}",
        ss.ttft.mean(),
        v.ttft.mean()
    );
    // Fig. 11: throughput ordering SparseServe >= vLLM-S >= vLLM (roughly)
    assert!(
        ss.throughput() > 1.2 * v.throughput(),
        "{} vs {}",
        ss.throughput(),
        v.throughput()
    );
    assert!(s.throughput() >= v.throughput() * 0.95);
}

#[test]
fn fig10_vllm_so_collapses_at_high_rate() {
    // Paper: at high rates vLLM-SO (naive memcpy offloading) becomes worse
    // than both vLLM and vLLM-S due to loading latency.
    let n = 20;
    let rate = 0.25;
    let so = run_system(ServingConfig::vllm_so(2048, 2048), rate, n);
    let ss = run_system(ServingConfig::sparseserve(2048, 2048, 32), rate, n);
    assert!(
        so.tbt.mean() > 2.0 * ss.tbt.mean(),
        "vLLM-SO TBT {} must be far above SparseServe {}",
        so.tbt.mean(),
        ss.tbt.mean()
    );
}

#[test]
fn fig12_tbt_sparseserve_close_to_vllm() {
    // moderate rate where vLLM still functions
    let v = run_system(ServingConfig::vllm(2048), 0.05, 16);
    let ss = run_system(ServingConfig::sparseserve(2048, 2048, 32), 0.05, 16);
    // paper: SparseServe TBT within ~20% of vLLM (slightly higher is OK)
    assert!(
        ss.tbt.mean() < v.tbt.mean() * 1.6,
        "SparseServe TBT {} vs vLLM {}",
        ss.tbt.mean(),
        v.tbt.mean()
    );
}

#[test]
fn fig15_ws_control_cuts_loads_at_high_rate() {
    let mut with = ServingConfig::sparseserve(2048, 2048, 32);
    with.r_max = 64;
    let mut without = with.clone();
    without.ws_batch_control = false;

    let m_with = run_system(with, 0.4, 48);
    let m_without = run_system(without, 0.4, 48);
    let loads_with = m_with.blocks_loaded_per_iter.mean();
    let loads_without = m_without.blocks_loaded_per_iter.mean();
    assert!(
        loads_without > 2.0 * (loads_with + 1.0),
        "WS control must cut loads: {loads_without} vs {loads_with}"
    );
    assert!(m_with.throughput() >= m_without.throughput() * 0.95);
}

#[test]
fn fig16a_layer_segmented_lowers_ttft_at_high_rate() {
    let ls = ServingConfig::sparseserve(2048, 2048, 32);
    let mut chunked = ls.clone();
    chunked.prefill_mode = sparseserve::config::PrefillMode::Chunked;

    let m_ls = run_system(ls, 0.25, 30);
    let m_ch = run_system(chunked, 0.25, 30);
    assert!(
        m_ls.ttft.mean() < m_ch.ttft.mean(),
        "layer-segmented TTFT {} must beat chunked {}",
        m_ls.ttft.mean(),
        m_ch.ttft.mean()
    );
}

#[test]
fn fig13_full_system_beats_vllm_when_saturated() {
    // At a saturating rate the full ladder must clearly out-serve vLLM
    // (the bench does the true goodput search; this is the smoke check).
    let rate = 0.4;
    let n = 36;
    let ladder = sparseserve::baselines::ablation_ladder(2048, 2048, 32);
    let base = run_system(ladder[0].cfg.clone(), rate, n).throughput();
    let full = run_system(ladder.last().unwrap().cfg.clone(), rate, n).throughput();
    assert!(
        full > 1.5 * base,
        "full SparseServe {full} must clearly beat vLLM {base}"
    );
}
