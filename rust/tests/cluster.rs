//! Cluster serving end-to-end: the PR's acceptance experiment.
//!
//! One skewed multi-tenant trace, one shared clock, three systems —
//! a single capacity engine, a two-engine cluster without migration,
//! and the same two engines with typed KV migration. Migration must
//! strictly win on goodput AND finished requests, with nonzero
//! migration counters and accounted wire time; the no-migration
//! variants must show the evictions it rescued.

use sparseserve::cluster::ClusterReport;
use sparseserve::figures::{cluster_trace, run_cluster_variant, ClusterVariant};

fn run_all(skew: f64, seed: u64) -> (ClusterReport, ClusterReport, ClusterReport) {
    let trace = cluster_trace(skew, seed, 14);
    let single = run_cluster_variant(ClusterVariant::Single, trace.clone());
    let scale = run_cluster_variant(ClusterVariant::ScaleOut, trace.clone());
    let migrate = run_cluster_variant(ClusterVariant::ScaleOutMigrate, trace);
    (single, scale, migrate)
}

#[test]
fn migration_strictly_beats_both_baselines_under_skew() {
    let (single, scale, migrate) = run_all(0.8, 7);

    // the pressure is real: the no-migration systems evict
    assert!(
        single.requests_evicted() > 0,
        "one pressured engine must evict under this trace"
    );
    assert!(
        scale.requests_evicted() > 0,
        "scale-out alone must still evict (the spill engine's DRAM is shallow)"
    );

    // migration actually ran, with accounted wire time and bytes
    assert!(migrate.requests_migrated() > 0, "no migrations happened");
    assert!(migrate.migration_transfer_s() > 0.0);
    assert!(migrate.migration_bytes() > 0);

    // ...and it strictly wins on both finished requests and goodput
    assert!(
        migrate.requests_finished() > scale.requests_finished(),
        "migration must rescue victims scale-out evicts: {} vs {}",
        migrate.requests_finished(),
        scale.requests_finished()
    );
    assert!(
        migrate.requests_finished() > single.requests_finished(),
        "migration must beat the single engine: {} vs {}",
        migrate.requests_finished(),
        single.requests_finished()
    );
    assert!(
        migrate.goodput_rps() > scale.goodput_rps(),
        "goodput: migration {} vs scale-out {}",
        migrate.goodput_rps(),
        scale.goodput_rps()
    );
    assert!(
        migrate.goodput_rps() > single.goodput_rps(),
        "goodput: migration {} vs single {}",
        migrate.goodput_rps(),
        single.goodput_rps()
    );

    // migration never destroys a request the baselines would have kept
    assert!(migrate.requests_evicted() <= scale.requests_evicted());

    // conservation: every request finished, was evicted, was rejected
    // (by the router or an engine), or is still live at shutdown —
    // nothing vanishes across the migration plane
    for rep in [&single, &scale, &migrate] {
        let engine_rejects: usize =
            rep.engines.iter().map(|r| r.metrics.requests_rejected).sum();
        let cancels: usize = rep.engines.iter().map(|r| r.metrics.requests_cancelled).sum();
        let live = rep
            .engines
            .iter()
            .flat_map(|r| r.requests.values())
            .filter(|r| !r.is_done() && !r.is_cancelled())
            .count();
        let accounted = rep.requests_finished()
            + rep.requests_evicted()
            + rep.rejected.len()
            + engine_rejects
            + cancels
            + live;
        assert_eq!(accounted, 14, "request conservation broke");
    }
}

#[test]
fn unskewed_trace_still_orders_the_variants_sanely() {
    let (single, scale, migrate) = run_all(0.0, 7);
    // scale-out never does worse than one engine on the same trace
    assert!(scale.requests_finished() >= single.requests_finished());
    assert!(migrate.requests_finished() >= scale.requests_finished());
    // the shared clock is one clock: every per-engine report got the
    // same makespan stamp
    for rep in [&single, &scale, &migrate] {
        for e in &rep.engines {
            assert!((e.metrics.makespan_s - rep.makespan_s).abs() < 1e-9);
        }
    }
}

#[test]
fn migration_counters_live_at_the_source_engine() {
    let trace = cluster_trace(0.8, 7, 14);
    let rep = run_cluster_variant(ClusterVariant::ScaleOutMigrate, trace);
    assert!(rep.requests_migrated() > 0);
    // engine 0 is the pressured capacity engine: every drain starts
    // there, so it owns the migration counters...
    assert_eq!(rep.engines[0].metrics.requests_migrated, rep.requests_migrated());
    assert!(rep.engines[0].metrics.migration_transfer_total_s > 0.0);
    // ...and the spill engine only receives (imports are not drains)
    assert_eq!(rep.engines[1].metrics.requests_migrated, 0);
    // rescued victims really finish on the spill engine
    assert!(rep.engines[1].metrics.requests_finished > 0);
}
