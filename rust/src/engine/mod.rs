//! Model executor: drives batches from the scheduler through a backend.
//!
//! [`EngineCore`] owns the per-iteration serving logic (plan →
//! [`StepSession`] phases → commit/rollback → emit → release) plus the
//! request lifecycle (submit / cancel / typed errors). Two thin drivers
//! sit on top:
//!
//! - [`Engine::run_trace`]: offline, clock-driven trace replay;
//! - [`crate::coordinator::Server`]: online, thread-driven streaming.
//!
//! Two backends share the exact same scheduler / KV-manager / DSA control
//! logic (the paper's contribution), differing only in how a batch's
//! compute is realized:
//!
//! - [`PjrtBackend`]: the real three-layer path — tiny-llm AOT artifacts
//!   executed on the PJRT CPU client, real KV bytes in the block pools,
//!   greedy decode bit-identical to the python goldens.
//! - [`SimBackend`]: the paper-scale testbed substitute — analytic
//!   compute/PCIe cost models + the Fig. 8-calibrated synthetic
//!   selection process, at LWM-7B / Llama3-8B scale.

// Serving-path no-panic discipline (satellite of sparselint's
// `no-panic` pass): unwrap/expect in this module tree is a clippy
// warning, denied under CI's `-D warnings`. The few justified
// sites carry fn-level allows next to their sparselint comments.
#![warn(clippy::unwrap_used, clippy::expect_used)]

mod backend;
mod core;
mod error;
mod pjrt_backend;
mod serve_loop;
mod sim_backend;

pub use backend::{
    drive_step, drive_step_pipelined, prefill_layer_range, Backend, BatchOutcome, MemStats,
    MigrationPayload, PhaseEvent, StageHints, StepSession,
};
pub use self::core::{
    EngineCore, MigrationCandidate, RunReport, StepOutcome, SubmitRequest, TokenEvent,
};
pub use error::ServeError;
pub use pjrt_backend::PjrtBackend;
pub use serve_loop::Engine;
pub use sim_backend::SimBackend;
