//! The real three-layer backend: tiny-llm AOT artifacts on PJRT.
//!
//! Mirrors `python/compile/pipeline.py` operation for operation so greedy
//! decode reproduces the python goldens bit-for-bit:
//!
//! prefill (layer-segmented): embed -> per-layer `prefill_layer_{T}` ->
//!   KV saved via the transfer engine -> `lm_head` on the last valid row;
//! prefill (chunked baseline): per-chunk, per-layer `prefill_chunk_{T}`
//!   with the accumulated past re-exported from DRAM each chunk;
//! decode: `decode_qkv_{B}` (projection+RoPE+block scoring) -> host
//!   top-k -> KV-manager gather (FlashH2D on misses) ->
//!   `decode_attend_{B}_{K}` (sparse attention+FFN) -> `lm_head_{B}`.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::ServingConfig;
use crate::memory::manager::NEG_INF;
use crate::memory::{engine_for, KvManager, MemoryError, ReqId};
use crate::runtime::{HostTensor, MixedInput, Runtime};
use crate::scheduler::{Batch, PrefillWork, Request};
use crate::sparse::{top_k_blocks_fast, WorkingSetTracker};

use super::backend::{Backend, BatchOutcome, MemStats};

struct RealReq {
    last_token: i32,
    /// Layer-segmented prefill activation carried across batches:
    /// (data [t_pad, d], t_pad, t_real).
    hidden: Option<(Vec<f32>, usize, usize)>,
    ws: WorkingSetTracker,
}

pub struct PjrtBackend {
    pub rt: Arc<Runtime>,
    pub cfg: ServingConfig,
    pub kv: KvManager,
    reqs: HashMap<ReqId, RealReq>,
    /// Precomputed per-layer weight names (device-resident buffer keys).
    layer_wnames: Vec<Vec<String>>,
    /// When set, every decode step's full (layer, head, block) selection is
    /// appended to `selection_log` (single-request experiments: Fig. 8).
    pub record_selections: bool,
    pub selection_log: Vec<Vec<(u16, u16, u32)>>,
}

impl PjrtBackend {
    pub fn new(rt: Arc<Runtime>, cfg: ServingConfig, hbm_kv_bytes: usize, dram_bytes: usize) -> Self {
        let spec = rt.manifest.model.clone();
        let engine = engine_for(cfg.transfer, crate::config::HardwareSpec::a100_40gb());
        let layer_wnames = (0..spec.n_layers)
            .map(|i| {
                crate::runtime::WeightStore::layer_names(i)
            })
            .collect();
        let kv = KvManager::new(spec, hbm_kv_bytes, dram_bytes, cfg.offload, engine);
        Self {
            rt,
            cfg,
            kv,
            reqs: HashMap::new(),
            layer_wnames,
            record_selections: false,
            selection_log: Vec::new(),
        }
    }

    /// Weight name for (layer, LAYER_WEIGHT_NAMES index).
    fn wname(&self, layer: usize, idx: usize) -> &str {
        &self.layer_wnames[layer][idx]
    }

    fn spec(&self) -> &crate::config::ModelSpec {
        self.kv.spec()
    }

    /// Budget in blocks, clamped to the model's max (dense = all blocks).
    fn budget_needed(&self) -> usize {
        let nb = self.spec().max_blocks();
        if self.cfg.sparse_attention {
            self.cfg.budget_blocks(self.spec().block_size).min(nb)
        } else {
            nb
        }
    }

    /// Smallest compiled K bucket covering the budget.
    fn budget_bucket(&self) -> Result<usize> {
        let need = self.budget_needed();
        self.rt
            .manifest
            .fit_bucket("budget_k", need)
            .ok_or_else(|| anyhow!("no budget_k bucket >= {need}"))
    }

    // ------------------------------------------------------------- prefill

    fn run_prefill(&mut self, work: &PrefillWork, requests: &HashMap<ReqId, Request>, out: &mut BatchOutcome) -> Result<()> {
        match work {
            PrefillWork::LayerSegment { req, layer_start, layer_end, tok_start, tok_len, is_last } => {
                let r = &requests[req];
                if *tok_start != 0 || *tok_len != r.prompt_len {
                    return Err(anyhow!(
                        "real backend supports whole-prompt layer segments only \
                         (hybrid within-layer chunking is simulator-only); \
                         set max_inject_tokens >= max prompt length"
                    ));
                }
                self.prefill_layers(*req, r, *layer_start, *layer_end, *is_last, out)
            }
            PrefillWork::Chunk { req, start, len, is_last } => {
                let r = &requests[req];
                if *start == 0 && *len == r.prompt_len {
                    // plain prefill = all layers, whole prompt, no past
                    self.prefill_layers(*req, r, 0, self.spec().n_layers, *is_last, out)
                } else {
                    self.prefill_chunk(*req, r, *start, *len, *is_last, out)
                }
            }
        }
    }

    /// Whole-prompt prefill of layers [layer_start, layer_end).
    fn prefill_layers(
        &mut self,
        id: ReqId,
        req: &Request,
        layer_start: usize,
        layer_end: usize,
        is_last: bool,
        out: &mut BatchOutcome,
    ) -> Result<()> {
        let d = self.spec().d_model;
        let plen = req.prompt_len;
        let t_pad = self
            .rt
            .manifest
            .fit_bucket("prefill_t", plen)
            .ok_or_else(|| anyhow!("prompt {plen} exceeds prefill buckets"))?;

        // layer 0: embed the (padded) prompt; later segments restore the
        // saved activation (paper Fig. 9: "activation states ... saved")
        let mut x: Vec<f32> = if layer_start == 0 {
            let mut toks = vec![0i32; t_pad];
            toks[..plen].copy_from_slice(&req.prompt);
            let tokens = HostTensor::i32(vec![t_pad], toks);
            let outs = self
                .rt
                .execute(&format!("embed_{t_pad}"), &[&tokens, self.rt.weights.get("embedding")])?;
            outs[0].as_f32().to_vec()
        } else {
            let (h, tp, _tr) = self
                .reqs
                .get_mut(&id)
                .and_then(|r| r.hidden.take())
                .ok_or_else(|| anyhow!("missing saved activation for req {id}"))?;
            debug_assert_eq!(tp, t_pad);
            h
        };

        let mut seg_mask = vec![0.0f32; t_pad];
        seg_mask[plen..].fill(NEG_INF);
        let seg_mask_t = HostTensor::f32(vec![t_pad], seg_mask);
        let pos0 = HostTensor::scalar_i32(0);

        for layer in layer_start..layer_end {
            let xt = HostTensor::f32(vec![t_pad, d], x);
            let lw = self.rt.weights.layer(layer);
            let mut inputs: Vec<&HostTensor> = vec![&xt, &pos0, &seg_mask_t];
            inputs.extend(lw);
            let outs = self.rt.execute(&format!("prefill_layer_{t_pad}"), &inputs)?;
            // outs: (k [Hkv,T,Dh], v, x2 [T,d])
            self.kv
                .append_prefill_layer(id, layer, outs[0].as_f32(), outs[1].as_f32(), t_pad, plen)?;
            x = outs[2].as_f32().to_vec();
        }

        if is_last {
            let tok = self.lm_head_rows(&[(&x, t_pad, plen - 1)])?[0];
            let st = self.reqs.get_mut(&id).expect("unregistered");
            st.last_token = tok;
            st.hidden = None;
            out.tokens.push((id, Some(tok)));
        } else {
            self.reqs.get_mut(&id).expect("unregistered").hidden = Some((x, t_pad, plen));
        }
        Ok(())
    }

    /// One chunk of the chunked-prefill baseline (start > 0: has past).
    fn prefill_chunk(
        &mut self,
        id: ReqId,
        req: &Request,
        start: usize,
        len: usize,
        is_last: bool,
        out: &mut BatchOutcome,
    ) -> Result<()> {
        let spec = self.spec().clone();
        let (d, hkv, dh) = (spec.d_model, spec.n_kv_heads, spec.head_dim);
        let t_pad = self
            .rt
            .manifest
            .fit_bucket("chunk_t", len)
            .ok_or_else(|| anyhow!("chunk {len} exceeds chunk buckets"))?;
        let p_max = self.rt.manifest.chunk_past;
        if start > p_max {
            return Err(anyhow!("past {start} exceeds chunk_past bucket {p_max}"));
        }

        let mut toks = vec![0i32; t_pad];
        toks[..len].copy_from_slice(&req.prompt[start..start + len]);
        let tokens = HostTensor::i32(vec![t_pad], toks);
        let embedded = self
            .rt
            .execute(&format!("embed_{t_pad}"), &[&tokens, self.rt.weights.get("embedding")])?;
        let mut x = embedded[0].as_f32().to_vec();

        let mut seg_mask = vec![0.0f32; t_pad];
        seg_mask[len..].fill(NEG_INF);
        let seg_mask_t = HostTensor::f32(vec![t_pad], seg_mask);
        let pos = HostTensor::scalar_i32(start as i32);

        for layer in 0..spec.n_layers {
            // export this layer's accumulated past (exactly `start` tokens)
            let mut pk = vec![0.0f32; hkv * p_max * dh];
            let mut pv = vec![0.0f32; hkv * p_max * dh];
            let mut pm = vec![0.0f32; p_max];
            self.kv.export_past(id, layer, p_max, &mut pk, &mut pv, &mut pm);
            let pk_t = HostTensor::f32(vec![hkv, p_max, dh], pk);
            let pv_t = HostTensor::f32(vec![hkv, p_max, dh], pv);
            let pm_t = HostTensor::f32(vec![p_max], pm);

            let xt = HostTensor::f32(vec![t_pad, d], x);
            let lw = self.rt.weights.layer(layer);
            let mut inputs: Vec<&HostTensor> = vec![&xt, &pos, &seg_mask_t, &pk_t, &pv_t, &pm_t];
            inputs.extend(lw);
            let outs = self.rt.execute(&format!("prefill_chunk_{t_pad}"), &inputs)?;
            self.kv
                .append_prefill_layer(id, layer, outs[0].as_f32(), outs[1].as_f32(), t_pad, len)?;
            x = outs[2].as_f32().to_vec();
        }

        if is_last {
            let tok = self.lm_head_rows(&[(&x, t_pad, len - 1)])?[0];
            self.reqs.get_mut(&id).expect("unregistered").last_token = tok;
            out.tokens.push((id, Some(tok)));
        }
        Ok(())
    }

    /// lm_head over selected rows of hidden states: (data [t_pad, d], t_pad, row).
    fn lm_head_rows(&self, rows: &[(&Vec<f32>, usize, usize)]) -> Result<Vec<i32>> {
        let d = self.spec().d_model;
        let b = rows.len();
        let b_pad = self
            .rt
            .manifest
            .fit_bucket("decode_b", b)
            .ok_or_else(|| anyhow!("no decode bucket >= {b}"))?;
        let mut x = vec![0.0f32; b_pad * d];
        for (i, (data, _t_pad, row)) in rows.iter().enumerate() {
            x[i * d..(i + 1) * d].copy_from_slice(&data[row * d..(row + 1) * d]);
        }
        let xt = HostTensor::f32(vec![b_pad, d], x);
        let outs = self.rt.execute_mixed(
            &format!("lm_head_{b_pad}"),
            &[
                MixedInput::Tensor(&xt),
                MixedInput::Weight("final_norm"),
                MixedInput::Weight("lm_head"),
            ],
        )?;
        Ok(outs[0].as_i32()[..b].to_vec())
    }

    // -------------------------------------------------------------- decode

    /// One decode step for a group of requests (<= max decode bucket).
    fn decode_group(&mut self, ids: &[ReqId], out: &mut BatchOutcome) -> Result<()> {
        let spec = self.spec().clone();
        let (d, hq, hkv, dh, bs) =
            (spec.d_model, spec.n_heads, spec.n_kv_heads, spec.head_dim, spec.block_size);
        let nb = spec.max_blocks();
        let b = ids.len();
        let b_pad = self
            .rt
            .manifest
            .fit_bucket("decode_b", b)
            .ok_or_else(|| anyhow!("no decode bucket >= {b}"))?;
        let k_bucket = self.budget_bucket()?;
        let budget = self.budget_needed().min(k_bucket);
        let s_len = k_bucket * bs;

        // ---- embed last tokens ----
        let mut toks = vec![0i32; b_pad];
        for (i, id) in ids.iter().enumerate() {
            toks[i] = self.reqs[id].last_token;
        }
        let tokens = HostTensor::i32(vec![b_pad], toks);
        let emb = self.rt.execute_mixed(
            &format!("embed_{b_pad}"),
            &[MixedInput::Tensor(&tokens), MixedInput::Weight("embedding")],
        )?;
        let mut x = emb[0].as_f32().to_vec(); // [b_pad, d]

        // positions: current sequence length (same for every layer)
        let mut pos = vec![0i32; b_pad];
        for (i, id) in ids.iter().enumerate() {
            pos[i] = self.kv.seq_len(*id) as i32;
        }
        let pos_t = HostTensor::i32(vec![b_pad], pos);

        // per-step working-set recordings
        let mut ws_items: Vec<Vec<(u16, u16, u32)>> = vec![Vec::new(); b];

        for layer in 0..spec.n_layers {
            // ---- metadata tensors ----
            let mut lo = vec![0.0f32; b_pad * hkv * nb * dh];
            let mut hi = vec![0.0f32; b_pad * hkv * nb * dh];
            let mut mm = vec![NEG_INF; b_pad * hkv * nb];
            for (i, id) in ids.iter().enumerate() {
                let lo_s = &mut lo[i * hkv * nb * dh..(i + 1) * hkv * nb * dh];
                let hi_s = &mut hi[i * hkv * nb * dh..(i + 1) * hkv * nb * dh];
                let mm_s = &mut mm[i * hkv * nb..(i + 1) * hkv * nb];
                self.kv.metadata_into(*id, layer, nb, lo_s, hi_s, mm_s);
            }
            let xt = HostTensor::f32(vec![b_pad, d], x.clone());
            let lo_t = HostTensor::f32(vec![b_pad, hkv, nb, dh], lo);
            let hi_t = HostTensor::f32(vec![b_pad, hkv, nb, dh], hi);
            let mm_t = HostTensor::f32(vec![b_pad, hkv, nb], mm);
            let inputs = [
                MixedInput::Tensor(&xt),
                MixedInput::Tensor(&pos_t),
                MixedInput::Tensor(&lo_t),
                MixedInput::Tensor(&hi_t),
                MixedInput::Tensor(&mm_t),
                MixedInput::Weight(self.wname(layer, 0)), // attn_norm
                MixedInput::Weight(self.wname(layer, 1)), // wq
                MixedInput::Weight(self.wname(layer, 2)), // wk
                MixedInput::Weight(self.wname(layer, 3)), // wv
            ];
            let outs = self.rt.execute_mixed(&format!("decode_qkv_{b_pad}"), &inputs)?;
            // outs: q [B,Hq,Dh], k [B,Hkv,Dh], v [B,Hkv,Dh], scores [B,Hkv,NB]
            let q = outs[0].as_f32();
            let kk = outs[1].as_f32();
            let vv = outs[2].as_f32();
            let scores = outs[3].as_f32();

            // ---- save new token KV ----
            for (i, id) in ids.iter().enumerate() {
                self.kv.append_decode_token(
                    *id,
                    layer,
                    &kk[i * hkv * dh..(i + 1) * hkv * dh],
                    &vv[i * hkv * dh..(i + 1) * hkv * dh],
                )?;
            }

            // ---- select + gather ----
            let mut gk = vec![0.0f32; b_pad * hkv * s_len * dh];
            let mut gv = vec![0.0f32; b_pad * hkv * s_len * dh];
            let mut gm = vec![NEG_INF; b_pad * hkv * s_len];
            for (i, id) in ids.iter().enumerate() {
                let n_sealed = self.kv.n_sealed(*id, layer);
                let sel: Vec<Vec<u32>> = (0..hkv)
                    .map(|h| {
                        let row = &scores[(i * hkv + h) * nb..(i * hkv + h + 1) * nb];
                        top_k_blocks_fast(row, n_sealed, budget.saturating_sub(1))
                    })
                    .collect();
                for (h, sh) in sel.iter().enumerate() {
                    for &blk in sh {
                        ws_items[i].push((layer as u16, h as u16, blk));
                    }
                    // the open block is part of the working set too
                    if self.kv.open_fill(*id, layer) > 0 {
                        ws_items[i].push((layer as u16, h as u16, n_sealed as u32));
                    }
                }
                let gk_s = &mut gk[i * hkv * s_len * dh..(i + 1) * hkv * s_len * dh];
                let gv_s = &mut gv[i * hkv * s_len * dh..(i + 1) * hkv * s_len * dh];
                let gm_s = &mut gm[i * hkv * s_len..(i + 1) * hkv * s_len];
                self.kv.gather_into(*id, layer, &sel, k_bucket, gk_s, gv_s, gm_s)?;
            }

            // ---- sparse attention + FFN ----
            let xt = HostTensor::f32(vec![b_pad, d], x);
            let q_t = HostTensor::f32(vec![b_pad, hq, dh], q.to_vec());
            let gk_t = HostTensor::f32(vec![b_pad, hkv, s_len, dh], gk);
            let gv_t = HostTensor::f32(vec![b_pad, hkv, s_len, dh], gv);
            let gm_t = HostTensor::f32(vec![b_pad, hkv, s_len], gm);
            let inputs = [
                MixedInput::Tensor(&xt),
                MixedInput::Tensor(&q_t),
                MixedInput::Tensor(&gk_t),
                MixedInput::Tensor(&gv_t),
                MixedInput::Tensor(&gm_t),
                MixedInput::Weight(self.wname(layer, 4)), // wo
                MixedInput::Weight(self.wname(layer, 5)), // ffn_norm
                MixedInput::Weight(self.wname(layer, 6)), // w_gate
                MixedInput::Weight(self.wname(layer, 7)), // w_up
                MixedInput::Weight(self.wname(layer, 8)), // w_down
            ];
            let outs = self
                .rt
                .execute_mixed(&format!("decode_attend_{b_pad}_{k_bucket}"), &inputs)?;
            x = outs[0].as_f32().to_vec();
        }

        // ---- next token ----
        let xt = HostTensor::f32(vec![b_pad, d], x);
        let outs = self.rt.execute_mixed(
            &format!("lm_head_{b_pad}"),
            &[
                MixedInput::Tensor(&xt),
                MixedInput::Weight("final_norm"),
                MixedInput::Weight("lm_head"),
            ],
        )?;
        let next = outs[0].as_i32();
        for (i, id) in ids.iter().enumerate() {
            let st = self.reqs.get_mut(id).unwrap();
            st.last_token = next[i];
            let items = std::mem::take(&mut ws_items[i]);
            if self.record_selections {
                self.selection_log.push(items.clone());
            }
            let st = self.reqs.get_mut(id).unwrap();
            st.ws.record_step(items);
            out.tokens.push((*id, Some(next[i])));
        }
        Ok(())
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn register(&mut self, req: &Request) -> Result<()> {
        self.kv.register(req.id);
        self.reqs.insert(
            req.id,
            RealReq {
                last_token: 0,
                hidden: None,
                ws: WorkingSetTracker::new(self.cfg.ws_window),
            },
        );
        Ok(())
    }

    fn release(&mut self, req: ReqId) {
        self.kv.release(req);
        self.reqs.remove(&req);
    }

    fn mem_stats(&self) -> MemStats {
        MemStats {
            hbm_bytes_used: self.kv.hbm_bytes_used(),
            // without offloading the DRAM pool *models* HBM storage and
            // is already counted above — don't double-report it
            dram_bytes_used: if self.kv.offload() { self.kv.dram_bytes_used() } else { 0 },
            n_registered: self.reqs.len(),
        }
    }

    /// Stage each scheduled decode's predicted working set — the
    /// recency-ranked `(layer, head, block)` union from its tracker — as
    /// asynchronous FlashH2D copies, FCFS priority. Staged blocks are
    /// pinned until consumed by this batch's gathers (hit) or retired at
    /// `end_iteration` (wasted).
    fn prefetch(&mut self, decodes: &[ReqId]) -> usize {
        if !(self.cfg.prefetch && self.cfg.offload && self.cfg.sparse_attention) {
            return 0;
        }
        // over-collect by 2x: already-resident plan entries are skipped
        // by staging without consuming its budget
        let plan_cap = self.cfg.max_prefetch_blocks.saturating_mul(2);
        let mut plan = Vec::new();
        for &id in decodes {
            if plan.len() >= plan_cap {
                break;
            }
            let Some(r) = self.reqs.get(&id) else { continue };
            for (layer, head, block) in r.ws.ranked_blocks_capped(plan_cap - plan.len()) {
                plan.push(crate::memory::BlockKey::new(id, layer, head, block));
            }
        }
        // keep one gather's worst-case pins (every head at full budget)
        // worth of slots free for demand misses — clamped so a small HBM
        // cache (where that exceeds capacity) can still stage half of it
        let headroom = (self.spec().n_kv_heads * self.budget_needed())
            .min(self.kv.cache_capacity_slots() / 2);
        self.kv
            .prefetch_working_set(&plan, self.cfg.max_prefetch_blocks, headroom)
    }

    fn decode_ws_bytes(&mut self, req: ReqId) -> usize {
        let bb = self.kv.block_bytes();
        let spec = self.kv.spec();
        let init = {
            let budget = if self.cfg.sparse_attention {
                self.cfg.budget_blocks(spec.block_size)
            } else {
                spec.max_blocks()
            };
            budget.min(self.kv.n_blocks(req).max(1))
                * spec.n_kv_heads
                * spec.n_layers
                * bb
        };
        let r = match self.reqs.get_mut(&req) {
            Some(r) => r,
            None => return init,
        };
        if r.ws.steps_recorded() == 0 {
            init
        } else {
            r.ws.ws_bytes(bb)
        }
    }

    fn run_batch(
        &mut self,
        batch: &Batch,
        requests: &HashMap<ReqId, Request>,
    ) -> Result<BatchOutcome> {
        let t0 = Instant::now();
        let mut out = BatchOutcome::default();

        if let Some(work) = &batch.prefill {
            self.run_prefill(work, requests, &mut out)?;
        }

        // Pre-flight: a decode step allocates DRAM blocks only for
        // requests sitting on a block boundary. Fail typed BEFORE
        // mutating anyone's KV so an eviction never leaves the surviving
        // batch-mates with a half-applied step (duplicated KV on re-run).
        let mut needed = 0usize;
        let mut boundary_req = None;
        for &id in &batch.decodes {
            let n = self.kv.decode_slots_needed(id);
            if n > 0 && boundary_req.is_none() {
                boundary_req = Some(id);
            }
            needed += n;
        }
        if needed > self.kv.dram_free_slots() {
            let req = boundary_req.unwrap_or(batch.decodes[0]);
            return Err(MemoryError::DramExhausted { req }.into());
        }

        // split decodes into compiled batch buckets
        let max_b = self
            .rt
            .manifest
            .bucket("decode_b")
            .iter()
            .copied()
            .max()
            .unwrap_or(1);
        for group in batch.decodes.chunks(max_b) {
            self.decode_group(group, &mut out)?;
        }

        let iter = self.kv.end_iteration();
        out.blocks_loaded = iter.blocks_loaded + iter.prefetch_blocks;
        out.load_time_s = iter.load.modeled_s + iter.prefetch.modeled_s;
        out.save_time_s = iter.save.modeled_s;
        // demand loads are the PCIe time the gather had to wait on; the
        // staged (prefetch) stream overlapped compute
        out.stall_time_s = iter.load.modeled_s;
        out.prefetch_blocks = iter.prefetch_blocks;
        out.prefetch_hits = iter.prefetch_hits;
        out.prefetch_wasted = iter.prefetch_wasted;
        out.iter_time_s = t0.elapsed().as_secs_f64();
        Ok(out)
    }
}
