//! The real three-layer backend: tiny-llm AOT artifacts on PJRT.
//!
//! Mirrors `python/compile/pipeline.py` operation for operation so greedy
//! decode reproduces the python goldens bit-for-bit:
//!
//! prefill (layer-segmented): embed -> per-layer `prefill_layer_{T}` ->
//!   KV saved via the transfer engine -> `lm_head` on the last valid row;
//! prefill (chunked baseline): per-chunk, per-layer `prefill_chunk_{T}`
//!   with the accumulated past re-exported from DRAM each chunk;
//! decode: `decode_qkv_{B}` (projection+RoPE+block scoring) -> host
//!   top-k -> KV-manager gather (FlashH2D on misses) ->
//!   `decode_attend_{B}_{K}` (sparse attention+FFN) -> `lm_head_{B}`.
//!
//! Execution is session-based ([`super::StepSession`]): `begin_step`
//! pre-flights the decode step's DRAM demand (typed failure with zero
//! side effects), opens a [`crate::memory::KvManager`] transaction and
//! snapshots each batch participant's last token. The engine then drives
//! one `prefill_segment`/`decode_layer` call per layer — layer-segmented
//! prefill is the real execution path, not a planner annotation — and a
//! mid-batch typed `MemoryError` (mid-gather `HbmExhausted`, append
//! `DramExhausted`) rolls the whole step back: KV truncated to pre-step
//! lengths, stale residency purged, activations restored, so the
//! surviving batch-mates re-run identically in the same iteration.
//!
//! ## Zero-clone step pipeline
//!
//! The carried layer-segmented prefill activation is never cloned: it is
//! *moved* out of the request at `pf_init`, recovered from the input
//! tensor after the first layer runs, and kept aside for rollback
//! (move-based copy-on-write) — replacing the old multi-megabyte
//! per-hybrid-batch clone in `begin_step`. The per-layer decode hot loop
//! builds its metadata/gather tensors and top-k selections in recycled
//! buffers ([`GatherScratch`]) and reclaims each input tensor's storage
//! after execution ([`HostTensor::into_f32`]), so steady-state decode
//! allocates no fresh staging buffers. Aborted (rolled-back) sessions
//! charge their wall time to the next commit's
//! [`BatchOutcome::abort_time_s`].

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::ServingConfig;
use crate::memory::manager::NEG_INF;
use crate::memory::{engine_for, BlockKey, KvManager, MemoryError, ReqId};
use crate::runtime::{HostTensor, MixedInput, Runtime};
use crate::scheduler::{Batch, PrefillWork, Request};
use crate::sparse::{top_k_blocks_fast_into, WorkingSetTracker};

use super::backend::{
    Backend, BatchOutcome, MemStats, PhaseEvent, StageHints, StepSession,
};

/// Fallible positional access into a kernel's output list. Each kernel's
/// output arity is fixed by the compiled manifest, so a short list means
/// the loaded artifact disagrees with this driver — surfaced as a typed
/// error instead of an index panic on the serving path.
fn nth<'a>(outs: &'a [HostTensor], i: usize) -> Result<&'a HostTensor> {
    outs.get(i).ok_or_else(|| {
        anyhow!("kernel returned {} outputs, expected at least {}", outs.len(), i + 1)
    })
}

struct RealReq {
    last_token: i32,
    /// Layer-segmented prefill activation carried across batches:
    /// (data [t_pad, d], t_pad, t_real).
    hidden: Option<(Vec<f32>, usize, usize)>,
    ws: WorkingSetTracker,
}

/// Recycled per-step buffers for the decode hot loop and the staging
/// planner: taken out, used, and put back each phase so steady-state
/// decode performs no fresh tensor-staging allocations.
#[derive(Default)]
struct GatherScratch {
    /// Metadata tensors for `decode_qkv` (lo/hi cuboids + mask).
    lo: Vec<f32>,
    hi: Vec<f32>,
    mm: Vec<f32>,
    /// Gather staging tensors for `decode_attend` (K/V/mask).
    gk: Vec<f32>,
    gv: Vec<f32>,
    gm: Vec<f32>,
    /// Per-head top-k output buffers.
    sel: Vec<Vec<u32>>,
    /// Staging plan (prefetch path).
    plan: Vec<BlockKey>,
    /// Ranked working-set buffer feeding the plan.
    ranked: Vec<(u16, u16, u32)>,
}

pub struct PjrtBackend {
    pub rt: Arc<Runtime>,
    pub cfg: ServingConfig,
    pub kv: KvManager,
    reqs: HashMap<ReqId, RealReq>,
    /// Precomputed per-layer weight names (device-resident buffer keys).
    layer_wnames: Vec<Vec<String>>,
    /// Recycled hot-loop buffers (see [`GatherScratch`]).
    scratch: GatherScratch,
    /// Second scratch slot of the pipelined executor's double buffer:
    /// `begin_step` rotates the two so the previous iteration's gather
    /// buffers stay intact while the engine speculatively plans the
    /// next batch. Both slots are warm after two iterations, keeping
    /// steady-state decode allocation-free.
    scratch_spare: GatherScratch,
    /// Wall time burnt by rolled-back sessions, awaiting the next
    /// commit's `abort_time_s` (or `abort_iteration`).
    aborted_time_s: f64,
    /// When set, every decode step's full (layer, head, block) selection is
    /// appended to `selection_log` (single-request experiments: Fig. 8).
    pub record_selections: bool,
    pub selection_log: Vec<Vec<(u16, u16, u32)>>,
}

impl PjrtBackend {
    pub fn new(rt: Arc<Runtime>, cfg: ServingConfig, hbm_kv_bytes: usize, dram_bytes: usize) -> Self {
        let spec = rt.manifest.model.clone();
        let engine = engine_for(cfg.transfer, crate::config::HardwareSpec::a100_40gb());
        let layer_wnames = (0..spec.n_layers)
            .map(|i| {
                crate::runtime::WeightStore::layer_names(i)
            })
            .collect();
        let kv = KvManager::new(spec, hbm_kv_bytes, dram_bytes, cfg.offload, engine);
        Self {
            rt,
            cfg,
            kv,
            reqs: HashMap::new(),
            layer_wnames,
            scratch: GatherScratch::default(),
            scratch_spare: GatherScratch::default(),
            aborted_time_s: 0.0,
            record_selections: false,
            selection_log: Vec::new(),
        }
    }

    /// Weight name for (layer, LAYER_WEIGHT_NAMES index).
    fn wname(&self, layer: usize, idx: usize) -> &str {
        &self.layer_wnames[layer][idx]
    }

    fn spec(&self) -> &crate::config::ModelSpec {
        self.kv.spec()
    }

    /// Budget in blocks, clamped to the model's max (dense = all blocks).
    fn budget_needed(&self) -> usize {
        let nb = self.spec().max_blocks();
        if self.cfg.sparse_attention {
            self.cfg.budget_blocks(self.spec().block_size).min(nb)
        } else {
            nb
        }
    }

    /// Smallest compiled K bucket covering the budget.
    fn budget_bucket(&self) -> Result<usize> {
        let need = self.budget_needed();
        self.rt
            .manifest
            .fit_bucket("budget_k", need)
            .ok_or_else(|| anyhow!("no budget_k bucket >= {need}"))
    }

    /// lm_head over selected rows of hidden states: (data [t_pad, d], t_pad, row).
    fn lm_head_rows(&self, rows: &[(&Vec<f32>, usize, usize)]) -> Result<Vec<i32>> {
        let d = self.spec().d_model;
        let b = rows.len();
        let b_pad = self
            .rt
            .manifest
            .fit_bucket("decode_b", b)
            .ok_or_else(|| anyhow!("no decode bucket >= {b}"))?;
        let mut x = vec![0.0f32; b_pad * d];
        for (i, (data, _t_pad, row)) in rows.iter().enumerate() {
            x[i * d..(i + 1) * d].copy_from_slice(&data[row * d..(row + 1) * d]);
        }
        let xt = HostTensor::f32(vec![b_pad, d], x);
        let outs = self.rt.execute_mixed(
            &format!("lm_head_{b_pad}"),
            &[
                MixedInput::Tensor(&xt),
                MixedInput::Weight("final_norm"),
                MixedInput::Weight("lm_head"),
            ],
        )?;
        Ok(nth(&outs, 0)?.as_i32()[..b].to_vec())
    }

    /// Embed `tokens` padded to the named bucket family; returns the
    /// activation and the padded length. Shared by every prefill entry
    /// path (whole-prompt layer-segmented, plain, chunked).
    fn embed_padded(&self, tokens: &[i32], bucket: &str) -> Result<(Vec<f32>, usize)> {
        let t_pad = self
            .rt
            .manifest
            .fit_bucket(bucket, tokens.len())
            .ok_or_else(|| anyhow!("{} tokens exceed {bucket} buckets", tokens.len()))?;
        let mut toks = vec![0i32; t_pad];
        toks[..tokens.len()].copy_from_slice(tokens);
        let tokens_t = HostTensor::i32(vec![t_pad], toks);
        let mut outs = self.rt.execute(
            &format!("embed_{t_pad}"),
            &[&tokens_t, self.rt.weights.get("embedding")],
        )?;
        Ok((outs.swap_remove(0).into_f32(), t_pad))
    }

    /// Recency-ranked staging plan for a set of decode requests, FCFS,
    /// built into a caller-owned buffer (the ranked working sets come
    /// through the tracker's recycled `_into` path).
    fn staging_plan_into(&mut self, ids: &[ReqId], cap: usize, plan: &mut Vec<BlockKey>) {
        plan.clear();
        let mut ranked = std::mem::take(&mut self.scratch.ranked);
        for &id in ids {
            if plan.len() >= cap {
                break;
            }
            let Some(r) = self.reqs.get_mut(&id) else { continue };
            r.ws.ranked_blocks_capped_into(cap - plan.len(), &mut ranked);
            for &(layer, head, block) in &ranked {
                plan.push(BlockKey::new(id, layer, head, block));
            }
        }
        self.scratch.ranked = ranked;
    }
}

/// Which kernel family a prefill session runs per layer.
enum PfMode {
    /// Whole prompt, no past: `prefill_layer_{T}` (layer-segmented path
    /// and plain prefill).
    WholePrompt,
    /// A chunk with accumulated past re-exported each layer:
    /// `prefill_chunk_{T}` (chunked baseline).
    ChunkPast,
}

/// Prefill activation carried across this session's layer phases.
struct PfState {
    mode: PfMode,
    x: Vec<f32>,
    t_pad: usize,
    /// Valid token rows in `x` (prompt length / chunk length).
    valid: usize,
    /// Past tokens preceding this chunk (`ChunkPast` position offset).
    start: usize,
    /// The activation was *moved* out of the request's saved stash
    /// (later layer-segment batch): rollback must hand the pre-step
    /// buffer back (move-based copy-on-write, no clone taken).
    from_stash: bool,
}

/// Per-compiled-bucket decode group state carried across layer phases.
struct DecGroup {
    ids: Vec<ReqId>,
    b_pad: usize,
    x: Vec<f32>,
    pos: Vec<i32>,
    ws_items: Vec<Vec<(u16, u16, u32)>>,
}

struct DecState {
    k_bucket: usize,
    budget: usize,
    groups: Vec<DecGroup>,
}

/// One in-flight real-backend batch (see [`StepSession`]).
struct PjrtSession<'s> {
    be: &'s mut PjrtBackend,
    batch: &'s Batch,
    requests: &'s HashMap<ReqId, Request>,
    t0: Instant,
    tokens: Vec<(ReqId, Option<i32>)>,
    /// Pre-step host-side snapshots: (id, last_token). The carried
    /// prefill activation is NOT cloned here — see `hidden_orig`.
    snap: Vec<(ReqId, i32)>,
    /// The pre-step stashed activation, recovered by move after the
    /// first prefill layer consumed it (rollback restore; dropped on
    /// commit).
    hidden_orig: Option<(ReqId, (Vec<f32>, usize, usize))>,
    /// Prefill layers successfully run by this session.
    pf_layers_run: usize,
    pf: Option<PfState>,
    dec: Option<DecState>,
    /// Phase-delta baselines into the KvManager's iteration stats.
    last_loaded: usize,
    last_load_bytes: usize,
    staged: bool,
}

impl<'s> PjrtSession<'s> {
    /// Per-phase (miss blocks, demand bytes) delta from the KV manager.
    fn load_delta(&mut self) -> (usize, usize) {
        let iter = self.be.kv.iter_so_far();
        let blocks = iter.blocks_loaded - self.last_loaded;
        let bytes = iter.load.bytes - self.last_load_bytes;
        self.last_loaded = iter.blocks_loaded;
        self.last_load_bytes = iter.load.bytes;
        (blocks, bytes)
    }

    // ------------------------------------------------------------- prefill

    /// First prefill phase: build the carried activation (embed, restore
    /// a stashed hidden state, or embed a chunk).
    fn pf_init(&mut self, layer: usize) -> Result<()> {
        if self.pf.is_some() {
            return Ok(());
        }
        let be = &mut *self.be;
        let Some(work) = self.batch.prefill.as_ref() else {
            return Err(anyhow!("prefill phase driven with no prefill planned"));
        };
        let req_id = work.req();
        let r = &self.requests[&req_id];
        let state = match work {
            PrefillWork::LayerSegment { tok_start, tok_len, .. } => {
                if *tok_start != 0 || *tok_len != r.prompt_len {
                    return Err(anyhow!(
                        "real backend supports whole-prompt layer segments only \
                         (hybrid within-layer chunking is simulator-only); \
                         set max_inject_tokens >= max prompt length"
                    ));
                }
                // single-layer HBM bound: the segment only keeps ONE
                // layer of KV live, but that one layer must fit (paper §3.4)
                let spec = be.spec();
                let seg_layer_bytes = r.prompt_len.div_ceil(spec.block_size)
                    * spec.n_kv_heads
                    * be.kv.block_bytes();
                if be.cfg.offload && seg_layer_bytes > be.kv.hbm_bytes_capacity() {
                    return Err(MemoryError::HbmExhausted { req: req_id }.into());
                }
                if layer == 0 {
                    let (x, t_pad) = be.embed_padded(&r.prompt, "prefill_t")?;
                    PfState {
                        mode: PfMode::WholePrompt,
                        x,
                        t_pad,
                        valid: r.prompt_len,
                        start: 0,
                        from_stash: false,
                    }
                } else {
                    // later segment batch: restore the stashed activation
                    // (paper Fig. 9: "activation states ... saved") —
                    // MOVED, not cloned; rollback hands it back
                    let (h, t_pad, tr) = be
                        .reqs
                        .get_mut(&req_id)
                        .and_then(|st| st.hidden.take())
                        .ok_or_else(|| anyhow!("missing saved activation for req {req_id}"))?;
                    PfState {
                        mode: PfMode::WholePrompt,
                        x: h,
                        t_pad,
                        valid: tr,
                        start: 0,
                        from_stash: true,
                    }
                }
            }
            PrefillWork::Chunk { start, len, .. } => {
                if *start == 0 && *len == r.prompt_len {
                    // plain prefill = whole prompt, no past
                    let (x, t_pad) = be.embed_padded(&r.prompt, "prefill_t")?;
                    PfState {
                        mode: PfMode::WholePrompt,
                        x,
                        t_pad,
                        valid: r.prompt_len,
                        start: 0,
                        from_stash: false,
                    }
                } else {
                    let p_max = be.rt.manifest.chunk_past;
                    if *start > p_max {
                        return Err(anyhow!("past {start} exceeds chunk_past bucket {p_max}"));
                    }
                    let (x, t_pad) =
                        be.embed_padded(&r.prompt[*start..*start + *len], "chunk_t")?;
                    PfState {
                        mode: PfMode::ChunkPast,
                        x,
                        t_pad,
                        valid: *len,
                        start: *start,
                        from_stash: false,
                    }
                }
            }
        };
        self.pf = Some(state);
        Ok(())
    }

    /// Run one prefill layer on the carried activation. The input
    /// tensor's storage is recovered after execution — on the first
    /// layer of a stash-restored segment it IS the pre-step activation
    /// and is kept aside for rollback.
    fn pf_layer(&mut self, layer: usize) -> Result<()> {
        let be = &mut *self.be;
        let Some(pf) = self.pf.as_mut() else {
            return Err(anyhow!("pf_layer driven before pf_init"));
        };
        let Some(work) = self.batch.prefill.as_ref() else {
            return Err(anyhow!("prefill phase driven with no prefill planned"));
        };
        let req_id = work.req();
        let spec = be.spec().clone();
        let d = spec.d_model;
        let t_pad = pf.t_pad;

        let mut seg_mask = vec![0.0f32; t_pad];
        seg_mask[pf.valid..].fill(NEG_INF);
        let seg_mask_t = HostTensor::f32(vec![t_pad], seg_mask);
        let x = std::mem::take(&mut pf.x);
        let xt = HostTensor::f32(vec![t_pad, d], x);

        let res = match pf.mode {
            PfMode::WholePrompt => {
                let pos0 = HostTensor::scalar_i32(0);
                let lw = be.rt.weights.layer(layer);
                let mut inputs: Vec<&HostTensor> = vec![&xt, &pos0, &seg_mask_t];
                inputs.extend(lw);
                be.rt.execute(&format!("prefill_layer_{t_pad}"), &inputs)
            }
            PfMode::ChunkPast => {
                let (hkv, dh) = (spec.n_kv_heads, spec.head_dim);
                let p_max = be.rt.manifest.chunk_past;
                // export this layer's accumulated past (exactly `start` tokens)
                let mut pk = vec![0.0f32; hkv * p_max * dh];
                let mut pv = vec![0.0f32; hkv * p_max * dh];
                let mut pm = vec![0.0f32; p_max];
                be.kv.export_past(req_id, layer, p_max, &mut pk, &mut pv, &mut pm);
                let pk_t = HostTensor::f32(vec![hkv, p_max, dh], pk);
                let pv_t = HostTensor::f32(vec![hkv, p_max, dh], pv);
                let pm_t = HostTensor::f32(vec![p_max], pm);
                let pos = HostTensor::scalar_i32(pf.start as i32);
                let lw = be.rt.weights.layer(layer);
                let mut inputs: Vec<&HostTensor> =
                    vec![&xt, &pos, &seg_mask_t, &pk_t, &pv_t, &pm_t];
                inputs.extend(lw);
                be.rt.execute(&format!("prefill_chunk_{t_pad}"), &inputs)
            }
        };
        // recover the input activation before any error can drop it
        let x_back = xt.into_f32();
        let mut outs = match res {
            Ok(outs) => outs,
            Err(e) => {
                pf.x = x_back;
                return Err(e);
            }
        };
        if pf.from_stash && self.pf_layers_run == 0 {
            // move-based copy-on-write: the pre-step stash is kept aside
            // for rollback instead of being cloned up front in begin_step
            self.hidden_orig = Some((req_id, (x_back, t_pad, pf.valid)));
        }
        // outs: (k [Hkv,T,Dh], v, x2 [T,d])
        be.kv.append_prefill_layer(
            req_id,
            layer,
            nth(&outs, 0)?.as_f32(),
            nth(&outs, 1)?.as_f32(),
            t_pad,
            pf.valid,
        )?;
        pf.x = outs.swap_remove(2).into_f32();
        Ok(())
    }

    /// Final prefill phase of this session's work item: first token
    /// (`is_last`) or stash the activation for the next layer batch.
    fn pf_finish(&mut self) -> Result<()> {
        let Some(work) = self.batch.prefill.as_ref() else {
            return Err(anyhow!("prefill phase driven with no prefill planned"));
        };
        let req_id = work.req();
        let Some(pf) = self.pf.take() else {
            return Err(anyhow!("pf_finish driven before pf_init"));
        };
        if work.is_last() {
            let tok = self.be.lm_head_rows(&[(&pf.x, pf.t_pad, pf.valid - 1)])?[0];
            let Some(st) = self.be.reqs.get_mut(&req_id) else {
                return Err(MemoryError::Unregistered { req: req_id }.into());
            };
            st.last_token = tok;
            st.hidden = None;
            self.tokens.push((req_id, Some(tok)));
        } else if matches!(pf.mode, PfMode::WholePrompt) {
            let Some(st) = self.be.reqs.get_mut(&req_id) else {
                return Err(MemoryError::Unregistered { req: req_id }.into());
            };
            st.hidden = Some((pf.x, pf.t_pad, pf.valid));
        }
        Ok(())
    }

    // -------------------------------------------------------------- decode

    /// First decode phase: split decodes into compiled batch buckets and
    /// embed every group's last tokens.
    fn dec_init(&mut self) -> Result<()> {
        if self.dec.is_some() {
            return Ok(());
        }
        let be = &mut *self.be;
        let d = be.spec().d_model;
        let k_bucket = be.budget_bucket()?;
        let budget = be.budget_needed().min(k_bucket);
        let max_b = be
            .rt
            .manifest
            .bucket("decode_b")
            .iter()
            .copied()
            .max()
            .unwrap_or(1);
        let mut groups = Vec::new();
        for ids in self.batch.decodes.chunks(max_b) {
            let b = ids.len();
            let b_pad = be
                .rt
                .manifest
                .fit_bucket("decode_b", b)
                .ok_or_else(|| anyhow!("no decode bucket >= {b}"))?;
            let mut toks = vec![0i32; b_pad];
            for (i, id) in ids.iter().enumerate() {
                toks[i] = be.reqs[id].last_token;
            }
            let tokens = HostTensor::i32(vec![b_pad], toks);
            let mut emb = be.rt.execute_mixed(
                &format!("embed_{b_pad}"),
                &[MixedInput::Tensor(&tokens), MixedInput::Weight("embedding")],
            )?;
            let x = emb.swap_remove(0).into_f32(); // [b_pad, d]
            debug_assert_eq!(x.len(), b_pad * d);
            // positions: current sequence length (same for every layer)
            let mut pos = vec![0i32; b_pad];
            for (i, id) in ids.iter().enumerate() {
                pos[i] = be.kv.seq_len(*id) as i32;
            }
            groups.push(DecGroup {
                ids: ids.to_vec(),
                b_pad,
                x,
                pos,
                ws_items: vec![Vec::new(); b],
            });
        }
        self.dec = Some(DecState { k_bucket, budget, groups });
        Ok(())
    }

    /// One decode layer for one group (projection+scoring -> save new
    /// token KV -> select+gather -> sparse attention+FFN). Every staging
    /// buffer comes from (and returns to) the backend's recycled
    /// [`GatherScratch`]; input tensor storage is reclaimed after each
    /// kernel.
    fn dec_group_layer(&mut self, gi: usize, layer: usize) -> Result<()> {
        let be = &mut *self.be;
        let Some(dec) = self.dec.as_mut() else {
            return Err(anyhow!("dec_group_layer driven before dec_init"));
        };
        let spec = be.spec().clone();
        let (d, _hq, hkv, dh, bs) =
            (spec.d_model, spec.n_heads, spec.n_kv_heads, spec.head_dim, spec.block_size);
        let nb = spec.max_blocks();
        let (k_bucket, budget) = (dec.k_bucket, dec.budget);
        let s_len = k_bucket * bs;
        let g = &mut dec.groups[gi];
        let b_pad = g.b_pad;

        // ---- metadata tensors (recycled) ----
        let mut lo = std::mem::take(&mut be.scratch.lo);
        let mut hi = std::mem::take(&mut be.scratch.hi);
        let mut mm = std::mem::take(&mut be.scratch.mm);
        lo.clear();
        lo.resize(b_pad * hkv * nb * dh, 0.0);
        hi.clear();
        hi.resize(b_pad * hkv * nb * dh, 0.0);
        mm.clear();
        mm.resize(b_pad * hkv * nb, NEG_INF);
        for (i, id) in g.ids.iter().enumerate() {
            let lo_s = &mut lo[i * hkv * nb * dh..(i + 1) * hkv * nb * dh];
            let hi_s = &mut hi[i * hkv * nb * dh..(i + 1) * hkv * nb * dh];
            let mm_s = &mut mm[i * hkv * nb..(i + 1) * hkv * nb];
            be.kv.metadata_into(*id, layer, nb, lo_s, hi_s, mm_s);
        }
        let xt = HostTensor::f32(vec![b_pad, d], std::mem::take(&mut g.x));
        let pos_t = HostTensor::i32(vec![b_pad], std::mem::take(&mut g.pos));
        let lo_t = HostTensor::f32(vec![b_pad, hkv, nb, dh], lo);
        let hi_t = HostTensor::f32(vec![b_pad, hkv, nb, dh], hi);
        let mm_t = HostTensor::f32(vec![b_pad, hkv, nb], mm);
        let inputs = [
            MixedInput::Tensor(&xt),
            MixedInput::Tensor(&pos_t),
            MixedInput::Tensor(&lo_t),
            MixedInput::Tensor(&hi_t),
            MixedInput::Tensor(&mm_t),
            MixedInput::Weight(be.wname(layer, 0)), // attn_norm
            MixedInput::Weight(be.wname(layer, 1)), // wq
            MixedInput::Weight(be.wname(layer, 2)), // wk
            MixedInput::Weight(be.wname(layer, 3)), // wv
        ];
        let res = be.rt.execute_mixed(&format!("decode_qkv_{b_pad}"), &inputs);
        // reclaim every input buffer (even on error — the session rolls
        // back but the scratch capacity survives)
        be.scratch.lo = lo_t.into_f32();
        be.scratch.hi = hi_t.into_f32();
        be.scratch.mm = mm_t.into_f32();
        g.x = xt.into_f32();
        g.pos = pos_t.into_i32();
        let outs = res?;
        // outs: q [B,Hq,Dh], k [B,Hkv,Dh], v [B,Hkv,Dh], scores [B,Hkv,NB]
        let q = nth(&outs, 0)?;
        let kk = nth(&outs, 1)?.as_f32();
        let vv = nth(&outs, 2)?.as_f32();
        let scores = nth(&outs, 3)?.as_f32();

        // ---- save new token KV ----
        for (i, id) in g.ids.iter().enumerate() {
            be.kv.append_decode_token(
                *id,
                layer,
                &kk[i * hkv * dh..(i + 1) * hkv * dh],
                &vv[i * hkv * dh..(i + 1) * hkv * dh],
            )?;
        }

        // ---- select + gather (recycled top-k + staging buffers) ----
        let mut gk = std::mem::take(&mut be.scratch.gk);
        let mut gv = std::mem::take(&mut be.scratch.gv);
        let mut gm = std::mem::take(&mut be.scratch.gm);
        gk.clear();
        gk.resize(b_pad * hkv * s_len * dh, 0.0);
        gv.clear();
        gv.resize(b_pad * hkv * s_len * dh, 0.0);
        gm.clear();
        gm.resize(b_pad * hkv * s_len, NEG_INF);
        let mut sel = std::mem::take(&mut be.scratch.sel);
        sel.resize_with(hkv, Vec::new);
        let mut gather_err = None;
        for (i, id) in g.ids.iter().enumerate() {
            let n_sealed = be.kv.n_sealed(*id, layer);
            for (h, out) in sel.iter_mut().enumerate() {
                let row = &scores[(i * hkv + h) * nb..(i * hkv + h + 1) * nb];
                top_k_blocks_fast_into(row, n_sealed, budget.saturating_sub(1), out);
            }
            for (h, sh) in sel.iter().enumerate() {
                for &blk in sh {
                    g.ws_items[i].push((layer as u16, h as u16, blk));
                }
                // the open block is part of the working set too
                if be.kv.open_fill(*id, layer) > 0 {
                    g.ws_items[i].push((layer as u16, h as u16, n_sealed as u32));
                }
            }
            let gk_s = &mut gk[i * hkv * s_len * dh..(i + 1) * hkv * s_len * dh];
            let gv_s = &mut gv[i * hkv * s_len * dh..(i + 1) * hkv * s_len * dh];
            let gm_s = &mut gm[i * hkv * s_len..(i + 1) * hkv * s_len];
            if let Err(e) = be.kv.gather_into(*id, layer, &sel, k_bucket, gk_s, gv_s, gm_s) {
                gather_err = Some(e);
                break;
            }
        }
        be.scratch.sel = sel;
        if let Some(e) = gather_err {
            // put the staging buffers back before failing: the typed
            // rollback+retry path must not churn the recycled scratch
            be.scratch.gk = gk;
            be.scratch.gv = gv;
            be.scratch.gm = gm;
            return Err(e.into());
        }

        // ---- sparse attention + FFN ----
        let xt = HostTensor::f32(vec![b_pad, d], std::mem::take(&mut g.x));
        let gk_t = HostTensor::f32(vec![b_pad, hkv, s_len, dh], gk);
        let gv_t = HostTensor::f32(vec![b_pad, hkv, s_len, dh], gv);
        let gm_t = HostTensor::f32(vec![b_pad, hkv, s_len], gm);
        let inputs = [
            MixedInput::Tensor(&xt),
            MixedInput::Tensor(q), // straight from decode_qkv
            MixedInput::Tensor(&gk_t),
            MixedInput::Tensor(&gv_t),
            MixedInput::Tensor(&gm_t),
            MixedInput::Weight(be.wname(layer, 4)), // wo
            MixedInput::Weight(be.wname(layer, 5)), // ffn_norm
            MixedInput::Weight(be.wname(layer, 6)), // w_gate
            MixedInput::Weight(be.wname(layer, 7)), // w_up
            MixedInput::Weight(be.wname(layer, 8)), // w_down
        ];
        let res = be
            .rt
            .execute_mixed(&format!("decode_attend_{b_pad}_{k_bucket}"), &inputs);
        be.scratch.gk = gk_t.into_f32();
        be.scratch.gv = gv_t.into_f32();
        be.scratch.gm = gm_t.into_f32();
        let mut aouts = res?;
        g.x = aouts.swap_remove(0).into_f32();
        Ok(())
    }

    /// Commit-time finalization: decode lm_head + token emission +
    /// working-set recording, then the iteration's transfer accounting.
    fn finalize(&mut self) -> Result<BatchOutcome> {
        let mut out = BatchOutcome::default();
        if let Some(dec) = self.dec.take() {
            for mut g in dec.groups {
                let next = {
                    let be = &*self.be;
                    let d = be.spec().d_model;
                    let xt = HostTensor::f32(vec![g.b_pad, d], std::mem::take(&mut g.x));
                    let outs = be.rt.execute_mixed(
                        &format!("lm_head_{}", g.b_pad),
                        &[
                            MixedInput::Tensor(&xt),
                            MixedInput::Weight("final_norm"),
                            MixedInput::Weight("lm_head"),
                        ],
                    )?;
                    nth(&outs, 0)?.as_i32().to_vec()
                };
                for (i, id) in g.ids.iter().enumerate() {
                    let items = std::mem::take(&mut g.ws_items[i]);
                    if self.be.record_selections {
                        self.be.selection_log.push(items.clone());
                    }
                    let Some(st) = self.be.reqs.get_mut(id) else {
                        debug_assert!(false, "decoded id {id} has no request record");
                        continue;
                    };
                    st.last_token = next[i];
                    st.ws.record_step(items);
                    self.tokens.push((*id, Some(next[i])));
                }
            }
        }
        out.tokens = std::mem::take(&mut self.tokens);

        let iter = self.be.kv.end_iteration();
        out.blocks_loaded = iter.blocks_loaded + iter.prefetch_blocks;
        out.load_time_s = iter.load.modeled_s + iter.prefetch.modeled_s;
        out.save_time_s = iter.save.modeled_s;
        // demand loads are the PCIe time the gathers had to wait on; the
        // staged (prefetch) stream overlapped compute. The real backend
        // measures wall time, so the coarse/per-layer distinction is a
        // simulator concern — both report the demand-modeled stall here.
        out.stall_time_s = iter.load.modeled_s;
        out.coarse_stall_time_s = iter.load.modeled_s;
        out.hidden_time_s = iter.prefetch.modeled_s;
        out.prefetch_blocks = iter.prefetch_blocks;
        out.prefetch_hits = iter.prefetch_hits;
        out.prefetch_wasted = iter.prefetch_wasted;
        out.prefetch_deferred = iter.prefetch_deferred;
        out.iter_time_s = self.t0.elapsed().as_secs_f64();
        // rolled-back attempts of this iteration are charged on top of
        // the committed wall time by the engine
        out.abort_time_s = std::mem::take(&mut self.be.aborted_time_s);
        Ok(out)
    }

    /// Restore host-side snapshots (tokens + moved-out activation) and
    /// undo the KV transaction. The aborted wall time is charged to the
    /// serving clock via the next commit / `abort_iteration`.
    fn undo(&mut self) {
        self.be.aborted_time_s += self.t0.elapsed().as_secs_f64();
        for (id, last_token) in self.snap.drain(..) {
            if let Some(st) = self.be.reqs.get_mut(&id) {
                st.last_token = last_token;
                // stashes recorded THIS step are undone; the pre-step
                // stash (if one was moved out) is restored below
                st.hidden = None;
            }
        }
        let mut restore = self.hidden_orig.take();
        if restore.is_none() && self.pf_layers_run == 0 {
            // the stash was moved into the session but no layer consumed
            // it yet: the session state still IS the pre-step activation
            if let (Some(pf), Some(work)) = (self.pf.take(), self.batch.prefill.as_ref()) {
                if pf.from_stash {
                    restore = Some((work.req(), (pf.x, pf.t_pad, pf.valid)));
                }
            }
        }
        if let Some((id, hidden)) = restore {
            if let Some(st) = self.be.reqs.get_mut(&id) {
                st.hidden = Some(hidden);
            }
        }
        self.be.kv.rollback_txn();
    }
}

impl StepSession for PjrtSession<'_> {
    /// Stage the batch decodes' predicted working sets — ranked
    /// `(layer, head, block)` unions (recency order, frequency-blended
    /// when configured) — as asynchronous FlashH2D copies, FCFS; then
    /// the next-batch hints with leftover budget, deferred.
    fn stage(&mut self, hints: &StageHints) -> usize {
        debug_assert!(!self.staged, "stage() called twice");
        self.staged = true;
        let be = &mut *self.be;
        if !(be.cfg.prefetch && be.cfg.offload && be.cfg.sparse_attention) {
            return 0;
        }
        let cap = be.cfg.max_prefetch_blocks;
        // keep one gather's worst-case pins (every head at full budget)
        // worth of slots free for demand misses — clamped so a small HBM
        // cache (where that exceeds capacity) can still stage half of it
        let headroom = (be.spec().n_kv_heads * be.budget_needed())
            .min(be.kv.cache_capacity_slots() / 2);
        // over-collect by 2x: already-resident plan entries are skipped
        // by staging without consuming its budget
        let mut plan = std::mem::take(&mut be.scratch.plan);
        be.staging_plan_into(&self.batch.decodes, cap.saturating_mul(2), &mut plan);
        let mut staged = be.kv.prefetch_working_set(&plan, cap, headroom, false);
        let rem = cap.saturating_sub(staged);
        if rem > 0 && !hints.next_decodes.is_empty() {
            be.staging_plan_into(&hints.next_decodes, rem.saturating_mul(2), &mut plan);
            staged += be.kv.prefetch_working_set(&plan, rem, headroom, true);
        }
        be.scratch.plan = plan;
        staged
    }

    fn prefill_segment(&mut self, layer_start: usize, layer_end: usize) -> Result<PhaseEvent> {
        debug_assert_eq!(layer_end, layer_start + 1, "engine drives one layer per segment");
        let t0 = Instant::now();
        let Some(work) = self.batch.prefill.as_ref() else {
            return Err(anyhow!("prefill phase driven with no prefill planned"));
        };
        let (_, last_layer) =
            super::backend::prefill_layer_range(work, self.be.spec().n_layers);
        self.pf_init(layer_start)?;
        self.pf_layer(layer_start)?;
        self.pf_layers_run += 1;
        if layer_start + 1 == last_layer {
            self.pf_finish()?;
        }
        let (miss_blocks, bytes_moved) = self.load_delta();
        Ok(PhaseEvent {
            layer_start,
            layer_end,
            compute_s: t0.elapsed().as_secs_f64(),
            miss_blocks,
            bytes_moved,
        })
    }

    fn decode_layer(&mut self, layer: usize) -> Result<PhaseEvent> {
        let t0 = Instant::now();
        if layer == 0 {
            self.dec_init()?;
        }
        let n_groups = self.dec.as_ref().map(|d| d.groups.len()).unwrap_or(0);
        for gi in 0..n_groups {
            self.dec_group_layer(gi, layer)?;
        }
        let (miss_blocks, bytes_moved) = self.load_delta();
        Ok(PhaseEvent {
            layer_start: layer,
            layer_end: layer + 1,
            compute_s: t0.elapsed().as_secs_f64(),
            miss_blocks,
            bytes_moved,
        })
    }

    fn commit(mut self: Box<Self>) -> Result<BatchOutcome> {
        match self.finalize() {
            Ok(out) => {
                self.be.kv.commit_txn();
                Ok(out)
            }
            Err(e) => {
                // a failed finalization (lm_head execution error) is
                // fatal to the step: leave the KV state rolled back
                self.undo();
                Err(e)
            }
        }
    }

    fn rollback(mut self: Box<Self>) {
        self.undo();
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn n_layers(&self) -> usize {
        self.spec().n_layers
    }

    fn register(&mut self, req: &Request) -> Result<()> {
        self.kv.register(req.id);
        self.reqs.insert(
            req.id,
            RealReq {
                last_token: 0,
                hidden: None,
                ws: WorkingSetTracker::new(self.cfg.ws_window)
                    .with_freq_ranking(self.cfg.prefetch_freq_ranking),
            },
        );
        Ok(())
    }

    fn release(&mut self, req: ReqId) {
        self.kv.release(req);
        self.reqs.remove(&req);
    }

    fn abort_iteration(&mut self) -> f64 {
        // discard the aborted attempts' transfer stats and retire their
        // stages — including deferred ones, which the first
        // end_iteration only promotes — so the next committed step's
        // outcome starts clean
        let _ = self.kv.end_iteration();
        let _ = self.kv.end_iteration();
        // the burnt wall time is handed to the engine (the serving clock
        // still advances even though nothing committed)
        std::mem::take(&mut self.aborted_time_s)
    }

    fn mem_stats(&self) -> MemStats {
        MemStats {
            hbm_bytes_used: self.kv.hbm_bytes_used(),
            // without offloading the DRAM pool *models* HBM storage and
            // is already counted above — don't double-report it
            dram_bytes_used: if self.kv.offload() { self.kv.dram_bytes_used() } else { 0 },
            n_registered: self.reqs.len(),
        }
    }

    fn decode_ws_bytes(&mut self, req: ReqId) -> usize {
        let bb = self.kv.block_bytes();
        let spec = self.kv.spec();
        let init = {
            let budget = if self.cfg.sparse_attention {
                self.cfg.budget_blocks(spec.block_size)
            } else {
                spec.max_blocks()
            };
            budget.min(self.kv.n_blocks(req).max(1))
                * spec.n_kv_heads
                * spec.n_layers
                * bb
        };
        let r = match self.reqs.get_mut(&req) {
            Some(r) => r,
            None => return init,
        };
        if r.ws.steps_recorded() == 0 {
            init
        } else {
            r.ws.ws_bytes(bb)
        }
    }

    fn begin_step<'s>(
        &'s mut self,
        batch: &'s Batch,
        requests: &'s HashMap<ReqId, Request>,
    ) -> Result<Box<dyn StepSession + 's>> {
        // Rotate the double-buffered scratch slots (see `scratch_spare`):
        // the previous session's buffers are left untouched for one more
        // iteration while this session reuses the other slot's capacity.
        std::mem::swap(&mut self.scratch, &mut self.scratch_spare);

        // Pre-flight: a decode step allocates DRAM blocks only for
        // requests sitting on a block boundary. Fail typed BEFORE any
        // side effect so an eviction never costs the surviving
        // batch-mates anything (the retry path handles mid-step failures
        // the pre-flight cannot see, e.g. mid-gather HbmExhausted).
        let mut needed = 0usize;
        let mut boundary_req = None;
        for &id in &batch.decodes {
            let n = self.kv.decode_slots_needed(id);
            if n > 0 && boundary_req.is_none() {
                boundary_req = Some(id);
            }
            needed += n;
        }
        if needed > self.kv.dram_free_slots() {
            // needed > 0 here, so at least one decode sat on a boundary
            let Some(req) = boundary_req else {
                return Err(anyhow!("DRAM pre-flight overflow with no boundary request"));
            };
            return Err(MemoryError::DramExhausted { req }.into());
        }

        // Host-side snapshots of every participant: last tokens only.
        // The carried prefill activation is NOT cloned (the old hybrid-
        // batch multi-megabyte copy): it is moved out by the session on
        // first use and moved back on rollback (copy-on-write by move).
        let mut snap = Vec::new();
        let mut participants: Vec<ReqId> = batch.decodes.clone();
        if let Some(w) = &batch.prefill {
            participants.push(w.req());
        }
        for id in participants {
            if let Some(st) = self.reqs.get(&id) {
                snap.push((id, st.last_token));
            }
        }

        self.kv.begin_txn();
        let last_loaded = self.kv.iter_so_far().blocks_loaded;
        let last_load_bytes = self.kv.iter_so_far().load.bytes;
        Ok(Box::new(PjrtSession {
            be: self,
            batch,
            requests,
            t0: Instant::now(),
            tokens: Vec::new(),
            snap,
            hidden_orig: None,
            pf_layers_run: 0,
            pf: None,
            dec: None,
            last_loaded,
            last_load_bytes,
            staged: false,
        }))
    }
}
