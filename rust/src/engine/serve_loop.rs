//! Offline trace replay: a clock-driven driver over [`EngineCore`].
//!
//! The serving clock advances by each batch's iteration time (modeled or
//! measured) and requests arrive according to their trace timestamps.
//! All batch/emit/release logic lives in [`EngineCore::step`] — this
//! file only owns the virtual clock and arrival delivery.

use anyhow::Result;

use crate::scheduler::{Request, Scheduler};

use super::backend::Backend;
use super::core::{EngineCore, RunReport};

pub struct Engine {
    pub core: EngineCore,
    pub clock_s: f64,
}

impl Engine {
    pub fn new(sched: Scheduler, backend: Box<dyn Backend>) -> Self {
        Self { core: EngineCore::new(sched, backend), clock_s: 0.0 }
    }

    /// Serve a whole trace to completion (or until `max_clock_s`).
    pub fn run_trace(mut self, mut trace: Vec<Request>, max_clock_s: f64) -> Result<RunReport> {
        trace.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let mut next_arrival = 0usize;

        loop {
            // deliver due arrivals
            while next_arrival < trace.len() && trace[next_arrival].arrival_s <= self.clock_s {
                self.core
                    .submit_request(trace[next_arrival].clone())
                    .map_err(anyhow::Error::new)?;
                next_arrival += 1;
            }
            if !self.core.has_work() {
                if next_arrival >= trace.len() {
                    break; // done
                }
                // idle: jump to the next arrival
                self.clock_s = trace[next_arrival].arrival_s;
                continue;
            }

            let outcome = self.core.step(self.clock_s).map_err(anyhow::Error::new)?;
            // the clock advances even for abandoned iterations: aborted
            // (rolled-back) attempts burn real time (iter_time_s is 0 on
            // a plain idle/blocked step)
            self.clock_s += outcome.iter_time_s;
            if !outcome.ran_batch {
                // typed rejections/evictions ARE progress: requests left
                // the system, re-plan immediately
                if !outcome.rejected.is_empty() || !outcome.evicted.is_empty() {
                    continue;
                }
                // admission blocked and nothing running: wait for the next
                // event (arrival won't help if HBM is the blocker, but a
                // running request must exist whenever something is blocked;
                // guard against livelock by stepping to the next arrival)
                if next_arrival < trace.len() {
                    self.clock_s = self.clock_s.max(trace[next_arrival].arrival_s);
                    next_arrival_guard(&mut self.clock_s);
                    continue;
                }
                anyhow::bail!("scheduler deadlock: work pending but empty batch");
            }

            if self.clock_s > max_clock_s {
                break;
            }
        }

        Ok(self.core.into_report(self.clock_s))
    }
}

fn next_arrival_guard(clock: &mut f64) {
    // nudge the clock so a blocked state with a just-delivered arrival
    // cannot spin at the same timestamp
    *clock += 1e-6;
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::{HardwareSpec, ModelSpec, ServingConfig};
    use crate::engine::SimBackend;
    use crate::workload::{generate, WorkloadSpec};

    fn run(cfg: ServingConfig, rate: f64, n: usize) -> RunReport {
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
        let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
        let engine = Engine::new(sched, Box::new(backend));
        let trace = generate(&WorkloadSpec::paper_lwm(rate, 7), n, 0);
        engine.run_trace(trace, 1e7).unwrap()
    }

    #[test]
    fn sparseserve_completes_trace() {
        let rep = run(ServingConfig::sparseserve(2048, 2048, 32), 0.05, 10);
        assert_eq!(rep.metrics.requests_finished, 10);
        assert!(rep.metrics.throughput() > 0.0);
        assert!(rep.metrics.ttft.len() == 10);
    }

    #[test]
    fn vllm_completes_trace() {
        let rep = run(ServingConfig::vllm(2048), 0.02, 6);
        assert_eq!(rep.metrics.requests_finished, 6);
    }

    #[test]
    fn higher_rate_worsens_vllm_ttft() {
        let slow = run(ServingConfig::vllm(2048), 0.02, 12);
        let fast = run(ServingConfig::vllm(2048), 0.2, 12);
        assert!(
            fast.metrics.ttft.mean() > slow.metrics.ttft.mean(),
            "queueing must grow with rate: {} vs {}",
            fast.metrics.ttft.mean(),
            slow.metrics.ttft.mean()
        );
    }

    /// Tentpole equivalence: the pipelined executor changes WHEN the
    /// plan/stage share is charged, never WHAT executes. With every
    /// arrival at t=0 the batch sequence depends only on iteration
    /// count, so depth 1 and depth 2 must produce identical tokens,
    /// finished sets and KV byte-state step by step — while depth 2
    /// finishes no later on the serving clock.
    #[test]
    fn pipeline_depth_changes_timing_not_behavior() {
        let mk = |depth: usize| {
            let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
            cfg.pipeline_depth = depth;
            let spec = ModelSpec::lwm_7b();
            let hw = HardwareSpec::a100_40gb();
            let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
            let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
            let mut core = EngineCore::new(sched, Box::new(backend));
            for _ in 0..3 {
                core.submit(
                    crate::engine::SubmitRequest::synthetic(12_000).max_new(24),
                    0.0,
                )
                .unwrap();
            }
            core
        };
        let (mut c1, mut c2) = (mk(1), mk(2));
        let (mut t1, mut t2) = (0.0_f64, 0.0_f64);
        for _ in 0..500 {
            if !c1.has_work() {
                break;
            }
            let o1 = c1.step(t1).unwrap();
            let o2 = c2.step(t2).unwrap();
            t1 += o1.iter_time_s;
            t2 += o2.iter_time_s;
            // identical execution...
            let e1: Vec<_> = o1.emitted.iter().map(|e| (e.req, e.token, e.index)).collect();
            let e2: Vec<_> = o2.emitted.iter().map(|e| (e.req, e.token, e.index)).collect();
            assert_eq!(e1, e2, "pipelining must not change emitted tokens");
            let f1: Vec<_> = o1.finished.iter().map(|(id, _)| *id).collect();
            let f2: Vec<_> = o2.finished.iter().map(|(id, _)| *id).collect();
            assert_eq!(f1, f2, "pipelining must not change the finished set");
            let (m1, m2) = (c1.mem_stats(), c2.mem_stats());
            assert_eq!(m1.hbm_bytes_used, m2.hbm_bytes_used, "identical HBM byte-state");
            assert_eq!(m1.dram_bytes_used, m2.dram_bytes_used, "identical DRAM byte-state");
            // ...on a never-slower serving clock
            assert!(t2 <= t1 + 1e-12, "depth 2 must not be slower: {t2} vs {t1}");
        }
        assert!(!c1.has_work() && !c2.has_work(), "both engines drained");
        assert_eq!(c1.metrics().tokens_generated, c2.metrics().tokens_generated);
        assert!(
            c2.metrics().plan_stage_hidden_s > 0.0,
            "steady decode must hide plan/stage time"
        );
        assert!(t2 < t1, "hidden plan/stage time must shorten the makespan");
    }

    #[test]
    fn sparseserve_beats_vllm_at_high_rate() {
        let v = run(ServingConfig::vllm(2048), 0.15, 16);
        let s = run(ServingConfig::sparseserve(2048, 2048, 32), 0.15, 16);
        assert!(
            s.metrics.ttft.mean() < v.metrics.ttft.mean(),
            "sparseserve TTFT {} must beat vllm {}",
            s.metrics.ttft.mean(),
            v.metrics.ttft.mean()
        );
        assert!(s.metrics.throughput() >= v.metrics.throughput() * 0.9);
    }
}
