//! `EngineCore`: the single per-iteration serving engine.
//!
//! Owns the scheduler + backend pair and the one true
//! plan → session phases → commit/rollback → advance_prefill → emit →
//! release sequence. Both serving front-ends are thin drivers over it:
//!
//! - [`crate::engine::Engine::run_trace`] advances a virtual clock by
//!   each step's iteration time (offline trace replay);
//! - [`crate::coordinator::Server`] calls [`EngineCore::step`] on a
//!   wall-clock loop and fans token events out to client streams.
//!
//! The request lifecycle is explicit: [`SubmitRequest`] carries
//! per-request parameters (max tokens, stop tokens, priority class,
//! TTFT SLO, sparse-budget override), [`EngineCore::cancel`] frees KV
//! state mid-flight, and failures surface as typed
//! [`ServeError`](super::ServeError)s.

use crate::memory::{MemoryError, ReqId};
use crate::metrics::RunMetrics;
use crate::scheduler::{
    Batch, Phase, Priority, Request, RequestParams, RequestTiming, Scheduler,
};

use super::backend::{
    drive_step, drive_step_pipelined, Backend, MemStats, MigrationPayload, StageHints,
};
use super::error::ServeError;

/// A request as submitted by a client: prompt + lifecycle parameters.
/// Built with a fluent builder:
///
/// ```ignore
/// let sub = SubmitRequest::new(prompt_tokens)
///     .max_new(64)
///     .stop_tokens(vec![EOS])
///     .priority(Priority::Interactive)
///     .ttft_slo(0.5)
///     .sparse_budget(1024);
/// ```
#[derive(Debug, Clone)]
pub struct SubmitRequest {
    prompt: Vec<i32>,
    prompt_len: usize,
    params: RequestParams,
}

impl SubmitRequest {
    /// A request with real prompt tokens (the PJRT path).
    pub fn new(prompt: Vec<i32>) -> Self {
        let prompt_len = prompt.len();
        Self { prompt, prompt_len, params: RequestParams::default() }
    }

    /// A length-only request (the simulator path — no token ids).
    pub fn synthetic(prompt_len: usize) -> Self {
        Self { prompt: Vec::new(), prompt_len, params: RequestParams::default() }
    }

    pub fn max_new(mut self, n: usize) -> Self {
        self.params.max_new_tokens = n;
        self
    }

    pub fn stop_tokens(mut self, toks: Vec<i32>) -> Self {
        self.params.stop_tokens = toks;
        self
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.params.priority = p;
        self
    }

    /// Shorthand for `.priority(Priority::Interactive)`.
    pub fn interactive(self) -> Self {
        self.priority(Priority::Interactive)
    }

    pub fn ttft_slo(mut self, seconds: f64) -> Self {
        self.params.ttft_slo_s = Some(seconds);
        self
    }

    /// Per-request DSA token-budget override (see
    /// [`RequestParams::sparse_budget`]).
    pub fn sparse_budget(mut self, tokens: usize) -> Self {
        self.params.sparse_budget = Some(tokens);
        self
    }

    /// Replace the whole parameter bundle at once.
    pub fn params(mut self, p: RequestParams) -> Self {
        self.params = p;
        self
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// Materialize the scheduler-side request (id + arrival stamped by
    /// the engine).
    pub fn into_request(self, id: ReqId, arrival_s: f64) -> Request {
        Request::with_params(id, self.prompt, self.prompt_len, self.params, arrival_s)
    }
}

/// One token produced by a step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TokenEvent {
    pub req: ReqId,
    /// `None` under the simulator backend (it tracks counts only).
    pub token: Option<i32>,
    /// Index of this token within the request's *emitted* token stream
    /// (0 for the first token; counts real tokens only when `token` is
    /// `Some`, decode steps otherwise).
    pub index: usize,
}

/// A victim drained for cross-engine migration instead of destroyed:
/// the scheduler-side request record (reservation already released at
/// the source), the DRAM bytes a target must re-reserve, and the
/// backend payload whose RNG/working-set state replays byte-identically
/// after [`EngineCore::admit_migration`]. Produced only in
/// [`EngineCore::capture_migrations`] mode; a candidate no engine can
/// take is finalized as a true eviction via
/// [`EngineCore::finalize_eviction`].
#[derive(Debug, Clone)]
pub struct MigrationCandidate {
    pub request: Request,
    /// Scheduler admission reservation the target must re-take (the
    /// source released exactly this many bytes at drain time).
    pub reserve_bytes: usize,
    pub payload: MigrationPayload,
    /// The typed memory-exhaustion message that made this request the
    /// victim.
    pub reason: String,
}

/// Result of one `EngineCore::step` call.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Iteration latency on the serving clock (0 when no batch ran).
    pub iter_time_s: f64,
    /// Whether a batch was actually executed. `false` means the engine
    /// is idle or blocked on admission — the driver decides whether to
    /// advance the clock, sleep, or bail.
    pub ran_batch: bool,
    /// Requests in the executed batch (decodes + prefill).
    pub batch_requests: usize,
    /// Token events emitted this step.
    pub emitted: Vec<TokenEvent>,
    /// Requests that finished this step, with their timing summary.
    /// Their KV state has already been released.
    pub finished: Vec<(ReqId, RequestTiming)>,
    /// Requests rejected this step because their memory demand can never
    /// fit (hopeless head-of-queue); oversubscription surfaces here as a
    /// typed error instead of blocking the queue forever.
    pub rejected: Vec<(ReqId, ServeError)>,
    /// Requests evicted this step because a memory tier ran out while
    /// executing them (typed `MemoryError` from the backend); their KV
    /// state has been released and the engine stays usable.
    pub evicted: Vec<(ReqId, ServeError)>,
    /// Victims drained for migration instead of evicted (capture mode
    /// only, see [`EngineCore::capture_migrations`]): the caller owns
    /// re-admission at a target or eviction finalization at the source.
    pub migratable: Vec<MigrationCandidate>,
}

/// Outcome of a whole serving run (offline trace replay or an online
/// session drained at shutdown).
pub struct RunReport {
    pub metrics: RunMetrics,
    /// Request records with timing fields filled. With the default
    /// `retain_finished(true)` this is every request the engine saw
    /// (finished, cancelled and in-flight); with pruning enabled (the
    /// online `Server` path) completed records were dropped as they
    /// finished and only in-flight requests remain.
    pub requests: std::collections::HashMap<ReqId, Request>,
    pub iterations: u64,
}

/// The serving engine: one scheduler + one backend, driven step by step.
pub struct EngineCore {
    sched: Scheduler,
    backend: Box<dyn Backend>,
    metrics: RunMetrics,
    /// Admission-queue capacity; `None` = unbounded.
    queue_cap: Option<usize>,
    /// Keep finished/cancelled request records (prompts, token ids,
    /// timing series) until [`Self::into_report`]. Offline replay wants
    /// them for the report; a long-running online server must prune
    /// them or host memory grows without bound.
    retain_finished: bool,
    /// Recycled planner outputs: `Scheduler::plan_into` /
    /// `stage_hints_into` refill these every iteration instead of
    /// materializing fresh vectors (zero-clone step pipeline).
    batch: Batch,
    hints: StageHints,
    /// Double-buffered speculation slots of the pipelined executor
    /// (`ServingConfig::pipeline_depth >= 2`): iteration N+1's decode
    /// packing and staging hints, computed under iteration N's compute
    /// via the read-only `Scheduler::preview_decodes_into`. Stamped with
    /// the scheduler's plan version at speculation time; consumed at the
    /// next step only if nothing staled them (version unchanged AND the
    /// real plan's decode list matches). The real `plan_into` ALWAYS
    /// runs, so executed batches are identical at every depth — the
    /// speculation only decides whether the plan/stage share was already
    /// hidden under the predecessor's compute.
    spec_batch: Batch,
    spec_hints: Vec<ReqId>,
    spec_valid: bool,
    spec_version: u64,
    /// Drain memory-exhaustion victims into
    /// [`StepOutcome::migratable`] instead of evicting them (cluster
    /// serving; single-engine drivers leave this off and keep the PR 2
    /// evict-victim-then-retry semantics).
    capture_migrations: bool,
    next_id: ReqId,
}

impl EngineCore {
    pub fn new(mut sched: Scheduler, backend: Box<dyn Backend>) -> Self {
        // A backend without an adoption path cannot seed matched-span KV
        // from the shared prefix pool; skipping that span's prefill would
        // leave it unwritten. Serve correct-but-unshared instead.
        if sched.cfg.prefix_sharing && !backend.supports_prefix_sharing() {
            sched.disable_prefix_sharing();
        }
        Self {
            sched,
            backend,
            metrics: RunMetrics::new(),
            queue_cap: None,
            retain_finished: true,
            batch: Batch::default(),
            hints: StageHints::default(),
            spec_batch: Batch::default(),
            spec_hints: Vec::new(),
            spec_valid: false,
            spec_version: 0,
            capture_migrations: false,
            next_id: 1,
        }
    }

    /// Enable migration capture: a typed memory-exhaustion victim is
    /// drained ([`Backend::export_migration`] +
    /// [`Scheduler::extract_for_migration`]) into
    /// [`StepOutcome::migratable`] instead of destroyed. Falls back to
    /// plain eviction per victim when either side cannot drain it.
    pub fn capture_migrations(mut self, on: bool) -> Self {
        self.capture_migrations = on;
        self
    }

    /// Bound the admission queue: submissions beyond `cap` waiting
    /// requests fail with [`ServeError::QueueFull`] (backpressure).
    pub fn with_queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = Some(cap);
        self
    }

    /// Drop finished/cancelled request records as soon as their metrics
    /// are folded in (long-running online service; `into_report` then
    /// only returns still-in-flight requests).
    pub fn retain_finished(mut self, keep: bool) -> Self {
        self.retain_finished = keep;
        self
    }

    /// Submit with an engine-assigned id. Returns the id on success.
    pub fn submit(&mut self, sub: SubmitRequest, now: f64) -> Result<ReqId, ServeError> {
        let id = self.next_id;
        self.submit_with_id(id, sub, now)?;
        Ok(id)
    }

    /// Submit under a caller-chosen id (the coordinator hands ids out
    /// before the engine thread sees the request).
    pub fn submit_with_id(
        &mut self,
        id: ReqId,
        sub: SubmitRequest,
        now: f64,
    ) -> Result<(), ServeError> {
        self.submit_request(sub.into_request(id, now))
    }

    /// Lowest-level submit: a fully-formed request (trace replay keeps
    /// its pre-assigned ids and arrival stamps).
    pub fn submit_request(&mut self, req: Request) -> Result<(), ServeError> {
        if let Some(cap) = self.queue_cap {
            if self.sched.n_queued() >= cap {
                return Err(ServeError::QueueFull { cap });
            }
        }
        if self.sched.requests.contains_key(&req.id) {
            return Err(ServeError::rejected(format!("duplicate request id {}", req.id)));
        }
        // a per-request registration failure rejects that request only —
        // the engine itself stays usable (BackendFailed is reserved for
        // batch-execution failures)
        self.backend
            .register(&req)
            .map_err(|e| ServeError::rejected(format!("backend registration failed: {e:#}")))?;
        self.next_id = self.next_id.max(req.id + 1);
        self.sched.submit(req);
        Ok(())
    }

    /// Cancel a request: drop it from the scheduler and free its KV
    /// state. Returns false when there is nothing to cancel (unknown id
    /// or already finished/cancelled).
    pub fn cancel(&mut self, id: ReqId) -> bool {
        if !self.sched.cancel(id) {
            return false;
        }
        self.backend.release(id);
        self.metrics.record_request(&self.sched.requests[&id]);
        if !self.retain_finished {
            self.sched.requests.remove(&id);
        }
        true
    }

    /// Drop a request the scheduler can never run (admission failure:
    /// its memory demand exceeds capacity). Same state transition as
    /// [`Self::cancel`] but accounted as a rejection, not a client
    /// cancellation.
    pub fn reject(&mut self, id: ReqId) -> bool {
        if !self.sched.cancel(id) {
            return false;
        }
        self.backend.release(id);
        self.metrics.requests_rejected += 1;
        if !self.retain_finished {
            self.sched.requests.remove(&id);
        }
        true
    }

    pub fn has_work(&self) -> bool {
        self.sched.has_work()
    }

    pub fn n_queued(&self) -> usize {
        self.sched.n_queued()
    }

    pub fn n_active(&self) -> usize {
        self.sched.n_active()
    }

    /// Scheduler view (read-only introspection).
    pub fn sched(&self) -> &Scheduler {
        &self.sched
    }

    /// Backend KV-memory occupancy.
    pub fn mem_stats(&self) -> MemStats {
        self.backend.mem_stats()
    }

    /// Metrics accumulated so far (makespan is only set by
    /// [`Self::into_report`]).
    pub fn metrics(&self) -> &RunMetrics {
        &self.metrics
    }

    /// Execute one iteration at serving-clock time `now`: plan a hybrid
    /// batch, drive it as a backend [`super::StepSession`] (stage →
    /// per-layer phases → commit), advance prefill progress, emit tokens
    /// (stamped at `now + iter_time_s`) and release finished requests.
    ///
    /// A typed mid-batch memory exhaustion rolls the session back, evicts
    /// the victim and *retries the surviving batch-mates in the same
    /// iteration* — their KV state is byte-identical to pre-step after
    /// the rollback, so nobody else loses their step. This path runs for
    /// real on BOTH backends: the simulator's decode is mid-phase
    /// fallible (a layer band whose batch-wide working set cannot fit
    /// HBM faults typed partway through the decode), so pure-sim
    /// eviction workloads exercise rollback, retry and abort-time
    /// charging (`RunMetrics::abort_time_total_s` is nonzero under HBM
    /// oversubscription).
    ///
    /// Never blocks. When the scheduler is idle or admission-blocked the
    /// returned outcome has `ran_batch == false` and the driver chooses
    /// the policy (jump the virtual clock / sleep / bail on deadlock).
    pub fn step(&mut self, now: f64) -> Result<StepOutcome, ServeError> {
        let mut out = StepOutcome::default();
        if !self.sched.has_work() {
            return Ok(out);
        }

        // A head-of-queue request whose KV can never fit its tier (HBM
        // without offloading, DRAM with it) would block admission
        // forever — and, unchecked, eventually exhaust DRAM mid-run.
        // Reject it with a typed error and keep serving.
        while let Some(id) = self.sched.hopeless_head() {
            let reason = format!(
                "request {id}: KV demand exceeds {} capacity",
                if self.sched.cfg.offload { "DRAM" } else { "HBM" }
            );
            self.reject(id);
            out.rejected.push((id, ServeError::rejected(reason)));
        }
        if !self.sched.has_work() {
            return Ok(out);
        }

        let backend = &mut self.backend;
        let mut ws = |id| backend.decode_ws_bytes(id);
        self.sched.plan_into(now, &mut ws, &mut self.batch);
        // planning may have admitted requests whose prompts matched the
        // shared prefix index: forward each adoption before the batch
        // runs, so the matched groups resolve to the shared residency
        // namespace from the very first gather
        while let Some((id, matched, group)) = self.sched.pop_adoption() {
            self.backend.adopt_prefix(id, matched, group);
        }
        if self.batch.is_empty() {
            return Ok(out);
        }

        // ---- pipelined executor: consume the speculative plan ----
        // The real plan above ALWAYS ran, so the executed batch is the
        // synchronous one at any depth. The speculation taken under the
        // PREVIOUS iteration's compute decides only whether this
        // iteration's plan/stage share was already paid there: it is
        // trusted iff nothing staled it — the scheduler's plan version
        // is unchanged AND its decode packing equals the real plan's.
        let depth = self.sched.cfg.pipeline_depth;
        let primed = depth > 1
            && self.spec_valid
            && self.spec_version == self.sched.plan_version()
            && self.spec_batch.decodes == self.batch.decodes;
        if primed {
            // reuse the hints precomputed with the speculation: with an
            // unchanged version and an identical batch they are provably
            // equal to a fresh `stage_hints_into`
            std::mem::swap(&mut self.hints.next_decodes, &mut self.spec_hints);
            self.metrics.pipeline_spec_used += 1;
        } else {
            // cross-iteration staging: the session stages this batch's
            // working sets first, then (with leftover budget, under this
            // batch's compute) the decodes predicted to run NEXT
            self.sched.stage_hints_into(&self.batch, &mut self.hints.next_decodes);
            if depth > 1 && self.spec_valid {
                // a speculative plan existed but went stale (eviction,
                // finish, graduation, migration): re-planned, never
                // executed
                self.metrics.pipeline_replans += 1;
            }
        }
        self.hints.pipelined = primed;
        self.spec_valid = false;

        // ---- speculate iteration N+1 under this one's compute ----
        // Read-only preview of the next plan's decode packing plus its
        // staging hints, stamped with the current plan version. On the
        // modeled clock this work overlaps the batch driven below; the
        // cost model prices the overlap at consume time (`primed`).
        if depth > 1 {
            let backend = &mut self.backend;
            let mut ws = |id| backend.decode_ws_bytes(id);
            self.sched.preview_decodes_into(&mut ws, &mut self.spec_batch.decodes);
            self.spec_batch.prefill = None;
            self.sched.stage_hints_into(&self.spec_batch, &mut self.spec_hints);
            self.spec_version = self.sched.plan_version();
            self.spec_valid = true;
        }

        let bo = loop {
            let res = if depth > 1 {
                drive_step_pipelined(
                    self.backend.as_mut(),
                    &self.batch,
                    &self.sched.requests,
                    &self.hints,
                )
            } else {
                drive_step(
                    self.backend.as_mut(),
                    &self.batch,
                    &self.sched.requests,
                    &self.hints,
                )
            };
            match res {
                Ok(bo) => break bo,
                Err(e) => {
                    // typed memory-tier exhaustion: the session already
                    // rolled back, so every batch-mate's KV is pristine.
                    // Evict the victim and retry the survivors in the
                    // SAME iteration. Anything untyped is fatal.
                    let info = e
                        .downcast_ref::<MemoryError>()
                        .map(|me| (me.req(), me.to_string()));
                    let Some((victim, reason)) = info else {
                        return Err(ServeError::backend(e));
                    };
                    // capture mode: drain the victim for re-admission
                    // elsewhere — scheduler reservation released first,
                    // then the backend state moves out wholesale. Either
                    // side refusing falls back to a true eviction.
                    let mut captured = false;
                    if self.capture_migrations {
                        if let Some((request, reserve_bytes)) =
                            self.sched.extract_for_migration(victim)
                        {
                            match self.backend.export_migration(victim) {
                                Some(payload) => {
                                    out.migratable.push(MigrationCandidate {
                                        request,
                                        reserve_bytes,
                                        payload,
                                        reason: reason.clone(),
                                    });
                                    captured = true;
                                }
                                None => {
                                    // the backend cannot drain: restore
                                    // the reservation (the bytes were
                                    // just freed, so this cannot fail)
                                    // and evict normally below
                                    let _ = self
                                        .sched
                                        .admit_migrated(request, reserve_bytes);
                                }
                            }
                        }
                    }
                    if !captured {
                        let err = ServeError::Evicted { reason };
                        if self.sched.cancel(victim) {
                            self.backend.release(victim);
                            self.metrics.requests_evicted += 1;
                            if !self.retain_finished {
                                self.sched.requests.remove(&victim);
                            }
                        }
                        out.evicted.push((victim, err));
                    }
                    let before = self.batch.n_requests();
                    self.batch.decodes.retain(|&id| id != victim);
                    if self.batch.prefill.as_ref().map_or(false, |w| w.req() == victim) {
                        self.batch.prefill = None;
                    }
                    // the staging hints were computed BEFORE this attempt
                    // and may still name the evicted victim: staging a
                    // released request's working set would repopulate the
                    // cache with unreachable groups and skew the prefetch
                    // counters — repair them before the retry, and price
                    // the retry synchronously (the speculated plan this
                    // iteration consumed no longer matches what runs)
                    self.hints.next_decodes.retain(|&id| id != victim);
                    debug_assert!(
                        !self.hints.next_decodes.contains(&victim),
                        "evicted victim must not be re-staged"
                    );
                    self.hints.pipelined = false;
                    if self.batch.is_empty() || self.batch.n_requests() == before {
                        // nothing left to retry, or the victim was not in
                        // the batch (cannot shrink further) — give up on
                        // this iteration (dropping the aborted attempts'
                        // transfer accounting) but still charge their
                        // burnt compute to the serving clock; the engine
                        // stays alive
                        let aborted = self.backend.abort_iteration();
                        out.iter_time_s = aborted;
                        self.metrics.record_abandoned_iteration(aborted);
                        return Ok(out);
                    }
                }
            }
        };
        out.ran_batch = true;
        // a committed retry also pays for the attempts it rolled back
        out.iter_time_s = bo.iter_time_s + bo.abort_time_s;
        out.batch_requests = self.batch.n_requests();
        self.metrics.record_iteration(&bo);

        if let Some(work) = &self.batch.prefill {
            self.sched.advance_prefill(work);
        }

        let t_emit = now + out.iter_time_s;
        for (id, tok) in &bo.tokens {
            let finished = self.sched.emit_token(*id, *tok, t_emit);
            let r = &self.sched.requests[id];
            // Count only actually emitted tokens toward the stream index
            // (a prefill-only step carries no payload token).
            let index = match tok {
                Some(_) => r.generated.len() - 1,
                None => r.n_generated - 1,
            };
            out.emitted.push(TokenEvent { req: *id, token: *tok, index });
            if finished {
                self.backend.release(*id);
                self.metrics.record_request(r);
                out.finished.push((*id, r.timing()));
                if !self.retain_finished {
                    self.sched.requests.remove(id);
                }
            }
        }
        Ok(out)
    }

    /// Re-admit a drained [`MigrationCandidate`] on THIS engine: take
    /// the scheduler reservation (`reserve_bytes`, atomically with the
    /// source's release — single-threaded cluster sequencing means no
    /// double-count window ever exists), then land the backend payload.
    /// On failure the candidate is handed back unchanged so the caller
    /// can try another target or finalize the eviction at the source.
    pub fn admit_migration(
        &mut self,
        candidate: MigrationCandidate,
    ) -> Result<(), MigrationCandidate> {
        let MigrationCandidate { request, reserve_bytes, payload, reason } = candidate;
        let id = request.id;
        match self.sched.admit_migrated(request, reserve_bytes) {
            Err(request) => Err(MigrationCandidate { request, reserve_bytes, payload, reason }),
            Ok(()) => {
                // scheduler admission guarantees the id was not live
                // here, and live backend entries are a subset of live
                // scheduler entries — the import cannot collide
                self.backend
                    .import_migration(payload)
                    .unwrap_or_else(|e| {
                        // sparselint: allow(no-panic) -- the payload was consumed by the failed import; limping on would corrupt cross-engine KV accounting (migration atomicity invariant), so fail loudly
                        panic!("backend refused an admitted migration (req {id}): {e:#}")
                    });
                self.next_id = self.next_id.max(id + 1);
                Ok(())
            }
        }
    }

    /// No engine could take this drained candidate: finalize it as a
    /// true eviction at the source (the drain already released all of
    /// its state; this accounts it and keeps the record for the report).
    pub fn finalize_eviction(&mut self, candidate: MigrationCandidate) {
        let MigrationCandidate { mut request, .. } = candidate;
        request.phase = Phase::Cancelled;
        // accounted exactly like the in-step eviction path: the evicted
        // counter, not a client cancellation
        self.metrics.requests_evicted += 1;
        if self.retain_finished {
            self.sched.requests.insert(request.id, request);
        }
    }

    /// Account one outbound migration on this (source) engine's metrics:
    /// the FlashD2H + FlashH2D transfer time charged to the shared
    /// cluster clock, and the DRAM-tier bytes that moved.
    pub fn record_migration(&mut self, transfer_s: f64, bytes: usize) {
        self.metrics.record_migration(transfer_s, bytes);
    }

    /// Finish the run: fold still-in-flight requests into the metrics
    /// (their TTFT/queue delays matter), stamp the makespan and hand the
    /// whole state back.
    pub fn into_report(mut self, makespan_s: f64) -> RunReport {
        for r in self.sched.requests.values() {
            if !r.is_done() && !r.is_cancelled() {
                self.metrics.record_request(r);
            }
        }
        self.metrics.makespan_s = makespan_s;
        // fold the scheduler's admission-time prefix accounting in: the
        // hit/skipped-token counters accumulate over the run, the
        // resident-bytes figure is the shared pool's end-of-run charge
        self.metrics.prefix_hits = self.sched.prefix_hits;
        self.metrics.prefix_matched_tokens = self.sched.prefix_matched_tokens;
        self.metrics.prefix_resident_bytes = self.sched.prefix_resident_bytes() as u64;
        RunReport {
            metrics: self.metrics,
            requests: std::mem::take(&mut self.sched.requests),
            iterations: self.sched.iterations,
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::{HardwareSpec, ModelSpec, ServingConfig};
    use crate::engine::SimBackend;

    fn core(queue_cap: Option<usize>) -> EngineCore {
        let cfg = ServingConfig::sparseserve(2048, 2048, 32);
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
        let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
        let mut c = EngineCore::new(sched, Box::new(backend));
        if let Some(cap) = queue_cap {
            c = c.with_queue_cap(cap);
        }
        c
    }

    /// Step until `pred` or panic after `max` iterations.
    fn step_until(c: &mut EngineCore, max: usize, mut pred: impl FnMut(&EngineCore) -> bool) {
        let mut now = 0.0;
        for _ in 0..max {
            if pred(c) {
                return;
            }
            let out = c.step(now).unwrap();
            assert!(out.ran_batch, "engine stalled");
            now += out.iter_time_s;
        }
        panic!("predicate not reached in {max} steps");
    }

    #[test]
    fn submit_step_finish_lifecycle() {
        let mut c = core(None);
        let id = c
            .submit(SubmitRequest::synthetic(8192).max_new(3), 0.0)
            .unwrap();
        assert!(c.has_work());
        let mut finished = Vec::new();
        let mut now = 0.0;
        for _ in 0..64 {
            let out = c.step(now).unwrap();
            assert!(out.ran_batch);
            now += out.iter_time_s;
            finished.extend(out.finished.iter().copied());
            if !c.has_work() {
                break;
            }
        }
        assert_eq!(finished.len(), 1);
        let (fid, timing) = finished[0];
        assert_eq!(fid, id);
        assert_eq!(timing.n_tokens, 3);
        assert!(timing.ttft_s.unwrap() > 0.0);
        let report = c.into_report(now);
        assert_eq!(report.metrics.requests_finished, 1);
        assert_eq!(report.metrics.tokens_generated, 3);
    }

    #[test]
    fn queue_cap_backpressure() {
        let mut c = core(Some(2));
        c.submit(SubmitRequest::synthetic(1000).max_new(4), 0.0).unwrap();
        c.submit(SubmitRequest::synthetic(1000).max_new(4), 0.0).unwrap();
        let err = c
            .submit(SubmitRequest::synthetic(1000).max_new(4), 0.0)
            .unwrap_err();
        assert!(matches!(err, ServeError::QueueFull { cap: 2 }));
        // draining the queue re-opens admission
        step_until(&mut c, 16, |c| c.n_queued() < 2);
        c.submit(SubmitRequest::synthetic(1000).max_new(4), 0.1).unwrap();
    }

    #[test]
    fn cancel_mid_decode_frees_backend_memory() {
        let mut c = core(None);
        let id = c
            .submit(SubmitRequest::synthetic(8192).max_new(1000), 0.0)
            .unwrap();
        // reach decode and run a few iterations so HBM cache fills
        step_until(&mut c, 64, |c| {
            c.sched().requests[&id].n_generated >= 3
        });
        let before = c.mem_stats();
        assert!(before.dram_bytes_used > 0, "decode must hold KV");
        assert!(before.hbm_bytes_used > 0, "decode must populate the cache");
        assert_eq!(before.n_registered, 1);

        assert!(c.cancel(id));
        let after = c.mem_stats();
        assert_eq!(after.n_registered, 0);
        assert_eq!(after.dram_bytes_used, 0, "cancel must free DRAM KV");
        assert_eq!(after.hbm_bytes_used, 0, "cancel must evict HBM blocks");
        assert!(!c.has_work());
        assert!(!c.cancel(id), "second cancel is a no-op");

        let report = c.into_report(1.0);
        assert_eq!(report.metrics.requests_cancelled, 1);
        assert_eq!(report.metrics.requests_finished, 0);
    }

    #[test]
    fn cancel_queued_request_never_runs() {
        let mut c = core(None);
        let a = c.submit(SubmitRequest::synthetic(4096).max_new(2), 0.0).unwrap();
        let b = c.submit(SubmitRequest::synthetic(4096).max_new(2), 0.0).unwrap();
        assert!(c.cancel(b));
        let mut now = 0.0;
        while c.has_work() {
            let out = c.step(now).unwrap();
            assert!(out.ran_batch);
            assert!(out.emitted.iter().all(|e| e.req != b));
            now += out.iter_time_s;
        }
        let report = c.into_report(now);
        assert!(report.requests[&a].is_done());
        assert!(report.requests[&b].is_cancelled());
        assert_eq!(report.requests[&b].n_generated, 0);
    }

    #[test]
    fn interactive_preempts_queued_batch() {
        let mut c = core(None);
        // keep the prefill slot busy so later submissions stay queued
        let _running = c
            .submit(SubmitRequest::synthetic(20_000).max_new(4), 0.0)
            .unwrap();
        let out = c.step(0.0).unwrap();
        assert!(out.ran_batch);
        let batch_req = c
            .submit(SubmitRequest::synthetic(4096).max_new(2), 0.1)
            .unwrap();
        let inter = c
            .submit(
                SubmitRequest::synthetic(4096).max_new(2).interactive(),
                0.2,
            )
            .unwrap();
        assert_eq!(c.sched().queued_ids(), vec![inter, batch_req]);
    }

    #[test]
    fn retain_finished_false_prunes_request_state() {
        let mut c = core(None).retain_finished(false);
        let done = c.submit(SubmitRequest::synthetic(4096).max_new(2), 0.0).unwrap();
        step_until(&mut c, 32, |c| !c.has_work());
        assert!(!c.sched().requests.contains_key(&done), "finished record pruned");
        let gone = c.submit(SubmitRequest::synthetic(4096).max_new(2), 1.0).unwrap();
        assert!(c.cancel(gone));
        assert!(!c.sched().requests.contains_key(&gone), "cancelled record pruned");
        let report = c.into_report(2.0);
        assert!(report.requests.is_empty());
        // metrics survive the pruning
        assert_eq!(report.metrics.requests_finished, 1);
        assert_eq!(report.metrics.requests_cancelled, 1);
    }

    /// HBM-oversubscribed engine (the tests/engine_core.rs eviction
    /// recipe): three 64-band-group decodes cannot share 160 band slots.
    fn pressured_core(capture: bool) -> EngineCore {
        let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
        cfg.ws_batch_control = false; // let the oversized batch form
        cfg.prefetch = false; // pure demand traffic
        let spec = ModelSpec::lwm_7b();
        let mut hw = HardwareSpec::a100_40gb();
        hw.hbm_kv_bytes = 40 * spec.n_layers * spec.n_kv_heads * spec.block_bytes();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw);
        let sched = Scheduler::new(cfg, spec, 1 << 40); // admission unconstrained
        EngineCore::new(sched, Box::new(backend)).capture_migrations(capture)
    }

    /// Step a pressured source until it drains its first victim.
    fn first_candidate(src: &mut EngineCore) -> MigrationCandidate {
        for _ in 0..3 {
            src.submit(SubmitRequest::synthetic(8192).max_new(64), 0.0).unwrap();
        }
        let mut now = 0.0;
        for _ in 0..400 {
            let out = src.step(now).unwrap();
            assert!(
                out.evicted.is_empty(),
                "capture mode must drain, not evict: {:?}",
                out.evicted
            );
            now += out.iter_time_s.max(1e-3);
            if let Some(c) = out.migratable.into_iter().next() {
                return c;
            }
        }
        panic!("HBM pressure must produce a migration candidate");
    }

    #[test]
    fn capture_mode_drains_victim_and_target_finishes_it() {
        let mut src = pressured_core(true);
        let cand = first_candidate(&mut src);
        let id = cand.request.id;
        assert!(cand.reserve_bytes > 0, "drain must carry the DRAM reservation");
        assert!(cand.payload.kv_bytes > 0, "mid-flight victim has DRAM KV");
        assert!(cand.reason.contains("HBM exhausted"), "{}", cand.reason);
        assert_eq!(src.metrics().requests_evicted, 0);
        assert!(!src.sched().requests.contains_key(&id), "victim left the source");

        // a roomy target re-admits it and runs it to completion
        let mut dst = core(None);
        dst.admit_migration(cand).unwrap();
        assert!(dst.sched().requests.contains_key(&id));
        let mut now = 0.0;
        let mut steps = 0;
        while dst.has_work() {
            steps += 1;
            assert!(steps < 400, "migrated request must make progress");
            let out = dst.step(now).unwrap();
            now += out.iter_time_s.max(1e-3);
        }
        let r = &dst.sched().requests[&id];
        assert!(r.is_done(), "migrated request must finish at the target");
        assert_eq!(dst.metrics().requests_finished, 1);
    }

    #[test]
    fn failed_target_admission_hands_candidate_back_for_finalize() {
        let mut src = pressured_core(true);
        let cand = first_candidate(&mut src);
        let id = cand.request.id;

        // a target with a 1 MiB DRAM budget cannot reserve the KV
        let cfg = ServingConfig::sparseserve(2048, 2048, 32);
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
        let sched =
            Scheduler::new(cfg, spec, hw.hbm_kv_bytes).with_dram_capacity(1 << 20);
        let mut tiny = EngineCore::new(sched, Box::new(backend));
        let cand = tiny.admit_migration(cand).expect_err("must hand the candidate back");
        assert_eq!(cand.request.id, id, "candidate returned unchanged");
        assert_eq!(tiny.sched().reserved_bytes(), 0, "failed admit reserves nothing");
        assert_eq!(tiny.mem_stats().n_registered, 0);

        // no engine had headroom: finalize as a true eviction at source
        src.finalize_eviction(cand);
        assert_eq!(src.metrics().requests_evicted, 1);
        let rec = &src.sched().requests[&id];
        assert!(rec.is_cancelled(), "finalized candidate is recorded as destroyed");
    }

    /// The `pressured_core` recipe at pipeline depth 2: speculative
    /// plans form every step and mid-batch evictions must stale them.
    fn pressured_pipelined_core() -> EngineCore {
        let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
        cfg.ws_batch_control = false;
        cfg.prefetch = false;
        cfg.pipeline_depth = 2;
        let spec = ModelSpec::lwm_7b();
        let mut hw = HardwareSpec::a100_40gb();
        hw.hbm_kv_bytes = 40 * spec.n_layers * spec.n_kv_heads * spec.block_bytes();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw);
        let sched = Scheduler::new(cfg, spec, 1 << 40);
        EngineCore::new(sched, Box::new(backend))
    }

    #[test]
    fn mid_batch_eviction_stales_the_speculative_plan() {
        let mut c = pressured_pipelined_core();
        for _ in 0..3 {
            c.submit(SubmitRequest::synthetic(8192).max_new(64), 0.0).unwrap();
        }
        let mut now = 0.0;
        let mut victim = None;
        for _ in 0..400 {
            let out = c.step(now).unwrap();
            now += out.iter_time_s.max(1e-3);
            if let Some((id, _)) = out.evicted.first() {
                victim = Some(*id);
                break;
            }
        }
        let victim = victim.expect("HBM pressure must evict");
        // the eviction bumped the plan version mid-step, so the next
        // step must RE-PLAN instead of executing the stale speculation
        let replans_before = c.metrics().pipeline_replans;
        let out = c.step(now).unwrap();
        now += out.iter_time_s.max(1e-3);
        assert!(
            c.metrics().pipeline_replans > replans_before,
            "stale speculation must be re-planned, not executed"
        );
        assert!(
            out.emitted.iter().all(|e| e.req != victim),
            "no stale victim in the executed batch"
        );
        // the engine keeps serving after the repair (the sim backend's
        // begin_step pin-conservation debug_assert rides every step)
        for _ in 0..50 {
            if !c.has_work() {
                break;
            }
            let out = c.step(now).unwrap();
            assert!(out.emitted.iter().all(|e| e.req != victim));
            now += out.iter_time_s.max(1e-3);
        }
    }

    #[test]
    fn steady_decode_primes_the_pipeline() {
        // unpressured depth-2 engine: once decodes reach steady state the
        // speculation survives validation and the overlap is earned
        let cfg = {
            let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
            cfg.pipeline_depth = 2;
            cfg
        };
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
        let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
        let mut c = EngineCore::new(sched, Box::new(backend));
        c.submit(SubmitRequest::synthetic(8192).max_new(32), 0.0).unwrap();
        let mut now = 0.0;
        while c.has_work() {
            let out = c.step(now).unwrap();
            assert!(out.ran_batch);
            now += out.iter_time_s;
        }
        let m = c.metrics();
        assert!(m.pipeline_spec_used > 0, "steady decode must prime the pipeline");
        assert!(m.plan_stage_hidden_s > 0.0, "primed iterations must hide plan/stage time");
    }

    #[test]
    fn duplicate_id_rejected() {
        let mut c = core(None);
        c.submit_with_id(7, SubmitRequest::synthetic(100).max_new(1), 0.0)
            .unwrap();
        let err = c
            .submit_with_id(7, SubmitRequest::synthetic(100).max_new(1), 0.0)
            .unwrap_err();
        assert!(matches!(err, ServeError::AdmissionRejected { .. }));
    }
}
