//! Paper-scale simulated backend.
//!
//! Executes batches against the analytic cost model (sim::cost) and the
//! Fig. 8-calibrated synthetic selection process (sim::selection), while
//! sharing the *real* scheduler, LRU-cache accounting, working-set,
//! staging-policy and prefetch machinery with the PJRT backend.
//! Selection/caching granularity is the **layer-band group** (one group
//! = that block index across one band of layers and all KV heads); cost
//! accounting multiplies back to per-head blocks.
//!
//! Execution is session-based ([`super::StepSession`]): the engine
//! drives `stage` → per-layer phases → `commit`/`rollback`. The
//! selection process draws **per layer band** (`ServingConfig::
//! sim_selection_bands`, K bands over the model's layers): when a decode
//! phase reaches a band's first layer, that band's selections are drawn
//! and the residency cache is touched with *that band's* groups — misses
//! land in the per-layer demand profile where they are discovered, which
//! is exactly what the per-layer event model
//! ([`crate::sim::layered_iter`]) overlaps with the remaining layers'
//! compute. `ServingConfig::sim_layer_skew` tilts miss discovery toward
//! early or late layers the way real DSAs do.
//!
//! ## Mid-decode fallibility
//!
//! A band's working set must be simultaneously resident while its gather
//! runs, so every touched group is pinned for the duration of the band
//! phase. When a demanded group cannot become resident (the cache is
//! pinned shut by prefetch stages plus the executing band's own working
//! set), `decode_layer` fails with a typed
//! [`MemoryError::HbmExhausted`] naming the request — MID-decode, after
//! earlier bands' compute has been burnt. This is what makes
//! `EngineCore::step`'s evict-victim-then-retry path, the undo-log
//! rollback and `BatchOutcome::abort_time_s` charging all real on
//! pure-sim eviction workloads (previously the sim's only fallible phase
//! preceded decode compute, so abort time was provably always zero).
//! The failing band's compute is attributed *before* its touches run:
//! the layer was executing when the gather hit the wall, so that time is
//! burnt either way. Prefill re-fetch stays best-effort (streamed, not
//! simultaneously resident): a non-insertable chunk re-fetch group still
//! pays its demand load but never faults.
//!
//! ## Zero-clone steady state
//!
//! The decode critical path performs no clones and no steady-state
//! allocation: rollback support is an incremental undo log — `len` is
//! journaled per touched request and `SelectionModel` /
//! `WorkingSetTracker` arm their own `begin_txn` record-and-revert
//! scopes — instead of the old per-iteration clone snapshots, and every
//! per-step working buffer (per-band selection draws, working-set items,
//! ranked staging plan, per-layer accumulators, residency log, band
//! pins) lives in a recycled [`StepScratch`] owned by the backend.
//! Rollback restores every batch request's simulated state (KV length,
//! selection RNG, working-set history) and the residency cache
//! byte-identically, so a retried batch replays exactly; the aborted
//! attempt's burnt compute is surfaced as `BatchOutcome::abort_time_s`
//! on the next commit.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::{HardwareSpec, IterModel, ModelSpec, ServingConfig};
use crate::memory::staging_policy::{stage_block, StageAdmission, StagingPolicy};
use crate::memory::{BlockKey, LruCache, MemoryError, PrefetchEngine, ReqId, PREFIX_NS};
use crate::scheduler::{Batch, PrefillWork, Request};
use crate::sim::{layered_iter, pipelined_iter, two_stream_iter, CostModel, SelectionModel};
use crate::sparse::working_set::SelItem;
use crate::sparse::WorkingSetTracker;

use super::backend::{
    Backend, BatchOutcome, MemStats, PhaseEvent, StageHints, StepSession,
};

struct SimReq {
    /// Tokens with stored KV.
    len: usize,
    selection: SelectionModel,
    ws: WorkingSetTracker,
    /// DSA budget in block groups per layer band (per-request override
    /// or the config default).
    budget_groups: usize,
    /// Band-groups covered by the shared KV prefix adopted at admission:
    /// group indices below this are keyed by `prefix_ns` (one residency
    /// entry serves every sharer), the rest by the request id. 0 = fully
    /// private.
    prefix_groups: usize,
    /// Shared residency namespace (`PREFIX_NS | path tail id`); 0 when
    /// private. Requests sharing the same matched path share the
    /// namespace, so one sharer's load/stage is every sharer's hit.
    prefix_ns: u32,
}

/// Recycled per-step working buffers: cleared (never freed) by
/// `begin_step`, so steady-state decode iterations allocate nothing.
#[derive(Default)]
struct StepScratch {
    /// Undo log: (request, pre-step KV length, sel/ws txns armed).
    touched: Vec<(ReqId, usize, bool)>,
    /// (inserted, evicted-by-that-insert) residency log for rollback.
    cache_log: Vec<(BlockKey, Option<BlockKey>)>,
    /// Per-layer accumulation driving the event model.
    layer_compute: Vec<f64>,
    layer_miss_blocks: Vec<usize>,
    layer_demand: Vec<f64>,
    /// Per-band decode attribution (compute per layer, missed groups).
    band_compute_per_layer: Vec<f64>,
    band_miss_groups: Vec<usize>,
    /// Groups pinned by the band phase currently in flight (its working
    /// set must stay simultaneously resident); unpinned at band end.
    band_pins: Vec<BlockKey>,
    /// Per-decode-request selection buffers for the in-flight band.
    band_sels: Vec<Vec<u32>>,
    /// Per-decode-request accumulated (band, head, block) items of the
    /// whole step (recorded as ONE working-set step at the last band).
    ws_accum: Vec<Vec<SelItem>>,
    /// Scratch for prefill past-group touches.
    sel: Vec<u32>,
    /// Ranked working-set buffer (`ranked_blocks_capped_into`).
    ranked: Vec<SelItem>,
    /// Per-request effective KV tokens of the decode batch (per band).
    kv_tokens: Vec<usize>,
}

pub struct SimBackend {
    pub cfg: ServingConfig,
    pub cost: CostModel,
    /// HBM residency cache at band-group granularity.
    cache: LruCache<()>,
    reqs: HashMap<ReqId, SimReq>,
    /// Layer bands of the selection process (1..=n_layers).
    n_bands: usize,
    /// `[start, end)` layers of each band.
    band_bounds: Vec<(usize, usize)>,
    /// layer -> band lookup.
    layer_band: Vec<usize>,
    /// per-head blocks represented by one cached band-group (mean when
    /// the band count does not divide the layer count).
    group_blocks: usize,
    group_bytes: usize,
    seed: u64,
    /// Monotone admission counter mixed into per-request selection
    /// seeds, so a released request id reused by a later admission draws
    /// a fresh RNG stream instead of replaying the old one.
    admissions: u64,
    /// Live shared-prefix namespaces: ns -> (sharers, shared groups).
    /// The DRAM charge in `mem_stats` counts each namespace ONCE (the
    /// whole point of sharing); the last sharer's release tears down the
    /// namespace's stages and residency entries.
    prefix_refs: HashMap<u32, (u32, usize)>,
    /// Working-set staging bookkeeping (band-group granularity).
    prefetcher: PrefetchEngine,
    /// Groups staged for the current iteration, consumed at commit
    /// (their PCIe time overlaps that batch's compute).
    staged_groups: usize,
    /// Groups staged for the NEXT iteration (cross-iteration hints).
    staged_deferred_groups: usize,
    /// Recycled per-step buffers (see [`StepScratch`]).
    scratch: StepScratch,
    /// Second scratch slot of the pipelined executor's double buffer:
    /// `begin_step` rotates the two, so the slot a just-settled session
    /// filled (per-layer telemetry, undo/residency logs) stays intact
    /// while the engine speculatively plans the next iteration against
    /// it — the next session never clears buffers a pipelined consumer
    /// may still read. Both slots are warm after two iterations, so
    /// steady-state decode still allocates nothing.
    scratch_spare: StepScratch,
    /// Backend-only execution window of the last committed iteration
    /// (`PipelinedTiming::exec_s`): the window the NEXT iteration's
    /// speculative plan/stage hides under when `StageHints::pipelined`.
    prev_exec_s: f64,
    /// Compute burnt by rolled-back sessions, awaiting the next commit's
    /// `abort_time_s` (or `abort_iteration`).
    aborted_time_s: f64,
    /// Cumulative counters.
    pub total_blocks_loaded: u64,
}

impl SimBackend {
    pub fn new(cfg: ServingConfig, spec: ModelSpec, hw: HardwareSpec) -> Self {
        let n_bands = cfg.sim_selection_bands.clamp(1, spec.n_layers);
        let group_blocks = (spec.n_layers * spec.n_kv_heads / n_bands).max(1);
        let group_bytes = group_blocks * spec.block_bytes();
        let capacity = (hw.hbm_kv_bytes / group_bytes).max(1);
        // contiguous, near-equal layer bands
        let mut band_bounds = Vec::with_capacity(n_bands);
        let (base, rem) = (spec.n_layers / n_bands, spec.n_layers % n_bands);
        let mut l = 0;
        for b in 0..n_bands {
            let sz = base + usize::from(b < rem);
            band_bounds.push((l, l + sz));
            l += sz;
        }
        let mut layer_band = vec![0usize; spec.n_layers];
        for (b, &(l0, l1)) in band_bounds.iter().enumerate() {
            for lb in layer_band.iter_mut().take(l1).skip(l0) {
                *lb = b;
            }
        }
        Self {
            cfg,
            cost: CostModel::new(spec, hw),
            cache: LruCache::new(capacity),
            reqs: HashMap::new(),
            n_bands,
            band_bounds,
            layer_band,
            group_blocks,
            group_bytes,
            seed: 0x51,
            admissions: 0,
            prefix_refs: HashMap::new(),
            prefetcher: PrefetchEngine::new(0), // no real bytes to copy
            staged_groups: 0,
            staged_deferred_groups: 0,
            scratch: StepScratch::default(),
            scratch_spare: StepScratch::default(),
            prev_exec_s: 0.0,
            aborted_time_s: 0.0,
            total_blocks_loaded: 0,
        }
    }

    fn spec(&self) -> &ModelSpec {
        &self.cost.spec
    }

    pub fn hbm_capacity_bytes(&self) -> usize {
        self.cache.capacity() * self.group_bytes
    }

    /// Resident cache entries currently pinned (prefetch stages + the
    /// in-flight band's working set) — the conservation quantity the
    /// rollback tests assert on.
    pub fn pinned_entries(&self) -> usize {
        self.cache.pinned_len()
    }

    /// Reference decode iteration (SLO unit).
    pub fn decode_iter_ref(&self) -> f64 {
        let kv = if self.cfg.sparse_attention {
            self.cfg.token_budget.min(self.spec().max_ctx)
        } else {
            self.spec().max_ctx / 2
        };
        self.cost.decode_iter_ref(kv)
    }

    fn budget_groups(&self) -> usize {
        self.cfg.budget_blocks(self.spec().block_size)
    }

    /// Touch the cache with one band of a decode request's selection,
    /// pinning every touched group until the band phase ends (the
    /// in-flight gather needs them simultaneously resident). Hits on
    /// staged groups consume their prefetch pin (the staged bytes
    /// already paid for the transfer on the overlapped stream). Inserts
    /// are logged (in the recycled scratch) for session rollback.
    ///
    /// Returns the misses discovered, or a typed `HbmExhausted` when a
    /// demanded group cannot become resident — the cache is pinned shut
    /// by stages plus the executing band's own working set, i.e. HBM
    /// cannot hold this band's batch-wide working set.
    fn touch_band_groups(
        &mut self,
        req: ReqId,
        band: u16,
        groups: &[u32],
    ) -> Result<usize, MemoryError> {
        let mut misses = 0;
        let (pns, pgroups) = self
            .reqs
            .get(&req)
            .map(|r| (r.prefix_ns, r.prefix_groups))
            .unwrap_or((0, 0));
        for &g in groups {
            // shared-prefix groups are keyed by namespace, not request
            let key = if (g as usize) < pgroups {
                BlockKey::new(pns, band, 0, g)
            } else {
                BlockKey::new(req, band, 0, g)
            };
            if self.cache.get(&key).is_some() {
                if self.prefetcher.note_access(&key) {
                    self.cache.unpin(&key);
                }
            } else {
                misses += 1;
                if !self.cache.can_accept() {
                    return Err(MemoryError::HbmExhausted { req });
                }
                let evicted = self.cache.insert(key, ()).map(|(k, ())| k);
                self.scratch.cache_log.push((key, evicted));
            }
            self.cache.pin(&key);
            self.scratch.band_pins.push(key);
        }
        Ok(misses)
    }

    /// Best-effort cache touch (prefill past-KV re-fetch): a skipped
    /// insert still pays the demand load, and nothing is pinned —
    /// prefill streams the past KV layer by layer instead of needing it
    /// simultaneously resident, so it never faults on residency.
    fn touch_groups_best_effort(&mut self, req: ReqId, band: u16, groups: &[u32]) -> usize {
        let mut misses = 0;
        let (pns, pgroups) = self
            .reqs
            .get(&req)
            .map(|r| (r.prefix_ns, r.prefix_groups))
            .unwrap_or((0, 0));
        for &g in groups {
            let key = if (g as usize) < pgroups {
                BlockKey::new(pns, band, 0, g)
            } else {
                BlockKey::new(req, band, 0, g)
            };
            if self.cache.get(&key).is_some() {
                if self.prefetcher.note_access(&key) {
                    self.cache.unpin(&key);
                }
            } else {
                misses += 1;
                if self.cache.can_accept() {
                    let evicted = self.cache.insert(key, ()).map(|(k, ())| k);
                    self.scratch.cache_log.push((key, evicted));
                }
            }
        }
        misses
    }

    /// Drop the in-flight band's residency pins (its gather finished, or
    /// the session is closing).
    fn release_band_pins(&mut self) {
        while let Some(key) = self.scratch.band_pins.pop() {
            self.cache.unpin(&key);
        }
    }

    /// Stage the working sets of `current` decodes (this iteration,
    /// FCFS), then `next` (cross-iteration hints, deferred) with the
    /// leftover budget — admission through the shared
    /// [`StagingPolicy`], so this path cannot drift from
    /// `KvManager::prefetch_working_set`. Ranking reuses the scratch
    /// buffer (recency order, frequency-blended when configured).
    fn stage_working_sets(&mut self, current: &[ReqId], next: &[ReqId]) -> usize {
        if !(self.cfg.prefetch && self.cfg.offload && self.cfg.sparse_attention) {
            return 0;
        }
        // keep the executing batch's (and the hinted next batch's)
        // per-band demand free-or-evictable: stages pinning HBM shut
        // would turn a band's own working set into a spurious
        // mid-decode HbmExhausted eviction
        let mut demand = 0usize;
        for &id in current.iter().chain(next) {
            if let Some(r) = self.reqs.get(&id) {
                demand += r.budget_groups;
            }
        }
        let policy = StagingPolicy {
            max_blocks: self.cfg.max_prefetch_blocks,
            headroom: demand.min(self.cache.capacity()),
        };
        let mut ranked = std::mem::take(&mut self.scratch.ranked);
        let mut staged = 0usize;
        let mut deferred = 0usize;
        'all: for (ids, defer) in [(current, false), (next, true)] {
            for &id in ids {
                // over-collect by 2x: resident entries are skipped for free
                let want = policy
                    .max_blocks
                    .saturating_sub(staged + deferred)
                    .saturating_mul(2);
                if want == 0 {
                    break 'all;
                }
                let (pns, pgroups) = match self.reqs.get_mut(&id) {
                    Some(r) => {
                        r.ws.ranked_blocks_capped_into(want, &mut ranked);
                        (r.prefix_ns, r.prefix_groups)
                    }
                    None => continue,
                };
                for &(band, head, g) in &ranked {
                    // shared-prefix groups stage under their namespace:
                    // skip-resident sees (and serves) other sharers
                    let key = if (g as usize) < pgroups {
                        BlockKey::new(pns, band, head, g)
                    } else {
                        BlockKey::new(id, band, head, g)
                    };
                    match policy.admit(&self.cache, &key, staged + deferred) {
                        StageAdmission::Stop => break 'all,
                        StageAdmission::SkipResident => continue,
                        StageAdmission::Admit => {}
                    }
                    stage_block(
                        &mut self.cache,
                        &mut self.prefetcher,
                        key,
                        (),
                        self.group_bytes,
                        defer,
                    );
                    if defer {
                        deferred += 1;
                    } else {
                        staged += 1;
                    }
                }
            }
        }
        self.scratch.ranked = ranked;
        self.staged_groups += staged;
        self.staged_deferred_groups += deferred;
        staged + deferred
    }

    /// Prefetch hit/waste totals (tests + figures).
    pub fn prefetch_stats(&self) -> crate::memory::PrefetchStats {
        self.prefetcher.stats
    }

    /// Drop one sharer of a prefix namespace. At the LAST sharer the
    /// namespace dies: its stages are cancelled (stage pins returned —
    /// pin conservation at shared teardown) and its residency entries
    /// evicted. Until then everything stays for the surviving sharers.
    fn drop_prefix_ref(&mut self, ns: u32) {
        let Some(e) = self.prefix_refs.get_mut(&ns) else {
            debug_assert!(false, "prefix deref without a live namespace");
            return;
        };
        e.0 -= 1;
        if e.0 == 0 {
            self.prefix_refs.remove(&ns);
            for key in self.prefetcher.cancel_request(ns) {
                self.cache.unpin(&key);
            }
            self.cache.remove_request(ns);
        }
    }
}

/// One in-flight simulated batch (see [`StepSession`]). All per-step
/// buffers live in the backend's recycled [`StepScratch`]; the session
/// itself holds only small per-phase state.
struct SimSession<'s> {
    be: &'s mut SimBackend,
    batch: &'s Batch,
    requests: &'s HashMap<ReqId, Request>,
    tokens: Vec<(ReqId, Option<i32>)>,
    /// Prefill chunk past-refetch misses of the band currently being
    /// driven (groups), attributed to that band's layers.
    chunk_band_miss: usize,
    hits_at_start: u64,
    staged: bool,
    /// This batch's plan + hints were speculatively computed under the
    /// previous iteration's compute ([`StageHints::pipelined`]): commit
    /// charges the pipelined iteration bound instead of the serialized
    /// one.
    pipelined: bool,
}

impl<'s> SimSession<'s> {
    /// Run one layer band of the decode batch: draw every decode's band
    /// selection, attribute the band's compute, then touch the residency
    /// cache with the band's groups (fallible, typed). Undo scopes (len
    /// journal + sel/ws txns) are armed at band 0, before any mutation —
    /// the zero-clone replacement for the old snapshots; working-set
    /// recording and KV growth close the step at the last band.
    // sparselint: hot
    fn run_decode_band(&mut self, band: usize) -> Result<(), MemoryError> {
        let bs = self.be.spec().block_size;
        let n_layers = self.be.spec().n_layers;
        let heads = self.be.spec().n_kv_heads;
        let sparse = self.be.cfg.sparse_attention;
        let offload = self.be.cfg.offload;
        let (l0, l1) = self.be.band_bounds[band];
        let band_layers = l1 - l0;
        let last_band = self.be.n_bands - 1;

        // 1. selection draws (per request, this band only)
        let mut kv_tokens = std::mem::take(&mut self.be.scratch.kv_tokens);
        kv_tokens.clear();
        for (i, &id) in self.batch.decodes.iter().enumerate() {
            let mut sel = std::mem::take(&mut self.be.scratch.band_sels[i]);
            sel.clear();
            let Some(r) = self.be.reqs.get_mut(&id) else {
                return Err(MemoryError::Unregistered { req: id });
            };
            if band == 0 {
                // arm the undo scopes before this request's first mutation
                if sparse {
                    r.selection.begin_txn();
                    r.ws.begin_txn();
                }
                self.be.scratch.touched.push((id, r.len, sparse));
                self.tokens.push((id, None));
            }
            let len = r.len;
            if sparse {
                let budget = r.budget_groups;
                r.selection.next_band_selection_into(band, len / bs, budget, &mut sel);
                kv_tokens.push((sel.len() * bs + len % bs).min(len).max(1));
            } else {
                kv_tokens.push(len.max(1));
            }
            self.be.scratch.band_sels[i] = sel;
        }

        // 2. the band's compute is attributed BEFORE its cache touches:
        // on a mid-band memory fault the layer was already executing, so
        // this time is burnt (rollback charges it as abort time)
        let compute = self
            .be
            .cost
            .decode_iter_time(self.batch.decodes.len(), &kv_tokens)
            * band_layers as f64
            / n_layers as f64;
        let per_layer = compute / band_layers.max(1) as f64;
        self.be.scratch.band_compute_per_layer[band] = per_layer;
        for l in l0..l1 {
            self.be.scratch.layer_compute[l] += per_layer;
        }
        self.be.scratch.kv_tokens = kv_tokens;

        // 3. residency touches: misses are DISCOVERED at this band's
        // layers, and insertion faults typed when HBM cannot hold the
        // executing band's batch-wide working set
        let mut miss = 0usize;
        if sparse && offload {
            for (i, &id) in self.batch.decodes.iter().enumerate() {
                let sel = std::mem::take(&mut self.be.scratch.band_sels[i]);
                let res = self.be.touch_band_groups(id, band as u16, &sel);
                self.be.scratch.band_sels[i] = sel;
                miss += res?;
            }
        }
        self.be.scratch.band_miss_groups[band] = miss;
        for l in l0..l1 {
            self.be.scratch.layer_miss_blocks[l] += miss * heads;
        }

        // 4. working-set recording + KV growth close the step at the
        // last band (every band's draw used the same pre-step length)
        for (i, &id) in self.batch.decodes.iter().enumerate() {
            if sparse {
                let sel = std::mem::take(&mut self.be.scratch.band_sels[i]);
                self.be.scratch.ws_accum[i]
                    .extend(sel.iter().map(|&b| (band as u16, 0u16, b)));
                self.be.scratch.band_sels[i] = sel;
            }
            if band == last_band {
                let items = std::mem::take(&mut self.be.scratch.ws_accum[i]);
                let Some(r) = self.be.reqs.get_mut(&id) else {
                    return Err(MemoryError::Unregistered { req: id });
                };
                if sparse {
                    r.ws.record_step_from(&items);
                }
                r.len += 1;
                self.be.scratch.ws_accum[i] = items;
            }
        }
        Ok(())
    }
}

impl StepSession for SimSession<'_> {
    fn stage(&mut self, hints: &StageHints) -> usize {
        debug_assert!(!self.staged, "stage() called twice");
        self.staged = true;
        self.pipelined = hints.pipelined;
        let groups = self
            .be
            .stage_working_sets(&self.batch.decodes, &hints.next_decodes);
        groups * self.be.group_blocks
    }

    fn prefill_segment(&mut self, layer_start: usize, layer_end: usize) -> Result<PhaseEvent> {
        debug_assert_eq!(layer_end, layer_start + 1, "engine drives one layer per segment");
        let Some(work) = self.batch.prefill.as_ref() else {
            return Err(anyhow::anyhow!("prefill_segment driven with no prefill planned"));
        };
        let req_id = work.req();
        let spec = self.be.spec().clone();
        let bs = spec.block_size;
        let save_f = self
            .be
            .cost
            .save_overhead_factor(self.be.cfg.transfer, self.be.cfg.offload);
        let layer = layer_start;
        let mut miss_blocks = 0usize;
        let compute_s;
        match work {
            PrefillWork::Chunk { start, len, is_last, .. } => {
                compute_s = self.be.cost.prefill_layer_time(*len, *start) * save_f;
                // offloaded chunked prefill re-fetches evicted past KV;
                // each band's groups are touched when the chunk reaches
                // that band's first layer (best-effort: prefill streams
                // the past, it never faults on residency), so re-fetch
                // misses are attributed to the layers that discover them
                if self.be.cfg.offload && *start > 0 {
                    let band = self.be.layer_band[layer];
                    if layer == self.be.band_bounds[band].0 {
                        let mut past = std::mem::take(&mut self.be.scratch.sel);
                        past.clear();
                        past.extend(0..(*start / bs) as u32);
                        self.chunk_band_miss =
                            self.be.touch_groups_best_effort(req_id, band as u16, &past);
                        self.be.scratch.sel = past;
                    }
                    miss_blocks += self.chunk_band_miss * spec.n_kv_heads;
                }
                if layer + 1 == spec.n_layers {
                    let Some(r) = self.be.reqs.get_mut(&req_id) else {
                        return Err(MemoryError::Unregistered { req: req_id }.into());
                    };
                    let prev = r.len;
                    r.len += len;
                    self.be.scratch.touched.push((req_id, prev, false));
                    if *is_last {
                        self.tokens.push((req_id, None));
                    }
                }
            }
            PrefillWork::LayerSegment { layer_end: seg_end, tok_start, tok_len, is_last, .. } => {
                // single-layer HBM bound: a segment only ever needs ONE
                // layer of KV resident — but that one layer must fit
                let seg_layer_bytes =
                    tok_len.div_ceil(bs) * spec.n_kv_heads * spec.block_bytes();
                if seg_layer_bytes > self.be.hbm_capacity_bytes() {
                    return Err(MemoryError::HbmExhausted { req: req_id }.into());
                }
                compute_s = self.be.cost.prefill_layer_time(*tok_len, *tok_start) * save_f;
                // layer-segmented prefill writes straight to DRAM and
                // evicts immediately: no cache traffic
                if layer + 1 == *seg_end && *is_last {
                    let prompt_len = self.requests[&req_id].prompt_len;
                    let Some(r) = self.be.reqs.get_mut(&req_id) else {
                        return Err(MemoryError::Unregistered { req: req_id }.into());
                    };
                    let prev = r.len;
                    r.len = prompt_len;
                    self.be.scratch.touched.push((req_id, prev, false));
                    self.tokens.push((req_id, None));
                }
            }
        }
        self.be.scratch.layer_compute[layer] += compute_s;
        self.be.scratch.layer_miss_blocks[layer] += miss_blocks;
        Ok(PhaseEvent {
            layer_start,
            layer_end,
            compute_s,
            miss_blocks,
            bytes_moved: miss_blocks * self.be.spec().block_bytes(),
        })
    }

    fn decode_layer(&mut self, layer: usize) -> Result<PhaseEvent> {
        let band = self.be.layer_band[layer];
        if layer == self.be.band_bounds[band].0 {
            // the previous band's gather is done: its residency pins drop
            self.be.release_band_pins();
            self.run_decode_band(band)?;
        }
        let compute_s = self.be.scratch.band_compute_per_layer[band];
        // one missed band-group spans the band's layers: each layer's
        // gather needs its per-head slice of the group's bytes
        let miss_blocks = self.be.scratch.band_miss_groups[band] * self.be.spec().n_kv_heads;
        Ok(PhaseEvent {
            layer_start: layer,
            layer_end: layer + 1,
            compute_s,
            miss_blocks,
            bytes_moved: miss_blocks * self.be.spec().block_bytes(),
        })
    }

    fn commit(self: Box<Self>) -> Result<BatchOutcome> {
        let SimSession { be, batch, tokens, hits_at_start, pipelined, .. } = *self;
        // the last band's gather is done; its residency pins drop
        be.release_band_pins();
        // the step is final: close every armed undo scope
        for &(id, _, armed) in &be.scratch.touched {
            if armed {
                if let Some(r) = be.reqs.get_mut(&id) {
                    r.selection.commit_txn();
                    r.ws.commit_txn();
                }
            }
        }
        let mut out = BatchOutcome::default();

        // ------------- PCIe streams & iteration timing -------------
        // Prefetch (incl. deferred stages issued under this compute) was
        // put on the copy stream before the batch; demand misses are
        // discovered band by band and charged by the configured model.
        let staged_groups = std::mem::take(&mut be.staged_groups);
        let deferred_groups = std::mem::take(&mut be.staged_deferred_groups);
        let prefetch_blocks = (staged_groups + deferred_groups) * be.group_blocks;
        let miss_blocks: usize = be.scratch.layer_miss_blocks.iter().sum();
        let prefetch_s = be.cost.load_time(be.cfg.transfer, prefetch_blocks);
        let demand_s = be.cost.load_time(be.cfg.transfer, miss_blocks);
        let compute_s: f64 = be.scratch.layer_compute.iter().sum();
        // per-layer demand slices, proportional to where the misses were
        // discovered (the total load time stays the engine-modeled one);
        // built into the recycled buffer
        be.scratch.layer_demand.clear();
        if miss_blocks == 0 {
            be.scratch.layer_demand.resize(be.scratch.layer_miss_blocks.len(), 0.0);
        } else {
            for &m in &be.scratch.layer_miss_blocks {
                be.scratch.layer_demand.push(demand_s * m as f64 / miss_blocks as f64);
            }
        }
        let coarse = two_stream_iter(compute_s, prefetch_s, demand_s);
        let timing = match be.cfg.iter_model {
            IterModel::Coarse => coarse,
            IterModel::PerLayer => layered_iter(
                &be.scratch.layer_compute,
                &be.scratch.layer_demand,
                prefetch_s,
            ),
        };

        out.tokens = tokens;
        out.blocks_loaded = miss_blocks + prefetch_blocks;
        out.load_time_s = demand_s + prefetch_s;
        out.stall_time_s = timing.stall_s;
        out.hidden_time_s = timing.hidden_s;
        out.coarse_stall_time_s = coarse.stall_s;
        out.iter_time_s = timing.iter_time_s;

        // ------------- pipelined executor accounting -------------
        // The host-side plan/stage share of this iteration is a slice of
        // the decode overhead already inside `compute_s`. When the engine
        // pre-planned this batch under the predecessor's compute
        // (`pipeline_depth >= 2` and the speculation survived), charge
        // the pipelined bound: the share hides under the previous
        // execution window and any overhang is a fill bubble. A
        // synchronous iteration keeps the serialized bound bit-identical
        // — but still records its execution window, so a pipelined
        // successor knows what it can hide under.
        let plan_stage_s = be.cost.plan_stage_time(batch.decodes.len(), prefetch_blocks);
        if pipelined {
            let pt = pipelined_iter(timing.iter_time_s, plan_stage_s, be.prev_exec_s);
            out.iter_time_s = pt.iter_time_s;
            out.plan_stage_hidden_s = pt.plan_stage_hidden_s;
            out.pipeline_bubble_s = pt.pipeline_bubble_s;
            be.prev_exec_s = pt.exec_s;
        } else {
            be.prev_exec_s = (timing.iter_time_s - plan_stage_s).max(0.0);
        }

        out.prefetch_blocks = prefetch_blocks;
        out.prefetch_deferred = deferred_groups * be.group_blocks;
        // rolled-back attempts of this iteration surface here and are
        // charged to the serving clock by the engine
        out.abort_time_s = std::mem::take(&mut be.aborted_time_s);
        be.total_blocks_loaded += (miss_blocks + prefetch_blocks) as u64;

        // retire unconsumed stages: wasted this iteration, but they stay
        // resident (unpinned) and may still hit later; deferred stages
        // are promoted and retire at the END of the next iteration
        let wasted = be.prefetcher.end_iteration();
        for key in &wasted {
            be.cache.unpin(key);
        }
        out.prefetch_hits =
            (be.prefetcher.stats.hits - hits_at_start) as usize * be.group_blocks;
        out.prefetch_wasted = wasted.len() * be.group_blocks;
        Ok(out)
    }

    fn rollback(self: Box<Self>) {
        let SimSession { be, .. } = *self;
        // drop the failed band's in-flight residency pins first, so the
        // cache-log unwind below removes unpinned entries (pin
        // conservation: every pin this session took is released here)
        be.release_band_pins();
        // the aborted attempt's burnt compute is charged to the serving
        // clock via the next committed outcome's abort_time_s
        be.aborted_time_s += be.scratch.layer_compute.iter().sum::<f64>();
        // restore every mutated request's simulated state from the undo
        // log (no clones were taken); a released (evicted) victim is
        // simply gone
        for &(id, len, armed) in &be.scratch.touched {
            if let Some(r) = be.reqs.get_mut(&id) {
                r.len = len;
                if armed {
                    r.selection.rollback_txn();
                    r.ws.rollback_txn();
                }
            }
        }
        be.scratch.touched.clear();
        // undo residency churn in reverse order; re-inserting an evicted
        // group is free in the simulator (residency is bookkeeping only)
        while let Some((inserted, evicted)) = be.scratch.cache_log.pop() {
            be.cache.remove(&inserted);
            if let Some(ev) = evicted {
                // an evicted key is restorable while its owner lives — a
                // request for private keys, a prefix namespace for shared
                let live = be.reqs.contains_key(&ev.req)
                    || be.prefix_refs.contains_key(&ev.req);
                if live && !be.cache.contains(&ev) {
                    be.cache.insert(ev, ());
                }
            }
        }
        // prefetch stages survive the rollback (pre-existing groups; the
        // retried batch consumes them) — staged_groups counters keep
        // accumulating into the retry session's commit
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn n_layers(&self) -> usize {
        self.spec().n_layers
    }

    fn register(&mut self, req: &Request) -> Result<()> {
        let budget_groups = match req.sparse_budget {
            Some(tokens) => tokens.div_ceil(self.spec().block_size).max(1),
            None => self.budget_groups(),
        };
        // shared-prefix adoption: the scheduler matched `prefix_matched`
        // prompt tokens against a prior request's path. KV for those
        // tokens already exists — seed the stored length there (prefill
        // starts past them) and join the path's residency namespace so
        // the matched groups' loads/stages are shared with every sharer.
        let shared = (self.cfg.prefix_sharing && req.prefix_matched > 0)
            .then_some(req.prefix_group)
            .flatten();
        let (prefix_ns, prefix_groups, len) = match shared {
            Some(g) => {
                let ns = PREFIX_NS | g;
                let groups = req.prefix_matched / self.spec().block_size;
                let e = self.prefix_refs.entry(ns).or_insert((0, groups));
                e.0 += 1;
                e.1 = e.1.max(groups);
                (ns, groups, req.prefix_matched)
            }
            None => (0, 0, 0),
        };
        // mix a monotone admission counter into the seed: a released id
        // reused by a later admission must NOT replay the old request's
        // selection stream
        self.admissions = self.admissions.wrapping_add(1);
        let seed = self.seed
            ^ (req.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ self.admissions.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        self.reqs.insert(
            req.id,
            SimReq {
                len,
                selection: SelectionModel::new(seed)
                    .with_bands(self.n_bands, self.cfg.sim_layer_skew),
                ws: WorkingSetTracker::new(self.cfg.ws_window)
                    .with_freq_ranking(self.cfg.prefetch_freq_ranking),
                budget_groups,
                prefix_groups,
                prefix_ns,
            },
        );
        Ok(())
    }

    fn release(&mut self, req: ReqId) {
        // drop stage pins before the entries go away (cancel mid-flight
        // must not leave the cache pinned shut)
        for key in self.prefetcher.cancel_request(req) {
            self.cache.unpin(&key);
        }
        if let Some(r) = self.reqs.remove(&req) {
            if r.prefix_groups > 0 {
                self.drop_prefix_ref(r.prefix_ns);
            }
        }
        self.cache.remove_request(req);
    }

    fn supports_prefix_sharing(&self) -> bool {
        true
    }

    fn adopt_prefix(&mut self, req: ReqId, matched_tokens: usize, group: u32) {
        // admission-time adoption: registration ran at submit, before the
        // scheduler matched the prompt, so the prefix fields land here.
        // Idempotent against the register-time path (migrated/test
        // requests arrive with the fields already set and the reference
        // already held).
        if !self.cfg.prefix_sharing || matched_tokens == 0 {
            return;
        }
        let bs = self.spec().block_size;
        let ns = PREFIX_NS | group;
        let groups = matched_tokens / bs;
        match self.reqs.get_mut(&req) {
            Some(r) if r.prefix_groups == 0 => {
                r.prefix_ns = ns;
                r.prefix_groups = groups;
                // the matched span's KV already exists on the shared
                // path: stored length starts past it (prefill skipped)
                r.len = r.len.max(matched_tokens);
            }
            _ => return,
        }
        let e = self.prefix_refs.entry(ns).or_insert((0, groups));
        e.0 += 1;
        e.1 = e.1.max(groups);
    }

    fn export_migration(&mut self, req: ReqId) -> Option<super::backend::MigrationPayload> {
        // Drain the request for re-admission elsewhere. HBM residency
        // does not travel — stage pins are cancelled and cached groups
        // dropped exactly like `release` — but the DRAM-tier KV plus the
        // selection RNG and working-set history move WHOLESALE (no
        // clone), so the target resumes the identical stream.
        let r = self.reqs.remove(&req)?;
        for key in self.prefetcher.cancel_request(req) {
            self.cache.unpin(&key);
        }
        // sharing is dropped at the migration boundary: the payload is a
        // deep copy of the FULL KV (shared prefix included), so the
        // namespace reference is returned here and the target sees a
        // fully private request
        if r.prefix_groups > 0 {
            self.drop_prefix_ref(r.prefix_ns);
        }
        self.cache.remove_request(req);
        let bs = self.spec().block_size;
        // mirror mem_stats(): the DRAM tier holds every band's groups —
        // full bytes, NOT the shared-suffix delta
        let kv_bytes = r.len.div_ceil(bs) * self.group_bytes * self.n_bands;
        Some(super::backend::MigrationPayload {
            req,
            len: r.len,
            budget_groups: r.budget_groups,
            selection: r.selection,
            ws: r.ws,
            kv_bytes,
        })
    }

    fn import_migration(&mut self, payload: super::backend::MigrationPayload) -> Result<()> {
        if self.reqs.contains_key(&payload.req) {
            anyhow::bail!(
                "migration target already serves request {}",
                payload.req
            );
        }
        // Deliberately NOT a register(): the admission counter is not
        // bumped and no seed is drawn — the payload's SelectionModel
        // resumes the source's RNG stream exactly where it stopped.
        // Migrated KV is fully private: prefix sharing never crosses
        // the cluster boundary (the payload carried full bytes).
        self.reqs.insert(
            payload.req,
            SimReq {
                len: payload.len,
                selection: payload.selection,
                ws: payload.ws,
                budget_groups: payload.budget_groups,
                prefix_groups: 0,
                prefix_ns: 0,
            },
        );
        Ok(())
    }

    fn abort_iteration(&mut self) -> f64 {
        // a rolled-back session already dropped its band pins; drain
        // defensively so an abandoned iteration can never leak one
        debug_assert!(self.scratch.band_pins.is_empty(), "band pins leaked past rollback");
        while let Some(key) = self.scratch.band_pins.pop() {
            self.cache.unpin(&key);
        }
        // the abandoned iteration's staging accounting must not leak
        // into the next committed step's outcome: retire the current
        // stages AND the deferred ones (the first end_iteration promotes
        // them, the second retires them) — otherwise the next outcome
        // would report hits/wastes for blocks no prefetch_blocks counted
        self.staged_groups = 0;
        self.staged_deferred_groups = 0;
        for _ in 0..2 {
            for key in self.prefetcher.end_iteration() {
                self.cache.unpin(&key);
            }
        }
        // the burnt compute is handed to the engine (the serving clock
        // still advances even though nothing committed)
        std::mem::take(&mut self.aborted_time_s)
    }

    fn mem_stats(&self) -> MemStats {
        let bs = self.cost.spec.block_size;
        // each request is charged its PRIVATE suffix; every live shared
        // namespace is charged exactly once — that accounting delta is
        // the capacity benefit prefix sharing exists for
        let kv_bytes: usize = self
            .reqs
            .values()
            .map(|r| {
                r.len.div_ceil(bs).saturating_sub(r.prefix_groups)
                    * self.group_bytes
                    * self.n_bands
            })
            .sum::<usize>()
            + self
                .prefix_refs
                .values()
                .map(|&(_, groups)| groups * self.group_bytes * self.n_bands)
                .sum::<usize>();
        if self.cfg.offload {
            // DRAM is home; HBM holds the LRU residency cache.
            MemStats {
                hbm_bytes_used: self.cache.len() * self.group_bytes,
                dram_bytes_used: kv_bytes,
                n_registered: self.reqs.len(),
            }
        } else {
            // vLLM semantics: every stored block is pinned in HBM.
            MemStats {
                hbm_bytes_used: kv_bytes,
                dram_bytes_used: 0,
                n_registered: self.reqs.len(),
            }
        }
    }

    fn decode_ws_bytes(&mut self, req: ReqId) -> usize {
        let group_bytes = self.group_bytes;
        let n_bands = self.n_bands;
        let spec_bs = self.spec().block_size;
        let Some(r) = self.reqs.get_mut(&req) else {
            debug_assert!(false, "decode_ws_bytes for unregistered request {req}");
            return 0;
        };
        let budget = r.budget_groups;
        if !self.cfg.sparse_attention {
            // dense attention touches the whole context (every band)
            return r.len.div_ceil(spec_bs) * group_bytes * n_bands;
        }
        if r.ws.steps_recorded() == 0 {
            // no history yet: assume the full budget is hot in every band
            return budget.min(r.len.div_ceil(spec_bs)).max(1) * group_bytes * n_bands;
        }
        // the union already counts band-groups across all bands
        r.ws.ws_blocks() * group_bytes
    }

    fn begin_step<'s>(
        &'s mut self,
        batch: &'s Batch,
        requests: &'s HashMap<ReqId, Request>,
    ) -> Result<Box<dyn StepSession + 's>> {
        let n_layers = self.spec().n_layers;
        let n_bands = self.n_bands;
        let hits_at_start = self.prefetcher.stats.hits;
        // a previous session always drains its pins at commit/rollback
        debug_assert!(self.scratch.band_pins.is_empty(), "stale band pins");
        self.release_band_pins();
        // rotate the double-buffered scratch slots (see `scratch_spare`):
        // the previous session's slot is left untouched for one more
        // iteration while the slot cleared below hosts this one
        std::mem::swap(&mut self.scratch, &mut self.scratch_spare);
        // reset the recycled per-step scratch (clear, never free)
        let s = &mut self.scratch;
        s.touched.clear();
        s.cache_log.clear();
        s.layer_compute.clear();
        s.layer_compute.resize(n_layers, 0.0);
        s.layer_miss_blocks.clear();
        s.layer_miss_blocks.resize(n_layers, 0);
        s.band_compute_per_layer.clear();
        s.band_compute_per_layer.resize(n_bands, 0.0);
        s.band_miss_groups.clear();
        s.band_miss_groups.resize(n_bands, 0);
        if s.band_sels.len() < batch.decodes.len() {
            s.band_sels.resize_with(batch.decodes.len(), Vec::new);
        }
        if s.ws_accum.len() < batch.decodes.len() {
            s.ws_accum.resize_with(batch.decodes.len(), Vec::new);
        }
        for v in &mut s.ws_accum {
            v.clear();
        }
        Ok(Box::new(SimSession {
            be: self,
            batch,
            requests,
            tokens: Vec::new(),
            chunk_band_miss: 0,
            hits_at_start,
            staged: false,
            pipelined: false,
        }))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::serving::TransferKind;
    use crate::engine::backend::drive_step;
    use crate::scheduler::Phase;
    use crate::sim::selection_clones_this_thread;
    use crate::sparse::ws_clones_this_thread;

    fn mk(cfg: ServingConfig) -> SimBackend {
        SimBackend::new(cfg, ModelSpec::lwm_7b(), HardwareSpec::a100_40gb())
    }

    /// Drive one batch through a full session with no staging hints.
    fn run(b: &mut SimBackend, batch: &Batch, reqs: &HashMap<ReqId, Request>) -> BatchOutcome {
        drive_step(b, batch, reqs, &StageHints::default()).unwrap()
    }

    fn prefill_all(b: &mut SimBackend, id: ReqId, plen: usize) -> HashMap<ReqId, Request> {
        let mut reqs = HashMap::new();
        let mut r = Request::new(id, plen, 64, 0.0);
        r.phase = Phase::Prefill;
        b.register(&r).unwrap();
        reqs.insert(id, r);
        let batch = Batch {
            decodes: vec![],
            prefill: Some(PrefillWork::Chunk { req: id, start: 0, len: plen, is_last: true }),
        };
        run(b, &batch, &reqs);
        reqs.get_mut(&id).unwrap().phase = Phase::Decode;
        reqs
    }

    #[test]
    fn decode_outputs_token_per_request() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        let out = run(&mut b, &batch, &reqs);
        assert_eq!(out.tokens, vec![(1, None)]);
        assert!(out.iter_time_s > 0.0);
    }

    /// Pipelined pricing: a twin backend pair runs the same decode
    /// stream, one synchronous and one with `StageHints::pipelined`.
    /// The pipelined twin's iteration is cheaper by exactly the hidden
    /// plan/stage share (a deep decode window hides all of it, so the
    /// bubble is zero), and the synchronous twin never reports overlap.
    #[test]
    fn pipelined_hints_charge_the_overlapped_bound() {
        use crate::engine::backend::drive_step_pipelined;
        let cfg = ServingConfig::sparseserve(2048, 2048, 32);
        let mut bs = mk(cfg.clone());
        let mut bp = mk(cfg);
        let rs = prefill_all(&mut bs, 1, 16_000);
        let rp = prefill_all(&mut bp, 1, 16_000);
        let batch = Batch { decodes: vec![1], prefill: None };
        // pipeline fill: the first decode pays its plan serially on both
        // twins and records the window the next plan can hide under
        let fill_s = run(&mut bs, &batch, &rs);
        let fill_p = run(&mut bp, &batch, &rp);
        assert_eq!(fill_p.plan_stage_hidden_s, 0.0);
        assert_eq!(fill_p.pipeline_bubble_s, 0.0);
        assert_eq!(fill_s.iter_time_s, fill_p.iter_time_s);
        let hints = StageHints { pipelined: true, ..Default::default() };
        for _ in 0..4 {
            let sync = run(&mut bs, &batch, &rs);
            let piped = drive_step_pipelined(&mut bp, &batch, &rp, &hints).unwrap();
            assert_eq!(piped.tokens, sync.tokens);
            assert!(piped.plan_stage_hidden_s > 0.0, "{piped:?}");
            assert_eq!(piped.pipeline_bubble_s, 0.0, "{piped:?}");
            // hidden + iter == the serialized bound the sync twin paid
            let serialized_s = piped.iter_time_s + piped.plan_stage_hidden_s;
            assert!((serialized_s - sync.iter_time_s).abs() < 1e-12);
            assert!(piped.iter_time_s < sync.iter_time_s);
        }
    }

    #[test]
    fn warm_cache_stops_loading() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        let first = run(&mut b, &batch, &reqs);
        assert!(first.blocks_loaded > 0, "cold start loads");
        let mut warm_loads = 0;
        for _ in 0..5 {
            warm_loads = run(&mut b, &batch, &reqs).blocks_loaded;
        }
        assert!(
            warm_loads < first.blocks_loaded / 2,
            "locality must cut loads: {warm_loads} vs {first:?}"
        );
    }

    #[test]
    fn misses_are_attributed_to_the_band_that_discovers_them() {
        // the uniform smear is gone: with K bands, a decode step's misses
        // must land in the per-layer profile at their band's layers, and
        // every band must discover SOME misses on a cold start
        let mut b = mk(ServingConfig::sparseserve_np(2048, 2048, 32));
        assert_eq!(b.n_bands, 4);
        let reqs = prefill_all(&mut b, 1, 16_000);
        let batch = Batch { decodes: vec![1], prefill: None };
        // sparselint: allow(txn-pairing) -- test drives phases by hand to read per-layer misses; commit() below closes the step
        let mut sess = b.begin_step(&batch, &reqs).unwrap();
        sess.stage(&StageHints::default());
        let mut per_layer = Vec::new();
        for layer in 0..32 {
            per_layer.push(sess.decode_layer(layer).unwrap().miss_blocks);
        }
        drop(sess.commit().unwrap());
        // cold start: every band misses its whole selection
        for band in 0..4 {
            assert!(
                per_layer[band * 8] > 0,
                "band {band} must discover its own misses: {per_layer:?}"
            );
        }
        // within a band the attribution is uniform; across band
        // boundaries it is free to differ (independent draws)
        for layer in 0..32 {
            assert_eq!(per_layer[layer], per_layer[(layer / 8) * 8], "uniform within band");
        }
    }

    #[test]
    fn steady_state_decode_iterations_are_clone_free() {
        // acceptance criterion: the decode hot path performs ZERO clones
        // of SelectionModel / WorkingSetTracker once warm (the undo-log
        // snapshots replaced the per-iteration clone snapshots). The
        // probes are thread-local, so parallel tests cannot interfere.
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 16_000);
        let batch = Batch { decodes: vec![1], prefill: None };
        for _ in 0..3 {
            run(&mut b, &batch, &reqs); // warm up
        }
        let sel0 = selection_clones_this_thread();
        let ws0 = ws_clones_this_thread();
        for _ in 0..10 {
            let out = run(&mut b, &batch, &reqs);
            assert_eq!(out.tokens.len(), 1);
        }
        assert_eq!(
            selection_clones_this_thread(),
            sel0,
            "steady-state decode cloned a SelectionModel"
        );
        assert_eq!(
            ws_clones_this_thread(),
            ws0,
            "steady-state decode cloned a WorkingSetTracker"
        );
    }

    #[test]
    fn dense_vllm_never_touches_pcie() {
        let mut b = mk(ServingConfig::vllm(2048));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        let out = run(&mut b, &batch, &reqs);
        assert_eq!(out.blocks_loaded, 0);
        assert_eq!(out.load_time_s, 0.0);
    }

    #[test]
    fn sparse_decode_iterations_are_faster_than_dense() {
        let mut s = mk(ServingConfig::vllm_s(2048, 2048));
        let mut d = mk(ServingConfig::vllm(2048));
        let rs = prefill_all(&mut s, 1, 32_000);
        let rd = prefill_all(&mut d, 1, 32_000);
        let batch = Batch { decodes: vec![1], prefill: None };
        let ts = run(&mut s, &batch, &rs).iter_time_s;
        let td = run(&mut d, &batch, &rd).iter_time_s;
        assert!(td > 1.25 * ts, "dense {td} vs sparse {ts}");
    }

    #[test]
    fn memcpy_engine_amplifies_load_time() {
        let mut flash = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
        cfg.transfer = TransferKind::Memcpy;
        let mut mem = mk(cfg);
        let rf = prefill_all(&mut flash, 1, 16_000);
        let rm = prefill_all(&mut mem, 1, 16_000);
        let batch = Batch { decodes: vec![1], prefill: None };
        let f = run(&mut flash, &batch, &rf);
        let m = run(&mut mem, &batch, &rm);
        assert_eq!(f.blocks_loaded, m.blocks_loaded);
        assert!(m.load_time_s > 3.0 * f.load_time_s);
    }

    #[test]
    fn ws_estimate_grows_with_history_and_caps_at_union() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 16_000);
        let w0 = b.decode_ws_bytes(1);
        assert!(w0 > 0);
        let batch = Batch { decodes: vec![1], prefill: None };
        for _ in 0..14 {
            run(&mut b, &batch, &reqs);
        }
        let w = b.decode_ws_bytes(1);
        // union over 12 steps >= single-step budget
        assert!(w >= w0, "w={w} w0={w0}");
        // but bounded: locality keeps it within ~3x budget
        assert!(w < 4 * w0, "w={w} w0={w0}");
    }

    #[test]
    fn sparse_budget_override_cuts_decode_cost() {
        let cfg = ServingConfig::sparseserve(2048, 2048, 32);
        let mut full = mk(cfg.clone());
        let mut small = mk(cfg);
        let reqs_f = prefill_all(&mut full, 1, 32_000);
        // same request, but submitted with a 256-token DSA budget override
        let mut r = Request::new(1, 32_000, 64, 0.0);
        r.sparse_budget = Some(256);
        r.phase = Phase::Prefill;
        small.register(&r).unwrap();
        let mut reqs_s = HashMap::new();
        reqs_s.insert(1, r);
        let prefill = Batch {
            decodes: vec![],
            prefill: Some(PrefillWork::Chunk { req: 1, start: 0, len: 32_000, is_last: true }),
        };
        run(&mut small, &prefill, &reqs_s);
        reqs_s.get_mut(&1).unwrap().phase = Phase::Decode;

        let batch = Batch { decodes: vec![1], prefill: None };
        let tf = run(&mut full, &batch, &reqs_f).iter_time_s;
        let ts = run(&mut small, &batch, &reqs_s).iter_time_s;
        assert!(tf > 2.0 * ts, "full-budget decode {tf} vs overridden {ts}");
        // the Alg. 1 working-set estimate shrinks with the override too
        assert!(small.decode_ws_bytes(1) < full.decode_ws_bytes(1));
    }

    #[test]
    fn release_clears_mem_stats() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        run(&mut b, &batch, &reqs);
        let before = b.mem_stats();
        assert!(before.dram_bytes_used > 0 && before.hbm_bytes_used > 0);
        assert_eq!(before.n_registered, 1);
        b.release(1);
        assert_eq!(b.mem_stats(), MemStats::default());
        assert_eq!(b.pinned_entries(), 0, "release must drop every pin");
    }

    #[test]
    fn reused_request_id_draws_a_fresh_selection_stream() {
        // regression: SelectionModel::new(seed ^ req.id) replayed an
        // identical RNG stream when a released id was reused; the
        // admission counter mixed into the seed must make them diverge
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let r = Request::new(7, 8192, 64, 0.0);
        b.register(&r).unwrap();
        let first: Vec<Vec<u32>> = (0..4)
            .map(|_| b.reqs.get_mut(&7).unwrap().selection.next_selection(512, 32))
            .collect();
        b.release(7);
        b.register(&r).unwrap();
        let second: Vec<Vec<u32>> = (0..4)
            .map(|_| b.reqs.get_mut(&7).unwrap().selection.next_selection(512, 32))
            .collect();
        assert_ne!(first, second, "reused id must not replay the old stream");
    }

    /// Backend with a deliberately small HBM cache (`groups`
    /// iteration-granular block groups, i.e. `groups * n_bands`
    /// band-group slots) to create eviction pressure — the regime the
    /// prefetcher exists for.
    fn mk_pressured(cfg: ServingConfig, groups: usize) -> SimBackend {
        let spec = ModelSpec::lwm_7b();
        let mut hw = HardwareSpec::a100_40gb();
        hw.hbm_kv_bytes = groups * spec.n_layers * spec.n_kv_heads * spec.block_bytes();
        SimBackend::new(cfg, spec, hw)
    }

    fn prefill_two(b: &mut SimBackend, plen: usize) -> HashMap<ReqId, Request> {
        let mut reqs = HashMap::new();
        for id in 1..=2u32 {
            let mut r = Request::new(id, plen, 512, 0.0);
            r.phase = Phase::Prefill;
            b.register(&r).unwrap();
            reqs.insert(id, r);
            let batch = Batch {
                decodes: vec![],
                prefill: Some(PrefillWork::Chunk { req: id, start: 0, len: plen, is_last: true }),
            };
            run(b, &batch, &reqs);
            reqs.get_mut(&id).unwrap().phase = Phase::Decode;
        }
        reqs
    }

    #[test]
    fn prefetch_stages_blocks_and_earns_hits() {
        // under cache pressure the prefetcher must stage work and convert
        // would-be misses into hits
        let mut b = mk_pressured(ServingConfig::sparseserve(2048, 2048, 32), 96);
        let reqs = prefill_two(&mut b, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        // first iteration builds working-set history (nothing to rank yet)
        run(&mut b, &batch, &reqs);
        let mut staged_total = 0usize;
        let mut hits_total = 0usize;
        for _ in 0..8 {
            let out = run(&mut b, &batch, &reqs);
            staged_total += out.prefetch_blocks;
            hits_total += out.prefetch_hits;
        }
        assert!(staged_total > 0, "pressure must trigger staging");
        assert!(hits_total > 0, "staged blocks must become hits");
        assert!(b.prefetch_stats().hits > 0);
    }

    #[test]
    fn no_prefetch_ablation_stalls_strictly_more() {
        // acceptance criterion: equal workload, prefetch off must show
        // strictly more stall time than prefetch on. Pinned to the
        // coarse model: the assertion is about prefetch accounting, not
        // the per-layer overlap model (covered separately below).
        let mut cfg_pf = ServingConfig::sparseserve(2048, 2048, 32);
        cfg_pf.iter_model = IterModel::Coarse;
        let mut cfg_np = ServingConfig::sparseserve_np(2048, 2048, 32);
        cfg_np.iter_model = IterModel::Coarse;
        let mut pf = mk_pressured(cfg_pf, 96);
        let mut np = mk_pressured(cfg_np, 96);
        let rp = prefill_two(&mut pf, 16_000);
        let rn = prefill_two(&mut np, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        let (mut stall_pf, mut stall_np) = (0.0, 0.0);
        let (mut toks_pf, mut toks_np) = (0usize, 0usize);
        for _ in 0..24 {
            let o = run(&mut pf, &batch, &rp);
            stall_pf += o.stall_time_s;
            toks_pf += o.tokens.len();
            let o = run(&mut np, &batch, &rn); // config off -> staging no-ops
            stall_np += o.stall_time_s;
            toks_np += o.tokens.len();
        }
        assert_eq!(toks_pf, toks_np, "equal workload");
        assert!(
            stall_np > stall_pf,
            "no-prefetch must stall strictly more: np={stall_np} pf={stall_pf}"
        );
    }

    #[test]
    fn per_layer_model_overlaps_misses_with_later_layers() {
        // acceptance criterion: on a miss-heavy workload, layer-N demand
        // misses overlap later layers' compute — strictly less stall
        // than the coarse model charges for identical traffic
        let mut cfg_l = ServingConfig::sparseserve_np(2048, 2048, 32);
        cfg_l.iter_model = IterModel::PerLayer;
        let mut cfg_c = cfg_l.clone();
        cfg_c.iter_model = IterModel::Coarse;
        let mut bl = mk_pressured(cfg_l, 96);
        let mut bc = mk_pressured(cfg_c, 96);
        let rl = prefill_two(&mut bl, 16_000);
        let rc = prefill_two(&mut bc, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        let (mut stall_l, mut stall_c) = (0.0, 0.0);
        let (mut loads_l, mut loads_c) = (0usize, 0usize);
        let (mut iter_l, mut iter_c) = (0.0, 0.0);
        for _ in 0..16 {
            let o = run(&mut bl, &batch, &rl);
            stall_l += o.stall_time_s;
            loads_l += o.blocks_loaded;
            iter_l += o.iter_time_s;
            // the per-layer run reports the coarse counterfactual too
            assert!(o.stall_time_s <= o.coarse_stall_time_s + 1e-12);
            let o = run(&mut bc, &batch, &rc);
            stall_c += o.stall_time_s;
            loads_c += o.blocks_loaded;
            iter_c += o.iter_time_s;
        }
        assert_eq!(loads_l, loads_c, "identical traffic");
        assert!(loads_l > 0, "workload must be miss-heavy");
        assert!(
            stall_l < stall_c,
            "per-layer overlap must tighten stall: layered={stall_l} coarse={stall_c}"
        );
        assert!(iter_l < iter_c, "iterations must tighten too");
    }

    #[test]
    fn layer_skew_moves_stall_early_vs_late_at_equal_totals() {
        // acceptance criterion: per-layer stall must vary monotonically
        // with the layer-skew knob — misses concentrated in EARLY layers
        // keep the copy stream busy under the remaining layers' compute
        // and stall strictly less than the same miss volume concentrated
        // in LATE layers (the stream idles, then the copies land past
        // the compute window). Three decodes under heavy cache pressure
        // put per-iteration demand in the same regime as compute, where
        // discovery timing matters.
        let run_skewed = |skew: f64| -> (f64, usize) {
            let mut cfg = ServingConfig::sparseserve_np(2048, 2048, 32);
            cfg.ws_batch_control = false;
            cfg.sim_layer_skew = skew;
            // 224 band slots: 3 x 64 in-flight pins fit, but the window
            // union (~1000 band-groups) thrashes hard
            let mut b = mk_pressured(cfg, 56);
            let mut reqs = HashMap::new();
            for id in 1..=3u32 {
                let mut r = Request::new(id, 16_000, 512, 0.0);
                r.phase = Phase::Prefill;
                b.register(&r).unwrap();
                reqs.insert(id, r);
                let batch = Batch {
                    decodes: vec![],
                    prefill: Some(PrefillWork::Chunk {
                        req: id, start: 0, len: 16_000, is_last: true,
                    }),
                };
                run(&mut b, &batch, &reqs);
                reqs.get_mut(&id).unwrap().phase = Phase::Decode;
            }
            let batch = Batch { decodes: vec![1, 2, 3], prefill: None };
            let (mut stall, mut loads) = (0.0, 0usize);
            for _ in 0..30 {
                let o = run(&mut b, &batch, &reqs);
                stall += o.stall_time_s;
                loads += o.blocks_loaded;
            }
            (stall, loads)
        };
        let (stall_early, loads_early) = run_skewed(-1.0);
        let (stall_flat, loads_flat) = run_skewed(0.0);
        let (stall_late, loads_late) = run_skewed(1.0);
        // equal totals: the skew tilt preserves aggregate churn, so the
        // three runs move comparable traffic
        let max_loads = loads_early.max(loads_flat).max(loads_late) as f64;
        let min_loads = loads_early.min(loads_flat).min(loads_late) as f64;
        assert!(min_loads > 0.0, "workload must be miss-heavy");
        assert!(
            max_loads / min_loads < 1.5,
            "skew must not change miss totals: {loads_early} {loads_flat} {loads_late}"
        );
        // strict endpoint ordering; flat sits between the tilts (ties
        // with early allowed: once the stream saturates from the first
        // band they price identically)
        assert!(
            stall_late > stall_early * 1.02 + 1e-4,
            "late-skewed misses must stall strictly more: \
             early={stall_early} flat={stall_flat} late={stall_late}"
        );
        assert!(
            stall_early <= stall_flat + 0.05 * stall_late + 1e-9,
            "early must not exceed flat: {stall_early} vs {stall_flat}"
        );
        assert!(
            stall_flat <= stall_late + 1e-9,
            "flat must not exceed late: {stall_flat} vs {stall_late}"
        );
    }

    #[test]
    fn unused_stages_are_accounted_as_wasted() {
        let mut b = mk_pressured(ServingConfig::sparseserve(2048, 2048, 32), 96);
        let reqs = prefill_two(&mut b, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        run(&mut b, &batch, &reqs); // build history
        // cross-iteration hints on an idle batch: stages are deferred...
        let idle = Batch { decodes: vec![], prefill: None };
        let hints = StageHints { next_decodes: vec![1, 2], ..Default::default() };
        let out = drive_step(&mut b, &idle, &reqs, &hints).unwrap();
        assert!(out.prefetch_blocks > 0, "hints must stage");
        assert_eq!(out.prefetch_deferred, out.prefetch_blocks);
        assert_eq!(out.prefetch_wasted, 0, "deferred stages are not wasted yet");
        // ...an idle follow-up iteration never touches them -> wasted now
        let out2 = drive_step(&mut b, &idle, &reqs, &StageHints::default()).unwrap();
        assert!(out2.prefetch_wasted > 0);
        assert!(b.prefetch_stats().wasted > 0);
        assert_eq!(b.pinned_entries(), 0, "retired stages must drop their pins");
        // wasted stages were unpinned: later batches keep running normally
        run(&mut b, &batch, &reqs);
    }

    #[test]
    fn cross_iteration_hints_become_next_iteration_hits() {
        let mut b = mk_pressured(ServingConfig::sparseserve(2048, 2048, 32), 96);
        let reqs = prefill_two(&mut b, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        run(&mut b, &batch, &reqs); // build history
        // stage NEXT iteration's working sets under an idle batch
        let idle = Batch { decodes: vec![], prefill: None };
        let hints = StageHints { next_decodes: vec![1, 2], ..Default::default() };
        let staged = drive_step(&mut b, &idle, &reqs, &hints).unwrap().prefetch_deferred;
        assert!(staged > 0);
        let hits_before = b.prefetch_stats().hits;
        run(&mut b, &batch, &reqs);
        assert!(
            b.prefetch_stats().hits > hits_before,
            "cross-iteration stages must earn hits in the following batch"
        );
    }

    #[test]
    fn release_cancels_stage_pins() {
        let mut b = mk_pressured(ServingConfig::sparseserve(2048, 2048, 32), 96);
        let reqs = prefill_two(&mut b, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        run(&mut b, &batch, &reqs);
        // stage for a batch, then release mid-flight: stage pins must be
        // released with the requests
        let idle = Batch { decodes: vec![], prefill: None };
        let hints = StageHints { next_decodes: vec![1, 2], ..Default::default() };
        let staged = drive_step(&mut b, &idle, &reqs, &hints).unwrap().prefetch_blocks;
        assert!(staged > 0);
        b.release(1);
        b.release(2);
        assert!(b.prefetch_stats().cancelled > 0, "cancel must drop stages");
        assert_eq!(b.mem_stats(), MemStats::default());
        assert_eq!(b.pinned_entries(), 0, "cancelled stages must drop their pins");
        // a fresh request can use the full cache again (nothing pinned)
        let reqs2 = prefill_all(&mut b, 9, 16_000);
        let b9 = Batch { decodes: vec![9], prefill: None };
        run(&mut b, &b9, &reqs2);
    }

    #[test]
    fn layer_segmented_prefill_avoids_cache_traffic() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let mut r = Request::new(1, 8192, 8, 0.0);
        r.phase = Phase::Prefill;
        b.register(&r).unwrap();
        let mut reqs = HashMap::new();
        reqs.insert(1, r);
        for layer in 0..32 {
            let batch = Batch {
                decodes: vec![],
                prefill: Some(PrefillWork::LayerSegment {
                    req: 1, layer_start: layer, layer_end: layer + 1,
                    tok_start: 0, tok_len: 8192, is_last: layer == 31,
                }),
            };
            let out = run(&mut b, &batch, &reqs);
            assert_eq!(out.blocks_loaded, 0);
        }
        assert_eq!(b.reqs[&1].len, 8192);
    }

    #[test]
    fn layer_segment_exceeding_single_layer_hbm_bound_is_typed() {
        // an HBM so small that even ONE layer of the segment cannot fit:
        // the session must fail typed (HbmExhausted names the victim),
        // and rollback must leave the request's state untouched
        let mut b = mk_pressured(ServingConfig::sparseserve(2048, 2048, 32), 4);
        let mut r = Request::new(1, 200_000, 8, 0.0);
        r.phase = Phase::Prefill;
        b.register(&r).unwrap();
        let mut reqs = HashMap::new();
        reqs.insert(1, r);
        let batch = Batch {
            decodes: vec![],
            prefill: Some(PrefillWork::LayerSegment {
                req: 1, layer_start: 0, layer_end: 1,
                tok_start: 0, tok_len: 200_000, is_last: false,
            }),
        };
        let err = drive_step(&mut b, &batch, &reqs, &StageHints::default()).unwrap_err();
        let me = err.downcast_ref::<MemoryError>().expect("typed memory error");
        assert_eq!(me.req(), 1);
        assert_eq!(b.reqs[&1].len, 0, "rollback leaves KV untouched");
    }

    #[test]
    fn decode_band_exceeding_hbm_is_typed_mid_decode_and_charges_abort() {
        // the tentpole's bug fix: the decode phase itself is now
        // fallible. A batch whose per-band working set cannot fit HBM
        // must fail typed MID-decode — after compute has been burnt — so
        // rollback charges nonzero abort time (previously the sim's only
        // fallible phase preceded decode compute and abort time was
        // provably zero).
        let mut cfg = ServingConfig::sparseserve_np(2048, 2048, 32);
        cfg.ws_batch_control = false;
        // 3 decodes x 64 band-groups = 192 > 40 * 4 = 160 band slots
        let mut b = mk_pressured(cfg, 40);
        let mut reqs = HashMap::new();
        for id in 1..=3u32 {
            let mut r = Request::new(id, 16_000, 512, 0.0);
            r.phase = Phase::Prefill;
            b.register(&r).unwrap();
            reqs.insert(id, r);
            let batch = Batch {
                decodes: vec![],
                prefill: Some(PrefillWork::Chunk { req: id, start: 0, len: 16_000, is_last: true }),
            };
            run(&mut b, &batch, &reqs);
            reqs.get_mut(&id).unwrap().phase = Phase::Decode;
        }
        let pinned_before = b.pinned_entries();
        let batch = Batch { decodes: vec![1, 2, 3], prefill: None };
        let err = drive_step(&mut b, &batch, &reqs, &StageHints::default()).unwrap_err();
        let me = err.downcast_ref::<MemoryError>().expect("typed memory error");
        assert!(matches!(me, MemoryError::HbmExhausted { .. }));
        assert_eq!(
            b.pinned_entries(),
            pinned_before,
            "rollback must conserve cache pins"
        );
        // the failing band was already computing: its burnt time must
        // surface as abort_time_s on the next committed step
        let survivors = Batch { decodes: vec![1, 2], prefill: None };
        let out = run(&mut b, &survivors, &reqs);
        assert!(out.abort_time_s > 0.0, "mid-decode abort must charge burnt compute");
        assert_eq!(out.tokens.len(), 2);
    }

    #[test]
    fn session_rollback_restores_sim_state_and_mem_stats() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        run(&mut b, &batch, &reqs); // warm one iteration
        let stats_before = b.mem_stats();
        let len_before = b.reqs[&1].len;
        let pinned_before = b.pinned_entries();

        // drive phases by hand, then roll back instead of committing
        // sparselint: allow(txn-pairing) -- rollback() below closes the step; the test exists to observe the rollback
        let mut sess = b.begin_step(&batch, &reqs).unwrap();
        sess.stage(&StageHints::default());
        for layer in 0..32 {
            sess.decode_layer(layer).unwrap();
        }
        sess.rollback();

        assert_eq!(b.reqs[&1].len, len_before, "KV length restored");
        assert_eq!(b.mem_stats().dram_bytes_used, stats_before.dram_bytes_used);
        // pin conservation: rollback drops every pin the session took,
        // keeping only pre-existing prefetch-stage pins (stages survive)
        assert!(
            b.pinned_entries() >= pinned_before,
            "pre-existing stage pins must survive rollback"
        );
        b.abort_iteration();
        assert_eq!(b.pinned_entries(), 0, "no pin survives an aborted iteration");
        // a committed re-run after rollback behaves like a fresh step
        let out = run(&mut b, &batch, &reqs);
        assert_eq!(out.tokens, vec![(1, None)]);
        assert_eq!(b.reqs[&1].len, len_before + 1);
    }

    #[test]
    fn undo_log_rollback_matches_clone_snapshot_byte_for_byte() {
        // rollback-equivalence: the incremental undo logs must restore
        // exactly what the old clone-snapshot path restored — identical
        // working-set state AND an identical future selection sequence
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 16_000);
        let batch = Batch { decodes: vec![1], prefill: None };
        for _ in 0..4 {
            run(&mut b, &batch, &reqs); // build history
        }
        // the old path: clone the whole per-request state up front
        let sel_snapshot = b.reqs[&1].selection.clone();
        let ws_snapshot = b.reqs[&1].ws.clone();
        let len_snapshot = b.reqs[&1].len;
        let pins_snapshot = b.pinned_entries();

        // sparselint: allow(txn-pairing) -- rollback-equivalence test: rollback() below closes the step
        let mut sess = b.begin_step(&batch, &reqs).unwrap();
        sess.stage(&StageHints::default());
        for layer in 0..32 {
            sess.decode_layer(layer).unwrap();
        }
        sess.rollback();

        assert_eq!(b.reqs[&1].len, len_snapshot);
        assert_eq!(b.reqs[&1].ws.steps_recorded(), ws_snapshot.steps_recorded());
        assert_eq!(b.reqs[&1].ws.ranked_blocks(), ws_snapshot.ranked_blocks());
        // identical future draws prove the RNG/pools were restored exactly
        let mut restored = b.reqs[&1].selection.clone();
        let mut reference = sel_snapshot;
        for _ in 0..5 {
            assert_eq!(
                restored.next_selection(1000, 64),
                reference.next_selection(1000, 64),
                "selection state diverged from the clone snapshot"
            );
        }
        assert!(
            b.pinned_entries() >= pins_snapshot,
            "rollback must conserve pre-existing stage pins"
        );

        // --- part 2: the same equivalence under a MID-decode typed
        // failure (the fallible path this PR adds): surviving
        // batch-mates must replay byte-identically on the retry
        let mut cfg = ServingConfig::sparseserve_np(2048, 2048, 32);
        cfg.ws_batch_control = false;
        let mut b = mk_pressured(cfg, 40); // 160 band slots < 3 x 64
        let mut reqs = HashMap::new();
        for id in 1..=3u32 {
            let mut r = Request::new(id, 16_000, 512, 0.0);
            r.phase = Phase::Prefill;
            b.register(&r).unwrap();
            reqs.insert(id, r);
            let batch = Batch {
                decodes: vec![],
                prefill: Some(PrefillWork::Chunk { req: id, start: 0, len: 16_000, is_last: true }),
            };
            run(&mut b, &batch, &reqs);
            reqs.get_mut(&id).unwrap().phase = Phase::Decode;
        }
        let snapshots: Vec<(SelectionModel, usize)> = (1..=3u32)
            .map(|id| (b.reqs[&id].selection.clone(), b.reqs[&id].len))
            .collect();
        let pinned_before = b.pinned_entries();
        let batch = Batch { decodes: vec![1, 2, 3], prefill: None };
        drive_step(&mut b, &batch, &reqs, &StageHints::default())
            .expect_err("oversubscribed band must fault");
        assert_eq!(b.pinned_entries(), pinned_before, "pin conservation");
        for (i, (snap_sel, snap_len)) in snapshots.into_iter().enumerate() {
            let id = (i + 1) as u32;
            assert_eq!(b.reqs[&id].len, snap_len, "req {id} KV length restored");
            let mut restored = b.reqs[&id].selection.clone();
            let mut reference = snap_sel;
            for _ in 0..4 {
                assert_eq!(
                    restored.next_selection(500, 64),
                    reference.next_selection(500, 64),
                    "req {id} selection must replay byte-identically"
                );
            }
        }
    }

    #[test]
    fn migrated_mid_decode_request_replays_byte_identically() {
        // part 3 of the rollback-equivalence harness: a DRAIN must be as
        // exact as a rollback. A request migrated mid-decode carries its
        // SelectionModel + WorkingSetTracker wholesale, so its future
        // selection stream at the target is byte-identical to the
        // unmigrated counterfactual's.
        let cfg = ServingConfig::sparseserve(2048, 2048, 32);
        let mut src = mk(cfg.clone());
        let reqs = prefill_all(&mut src, 1, 16_000);
        let batch = Batch { decodes: vec![1], prefill: None };
        for _ in 0..4 {
            run(&mut src, &batch, &reqs); // mid-decode with history
        }
        // the unmigrated counterfactual: clone the state that would have
        // kept running at the source
        let mut ref_sel = src.reqs[&1].selection.clone();
        let ref_len = src.reqs[&1].len;
        let ref_ws_steps = src.reqs[&1].ws.steps_recorded();
        let ref_ws_blocks = src.reqs[&1].ws.ranked_blocks();

        let payload = src.export_migration(1).expect("live decode must drain");
        assert_eq!(payload.req, 1);
        assert_eq!(payload.len, ref_len);
        assert!(payload.kv_bytes > 0, "mid-decode KV must have DRAM bytes");
        assert_eq!(src.mem_stats(), MemStats::default(), "source fully drained");
        assert_eq!(src.pinned_entries(), 0, "drain must leave no pin behind");
        assert!(src.export_migration(1).is_none(), "double drain refused");

        let mut dst = mk(cfg);
        dst.import_migration(payload).unwrap();
        assert_eq!(dst.reqs[&1].len, ref_len);
        assert_eq!(dst.reqs[&1].ws.steps_recorded(), ref_ws_steps);
        assert_eq!(dst.reqs[&1].ws.ranked_blocks(), ref_ws_blocks);
        // identical future draws prove the RNG stream moved exactly —
        // the monotone-counter seed was preserved, not redrawn
        let mut migrated = dst.reqs[&1].selection.clone();
        for _ in 0..5 {
            assert_eq!(
                migrated.next_selection(1000, 64),
                ref_sel.next_selection(1000, 64),
                "migrated selection stream diverged from the unmigrated run"
            );
        }
        // the request keeps decoding at the target
        let out = run(&mut dst, &batch, &reqs);
        assert_eq!(out.tokens, vec![(1, None)]);
        assert_eq!(dst.reqs[&1].len, ref_len + 1);
        // a second import onto the now-live id must refuse, handing the
        // payload back intact via the error path
        let clash = super::backend::MigrationPayload {
            req: 1,
            len: 8,
            budget_groups: 1,
            selection: SelectionModel::new(9),
            ws: WorkingSetTracker::new(4),
            kv_bytes: 0,
        };
        assert!(dst.import_migration(clash).is_err(), "id collision refused");
    }

    #[test]
    fn drain_conserves_pins_of_surviving_requests() {
        // pin conservation across the drain: exporting one request under
        // active prefetch staging must cancel ONLY the victim's stage
        // pins — the survivor's stages stay pinned and still earn hits
        let mut b = mk_pressured(ServingConfig::sparseserve(2048, 2048, 32), 96);
        let reqs = prefill_two(&mut b, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        run(&mut b, &batch, &reqs); // build working-set history
        // stage both requests' working sets under an idle batch
        let idle = Batch { decodes: vec![], prefill: None };
        let hints = StageHints { next_decodes: vec![1, 2], ..Default::default() };
        let staged = drive_step(&mut b, &idle, &reqs, &hints).unwrap().prefetch_blocks;
        assert!(staged > 0, "pressure must trigger staging");
        let pins_before = b.pinned_entries();
        assert!(pins_before > 0, "stages must hold pins");

        let cancelled_before = b.prefetch_stats().cancelled;
        let payload = b.export_migration(1).expect("staged request must drain");
        assert!(payload.kv_bytes > 0);
        assert!(
            b.prefetch_stats().cancelled > cancelled_before,
            "the victim's in-flight stages must be cancelled by the drain"
        );
        let pins_after = b.pinned_entries();
        assert!(pins_after < pins_before, "victim pins must drop");
        assert!(pins_after > 0, "survivor stage pins must be conserved");
        // the survivor keeps decoding and consumes its surviving stages
        let hits_before = b.prefetch_stats().hits;
        let b2 = Batch { decodes: vec![2], prefill: None };
        let out = run(&mut b, &b2, &reqs);
        assert_eq!(out.tokens, vec![(2, None)]);
        assert!(
            b.prefetch_stats().hits > hits_before,
            "surviving stages must still earn hits after the drain"
        );
        b.release(2);
        assert_eq!(b.pinned_entries(), 0, "no pin outlives its request");
    }

    #[test]
    fn rolled_back_compute_is_charged_as_abort_time() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        run(&mut b, &batch, &reqs); // warm
        // drive decode phases, then abort: the burnt compute must surface
        // on the NEXT committed outcome (the engine adds it to the clock)
        // sparselint: allow(txn-pairing) -- rollback() below closes the step; the abort charge is the assertion target
        let mut sess = b.begin_step(&batch, &reqs).unwrap();
        sess.stage(&StageHints::default());
        for layer in 0..32 {
            sess.decode_layer(layer).unwrap();
        }
        sess.rollback();
        let out = run(&mut b, &batch, &reqs);
        assert!(out.abort_time_s > 0.0, "aborted compute must be charged");
        // ...and only once
        let out2 = run(&mut b, &batch, &reqs);
        assert_eq!(out2.abort_time_s, 0.0, "abort charge must not persist");
        // an abandoned iteration hands the charge to abort_iteration
        // sparselint: allow(txn-pairing) -- rollback() + abort_iteration() below close the step
        let mut sess = b.begin_step(&batch, &reqs).unwrap();
        sess.stage(&StageHints::default());
        for layer in 0..32 {
            sess.decode_layer(layer).unwrap();
        }
        sess.rollback();
        assert!(b.abort_iteration() > 0.0);
        assert_eq!(b.pinned_entries(), 0, "abort_iteration must drop all pins");
        assert_eq!(run(&mut b, &batch, &reqs).abort_time_s, 0.0);
    }

    // ------------------------------------------ cross-request prefix sharing

    /// Register `id` as admitted with `matched` prompt tokens covered by
    /// shared path `group` (what the scheduler's admission match sets).
    fn register_sharer(
        b: &mut SimBackend,
        reqs: &mut HashMap<ReqId, Request>,
        id: ReqId,
        plen: usize,
        matched: usize,
        group: u32,
    ) {
        let mut r = Request::new(id, plen, 64, 0.0);
        r.prefix_matched = matched;
        r.prefix_group = Some(group);
        r.tokens_done = matched;
        r.phase = Phase::Decode;
        b.register(&r).unwrap();
        reqs.insert(id, r);
    }

    fn sharing_cfg() -> ServingConfig {
        let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
        cfg.prefix_sharing = true;
        cfg
    }

    #[test]
    fn shared_namespace_is_charged_once_and_dies_with_its_last_sharer() {
        let mut b = mk(sharing_cfg());
        let bs = b.spec().block_size;
        let matched = 32 * bs;
        let mut reqs = HashMap::new();
        register_sharer(&mut b, &mut reqs, 1, matched + bs, matched, 7);
        let one = b.mem_stats().dram_bytes_used;
        assert!(one > 0, "the shared prefix KV is charged");
        register_sharer(&mut b, &mut reqs, 2, matched + bs, matched, 7);
        // the second sharer adds NO bytes: its prefix region is the same
        // namespace, and its private suffix has not been prefilled yet
        assert_eq!(b.mem_stats().dram_bytes_used, one, "shared region charged once");
        // the first release keeps the namespace alive for the survivor...
        b.release(1);
        assert_eq!(b.mem_stats().dram_bytes_used, one);
        // ...and the last one tears it down
        b.release(2);
        assert_eq!(b.mem_stats(), MemStats::default());
    }

    #[test]
    fn one_sharers_demand_load_is_every_sharers_hit() {
        let mut cfg = sharing_cfg();
        cfg.prefetch = false; // isolate the demand path
        let mut b = mk(cfg);
        let bs = b.spec().block_size;
        // context well under the DSA budget: selection deterministically
        // covers every group, so the sharers' working sets are identical
        let matched = b.budget_groups().min(24) * bs;
        let mut reqs = HashMap::new();
        register_sharer(&mut b, &mut reqs, 1, matched + bs, matched, 3);
        register_sharer(&mut b, &mut reqs, 2, matched + bs, matched, 3);
        let cold = run(&mut b, &Batch { decodes: vec![1], prefill: None }, &reqs);
        assert!(cold.blocks_loaded > 0, "first sharer pays the demand loads");
        let warm = run(&mut b, &Batch { decodes: vec![2], prefill: None }, &reqs);
        assert_eq!(warm.blocks_loaded, 0, "second sharer rides shared residency");
    }

    #[test]
    fn sharing_off_keys_stay_private_and_pay_their_own_loads() {
        // the control for the test above: identical setup minus the knob
        let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
        cfg.prefetch = false;
        let mut b = mk(cfg);
        let bs = b.spec().block_size;
        let matched = b.budget_groups().min(24) * bs;
        let mut reqs = HashMap::new();
        for id in 1..=2u32 {
            // prefix fields are set, but the knob is off: ignored
            register_sharer(&mut b, &mut reqs, id, matched + bs, matched, 3);
            // without sharing nothing seeds the stored length; simulate
            // the finished prefill so decode has KV to select over
            b.reqs.get_mut(&id).unwrap().len = matched;
        }
        let cold = run(&mut b, &Batch { decodes: vec![1], prefill: None }, &reqs);
        let second = run(&mut b, &Batch { decodes: vec![2], prefill: None }, &reqs);
        assert!(cold.blocks_loaded > 0);
        assert_eq!(
            second.blocks_loaded, cold.blocks_loaded,
            "private keys cannot share residency"
        );
    }

    #[test]
    fn export_migration_drops_sharing_and_carries_full_bytes() {
        let mut b = mk(sharing_cfg());
        let bs = b.spec().block_size;
        let matched = 16 * bs;
        let mut reqs = HashMap::new();
        register_sharer(&mut b, &mut reqs, 1, matched + bs, matched, 5);
        register_sharer(&mut b, &mut reqs, 2, matched + bs, matched, 5);
        let shared_bytes = b.mem_stats().dram_bytes_used;
        let payload = b.export_migration(2).expect("sharer must export");
        // the payload deep-copies the FULL KV, shared prefix included —
        // the target pays full freight (cluster reservations match)
        assert_eq!(payload.kv_bytes, shared_bytes, "full bytes, not the delta");
        // the donor side keeps the namespace for the survivor
        assert_eq!(b.mem_stats().dram_bytes_used, shared_bytes);
        let mut dst = mk(sharing_cfg());
        dst.import_migration(payload).unwrap();
        // fully private on the far side: charged as plain KV
        assert_eq!(dst.mem_stats().dram_bytes_used, shared_bytes);
        b.release(1);
        assert_eq!(b.mem_stats().dram_bytes_used, 0);
        dst.release(2);
        assert_eq!(dst.mem_stats().dram_bytes_used, 0);
    }
}
