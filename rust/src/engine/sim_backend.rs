//! Paper-scale simulated backend.
//!
//! Executes batches against the analytic cost model (sim::cost) and the
//! Fig. 8-calibrated synthetic selection process (sim::selection), while
//! sharing the *real* scheduler, LRU-cache accounting, working-set and
//! prefetch machinery with the PJRT backend. Selection/caching
//! granularity is the block-index *group* (one group = that block index
//! across all layers and KV heads); cost accounting multiplies back to
//! per-head blocks.
//!
//! Load/compute overlap is *earned*, not assumed: before each decode
//! batch the prefetcher stages the recency-ranked working-set union of
//! every scheduled request (`Backend::prefetch`), and the iteration's
//! stall is computed by the two-stream event model
//! ([`crate::sim::two_stream_iter`]) from the bytes actually staged
//! ahead of need vs the misses discovered at selection time.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::{HardwareSpec, ModelSpec, ServingConfig};
use crate::memory::{BlockKey, LruCache, PrefetchEngine, ReqId};
use crate::scheduler::{Batch, PrefillWork, Request};
use crate::sim::{two_stream_iter, CostModel, SelectionModel};
use crate::sparse::WorkingSetTracker;

use super::backend::{Backend, BatchOutcome, MemStats};

struct SimReq {
    /// Tokens with stored KV.
    len: usize,
    selection: SelectionModel,
    ws: WorkingSetTracker,
    /// DSA budget in block groups (per-request override or the config
    /// default).
    budget_groups: usize,
}

pub struct SimBackend {
    pub cfg: ServingConfig,
    pub cost: CostModel,
    /// HBM residency cache at block-group granularity.
    cache: LruCache<()>,
    reqs: HashMap<ReqId, SimReq>,
    /// per-head blocks represented by one cached group.
    group_blocks: usize,
    group_bytes: usize,
    seed: u64,
    /// Working-set staging bookkeeping (group granularity).
    prefetcher: PrefetchEngine,
    /// Groups staged by the last `prefetch()` call, consumed by the next
    /// `run_batch` (their PCIe time overlaps that batch's compute).
    staged_groups: usize,
    /// Cumulative counters.
    pub total_blocks_loaded: u64,
}

impl SimBackend {
    pub fn new(cfg: ServingConfig, spec: ModelSpec, hw: HardwareSpec) -> Self {
        let group_blocks = spec.n_layers * spec.n_kv_heads;
        let group_bytes = group_blocks * spec.block_bytes();
        let capacity = (hw.hbm_kv_bytes / group_bytes).max(1);
        Self {
            cfg,
            cost: CostModel::new(spec, hw),
            cache: LruCache::new(capacity),
            reqs: HashMap::new(),
            group_blocks,
            group_bytes,
            seed: 0x51,
            prefetcher: PrefetchEngine::new(0), // no real bytes to copy
            staged_groups: 0,
            total_blocks_loaded: 0,
        }
    }

    fn spec(&self) -> &ModelSpec {
        &self.cost.spec
    }

    pub fn hbm_capacity_bytes(&self) -> usize {
        self.cache.capacity() * self.group_bytes
    }

    /// Reference decode iteration (SLO unit).
    pub fn decode_iter_ref(&self) -> f64 {
        let kv = if self.cfg.sparse_attention {
            self.cfg.token_budget.min(self.spec().max_ctx)
        } else {
            self.spec().max_ctx / 2
        };
        self.cost.decode_iter_ref(kv)
    }

    fn budget_groups(&self) -> usize {
        self.cfg.budget_blocks(self.spec().block_size)
    }

    /// Touch the cache for a request's selected groups; returns misses.
    /// Hits on staged groups consume their prefetch pin (the staged
    /// bytes already paid for the transfer on the overlapped stream).
    fn touch_groups(&mut self, req: ReqId, groups: &[u32]) -> usize {
        let mut misses = 0;
        for &g in groups {
            let key = BlockKey::new(req, 0, 0, g);
            if self.cache.get(&key).is_some() {
                if self.prefetcher.note_access(&key) {
                    self.cache.unpin(&key);
                }
            } else {
                misses += 1;
                // residency only when the cache can take it without
                // evicting a pinned stage (a skipped insert still pays
                // the demand load)
                if self.cache.can_accept() {
                    if let Some(_evicted) = self.cache.insert(key, ()) {}
                }
            }
        }
        misses
    }

    /// Prefetch hit/waste totals (tests + figures).
    pub fn prefetch_stats(&self) -> crate::memory::PrefetchStats {
        self.prefetcher.stats
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn register(&mut self, req: &Request) -> Result<()> {
        let budget_groups = match req.sparse_budget {
            Some(tokens) => tokens.div_ceil(self.spec().block_size).max(1),
            None => self.budget_groups(),
        };
        self.reqs.insert(
            req.id,
            SimReq {
                len: 0,
                selection: SelectionModel::new(self.seed ^ req.id as u64),
                ws: WorkingSetTracker::new(self.cfg.ws_window),
                budget_groups,
            },
        );
        Ok(())
    }

    fn release(&mut self, req: ReqId) {
        // drop stage pins before the entries go away (cancel mid-flight
        // must not leave the cache pinned shut)
        for key in self.prefetcher.cancel_request(req) {
            self.cache.unpin(&key);
        }
        self.reqs.remove(&req);
        self.cache.remove_request(req);
    }

    fn mem_stats(&self) -> MemStats {
        let bs = self.cost.spec.block_size;
        let kv_bytes: usize = self
            .reqs
            .values()
            .map(|r| r.len.div_ceil(bs) * self.group_bytes)
            .sum();
        if self.cfg.offload {
            // DRAM is home; HBM holds the LRU residency cache.
            MemStats {
                hbm_bytes_used: self.cache.len() * self.group_bytes,
                dram_bytes_used: kv_bytes,
                n_registered: self.reqs.len(),
            }
        } else {
            // vLLM semantics: every stored block is pinned in HBM.
            MemStats {
                hbm_bytes_used: kv_bytes,
                dram_bytes_used: 0,
                n_registered: self.reqs.len(),
            }
        }
    }

    fn decode_ws_bytes(&mut self, req: ReqId) -> usize {
        let group_bytes = self.group_bytes;
        let spec_bs = self.spec().block_size;
        let r = self.reqs.get_mut(&req).expect("unregistered");
        let budget = r.budget_groups;
        if !self.cfg.sparse_attention {
            // dense attention touches the whole context
            return r.len.div_ceil(spec_bs) * group_bytes;
        }
        if r.ws.steps_recorded() == 0 {
            // no history yet: assume the full budget is hot
            return budget.min(r.len.div_ceil(spec_bs)).max(1) * group_bytes;
        }
        r.ws.ws_blocks() * group_bytes
    }

    /// Stage each scheduled decode's predicted working set (its
    /// recency-ranked window union) into the HBM cache, FCFS priority,
    /// up to the `max_prefetch_blocks` budget. Staged groups are pinned
    /// until the batch consumes them (hit) or ends (wasted).
    fn prefetch(&mut self, decodes: &[ReqId]) -> usize {
        if !(self.cfg.prefetch && self.cfg.offload && self.cfg.sparse_attention) {
            return 0;
        }
        let cap = self.cfg.max_prefetch_blocks;
        // keep one selection's worth of groups free-or-evictable so
        // demand misses can still become resident behind the stages
        let headroom = self.budget_groups().min(self.cache.capacity() / 2);
        let mut staged = 0usize;
        'reqs: for &id in decodes {
            // over-collect by 2x: resident entries are skipped for free
            let want = cap.saturating_sub(staged).saturating_mul(2);
            let ranked = match self.reqs.get(&id) {
                Some(r) => r.ws.ranked_blocks_capped(want),
                None => continue,
            };
            for (_, _, g) in ranked {
                if staged >= cap {
                    break 'reqs;
                }
                let key = BlockKey::new(id, 0, 0, g);
                if self.cache.contains(&key) {
                    continue;
                }
                let free_after = self
                    .cache
                    .capacity()
                    .saturating_sub(self.cache.pinned_len() + 1);
                if !self.cache.can_accept() || free_after < headroom {
                    break 'reqs; // staging further would squeeze out misses
                }
                if let Some(_evicted) = self.cache.insert(key, ()) {}
                self.cache.pin(&key);
                self.prefetcher.mark_staged(key, self.group_bytes);
                staged += 1;
            }
        }
        self.staged_groups += staged;
        staged
    }

    fn run_batch(
        &mut self,
        batch: &Batch,
        requests: &HashMap<ReqId, Request>,
    ) -> Result<BatchOutcome> {
        let spec = self.spec().clone();
        let bs = spec.block_size;
        let mut out = BatchOutcome::default();
        let mut compute_s = 0.0;
        let mut miss_groups_total = 0usize;
        let hits_at_start = self.prefetcher.stats.hits;

        // ---------------- prefill share ----------------
        if let Some(work) = &batch.prefill {
            let req_id = work.req();
            let save_f = self
                .cost
                .save_overhead_factor(self.cfg.transfer, self.cfg.offload);
            match work {
                PrefillWork::Chunk { start, len, is_last, .. } => {
                    let t = self.cost.prefill_layer_time(*len, *start) * spec.n_layers as f64;
                    compute_s += t * save_f;
                    // offloaded chunked prefill re-fetches evicted past KV
                    if self.cfg.offload && *start > 0 {
                        let past_groups: Vec<u32> = (0..(*start / bs) as u32).collect();
                        let misses = self.touch_groups(req_id, &past_groups);
                        miss_groups_total += misses;
                    }
                    let r = self.reqs.get_mut(&req_id).expect("unregistered");
                    r.len += len;
                    if *is_last {
                        out.tokens.push((req_id, None));
                    }
                }
                PrefillWork::LayerSegment {
                    layer_start, layer_end, tok_start, tok_len, is_last, ..
                } => {
                    let layers = (layer_end - layer_start) as f64;
                    let t = self.cost.prefill_layer_time(*tok_len, *tok_start) * layers;
                    compute_s += t * save_f;
                    // layer-segmented prefill writes straight to DRAM and
                    // evicts immediately: no cache traffic, single-layer WS
                    if *is_last {
                        let r = self.reqs.get_mut(&req_id).expect("unregistered");
                        r.len = requests[&req_id].prompt_len;
                        out.tokens.push((req_id, None));
                    }
                }
            }
        }

        // ---------------- decode share ----------------
        if !batch.decodes.is_empty() {
            let mut kv_tokens = Vec::with_capacity(batch.decodes.len());
            for &id in &batch.decodes {
                let sparse = self.cfg.sparse_attention;
                let offload = self.cfg.offload;
                let (n_sealed, len) = {
                    let r = self.reqs.get(&id).expect("unregistered");
                    (r.len / bs, r.len)
                };
                if sparse {
                    let sel = {
                        let r = self.reqs.get_mut(&id).unwrap();
                        let budget_groups = r.budget_groups;
                        r.selection.next_selection(n_sealed, budget_groups)
                    };
                    if offload {
                        let misses = self.touch_groups(id, &sel);
                        miss_groups_total += misses;
                    }
                    let r = self.reqs.get_mut(&id).unwrap();
                    r.ws.record_step(sel.iter().map(|&b| (0u16, 0u16, b)).collect());
                    kv_tokens.push((sel.len() * bs + len % bs).min(len).max(1));
                } else {
                    kv_tokens.push(len.max(1));
                }
                self.reqs.get_mut(&id).unwrap().len += 1;
                out.tokens.push((id, None));
            }
            compute_s += self.cost.decode_iter_time(batch.decodes.len(), &kv_tokens);
        }

        // ---------------- PCIe streams & iteration timing ----------------
        // Two-stream event model: prefetch bytes were issued before the
        // batch and overlap compute; demand misses are discovered at
        // selection time and stall the gather. The overlap is therefore
        // exactly what the prefetcher earned — no assumed factor.
        let staged_groups = std::mem::take(&mut self.staged_groups);
        let prefetch_blocks = staged_groups * self.group_blocks;
        let miss_blocks = miss_groups_total * self.group_blocks;
        let prefetch_s = self.cost.load_time(self.cfg.transfer, prefetch_blocks);
        let demand_s = self.cost.load_time(self.cfg.transfer, miss_blocks);
        let timing = two_stream_iter(compute_s, prefetch_s, demand_s);

        out.blocks_loaded = miss_blocks + prefetch_blocks;
        out.load_time_s = demand_s + prefetch_s;
        out.stall_time_s = timing.stall_s;
        out.iter_time_s = timing.iter_time_s;
        out.prefetch_blocks = prefetch_blocks;
        self.total_blocks_loaded += (miss_blocks + prefetch_blocks) as u64;

        // retire unconsumed stages: wasted this iteration, but they stay
        // resident (unpinned) and may still hit later
        let wasted = self.prefetcher.end_iteration();
        for key in &wasted {
            self.cache.unpin(key);
        }
        out.prefetch_hits =
            (self.prefetcher.stats.hits - hits_at_start) as usize * self.group_blocks;
        out.prefetch_wasted = wasted.len() * self.group_blocks;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::serving::TransferKind;

    fn mk(cfg: ServingConfig) -> SimBackend {
        SimBackend::new(cfg, ModelSpec::lwm_7b(), HardwareSpec::a100_40gb())
    }

    fn prefill_all(b: &mut SimBackend, id: ReqId, plen: usize) -> HashMap<ReqId, Request> {
        let mut reqs = HashMap::new();
        let mut r = Request::new(id, plen, 64, 0.0);
        r.phase = crate::scheduler::Phase::Prefill;
        b.register(&r).unwrap();
        reqs.insert(id, r);
        let batch = Batch {
            decodes: vec![],
            prefill: Some(PrefillWork::Chunk { req: id, start: 0, len: plen, is_last: true }),
        };
        b.run_batch(&batch, &reqs).unwrap();
        reqs.get_mut(&id).unwrap().phase = crate::scheduler::Phase::Decode;
        reqs
    }

    #[test]
    fn decode_outputs_token_per_request() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        let out = b.run_batch(&batch, &reqs).unwrap();
        assert_eq!(out.tokens, vec![(1, None)]);
        assert!(out.iter_time_s > 0.0);
    }

    #[test]
    fn warm_cache_stops_loading() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        let first = b.run_batch(&batch, &reqs).unwrap();
        assert!(first.blocks_loaded > 0, "cold start loads");
        let mut warm_loads = 0;
        for _ in 0..5 {
            warm_loads = b.run_batch(&batch, &reqs).unwrap().blocks_loaded;
        }
        assert!(
            warm_loads < first.blocks_loaded / 2,
            "locality must cut loads: {warm_loads} vs {first:?}"
        );
    }

    #[test]
    fn dense_vllm_never_touches_pcie() {
        let mut b = mk(ServingConfig::vllm(2048));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        let out = b.run_batch(&batch, &reqs).unwrap();
        assert_eq!(out.blocks_loaded, 0);
        assert_eq!(out.load_time_s, 0.0);
    }

    #[test]
    fn sparse_decode_iterations_are_faster_than_dense() {
        let mut s = mk(ServingConfig::vllm_s(2048, 2048));
        let mut d = mk(ServingConfig::vllm(2048));
        let rs = prefill_all(&mut s, 1, 32_000);
        let rd = prefill_all(&mut d, 1, 32_000);
        let batch = Batch { decodes: vec![1], prefill: None };
        let ts = s.run_batch(&batch, &rs).unwrap().iter_time_s;
        let td = d.run_batch(&batch, &rd).unwrap().iter_time_s;
        assert!(td > 1.25 * ts, "dense {td} vs sparse {ts}");
    }

    #[test]
    fn memcpy_engine_amplifies_load_time() {
        let mut flash = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
        cfg.transfer = TransferKind::Memcpy;
        let mut mem = mk(cfg);
        let rf = prefill_all(&mut flash, 1, 16_000);
        let rm = prefill_all(&mut mem, 1, 16_000);
        let batch = Batch { decodes: vec![1], prefill: None };
        let f = flash.run_batch(&batch, &rf).unwrap();
        let m = mem.run_batch(&batch, &rm).unwrap();
        assert_eq!(f.blocks_loaded, m.blocks_loaded);
        assert!(m.load_time_s > 3.0 * f.load_time_s);
    }

    #[test]
    fn ws_estimate_grows_with_history_and_caps_at_union() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 16_000);
        let w0 = b.decode_ws_bytes(1);
        assert!(w0 > 0);
        let batch = Batch { decodes: vec![1], prefill: None };
        for _ in 0..14 {
            b.run_batch(&batch, &reqs).unwrap();
        }
        let w = b.decode_ws_bytes(1);
        // union over 12 steps >= single-step budget
        assert!(w >= w0, "w={w} w0={w0}");
        // but bounded: locality keeps it within ~3x budget
        assert!(w < 4 * w0, "w={w} w0={w0}");
    }

    #[test]
    fn sparse_budget_override_cuts_decode_cost() {
        let cfg = ServingConfig::sparseserve(2048, 2048, 32);
        let mut full = mk(cfg.clone());
        let mut small = mk(cfg);
        let reqs_f = prefill_all(&mut full, 1, 32_000);
        // same request, but submitted with a 256-token DSA budget override
        let mut r = Request::new(1, 32_000, 64, 0.0);
        r.sparse_budget = Some(256);
        r.phase = crate::scheduler::Phase::Prefill;
        small.register(&r).unwrap();
        let mut reqs_s = HashMap::new();
        reqs_s.insert(1, r);
        let prefill = Batch {
            decodes: vec![],
            prefill: Some(PrefillWork::Chunk { req: 1, start: 0, len: 32_000, is_last: true }),
        };
        small.run_batch(&prefill, &reqs_s).unwrap();
        reqs_s.get_mut(&1).unwrap().phase = crate::scheduler::Phase::Decode;

        let batch = Batch { decodes: vec![1], prefill: None };
        let tf = full.run_batch(&batch, &reqs_f).unwrap().iter_time_s;
        let ts = small.run_batch(&batch, &reqs_s).unwrap().iter_time_s;
        assert!(tf > 2.0 * ts, "full-budget decode {tf} vs overridden {ts}");
        // the Alg. 1 working-set estimate shrinks with the override too
        assert!(small.decode_ws_bytes(1) < full.decode_ws_bytes(1));
    }

    #[test]
    fn release_clears_mem_stats() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let reqs = prefill_all(&mut b, 1, 8192);
        let batch = Batch { decodes: vec![1], prefill: None };
        b.run_batch(&batch, &reqs).unwrap();
        let before = b.mem_stats();
        assert!(before.dram_bytes_used > 0 && before.hbm_bytes_used > 0);
        assert_eq!(before.n_registered, 1);
        b.release(1);
        assert_eq!(b.mem_stats(), MemStats::default());
    }

    /// Backend with a deliberately small HBM cache (`groups` block
    /// groups) to create eviction pressure — the regime the prefetcher
    /// exists for.
    fn mk_pressured(cfg: ServingConfig, groups: usize) -> SimBackend {
        let spec = ModelSpec::lwm_7b();
        let mut hw = HardwareSpec::a100_40gb();
        hw.hbm_kv_bytes = groups * spec.n_layers * spec.n_kv_heads * spec.block_bytes();
        SimBackend::new(cfg, spec, hw)
    }

    fn prefill_two(b: &mut SimBackend, plen: usize) -> HashMap<ReqId, Request> {
        let mut reqs = HashMap::new();
        for id in 1..=2u32 {
            let mut r = Request::new(id, plen, 512, 0.0);
            r.phase = crate::scheduler::Phase::Prefill;
            b.register(&r).unwrap();
            reqs.insert(id, r);
            let batch = Batch {
                decodes: vec![],
                prefill: Some(PrefillWork::Chunk { req: id, start: 0, len: plen, is_last: true }),
            };
            b.run_batch(&batch, &reqs).unwrap();
            reqs.get_mut(&id).unwrap().phase = crate::scheduler::Phase::Decode;
        }
        reqs
    }

    #[test]
    fn prefetch_stages_blocks_and_earns_hits() {
        // under cache pressure the prefetcher must stage work and convert
        // would-be misses into hits
        let mut b = mk_pressured(ServingConfig::sparseserve(2048, 2048, 32), 96);
        let reqs = prefill_two(&mut b, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        // first iteration builds working-set history (nothing to rank yet)
        b.run_batch(&batch, &reqs).unwrap();
        let mut staged_total = 0usize;
        let mut hits_total = 0usize;
        for _ in 0..8 {
            b.prefetch(&batch.decodes);
            let out = b.run_batch(&batch, &reqs).unwrap();
            staged_total += out.prefetch_blocks;
            hits_total += out.prefetch_hits;
        }
        assert!(staged_total > 0, "pressure must trigger staging");
        assert!(hits_total > 0, "staged blocks must become hits");
        assert!(b.prefetch_stats().hits > 0);
    }

    #[test]
    fn no_prefetch_ablation_stalls_strictly_more() {
        // acceptance criterion: equal workload, prefetch off must show
        // strictly more stall time than prefetch on
        let cfg_pf = ServingConfig::sparseserve(2048, 2048, 32);
        let cfg_np = ServingConfig::sparseserve_np(2048, 2048, 32);
        let mut pf = mk_pressured(cfg_pf, 96);
        let mut np = mk_pressured(cfg_np, 96);
        let rp = prefill_two(&mut pf, 16_000);
        let rn = prefill_two(&mut np, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        let (mut stall_pf, mut stall_np) = (0.0, 0.0);
        let (mut toks_pf, mut toks_np) = (0usize, 0usize);
        for _ in 0..24 {
            pf.prefetch(&batch.decodes);
            let o = pf.run_batch(&batch, &rp).unwrap();
            stall_pf += o.stall_time_s;
            toks_pf += o.tokens.len();
            np.prefetch(&batch.decodes); // config off -> no-op
            let o = np.run_batch(&batch, &rn).unwrap();
            stall_np += o.stall_time_s;
            toks_np += o.tokens.len();
        }
        assert_eq!(toks_pf, toks_np, "equal workload");
        assert!(
            stall_np > stall_pf,
            "no-prefetch must stall strictly more: np={stall_np} pf={stall_pf}"
        );
    }

    #[test]
    fn unused_stages_are_accounted_as_wasted() {
        let mut b = mk_pressured(ServingConfig::sparseserve(2048, 2048, 32), 96);
        let reqs = prefill_two(&mut b, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        b.run_batch(&batch, &reqs).unwrap(); // build history
        let staged = b.prefetch(&[1, 2]);
        assert!(staged > 0);
        // run a batch that never touches request 1/2's staged groups:
        // an empty decode set consumes nothing
        let idle = Batch { decodes: vec![], prefill: None };
        let out = b.run_batch(&idle, &reqs).unwrap();
        assert_eq!(out.prefetch_wasted, out.prefetch_blocks);
        assert!(out.prefetch_wasted > 0);
        assert!(b.prefetch_stats().wasted > 0);
        // wasted stages were unpinned: later batches keep running normally
        b.prefetch(&[1, 2]);
        b.run_batch(&batch, &reqs).unwrap();
    }

    #[test]
    fn release_cancels_stage_pins() {
        let mut b = mk_pressured(ServingConfig::sparseserve(2048, 2048, 32), 96);
        let reqs = prefill_two(&mut b, 16_000);
        let batch = Batch { decodes: vec![1, 2], prefill: None };
        b.run_batch(&batch, &reqs).unwrap();
        let staged = b.prefetch(&[1, 2]);
        assert!(staged > 0);
        // cancel mid-flight: stage pins must be released with the request
        b.release(1);
        b.release(2);
        assert!(b.prefetch_stats().cancelled > 0, "cancel must drop stages");
        assert_eq!(b.mem_stats(), MemStats::default());
        // a fresh request can use the full cache again (nothing pinned)
        let reqs2 = prefill_all(&mut b, 9, 16_000);
        let b9 = Batch { decodes: vec![9], prefill: None };
        b.run_batch(&b9, &reqs2).unwrap();
    }

    #[test]
    fn layer_segmented_prefill_avoids_cache_traffic() {
        let mut b = mk(ServingConfig::sparseserve(2048, 2048, 32));
        let mut r = Request::new(1, 8192, 8, 0.0);
        r.phase = crate::scheduler::Phase::Prefill;
        b.register(&r).unwrap();
        let mut reqs = HashMap::new();
        reqs.insert(1, r);
        for layer in 0..32 {
            let batch = Batch {
                decodes: vec![],
                prefill: Some(PrefillWork::LayerSegment {
                    req: 1, layer_start: layer, layer_end: layer + 1,
                    tok_start: 0, tok_len: 8192, is_last: layer == 31,
                }),
            };
            let out = b.run_batch(&batch, &reqs).unwrap();
            assert_eq!(out.blocks_loaded, 0);
        }
        assert_eq!(b.reqs[&1].len, 8192);
    }
}
