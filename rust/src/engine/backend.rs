//! The execution backend interface.

use anyhow::Result;

use crate::memory::ReqId;
use crate::scheduler::{Batch, Request};

/// Result of executing one hybrid batch on a backend.
///
/// (The engine-level result of one `EngineCore::step` — token events,
/// finished requests — is [`crate::engine::StepOutcome`].)
#[derive(Debug, Clone, Default)]
pub struct BatchOutcome {
    /// Iteration latency on the serving clock, seconds (modeled for the
    /// simulator, measured for the real backend).
    pub iter_time_s: f64,
    /// Tokens produced this iteration: decode tokens for every decode
    /// request, plus the first token when a prefill completed.
    pub tokens: Vec<(ReqId, Option<i32>)>,
    /// KV blocks moved over PCIe this iteration (demand misses plus
    /// prefetch stages).
    pub blocks_loaded: usize,
    /// Modeled PCIe busy time (demand + prefetch streams).
    pub load_time_s: f64,
    /// Modeled PCIe save critical-path time.
    pub save_time_s: f64,
    /// Iteration time lost to PCIe traffic that compute could not hide
    /// (demand misses + prefetch spill past the compute window).
    pub stall_time_s: f64,
    /// Blocks staged ahead of need by the working-set prefetcher.
    pub prefetch_blocks: usize,
    /// Staged blocks consumed by this iteration's gathers.
    pub prefetch_hits: usize,
    /// Staged blocks this iteration never touched (mispredictions).
    pub prefetch_wasted: usize,
}

/// KV-memory occupancy snapshot (request lifecycle observability: tests
/// assert cancellation actually frees blocks through these numbers).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemStats {
    /// HBM bytes currently holding KV blocks (cache population with
    /// offloading; every stored block without).
    pub hbm_bytes_used: usize,
    /// DRAM bytes currently holding KV blocks.
    pub dram_bytes_used: usize,
    /// Requests with registered (live) KV state.
    pub n_registered: usize,
}

pub trait Backend {
    /// Called when a request is admitted (allocate KV state).
    fn register(&mut self, req: &Request) -> Result<()>;

    /// Called when a request finishes or is cancelled (free KV state).
    fn release(&mut self, req: ReqId);

    /// Execute one hybrid batch. `requests` gives access to prompt tokens
    /// and progress counters.
    fn run_batch(
        &mut self,
        batch: &Batch,
        requests: &std::collections::HashMap<ReqId, Request>,
    ) -> Result<BatchOutcome>;

    /// Decode working-set estimate in bytes (Alg. 1 input).
    fn decode_ws_bytes(&mut self, req: ReqId) -> usize;

    /// Stage the predicted working sets of the batch's decode requests
    /// into HBM ahead of execution (`decodes` in plan order — earlier
    /// FCFS requests get staging priority). Called by the engine between
    /// planning and `run_batch`; the staged traffic overlaps the
    /// iteration's compute. Returns blocks staged. Default: no-op for
    /// backends without a prefetch pipeline.
    fn prefetch(&mut self, decodes: &[ReqId]) -> usize {
        let _ = decodes;
        0
    }

    /// KV-memory occupancy (HBM/DRAM bytes, live requests).
    fn mem_stats(&self) -> MemStats;

    /// Human-readable backend name.
    fn name(&self) -> &'static str;
}
