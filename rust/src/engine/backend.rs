//! The execution backend interface.

use anyhow::Result;

use crate::memory::ReqId;
use crate::scheduler::{Batch, Request};

/// Result of executing one hybrid batch on a backend.
///
/// (The engine-level result of one `EngineCore::step` — token events,
/// finished requests — is [`crate::engine::StepOutcome`].)
#[derive(Debug, Clone, Default)]
pub struct BatchOutcome {
    /// Iteration latency on the serving clock, seconds (modeled for the
    /// simulator, measured for the real backend).
    pub iter_time_s: f64,
    /// Tokens produced this iteration: decode tokens for every decode
    /// request, plus the first token when a prefill completed.
    pub tokens: Vec<(ReqId, Option<i32>)>,
    /// KV blocks loaded from DRAM (cache misses).
    pub blocks_loaded: usize,
    /// Modeled PCIe load time.
    pub load_time_s: f64,
    /// Modeled PCIe save critical-path time.
    pub save_time_s: f64,
}

/// KV-memory occupancy snapshot (request lifecycle observability: tests
/// assert cancellation actually frees blocks through these numbers).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemStats {
    /// HBM bytes currently holding KV blocks (cache population with
    /// offloading; every stored block without).
    pub hbm_bytes_used: usize,
    /// DRAM bytes currently holding KV blocks.
    pub dram_bytes_used: usize,
    /// Requests with registered (live) KV state.
    pub n_registered: usize,
}

pub trait Backend {
    /// Called when a request is admitted (allocate KV state).
    fn register(&mut self, req: &Request) -> Result<()>;

    /// Called when a request finishes or is cancelled (free KV state).
    fn release(&mut self, req: ReqId);

    /// Execute one hybrid batch. `requests` gives access to prompt tokens
    /// and progress counters.
    fn run_batch(
        &mut self,
        batch: &Batch,
        requests: &std::collections::HashMap<ReqId, Request>,
    ) -> Result<BatchOutcome>;

    /// Decode working-set estimate in bytes (Alg. 1 input).
    fn decode_ws_bytes(&mut self, req: ReqId) -> usize;

    /// KV-memory occupancy (HBM/DRAM bytes, live requests).
    fn mem_stats(&self) -> MemStats;

    /// Human-readable backend name.
    fn name(&self) -> &'static str;
}
