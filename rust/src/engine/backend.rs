//! The execution backend interface: transactional, layer-phased steps.
//!
//! A backend executes one hybrid batch as a [`StepSession`] — an
//! explicit, phase-structured transaction the engine drives:
//!
//! ```text
//! begin_step(batch)                 // pre-flight + open the transaction
//!   .stage(hints)                   // prefetch working sets (this batch
//!                                   //  first, then next-batch hints)
//!   .prefill_segment(l, l+1) ...    // per-layer prefill phases
//!   .decode_layer(0..n_layers) ...  // per-layer decode phases
//! -> commit()  -> BatchOutcome      // keep everything, close the step
//!  | rollback()                     // undo partial KV appends: every
//!                                   //  batch-mate's KV is byte-identical
//!                                   //  to its pre-step state
//! ```
//!
//! Each phase emits a [`PhaseEvent`] (compute time, misses discovered at
//! that layer, bytes moved), which is what lets the simulator charge
//! PCIe traffic with the per-layer overlap model
//! ([`crate::sim::layered_iter`]) instead of stalling wholesale, and
//! what makes layer-segmented prefill a real execution path (one layer's
//! HBM bound enforced per segment) rather than a planner-only mode.
//!
//! ## Invariants
//!
//! - Phase order is fixed: `stage` (at most once, first), then prefill
//!   segments in ascending layer order, then decode layers `0..n_layers`
//!   in order, then exactly one of `commit` / `rollback`.
//! - A failed phase leaves the session rollback-able: `rollback()` after
//!   any phase error restores every batch participant's KV state, so the
//!   engine can retry the surviving batch-mates *in the same iteration*
//!   (typed [`crate::memory::MemoryError`]s name the victim to drop).
//! - Prefetch stages survive a rollback: they reference pre-existing
//!   sealed blocks and keep feeding the retry.
//! - Cross-iteration staging: `stage` receives [`StageHints`] naming the
//!   requests predicted to decode *next* iteration; their working sets
//!   are staged with leftover budget under this batch's compute and are
//!   retired only at the end of the iteration they were staged for.
//!
//! [`drive_step`] encodes the canonical order; `EngineCore::step` layers
//! partial-batch retry on top of it.

use std::collections::HashMap;

use anyhow::Result;

use crate::memory::ReqId;
use crate::scheduler::{Batch, PrefillWork, Request};
use crate::sim::SelectionModel;
use crate::sparse::WorkingSetTracker;

/// Result of executing one hybrid batch on a backend.
///
/// (The engine-level result of one `EngineCore::step` — token events,
/// finished requests — is [`crate::engine::StepOutcome`].)
#[derive(Debug, Clone, Default)]
pub struct BatchOutcome {
    /// Iteration latency on the serving clock, seconds (modeled for the
    /// simulator, measured for the real backend).
    pub iter_time_s: f64,
    /// Tokens produced this iteration: decode tokens for every decode
    /// request, plus the first token when a prefill completed.
    pub tokens: Vec<(ReqId, Option<i32>)>,
    /// KV blocks moved over PCIe this iteration (demand misses plus
    /// prefetch stages).
    pub blocks_loaded: usize,
    /// Modeled PCIe busy time (demand + prefetch streams).
    pub load_time_s: f64,
    /// Modeled PCIe save critical-path time.
    pub save_time_s: f64,
    /// Iteration time lost to PCIe traffic that compute could not hide
    /// (under the configured event model).
    pub stall_time_s: f64,
    /// Copy-stream time hidden under compute (the overlap the per-layer
    /// model + prefetcher earned).
    pub hidden_time_s: f64,
    /// What the coarse two-stream model would have charged as stall for
    /// the same traffic (diagnostics; `bench` compares the two).
    pub coarse_stall_time_s: f64,
    /// Blocks staged ahead of need by the working-set prefetcher.
    pub prefetch_blocks: usize,
    /// Staged blocks consumed by this iteration's gathers.
    pub prefetch_hits: usize,
    /// Staged blocks this iteration never touched (mispredictions).
    pub prefetch_wasted: usize,
    /// Blocks staged for the NEXT iteration (cross-iteration hints).
    pub prefetch_deferred: usize,
    /// Compute time burnt on this iteration's rolled-back attempts
    /// (sessions that hit a typed memory error and were retried without
    /// the victim). The engine charges it to the serving clock on top of
    /// `iter_time_s`, so eviction-heavy workloads stop under-reporting
    /// latency.
    pub abort_time_s: f64,
    /// Host plan/stage time this iteration hid under its predecessor's
    /// compute (pipelined executor only; zero when `pipeline_depth` is
    /// 1, when the speculative plan was invalidated and re-planned
    /// synchronously, and on the real backend, which measures wall time
    /// instead of modeling the overlap).
    pub plan_stage_hidden_s: f64,
    /// Host plan/stage time the predecessor's compute window could not
    /// absorb — the pipeline bubble charged to `iter_time_s` (see
    /// [`crate::sim::pipelined_iter`]).
    pub pipeline_bubble_s: f64,
    /// Per-phase telemetry in execution order (prefill segments, then
    /// decode layers), collected by [`drive_step`] from the events each
    /// phase returned. This is what feeds the per-layer
    /// compute-vs-transfer-wait profile on `RunMetrics` — the measured
    /// `PhaseEvent::compute_s` of the real backend and the modeled one
    /// of the simulator both land here instead of being discarded.
    pub phases: Vec<PhaseEvent>,
}

/// KV-memory occupancy snapshot (request lifecycle observability: tests
/// assert cancellation actually frees blocks through these numbers).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemStats {
    /// HBM bytes currently holding KV blocks (cache population with
    /// offloading; every stored block without).
    pub hbm_bytes_used: usize,
    /// DRAM bytes currently holding KV blocks.
    pub dram_bytes_used: usize,
    /// Requests with registered (live) KV state.
    pub n_registered: usize,
}

/// Staging hints for [`StepSession::stage`]: which requests the planner
/// predicts will decode in the *next* iteration. The session stages the
/// current batch's working sets first (full budget, FCFS order from
/// `Batch::decodes`), then these with whatever budget remains — issued
/// under the current batch's compute so next iteration's gathers start
/// warm (cross-iteration staging).
#[derive(Debug, Clone, Default)]
pub struct StageHints {
    /// Predicted next-iteration decodes not in the current batch
    /// (e.g. decodes the WS batch control skipped this iteration).
    pub next_decodes: Vec<ReqId>,
    /// This batch's plan + stage hints were speculatively computed
    /// under the PREVIOUS iteration's compute (pipelined executor,
    /// `pipeline_depth >= 2`, speculation validated at consume time).
    /// The simulated backend then charges the pipelined iteration
    /// bound ([`crate::sim::pipelined_iter`]) instead of serializing
    /// the plan/stage share; false = synchronous order (pipeline fill,
    /// depth 1, or an invalidated speculation that was re-planned).
    pub pipelined: bool,
}

/// One phase's worth of execution telemetry, emitted by
/// [`StepSession::prefill_segment`] / [`StepSession::decode_layer`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseEvent {
    /// Layer range this phase covered (`[layer_start, layer_end)`).
    pub layer_start: usize,
    pub layer_end: usize,
    /// GPU compute attributed to this phase, seconds (modeled for the
    /// simulator, measured for the real backend).
    pub compute_s: f64,
    /// Demand misses discovered at this phase (per-head blocks).
    pub miss_blocks: usize,
    /// PCIe bytes this phase moved on demand.
    pub bytes_moved: usize,
}

/// Serialized cross-engine state of one in-flight request: what a
/// cluster tier drains from a hot engine's backend
/// ([`Backend::export_migration`]) and re-admits at a cold one
/// ([`Backend::import_migration`]).
///
/// The payload carries everything the simulator needs to *replay the
/// request byte-identically* on the target engine: the sealed KV length,
/// the per-request DSA budget, and — crucially — the live
/// [`SelectionModel`] (its RNG stream, seeded from the source engine's
/// monotone admission counter, moves wholesale so post-migration draws
/// match an unmigrated run draw-for-draw) and [`WorkingSetTracker`]
/// (recency window + frequency EWMAs, so prefetch ranking does not
/// restart cold). `kv_bytes` is the DRAM-tier footprint serialized over
/// the wire; the cluster prices it as FlashD2H at the source plus
/// FlashH2D at the target on the shared clock.
#[derive(Debug, Clone)]
pub struct MigrationPayload {
    pub req: ReqId,
    /// Sealed KV tokens (prompt progress + generated) at drain time.
    pub len: usize,
    /// Per-request working-set budget, in band groups.
    pub budget_groups: usize,
    /// Live selection state, moved (not cloned) off the source engine.
    pub selection: SelectionModel,
    /// Live working-set history, moved off the source engine.
    pub ws: WorkingSetTracker,
    /// DRAM-tier KV bytes serialized across engines.
    pub kv_bytes: usize,
}

/// One in-flight batch execution: a transaction over the backend's KV
/// state, driven phase by phase (see the module docs for the lifecycle
/// and invariants). Borrows the backend exclusively, so exactly one
/// session can exist at a time.
pub trait StepSession {
    /// Prefetch phase: stage the batch's predicted working sets (FCFS),
    /// then the `hints.next_decodes`' with leftover budget. Returns
    /// blocks staged. Call at most once, before any compute phase.
    fn stage(&mut self, hints: &StageHints) -> usize;

    /// Execute the batch's prefill work restricted to layers
    /// `[layer_start, layer_end)`. The engine derives segment bounds from
    /// the planned [`PrefillWork`] (a chunk spans all layers, driven one
    /// layer at a time). Layer-segmented work enforces the single-layer
    /// HBM bound per segment. Typed `MemoryError`s are rollback-able.
    fn prefill_segment(&mut self, layer_start: usize, layer_end: usize) -> Result<PhaseEvent>;

    /// Execute one decode layer for every decode request in the batch.
    /// Typed `MemoryError`s (mid-gather `HbmExhausted`, append
    /// `DramExhausted`) are rollback-able. This phase is fallible on
    /// BOTH backends: the simulator's per-layer-band selection touches
    /// the residency cache as each band starts, so a batch whose
    /// band-wide working set cannot fit HBM faults typed MID-decode,
    /// after earlier bands' compute has been burnt (the burnt time is
    /// charged as `BatchOutcome::abort_time_s` on the retry's commit).
    fn decode_layer(&mut self, layer: usize) -> Result<PhaseEvent>;

    /// Finalize: emit tokens, close the KV transaction, return the
    /// outcome. Consumes the session.
    fn commit(self: Box<Self>) -> Result<BatchOutcome>;

    /// Undo the step: every batch participant's KV state (lengths,
    /// blocks, metadata, hidden prefill activations, last tokens) is
    /// restored to its pre-step value so the batch — minus any victim —
    /// can re-run in the same iteration. Prefetch stages survive.
    fn rollback(self: Box<Self>);
}

pub trait Backend {
    /// Called when a request is admitted (allocate KV state).
    fn register(&mut self, req: &Request) -> Result<()>;

    /// Called when a request finishes or is cancelled (free KV state).
    fn release(&mut self, req: ReqId);

    /// Admission matched `matched_tokens` of `req`'s prompt against the
    /// shared prefix path tailed at `group`. Registration happens at
    /// submit time — before admission resolves the match — so the engine
    /// forwards the adoption here right after planning, before the batch
    /// runs. The backend joins the path's shared residency namespace and
    /// starts `req`'s stored KV past the matched tokens (their prefill
    /// is skipped). Default: no-op (backends without shared-residency
    /// modeling still run correctly — they just re-prefill nothing,
    /// because the scheduler never plans the matched span).
    fn adopt_prefix(&mut self, _req: ReqId, _matched_tokens: usize, _group: u32) {}

    /// Whether this backend implements [`Backend::adopt_prefix`]. The
    /// engine disables the scheduler's prefix index against backends
    /// that do not: admission-time prefill skipping is only sound when
    /// the backend can seed the matched span's KV from the shared path.
    fn supports_prefix_sharing(&self) -> bool {
        false
    }

    /// Open a step transaction for one hybrid batch. Pre-flight checks
    /// (e.g. DRAM demand of the decode step) fail here, typed, with zero
    /// side effects. `requests` gives access to prompt tokens and
    /// progress counters for the session's lifetime.
    fn begin_step<'s>(
        &'s mut self,
        batch: &'s Batch,
        requests: &'s HashMap<ReqId, Request>,
    ) -> Result<Box<dyn StepSession + 's>>;

    /// The engine gave up on the current iteration (every batch-mate was
    /// evicted before a session could commit): discard the aborted
    /// attempts' per-iteration transfer accounting and retire their
    /// prefetch stages, so the NEXT committed step's `BatchOutcome` does
    /// not inherit traffic it never moved. Returns the compute time the
    /// abandoned attempts burnt (charged to the serving clock by the
    /// engine). Default: no-op returning 0 (stateless backends).
    fn abort_iteration(&mut self) -> f64 {
        0.0
    }

    /// Drain a live request's cross-engine state for KV migration.
    /// Returns `None` when the backend cannot migrate (the real backend's
    /// kernel-resident KV has no re-seed path yet — `KvManager::
    /// drain_request` is the block-level seam, but selection state is
    /// synthetic-only) or the request is unknown. On `Some`, the
    /// request's local state is gone exactly as after [`Backend::release`]
    /// (pins dropped, residency purged) — the caller owns the payload.
    fn export_migration(&mut self, _req: ReqId) -> Option<MigrationPayload> {
        None
    }

    /// Re-admit a migrated request's state on this backend, preserving
    /// its RNG stream and working-set history (the inverse of
    /// [`Backend::export_migration`]; must NOT re-seed like `register`).
    /// Errors typed when unsupported or the id is already live here.
    fn import_migration(&mut self, payload: MigrationPayload) -> Result<()> {
        anyhow::bail!(
            "backend does not support KV migration (req {})",
            payload.req
        )
    }

    /// Decode working-set estimate in bytes (Alg. 1 input).
    fn decode_ws_bytes(&mut self, req: ReqId) -> usize;

    /// Model depth: how many `decode_layer` phases one step drives.
    fn n_layers(&self) -> usize;

    /// KV-memory occupancy (HBM/DRAM bytes, live requests).
    fn mem_stats(&self) -> MemStats;

    /// Human-readable backend name.
    fn name(&self) -> &'static str;
}

/// The layer range a prefill work item drives: a chunk runs every layer
/// (one `prefill_segment` per layer), a layer segment runs its planned
/// range.
pub fn prefill_layer_range(work: &PrefillWork, n_layers: usize) -> (usize, usize) {
    match work {
        PrefillWork::Chunk { .. } => (0, n_layers),
        PrefillWork::LayerSegment { layer_start, layer_end, .. } => (*layer_start, *layer_end),
    }
}

/// Drive one batch through the canonical phase order: stage, per-layer
/// prefill segments, per-layer decode, then commit — or rollback on the
/// first phase error (the error is returned so the caller can evict the
/// typed victim and retry the survivors). This is the one place the
/// phase protocol is encoded; every direct batch executor (engine,
/// figures, benches) goes through it.
pub fn drive_step(
    backend: &mut dyn Backend,
    batch: &Batch,
    requests: &HashMap<ReqId, Request>,
    hints: &StageHints,
) -> Result<BatchOutcome> {
    let n_layers = backend.n_layers();
    let mut sess = backend.begin_step(batch, requests)?;
    sess.stage(hints);
    let mut events: Vec<PhaseEvent> = Vec::new();
    let mut phase_err = None;
    'phases: {
        if let Some(work) = &batch.prefill {
            let (l0, l1) = prefill_layer_range(work, n_layers);
            for layer in l0..l1 {
                match sess.prefill_segment(layer, layer + 1) {
                    Ok(ev) => events.push(ev),
                    Err(e) => {
                        phase_err = Some(e);
                        break 'phases;
                    }
                }
            }
        }
        if !batch.decodes.is_empty() {
            for layer in 0..n_layers {
                match sess.decode_layer(layer) {
                    Ok(ev) => events.push(ev),
                    Err(e) => {
                        phase_err = Some(e);
                        break 'phases;
                    }
                }
            }
        }
    }
    match phase_err {
        None => sess.commit().map(|mut out| {
            out.phases = events;
            out
        }),
        Some(e) => {
            sess.rollback();
            Err(e)
        }
    }
}

/// The pipelined twin of [`drive_step`]: drives a batch whose plan and
/// stage hints were speculatively computed by the engine while the
/// PREVIOUS session executed (`ServingConfig::pipeline_depth >= 2`).
///
/// The phase order is byte-identical to the synchronous driver — the
/// exclusive backend borrow in [`Backend::begin_step`] makes two live
/// sessions impossible, so the two pipeline stages never interleave
/// phases: what overlaps is the *scheduler's* plan/stage for iteration
/// N+1 against the backend's compute for iteration N, and the backend
/// prices that overlap from `hints.pipelined` at commit time. It is a
/// separate function (not a flag on `drive_step`) so the repo's static
/// analysis can hold it to the same contract independently: sparselint
/// lists it as the second sanctioned `begin_step` caller, and the
/// `step-typestate` pass checks its inline begin -> stage -> phases ->
/// settle order like any other driver.
pub fn drive_step_pipelined(
    backend: &mut dyn Backend,
    batch: &Batch,
    requests: &HashMap<ReqId, Request>,
    hints: &StageHints,
) -> Result<BatchOutcome> {
    let n_layers = backend.n_layers();
    let mut sess = backend.begin_step(batch, requests)?;
    sess.stage(hints);
    let mut events: Vec<PhaseEvent> = Vec::new();
    let mut phase_err = None;
    'phases: {
        if let Some(work) = &batch.prefill {
            let (l0, l1) = prefill_layer_range(work, n_layers);
            for layer in l0..l1 {
                match sess.prefill_segment(layer, layer + 1) {
                    Ok(ev) => events.push(ev),
                    Err(e) => {
                        phase_err = Some(e);
                        break 'phases;
                    }
                }
            }
        }
        if !batch.decodes.is_empty() {
            for layer in 0..n_layers {
                match sess.decode_layer(layer) {
                    Ok(ev) => events.push(ev),
                    Err(e) => {
                        phase_err = Some(e);
                        break 'phases;
                    }
                }
            }
        }
    }
    match phase_err {
        None => sess.commit().map(|mut out| {
            out.phases = events;
            out
        }),
        Some(e) => {
            sess.rollback();
            Err(e)
        }
    }
}
