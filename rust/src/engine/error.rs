//! Typed serving errors (the request-lifecycle error taxonomy).

use std::fmt;
use std::sync::Arc;

/// Every way a request (or an engine step) can fail. Replaces the old
/// stringly-typed `StreamEvent::Error(String)` so clients can branch on
/// the failure class (retry on `QueueFull`, surface `BackendFailed`, …).
#[derive(Debug, Clone)]
pub enum ServeError {
    /// The engine refused the request before admission (duplicate id,
    /// registration failure, head-of-queue reservation that can never
    /// fit). No tokens were produced; resubmitting unchanged will fail
    /// again unless capacity changes.
    AdmissionRejected { reason: String },
    /// The request was cancelled by the client.
    Cancelled,
    /// The engine evicted an already-admitted request it could never
    /// schedule (its working set exceeds available HBM). Tokens
    /// streamed before the eviction were delivered.
    Evicted { reason: String },
    /// The backend failed executing a batch; the engine is no longer
    /// usable. `source` carries the underlying failure chain.
    BackendFailed { source: Arc<anyhow::Error> },
    /// The admission queue is at its configured capacity; resubmit later
    /// (client-side backpressure).
    QueueFull { cap: usize },
    /// The engine thread went away before the request completed.
    Disconnected,
}

impl ServeError {
    pub fn backend(err: anyhow::Error) -> Self {
        ServeError::BackendFailed { source: Arc::new(err) }
    }

    pub fn rejected(reason: impl Into<String>) -> Self {
        ServeError::AdmissionRejected { reason: reason.into() }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::AdmissionRejected { reason } => {
                write!(f, "admission rejected: {reason}")
            }
            ServeError::Cancelled => write!(f, "request cancelled"),
            ServeError::Evicted { reason } => write!(f, "request evicted: {reason}"),
            ServeError::BackendFailed { source } => {
                write!(f, "backend failed: {source:#}")
            }
            ServeError::QueueFull { cap } => {
                write!(f, "admission queue full (cap {cap})")
            }
            ServeError::Disconnected => write!(f, "engine disconnected before completion"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn displays_carry_context() {
        let e = ServeError::backend(anyhow::anyhow!("pjrt exploded"));
        assert!(e.to_string().contains("pjrt exploded"));
        let q = ServeError::QueueFull { cap: 4 };
        assert!(q.to_string().contains("cap 4"));
        // cloneable (fans out to every involved request stream)
        let _ = e.clone();
    }
}
