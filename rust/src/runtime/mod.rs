//! L3 <-> L2 bridge: load AOT artifacts and execute them via PJRT.
//!
//! The python compile path (`python/compile/aot.py`) emits, per model
//! config:
//!
//! - `manifest.json` — model architecture + weight layout + entry points
//! - `weights.bin`   — all weights, f32 LE, manifest order
//! - `<entry>.hlo.txt` — one HLO-text module per entry point x shape bucket
//! - `golden.json`   — greedy-token traces for parity tests
//!
//! This module loads all of it once at startup and exposes typed host
//! tensors plus an `execute(entry, inputs)` call; the PJRT CPU client is
//! the "GPU" of the testbed substitute. HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax>=0.5 serialized protos).

mod manifest;
mod tensor;
mod weights;

pub use manifest::{EntryInfo, Manifest};
pub use tensor::{HostTensor, TensorData};
pub use weights::WeightStore;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard, PoisonError};

use anyhow::{anyhow, Context, Result};

use crate::xla;

/// A loaded artifact directory + PJRT client with lazily compiled
/// executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    pub weights: WeightStore,
    dir: PathBuf,
    executables: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative PJRT executions (metrics).
    pub exec_count: std::sync::atomic::AtomicU64,
    /// Per-entry cumulative (calls, seconds) — §Perf profiling.
    exec_stats: Mutex<HashMap<String, (u64, f64)>>,
    /// Device-resident weight buffers (uploaded on first use).
    weight_buffers: Mutex<HashMap<String, std::sync::Arc<xla::PjRtBuffer>>>,
}

/// Input to [`Runtime::execute_mixed`].
pub enum MixedInput<'a> {
    /// Per-call host tensor (uploaded for this execution).
    Tensor(&'a HostTensor),
    /// Named weight (cached device-resident buffer).
    Weight(&'a str),
}

enum BufferSlot {
    Owned(xla::PjRtBuffer),
    Shared(std::sync::Arc<xla::PjRtBuffer>),
}

/// Poison-tolerant lock for the runtime's caches: the executable,
/// weight-buffer, and stats maps stay internally consistent even if a
/// panic unwinds through a holder, so recover the guard instead of
/// propagating the poison as a second panic on the serving path.
fn lock_cache<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Runtime {
    /// Load manifest + weights from an artifact directory
    /// (e.g. `artifacts/tiny-llm`). Executables compile on first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Manifest::parse(&text)?;
        let weights = WeightStore::load(&dir, &manifest)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            weights,
            dir,
            executables: Mutex::new(HashMap::new()),
            exec_count: std::sync::atomic::AtomicU64::new(0),
            exec_stats: Mutex::new(HashMap::new()),
            weight_buffers: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifact location for a config name, relative to the repo
    /// root (works from `cargo test` / examples / benches).
    pub fn default_dir(config: &str) -> PathBuf {
        let root = std::env::var("SPARSESERVE_ARTIFACTS")
            .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
        Path::new(&root).join(config)
    }

    /// Compile (or fetch the cached) executable for an entry point.
    pub fn executable(&self, entry: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = lock_cache(&self.executables).get(entry) {
            return Ok(e.clone());
        }
        let info = self
            .manifest
            .entry(entry)
            .ok_or_else(|| anyhow!("unknown entry point '{entry}'"))?;
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {entry}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        lock_cache(&self.executables).insert(entry.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact (startup warm-up so the request path
    /// never pays compilation).
    pub fn warm_up(&self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.entries.iter().map(|e| e.name.clone()).collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Execute an entry point. Inputs are host tensors in the artifact's
    /// parameter order; the output tuple is decomposed into host tensors.
    pub fn execute(&self, entry: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let t0 = std::time::Instant::now();
        let exe = self.executable(entry)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        let result = exe
            .execute::<&xla::Literal>(&refs)
            .map_err(|e| anyhow!("executing {entry}: {e:?}"))?;
        self.exec_count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {entry} result: {e:?}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("decomposing {entry} tuple: {e:?}"))?;
        let res: Result<Vec<HostTensor>> =
            parts.into_iter().map(HostTensor::from_literal).collect();
        {
            let mut stats = lock_cache(&self.exec_stats);
            let e = stats.entry(entry.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += t0.elapsed().as_secs_f64();
        }
        res
    }

    /// Upload a tensor to the device.
    /// (Uses the typed `buffer_from_host_buffer`: the vendored crate's
    /// `buffer_from_host_raw_bytes` passes `ElementType` where the C API
    /// expects `PrimitiveType`, mis-sizing the buffer.)
    fn to_buffer(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        match &t.data {
            TensorData::F32(v) => self.client.buffer_from_host_buffer(v, &t.dims, None),
            TensorData::I32(v) => self.client.buffer_from_host_buffer(v, &t.dims, None),
        }
        .map_err(|e| anyhow!("buffer upload: {e:?}"))
    }

    /// Device-resident buffer for a named weight (uploaded once, §Perf:
    /// avoids re-staging ~1.3 MB of weights on every decode_attend call).
    pub fn weight_buffer(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtBuffer>> {
        if let Some(b) = lock_cache(&self.weight_buffers).get(name) {
            return Ok(b.clone());
        }
        let buf = std::sync::Arc::new(self.to_buffer(self.weights.get(name))?);
        lock_cache(&self.weight_buffers).insert(name.to_string(), buf.clone());
        Ok(buf)
    }

    /// Execute with a mix of per-call host tensors and cached
    /// device-resident weights (named).
    pub fn execute_mixed(&self, entry: &str, inputs: &[MixedInput<'_>]) -> Result<Vec<HostTensor>> {
        let t0 = std::time::Instant::now();
        let exe = self.executable(entry)?;
        let mut slots: Vec<BufferSlot> = Vec::with_capacity(inputs.len());
        for inp in inputs {
            match inp {
                MixedInput::Tensor(t) => slots.push(BufferSlot::Owned(self.to_buffer(t)?)),
                MixedInput::Weight(name) => {
                    slots.push(BufferSlot::Shared(self.weight_buffer(name)?))
                }
            }
        }
        let refs: Vec<&xla::PjRtBuffer> = slots
            .iter()
            .map(|s| match s {
                BufferSlot::Owned(b) => b,
                BufferSlot::Shared(b) => b.as_ref(),
            })
            .collect();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .map_err(|e| anyhow!("executing {entry} (buffers): {e:?}"))?;
        self.exec_count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {entry} result: {e:?}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("decomposing {entry} tuple: {e:?}"))?;
        let res: Result<Vec<HostTensor>> =
            parts.into_iter().map(HostTensor::from_literal).collect();
        {
            let mut stats = lock_cache(&self.exec_stats);
            let e = stats.entry(entry.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += t0.elapsed().as_secs_f64();
        }
        res
    }

    /// Per-entry cumulative (calls, seconds), sorted by total time.
    pub fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        let stats = lock_cache(&self.exec_stats);
        let mut v: Vec<(String, u64, f64)> =
            stats.iter().map(|(k, (c, s))| (k.clone(), *c, *s)).collect();
        v.sort_by(|a, b| b.2.total_cmp(&a.2));
        v
    }
}
