//! `manifest.json` parsing: model spec, weight layout, entry points.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::config::ModelSpec;
use crate::util::json::{parse, Value};

/// One AOT entry point (an `<entry>.hlo.txt` file + its signature).
#[derive(Debug, Clone)]
pub struct EntryInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    /// Shape-bucket parameters, e.g. {"b": 4} or {"t": 256}.
    pub bucket: HashMap<String, usize>,
    /// Parameter shapes in call order.
    pub params: Vec<Vec<usize>>,
}

/// One weight tensor's slice of `weights.bin`.
#[derive(Debug, Clone)]
pub struct WeightInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_f32: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelSpec,
    pub seed: u64,
    pub weights_bin: String,
    pub total_f32: usize,
    pub weights: Vec<WeightInfo>,
    pub entries: Vec<EntryInfo>,
    /// prefill_t / chunk_t / decode_b / budget_k buckets.
    pub buckets: HashMap<String, Vec<usize>>,
    pub chunk_past: usize,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let model = ModelSpec::from_manifest(&v)?;

        let weights = v
            .get("weights")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'weights'"))?
            .iter()
            .map(|w| {
                Ok(WeightInfo {
                    name: w
                        .get("name")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("weight name"))?
                        .to_string(),
                    shape: shape_of(w.get("shape"))?,
                    offset_f32: w
                        .get("offset_f32")
                        .and_then(Value::as_usize)
                        .ok_or_else(|| anyhow!("weight offset"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let entries = v
            .get("entries")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'entries'"))?
            .iter()
            .map(|e| {
                let bucket = e
                    .get("bucket")
                    .and_then(Value::as_obj)
                    .map(|o| {
                        o.iter()
                            .filter_map(|(k, v)| v.as_usize().map(|n| (k.clone(), n)))
                            .collect()
                    })
                    .unwrap_or_default();
                let params = e
                    .get("params")
                    .and_then(Value::as_arr)
                    .ok_or_else(|| anyhow!("entry params"))?
                    .iter()
                    .map(|p| shape_of(p.get("shape")))
                    .collect::<Result<Vec<_>>>()?;
                Ok(EntryInfo {
                    name: str_field(e, "name")?,
                    file: str_field(e, "file")?,
                    kind: str_field(e, "kind")?,
                    bucket,
                    params,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut buckets = HashMap::new();
        let mut chunk_past = 0;
        if let Some(b) = v.get("buckets").and_then(Value::as_obj) {
            for (k, val) in b {
                if let Some(arr) = val.as_arr() {
                    buckets.insert(
                        k.clone(),
                        arr.iter().filter_map(Value::as_usize).collect(),
                    );
                } else if k == "chunk_past" {
                    chunk_past = val.as_usize().unwrap_or(0);
                }
            }
        }

        Ok(Self {
            model,
            seed: v.get("seed").and_then(Value::as_usize).unwrap_or(0) as u64,
            weights_bin: str_field(&v, "weights_bin")?,
            total_f32: v
                .get("total_f32")
                .and_then(Value::as_usize)
                .ok_or_else(|| anyhow!("total_f32"))?,
            weights,
            entries,
            buckets,
            chunk_past,
        })
    }

    pub fn entry(&self, name: &str) -> Option<&EntryInfo> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn bucket(&self, key: &str) -> &[usize] {
        self.buckets.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Smallest bucket >= n (batch/segment padding target).
    pub fn fit_bucket(&self, key: &str, n: usize) -> Option<usize> {
        let mut opts: Vec<usize> = self.bucket(key).to_vec();
        opts.sort_unstable();
        opts.into_iter().find(|&b| b >= n)
    }
}

fn str_field(v: &Value, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing field '{key}'"))
}

fn shape_of(v: Option<&Value>) -> Result<Vec<usize>> {
    v.and_then(Value::as_arr)
        .map(|a| a.iter().filter_map(Value::as_usize).collect())
        .ok_or_else(|| anyhow!("missing shape"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"name":"tiny-llm","vocab":256,"d_model":128,"n_layers":4,
                "n_heads":4,"n_kv_heads":4,"head_dim":32,"ffn_dim":512,
                "block_size":16,"max_ctx":2048,"rope_theta":10000.0},
      "seed": 1234,
      "buckets": {"prefill_t":[64,256],"decode_b":[1,2],"budget_k":[4,128],
                  "chunk_t":[64],"chunk_past":256},
      "weights_bin": "weights.bin",
      "total_f32": 100,
      "weights": [{"name":"embedding","shape":[256,128],"offset_f32":0}],
      "entries": [{"name":"embed_1","file":"embed_1.hlo.txt","kind":"embed",
                   "bucket":{"n":1},
                   "params":[{"shape":[1],"dtype":"int32"},
                             {"shape":[256,128],"dtype":"float32"}]}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model.name, "tiny-llm");
        assert_eq!(m.seed, 1234);
        assert_eq!(m.weights[0].shape, vec![256, 128]);
        let e = m.entry("embed_1").unwrap();
        assert_eq!(e.kind, "embed");
        assert_eq!(e.bucket["n"], 1);
        assert_eq!(e.params[1], vec![256, 128]);
    }

    #[test]
    fn fit_bucket_picks_smallest_geq() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.fit_bucket("prefill_t", 1), Some(64));
        assert_eq!(m.fit_bucket("prefill_t", 64), Some(64));
        assert_eq!(m.fit_bucket("prefill_t", 65), Some(256));
        assert_eq!(m.fit_bucket("prefill_t", 257), None);
    }
}
