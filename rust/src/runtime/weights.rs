//! `weights.bin` loading: all model weights as host tensors, addressable
//! by name and pre-grouped per layer in entry-point parameter order.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;
use super::tensor::HostTensor;

/// Per-layer weight parameter order shared with `python/compile/model.py`
/// (`LAYER_WEIGHT_NAMES`).
pub const LAYER_WEIGHT_NAMES: [&str; 9] = [
    "attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down",
];

pub struct WeightStore {
    by_name: HashMap<String, HostTensor>,
}

impl WeightStore {
    pub fn load(dir: &Path, manifest: &Manifest) -> Result<Self> {
        let path = dir.join(&manifest.weights_bin);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != manifest.total_f32 * 4 {
            return Err(anyhow!(
                "weights.bin is {} bytes, manifest says {}",
                bytes.len(),
                manifest.total_f32 * 4
            ));
        }
        let all: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut by_name = HashMap::new();
        for w in &manifest.weights {
            let n: usize = w.shape.iter().product();
            let slice = all
                .get(w.offset_f32..w.offset_f32 + n)
                .ok_or_else(|| anyhow!("weight '{}' out of range", w.name))?;
            by_name.insert(w.name.clone(), HostTensor::f32(w.shape.clone(), slice.to_vec()));
        }
        Ok(Self { by_name })
    }

    pub fn get(&self, name: &str) -> &HostTensor {
        self.by_name
            .get(name)
            // sparselint: allow(panic-path) -- weight names come from the manifest's static entry-point layout, validated when the store loads; a miss is a build bug, not a serving state
            .unwrap_or_else(|| panic!("unknown weight '{name}'"))
    }

    /// Fully qualified weight names of layer `i` in entry-point order.
    pub fn layer_names(i: usize) -> Vec<String> {
        LAYER_WEIGHT_NAMES
            .iter()
            .map(|n| format!("l{i}.{n}"))
            .collect()
    }

    /// The 9 per-layer attention+FFN weights in model entry-point order.
    pub fn layer(&self, i: usize) -> Vec<&HostTensor> {
        LAYER_WEIGHT_NAMES
            .iter()
            .map(|n| self.get(&format!("l{i}.{n}")))
            .collect()
    }

    /// Subset of layer weights by name (decode_qkv needs attn_norm,wq,wk,wv;
    /// decode_attend needs wo,ffn_norm,w_gate,w_up,w_down).
    pub fn layer_subset(&self, i: usize, names: &[&str]) -> Vec<&HostTensor> {
        names.iter().map(|n| self.get(&format!("l{i}.{n}"))).collect()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.by_name.keys()
    }
}
