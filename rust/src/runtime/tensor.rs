//! Host tensors: the typed boundary between rust data structures and
//! PJRT literals.

use anyhow::{anyhow, Result};

use crate::xla;

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data: TensorData::I32(data) }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Self::f32(dims, vec![0.0; n])
    }

    /// Filled with a constant (e.g. NEG_INF masks).
    pub fn full(dims: Vec<usize>, v: f32) -> Self {
        let n = dims.iter().product();
        Self::f32(dims, vec![v; n])
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self::i32(vec![], vec![v])
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            // sparselint: allow(panic-path) -- dtype is fixed by the compiled entry-point signature; a mismatch is a build/manifest bug caught by the golden parity tests, not a serving state
            TensorData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            // sparselint: allow(panic-path) -- dtype is fixed by the compiled entry-point signature; a mismatch is a build/manifest bug caught by the golden parity tests, not a serving state
            TensorData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    /// Take the f32 storage back out of the tensor (buffer recovery:
    /// the zero-clone step pipeline rebuilds input tensors from recycled
    /// buffers and reclaims them after execution instead of reallocating
    /// — see `PjrtBackend`'s gather scratch and carried-activation
    /// handling).
    pub fn into_f32(self) -> Vec<f32> {
        match self.data {
            TensorData::F32(v) => v,
            // sparselint: allow(panic-path) -- dtype is fixed by the compiled entry-point signature; a mismatch is a build/manifest bug caught by the golden parity tests, not a serving state
            TensorData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            // sparselint: allow(panic-path) -- dtype is fixed by the compiled entry-point signature; a mismatch is a build/manifest bug caught by the golden parity tests, not a serving state
            TensorData::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    /// Take the i32 storage back out of the tensor (see
    /// [`Self::into_f32`]).
    pub fn into_i32(self) -> Vec<i32> {
        match self.data {
            TensorData::I32(v) => v,
            // sparselint: allow(panic-path) -- dtype is fixed by the compiled entry-point signature; a mismatch is a build/manifest bug caught by the golden parity tests, not a serving state
            TensorData::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, bytes): (xla::ElementType, &[u8]) = match &self.data {
            TensorData::F32(v) => (xla::ElementType::F32, bytemuck_f32(v)),
            TensorData::I32(v) => (xla::ElementType::S32, bytemuck_i32(v)),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, &self.dims, bytes)
            .map_err(|e| anyhow!("literal create: {e:?}"))
    }

    pub fn from_literal(lit: xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Self::f32(
                dims,
                lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?,
            )),
            xla::ElementType::S32 => Ok(Self::i32(
                dims,
                lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?,
            )),
            other => Err(anyhow!("unsupported literal element type {other:?}")),
        }
    }
}

fn bytemuck_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_check_len() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(HostTensor::zeros(vec![4]).as_f32(), &[0.0; 4]);
        assert_eq!(HostTensor::full(vec![2], -1.0).as_f32(), &[-1.0, -1.0]);
    }

    #[test]
    fn literal_round_trip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_round_trip_i32() {
        let t = HostTensor::i32(vec![3], vec![-1, 0, 7]);
        let back = HostTensor::from_literal(t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_round_trip_scalar() {
        let t = HostTensor::scalar_i32(5);
        let back = HostTensor::from_literal(t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32(), &[5]);
        assert!(back.dims.is_empty());
    }
}
