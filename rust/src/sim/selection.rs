//! Synthetic block-selection process with Fig. 8's temporal locality.
//!
//! At paper scale we cannot run the real model, but the serving dynamics
//! (cache hit rates, thrashing, working-set sizes) depend only on the
//! *statistics* of the selection sequence. The model here reproduces the
//! two properties the paper measures:
//!
//! 1. high step-to-step overlap (Fig. 8: ~0.85 at window 1 for the real
//!    model) — most of a step's selection repeats recent selections;
//! 2. saturating window gain (+10.7% from w=1..12, +0.3% beyond) — the
//!    non-repeated picks come from a slowly drifting hot set, so widening
//!    the history window recovers most stragglers quickly.
//!
//! Mechanics: each request keeps a current selection set. Every step,
//! each selected block is kept with probability `p_keep`; replacements
//! are drawn 50/50 from a per-request *hot pool* (2x budget, slowly
//! drifting) or uniformly from all sealed blocks. Selection granularity
//! is the block index, shared across layers/heads (DESIGN.md notes the
//! fidelity trade: per-(layer,head) selection multiplies cost-accounting
//! counts but not the dynamics).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SelectionModel {
    rng: Rng,
    /// Probability a selected block stays selected next step.
    p_keep: f64,
    /// Fraction of replacement draws taken from the hot pool.
    p_hot: f64,
    /// Hot-pool drift probability per step.
    p_drift: f64,
    current: Vec<u32>,
    hot: Vec<u32>,
}

impl SelectionModel {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::with_stream(seed, 404),
            // calibrated against Fig. 8 (see sim::selection tests):
            // overlap(w=1) ~ 0.85, saturating ~ +10% by w=12. Replacements
            // come almost entirely from the slowly-drifting hot pool, so a
            // warm HBM cache absorbs nearly all of them (Fig. 1: few loads
            // until the aggregate working set outgrows the cache).
            p_keep: 0.85,
            p_hot: 0.98,
            p_drift: 0.004,
            current: Vec::new(),
            hot: Vec::new(),
        }
    }

    /// Draw the next step's selection of `budget` sealed blocks out of
    /// `n_sealed` (returns fewer when fewer exist).
    pub fn next_selection(&mut self, n_sealed: usize, budget: usize) -> Vec<u32> {
        let want = budget.min(n_sealed);
        if want == 0 {
            self.current.clear();
            return Vec::new();
        }
        // refresh hot pool: drift a few entries, keep size ~2.5x budget
        // (sets the window-union working set at ~1.5-2x the budget, the
        // per-request HBM demand behind Fig. 15's thrashing onset)
        let hot_size = (budget * 5 / 2).min(n_sealed).max(1);
        while self.hot.len() < hot_size {
            let b = self.rng.below(n_sealed) as u32;
            if !self.hot.contains(&b) {
                self.hot.push(b);
            }
        }
        self.hot.truncate(hot_size);
        for i in 0..self.hot.len() {
            if self.rng.f64() < self.p_drift {
                self.hot[i] = self.rng.below(n_sealed) as u32;
            }
        }

        let mut next: Vec<u32> = Vec::with_capacity(want);
        // keep survivors (dedup via sorted insert; budgets are small)
        for &b in &self.current {
            if (b as usize) < n_sealed
                && next.len() < want
                && self.rng.f64() < self.p_keep
                && !next.contains(&b)
            {
                next.push(b);
            }
        }
        // refill from hot pool / uniform
        let mut guard = 0;
        while next.len() < want && guard < 10_000 {
            guard += 1;
            let b = if self.rng.f64() < self.p_hot {
                *self.rng.choose(&self.hot)
            } else {
                self.rng.below(n_sealed) as u32
            };
            if (b as usize) < n_sealed && !next.contains(&b) {
                next.push(b);
            }
        }
        // pathological fallback (tiny n_sealed): fill sequentially
        for b in 0..n_sealed as u32 {
            if next.len() >= want {
                break;
            }
            if !next.contains(&b) {
                next.push(b);
            }
        }
        self.current = next.clone();
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    /// Replicates the Fig. 8 measurement on the synthetic process.
    fn overlap_profile(windows: &[usize]) -> Vec<f64> {
        let mut m = SelectionModel::new(42);
        let n_sealed = 1024;
        let budget = 64;
        let mut history: Vec<HashSet<u32>> = Vec::new();
        for _ in 0..200 {
            history.push(m.next_selection(n_sealed, budget).into_iter().collect());
        }
        windows
            .iter()
            .map(|&w| {
                let mut os = Vec::new();
                for s in 20..history.len() {
                    let cur = &history[s];
                    let mut prev: HashSet<u32> = HashSet::new();
                    for h in history[s.saturating_sub(w)..s].iter() {
                        prev.extend(h);
                    }
                    os.push(cur.intersection(&prev).count() as f64 / cur.len() as f64);
                }
                os.iter().sum::<f64>() / os.len() as f64
            })
            .collect()
    }

    #[test]
    fn overlap_matches_fig8_shape() {
        let o = overlap_profile(&[1, 4, 8, 12, 16]);
        // high base overlap
        assert!(o[0] > 0.78 && o[0] < 0.95, "w=1 overlap {}", o[0]);
        // monotone rising
        for w in o.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
        // saturation: big gain 1->12, tiny gain 12->16 (paper: +10.68% / +0.31%)
        let gain_1_12 = o[3] - o[0];
        let gain_12_16 = o[4] - o[3];
        assert!(gain_1_12 > 0.03, "gain 1->12 {gain_1_12}");
        assert!(gain_12_16 < 0.02, "gain 12->16 {gain_12_16}");
        assert!(gain_12_16 < gain_1_12 / 3.0, "must saturate past w=12");
    }

    #[test]
    fn selection_size_bounded() {
        let mut m = SelectionModel::new(1);
        for n in [0usize, 1, 3, 100] {
            let s = m.next_selection(n, 8);
            assert_eq!(s.len(), n.min(8));
            let set: HashSet<u32> = s.iter().copied().collect();
            assert_eq!(set.len(), s.len(), "duplicates in selection");
            assert!(s.iter().all(|&b| (b as usize) < n));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SelectionModel::new(5);
        let mut b = SelectionModel::new(5);
        for _ in 0..10 {
            assert_eq!(a.next_selection(100, 10), b.next_selection(100, 10));
        }
    }
}
