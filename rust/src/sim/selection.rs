//! Synthetic block-selection process with Fig. 8's temporal locality.
//!
//! At paper scale we cannot run the real model, but the serving dynamics
//! (cache hit rates, thrashing, working-set sizes) depend only on the
//! *statistics* of the selection sequence. The model here reproduces the
//! two properties the paper measures:
//!
//! 1. high step-to-step overlap (Fig. 8: ~0.85 at window 1 for the real
//!    model) — most of a step's selection repeats recent selections;
//! 2. saturating window gain (+10.7% from w=1..12, +0.3% beyond) — the
//!    non-repeated picks come from a slowly drifting hot set, so widening
//!    the history window recovers most stragglers quickly.
//!
//! Mechanics: each request keeps a current selection set. Every step,
//! each selected block is kept with probability `p_keep`; replacements
//! are drawn 50/50 from a per-request *hot pool* (2x budget, slowly
//! drifting) or uniformly from all sealed blocks. Selection granularity
//! is the block index, shared across layers/heads (DESIGN.md notes the
//! fidelity trade: per-(layer,head) selection multiplies cost-accounting
//! counts but not the dynamics).
//!
//! ## Hot-path contract (zero-clone step pipeline)
//!
//! The model runs once per decode request per iteration, so it supports
//! allocation-free steady-state operation:
//!
//! - [`SelectionModel::next_selection_into`] draws into a caller-owned
//!   buffer (no per-step `Vec` churn);
//! - `begin_txn` / `commit_txn` / `rollback_txn` form a record-and-revert
//!   undo log (mirroring `KvManager::{begin,commit,rollback}_txn`):
//!   `begin_txn` copies the RNG state and the small `current`/`hot`
//!   pools into recycled buffers, `rollback_txn` swaps them back —
//!   replacing the old clone-the-whole-model rollback snapshot.

use std::cell::Cell;

use crate::util::rng::Rng;

thread_local! {
    static SEL_CLONES: Cell<u64> = const { Cell::new(0) };
}

/// Clones of [`SelectionModel`] performed by the calling thread — the
/// test hook behind the zero-clone steady-state criterion.
pub fn selection_clones_this_thread() -> u64 {
    SEL_CLONES.with(|c| c.get())
}

#[derive(Debug)]
pub struct SelectionModel {
    rng: Rng,
    /// Probability a selected block stays selected next step.
    p_keep: f64,
    /// Fraction of replacement draws taken from the hot pool.
    p_hot: f64,
    /// Hot-pool drift probability per step.
    p_drift: f64,
    current: Vec<u32>,
    hot: Vec<u32>,
    // ---- open undo scope (armed by `begin_txn`); buffers recycled ----
    txn_open: bool,
    undo_rng: Rng,
    undo_current: Vec<u32>,
    undo_hot: Vec<u32>,
}

impl Clone for SelectionModel {
    /// Hand-written so the thread-local clone probe counts every copy:
    /// the decode steady state must perform none.
    fn clone(&self) -> Self {
        SEL_CLONES.with(|c| c.set(c.get() + 1));
        debug_assert!(!self.txn_open, "cloning a model with an open undo scope");
        Self {
            rng: self.rng.clone(),
            p_keep: self.p_keep,
            p_hot: self.p_hot,
            p_drift: self.p_drift,
            current: self.current.clone(),
            hot: self.hot.clone(),
            txn_open: false,
            undo_rng: Rng::new(0),
            undo_current: Vec::new(),
            undo_hot: Vec::new(),
        }
    }
}

impl SelectionModel {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::with_stream(seed, 404),
            // calibrated against Fig. 8 (see sim::selection tests):
            // overlap(w=1) ~ 0.85, saturating ~ +10% by w=12. Replacements
            // come almost entirely from the slowly-drifting hot pool, so a
            // warm HBM cache absorbs nearly all of them (Fig. 1: few loads
            // until the aggregate working set outgrows the cache).
            p_keep: 0.85,
            p_hot: 0.98,
            p_drift: 0.004,
            current: Vec::new(),
            hot: Vec::new(),
            txn_open: false,
            undo_rng: Rng::new(0),
            undo_current: Vec::new(),
            undo_hot: Vec::new(),
        }
    }

    // ------------------------------------------------------ undo scope

    /// Begin an undo scope: the RNG state and the `current`/`hot` pools
    /// are copied into recycled buffers (a ~1 KB memcpy, no allocation
    /// once warm) so a subsequent [`Self::rollback_txn`] restores the
    /// model exactly.
    pub fn begin_txn(&mut self) {
        debug_assert!(!self.txn_open, "nested SelectionModel txn");
        self.txn_open = true;
        self.undo_rng = self.rng.clone();
        self.undo_current.clear();
        self.undo_current.extend_from_slice(&self.current);
        self.undo_hot.clear();
        self.undo_hot.extend_from_slice(&self.hot);
    }

    /// Keep everything drawn since `begin_txn`. No-op without a scope.
    pub fn commit_txn(&mut self) {
        self.txn_open = false;
    }

    /// Revert to the `begin_txn` state: RNG, current selection and hot
    /// pool all restored exactly (the retried step replays identically).
    /// No-op without a scope.
    pub fn rollback_txn(&mut self) {
        if !self.txn_open {
            return;
        }
        self.txn_open = false;
        self.rng = self.undo_rng.clone();
        std::mem::swap(&mut self.current, &mut self.undo_current);
        std::mem::swap(&mut self.hot, &mut self.undo_hot);
    }

    // -------------------------------------------------------- sampling

    /// Draw the next step's selection of `budget` sealed blocks out of
    /// `n_sealed` (returns fewer when fewer exist).
    pub fn next_selection(&mut self, n_sealed: usize, budget: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.next_selection_into(n_sealed, budget, &mut out);
        out
    }

    /// [`Self::next_selection`] into a caller-owned buffer (cleared
    /// first) — the per-iteration hot path allocates nothing once the
    /// buffer is warm. Draw-for-draw identical to the allocating
    /// variant.
    pub fn next_selection_into(&mut self, n_sealed: usize, budget: usize, out: &mut Vec<u32>) {
        out.clear();
        let want = budget.min(n_sealed);
        if want == 0 {
            self.current.clear();
            return;
        }
        // refresh hot pool: drift a few entries, keep size ~2.5x budget
        // (sets the window-union working set at ~1.5-2x the budget, the
        // per-request HBM demand behind Fig. 15's thrashing onset)
        let hot_size = (budget * 5 / 2).min(n_sealed).max(1);
        while self.hot.len() < hot_size {
            let b = self.rng.below(n_sealed) as u32;
            if !self.hot.contains(&b) {
                self.hot.push(b);
            }
        }
        self.hot.truncate(hot_size);
        for i in 0..self.hot.len() {
            if self.rng.f64() < self.p_drift {
                self.hot[i] = self.rng.below(n_sealed) as u32;
            }
        }

        // keep survivors (dedup via linear scan; budgets are small)
        for &b in &self.current {
            if (b as usize) < n_sealed
                && out.len() < want
                && self.rng.f64() < self.p_keep
                && !out.contains(&b)
            {
                out.push(b);
            }
        }
        // refill from hot pool / uniform
        let mut guard = 0;
        while out.len() < want && guard < 10_000 {
            guard += 1;
            let b = if self.rng.f64() < self.p_hot {
                *self.rng.choose(&self.hot)
            } else {
                self.rng.below(n_sealed) as u32
            };
            if (b as usize) < n_sealed && !out.contains(&b) {
                out.push(b);
            }
        }
        // pathological fallback (tiny n_sealed): fill sequentially
        for b in 0..n_sealed as u32 {
            if out.len() >= want {
                break;
            }
            if !out.contains(&b) {
                out.push(b);
            }
        }
        self.current.clear();
        self.current.extend_from_slice(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    /// Replicates the Fig. 8 measurement on the synthetic process.
    fn overlap_profile(windows: &[usize]) -> Vec<f64> {
        let mut m = SelectionModel::new(42);
        let n_sealed = 1024;
        let budget = 64;
        let mut history: Vec<HashSet<u32>> = Vec::new();
        for _ in 0..200 {
            history.push(m.next_selection(n_sealed, budget).into_iter().collect());
        }
        windows
            .iter()
            .map(|&w| {
                let mut os = Vec::new();
                for s in 20..history.len() {
                    let cur = &history[s];
                    let mut prev: HashSet<u32> = HashSet::new();
                    for h in history[s.saturating_sub(w)..s].iter() {
                        prev.extend(h);
                    }
                    os.push(cur.intersection(&prev).count() as f64 / cur.len() as f64);
                }
                os.iter().sum::<f64>() / os.len() as f64
            })
            .collect()
    }

    #[test]
    fn overlap_matches_fig8_shape() {
        let o = overlap_profile(&[1, 4, 8, 12, 16]);
        // high base overlap
        assert!(o[0] > 0.78 && o[0] < 0.95, "w=1 overlap {}", o[0]);
        // monotone rising
        for w in o.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
        // saturation: big gain 1->12, tiny gain 12->16 (paper: +10.68% / +0.31%)
        let gain_1_12 = o[3] - o[0];
        let gain_12_16 = o[4] - o[3];
        assert!(gain_1_12 > 0.03, "gain 1->12 {gain_1_12}");
        assert!(gain_12_16 < 0.02, "gain 12->16 {gain_12_16}");
        assert!(gain_12_16 < gain_1_12 / 3.0, "must saturate past w=12");
    }

    #[test]
    fn selection_size_bounded() {
        let mut m = SelectionModel::new(1);
        for n in [0usize, 1, 3, 100] {
            let s = m.next_selection(n, 8);
            assert_eq!(s.len(), n.min(8));
            let set: HashSet<u32> = s.iter().copied().collect();
            assert_eq!(set.len(), s.len(), "duplicates in selection");
            assert!(s.iter().all(|&b| (b as usize) < n));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SelectionModel::new(5);
        let mut b = SelectionModel::new(5);
        for _ in 0..10 {
            assert_eq!(a.next_selection(100, 10), b.next_selection(100, 10));
        }
    }

    #[test]
    fn into_variant_matches_allocating_draw_for_draw() {
        let mut a = SelectionModel::new(77);
        let mut b = SelectionModel::new(77);
        let mut buf = Vec::new();
        for step in 0..20 {
            let n = 16 + step * 8;
            b.next_selection_into(n, 12, &mut buf);
            assert_eq!(a.next_selection(n, 12), buf, "step {step}");
        }
    }

    #[test]
    fn txn_rollback_restores_model_exactly() {
        let mut m = SelectionModel::new(9);
        for _ in 0..5 {
            m.next_selection(512, 32);
        }
        let reference = m.clone(); // the old, expensive rollback path
        m.begin_txn();
        let drawn = m.next_selection(512, 32);
        assert!(!drawn.is_empty());
        m.rollback_txn();
        assert_eq!(m.current, reference.current, "current pool restored");
        assert_eq!(m.hot, reference.hot, "hot pool restored");
        // identical future: the retried step replays the aborted draw
        let mut r = reference;
        for _ in 0..6 {
            assert_eq!(m.next_selection(512, 32), r.next_selection(512, 32));
        }
    }

    #[test]
    fn txn_commit_keeps_the_draw() {
        let mut m = SelectionModel::new(3);
        m.next_selection(256, 16);
        m.begin_txn();
        let drawn = m.next_selection(256, 16);
        m.commit_txn();
        assert_eq!(m.current, drawn);
        // scope-less txn calls are harmless no-ops
        m.rollback_txn();
        assert_eq!(m.current, drawn);
    }

    #[test]
    fn repeated_txns_reuse_undo_buffers() {
        let mut m = SelectionModel::new(4);
        m.next_selection(512, 32);
        m.begin_txn();
        m.next_selection(512, 32);
        m.rollback_txn();
        let cap_cur = m.undo_current.capacity();
        let cap_hot = m.undo_hot.capacity();
        for _ in 0..8 {
            m.begin_txn();
            m.next_selection(512, 32);
            m.rollback_txn();
        }
        assert_eq!(m.undo_current.capacity(), cap_cur, "undo buffer churned");
        assert_eq!(m.undo_hot.capacity(), cap_hot, "undo buffer churned");
    }

    #[test]
    fn clone_probe_counts_thread_local_clones() {
        let m = SelectionModel::new(1);
        let before = selection_clones_this_thread();
        let _c = m.clone();
        assert_eq!(selection_clones_this_thread(), before + 1);
    }
}
