//! Synthetic block-selection process with Fig. 8's temporal locality.
//!
//! At paper scale we cannot run the real model, but the serving dynamics
//! (cache hit rates, thrashing, working-set sizes) depend only on the
//! *statistics* of the selection sequence. The model here reproduces the
//! two properties the paper measures:
//!
//! 1. high step-to-step overlap (Fig. 8: ~0.85 at window 1 for the real
//!    model) — most of a step's selection repeats recent selections;
//! 2. saturating window gain (+10.7% from w=1..12, +0.3% beyond) — the
//!    non-repeated picks come from a slowly drifting hot set, so widening
//!    the history window recovers most stragglers quickly.
//!
//! Mechanics: each request keeps a current selection set per **layer
//! band**. Every step, each selected block is kept with probability
//! `p_keep`; replacements are drawn 50/50 from a per-request *hot pool*
//! (2x budget, slowly drifting) or uniformly from all sealed blocks.
//!
//! ## Layer bands
//!
//! Real DSAs (Quest-style per-layer top-k criticality, H2O-style
//! layer-varying hot sets) select *per layer*, and their cache misses
//! are discovered layer by layer with strong layer skew. The model
//! approximates this with `K` **layer bands** ([`Self::with_bands`]):
//! each band keeps its own current selection (drawn independently per
//! decode step, so per-band sequences have the same marginal Fig. 8
//! statistics as the old iteration-granular draw), while all bands share
//! ONE drifting hot pool — the cross-band correlation real models show
//! (a block hot at layer 5 is likely hot at layer 20 too). `K = 1`
//! reproduces the old iteration-granular process draw-for-draw.
//!
//! The `layer_skew` knob in [-1, 1] tilts the per-band *churn* (the
//! non-kept fraction of each draw) linearly across bands while keeping
//! the total churn — and hence the aggregate miss volume — constant:
//! negative skew concentrates fresh picks (and therefore cache misses)
//! in EARLY bands, positive skew in LATE bands. Miss discovery timing is
//! exactly what the per-layer event model ([`super::layered_iter`])
//! prices: early misses hide under the remaining layers' compute, late
//! misses cannot.
//!
//! ## Hot-path contract (zero-clone step pipeline)
//!
//! The model runs once per decode request per iteration, so it supports
//! allocation-free steady-state operation:
//!
//! - [`SelectionModel::next_band_selection_into`] draws into a
//!   caller-owned buffer (no per-step `Vec` churn);
//! - `begin_txn` / `commit_txn` / `rollback_txn` form a record-and-revert
//!   undo log (mirroring `KvManager::{begin,commit,rollback}_txn`):
//!   `begin_txn` copies the RNG state and the small per-band
//!   `current`/`hot` pools into recycled buffers, `rollback_txn` swaps
//!   them back — replacing the old clone-the-whole-model rollback
//!   snapshot.

use std::cell::Cell;

use crate::util::rng::Rng;

thread_local! {
    static SEL_CLONES: Cell<u64> = const { Cell::new(0) };
}

/// Clones of [`SelectionModel`] performed by the calling thread — the
/// test hook behind the zero-clone steady-state criterion.
pub fn selection_clones_this_thread() -> u64 {
    SEL_CLONES.with(|c| c.get())
}

#[derive(Debug)]
pub struct SelectionModel {
    rng: Rng,
    /// Probability a selected block stays selected next step.
    p_keep: f64,
    /// Fraction of replacement draws taken from the hot pool.
    p_hot: f64,
    /// Hot-pool drift probability per step.
    p_drift: f64,
    /// Layer bands K (1 = the old iteration-granular process).
    bands: usize,
    /// Churn tilt across bands in [-1, 1] (0 = uniform).
    layer_skew: f64,
    /// Per-band current selection.
    current: Vec<Vec<u32>>,
    /// Shared drifting hot pool (band-correlated keep/drift).
    hot: Vec<u32>,
    // ---- open undo scope (armed by `begin_txn`); buffers recycled ----
    txn_open: bool,
    undo_rng: Rng,
    undo_current: Vec<Vec<u32>>,
    undo_hot: Vec<u32>,
}

impl Clone for SelectionModel {
    /// Hand-written so the thread-local clone probe counts every copy:
    /// the decode steady state must perform none.
    fn clone(&self) -> Self {
        SEL_CLONES.with(|c| c.set(c.get() + 1));
        debug_assert!(!self.txn_open, "cloning a model with an open undo scope");
        Self {
            rng: self.rng,
            p_keep: self.p_keep,
            p_hot: self.p_hot,
            p_drift: self.p_drift,
            bands: self.bands,
            layer_skew: self.layer_skew,
            current: self.current.clone(),
            hot: self.hot.clone(),
            txn_open: false,
            undo_rng: Rng::new(0),
            undo_current: Vec::new(),
            undo_hot: Vec::new(),
        }
    }
}

impl SelectionModel {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::with_stream(seed, 404),
            // calibrated against Fig. 8 (see sim::selection tests):
            // overlap(w=1) ~ 0.85, saturating ~ +10% by w=12. Replacements
            // come almost entirely from the slowly-drifting hot pool, so a
            // warm HBM cache absorbs nearly all of them (Fig. 1: few loads
            // until the aggregate working set outgrows the cache).
            p_keep: 0.85,
            p_hot: 0.98,
            p_drift: 0.004,
            bands: 1,
            layer_skew: 0.0,
            current: vec![Vec::new()],
            hot: Vec::new(),
            txn_open: false,
            undo_rng: Rng::new(0),
            undo_current: Vec::new(),
            undo_hot: Vec::new(),
        }
    }

    /// Split the selection process into `bands` layer bands with the
    /// given churn skew (clamped to [-1, 1]). Each band keeps its own
    /// current selection; the hot pool stays shared. `bands = 1` is the
    /// iteration-granular process regardless of skew.
    pub fn with_bands(mut self, bands: usize, layer_skew: f64) -> Self {
        self.bands = bands.max(1);
        self.layer_skew = layer_skew.clamp(-1.0, 1.0);
        self.current.resize(self.bands, Vec::new());
        self
    }

    pub fn bands(&self) -> usize {
        self.bands
    }

    /// Effective keep probability of one band: the churn `1 - p_keep` is
    /// tilted linearly across bands by `layer_skew`, preserving the total
    /// churn (and hence the aggregate fresh-pick / miss volume) exactly
    /// in expectation: `sum_b churn_b = K * (1 - p_keep)` for any skew.
    fn band_p_keep(&self, band: usize) -> f64 {
        if self.bands <= 1 {
            return self.p_keep;
        }
        let tilt = 2.0 * band as f64 / (self.bands - 1) as f64 - 1.0;
        let churn = ((1.0 - self.p_keep) * (1.0 + self.layer_skew * tilt)).clamp(0.0, 1.0);
        1.0 - churn
    }

    // ------------------------------------------------------ undo scope

    /// Begin an undo scope: the RNG state and the per-band
    /// `current`/`hot` pools are copied into recycled buffers (a ~1 KB
    /// memcpy per band, no allocation once warm) so a subsequent
    /// [`Self::rollback_txn`] restores the model exactly.
    pub fn begin_txn(&mut self) {
        debug_assert!(!self.txn_open, "nested SelectionModel txn");
        self.txn_open = true;
        self.undo_rng = self.rng;
        // sparselint: allow(hot-path-reach) -- empty-vec filler for recycled undo buffers: resize never allocates element storage, and the outer vec grows once then stays
        self.undo_current.resize(self.current.len(), Vec::new());
        for (u, c) in self.undo_current.iter_mut().zip(&self.current) {
            u.clear();
            u.extend_from_slice(c);
        }
        self.undo_hot.clear();
        self.undo_hot.extend_from_slice(&self.hot);
    }

    /// Keep everything drawn since `begin_txn`. No-op without a scope.
    pub fn commit_txn(&mut self) {
        self.txn_open = false;
    }

    /// Revert to the `begin_txn` state: RNG, per-band current selections
    /// and hot pool all restored exactly (the retried step replays
    /// identically). No-op without a scope.
    pub fn rollback_txn(&mut self) {
        if !self.txn_open {
            return;
        }
        self.txn_open = false;
        self.rng = self.undo_rng;
        for (c, u) in self.current.iter_mut().zip(&mut self.undo_current) {
            std::mem::swap(c, u);
        }
        std::mem::swap(&mut self.hot, &mut self.undo_hot);
    }

    // -------------------------------------------------------- sampling

    /// Draw the next step's selection of `budget` sealed blocks out of
    /// `n_sealed` (returns fewer when fewer exist). Iteration-granular
    /// shorthand for [`Self::next_band_selection_into`] on band 0.
    pub fn next_selection(&mut self, n_sealed: usize, budget: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.next_band_selection_into(0, n_sealed, budget, &mut out);
        out
    }

    /// [`Self::next_selection`] into a caller-owned buffer (cleared
    /// first) — the per-iteration hot path allocates nothing once the
    /// buffer is warm. Draw-for-draw identical to the allocating
    /// variant.
    // sparselint: hot
    pub fn next_selection_into(&mut self, n_sealed: usize, budget: usize, out: &mut Vec<u32>) {
        self.next_band_selection_into(0, n_sealed, budget, out);
    }

    /// Refresh the shared hot pool for a new decode step: grow to
    /// ~2.5x budget, then drift a few entries. Runs once per step (at
    /// band 0), so all bands of the step draw from the same hot set.
    fn refresh_hot(&mut self, n_sealed: usize, budget: usize) {
        // hot size ~2.5x budget sets the window-union working set at
        // ~1.5-2x the budget, the per-request HBM demand behind Fig. 15's
        // thrashing onset
        let hot_size = (budget * 5 / 2).min(n_sealed).max(1);
        while self.hot.len() < hot_size {
            let b = self.rng.below(n_sealed) as u32;
            if !self.hot.contains(&b) {
                self.hot.push(b);
            }
        }
        self.hot.truncate(hot_size);
        for i in 0..self.hot.len() {
            if self.rng.f64() < self.p_drift {
                self.hot[i] = self.rng.below(n_sealed) as u32;
            }
        }
    }

    /// Draw one layer band's next selection into a caller-owned buffer.
    /// The simulator calls bands `0..K` in order once per decode step;
    /// band 0 advances the shared hot pool (one drift per step). For
    /// `bands == 1` this is draw-for-draw the old iteration-granular
    /// process.
    // sparselint: hot
    pub fn next_band_selection_into(
        &mut self,
        band: usize,
        n_sealed: usize,
        budget: usize,
        out: &mut Vec<u32>,
    ) {
        debug_assert!(band < self.bands, "band {band} out of {}", self.bands);
        out.clear();
        let want = budget.min(n_sealed);
        if want == 0 {
            self.current[band].clear();
            return;
        }
        if band == 0 || self.hot.is_empty() {
            self.refresh_hot(n_sealed, budget);
        }
        // keep survivors (dedup via linear scan; budgets are small)
        let p_keep = self.band_p_keep(band);
        for &b in &self.current[band] {
            if (b as usize) < n_sealed
                && out.len() < want
                && self.rng.f64() < p_keep
                && !out.contains(&b)
            {
                out.push(b);
            }
        }
        // refill from hot pool / uniform
        let mut guard = 0;
        while out.len() < want && guard < 10_000 {
            guard += 1;
            let b = if self.rng.f64() < self.p_hot {
                *self.rng.choose(&self.hot)
            } else {
                self.rng.below(n_sealed) as u32
            };
            if (b as usize) < n_sealed && !out.contains(&b) {
                out.push(b);
            }
        }
        // pathological fallback (tiny n_sealed): fill sequentially
        for b in 0..n_sealed as u32 {
            if out.len() >= want {
                break;
            }
            if !out.contains(&b) {
                out.push(b);
            }
        }
        self.current[band].clear();
        self.current[band].extend_from_slice(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    /// Replicates the Fig. 8 measurement on the synthetic process:
    /// per-band overlap profiles averaged across bands (for `bands = 1`
    /// this is exactly the old iteration-granular measurement).
    fn overlap_profile(bands: usize, skew: f64, windows: &[usize]) -> Vec<f64> {
        let mut m = SelectionModel::new(42).with_bands(bands, skew);
        let n_sealed = 1024;
        let budget = 64;
        // history[band][step]
        let mut history: Vec<Vec<HashSet<u32>>> = vec![Vec::new(); bands];
        let mut buf = Vec::new();
        for _ in 0..200 {
            for (band, hist) in history.iter_mut().enumerate() {
                m.next_band_selection_into(band, n_sealed, budget, &mut buf);
                hist.push(buf.iter().copied().collect());
            }
        }
        windows
            .iter()
            .map(|&w| {
                let mut os = Vec::new();
                for hist in &history {
                    for s in 20..hist.len() {
                        let cur = &hist[s];
                        let mut prev: HashSet<u32> = HashSet::new();
                        for h in hist[s.saturating_sub(w)..s].iter() {
                            prev.extend(h);
                        }
                        os.push(cur.intersection(&prev).count() as f64 / cur.len() as f64);
                    }
                }
                os.iter().sum::<f64>() / os.len() as f64
            })
            .collect()
    }

    #[test]
    fn overlap_matches_fig8_shape() {
        let o = overlap_profile(1, 0.0, &[1, 4, 8, 12, 16]);
        // high base overlap
        assert!(o[0] > 0.78 && o[0] < 0.95, "w=1 overlap {}", o[0]);
        // monotone rising
        for w in o.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
        // saturation: big gain 1->12, tiny gain 12->16 (paper: +10.68% / +0.31%)
        let gain_1_12 = o[3] - o[0];
        let gain_12_16 = o[4] - o[3];
        assert!(gain_1_12 > 0.03, "gain 1->12 {gain_1_12}");
        assert!(gain_12_16 < 0.02, "gain 12->16 {gain_12_16}");
        assert!(gain_12_16 < gain_1_12 / 3.0, "must saturate past w=12");
    }

    #[test]
    fn banded_selection_preserves_fig8_aggregate_stats() {
        // acceptance criterion for the per-layer-band refactor: the
        // aggregate (across-band) selection statistics of the K-band
        // model must match the iteration-granular model within tolerance,
        // so the Fig. 8 calibration survives the refactor.
        let windows = [1usize, 12, 16];
        let base = overlap_profile(1, 0.0, &windows);
        let banded = overlap_profile(4, 0.0, &windows);
        assert!(
            (banded[0] - base[0]).abs() < 0.05,
            "w=1 overlap drifted: banded {} vs base {}",
            banded[0],
            base[0]
        );
        // same saturating-window structure
        let gain_1_12 = banded[1] - banded[0];
        let gain_12_16 = banded[2] - banded[1];
        assert!(gain_1_12 > 0.03, "banded gain 1->12 {gain_1_12}");
        assert!(gain_12_16 < 0.02, "banded gain 12->16 {gain_12_16}");
        assert!(
            (gain_1_12 - (base[1] - base[0])).abs() < 0.05,
            "window gain drifted: banded {gain_1_12} vs base {}",
            base[1] - base[0]
        );
        // skewed churn keeps the MEAN overlap close too (the tilt is
        // total-churn-preserving; only the per-band distribution moves)
        let skewed = overlap_profile(4, 0.8, &windows);
        assert!(
            (skewed[0] - base[0]).abs() < 0.07,
            "skew must not change aggregate overlap: {} vs {}",
            skewed[0],
            base[0]
        );
    }

    #[test]
    fn layer_skew_tilts_churn_across_bands_preserving_totals() {
        // measure per-band fresh-pick (churn) counts over many steps:
        // positive skew must concentrate churn in LATE bands, and the
        // total churn must stay within tolerance of the unskewed run.
        let churn_per_band = |skew: f64| -> Vec<f64> {
            let bands = 4;
            let mut m = SelectionModel::new(7).with_bands(bands, skew);
            let (n_sealed, budget, steps) = (1024, 64, 150);
            let mut prev: Vec<HashSet<u32>> = vec![HashSet::new(); bands];
            let mut fresh = vec![0.0f64; bands];
            let mut buf = Vec::new();
            for s in 0..steps {
                for band in 0..bands {
                    m.next_band_selection_into(band, n_sealed, budget, &mut buf);
                    if s > 0 {
                        fresh[band] +=
                            buf.iter().filter(|b| !prev[band].contains(b)).count() as f64;
                    }
                    prev[band] = buf.iter().copied().collect();
                }
            }
            fresh
        };
        let flat = churn_per_band(0.0);
        let late = churn_per_band(0.9);
        assert!(
            late[3] > 1.5 * late[0],
            "positive skew must churn late bands most: {late:?}"
        );
        let total_flat: f64 = flat.iter().sum();
        let total_late: f64 = late.iter().sum();
        let ratio = total_late / total_flat;
        assert!(
            (0.8..1.25).contains(&ratio),
            "skew must preserve total churn: flat {total_flat} late {total_late}"
        );
    }

    #[test]
    fn selection_size_bounded() {
        let mut m = SelectionModel::new(1);
        for n in [0usize, 1, 3, 100] {
            let s = m.next_selection(n, 8);
            assert_eq!(s.len(), n.min(8));
            let set: HashSet<u32> = s.iter().copied().collect();
            assert_eq!(set.len(), s.len(), "duplicates in selection");
            assert!(s.iter().all(|&b| (b as usize) < n));
        }
    }

    #[test]
    fn banded_selection_size_bounded_per_band() {
        let mut m = SelectionModel::new(1).with_bands(3, 0.5);
        let mut buf = Vec::new();
        for n in [0usize, 1, 3, 100] {
            for band in 0..3 {
                m.next_band_selection_into(band, n, 8, &mut buf);
                assert_eq!(buf.len(), n.min(8));
                let set: HashSet<u32> = buf.iter().copied().collect();
                assert_eq!(set.len(), buf.len(), "duplicates in band selection");
                assert!(buf.iter().all(|&b| (b as usize) < n));
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SelectionModel::new(5);
        let mut b = SelectionModel::new(5);
        for _ in 0..10 {
            assert_eq!(a.next_selection(100, 10), b.next_selection(100, 10));
        }
    }

    #[test]
    fn into_variant_matches_allocating_draw_for_draw() {
        let mut a = SelectionModel::new(77);
        let mut b = SelectionModel::new(77);
        let mut buf = Vec::new();
        for step in 0..20 {
            let n = 16 + step * 8;
            b.next_selection_into(n, 12, &mut buf);
            assert_eq!(a.next_selection(n, 12), buf, "step {step}");
        }
    }

    #[test]
    fn txn_rollback_restores_model_exactly() {
        let mut m = SelectionModel::new(9).with_bands(4, 0.5);
        let mut buf = Vec::new();
        for _ in 0..5 {
            for band in 0..4 {
                m.next_band_selection_into(band, 512, 32, &mut buf);
            }
        }
        let reference = m.clone(); // the old, expensive rollback path
        m.begin_txn();
        for band in 0..4 {
            m.next_band_selection_into(band, 512, 32, &mut buf);
            assert!(!buf.is_empty());
        }
        m.rollback_txn();
        assert_eq!(m.current, reference.current, "per-band pools restored");
        assert_eq!(m.hot, reference.hot, "hot pool restored");
        // identical future: the retried step replays the aborted draws
        let mut r = reference;
        let mut rbuf = Vec::new();
        for _ in 0..6 {
            for band in 0..4 {
                m.next_band_selection_into(band, 512, 32, &mut buf);
                r.next_band_selection_into(band, 512, 32, &mut rbuf);
                assert_eq!(buf, rbuf, "band {band} diverged after rollback");
            }
        }
    }

    #[test]
    fn txn_commit_keeps_the_draw() {
        let mut m = SelectionModel::new(3);
        m.next_selection(256, 16);
        m.begin_txn();
        let drawn = m.next_selection(256, 16);
        m.commit_txn();
        assert_eq!(m.current[0], drawn);
        // scope-less txn calls are harmless no-ops
        m.rollback_txn();
        assert_eq!(m.current[0], drawn);
    }

    #[test]
    fn repeated_txns_reuse_undo_buffers() {
        let mut m = SelectionModel::new(4).with_bands(2, 0.0);
        let mut buf = Vec::new();
        for band in 0..2 {
            m.next_band_selection_into(band, 512, 32, &mut buf);
        }
        m.begin_txn();
        m.next_selection(512, 32);
        m.rollback_txn();
        let cap_cur: Vec<usize> = m.undo_current.iter().map(Vec::capacity).collect();
        let cap_hot = m.undo_hot.capacity();
        for _ in 0..8 {
            m.begin_txn();
            for band in 0..2 {
                m.next_band_selection_into(band, 512, 32, &mut buf);
            }
            m.rollback_txn();
        }
        let cap_now: Vec<usize> = m.undo_current.iter().map(Vec::capacity).collect();
        assert_eq!(cap_now, cap_cur, "undo buffer churned");
        assert_eq!(m.undo_hot.capacity(), cap_hot, "undo buffer churned");
    }

    #[test]
    fn clone_probe_counts_thread_local_clones() {
        let m = SelectionModel::new(1);
        let before = selection_clones_this_thread();
        let _c = m.clone();
        assert_eq!(selection_clones_this_thread(), before + 1);
    }
}
