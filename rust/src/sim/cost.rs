//! Analytic GPU + PCIe cost model for paper-scale iterations.
//!
//! Compute follows the standard transformer FLOP/byte accounting with a
//! roofline `max(flops / gpu_flops, bytes / hbm_bw)` per phase; PCIe
//! costs come from the calibrated [`HardwareSpec`] engine models. The
//! unit tests pin the derived *ratios* to what the paper reports
//! (chunked prefill overhead, Fig. 16b; saving overhead, Fig. 14b).

use crate::config::serving::TransferKind;
use crate::config::{HardwareSpec, ModelSpec};

#[derive(Debug, Clone)]
pub struct CostModel {
    pub spec: ModelSpec,
    pub hw: HardwareSpec,
}

/// Timing of one iteration under the two-stream event model.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IterTiming {
    /// GPU compute time of the batch.
    pub compute_s: f64,
    /// Copy-stream time hidden under compute (the overlap the
    /// prefetcher earned).
    pub hidden_s: f64,
    /// Critical-path excess: demand loads plus prefetch spill past the
    /// compute window.
    pub stall_s: f64,
    /// `compute_s + stall_s`.
    pub iter_time_s: f64,
}

/// Two-stream (compute + copy) iteration event model.
///
/// The copy stream carries two kinds of traffic:
///
/// - **prefetch** bytes were issued *before* the batch needed them, so
///   they run concurrently with compute: up to `compute_s` of them are
///   hidden; anything beyond spills onto the critical path (loading
///   "cannot be fully hidden by computation" once it outgrows the
///   compute window).
/// - **demand** bytes are misses discovered at selection time — the
///   gather blocks on them, so they always stall the iteration.
///
/// This replaces the old hard-coded `0.5 * compute` overlap credit:
/// overlap is now a measured property of how many bytes the prefetcher
/// actually moved ahead of need, so the no-prefetch ablation pays the
/// full demand stall and the prefetch-on run only pays for what staging
/// could not hide.
///
/// This is the *coarse* reference model ([`crate::config::IterModel::
/// Coarse`]): every demand byte stalls, no matter which layer discovered
/// it. The default simulator timing is the per-layer event model
/// ([`layered_iter`]); `bench` compares the two.
pub fn two_stream_iter(compute_s: f64, prefetch_s: f64, demand_s: f64) -> IterTiming {
    let hidden_s = prefetch_s.min(compute_s);
    let spill_s = prefetch_s - hidden_s;
    let stall_s = demand_s + spill_s;
    IterTiming { compute_s, hidden_s, stall_s, iter_time_s: compute_s + stall_s }
}

/// Per-layer iteration event model ([`crate::config::IterModel::
/// PerLayer`]).
///
/// The coarse model charges every demand miss wholesale to the critical
/// path, but misses are *discovered layer by layer*: the blocks layer N's
/// selection misses are only needed by layer N's gather, and FlashH2D's
/// fused gather streams them while the layer computes — so a miss
/// discovered at layer N overlaps layer N's (and, through copy-stream
/// queueing slack, later layers') compute instead of stalling everything.
///
/// Mechanics — one compute stream, one copy stream:
///
/// - prefetch bytes were issued *before* the batch and occupy the copy
///   stream from `t = 0`;
/// - layer `i`'s demand bytes are enqueued on the copy stream when layer
///   `i`'s compute begins (that is when its selection runs);
/// - the fused gather streams missed blocks *through* the attention
///   kernel as they land (online-softmax accumulation folds each block
///   in as a partial tile), so individual layers do not serialize behind
///   their own copies — the iteration commits when both streams drain:
///   `iter = max(compute chain, copy chain)`. This is the optimistic
///   streamed-gather bound; see DESIGN.md for the fidelity trade against
///   a layer-blocking model (which prices mirror-image early/late miss
///   profiles identically and so cannot express layer skew).
///
/// Consequences: `stall = iter_time - Σ compute` is strictly less than
/// the coarse model's whenever misses coexist with compute they can hide
/// under, identical when there is nothing to overlap (no compute, or all
/// traffic is prefetch spill) — and misses discovered EARLY stall
/// strictly less than the same volume discovered LATE, because an early
/// enqueue keeps the copy stream busy while later layers compute,
/// whereas a late enqueue first idles the stream and then pays the whole
/// copy past the compute window ([`crate::config::ServingConfig::
/// sim_layer_skew`] sweeps exactly this).
pub fn layered_iter(layer_compute: &[f64], layer_demand: &[f64], prefetch_s: f64) -> IterTiming {
    debug_assert_eq!(layer_compute.len(), layer_demand.len());
    let compute_s: f64 = layer_compute.iter().sum();
    let demand_s: f64 = layer_demand.iter().sum();
    let mut comp_t = 0.0f64;
    let mut copy_t = prefetch_s; // prefetch drains first on the copy stream
    for (&c, &d) in layer_compute.iter().zip(layer_demand) {
        if d > 0.0 {
            // enqueued when the layer starts; the stream may be idle
            copy_t = copy_t.max(comp_t) + d;
        }
        comp_t += c;
    }
    let iter_time_s = comp_t.max(copy_t);
    let stall_s = iter_time_s - compute_s;
    let hidden_s = (prefetch_s + demand_s - stall_s).max(0.0);
    IterTiming { compute_s, hidden_s, stall_s, iter_time_s }
}

/// Timing of one iteration under the two-stage pipelined executor
/// ([`crate::config::ServingConfig::pipeline_depth`] >= 2).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PipelinedTiming {
    /// Wall time charged to this iteration on the serving clock.
    pub iter_time_s: f64,
    /// Backend-only execution window (stage dispatch + per-layer
    /// phases + commit), i.e. the synchronous iteration minus the
    /// host-side plan/stage share. The NEXT iteration's plan hides
    /// under this window.
    pub exec_s: f64,
    /// Host plan/stage time hidden under the predecessor's compute.
    pub plan_stage_hidden_s: f64,
    /// Host plan/stage time the predecessor's compute window could not
    /// absorb: the pipeline bubble that lands on the critical path.
    pub pipeline_bubble_s: f64,
}

/// Pipelined iteration bound: the scheduler plans (and assembles
/// staging for) iteration N+1 while the backend executes iteration N,
/// so in steady state the period is `max(exec, plan_stage)` instead of
/// `exec + plan_stage`.
///
/// - `base_s` is the synchronous iteration time (which *includes* the
///   host plan/stage share — it is part of
///   [`CostModel::decode_iter_overhead`]'s per-iteration floor);
/// - `plan_stage_s` is this iteration's own plan/stage share, computed
///   by its PREDECESSOR's overlap window;
/// - `prev_exec_s` is the predecessor's backend-only execution window
///   (`exec_s` of the previous [`PipelinedTiming`]; 0 primes the
///   pipeline and charges the plan synchronously — the fill bubble).
///
/// `hidden = min(plan_stage, prev_exec)` and `bubble = plan_stage -
/// hidden` (invariant: `hidden + bubble == plan_stage`), so
/// `iter = exec + bubble` degenerates to `base_s` when nothing hides
/// (`prev_exec_s = 0`) and to `max(exec, plan_stage)` in steady state
/// (`prev_exec_s = exec_s`). The deferred FlashH2D staging the plan
/// issues shares the single copy stream with iteration N's demand
/// misses — [`layered_iter`] already queues demand behind staged
/// traffic, so the copy-stream contention is priced there, not here.
pub fn pipelined_iter(base_s: f64, plan_stage_s: f64, prev_exec_s: f64) -> PipelinedTiming {
    let exec_s = (base_s - plan_stage_s).max(0.0);
    let plan_stage_hidden_s = plan_stage_s.min(prev_exec_s.max(0.0));
    let pipeline_bubble_s = plan_stage_s - plan_stage_hidden_s;
    PipelinedTiming {
        iter_time_s: exec_s + pipeline_bubble_s,
        exec_s,
        plan_stage_hidden_s,
        pipeline_bubble_s,
    }
}

impl CostModel {
    pub fn new(spec: ModelSpec, hw: HardwareSpec) -> Self {
        Self { spec, hw }
    }

    /// Weight bytes of one layer (f16 at paper scale).
    fn layer_weight_bytes(&self) -> f64 {
        let s = &self.spec;
        let attn = s.d_model * (s.n_heads * s.head_dim) * 2
            + s.d_model * (s.n_kv_heads * s.head_dim) * 2;
        let ffn = 3 * s.d_model * s.ffn_dim;
        ((attn + ffn) * s.kv_dtype_bytes) as f64
    }

    /// Projection+FFN FLOPs for `t` tokens through one layer.
    fn layer_proj_flops(&self, t: usize) -> f64 {
        let s = &self.spec;
        let proj = 2.0
            * t as f64
            * (s.d_model * (s.n_heads * s.head_dim) * 2
                + s.d_model * (s.n_kv_heads * s.head_dim) * 2) as f64;
        let ffn = 2.0 * t as f64 * (3 * s.d_model * s.ffn_dim) as f64;
        proj + ffn
    }

    /// Attention FLOPs: `t` queries against `kv` keys (QK^T + PV).
    fn attn_flops(&self, t: usize, kv: usize) -> f64 {
        4.0 * t as f64 * kv as f64 * (self.spec.n_heads * self.spec.head_dim) as f64
    }

    /// Prefill-attention GPU utilization as a function of query count.
    /// Small chunks underutilize the SMs (few query tiles to parallelize
    /// over), which is what makes chunked prefill re-processing of the
    /// past KV expensive in practice; `Q_SAT` is calibrated so chunk-512
    /// prefill attention lands ~1.5x plain (Fig. 16b's measured point).
    const Q_SAT: f64 = 1024.0;

    fn attn_util(t: usize) -> f64 {
        t as f64 / (t as f64 + Self::Q_SAT)
    }

    /// One layer of prefill over `t` new tokens with `past` tokens of
    /// context (past = 0 for plain/layer-segmented full-prompt layers).
    pub fn prefill_layer_time(&self, t: usize, past: usize) -> f64 {
        // causal self-attention within the segment: ~t*t/2 pairs
        let proj = self.layer_proj_flops(t) / self.hw.gpu_flops;
        let attn_flops = self.attn_flops(t, past) + 0.5 * self.attn_flops(t, t);
        let attn_bytes = ((t + past)
            * self.spec.n_kv_heads
            * self.spec.head_dim
            * 2
            * self.spec.kv_dtype_bytes) as f64;
        let attn = (attn_flops / (self.hw.gpu_flops * Self::attn_util(t)))
            .max(attn_bytes / self.hw.hbm_bw);
        let weight_read = self.layer_weight_bytes() / self.hw.hbm_bw;
        proj.max(weight_read) + attn
    }

    /// Full prefill of a prompt, layer-segmented or plain (identical
    /// compute: every token attends once).
    pub fn prefill_time_plain(&self, prompt: usize) -> f64 {
        self.spec.n_layers as f64 * self.prefill_layer_time(prompt, 0)
    }

    /// Full prefill via chunked prefill: chunk c attends to all preceding
    /// chunks, re-reading their KV each iteration (the Fig. 16b overhead).
    pub fn prefill_time_chunked(&self, prompt: usize, chunk: usize) -> f64 {
        let mut total = 0.0;
        let mut done = 0;
        while done < prompt {
            let c = chunk.min(prompt - done);
            total += self.spec.n_layers as f64 * self.prefill_layer_time(c, done);
            done += c;
        }
        total
    }

    /// Prefill of only the suffix `[matched, prompt)` — the TTFT credit
    /// a shared-prefix hit earns: the matched span's KV is adopted from
    /// the prefix pool, so compute covers the remaining chunks only
    /// (each still attends over the full preceding context, adopted
    /// included). `matched = 0` degenerates to
    /// [`Self::prefill_time_chunked`].
    pub fn prefill_time_suffix(&self, prompt: usize, matched: usize, chunk: usize) -> f64 {
        let mut total = 0.0;
        let mut done = matched.min(prompt);
        while done < prompt {
            let c = chunk.min(prompt - done);
            total += self.spec.n_layers as f64 * self.prefill_layer_time(c, done);
            done += c;
        }
        total
    }

    /// Fixed per-decode-iteration overhead: kernel launches, block
    /// selection, gather assembly, sampling and scheduler bookkeeping —
    /// ~0.8 ms per layer on real serving stacks (vLLM-class systems
    /// measure 20-40 ms iteration floors on 32-layer models).
    pub fn decode_iter_overhead(&self) -> f64 {
        self.spec.n_layers as f64 * 0.8e-3
    }

    /// Host-side plan/stage share of one iteration: scheduler batch
    /// packing (Alg. 1 walk over the active set), stage-hint ranking,
    /// and staging-descriptor assembly for the FlashH2D copy workers.
    /// This is the slice of [`Self::decode_iter_overhead`] the
    /// pipelined executor can move off the critical path — a fixed
    /// dispatch floor plus per-request packing work plus per-staged-
    /// block descriptor assembly. Bounded well under the overhead
    /// floor: planning never exceeds the launch/selection work it
    /// fronts for.
    pub fn plan_stage_time(&self, batch: usize, staged_blocks: usize) -> f64 {
        let raw = 100.0e-6 + batch as f64 * 8.0e-6 + staged_blocks as f64 * 0.15e-6;
        raw.min(0.5 * self.decode_iter_overhead())
    }

    /// One decode iteration for a batch: each request reads `kv_tokens`
    /// of KV (its sparse budget, or its full context for dense attention).
    /// Weights are read once per layer regardless of batch size.
    pub fn decode_iter_time(&self, batch: usize, kv_tokens_per_req: &[usize]) -> f64 {
        debug_assert_eq!(batch, kv_tokens_per_req.len());
        if batch == 0 {
            return 0.0;
        }
        let s = &self.spec;
        let mut flops = 0.0;
        let mut kv_bytes = 0.0;
        for &kv in kv_tokens_per_req {
            flops += self.layer_proj_flops(1) + self.attn_flops(1, kv);
            kv_bytes +=
                (kv * s.n_kv_heads * s.head_dim * 2 * s.kv_dtype_bytes) as f64;
        }
        flops *= s.n_layers as f64;
        kv_bytes *= s.n_layers as f64;
        let bytes = s.n_layers as f64 * self.layer_weight_bytes() + kv_bytes;
        self.decode_iter_overhead() + (flops / self.hw.gpu_flops).max(bytes / self.hw.hbm_bw)
    }

    /// Reference single-request decode iteration (the SLO unit of Fig. 13:
    /// P99 TBT <= 25x this).
    pub fn decode_iter_ref(&self, kv_tokens: usize) -> f64 {
        self.decode_iter_time(1, &[kv_tokens])
    }

    /// PCIe time to load `n_blocks` per-head KV blocks with the engine.
    pub fn load_time(&self, kind: TransferKind, n_blocks: usize) -> f64 {
        if n_blocks == 0 {
            return 0.0;
        }
        match kind {
            TransferKind::Memcpy => self.hw.memcpy_time(n_blocks, self.spec.block_bytes()),
            TransferKind::Flash | TransferKind::GpuDirectSave => {
                self.hw.flash_h2d_time(n_blocks, self.spec.block_bytes())
            }
        }
    }

    /// Wire time of one cross-engine KV migration: the victim's
    /// DRAM-tier footprint drains over FlashD2H at the source, then
    /// fills over FlashH2D at the target. The two hops are sequential
    /// (the target cannot start loading blocks the source has not yet
    /// serialized), so the shared cluster clock is charged their sum.
    pub fn migration_time(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let block = self.spec.block_bytes();
        let n_blocks = bytes.div_ceil(block);
        self.hw.flash_d2h_time(bytes) + self.hw.flash_h2d_time(n_blocks, block)
    }

    /// Extra prefill-iteration latency caused by KV *saving*, as a factor
    /// on compute time. Calibrated to Fig. 14b: memcpy-based saving makes
    /// prefill 1.76x the compute time, GPU-direct 1.28x, FlashD2H 1.00x.
    pub fn save_overhead_factor(&self, kind: TransferKind, offload: bool) -> f64 {
        if !offload {
            return 1.0;
        }
        match kind {
            TransferKind::Memcpy => 1.76,
            TransferKind::GpuDirectSave => self.hw.gpu_save_interference,
            TransferKind::Flash => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::new(ModelSpec::lwm_7b(), HardwareSpec::a100_40gb())
    }

    #[test]
    fn chunked_prefill_overhead_matches_fig16b_shape() {
        // Fig. 16b: chunk 512 slows prefill attention ~1.5x; overhead
        // shrinks as chunks grow.
        let m = model();
        let prompt = 16_384;
        let plain = m.prefill_time_plain(prompt);
        let r512 = m.prefill_time_chunked(prompt, 512) / plain;
        let r2048 = m.prefill_time_chunked(prompt, 2048) / plain;
        let r4096 = m.prefill_time_chunked(prompt, 4096) / plain;
        assert!(r512 > r2048 && r2048 > r4096, "{r512} {r2048} {r4096}");
        assert!(r512 > 1.3 && r512 < 2.2, "chunk-512 overhead {r512}");
        assert!(r4096 < 1.4, "chunk-4096 overhead {r4096}");
    }

    #[test]
    fn decode_is_memory_bound_and_batching_amortizes_weights() {
        let m = model();
        let one = m.decode_iter_time(1, &[2048]);
        let eight = m.decode_iter_time(8, &vec![2048; 8]);
        // batching 8 must cost far less than 8x a single decode
        assert!(eight < 4.0 * one, "one={one} eight={eight}");
        assert!(eight > one);
    }

    #[test]
    fn sparse_decode_beats_dense_decode() {
        let m = model();
        // single request: modest gain (iteration overhead + weight reads
        // dominate — matches the paper's +SA goodput gain of only 1.2x)
        let dense1 = m.decode_iter_time(1, &[32_768]);
        let sparse1 = m.decode_iter_time(1, &[2048]);
        assert!(dense1 / sparse1 > 1.25, "dense={dense1} sparse={sparse1}");
        // batched: KV reads dominate and sparsity pays off severalfold
        let dense8 = m.decode_iter_time(8, &vec![32_768; 8]);
        let sparse8 = m.decode_iter_time(8, &vec![2048; 8]);
        assert!(dense8 / sparse8 > 2.5, "dense={dense8} sparse={sparse8}");
    }

    #[test]
    fn load_time_memcpy_vs_flash_matches_fig14a() {
        let m = model();
        let n = 256;
        let ratio = m.load_time(TransferKind::Memcpy, n)
            / m.load_time(TransferKind::Flash, n);
        assert!(ratio > 5.0, "FlashH2D must cut loading severalfold: {ratio}");
    }

    #[test]
    fn migration_time_prices_both_hops() {
        let m = model();
        let block = m.spec.block_bytes();
        let bytes = 256 * block;
        let t = m.migration_time(bytes);
        // strictly more than either hop alone, exactly their sum
        let d2h = m.hw.flash_d2h_time(bytes);
        let h2d = m.hw.flash_h2d_time(256, block);
        assert!(t > d2h && t > h2d);
        assert!((t - (d2h + h2d)).abs() < 1e-12);
        assert_eq!(m.migration_time(0), 0.0);
        // monotone in the footprint
        assert!(m.migration_time(2 * bytes) > t);
    }

    #[test]
    fn save_factors_match_fig14b() {
        let m = model();
        assert_eq!(m.save_overhead_factor(TransferKind::Flash, true), 1.0);
        assert!((m.save_overhead_factor(TransferKind::Memcpy, true) - 1.76).abs() < 1e-9);
        let g = m.save_overhead_factor(TransferKind::GpuDirectSave, true);
        assert!(g > 1.2 && g < 1.4);
        // no offloading -> no saving traffic at all
        assert_eq!(m.save_overhead_factor(TransferKind::Memcpy, false), 1.0);
    }

    #[test]
    fn suffix_prefill_earns_strict_ttft_credit() {
        let m = model();
        let prompt = 16_384;
        let chunk = 2048;
        let full = m.prefill_time_chunked(prompt, chunk);
        // matched = 0 is exactly the full chunked prefill
        assert_eq!(m.prefill_time_suffix(prompt, 0, chunk), full);
        // every adopted block strictly reduces prefill compute, and a
        // longer match reduces it further
        let half = m.prefill_time_suffix(prompt, prompt / 2, chunk);
        let most = m.prefill_time_suffix(prompt, prompt - chunk, chunk);
        assert!(half < full, "half={half} full={full}");
        assert!(most < half, "most={most} half={half}");
        // the credit exceeds the suffix's share: the skipped chunks were
        // the cheap early ones, the kept ones attend over the adopted
        // context too — still strictly cheaper than prefilling from 0
        assert!(most > 0.0);
        // fully matched prompt costs nothing more to prefill
        assert_eq!(m.prefill_time_suffix(prompt, prompt, chunk), 0.0);
    }

    #[test]
    fn prefill_scales_superlinearly_with_prompt() {
        let m = model();
        let t1 = m.prefill_time_plain(8192);
        let t2 = m.prefill_time_plain(16_384);
        assert!(t2 > 2.0 * t1, "quadratic attention term must show");
    }

    #[test]
    fn two_stream_hides_prefetch_but_not_demand() {
        // demand always stalls
        let t = two_stream_iter(1.0, 0.0, 0.3);
        assert_eq!(t.stall_s, 0.3);
        assert_eq!(t.iter_time_s, 1.3);
        // prefetch within the compute window is free
        let t = two_stream_iter(1.0, 0.8, 0.0);
        assert_eq!(t.stall_s, 0.0);
        assert_eq!(t.hidden_s, 0.8);
        assert_eq!(t.iter_time_s, 1.0);
        // prefetch past the window spills
        let t = two_stream_iter(1.0, 1.5, 0.1);
        assert!((t.stall_s - 0.6).abs() < 1e-12);
        assert!((t.iter_time_s - 1.6).abs() < 1e-12);
    }

    #[test]
    fn layered_model_overlaps_layer_misses_with_compute() {
        // misses lighter than per-layer compute hide entirely
        let t = layered_iter(&[0.25; 4], &[0.2; 4], 0.0);
        assert!(t.stall_s.abs() < 1e-12, "hidden misses must not stall: {t:?}");
        assert_eq!(t.iter_time_s, 1.0);
        // miss-heavy: still strictly less stall than the coarse model
        let heavy = layered_iter(&[0.1; 2], &[0.5; 2], 0.0);
        let coarse = two_stream_iter(0.2, 0.0, 1.0);
        assert!(heavy.stall_s > 0.0);
        assert!(
            heavy.stall_s < coarse.stall_s,
            "layered {heavy:?} must beat coarse {coarse:?}"
        );
        assert!((heavy.iter_time_s - 1.0).abs() < 1e-12); // copy-bound
        // no compute to hide under -> both models agree
        let bare = layered_iter(&[0.0; 3], &[0.1; 3], 0.0);
        assert!((bare.stall_s - 0.3).abs() < 1e-12);
    }

    #[test]
    fn early_skewed_demand_stalls_strictly_less_than_late_at_equal_totals() {
        // the pricing fact behind the layer-skew knob: the SAME total
        // demand volume stalls strictly less when discovered at early
        // layers (an early enqueue keeps the copy stream busy under the
        // remaining layers' compute) than at late layers (the stream
        // idles first, then the whole copy lands past the compute
        // window). Exact mirror profiles, so totals are equal by
        // construction.
        let compute = vec![0.1; 8];
        let weights: Vec<f64> = (0..8).map(|i| 8.0 - i as f64).collect();
        let wsum: f64 = weights.iter().sum();
        let profile = |total: f64, reversed: bool| -> Vec<f64> {
            let mut p: Vec<f64> = weights.iter().map(|w| total * w / wsum).collect();
            if reversed {
                p.reverse();
            }
            p
        };
        // mid regime (demand ~ compute): strictly ordered
        for total in [0.6, 1.0, 1.4] {
            let early = profile(total, false);
            let late = profile(total, true);
            assert!(
                (early.iter().sum::<f64>() - late.iter().sum::<f64>()).abs() < 1e-12,
                "equal totals by construction"
            );
            let t_early = layered_iter(&compute, &early, 0.0);
            let t_late = layered_iter(&compute, &late, 0.0);
            let t_flat = layered_iter(&compute, &vec![total / 8.0; 8], 0.0);
            assert!(
                t_early.stall_s < t_late.stall_s - 1e-9,
                "total={total}: early {} must stall strictly less than late {}",
                t_early.stall_s,
                t_late.stall_s
            );
            // flat sits between the two tilts (ties allowed: once the
            // copy stream saturates from t=0, early and flat coincide)
            assert!(t_early.stall_s <= t_flat.stall_s + 1e-9, "total={total}");
            assert!(t_flat.stall_s <= t_late.stall_s + 1e-9, "total={total}");
            // both bounded by the coarse wholesale charge
            let coarse = two_stream_iter(0.8, 0.0, total);
            assert!(t_late.stall_s <= coarse.stall_s + 1e-9);
        }
        // light regime (demand well under compute): every tilt hides
        // fully — skew matters only once loading pressures the window
        let le = layered_iter(&compute, &profile(0.3, false), 0.0);
        let ll = layered_iter(&compute, &profile(0.3, true), 0.0);
        assert!(le.stall_s.abs() < 1e-12 && ll.stall_s.abs() < 1e-12);
    }

    #[test]
    fn layered_model_queues_demand_behind_prefetch() {
        // prefetch occupies the single copy stream first; layer-0 demand
        // waits for it, so heavy staging delays demand visibly
        let t = layered_iter(&[1.0], &[0.5], 0.8);
        assert!((t.iter_time_s - 1.3).abs() < 1e-12); // 0.8 + 0.5 copy chain
        assert!((t.stall_s - 0.3).abs() < 1e-12);
        // pure prefetch spill matches the coarse model
        let t = layered_iter(&[0.5, 0.5], &[0.0, 0.0], 1.5);
        assert!((t.iter_time_s - 1.5).abs() < 1e-12);
        assert!((t.stall_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn layered_model_prefetching_demand_never_hurts() {
        // moving bytes from the demand stream (issued at layer start) to
        // the prefetch stream (issued at t=0) can only help
        for &(c, total) in &[(1.0, 0.4), (1.0, 1.7), (0.2, 0.9)] {
            let l = 4;
            let per = c / l as f64;
            let all_demand = layered_iter(&vec![per; l], &vec![total / l as f64; l], 0.0);
            for frac in [0.25, 0.5, 0.75, 1.0] {
                let pf = total * frac;
                let d = (total - pf) / l as f64;
                let t = layered_iter(&vec![per; l], &vec![d; l], pf);
                assert!(
                    t.iter_time_s <= all_demand.iter_time_s + 1e-12,
                    "prefetch made it worse: {t:?} vs {all_demand:?}"
                );
            }
        }
    }

    #[test]
    fn pipelined_iter_hides_plan_under_the_predecessor() {
        // steady state (prev_exec == exec): period = max(exec, plan)
        let t = pipelined_iter(1.0, 0.2, 0.8);
        assert!((t.exec_s - 0.8).abs() < 1e-12);
        assert!((t.plan_stage_hidden_s - 0.2).abs() < 1e-12);
        assert_eq!(t.pipeline_bubble_s, 0.0);
        assert!((t.iter_time_s - 0.8).abs() < 1e-12, "{t:?}");
        // pipeline fill (prev_exec = 0): nothing hides, full base paid
        let fill = pipelined_iter(1.0, 0.2, 0.0);
        assert_eq!(fill.plan_stage_hidden_s, 0.0);
        assert!((fill.pipeline_bubble_s - 0.2).abs() < 1e-12);
        assert!((fill.iter_time_s - 1.0).abs() < 1e-12, "{fill:?}");
        // plan-bound regime (plan > exec): period = plan_stage, split
        // into hidden + bubble against the short predecessor window
        let pb = pipelined_iter(0.5, 0.4, 0.1);
        assert!((pb.exec_s - 0.1).abs() < 1e-12);
        assert!((pb.plan_stage_hidden_s - 0.1).abs() < 1e-12);
        assert!((pb.pipeline_bubble_s - 0.3).abs() < 1e-12);
        assert!((pb.iter_time_s - 0.4).abs() < 1e-12, "{pb:?}");
        // invariants: hidden + bubble == plan_stage; never worse than
        // the synchronous order, never better than max(exec, plan)
        for &(base, ps, prev) in
            &[(1.0, 0.2, 0.8), (1.0, 0.2, 0.05), (0.5, 0.4, 0.1), (0.03, 0.01, 0.02)]
        {
            let t = pipelined_iter(base, ps, prev);
            assert!((t.plan_stage_hidden_s + t.pipeline_bubble_s - ps).abs() < 1e-12);
            assert!(t.iter_time_s <= base + 1e-12, "{t:?} vs base {base}");
            assert!(t.iter_time_s >= t.exec_s.max(ps.min(base)) - 1e-12, "{t:?}");
        }
    }

    #[test]
    fn plan_stage_time_stays_under_the_overhead_floor() {
        let m = model();
        // grows with batch size and staged volume...
        let small = m.plan_stage_time(1, 0);
        let big = m.plan_stage_time(64, 4096);
        assert!(big > small, "{small} {big}");
        // ...but is a strict slice of the per-iteration overhead the
        // synchronous order already charges (the pipelined bound
        // subtracts it from `base`, so it must never exceed base's
        // overhead share)
        assert!(big <= 0.5 * m.decode_iter_overhead() + 1e-15);
        // steady-decode shape (B=8, full prefetch budget): hiding it is
        // worth a measurable slice of the ~26 ms iteration floor
        let ps = m.plan_stage_time(8, 512);
        assert!(ps > 100.0e-6, "{ps}");
    }

    #[test]
    fn prefetching_demand_bytes_never_hurts() {
        // moving X seconds of traffic from the demand stream to the
        // prefetch stream can only reduce the iteration time
        for &(compute, total) in &[(1.0, 0.4), (1.0, 1.7), (0.2, 0.9)] {
            let all_demand = two_stream_iter(compute, 0.0, total);
            for frac in [0.25, 0.5, 0.75, 1.0] {
                let pf = total * frac;
                let t = two_stream_iter(compute, pf, total - pf);
                assert!(
                    t.iter_time_s <= all_demand.iter_time_s + 1e-12,
                    "prefetch made it worse: {t:?} vs {all_demand:?}"
                );
            }
        }
    }
}
