//! Paper-scale testbed simulator (the DESIGN.md substitution for the
//! A100 testbed).
//!
//! - [`cost`]: analytic GPU compute + PCIe transfer cost model for
//!   prefill/decode iterations at LWM-7B / Llama3-8B scale, calibrated to
//!   the paper's measured ratios (Figs. 4, 14, 16b);
//! - [`selection`]: a synthetic block-selection process with the temporal
//!   locality the paper measures in Fig. 8 (high step-to-step overlap
//!   that saturates with window size), driving the LRU cache dynamics of
//!   Figs. 1 and 15. Selection draws per **layer band** (shared drifting
//!   hot set, skew-tiltable churn), so miss discovery lands at the layer
//!   that needs the bytes — see DESIGN.md for the fidelity trade.

pub mod cost;
pub mod selection;

pub use cost::{
    layered_iter, pipelined_iter, two_stream_iter, CostModel, IterTiming, PipelinedTiming,
};
pub use selection::{selection_clones_this_thread, SelectionModel};
