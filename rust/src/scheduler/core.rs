//! The scheduler core: FCFS continuous batching + Algorithm 1 +
//! prefill planning (plain / chunked / layer-segmented).

use std::collections::{HashMap, VecDeque};

use crate::config::{ModelSpec, PrefillMode, ServingConfig};
use crate::memory::{block_hashes, PrefixIndex, ReqId};

use super::plan::{Batch, PrefillWork};
use super::request::{Phase, Priority, Request};

/// Decode working-set estimator supplied by the executor:
/// `req -> bytes` (history-window union for SparseServe, full KV for
/// dense attention).
pub type WsEstimate<'a> = &'a mut dyn FnMut(ReqId) -> usize;

pub struct Scheduler {
    pub cfg: ServingConfig,
    pub spec: ModelSpec,
    /// HBM KV capacity in bytes (M_avl = m_avl_frac * this).
    hbm_capacity: usize,
    /// DRAM KV capacity in bytes (offload-mode admission bound;
    /// `usize::MAX` = unbounded, the pre-fix behavior).
    dram_capacity: usize,
    pub requests: HashMap<ReqId, Request>,
    /// FCFS admission queue.
    queue: VecDeque<ReqId>,
    /// Admitted requests in admission order (Prefill or Decode phase).
    active: Vec<ReqId>,
    /// Full-lifetime KV reservations: against HBM without offloading
    /// (vLLM semantics), against DRAM with it (a long-running offload
    /// server must backpressure before the DRAM pool is exhausted).
    reserved: HashMap<ReqId, usize>,
    reserved_total: usize,
    /// Iterations planned (diagnostics).
    pub iterations: u64,
    /// Requests rejected by Alg. 1 at least once this run (diagnostics).
    pub ws_rejections: u64,
    /// Iterations where the starvation guard stopped packing behind a
    /// repeatedly-skipped decode (diagnostics).
    pub ws_starvation_stops: u64,
    /// EWMA of generated-token counts observed at finish (the
    /// `admission_estimates` input; see [`Self::expected_new_tokens`]).
    completion_ewma: f64,
    completion_obs: u64,
    /// Monotone counter bumped whenever the active set's composition
    /// changes outside planning itself (cancel, finish, prefill→decode
    /// graduation, migration in either direction). The pipelined
    /// executor stamps its speculative next-iteration plan with this
    /// version: a speculation taken at version V is stale — and must be
    /// re-planned, never executed — once the version moves.
    plan_version: u64,
    /// Cross-request prefix index (`Some` iff `cfg.prefix_sharing`):
    /// admission matches the prompt's block-aligned hash chain here and
    /// reserves only the unmatched-suffix KV privately.
    prefix: Option<PrefixIndex>,
    /// Bytes charged for resident prefix blocks — live (referenced by an
    /// admitted sharer) plus cached (refs 0, reclaimable on demand by
    /// [`PrefixIndex::evict_unreferenced`]). Conservation invariant:
    /// `reserved_total + prefix_resident_bytes` is the total KV charge,
    /// and with zero prefix hits it equals HEAD's exclusive accounting
    /// exactly (every path block shifts bytes from private to shared,
    /// never creating or dropping any).
    prefix_resident_bytes: usize,
    /// Per admitted request: acquired path tail + the bytes its path
    /// shifted out of the private reservation (released exactly once at
    /// finish / cancel / migration-export).
    prefix_paths: HashMap<ReqId, (u32, usize)>,
    /// Reused hash buffer (admission is on the planning path).
    hash_scratch: Vec<u64>,
    /// Admissions that matched a non-empty shared prefix (diagnostics;
    /// folded into `RunMetrics::prefix_hits`).
    pub prefix_hits: u64,
    /// Cumulative prompt tokens whose prefill was skipped via the index.
    pub prefix_matched_tokens: u64,
    /// Admission-time prefix matches not yet forwarded to the backend
    /// (`(id, matched tokens, path tail)`): registration happens at
    /// submit, before admission resolves the match, so the engine drains
    /// this queue right after planning and calls
    /// [`crate::engine::Backend::adopt_prefix`] for each entry.
    adoptions: Vec<(ReqId, usize, u32)>,
}

impl Scheduler {
    pub fn new(cfg: ServingConfig, spec: ModelSpec, hbm_capacity: usize) -> Self {
        let prefix = cfg.prefix_sharing.then(PrefixIndex::new);
        Self {
            cfg,
            spec,
            prefix,
            hbm_capacity,
            dram_capacity: usize::MAX,
            requests: HashMap::new(),
            queue: VecDeque::new(),
            active: Vec::new(),
            reserved: HashMap::new(),
            reserved_total: 0,
            iterations: 0,
            ws_rejections: 0,
            ws_starvation_stops: 0,
            completion_ewma: 0.0,
            completion_obs: 0,
            plan_version: 0,
            prefix_resident_bytes: 0,
            prefix_paths: HashMap::new(),
            hash_scratch: Vec::new(),
            prefix_hits: 0,
            prefix_matched_tokens: 0,
            adoptions: Vec::new(),
        }
    }

    /// Current plan version (see the field doc): speculative plans
    /// stamped with an older version are stale.
    pub fn plan_version(&self) -> u64 {
        self.plan_version
    }

    /// Bound offload-mode admission by DRAM capacity: the scheduler
    /// reserves each admitted request's full-lifetime KV against this
    /// budget and blocks (FCFS) when it would not fit, instead of letting
    /// the DRAM pool exhaust mid-decode.
    pub fn with_dram_capacity(mut self, bytes: usize) -> Self {
        self.dram_capacity = bytes;
        self
    }

    /// Enqueue a request. The queue is priority-aware: an `Interactive`
    /// request is placed ahead of every waiting `Batch` request (FCFS
    /// within each class); a request already admitted keeps running.
    pub fn submit(&mut self, req: Request) {
        let id = req.id;
        let priority = req.priority;
        self.requests.insert(id, req);
        if priority == Priority::Interactive {
            let pos = self
                .queue
                .iter()
                .position(|q| self.requests[q].priority == Priority::Batch)
                .unwrap_or(self.queue.len());
            self.queue.insert(pos, id);
        } else {
            self.queue.push_back(id);
        }
    }

    /// Cancel a request: drop it from the queue/active set and release
    /// its HBM reservation. Returns false if the id is unknown or the
    /// request already finished (nothing to cancel). The caller frees the
    /// backend KV state (`Backend::release`).
    pub fn cancel(&mut self, id: ReqId) -> bool {
        let Some(r) = self.requests.get_mut(&id) else {
            return false;
        };
        if matches!(r.phase, Phase::Finished | Phase::Cancelled) {
            return false;
        }
        r.phase = Phase::Cancelled;
        self.queue.retain(|&q| q != id);
        self.active.retain(|&a| a != id);
        if let Some(n) = self.reserved.remove(&id) {
            self.reserved_total -= n;
        }
        self.release_prefix(id);
        self.plan_version += 1;
        true
    }

    /// Drop `id`'s reference on its acquired prefix path (idempotent:
    /// finish, cancel and migration-export each route here, and the
    /// path entry is removed on the first call). The path's blocks stay
    /// resident as cached (refs-0) entries, still charged to
    /// `prefix_resident_bytes` until admission pressure evicts them —
    /// that retention is what makes the next conversation turn warm.
    fn release_prefix(&mut self, id: ReqId) {
        if let Some((tail, _)) = self.prefix_paths.remove(&id) {
            if let Some(ix) = self.prefix.as_mut() {
                ix.release_path(tail);
            }
        }
    }

    /// Bytes of one block-aligned prefix block across all layers and
    /// KV heads — the unit the shared prefix pool is charged in.
    pub fn prefix_block_bytes(&self) -> usize {
        self.spec.n_layers * self.spec.n_kv_heads * self.spec.block_bytes()
    }

    /// Shared prefix pool charge (live + cached blocks), bytes.
    pub fn prefix_resident_bytes(&self) -> usize {
        self.prefix_resident_bytes
    }

    /// Next admission-time prefix match the backend has not been told
    /// about yet (`(id, matched tokens, path tail)`). The engine drains
    /// this after every planning pass; entries are queued only by
    /// [`Self::try_admit`] on a non-empty match.
    pub fn pop_adoption(&mut self) -> Option<(ReqId, usize, u32)> {
        self.adoptions.pop()
    }

    /// Drop the prefix index (and the knob). The engine calls this when
    /// its backend cannot adopt shared prefixes — skipping matched
    /// prefill without backend adoption would leave that span's KV
    /// unwritten.
    pub fn disable_prefix_sharing(&mut self) {
        self.cfg.prefix_sharing = false;
        self.prefix = None;
    }

    /// Waiting request ids in admission order (diagnostics / tests).
    pub fn queued_ids(&self) -> Vec<ReqId> {
        self.queue.iter().copied().collect()
    }

    /// The request currently holding the (single) prefill slot, if any.
    pub fn prefilling_id(&self) -> Option<ReqId> {
        self.active
            .iter()
            .copied()
            .find(|id| self.requests[id].phase == Phase::Prefill)
    }

    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    pub fn m_avl(&self) -> usize {
        (self.hbm_capacity as f64 * self.cfg.m_avl_frac) as usize
    }

    /// Full-lifetime KV bytes of a request (prompt + all output tokens) —
    /// the vLLM-style HBM reservation.
    pub fn full_kv_bytes(&self, prompt_len: usize, max_new: usize) -> usize {
        let blocks = (prompt_len + max_new).div_ceil(self.spec.block_size);
        blocks * self.spec.n_layers * self.spec.n_kv_heads * self.spec.block_bytes()
    }

    /// How many new tokens to reserve KV for at admission. Without
    /// `admission_estimates` this is the conservative full lifetime
    /// (`max_new`). With it, once enough completions have been observed,
    /// the reservation shrinks to a safety-margined estimate of the
    /// request's *actual* completion length — short completions stop
    /// holding DRAM admission hostage for output they will never
    /// generate. A request that outlives its estimate grows its
    /// reservation token by token ([`Self::emit_token`]); true
    /// oversubscription surfaces as a typed `DramExhausted` the engine
    /// rolls back and evicts.
    pub fn expected_new_tokens(&self, r: &Request) -> usize {
        const MIN_OBS: u64 = 4;
        const SAFETY: f64 = 1.5;
        if !self.cfg.admission_estimates || self.completion_obs < MIN_OBS {
            return r.max_new_tokens;
        }
        let est = (self.completion_ewma * SAFETY).ceil() as usize;
        r.max_new_tokens.min(est.max(1))
    }

    /// Observed mean completion length (diagnostics / tests).
    pub fn completion_estimate(&self) -> Option<f64> {
        (self.completion_obs > 0).then_some(self.completion_ewma)
    }

    /// Prefill working set for the configured mode (paper §3.3):
    /// chunked keeps every processed token's KV across ALL layers resident;
    /// layer-segmented needs only ONE layer of the segment being processed.
    pub fn prefill_ws_bytes(&self, req: &Request, work: &PrefillWork) -> usize {
        let per_tok_layer =
            self.spec.n_kv_heads * self.spec.head_dim * 2 * self.spec.kv_dtype_bytes;
        match work {
            PrefillWork::Chunk { len, .. } => {
                (req.tokens_done + len) * per_tok_layer * self.spec.n_layers
            }
            PrefillWork::LayerSegment { tok_len, .. } => *tok_len * per_tok_layer,
        }
    }

    /// Plan the next hybrid batch (Algorithm 1 + prefill planner).
    /// `now` stamps admissions; `ws` estimates decode working sets.
    pub fn plan(&mut self, now: f64, ws: WsEstimate) -> Batch {
        let mut batch = Batch::default();
        self.plan_into(now, ws, &mut batch);
        batch
    }

    /// [`Self::plan`] into a caller-owned batch (cleared first) — the
    /// engine hands the same `Batch` back every iteration, so the
    /// planner's materialization vector is reused instead of
    /// reallocated (zero-clone step pipeline).
    // sparselint: hot
    pub fn plan_into(&mut self, now: f64, ws: WsEstimate, batch: &mut Batch) {
        batch.decodes.clear();
        batch.prefill = None;
        self.iterations += 1;
        let m_avl = self.m_avl();
        let mut ws_used = 0usize;
        let mut tokens = 0usize;

        // ---- 1. decode candidates, FCFS (Alg. 1 lines 5-14) ----
        // The resulting `batch.decodes` order doubles as the prefetch
        // priority order: earlier (older) requests get staging budget
        // first, matching their gather order in the backend.
        for &id in &self.active {
            if self.requests[&id].phase != Phase::Decode {
                continue;
            }
            if batch.decodes.len() >= self.cfg.r_max || tokens + 1 > self.cfg.t_max {
                break;
            }
            if self.cfg.ws_batch_control {
                let w = ws(id);
                if ws_used + w > m_avl {
                    self.ws_rejections += 1;
                    let Some(r) = self.requests.get_mut(&id) else {
                        debug_assert!(false, "active id {id} has no request record");
                        continue;
                    };
                    r.ws_skip_streak += 1;
                    let streak = r.ws_skip_streak;
                    // Starvation guard: a decode that COULD fit an
                    // emptier batch (w <= M_avl) must not be leapfrogged
                    // by younger, smaller requests forever. After K
                    // consecutive skips, stop packing behind it — its WS
                    // share frees up as older requests finish, so FCFS
                    // progress is guaranteed. (A request whose own WS
                    // exceeds M_avl is hopeless, not starved; skipping
                    // past it stays allowed and the serving layer evicts
                    // it.)
                    if streak as usize >= self.cfg.ws_starvation_k.max(1) && w <= m_avl {
                        self.ws_starvation_stops += 1;
                        break;
                    }
                    continue; // S.reset(req): skipped this iteration
                }
                ws_used += w;
                if let Some(r) = self.requests.get_mut(&id) {
                    r.ws_skip_streak = 0;
                }
            }
            batch.decodes.push(id);
            tokens += 1;
        }

        // ---- 2. admission (single prefill slot, strict FCFS) ----
        let prefilling = self
            .active
            .iter()
            .copied()
            .find(|id| self.requests[id].phase == Phase::Prefill);
        let target = match prefilling {
            Some(id) => Some(id),
            None => self.try_admit(now),
        };

        // ---- 3. prefill planning ----
        if let Some(id) = target {
            if let Some(work) = self.plan_prefill(id, tokens) {
                let ok = if self.cfg.ws_batch_control {
                    let w = self.prefill_ws_bytes(&self.requests[&id], &work);
                    if ws_used + w <= m_avl {
                        true
                    } else {
                        self.ws_rejections += 1;
                        false
                    }
                } else {
                    true
                };
                if ok {
                    batch.prefill = Some(work);
                }
            }
        }
    }

    /// The admission capacity a request's full KV reserves against: HBM
    /// without offloading (vLLM semantics), DRAM with it. Public so the
    /// cluster router can size its per-engine placement watermarks.
    pub fn admission_capacity(&self) -> usize {
        if self.cfg.offload {
            self.dram_capacity
        } else {
            self.hbm_capacity
        }
    }

    /// Head-of-queue request whose KV demand exceeds the *total*
    /// admission capacity — it can never be admitted, no matter what
    /// finishes. The engine rejects it with a typed error so it does not
    /// block the queue forever.
    pub fn hopeless_head(&self) -> Option<ReqId> {
        let &id = self.queue.front()?;
        let r = &self.requests[&id];
        let need = self.full_kv_bytes(r.prompt_len, self.expected_new_tokens(r));
        (need > self.admission_capacity()).then_some(id)
    }

    /// Head-of-queue admission. The request's full-lifetime KV is
    /// reserved against HBM without offloading (head-of-line blocking
    /// when it doesn't fit — the vLLM failure mode of Fig. 10) or against
    /// DRAM with it (backpressure instead of the old unbounded admission
    /// that exhausted the DRAM pool mid-decode).
    ///
    /// With `prefix_sharing` the prompt's block-aligned hash chain is
    /// matched against the prefix index FIRST and only the *unmatched
    /// delta* is reserved privately: every block on the acquired path
    /// (matched or newly published) is charged once to the shared pool
    /// instead. A re-entering conversation turn therefore never
    /// re-reserves its history (the double-reservation bug), and with
    /// zero hits the private+shared total equals HEAD's exclusive
    /// reservation byte for byte.
    fn try_admit(&mut self, now: f64) -> Option<ReqId> {
        let &id = self.queue.front()?;
        let (plen, mnew) = {
            let r = &self.requests[&id];
            (r.prompt_len, self.expected_new_tokens(r))
        };
        let full = self.full_kv_bytes(plen, mnew);
        let pbb = self.prefix_block_bytes();
        let bs = self.spec.block_size;
        let mut need = full;
        // (tail, path blocks, matched tokens, created blocks)
        let mut acquired: Option<(u32, usize, usize, usize)> = None;
        if self.cfg.prefix_sharing {
            if let Some(ix) = self.prefix.as_mut() {
                let mut scratch = std::mem::take(&mut self.hash_scratch);
                let prompt = self.requests.get(&id).map(|r| r.prompt.as_slice()).unwrap_or(&[]);
                block_hashes(prompt, bs, &mut scratch);
                if let Some(path) = ix.acquire_path(&scratch) {
                    // at least one prompt token must still prefill (the
                    // first decode token is produced by the prefill pass)
                    let mut matched_tok = path.matched_blocks * bs;
                    if matched_tok >= plen {
                        matched_tok = ((plen - 1) / bs) * bs;
                    }
                    let path_blocks = path.matched_blocks + path.new_blocks;
                    self.prefix_resident_bytes += path.new_blocks * pbb;
                    need = full.saturating_sub(path_blocks * pbb);
                    acquired = Some((path.tail, path_blocks, matched_tok, path.new_blocks));
                }
                self.hash_scratch = scratch;
            }
        }
        let cap = self.admission_capacity();
        let mut avail = cap
            .saturating_sub(self.reserved_total)
            .saturating_sub(self.prefix_resident_bytes);
        if need > avail {
            // reclaim cached (refs-0) prefix blocks before blocking —
            // the acquired path itself is protected by its references
            if let Some(ix) = self.prefix.as_mut() {
                let short_blocks = (need - avail).div_ceil(pbb.max(1));
                let evicted = ix.evict_unreferenced(short_blocks);
                self.prefix_resident_bytes -= evicted * pbb;
                avail += evicted * pbb;
            }
        }
        if need > avail {
            // blocked; FCFS forbids skipping ahead. Undo the acquisition
            // so the unbacked suffix never lingers as a phantom match.
            if let Some((tail, _, _, created)) = acquired {
                if let Some(ix) = self.prefix.as_mut() {
                    ix.release_path(tail);
                    let removed = ix.rollback_path(tail, created);
                    self.prefix_resident_bytes -= removed * pbb;
                }
            }
            return None;
        }
        self.reserved.insert(id, need);
        self.reserved_total += need;
        self.queue.pop_front();
        // presence is guaranteed: `need` above was computed from this
        // request's own record
        if let Some(r) = self.requests.get_mut(&id) {
            r.phase = Phase::Prefill;
            r.admitted_s = Some(now);
            if let Some((tail, path_blocks, matched_tok, _)) = acquired {
                r.prefix_matched = matched_tok;
                r.prefix_group = Some(tail);
                // prefill starts past the adopted prefix
                r.tokens_done = matched_tok;
                r.layer_tok_done = matched_tok;
                self.prefix_paths.insert(id, (tail, path_blocks * pbb));
                if matched_tok > 0 {
                    self.prefix_hits += 1;
                    self.prefix_matched_tokens += matched_tok as u64;
                    self.adoptions.push((id, matched_tok, tail));
                }
            }
        }
        self.active.push(id);
        Some(id)
    }

    /// Produce the next prefill work item for an admitted request, within
    /// the remaining token budget of this batch.
    fn plan_prefill(&self, id: ReqId, tokens_in_batch: usize) -> Option<PrefillWork> {
        let r = &self.requests[&id];
        let plen = r.prompt_len;
        // prefix-matched tokens are adopted, never prefilled: planning
        // works over the suffix [matched, plen)
        let matched = r.prefix_matched;
        match self.cfg.prefill_mode {
            PrefillMode::Plain => {
                if r.tokens_done > matched {
                    return None;
                }
                Some(PrefillWork::Chunk {
                    req: id,
                    start: matched,
                    len: plen - matched,
                    is_last: true,
                })
            }
            PrefillMode::Chunked => {
                let budget = self.cfg.t_max.saturating_sub(tokens_in_batch);
                let len = self
                    .cfg
                    .chunk_tokens
                    .min(budget)
                    .min(plen - r.tokens_done);
                if len == 0 {
                    return None;
                }
                Some(PrefillWork::Chunk {
                    req: id,
                    start: r.tokens_done,
                    len,
                    is_last: r.tokens_done + len == plen,
                })
            }
            PrefillMode::LayerSegmented => {
                let inject = self.cfg.max_inject_tokens.max(1);
                let rem = plen - matched;
                if rem <= inject {
                    // whole (unmatched) prompt per layer; possibly
                    // several layers/batch
                    let layers_per = (inject / rem.max(1)).max(1);
                    let layer_end = (r.layers_done + layers_per).min(self.spec.n_layers);
                    Some(PrefillWork::LayerSegment {
                        req: id,
                        layer_start: r.layers_done,
                        layer_end,
                        tok_start: matched,
                        tok_len: rem,
                        is_last: layer_end == self.spec.n_layers,
                    })
                } else {
                    // hybrid: chunk within the current layer (§3.4 "combination
                    // with chunked prefill")
                    let tok_len = inject.min(plen - r.layer_tok_done);
                    let last_chunk = r.layer_tok_done + tok_len == plen;
                    Some(PrefillWork::LayerSegment {
                        req: id,
                        layer_start: r.layers_done,
                        layer_end: r.layers_done + 1,
                        tok_start: r.layer_tok_done,
                        tok_len,
                        is_last: last_chunk && r.layers_done + 1 == self.spec.n_layers,
                    })
                }
            }
        }
    }

    /// Advance prefill progress after the executor ran a work item.
    /// (The first token is emitted separately via [`Self::emit_token`].)
    pub fn advance_prefill(&mut self, work: &PrefillWork) {
        let Some(r) = self.requests.get_mut(&work.req()) else {
            debug_assert!(false, "prefill work for unknown request {}", work.req());
            return;
        };
        match work {
            PrefillWork::Chunk { len, .. } => {
                r.tokens_done += len;
                debug_assert!(r.tokens_done <= r.prompt_len);
            }
            PrefillWork::LayerSegment { layer_start, layer_end, tok_start, tok_len, .. } => {
                debug_assert_eq!(*layer_start, r.layers_done);
                if *tok_start == r.prefix_matched && tok_start + tok_len == r.prompt_len {
                    // whole unmatched suffix in one segment
                    r.layers_done = *layer_end;
                } else {
                    debug_assert_eq!(*tok_start, r.layer_tok_done);
                    r.layer_tok_done += tok_len;
                    if r.layer_tok_done == r.prompt_len {
                        r.layers_done += 1;
                        // the next layer's chunking restarts past the
                        // adopted prefix, not at token 0
                        r.layer_tok_done = r.prefix_matched;
                    }
                }
                if r.layers_done == self.spec.n_layers {
                    r.tokens_done = r.prompt_len;
                }
            }
        }
    }

    /// Record a produced token. Returns true if the request just finished
    /// (the executor then releases its KV).
    pub fn emit_token(&mut self, id: ReqId, tok: Option<i32>, now: f64) -> bool {
        let Some(r) = self.requests.get_mut(&id) else {
            debug_assert!(false, "token emitted for unknown request {id}");
            return false;
        };
        let was_prefill = r.phase == Phase::Prefill;
        r.push_token(tok, now);
        let (finished, now_decode, plen, n_gen) =
            (r.phase == Phase::Finished, r.phase == Phase::Decode, r.prompt_len, r.n_generated);
        if was_prefill && now_decode {
            // prefill→decode graduation adds a decode candidate the next
            // plan must see: stale out any speculative plan
            self.plan_version += 1;
        }
        if finished {
            self.plan_version += 1;
            self.active.retain(|&a| a != id);
            // reclaim-on-finish: the whole reservation (estimate plus any
            // decode-time growth) frees the instant the request ends —
            // short completions release their unused headroom here
            if let Some(n) = self.reserved.remove(&id) {
                self.reserved_total -= n;
            }
            // the prefix path drops to cached (refs-0) state: the bytes
            // stay charged to the shared pool until eviction reclaims
            // them, keeping the next turn of this conversation warm
            self.release_prefix(id);
            if self.cfg.admission_estimates {
                // fold the observed completion length into the estimate
                const ALPHA: f64 = 0.2;
                self.completion_ewma = if self.completion_obs == 0 {
                    n_gen as f64
                } else {
                    (1.0 - ALPHA) * self.completion_ewma + ALPHA * n_gen as f64
                };
                self.completion_obs += 1;
            }
            true
        } else {
            // decode-time DRAM growth tracking: an estimate-admitted
            // request that outlives its estimate grows its reservation
            // with its actual KV (plus the next token) instead of
            // silently exceeding it. The shared-path bytes are carried
            // by the prefix pool, NOT this private reservation — growing
            // back to the full-lifetime figure here would re-reserve the
            // shared history (the admission double-reservation bug).
            if self.cfg.admission_estimates {
                let shared = self.prefix_paths.get(&id).map(|&(_, b)| b).unwrap_or(0);
                let needed = self.full_kv_bytes(plen, n_gen + 1).saturating_sub(shared);
                let cur = self.reserved.get(&id).copied().unwrap_or(0);
                if needed > cur {
                    self.reserved.insert(id, needed);
                    self.reserved_total += needed - cur;
                }
            }
            false
        }
    }

    /// Cross-iteration staging hints for a planned batch: active decodes
    /// that did NOT make it into this batch (typically skipped by the
    /// WS batch control) — the best prediction of what the *next*
    /// iteration will run. The backend stages their working sets with
    /// leftover prefetch budget under the current batch's compute, so
    /// their gathers start warm when they are finally scheduled.
    pub fn stage_hints(&self, batch: &Batch) -> Vec<ReqId> {
        let mut out = Vec::new();
        self.stage_hints_into(batch, &mut out);
        out
    }

    /// [`Self::stage_hints`] into a caller-owned buffer (cleared first)
    /// — the engine reuses one hint vector across iterations.
    // sparselint: hot
    pub fn stage_hints_into(&self, batch: &Batch, out: &mut Vec<ReqId>) {
        out.clear();
        out.extend(self.active.iter().copied().filter(|id| {
            self.requests[id].phase == Phase::Decode && !batch.decodes.contains(id)
        }));
    }

    /// Read-only preview of the decode half of the NEXT [`Self::plan`]:
    /// the same Algorithm 1 packing walk (FCFS order, WS batch control,
    /// starvation guard), predicting the streak a skip WOULD reach
    /// instead of recording it. The pipelined executor speculates
    /// iteration N+1's batch under iteration N's compute, and a preview
    /// that mutated `ws_skip_streak`, `iterations` or the diagnostics
    /// counters would make speculation observable at `pipeline_depth`
    /// 1 vs 2. The caller must validate the preview before trusting it
    /// ([`Self::plan_version`] unchanged + decode-list equality with the
    /// real plan).
    // sparselint: hot
    pub fn preview_decodes_into(&self, ws: WsEstimate, out: &mut Vec<ReqId>) {
        out.clear();
        let m_avl = self.m_avl();
        let mut ws_used = 0usize;
        let mut tokens = 0usize;
        for &id in &self.active {
            if self.requests[&id].phase != Phase::Decode {
                continue;
            }
            if out.len() >= self.cfg.r_max || tokens + 1 > self.cfg.t_max {
                break;
            }
            if self.cfg.ws_batch_control {
                let w = ws(id);
                if ws_used + w > m_avl {
                    // the real plan would bump this request's skip streak
                    // before testing the starvation guard
                    let streak = self.requests[&id].ws_skip_streak + 1;
                    if streak as usize >= self.cfg.ws_starvation_k.max(1) && w <= m_avl {
                        break;
                    }
                    continue;
                }
                ws_used += w;
            }
            out.push(id);
            tokens += 1;
        }
    }

    /// Active decode requests (executor helper).
    pub fn decoding(&self) -> Vec<ReqId> {
        self.active
            .iter()
            .copied()
            .filter(|id| self.requests[id].phase == Phase::Decode)
            .collect()
    }

    pub fn reserved_bytes(&self) -> usize {
        self.reserved_total
    }

    /// Admission headroom left under the reserving capacity (HBM without
    /// offloading, DRAM with it) — the cluster router's placement bound.
    pub fn admission_headroom(&self) -> usize {
        self.admission_capacity().saturating_sub(self.reserved_total)
    }

    /// Whether `bytes` can be reserved right now without displacement.
    pub fn can_reserve(&self, bytes: usize) -> bool {
        bytes <= self.admission_headroom()
    }

    /// The reservation a live request currently holds (0 when none).
    pub fn reservation_of(&self, id: ReqId) -> usize {
        self.reserved.get(&id).copied().unwrap_or(0)
    }

    /// Atomically remove an admitted request AND release its admission
    /// reservation, for cross-engine KV migration: the request's
    /// scheduler state (phase, prefill/decode progress, timing) moves to
    /// the target engine wholesale, and the freed bytes are visible to
    /// this engine's very next admission decision. Returns the request
    /// and the reservation it held; `None` when the id is unknown, not
    /// yet admitted, or already finished/cancelled (queued requests are
    /// re-routed, not migrated — they hold no reservation).
    ///
    /// Pairs with [`Self::admit_migrated`] on the target: the caller
    /// reserves there in the same scheduling instant, so the bytes are
    /// never double-counted (held at both engines) nor dropped (held at
    /// neither) across the move.
    pub fn extract_for_migration(&mut self, id: ReqId) -> Option<(Request, usize)> {
        let r = self.requests.get(&id)?;
        if !matches!(r.phase, Phase::Prefill | Phase::Decode) {
            return None;
        }
        // remove the record first (presence was just checked), THEN the
        // bookkeeping — so a miss cannot strand half-released state
        let mut req = self.requests.remove(&id)?;
        self.active.retain(|&a| a != id);
        let mut bytes = self.reserved.remove(&id).unwrap_or(0);
        self.reserved_total -= bytes;
        // Sharing is dropped at the cluster boundary: the migration
        // payload deep-copies the full KV (shared history included), so
        // the target must reserve the FULL bytes — private delta plus
        // the path share — and gets no index entry. `prefix_matched`
        // stays (prefill progress over the suffix is still real); the
        // group id does not survive the move.
        if let Some(&(_, shared)) = self.prefix_paths.get(&id) {
            bytes += shared;
            req.prefix_group = None;
        }
        self.release_prefix(id);
        self.plan_version += 1;
        Some((req, bytes))
    }

    /// Admit a migrated request at this (target) engine, re-reserving
    /// exactly the bytes its source reservation held. The request keeps
    /// its phase, progress counters and timestamps (its TTFT clock keeps
    /// running from the original arrival; it rejoins the active set at
    /// the back, behind this engine's older residents). On insufficient
    /// headroom or an id collision the request is handed back unchanged
    /// (`Err`), so the caller can fall back to true eviction — at no
    /// point is the reservation counted at both engines or at neither.
    pub fn admit_migrated(
        &mut self,
        req: Request,
        reserve_bytes: usize,
    ) -> std::result::Result<(), Request> {
        if self.requests.contains_key(&req.id)
            || !matches!(req.phase, Phase::Prefill | Phase::Decode)
            || !self.can_reserve(reserve_bytes)
        {
            return Err(req);
        }
        let id = req.id;
        self.reserved.insert(id, reserve_bytes);
        self.reserved_total += reserve_bytes;
        self.active.push(id);
        self.requests.insert(id, req);
        self.plan_version += 1;
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            vocab: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 4,
            head_dim: 32,
            ffn_dim: 512,
            block_size: 16,
            max_ctx: 2048,
            rope_theta: 1e4,
            kv_dtype_bytes: 4,
        }
    }

    fn sched(cfg: ServingConfig, hbm: usize) -> Scheduler {
        Scheduler::new(cfg, spec(), hbm)
    }

    fn no_ws(_: ReqId) -> usize {
        0
    }

    #[test]
    fn fcfs_admission_and_prefill_then_decode() {
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.max_inject_tokens = 64 * 4;
        let mut s = sched(cfg, 1 << 30);
        s.submit(Request::new(1, 64, 3, 0.0));
        let mut ws = |r| no_ws(r);
        let b = s.plan(0.0, &mut ws);
        assert!(b.decodes.is_empty());
        let w = b.prefill.unwrap();
        // prompt 64 <= maxInject 256 -> 4 layers per batch -> single segment
        assert_eq!(
            w,
            PrefillWork::LayerSegment {
                req: 1, layer_start: 0, layer_end: 4, tok_start: 0, tok_len: 64, is_last: true,
            }
        );
        s.advance_prefill(&w);
        assert!(!s.emit_token(1, Some(9), 0.1)); // first token
        assert_eq!(s.requests[&1].phase, Phase::Decode);
        let b2 = s.plan(0.2, &mut ws);
        assert_eq!(b2.decodes, vec![1]);
        assert!(b2.prefill.is_none());
    }

    #[test]
    fn layer_segmented_splits_layers_across_batches() {
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.max_inject_tokens = 100; // prompt 100 -> 1 layer per batch
        let mut s = sched(cfg, 1 << 30);
        s.submit(Request::new(1, 100, 2, 0.0));
        let mut ws = |r| no_ws(r);
        for layer in 0..4 {
            let b = s.plan(0.0, &mut ws);
            let w = b.prefill.unwrap();
            match &w {
                PrefillWork::LayerSegment { layer_start, layer_end, is_last, .. } => {
                    assert_eq!(*layer_start, layer);
                    assert_eq!(*layer_end, layer + 1);
                    assert_eq!(*is_last, layer == 3);
                }
                _ => panic!("expected layer segment"),
            }
            s.advance_prefill(&w);
        }
        assert_eq!(s.requests[&1].layers_done, 4);
    }

    #[test]
    fn layer_segmented_hybrid_chunks_long_prompts() {
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.max_inject_tokens = 50; // prompt 100 > inject -> chunk within layer
        let mut s = sched(cfg, 1 << 30);
        s.submit(Request::new(1, 100, 2, 0.0));
        let mut ws = |r| no_ws(r);
        let mut work_items = Vec::new();
        loop {
            let b = s.plan(0.0, &mut ws);
            match b.prefill {
                Some(w) => {
                    s.advance_prefill(&w);
                    let done = w.is_last();
                    work_items.push(w);
                    if done {
                        break;
                    }
                }
                None => panic!("stalled"),
            }
        }
        // 2 chunks per layer x 4 layers
        assert_eq!(work_items.len(), 8);
        assert!(matches!(
            work_items[1],
            PrefillWork::LayerSegment { layer_start: 0, tok_start: 50, tok_len: 50, .. }
        ));
    }

    #[test]
    fn chunked_respects_t_max_minus_decodes() {
        let mut cfg = ServingConfig::vllm(64);
        cfg.t_max = 64;
        let mut s = sched(cfg, 1 << 30);
        // one decoding request occupies 1 token of budget
        s.submit(Request::new(1, 32, 8, 0.0));
        let mut ws = |r| no_ws(r);
        let b = s.plan(0.0, &mut ws);
        let w = b.prefill.unwrap();
        s.advance_prefill(&w);
        s.emit_token(1, None, 0.1);
        s.submit(Request::new(2, 200, 2, 0.2));
        let b2 = s.plan(0.3, &mut ws);
        assert_eq!(b2.decodes, vec![1]);
        match b2.prefill.unwrap() {
            PrefillWork::Chunk { len, .. } => assert_eq!(len, 63), // 64 - 1 decode
            _ => panic!(),
        }
    }

    #[test]
    fn non_offload_admission_blocks_on_hbm() {
        // vLLM: HBM fits only one request's reservation -> head-of-line block
        let cfg = ServingConfig::vllm(2048);
        let spec_ = spec();
        let one_req = {
            let s = Scheduler::new(cfg.clone(), spec_.clone(), 0);
            s.full_kv_bytes(512, 64)
        };
        let mut s = Scheduler::new(cfg, spec_, one_req + one_req / 2);
        s.submit(Request::new(1, 512, 64, 0.0));
        s.submit(Request::new(2, 512, 64, 0.0));
        let mut ws = |r| no_ws(r);
        let b = s.plan(0.0, &mut ws);
        assert_eq!(b.prefill.as_ref().unwrap().req(), 1);
        // request 2 cannot be admitted while 1 holds its reservation
        s.advance_prefill(&b.prefill.unwrap());
        s.emit_token(1, None, 0.1);
        let b2 = s.plan(0.2, &mut ws);
        assert!(b2.prefill.is_none(), "req 2 must be blocked");
        assert_eq!(s.n_queued(), 1);
        // finishing request 1 releases the reservation
        for t in 0..63 {
            s.emit_token(1, None, 0.2 + t as f64);
        }
        assert_eq!(s.reserved_bytes(), 0);
        let b3 = s.plan(70.0, &mut ws);
        assert_eq!(b3.prefill.as_ref().unwrap().req(), 2);
    }

    #[test]
    fn offload_admission_blocks_on_dram_capacity() {
        // Offload mode must reserve DRAM bytes (mirroring the non-offload
        // HBM reservation) instead of admitting unboundedly.
        let cfg = ServingConfig::vllm_so(256, 2048);
        let spec_ = spec();
        let one_req = {
            let s = Scheduler::new(cfg.clone(), spec_.clone(), 0);
            s.full_kv_bytes(512, 64)
        };
        let mut s = Scheduler::new(cfg, spec_, 1 << 30)
            .with_dram_capacity(one_req + one_req / 2);
        s.submit(Request::new(1, 512, 64, 0.0));
        s.submit(Request::new(2, 512, 64, 0.0));
        let mut ws = |r| no_ws(r);
        let b = s.plan(0.0, &mut ws);
        assert_eq!(b.prefill.as_ref().unwrap().req(), 1);
        assert_eq!(s.reserved_bytes(), one_req);
        // request 2 blocked until 1's DRAM reservation frees
        s.advance_prefill(&b.prefill.unwrap());
        let b2 = s.plan(0.1, &mut ws);
        assert!(b2.prefill.is_none(), "req 2 must be DRAM-blocked");
        for t in 0..64 {
            s.emit_token(1, None, 0.2 + t as f64);
        }
        assert_eq!(s.reserved_bytes(), 0);
        let b3 = s.plan(70.0, &mut ws);
        assert_eq!(b3.prefill.as_ref().unwrap().req(), 2);
    }

    #[test]
    fn hopeless_head_is_flagged_for_rejection() {
        let cfg = ServingConfig::vllm_so(256, 2048);
        let spec_ = spec();
        let small = {
            let s = Scheduler::new(cfg.clone(), spec_.clone(), 0);
            s.full_kv_bytes(64, 8)
        };
        let mut s = Scheduler::new(cfg, spec_, 1 << 30).with_dram_capacity(small);
        assert!(s.hopeless_head().is_none());
        s.submit(Request::new(1, 512, 64, 0.0)); // needs far more than `small`
        assert_eq!(s.hopeless_head(), Some(1));
        // dropping it unblocks the queue for a request that fits
        assert!(s.cancel(1));
        s.submit(Request::new(2, 64, 8, 0.1));
        assert!(s.hopeless_head().is_none());
        let mut ws = |r| no_ws(r);
        assert_eq!(s.plan(0.2, &mut ws).prefill.unwrap().req(), 2);
    }

    #[test]
    fn starvation_guard_stops_leapfrogging_after_k_skips() {
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.r_max = 16;
        cfg.ws_starvation_k = 3;
        let hbm = 1 << 20;
        let mut s = sched(cfg, hbm);
        for id in 1..=3u32 {
            s.submit(Request::new(id, 16, 100, 0.0));
        }
        // drive all three through prefill into decode
        for _ in 0..3 {
            let mut ws = |r| no_ws(r);
            let b = s.plan(0.0, &mut ws);
            if let Some(w) = b.prefill {
                let done = w.is_last();
                s.advance_prefill(&w);
                if done {
                    s.emit_token(w.req(), None, 0.1);
                }
            }
        }
        assert_eq!(s.decoding().len(), 3);
        let m_avl = s.m_avl();
        // request 1 small, request 2 large (fits alone, not with 1),
        // request 3 small: FCFS would leapfrog 2 with 3 forever.
        let ws_of = move |r: ReqId| match r {
            1 => m_avl / 4,
            2 => m_avl, // alone it fits; never with request 1
            _ => m_avl / 4,
        };
        // skips 1..K-1: request 3 still leapfrogs request 2
        for _ in 0..2 {
            let mut ws = ws_of;
            let b = s.plan(1.0, &mut ws);
            assert_eq!(b.decodes, vec![1, 3], "pre-guard: smaller reqs pack");
        }
        // skip K: guard trips — nothing packs behind request 2 anymore
        let mut ws = ws_of;
        let b = s.plan(2.0, &mut ws);
        assert_eq!(b.decodes, vec![1], "guard must stop packing behind 2");
        assert!(s.ws_starvation_stops >= 1);
        // request 1 finishes -> its WS share frees -> request 2 runs
        for _ in 0..99 {
            s.emit_token(1, None, 3.0);
        }
        assert!(s.requests[&1].is_done());
        let mut ws = ws_of;
        let b = s.plan(4.0, &mut ws);
        assert_eq!(b.decodes, vec![2], "starved request finally progresses");
        assert_eq!(s.requests[&2].ws_skip_streak, 0, "streak resets on batch");
    }

    #[test]
    fn preview_decodes_matches_the_next_plan_without_mutating() {
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.r_max = 16;
        cfg.ws_starvation_k = 3;
        let mut s = sched(cfg, 1 << 20);
        for id in 1..=3u32 {
            s.submit(Request::new(id, 16, 100, 0.0));
        }
        for _ in 0..3 {
            let mut ws = |r| no_ws(r);
            let b = s.plan(0.0, &mut ws);
            if let Some(w) = b.prefill {
                let done = w.is_last();
                s.advance_prefill(&w);
                if done {
                    s.emit_token(w.req(), None, 0.1);
                }
            }
        }
        assert_eq!(s.decoding().len(), 3);
        let m_avl = s.m_avl();
        // request 2 never fits with request 1: rejections and (after K
        // skips) the starvation guard shape the packing
        let ws_of = move |r: ReqId| if r == 2 { m_avl } else { m_avl / 4 };
        for now in 0..4u32 {
            let mut pv = Vec::new();
            let iters = s.iterations;
            let rej = s.ws_rejections;
            let streak = s.requests[&2].ws_skip_streak;
            let mut ws = ws_of;
            s.preview_decodes_into(&mut ws, &mut pv);
            assert_eq!(s.iterations, iters, "preview must not count an iteration");
            assert_eq!(s.ws_rejections, rej, "preview must not record rejections");
            assert_eq!(s.requests[&2].ws_skip_streak, streak, "preview must not touch streaks");
            let mut ws = ws_of;
            let b = s.plan(now as f64, &mut ws);
            assert_eq!(pv, b.decodes, "preview must match the real plan");
        }
    }

    #[test]
    fn plan_version_moves_on_active_set_changes() {
        let mut s = sched(ServingConfig::sparseserve(256, 64, 4), 1 << 20);
        s.submit(Request::new(1, 16, 3, 0.0));
        let v0 = s.plan_version();
        let mut ws = |r| no_ws(r);
        let b = s.plan(0.0, &mut ws);
        assert_eq!(s.plan_version(), v0, "planning itself never bumps the version");
        let w = b.prefill.unwrap();
        s.advance_prefill(&w);
        s.emit_token(1, None, 0.1); // prefill -> decode graduation
        let v1 = s.plan_version();
        assert!(v1 > v0, "graduation stales speculative plans");
        s.emit_token(1, None, 0.2); // mid-decode token: plan-neutral
        assert_eq!(s.plan_version(), v1);
        s.emit_token(1, None, 0.3); // max_new reached -> finish
        assert!(s.plan_version() > v1, "finish stales speculative plans");
        let v2 = s.plan_version();
        assert!(!s.cancel(99), "unknown id: no-op");
        assert_eq!(s.plan_version(), v2);
        s.submit(Request::new(3, 16, 4, 1.0));
        let mut ws = |r| no_ws(r);
        s.plan(1.0, &mut ws);
        assert!(s.cancel(3));
        assert!(s.plan_version() > v2, "cancel stales speculative plans");
    }

    #[test]
    fn hopeless_ws_request_does_not_trip_the_guard() {
        // a decode whose OWN working set exceeds M_avl is hopeless, not
        // starved: the guard must keep letting others pass it
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.ws_starvation_k = 2;
        let mut s = sched(cfg, 1 << 20);
        for id in 1..=2u32 {
            s.submit(Request::new(id, 16, 100, 0.0));
        }
        for _ in 0..2 {
            let mut ws = |r| no_ws(r);
            let b = s.plan(0.0, &mut ws);
            if let Some(w) = b.prefill {
                let done = w.is_last();
                s.advance_prefill(&w);
                if done {
                    s.emit_token(w.req(), None, 0.1);
                }
            }
        }
        let m_avl = s.m_avl();
        let ws_of = move |r: ReqId| if r == 1 { 2 * m_avl } else { m_avl / 4 };
        for _ in 0..5 {
            let mut ws = ws_of;
            let b = s.plan(1.0, &mut ws);
            assert_eq!(b.decodes, vec![2], "req 2 must keep passing the hopeless req 1");
        }
    }

    #[test]
    fn ws_control_caps_batch_size() {
        // Alg. 1: each decode claims 40% of M_avl -> only 2 fit per batch
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.r_max = 16;
        let hbm = 1 << 20; // m_avl = 0.9 MiB
        let mut s = sched(cfg, hbm);
        for id in 1..=4u32 {
            s.submit(Request::new(id, 16, 5, 0.0));
        }
        // drive all four through prefill
        for _ in 0..4 {
            let mut ws = |r| no_ws(r);
            let b = s.plan(0.0, &mut ws);
            if let Some(w) = b.prefill {
                let done = w.is_last();
                s.advance_prefill(&w);
                if done {
                    s.emit_token(w.req(), None, 0.1);
                }
            }
        }
        assert_eq!(s.decoding().len(), 4);
        let ws_each = (s.m_avl() as f64 * 0.4) as usize; // 2 fit, 4 don't
        let mut ws_big = move |_r: ReqId| ws_each;
        let b = s.plan(1.0, &mut ws_big);
        assert_eq!(b.decodes.len(), 2, "Alg.1 must cap at working-set fit");
        assert!(s.ws_rejections >= 2);
        // sanity: invariant sum(ws) <= m_avl
        assert!(ws_each * b.decodes.len() <= s.m_avl());
    }

    #[test]
    fn ws_control_disabled_admits_all() {
        let mut cfg = ServingConfig::vllm_so(256, 64);
        cfg.r_max = 16;
        assert!(!cfg.ws_batch_control);
        let mut s = sched(cfg, 1000);
        for id in 1..=4u32 {
            s.submit(Request::new(id, 16, 5, 0.0));
            // offload mode admits immediately; drive prefill
            let mut ws = |r| no_ws(r);
            let b = s.plan(0.0, &mut ws);
            if let Some(w) = b.prefill {
                let done = w.is_last();
                s.advance_prefill(&w);
                if done {
                    s.emit_token(w.req(), None, 0.1);
                }
            }
        }
        let mut ws_big = |_r: ReqId| 360usize;
        let b = s.plan(1.0, &mut ws_big);
        assert_eq!(b.decodes.len(), 4, "no WS control -> everything batched");
    }

    #[test]
    fn interactive_jumps_queued_batch_requests() {
        let mut s = sched(ServingConfig::sparseserve(256, 64, 4), 1 << 30);
        s.submit(Request::new(1, 64, 2, 0.0));
        s.submit(Request::new(2, 64, 2, 0.0));
        let mut hi = Request::new(3, 64, 2, 0.1);
        hi.priority = Priority::Interactive;
        s.submit(hi);
        // Interactive lands ahead of every waiting Batch request...
        assert_eq!(s.queued_ids(), vec![3, 1, 2]);
        // ...but behind other Interactive requests (FCFS within class).
        let mut hi2 = Request::new(4, 64, 2, 0.2);
        hi2.priority = Priority::Interactive;
        s.submit(hi2);
        assert_eq!(s.queued_ids(), vec![3, 4, 1, 2]);
        let mut ws = |r| no_ws(r);
        let b = s.plan(0.2, &mut ws);
        assert_eq!(b.prefill.unwrap().req(), 3, "interactive admitted first");
    }

    #[test]
    fn cancel_releases_reservation_and_queue_slot() {
        // vLLM-style reservations: cancelling the admitted request must
        // unblock the head-of-line request behind it.
        let cfg = ServingConfig::vllm(2048);
        let spec_ = spec();
        let one_req = {
            let s = Scheduler::new(cfg.clone(), spec_.clone(), 0);
            s.full_kv_bytes(512, 64)
        };
        let mut s = Scheduler::new(cfg, spec_, one_req + one_req / 2);
        s.submit(Request::new(1, 512, 64, 0.0));
        s.submit(Request::new(2, 512, 64, 0.0));
        let mut ws = |r| no_ws(r);
        let b = s.plan(0.0, &mut ws);
        assert_eq!(b.prefill.as_ref().unwrap().req(), 1);
        assert!(s.reserved_bytes() > 0);
        assert!(s.cancel(1));
        assert_eq!(s.reserved_bytes(), 0);
        assert!(!s.cancel(1), "double cancel is a no-op");
        assert_eq!(s.requests[&1].phase, Phase::Cancelled);
        // request 2 is admissible now
        let b2 = s.plan(0.1, &mut ws);
        assert_eq!(b2.prefill.as_ref().unwrap().req(), 2);
        // cancelling a queued-only request just drops it
        s.submit(Request::new(3, 512, 64, 0.2));
        assert!(s.cancel(3));
        assert!(s.queued_ids().is_empty());
    }

    #[test]
    fn stage_hints_name_skipped_decodes() {
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.r_max = 16;
        let hbm = 1 << 20;
        let mut s = sched(cfg, hbm);
        for id in 1..=3u32 {
            s.submit(Request::new(id, 16, 100, 0.0));
        }
        for _ in 0..3 {
            let mut ws = |r| no_ws(r);
            let b = s.plan(0.0, &mut ws);
            if let Some(w) = b.prefill {
                let done = w.is_last();
                s.advance_prefill(&w);
                if done {
                    s.emit_token(w.req(), None, 0.1);
                }
            }
        }
        let m_avl = s.m_avl();
        // request 2's WS is too big to pack with 1: it gets skipped and
        // must appear as the next-iteration staging hint
        let ws_of = move |r: ReqId| if r == 2 { m_avl } else { m_avl / 4 };
        let mut ws = ws_of;
        let b = s.plan(1.0, &mut ws);
        assert_eq!(b.decodes, vec![1, 3]);
        assert_eq!(s.stage_hints(&b), vec![2], "the skipped decode is the hint");
        // everything scheduled -> no hints
        let mut ws_small = |_r: ReqId| 0usize;
        let b = s.plan(2.0, &mut ws_small);
        assert!(s.stage_hints(&b).is_empty());
    }

    #[test]
    fn completion_estimates_admit_more_aggressively() {
        // DRAM fits ~1.2 full-lifetime reservations (prompt 64 + 1000
        // max_new), but completions actually stop after ~8 tokens.
        let mut cfg = ServingConfig::vllm_so(256, 2048);
        cfg.admission_estimates = true;
        let spec_ = spec();
        let full = {
            let s = Scheduler::new(cfg.clone(), spec_.clone(), 0);
            s.full_kv_bytes(64, 1000)
        };
        let mut s = Scheduler::new(cfg, spec_, 1 << 30)
            .with_dram_capacity(full + full / 5);
        // warm the estimator: 4 genuinely short completions (max_new 8)
        for id in 1..=4u32 {
            s.submit(Request::new(id, 64, 8, 0.0));
            let mut ws = |r| no_ws(r);
            let b = s.plan(0.0, &mut ws);
            assert_eq!(b.prefill.as_ref().map(|w| w.req()), Some(id), "fits alone");
            s.advance_prefill(&b.prefill.unwrap());
            for t in 0..8 {
                s.emit_token(id, None, 0.1 + t as f64 * 0.01);
            }
            assert!(s.requests[&id].is_done());
        }
        assert!((s.completion_estimate().unwrap() - 8.0).abs() < 1e-9);
        // now TWO new requests with the same shape fit CONCURRENTLY:
        // the estimate reserves ~12 tokens each instead of 1000
        s.submit(Request::new(10, 64, 1000, 1.0));
        s.submit(Request::new(11, 64, 1000, 1.0));
        let mut ws = |r| no_ws(r);
        let b = s.plan(1.0, &mut ws);
        assert_eq!(b.prefill.as_ref().map(|w| w.req()), Some(10));
        s.advance_prefill(&b.prefill.unwrap());
        s.emit_token(10, None, 1.1);
        let b2 = s.plan(1.2, &mut ws);
        assert_eq!(
            b2.prefill.as_ref().map(|w| w.req()),
            Some(11),
            "estimate-based reservation must admit the second request too"
        );
        assert!(s.reserved_bytes() <= full + full / 5);
        // decode-time growth: request 10 keeps generating past the
        // estimate; its reservation must grow with its actual KV
        let before = s.reserved_bytes();
        for t in 0..200 {
            s.emit_token(10, None, 2.0 + t as f64 * 0.01);
        }
        assert!(
            s.reserved_bytes() > before,
            "long-running request must grow its reservation"
        );
    }

    #[test]
    fn estimates_off_keeps_full_lifetime_reservation() {
        let cfg = ServingConfig::vllm_so(256, 2048);
        assert!(!cfg.admission_estimates);
        let spec_ = spec();
        let mut s = Scheduler::new(cfg, spec_, 1 << 30);
        s.submit(Request::new(1, 64, 1000, 0.0));
        let mut ws = |r| no_ws(r);
        s.plan(0.0, &mut ws);
        assert_eq!(
            s.reserved_bytes(),
            s.full_kv_bytes(64, 1000),
            "default reservation is the full lifetime"
        );
    }

    #[test]
    fn reservation_released_the_moment_a_request_finishes_early() {
        // reclaim-on-finish: with estimates on, a short completion frees
        // its whole reservation (estimate + growth) at the finish token
        let mut cfg = ServingConfig::vllm_so(256, 2048);
        cfg.admission_estimates = true;
        let mut s = Scheduler::new(cfg, spec(), 1 << 30);
        s.submit(Request::new(1, 64, 5, 0.0));
        let mut ws = |r| no_ws(r);
        s.plan(0.0, &mut ws);
        assert!(s.reserved_bytes() > 0);
        s.advance_prefill(&PrefillWork::Chunk { req: 1, start: 0, len: 64, is_last: true });
        for t in 0..5 {
            s.emit_token(1, None, 0.1 + t as f64 * 0.01);
        }
        assert!(s.requests[&1].is_done());
        assert_eq!(s.reserved_bytes(), 0, "finish must reclaim everything");
        assert!(s.completion_estimate().is_some());
    }

    #[test]
    fn plan_into_matches_plan_and_reuses_the_batch() {
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.max_inject_tokens = 64 * 4;
        let mut a = sched(cfg.clone(), 1 << 30);
        let mut b = sched(cfg, 1 << 30);
        for id in 1..=3u32 {
            a.submit(Request::new(id, 64, 3, 0.0));
            b.submit(Request::new(id, 64, 3, 0.0));
        }
        let mut ws = |r| no_ws(r);
        let mut batch = Batch::default();
        for step in 0..6 {
            let expect = a.plan(step as f64, &mut ws);
            b.plan_into(step as f64, &mut ws, &mut batch);
            assert_eq!(batch.decodes, expect.decodes, "step {step}");
            assert_eq!(batch.prefill, expect.prefill, "step {step}");
            if let Some(w) = &expect.prefill {
                let done = w.is_last();
                a.advance_prefill(w);
                b.advance_prefill(w);
                if done {
                    a.emit_token(w.req(), None, 0.1);
                    b.emit_token(w.req(), None, 0.1);
                }
            }
        }
        // the hint variant matches its allocating counterpart too
        let mut hints = vec![99];
        b.stage_hints_into(&batch, &mut hints);
        assert_eq!(hints, a.stage_hints(&batch));
    }

    #[test]
    fn migration_moves_reservation_atomically_under_binding_dram() {
        // Two engines' schedulers with binding DRAM (each fits ~1.5
        // requests). Migrating a request must release the source
        // reservation and re-reserve at the target with no window where
        // the bytes are double-counted (blocking a source admission) or
        // dropped (letting the target oversubscribe).
        let cfg = ServingConfig::vllm_so(256, 2048);
        let spec_ = spec();
        let one = {
            let s = Scheduler::new(cfg.clone(), spec_.clone(), 0);
            s.full_kv_bytes(512, 64)
        };
        let cap = one + one / 2;
        let mut src =
            Scheduler::new(cfg.clone(), spec_.clone(), 1 << 30).with_dram_capacity(cap);
        let mut dst = Scheduler::new(cfg, spec_, 1 << 30).with_dram_capacity(cap);

        src.submit(Request::new(1, 512, 64, 0.0));
        src.submit(Request::new(2, 512, 64, 0.0));
        let mut ws = |r| no_ws(r);
        let b = src.plan(0.0, &mut ws);
        assert_eq!(b.prefill.as_ref().unwrap().req(), 1);
        assert_eq!(src.reserved_bytes(), one);
        // request 2 is DRAM-blocked behind request 1
        src.advance_prefill(&b.prefill.unwrap());
        assert!(src.plan(0.1, &mut ws).prefill.is_none());

        // queued requests are not migratable (no reservation to move)
        assert!(src.extract_for_migration(2).is_none());

        // extract request 1: the source frees INSTANTLY — its very next
        // plan admits the blocked request (no double-count window)
        let (req, bytes) = src.extract_for_migration(1).expect("live request");
        assert_eq!(bytes, one);
        assert_eq!(src.reserved_bytes(), 0);
        assert_eq!(src.plan(0.2, &mut ws).prefill.as_ref().unwrap().req(), 2);

        // target re-reserves the exact same bytes
        dst.admit_migrated(req, bytes).expect("target has headroom");
        assert_eq!(dst.reserved_bytes(), one);
        assert_eq!(dst.n_active(), 1);
        // cluster-wide invariant: exactly `2 * one` reserved in total
        assert_eq!(src.reserved_bytes() + dst.reserved_bytes(), 2 * one);

        // a second migrated request does NOT fit the target's remaining
        // half-reservation: it is handed back unchanged, reserving
        // nothing (the caller falls back to true eviction)
        let (req2, bytes2) = src.extract_for_migration(2).expect("admitted above");
        let back = dst.admit_migrated(req2, bytes2).expect_err("must not fit");
        assert_eq!(back.id, 2);
        assert_eq!(dst.reserved_bytes(), one, "failed admit reserves nothing");
        // an id collision is also refused
        let mut dup = Request::new(1, 512, 64, 0.0);
        dup.phase = Phase::Decode;
        assert!(dst.admit_migrated(dup, 0).is_err());
    }

    #[test]
    fn r_max_caps_decodes() {
        let mut cfg = ServingConfig::vllm_so(256, 2048);
        cfg.r_max = 2;
        let mut s = sched(cfg, 1 << 30);
        for id in 1..=3u32 {
            s.submit(Request::new(id, 16, 5, 0.0));
            let mut ws = |r| no_ws(r);
            let b = s.plan(0.0, &mut ws);
            if let Some(w) = b.prefill {
                let done = w.is_last();
                s.advance_prefill(&w);
                if done {
                    s.emit_token(w.req(), None, 0.1);
                }
            }
        }
        let mut ws = |r| no_ws(r);
        let b = s.plan(1.0, &mut ws);
        assert_eq!(b.decodes.len(), 2);
    }

    // ------------------------------------------- cross-request prefix sharing

    fn sharing_cfg() -> ServingConfig {
        let mut cfg = ServingConfig::sparseserve(256, 64, 4);
        cfg.prefix_sharing = true;
        cfg
    }

    /// Drive `id`'s prefill to completion, then emit every output token.
    fn run_to_finish(s: &mut Scheduler, id: ReqId) {
        let mut ws = |r| no_ws(r);
        loop {
            let b = s.plan(0.0, &mut ws);
            match b.prefill {
                Some(w) if w.req() == id => {
                    let done = w.is_last();
                    s.advance_prefill(&w);
                    if done {
                        break;
                    }
                }
                _ => break,
            }
        }
        let max_new = s.requests[&id].max_new_tokens;
        for t in 0..max_new {
            if s.emit_token(id, None, 0.1 + t as f64) {
                break;
            }
        }
        assert_eq!(s.requests[&id].phase, Phase::Finished);
    }

    /// Submit a token-filled request, drive its prefill to completion and
    /// graduate it to decode: the single prefill slot frees for the next
    /// admission while this request's path references stay held.
    fn admit_to_decode(s: &mut Scheduler, id: ReqId, prompt: Vec<i32>) {
        s.submit(Request::with_prompt(id, prompt, 8, 0.0));
        let mut ws = |r| no_ws(r);
        loop {
            let b = s.plan(0.0, &mut ws);
            let Some(w) = b.prefill else { break };
            assert_eq!(w.req(), id, "single prefill slot, strict FCFS");
            let done = w.is_last();
            s.advance_prefill(&w);
            if done {
                assert!(!s.emit_token(id, None, 0.1), "max_new 8 > 1");
                break;
            }
        }
        assert_eq!(s.requests[&id].phase, Phase::Decode);
    }

    #[test]
    fn prefix_hit_reserves_only_the_unmatched_suffix() {
        let mut s = sched(sharing_cfg(), 1 << 30);
        let pbb = s.prefix_block_bytes();
        // 64 shared system tokens + 16 unique tokens = 5 blocks of 16
        let shared: Vec<i32> = (0..64).collect();
        let mut p1 = shared.clone();
        p1.extend(1000..1016);
        let mut p2 = shared.clone();
        p2.extend(2000..2016);
        let full = s.full_kv_bytes(80, 8);

        s.submit(Request::with_prompt(1, p1, 8, 0.0));
        let mut ws = |r| no_ws(r);
        s.plan(0.0, &mut ws);
        // first sharer: no match, but its whole 5-block path shifts from
        // the private reservation to the shared pool
        assert_eq!(s.prefix_hits, 0);
        assert_eq!(s.reservation_of(1), full - 5 * pbb);
        assert_eq!(s.prefix_resident_bytes(), 5 * pbb);
        assert_eq!(s.reservation_of(1) + s.prefix_resident_bytes(), full);
        assert!(s.pop_adoption().is_none(), "no match, nothing to adopt");
        run_to_finish(&mut s, 1);

        // second sharer: 4 blocks (64 tokens) match; only its unique
        // tail block is new in the pool
        s.submit(Request::with_prompt(2, p2, 8, 1.0));
        s.plan(1.0, &mut ws);
        assert_eq!(s.prefix_hits, 1);
        assert_eq!(s.prefix_matched_tokens, 64);
        assert_eq!(s.requests[&2].prefix_matched, 64);
        assert_eq!(s.requests[&2].tokens_done, 64, "prefill starts past the match");
        assert_eq!(s.reservation_of(2), full - 5 * pbb);
        assert_eq!(s.prefix_resident_bytes(), 6 * pbb, "4 shared + 2 unique tails");
        let (id, matched, _tail) = s.pop_adoption().expect("hit queues an adoption");
        assert_eq!((id, matched), (2, 64));
    }

    #[test]
    fn reentering_turn_tops_up_without_regrowing_shared_bytes() {
        // Warm the completion estimator so admission reserves a SHORT
        // estimate and decode must top the reservation up token by token
        // — the double-reservation bug was this top-up path re-growing
        // toward the full figure including the shared history.
        let mut s = sched(sharing_cfg(), 1 << 30);
        for id in 10..14u32 {
            s.submit(Request::new(id, 16, 2, 0.0));
            run_to_finish(&mut s, id);
        }
        assert!(s.completion_estimate().is_some());

        // turn 1 of a conversation: 80-token prompt, finished
        let hist: Vec<i32> = (0..80).collect();
        s.submit(Request::with_prompt(1, hist.clone(), 2, 1.0));
        run_to_finish(&mut s, 1);

        // turn 2 re-sends the history plus 32 fresh tokens: the cached
        // chain matches and only the delta is reserved
        let mut turn2 = hist;
        turn2.extend(3000..3032);
        s.submit(Request::with_prompt(2, turn2, 64, 2.0));
        let mut ws = |r| no_ws(r);
        s.plan(2.0, &mut ws);
        let pbb = s.prefix_block_bytes();
        assert_eq!(s.requests[&2].prefix_matched, 80, "warm history fully matched");
        // the whole 7-block path (5 matched + 2 fresh) rides the pool
        let shared_bytes = 7 * pbb;
        assert_eq!(s.prefix_paths.get(&2).map(|&(_, b)| b), Some(shared_bytes));
        let reserved_at_admit = s.reservation_of(2);
        assert!(
            reserved_at_admit < s.full_kv_bytes(112, 64) - shared_bytes,
            "estimate-based admission must reserve less than the conservative delta"
        );

        // drive the 32-token suffix prefill, then graduate to decode
        loop {
            let b = s.plan(2.0, &mut ws);
            let Some(w) = b.prefill else { break };
            let done = w.is_last();
            s.advance_prefill(&w);
            if done {
                break;
            }
        }

        // outlive the estimate: every top-up targets exactly
        // (actual KV so far + next token) MINUS the shared path — the
        // reservation converges to (full - shared), never to the
        // double-counted full figure
        for t in 0..63usize {
            assert!(!s.emit_token(2, None, 3.0 + t as f64), "finishes on token 64");
            let n_gen = s.requests[&2].n_generated;
            let cap = s.full_kv_bytes(112, n_gen + 1).saturating_sub(shared_bytes);
            assert_eq!(
                s.reservation_of(2),
                reserved_at_admit.max(cap),
                "top-up must hold exactly the private delta at n_gen={n_gen}"
            );
        }
        assert_eq!(
            s.reservation_of(2),
            s.full_kv_bytes(112, 64) - shared_bytes,
            "converged reservation excludes the shared history"
        );
    }

    #[test]
    fn zero_hit_sharing_matches_exclusive_accounting_exactly() {
        // Unique prompts: the index never matches. A sharing-on scheduler
        // must track HEAD's exclusive accounting in lockstep — identical
        // plans, identical finish decisions, and total KV charge
        // (private + shared pool) equal to the exclusive reservation at
        // every step.
        let mut on = sched(sharing_cfg(), 1 << 30);
        let mut off = sched(ServingConfig::sparseserve(256, 64, 4), 1 << 30);
        let pbb = on.prefix_block_bytes();
        for id in 1..=3u32 {
            let p: Vec<i32> = (0..96).map(|t| (id as i32) * 1000 + t).collect();
            on.submit(Request::with_prompt(id, p.clone(), 4, 0.0));
            off.submit(Request::with_prompt(id, p, 4, 0.0));
        }
        let mut ws_a = |r| no_ws(r);
        let mut ws_b = |r| no_ws(r);
        // each request's 6-block path stays cached (refs 0) after finish
        let mut finished_paths = 0usize;
        for step in 0..64 {
            let t = 0.1 * step as f64;
            let b_on = on.plan(t, &mut ws_a);
            let b_off = off.plan(t, &mut ws_b);
            assert_eq!(b_on, b_off, "identical plans at 0% hits");
            for &d in &b_on.decodes {
                let fin = on.emit_token(d, None, t);
                assert_eq!(fin, off.emit_token(d, None, t), "identical finishes");
                finished_paths += fin as usize;
            }
            if let Some(w) = b_on.prefill {
                let done = w.is_last();
                on.advance_prefill(&w);
                off.advance_prefill(&w);
                if done {
                    assert!(!on.emit_token(w.req(), None, t));
                    assert!(!off.emit_token(w.req(), None, t));
                }
            }
            assert_eq!(
                on.reserved_bytes() + on.prefix_resident_bytes(),
                off.reserved_bytes() + finished_paths * 6 * pbb,
                "conservation: sharing shifts bytes, never creates or drops them"
            );
        }
        assert_eq!(on.prefix_hits, 0);
        assert_eq!(on.prefix_matched_tokens, 0);
        assert!(on.pop_adoption().is_none());
        assert!(on.requests.values().all(|r| r.is_done()), "all three served");
        assert_eq!(finished_paths, 3);
    }

    #[test]
    fn migration_export_folds_shared_bytes_into_the_reservation() {
        let mut src = sched(sharing_cfg(), 1 << 30);
        let shared: Vec<i32> = (0..64).collect();
        let mut p1 = shared.clone();
        p1.extend(1000..1016);
        let mut p2 = shared.clone();
        p2.extend(2000..2016);
        admit_to_decode(&mut src, 1, p1);
        admit_to_decode(&mut src, 2, p2);
        assert_eq!(src.prefix_hits, 1);
        let full = src.full_kv_bytes(80, 8);
        let pbb = src.prefix_block_bytes();
        assert_eq!(src.reservation_of(2), full - 5 * pbb);

        // the exported reservation is the FULL footprint: the payload
        // deep-copies the shared history, so the target prices it
        // unshared and the request carries no group id across the wire
        let (req, bytes) = src.extract_for_migration(2).expect("admitted");
        assert_eq!(bytes, full, "private delta + path share");
        assert!(req.prefix_group.is_none());
        assert_eq!(req.prefix_matched, 64, "prefill progress stays real");

        // the target re-reserves exactly those bytes, unshared
        let mut dst = sched(ServingConfig::sparseserve(256, 64, 4), 1 << 30);
        dst.admit_migrated(req, bytes).expect("fits");
        assert_eq!(dst.reservation_of(2), full);
        assert_eq!(dst.prefix_resident_bytes(), 0);

        // source: request 1 still holds its path; request 2's references
        // dropped to cached without disturbing the pool charge
        assert_eq!(src.reservation_of(1), full - 5 * pbb);
        assert_eq!(src.prefix_resident_bytes(), 6 * pbb);
    }
}
