//! Request state machine + the per-request lifecycle vocabulary
//! (parameters, priority classes, timing summaries).

use crate::memory::ReqId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for admission.
    Queued,
    /// Admitted; prompt being prefilled (chunked or layer-segmented).
    Prefill,
    /// First token emitted; generating.
    Decode,
    Finished,
    /// Client-cancelled (KV state released, no further scheduling).
    Cancelled,
}

/// Scheduling class of a request. `Interactive` requests are queued ahead
/// of every waiting `Batch` request (FCFS within a class); admission of a
/// request already prefilling is never revoked.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    Interactive,
    #[default]
    Batch,
}

/// Per-request serving parameters, carried by `SubmitRequest` and copied
/// into the scheduler's [`Request`] on submit.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestParams {
    /// Cap on generated tokens.
    pub max_new_tokens: usize,
    /// Generation stops early when one of these token ids is produced.
    /// The matched stop token is kept in the output (unlike OpenAI's
    /// `stop`, which omits the matched sequence). Real backend only:
    /// the simulator emits no token ids, so stop tokens can never match
    /// there and `max_new_tokens` is the only bound.
    pub stop_tokens: Vec<i32>,
    /// Scheduling class (queue ordering).
    pub priority: Priority,
    /// Optional TTFT service-level objective, seconds. Recorded against
    /// the achieved TTFT in `RunMetrics` (violations counter).
    pub ttft_slo_s: Option<f64>,
    /// Per-request override of the DSA token budget. Honored by backends
    /// that can re-budget per request (the simulator); the AOT-compiled
    /// real backend has a fixed kernel budget and ignores it.
    pub sparse_budget: Option<usize>,
}

impl Default for RequestParams {
    fn default() -> Self {
        Self {
            max_new_tokens: 1,
            stop_tokens: Vec::new(),
            priority: Priority::Batch,
            ttft_slo_s: None,
            sparse_budget: None,
        }
    }
}

/// Timing summary of one served request (reported in `StreamEvent::Done`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct RequestTiming {
    /// Tokens produced (decode steps, including the prefill's first token).
    pub n_tokens: usize,
    /// Time to first token, seconds since arrival.
    pub ttft_s: Option<f64>,
    /// Mean time between tokens, seconds (0 when fewer than 2 tokens).
    pub tbt_mean_s: f64,
    /// Admission delay, seconds since arrival.
    pub queue_delay_s: Option<f64>,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    /// Prompt token ids (empty under the simulator backend).
    pub prompt: Vec<i32>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub arrival_s: f64,

    // ---- lifecycle parameters (see [`RequestParams`]) ----
    pub priority: Priority,
    pub stop_tokens: Vec<i32>,
    pub ttft_slo_s: Option<f64>,
    pub sparse_budget: Option<usize>,

    pub phase: Phase,
    /// Prompt tokens covered by a shared KV prefix matched at admission
    /// (block-aligned; 0 without `prefix_sharing`). Prefill starts past
    /// these tokens — their KV is adopted from the shared block table.
    pub prefix_matched: usize,
    /// Tail node id of this request's acquired path in the scheduler's
    /// `PrefixIndex` (`None` without sharing). Released exactly once at
    /// finish / cancel / migration-export.
    pub prefix_group: Option<u32>,
    /// Consecutive iterations WS batch control skipped this decode
    /// (starvation-guard input; reset when it is batched).
    pub ws_skip_streak: u32,
    /// Chunked-prefill progress: prompt tokens fully processed (all layers).
    pub tokens_done: usize,
    /// Layer-segmented progress: layers fully processed over the prompt.
    pub layers_done: usize,
    /// Within-layer token progress (layer-segmented x chunked hybrid).
    pub layer_tok_done: usize,

    /// Generated token ids (real backend) / count (sim tracks len only).
    pub generated: Vec<i32>,
    pub n_generated: usize,

    // ---- timestamps (seconds on the serving clock) ----
    pub admitted_s: Option<f64>,
    pub first_token_s: Option<f64>,
    pub last_token_s: Option<f64>,
    pub finished_s: Option<f64>,
    /// Per-token inter-arrival times (TBT samples).
    pub tbt: Vec<f64>,
}

impl Request {
    pub fn new(id: ReqId, prompt_len: usize, max_new_tokens: usize, arrival_s: f64) -> Self {
        Self {
            id,
            prompt: Vec::new(),
            prompt_len,
            max_new_tokens,
            arrival_s,
            priority: Priority::Batch,
            stop_tokens: Vec::new(),
            ttft_slo_s: None,
            sparse_budget: None,
            phase: Phase::Queued,
            prefix_matched: 0,
            prefix_group: None,
            ws_skip_streak: 0,
            tokens_done: 0,
            layers_done: 0,
            layer_tok_done: 0,
            generated: Vec::new(),
            n_generated: 0,
            admitted_s: None,
            first_token_s: None,
            last_token_s: None,
            finished_s: None,
            tbt: Vec::new(),
        }
    }

    pub fn with_prompt(id: ReqId, prompt: Vec<i32>, max_new_tokens: usize, arrival_s: f64) -> Self {
        let mut r = Self::new(id, prompt.len(), max_new_tokens, arrival_s);
        r.prompt = prompt;
        r
    }

    /// Build a request from lifecycle parameters (the `SubmitRequest` path).
    pub fn with_params(
        id: ReqId,
        prompt: Vec<i32>,
        prompt_len: usize,
        params: RequestParams,
        arrival_s: f64,
    ) -> Self {
        let mut r = Self::new(id, prompt_len, params.max_new_tokens, arrival_s);
        r.prompt = prompt;
        r.priority = params.priority;
        r.stop_tokens = params.stop_tokens;
        r.ttft_slo_s = params.ttft_slo_s;
        r.sparse_budget = params.sparse_budget;
        r
    }

    /// The lifecycle parameter bundle this request was submitted with.
    pub fn params(&self) -> RequestParams {
        RequestParams {
            max_new_tokens: self.max_new_tokens,
            stop_tokens: self.stop_tokens.clone(),
            priority: self.priority,
            ttft_slo_s: self.ttft_slo_s,
            sparse_budget: self.sparse_budget,
        }
    }

    /// Record a generated token at time `now`.
    pub fn push_token(&mut self, tok: Option<i32>, now: f64) {
        if self.first_token_s.is_none() {
            self.first_token_s = Some(now);
        } else if let Some(last) = self.last_token_s {
            self.tbt.push(now - last);
        }
        self.last_token_s = Some(now);
        let hit_stop = match tok {
            Some(t) => {
                self.generated.push(t);
                self.stop_tokens.contains(&t)
            }
            None => false,
        };
        self.n_generated += 1;
        if self.n_generated >= self.max_new_tokens || hit_stop {
            self.phase = Phase::Finished;
            self.finished_s = Some(now);
        } else {
            self.phase = Phase::Decode;
        }
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_s.map(|t| t - self.arrival_s)
    }

    pub fn queue_delay(&self) -> Option<f64> {
        self.admitted_s.map(|t| t - self.arrival_s)
    }

    pub fn is_done(&self) -> bool {
        self.phase == Phase::Finished
    }

    pub fn is_cancelled(&self) -> bool {
        self.phase == Phase::Cancelled
    }

    /// Mean inter-token time (0 with fewer than two tokens).
    pub fn tbt_mean(&self) -> f64 {
        if self.tbt.is_empty() {
            0.0
        } else {
            self.tbt.iter().sum::<f64>() / self.tbt.len() as f64
        }
    }

    /// Timing summary for `StreamEvent::Done` / `StepOutcome::finished`.
    pub fn timing(&self) -> RequestTiming {
        RequestTiming {
            n_tokens: self.n_generated,
            ttft_s: self.ttft(),
            tbt_mean_s: self.tbt_mean(),
            queue_delay_s: self.queue_delay(),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn token_lifecycle_and_metrics() {
        let mut r = Request::new(1, 100, 3, 10.0);
        r.admitted_s = Some(11.0);
        r.push_token(Some(5), 12.0);
        assert_eq!(r.phase, Phase::Decode);
        assert_eq!(r.ttft(), Some(2.0));
        assert_eq!(r.queue_delay(), Some(1.0));
        r.push_token(Some(6), 12.5);
        r.push_token(Some(7), 13.5);
        assert!(r.is_done());
        assert_eq!(r.finished_s, Some(13.5));
        assert_eq!(r.tbt, vec![0.5, 1.0]);
        assert_eq!(r.generated, vec![5, 6, 7]);
        let t = r.timing();
        assert_eq!(t.n_tokens, 3);
        assert_eq!(t.ttft_s, Some(2.0));
        assert!((t.tbt_mean_s - 0.75).abs() < 1e-12);
    }

    #[test]
    fn single_token_request_finishes_immediately() {
        let mut r = Request::new(2, 10, 1, 0.0);
        r.push_token(None, 1.0);
        assert!(r.is_done());
        assert!(r.tbt.is_empty());
        assert_eq!(r.n_generated, 1);
    }

    #[test]
    fn stop_token_ends_generation_early() {
        let params = RequestParams {
            max_new_tokens: 100,
            stop_tokens: vec![42],
            ..Default::default()
        };
        let mut r = Request::with_params(3, vec![1, 2, 3], 3, params, 0.0);
        r.push_token(Some(7), 1.0);
        assert_eq!(r.phase, Phase::Decode);
        r.push_token(Some(42), 2.0);
        assert!(r.is_done(), "stop token must finish the request");
        assert_eq!(r.generated, vec![7, 42]);
        assert_eq!(r.timing().n_tokens, 2);
    }

    #[test]
    fn params_round_trip() {
        let params = RequestParams {
            max_new_tokens: 9,
            stop_tokens: vec![1, 2],
            priority: Priority::Interactive,
            ttft_slo_s: Some(0.5),
            sparse_budget: Some(128),
        };
        let r = Request::with_params(4, Vec::new(), 77, params.clone(), 0.0);
        assert_eq!(r.prompt_len, 77);
        assert_eq!(r.params(), params);
    }
}
