//! Request state machine.

use crate::memory::ReqId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for admission.
    Queued,
    /// Admitted; prompt being prefilled (chunked or layer-segmented).
    Prefill,
    /// First token emitted; generating.
    Decode,
    Finished,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    /// Prompt token ids (empty under the simulator backend).
    pub prompt: Vec<i32>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub arrival_s: f64,

    pub phase: Phase,
    /// Chunked-prefill progress: prompt tokens fully processed (all layers).
    pub tokens_done: usize,
    /// Layer-segmented progress: layers fully processed over the prompt.
    pub layers_done: usize,
    /// Within-layer token progress (layer-segmented x chunked hybrid).
    pub layer_tok_done: usize,

    /// Generated token ids (real backend) / count (sim tracks len only).
    pub generated: Vec<i32>,
    pub n_generated: usize,

    // ---- timestamps (seconds on the serving clock) ----
    pub admitted_s: Option<f64>,
    pub first_token_s: Option<f64>,
    pub last_token_s: Option<f64>,
    pub finished_s: Option<f64>,
    /// Per-token inter-arrival times (TBT samples).
    pub tbt: Vec<f64>,
}

impl Request {
    pub fn new(id: ReqId, prompt_len: usize, max_new_tokens: usize, arrival_s: f64) -> Self {
        Self {
            id,
            prompt: Vec::new(),
            prompt_len,
            max_new_tokens,
            arrival_s,
            phase: Phase::Queued,
            tokens_done: 0,
            layers_done: 0,
            layer_tok_done: 0,
            generated: Vec::new(),
            n_generated: 0,
            admitted_s: None,
            first_token_s: None,
            last_token_s: None,
            finished_s: None,
            tbt: Vec::new(),
        }
    }

    pub fn with_prompt(id: ReqId, prompt: Vec<i32>, max_new_tokens: usize, arrival_s: f64) -> Self {
        let mut r = Self::new(id, prompt.len(), max_new_tokens, arrival_s);
        r.prompt = prompt;
        r
    }

    /// Record a generated token at time `now`.
    pub fn push_token(&mut self, tok: Option<i32>, now: f64) {
        if self.first_token_s.is_none() {
            self.first_token_s = Some(now);
        } else if let Some(last) = self.last_token_s {
            self.tbt.push(now - last);
        }
        self.last_token_s = Some(now);
        if let Some(t) = tok {
            self.generated.push(t);
        }
        self.n_generated += 1;
        if self.n_generated >= self.max_new_tokens {
            self.phase = Phase::Finished;
            self.finished_s = Some(now);
        } else {
            self.phase = Phase::Decode;
        }
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_s.map(|t| t - self.arrival_s)
    }

    pub fn queue_delay(&self) -> Option<f64> {
        self.admitted_s.map(|t| t - self.arrival_s)
    }

    pub fn is_done(&self) -> bool {
        self.phase == Phase::Finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_lifecycle_and_metrics() {
        let mut r = Request::new(1, 100, 3, 10.0);
        r.admitted_s = Some(11.0);
        r.push_token(Some(5), 12.0);
        assert_eq!(r.phase, Phase::Decode);
        assert_eq!(r.ttft(), Some(2.0));
        assert_eq!(r.queue_delay(), Some(1.0));
        r.push_token(Some(6), 12.5);
        r.push_token(Some(7), 13.5);
        assert!(r.is_done());
        assert_eq!(r.finished_s, Some(13.5));
        assert_eq!(r.tbt, vec![0.5, 1.0]);
        assert_eq!(r.generated, vec![5, 6, 7]);
    }

    #[test]
    fn single_token_request_finishes_immediately() {
        let mut r = Request::new(2, 10, 1, 0.0);
        r.push_token(None, 1.0);
        assert!(r.is_done());
        assert!(r.tbt.is_empty());
        assert_eq!(r.n_generated, 1);
    }
}
