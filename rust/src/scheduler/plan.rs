//! Batch plans: what the executor runs in one hybrid iteration.

use crate::memory::ReqId;

/// One unit of prefill work scheduled into a hybrid batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PrefillWork {
    /// Chunked (plain = one chunk covering the whole prompt): process
    /// prompt tokens `[start, start+len)` through ALL layers.
    Chunk {
        req: ReqId,
        start: usize,
        len: usize,
        /// Completing this chunk finishes prefill (emits the first token).
        is_last: bool,
    },
    /// Layer-segmented (§3.4): process prompt tokens
    /// `[tok_start, tok_start+tok_len)` through layers
    /// `[layer_start, layer_end)`. `tok_len` spans the whole prompt unless
    /// the prompt exceeds maxInjectToken (hybrid chunking).
    LayerSegment {
        req: ReqId,
        layer_start: usize,
        layer_end: usize,
        tok_start: usize,
        tok_len: usize,
        is_last: bool,
    },
}

impl PrefillWork {
    pub fn req(&self) -> ReqId {
        match self {
            PrefillWork::Chunk { req, .. } | PrefillWork::LayerSegment { req, .. } => *req,
        }
    }

    pub fn is_last(&self) -> bool {
        match self {
            PrefillWork::Chunk { is_last, .. } | PrefillWork::LayerSegment { is_last, .. } => {
                *is_last
            }
        }
    }

    /// Tokens injected into the batch by this work item (the T_max /
    /// maxInjectToken accounting unit).
    pub fn injected_tokens(&self) -> usize {
        match self {
            PrefillWork::Chunk { len, .. } => *len,
            PrefillWork::LayerSegment { layer_start, layer_end, tok_len, .. } => {
                (layer_end - layer_start) * tok_len
            }
        }
    }
}

/// One hybrid iteration: decode steps for `decodes` plus at most one
/// prefill work item (paper Fig. 9 layout).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Batch {
    pub decodes: Vec<ReqId>,
    pub prefill: Option<PrefillWork>,
}

impl Batch {
    pub fn is_empty(&self) -> bool {
        self.decodes.is_empty() && self.prefill.is_none()
    }

    pub fn n_requests(&self) -> usize {
        self.decodes.len() + usize::from(self.prefill.is_some())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn injected_tokens_accounting() {
        let c = PrefillWork::Chunk { req: 1, start: 0, len: 256, is_last: false };
        assert_eq!(c.injected_tokens(), 256);
        let l = PrefillWork::LayerSegment {
            req: 1, layer_start: 2, layer_end: 4, tok_start: 0, tok_len: 1024, is_last: true,
        };
        assert_eq!(l.injected_tokens(), 2048);
        assert!(l.is_last());
        assert_eq!(l.req(), 1);
    }
}
