//! The request scheduler (paper §3.1 left box, §3.3, §3.4).
//!
//! FCFS continuous batching with hybrid prefill+decode batches, extended
//! with the paper's two scheduling contributions:
//!
//! - **working-set-aware batch size control** (Algorithm 1): the
//!   candidate batch from the base FCFS policy is filtered so the sum of
//!   per-request working sets stays within the available HBM cache,
//!   preventing thrashing (Fig. 15);
//! - **layer-segmented prefill** (§3.4): prefill proceeds layer by layer
//!   over the full prompt, bounding prefill HBM to one layer and
//!   sidestepping the chunked-prefill head-of-line blocking (Fig. 16).

// Serving-path no-panic discipline (satellite of sparselint's
// `no-panic` pass): unwrap/expect in this module tree is a clippy
// warning, denied under CI's `-D warnings`. The few justified
// sites carry fn-level allows next to their sparselint comments.
#![warn(clippy::unwrap_used, clippy::expect_used)]

mod core;
mod plan;
mod request;

pub use self::core::{Scheduler, WsEstimate};
pub use plan::{Batch, PrefillWork};
pub use request::{Phase, Priority, Request, RequestParams, RequestTiming};
