//! Working-set-aware request placement across engines.
//!
//! The router predicts each request's demand on both memory tiers —
//! DRAM (the full-lifetime KV reservation admission charges) and HBM
//! (the decode working set: the sparse attention budget, which is what
//! actually competes for HBM under DSA) — and places it on the engine
//! whose *post-admission* utilization is lowest. The HBM-side
//! prediction is refined online: every step the router compares each
//! engine's observed `mem_stats().hbm_bytes_used` (populated by that
//! engine's working-set cache residency) against the sum of its live
//! placements' predicted working sets and folds the ratio into an EWMA
//! correction factor, so a model whose real working sets run hotter or
//! colder than `min(len, budget)` converges to honest scores.
//!
//! Fresh placements are gated by a DRAM watermark (`admit_frac` of the
//! engine's admission capacity) so a slice of every engine's DRAM stays
//! in reserve for inbound migrations; migrations themselves are gated
//! by the target scheduler's true `can_reserve`. When no engine clears
//! the watermark the router returns a typed
//! [`ClusterError::AdmissionRejected`] — the cluster-level analogue of
//! the scheduler's hopeless-head-of-queue rejection.

use std::collections::HashMap;

use crate::memory::ReqId;

/// Typed cluster-level admission failure.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterError {
    /// No engine can take the request's DRAM reservation below its
    /// placement watermark: the demand is reported alongside the best
    /// headroom any engine could offer, so callers can distinguish
    /// "cluster full right now" from "request can never fit".
    AdmissionRejected { demand_bytes: usize, best_headroom_bytes: usize },
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::AdmissionRejected { demand_bytes, best_headroom_bytes } => write!(
                f,
                "cluster admission rejected: demand {demand_bytes} B exceeds every \
                 engine's placement headroom (best {best_headroom_bytes} B)"
            ),
        }
    }
}

impl std::error::Error for ClusterError {}

/// Predicted memory demand of one request, on both tiers.
#[derive(Debug, Clone, Copy)]
pub struct Demand {
    /// Full-lifetime KV bytes (prompt + all output tokens): what the
    /// target scheduler will reserve against DRAM at admission.
    pub dram_bytes: usize,
    /// Decode working-set bytes: `min(seq_len, sparse budget)` worth of
    /// KV blocks — the request's steady HBM footprint under DSA.
    pub ws_bytes: usize,
}

/// A point-in-time view of one engine, captured by the cluster driver.
#[derive(Debug, Clone, Copy)]
pub struct EngineSnapshot {
    /// The scheduler's admission capacity (DRAM with offloading).
    pub dram_capacity: usize,
    /// HBM bytes available to decode working sets (`Scheduler::m_avl`).
    pub ws_capacity: usize,
    /// Live requests (queued + active) — the least-loaded tiebreak.
    pub n_live: usize,
    /// Observed HBM residency (`MemStats::hbm_bytes_used`): the online
    /// feedback that calibrates the working-set prediction.
    pub hbm_bytes_used: usize,
    /// Whether the engine's scheduler can take `reserve_bytes` right
    /// now without displacement (migration gate; fresh placements use
    /// the router's own watermark accounting instead).
    pub can_reserve: bool,
}

/// Router tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Fraction of each engine's DRAM admission capacity fresh
    /// placements may fill; the rest is headroom kept for migrations.
    pub admit_frac: f64,
    /// EWMA weight of each new observed/predicted working-set ratio.
    pub feedback_alpha: f64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { admit_frac: 0.85, feedback_alpha: 0.25 }
    }
}

struct Placement {
    engine: usize,
    demand: Demand,
}

/// Working-set-aware placement state over `n` engines.
pub struct Router {
    cfg: RouterConfig,
    /// Per-engine EWMA of observed HBM bytes / predicted WS bytes.
    correction: Vec<f64>,
    /// Live placements: requests routed and not yet finished/evicted.
    placed: HashMap<ReqId, Placement>,
    /// Per-engine sums over `placed` (kept incrementally).
    dram_placed: Vec<usize>,
    ws_placed: Vec<usize>,
}

impl Router {
    pub fn new(n_engines: usize, cfg: RouterConfig) -> Self {
        Self {
            cfg,
            correction: vec![1.0; n_engines],
            placed: HashMap::new(),
            dram_placed: vec![0; n_engines],
            ws_placed: vec![0; n_engines],
        }
    }

    pub fn n_engines(&self) -> usize {
        self.correction.len()
    }

    /// Live requests the router believes engine `i` is holding.
    pub fn n_placed(&self, i: usize) -> usize {
        self.placed.values().filter(|p| p.engine == i).count()
    }

    /// Current working-set correction factor for engine `i` (starts at
    /// 1.0, refined by [`Router::observe`]).
    pub fn correction(&self, i: usize) -> f64 {
        self.correction[i]
    }

    /// Corrected working-set utilization engine `i` would run at after
    /// absorbing `extra_ws` more working-set bytes.
    fn ws_util(&self, i: usize, snap: &EngineSnapshot, extra_ws: usize) -> f64 {
        let predicted = (self.ws_placed[i] + extra_ws) as f64 * self.correction[i];
        predicted / snap.ws_capacity.max(1) as f64
    }

    /// DRAM bytes engine `i` can still take below its fresh-placement
    /// watermark (router-side accounting: counts queued placements the
    /// scheduler has not reserved for yet).
    fn watermark_headroom(&self, i: usize, snap: &EngineSnapshot) -> usize {
        let mark = (snap.dram_capacity as f64 * self.cfg.admit_frac) as usize;
        mark.saturating_sub(self.dram_placed[i])
    }

    /// Place a fresh request: among the engines whose DRAM watermark
    /// fits `demand`, pick the lowest post-admission utilization (the
    /// max of DRAM-watermark and corrected working-set pressure),
    /// breaking ties toward the least-loaded engine by live requests.
    pub fn place(
        &mut self,
        req: ReqId,
        demand: Demand,
        snaps: &[EngineSnapshot],
    ) -> Result<usize, ClusterError> {
        debug_assert_eq!(snaps.len(), self.n_engines());
        debug_assert!(!self.placed.contains_key(&req), "request {req} already placed");
        let mut best: Option<(usize, f64, usize)> = None; // (engine, score, n_live)
        let mut best_headroom = 0usize;
        for (i, snap) in snaps.iter().enumerate() {
            let headroom = self.watermark_headroom(i, snap);
            best_headroom = best_headroom.max(headroom);
            if demand.dram_bytes > headroom {
                continue;
            }
            let dram_util = (self.dram_placed[i] + demand.dram_bytes) as f64
                / ((snaps[i].dram_capacity as f64 * self.cfg.admit_frac).max(1.0));
            let score = dram_util.max(self.ws_util(i, snap, demand.ws_bytes));
            let better = match best {
                None => true,
                Some((_, s, live)) => {
                    score < s - 1e-12 || ((score - s).abs() <= 1e-12 && snap.n_live < live)
                }
            };
            if better {
                best = Some((i, score, snap.n_live));
            }
        }
        let Some((engine, _, _)) = best else {
            return Err(ClusterError::AdmissionRejected {
                demand_bytes: demand.dram_bytes,
                best_headroom_bytes: best_headroom,
            });
        };
        self.insert(req, engine, demand);
        Ok(engine)
    }

    /// Pick a migration target for a victim drained off `source`: an
    /// engine (never the source) whose scheduler can truly reserve the
    /// victim's bytes *and* whose corrected working-set pressure after
    /// absorbing it stays below the source's — migrating onto an
    /// equally-hot engine only bounces the victim. `None` means the
    /// caller should finalize the eviction instead.
    pub fn migration_target(
        &self,
        demand: Demand,
        source: usize,
        snaps: &[EngineSnapshot],
    ) -> Option<usize> {
        let source_util = self.ws_util(source, &snaps[source], 0);
        let mut best: Option<(usize, f64)> = None;
        for (i, snap) in snaps.iter().enumerate() {
            if i == source || !snap.can_reserve {
                continue;
            }
            let util = self.ws_util(i, snap, demand.ws_bytes);
            if util >= source_util {
                continue;
            }
            match best {
                Some((_, u)) if util >= u => {}
                _ => best = Some((i, util)),
            }
        }
        best.map(|(i, _)| i)
    }

    /// Move a live placement to `target` (called when a migration is
    /// dispatched, so the in-transit victim already counts against the
    /// target and a burst of fresh arrivals cannot strand it).
    pub fn on_migrated(&mut self, req: ReqId, target: usize) {
        if let Some(p) = self.remove(req) {
            self.insert(req, target, p.demand);
        }
    }

    /// Drop a placement: the request finished, was evicted, or was
    /// rejected by its engine.
    pub fn on_departed(&mut self, req: ReqId) {
        self.remove(req);
    }

    /// Fold one round of per-engine feedback into the working-set
    /// corrections: the ratio of observed HBM residency to predicted
    /// working-set bytes, EWMA-smoothed and clamped so a transient
    /// (e.g. an engine mid-prefill with no decodes resident) cannot
    /// swing placement wildly.
    pub fn observe(&mut self, snaps: &[EngineSnapshot]) {
        debug_assert_eq!(snaps.len(), self.n_engines());
        let a = self.cfg.feedback_alpha;
        for (i, snap) in snaps.iter().enumerate() {
            if self.ws_placed[i] == 0 || snap.hbm_bytes_used == 0 {
                continue;
            }
            let ratio = snap.hbm_bytes_used as f64 / self.ws_placed[i] as f64;
            self.correction[i] = ((1.0 - a) * self.correction[i] + a * ratio).clamp(0.25, 4.0);
        }
    }

    fn insert(&mut self, req: ReqId, engine: usize, demand: Demand) {
        self.dram_placed[engine] += demand.dram_bytes;
        self.ws_placed[engine] += demand.ws_bytes;
        self.placed.insert(req, Placement { engine, demand });
    }

    fn remove(&mut self, req: ReqId) -> Option<Placement> {
        let p = self.placed.remove(&req)?;
        self.dram_placed[p.engine] -= p.demand.dram_bytes;
        self.ws_placed[p.engine] -= p.demand.ws_bytes;
        Some(p)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn snap(dram: usize, ws: usize) -> EngineSnapshot {
        EngineSnapshot {
            dram_capacity: dram,
            ws_capacity: ws,
            n_live: 0,
            hbm_bytes_used: 0,
            can_reserve: true,
        }
    }

    fn d(dram: usize, ws: usize) -> Demand {
        Demand { dram_bytes: dram, ws_bytes: ws }
    }

    #[test]
    fn balances_by_predicted_working_set() {
        let mut r = Router::new(2, RouterConfig::default());
        let snaps = [snap(1 << 30, 1000), snap(1 << 30, 1000)];
        // equal engines: four identical requests alternate
        let a = r.place(1, d(100, 400), &snaps).unwrap();
        let b = r.place(2, d(100, 400), &snaps).unwrap();
        let c = r.place(3, d(100, 400), &snaps).unwrap();
        let e = r.place(4, d(100, 400), &snaps).unwrap();
        assert_ne!(a, b);
        assert_ne!(c, e);
        assert_eq!(r.n_placed(0) + r.n_placed(1), 4);
    }

    #[test]
    fn prefers_the_engine_with_working_set_headroom() {
        let mut r = Router::new(2, RouterConfig::default());
        // engine 0 has 10x the HBM working-set room
        let snaps = [snap(1 << 30, 10_000), snap(1 << 30, 1000)];
        for id in 0..4u32 {
            assert_eq!(r.place(id, d(100, 600), &snaps).unwrap(), 0);
        }
    }

    #[test]
    fn watermark_rejection_is_typed_and_names_best_headroom() {
        let mut r = Router::new(2, RouterConfig { admit_frac: 0.5, feedback_alpha: 0.25 });
        let snaps = [snap(1000, 100), snap(2000, 100)];
        // watermarks: 500 and 1000 bytes
        let err = r.place(1, d(1500, 10), &snaps).unwrap_err();
        assert_eq!(
            err,
            ClusterError::AdmissionRejected { demand_bytes: 1500, best_headroom_bytes: 1000 }
        );
        // fits under engine 1's watermark only
        assert_eq!(r.place(2, d(800, 10), &snaps).unwrap(), 1);
        // engine 1's watermark is now spent; 400 B only fits on engine 0
        assert_eq!(r.place(3, d(400, 10), &snaps).unwrap(), 0);
    }

    #[test]
    fn feedback_calibrates_working_set_correction() {
        let mut r = Router::new(2, RouterConfig::default());
        let snaps = [snap(1 << 30, 1000), snap(1 << 30, 1000)];
        r.place(1, d(100, 400), &snaps).unwrap();
        let placed_on = if r.n_placed(0) == 1 { 0 } else { 1 };
        // engine reports 2x the predicted residency -> correction rises
        let mut fed = snaps;
        fed[placed_on].hbm_bytes_used = 800;
        let before = r.correction(placed_on);
        r.observe(&fed);
        assert!(r.correction(placed_on) > before);
        // repeated observation converges toward the true 2.0 ratio
        for _ in 0..50 {
            r.observe(&fed);
        }
        assert!((r.correction(placed_on) - 2.0).abs() < 0.05);
        // the untouched engine never moves off 1.0
        assert_eq!(r.correction(1 - placed_on), 1.0);
    }

    #[test]
    fn migration_target_wants_a_strictly_colder_engine() {
        let mut r = Router::new(3, RouterConfig::default());
        let snaps = [snap(1 << 30, 1000), snap(1 << 30, 1000), snap(1 << 30, 1000)];
        // load engine 0 heavily, engine 1 lightly, engine 2 idle
        for id in 0..3u32 {
            r.insert(id, 0, d(100, 500));
        }
        r.insert(10, 1, d(100, 300));
        let target = r.migration_target(d(100, 500), 0, &snaps);
        assert_eq!(target, Some(2), "idle engine is the coldest target");
        // an engine that cannot reserve is skipped even when coldest
        let mut gated = snaps;
        gated[2].can_reserve = false;
        assert_eq!(r.migration_target(d(100, 500), 0, &gated), Some(1));
        // no admissible engine left -> None (fall back to eviction)
        let mut hot = gated;
        hot[1].can_reserve = false;
        assert_eq!(r.migration_target(d(100, 500), 0, &hot), None);
        // a cold source has no strictly colder peer -> None: migrating
        // off an idle engine would only bounce the victim
        assert_eq!(r.migration_target(d(100, 500), 2, &snaps), None);
    }

    #[test]
    fn departures_and_migrations_move_the_books() {
        let mut r = Router::new(2, RouterConfig::default());
        let snaps = [snap(1 << 30, 1000), snap(1 << 30, 1000)];
        r.place(1, d(100, 400), &snaps).unwrap();
        let src = if r.n_placed(0) == 1 { 0 } else { 1 };
        r.on_migrated(1, 1 - src);
        assert_eq!(r.n_placed(src), 0);
        assert_eq!(r.n_placed(1 - src), 1);
        r.on_departed(1);
        assert_eq!(r.n_placed(0) + r.n_placed(1), 0);
        // departing an unknown request is a no-op
        r.on_departed(99);
    }
}
