//! N-engine cluster serving on one shared clock.
//!
//! [`ClusterServer`] stands N independent [`EngineCore`]s (each with its
//! own scheduler budgets and backend) behind a [`Router`] and replays a
//! trace against them on a single shared serving clock. Engines overlap
//! in time: each has its own next-ready timestamp (start of its next
//! iteration) and the driver always advances the shared clock to the
//! earliest pending event — an arrival, a migration landing, or an
//! engine finishing its iteration — so a long prefill on one engine
//! never serializes its neighbours.
//!
//! KV migration rides the typed-eviction seam: engines run with
//! [`EngineCore::capture_migrations`] so a memory-exhaustion victim is
//! drained into a [`MigrationCandidate`] instead of destroyed. The
//! driver picks a strictly colder target through the router, charges
//! the FlashD2H + FlashH2D wire time on the shared clock (the victim is
//! in flight and unservable until `ready_at`), and re-admits it with
//! its RNG/working-set state intact — the migrated request replays
//! byte-identically (see `engine::sim_backend` tests). When no engine
//! has headroom the candidate falls back to a true eviction at the
//! source, which is exactly the single-engine behaviour.
//!
//! **Cross-request KV prefix sharing stops at the engine boundary.**
//! A migrated request's payload carries its FULL KV bytes — the shared
//! prefix is deep-copied out (the scheduler folds the path's shared
//! bytes back into the private reservation in `extract_for_migration`,
//! the backend drops its namespace reference in `export_migration`) and
//! the request lands at the target fully private. Engines never share
//! KV with each other; the candidate's `reserve_bytes` and the wire
//! time both price the unshared footprint, so a migration can never
//! under-reserve at the target by assuming a sharer that is not there.

use anyhow::Result;

use crate::engine::{EngineCore, MigrationCandidate};
use crate::memory::ReqId;
use crate::scheduler::Request;
use crate::sim::CostModel;

use super::router::{ClusterError, Demand, EngineSnapshot, Router, RouterConfig};

/// Cluster-level configuration (engine budgets live in each engine's
/// own `ServingConfig` / scheduler; these are the knobs of the tier
/// above them).
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    /// DSA token budget used to predict decode working sets (mirror of
    /// the engines' `ServingConfig::token_budget`; per-request
    /// `sparse_budget` overrides still win).
    pub ws_budget_tokens: usize,
    /// Drain memory-exhaustion victims across engines instead of
    /// evicting them. Off = the scale-out-without-migration baseline.
    pub migrate: bool,
    pub router: RouterConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { ws_budget_tokens: 2048, migrate: true, router: RouterConfig::default() }
    }
}

/// A drained victim on the wire between two engines.
struct PendingMigration {
    ready_at_s: f64,
    source: usize,
    target: usize,
    candidate: MigrationCandidate,
}

/// Outcome of a whole cluster run.
pub struct ClusterReport {
    /// Per-engine run reports, in engine order.
    pub engines: Vec<crate::engine::RunReport>,
    pub makespan_s: f64,
    /// Requests the router could place on no engine (typed).
    pub rejected: Vec<(ReqId, ClusterError)>,
}

impl ClusterReport {
    pub fn requests_finished(&self) -> usize {
        self.engines.iter().map(|r| r.metrics.requests_finished).sum()
    }

    pub fn requests_evicted(&self) -> usize {
        self.engines.iter().map(|r| r.metrics.requests_evicted).sum()
    }

    pub fn requests_migrated(&self) -> usize {
        self.engines.iter().map(|r| r.metrics.requests_migrated).sum()
    }

    pub fn migration_transfer_s(&self) -> f64 {
        self.engines.iter().map(|r| r.metrics.migration_transfer_total_s).sum()
    }

    pub fn migration_bytes(&self) -> u64 {
        self.engines.iter().map(|r| r.metrics.migration_bytes_total).sum()
    }

    /// Aggregate token throughput (shared clock, so per-engine rates add).
    pub fn throughput(&self) -> f64 {
        self.engines.iter().map(|r| r.metrics.throughput()).sum()
    }

    /// Served-to-completion request rate over the shared clock: the
    /// cluster's goodput. Evicted and rejected requests produced tokens
    /// the client never got a completion for, so only finishes count.
    pub fn goodput_rps(&self) -> f64 {
        self.requests_finished() as f64 / self.makespan_s.max(1e-9)
    }
}

/// N engines + router + migration plane on one shared clock.
pub struct ClusterServer {
    engines: Vec<EngineCore>,
    cost: CostModel,
    cfg: ClusterConfig,
    router: Router,
    clock_s: f64,
    in_flight: Vec<PendingMigration>,
    rejected: Vec<(ReqId, ClusterError)>,
}

impl ClusterServer {
    /// Build a cluster over caller-constructed engines (per-engine
    /// scheduler budgets and backends are the caller's degrees of
    /// freedom). Engines are switched into migration-capture mode iff
    /// `cfg.migrate`.
    pub fn new(engines: Vec<EngineCore>, cost: CostModel, cfg: ClusterConfig) -> Self {
        assert!(!engines.is_empty(), "a cluster needs at least one engine");
        let n = engines.len();
        let engines =
            engines.into_iter().map(|e| e.capture_migrations(cfg.migrate)).collect();
        Self {
            engines,
            cost,
            cfg,
            router: Router::new(n, cfg.router),
            clock_s: 0.0,
            in_flight: Vec::new(),
            rejected: Vec::new(),
        }
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    /// Predicted two-tier demand of a request: the conservative
    /// full-lifetime DRAM reservation, and `min(seq_len, sparse
    /// budget)` worth of KV blocks as the decode working set.
    fn demand_of(&self, req: &Request) -> Demand {
        // sparselint: allow(no-panic) -- ClusterServer::new requires >= 1 engine; all engines share one ModelSpec, so any sched() gives the same byte math
        let sched = self.engines[0].sched();
        let budget = req.sparse_budget.unwrap_or(self.cfg.ws_budget_tokens);
        let seq = req.prompt_len + req.max_new_tokens;
        Demand {
            dram_bytes: sched.full_kv_bytes(req.prompt_len, req.max_new_tokens),
            ws_bytes: sched.full_kv_bytes(seq.min(budget), 0),
        }
    }

    /// Point-in-time router inputs. `reserve_bytes` parameterizes the
    /// migration gate: each snapshot reports whether that engine's
    /// scheduler can truly reserve that many bytes right now.
    fn snapshots(&self, reserve_bytes: usize) -> Vec<EngineSnapshot> {
        self.engines
            .iter()
            .map(|e| {
                let sched = e.sched();
                EngineSnapshot {
                    dram_capacity: sched.admission_capacity(),
                    ws_capacity: sched.m_avl(),
                    n_live: e.n_active() + e.n_queued(),
                    hbm_bytes_used: e.mem_stats().hbm_bytes_used,
                    can_reserve: sched.can_reserve(reserve_bytes),
                }
            })
            .collect()
    }

    /// Route one arrival; a placement failure is recorded as a typed
    /// cluster rejection (the request never reaches an engine).
    fn route(&mut self, req: Request, ready: &mut [f64]) -> Result<()> {
        let demand = self.demand_of(&req);
        let snaps = self.snapshots(0);
        match self.router.place(req.id, demand, &snaps) {
            Ok(i) => {
                self.engines[i].submit_request(req).map_err(anyhow::Error::new)?;
                if ready[i].is_infinite() {
                    ready[i] = self.clock_s;
                }
            }
            Err(e) => self.rejected.push((req.id, e)),
        }
        Ok(())
    }

    /// Dispatch a drained victim: pick a strictly colder target that
    /// can reserve its bytes, charge the wire time at the source, and
    /// put it in flight. No such target -> finalize as a true eviction.
    fn dispatch_migration(&mut self, source: usize, candidate: MigrationCandidate) {
        let mut demand = self.demand_of(&candidate.request);
        demand.dram_bytes = candidate.reserve_bytes;
        let snaps = self.snapshots(candidate.reserve_bytes);
        match self.router.migration_target(demand, source, &snaps) {
            Some(target) => {
                let bytes = candidate.payload.kv_bytes;
                let transfer_s = self.cost.migration_time(bytes);
                self.engines[source].record_migration(transfer_s, bytes);
                self.router.on_migrated(candidate.request.id, target);
                self.in_flight.push(PendingMigration {
                    ready_at_s: self.clock_s + transfer_s,
                    source,
                    target,
                    candidate,
                });
            }
            None => {
                self.router.on_departed(candidate.request.id);
                self.engines[source].finalize_eviction(candidate);
            }
        }
    }

    /// Land a migration that finished its transfer: re-admit at the
    /// planned target, falling back to any engine that can still
    /// reserve the bytes (the target may have filled mid-flight), and
    /// finally to a true eviction at the source.
    fn land_migration(&mut self, m: PendingMigration, ready: &mut [f64]) {
        let PendingMigration { source, target, mut candidate, .. } = m;
        let id = candidate.request.id;
        match self.engines[target].admit_migration(candidate) {
            Ok(()) => {
                if ready[target].is_infinite() {
                    ready[target] = self.clock_s;
                }
                return;
            }
            Err(back) => candidate = back,
        }
        for i in 0..self.engines.len() {
            if i == target || i == source {
                continue;
            }
            if !self.engines[i].sched().can_reserve(candidate.reserve_bytes) {
                continue;
            }
            match self.engines[i].admit_migration(candidate) {
                Ok(()) => {
                    self.router.on_migrated(id, i);
                    if ready[i].is_infinite() {
                        ready[i] = self.clock_s;
                    }
                    return;
                }
                Err(back) => candidate = back,
            }
        }
        self.router.on_departed(id);
        self.engines[source].finalize_eviction(candidate);
    }

    /// Serve a whole trace to completion (or `max_clock_s`) and report.
    pub fn run_trace(mut self, mut trace: Vec<Request>, max_clock_s: f64) -> Result<ClusterReport> {
        trace.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let mut next_arrival = 0usize;
        let n = self.engines.len();
        // per-engine next-iteration start; infinity = admission-blocked
        // until a new arrival or migration lands on that engine
        let mut ready = vec![0.0f64; n];

        loop {
            while next_arrival < trace.len() && trace[next_arrival].arrival_s <= self.clock_s {
                let req = trace[next_arrival].clone();
                next_arrival += 1;
                self.route(req, &mut ready)?;
            }

            if !self.in_flight.is_empty() {
                let clock = self.clock_s;
                let (due, rest): (Vec<_>, Vec<_>) =
                    std::mem::take(&mut self.in_flight).into_iter().partition(|m| m.ready_at_s <= clock);
                self.in_flight = rest;
                for m in due {
                    self.land_migration(m, &mut ready);
                }
            }

            let mut stepped = false;
            for i in 0..n {
                if !(self.engines[i].has_work() && ready[i] <= self.clock_s) {
                    continue;
                }
                stepped = true;
                let out = self.engines[i].step(self.clock_s).map_err(anyhow::Error::new)?;
                for (id, _) in &out.finished {
                    self.router.on_departed(*id);
                }
                for (id, _) in &out.rejected {
                    self.router.on_departed(*id);
                }
                for (id, _) in &out.evicted {
                    self.router.on_departed(*id);
                }
                let progressed = out.ran_batch
                    || !out.rejected.is_empty()
                    || !out.evicted.is_empty()
                    || !out.migratable.is_empty();
                ready[i] = if progressed { self.clock_s + out.iter_time_s } else { f64::INFINITY };
                for candidate in out.migratable {
                    self.dispatch_migration(i, candidate);
                }
            }
            if stepped {
                let snaps = self.snapshots(0);
                self.router.observe(&snaps);
            }
            if self.clock_s > max_clock_s {
                break;
            }

            // advance the shared clock to the earliest pending event
            let mut horizon = f64::INFINITY;
            if next_arrival < trace.len() {
                horizon = horizon.min(trace[next_arrival].arrival_s);
            }
            for m in &self.in_flight {
                horizon = horizon.min(m.ready_at_s);
            }
            for i in 0..n {
                if self.engines[i].has_work() && ready[i].is_finite() {
                    horizon = horizon.min(ready[i]);
                }
            }
            if horizon.is_infinite() {
                break; // no event will ever fire again
            }
            self.clock_s = self.clock_s.max(horizon);
        }

        // the makespan covers every engine's final iteration (a ready
        // timestamp is the END of the last step an engine ran)
        let clock = ready
            .iter()
            .copied()
            .filter(|r| r.is_finite())
            .fold(self.clock_s, f64::max);
        // victims still on the wire at shutdown are true evictions
        for m in std::mem::take(&mut self.in_flight) {
            self.router.on_departed(m.candidate.request.id);
            self.engines[m.source].finalize_eviction(m.candidate);
        }
        Ok(ClusterReport {
            engines: self.engines.into_iter().map(|e| e.into_report(clock)).collect(),
            makespan_s: clock,
            rejected: self.rejected,
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::{HardwareSpec, ModelSpec, ServingConfig};
    use crate::engine::SimBackend;
    use crate::scheduler::Scheduler;
    use crate::workload::{generate, WorkloadSpec};

    fn roomy_engine(cfg: &ServingConfig, spec: &ModelSpec, hw: &HardwareSpec) -> EngineCore {
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
        let sched = Scheduler::new(cfg.clone(), spec.clone(), hw.hbm_kv_bytes);
        EngineCore::new(sched, Box::new(backend))
    }

    fn cluster_of(n: usize) -> ClusterServer {
        let cfg = ServingConfig::sparseserve(2048, 2048, 32);
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let engines = (0..n).map(|_| roomy_engine(&cfg, &spec, &hw)).collect();
        let cost = CostModel::new(spec, hw);
        ClusterServer::new(engines, cost, ClusterConfig::default())
    }

    #[test]
    fn two_engines_split_a_trace_and_finish_it() {
        let trace = generate(&WorkloadSpec::paper_lwm(0.1, 7), 12, 0);
        let rep = cluster_of(2).run_trace(trace, 1e7).unwrap();
        assert_eq!(rep.requests_finished(), 12);
        assert!(rep.rejected.is_empty());
        assert_eq!(rep.requests_migrated(), 0, "roomy engines never migrate");
        assert_eq!(rep.requests_evicted(), 0);
        // the router actually spread the load
        let busy = rep.engines.iter().filter(|r| r.metrics.requests_finished > 0).count();
        assert_eq!(busy, 2, "both engines must serve part of the trace");
        assert!(rep.goodput_rps() > 0.0);
        assert!(rep.makespan_s > 0.0);
    }

    #[test]
    fn one_engine_cluster_matches_the_plain_engine_driver() {
        let trace = generate(&WorkloadSpec::paper_lwm(0.1, 7), 8, 0);
        let rep = cluster_of(1).run_trace(trace.clone(), 1e7).unwrap();

        let cfg = ServingConfig::sparseserve(2048, 2048, 32);
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
        let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
        let single = crate::engine::Engine::new(sched, Box::new(backend))
            .run_trace(trace, 1e7)
            .unwrap();

        assert_eq!(rep.requests_finished(), single.metrics.requests_finished);
        // same engine, same trace, same admissions -> same serving clock
        assert!(
            (rep.engines[0].metrics.ttft.mean() - single.metrics.ttft.mean()).abs() < 1e-9,
            "cluster-of-one must reproduce the single-engine TTFTs: {} vs {}",
            rep.engines[0].metrics.ttft.mean(),
            single.metrics.ttft.mean()
        );
    }

    #[test]
    fn pipelined_engines_serve_the_cluster_and_hide_plan_time() {
        // depth-2 engines under the cluster driver: same completions as
        // the synchronous cluster, with the overlap counters earning it
        let trace = generate(&WorkloadSpec::paper_lwm(0.1, 7), 8, 0);
        let sync = cluster_of(2).run_trace(trace.clone(), 1e7).unwrap();
        let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
        cfg.pipeline_depth = 2;
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let engines = (0..2).map(|_| roomy_engine(&cfg, &spec, &hw)).collect();
        let cost = CostModel::new(spec, hw);
        let piped = ClusterServer::new(engines, cost, ClusterConfig::default())
            .run_trace(trace, 1e7)
            .unwrap();
        assert_eq!(piped.requests_finished(), sync.requests_finished());
        assert!(piped.rejected.is_empty());
        let hidden: f64 =
            piped.engines.iter().map(|r| r.metrics.plan_stage_hidden_s).sum();
        let primed: usize =
            piped.engines.iter().map(|r| r.metrics.pipeline_spec_used).sum();
        assert!(primed > 0, "cluster decode must prime the pipeline");
        assert!(hidden > 0.0, "pipelined engines must hide plan/stage time");
        let sync_hidden: f64 =
            sync.engines.iter().map(|r| r.metrics.plan_stage_hidden_s).sum();
        assert_eq!(sync_hidden, 0.0, "depth 1 never reports overlap");
    }

    #[test]
    fn sharing_engines_serve_a_shared_prompt_trace_and_report_hits() {
        // ten conversations over the same system prompt: with
        // prefix_sharing on, every admission after the first matches the
        // shared path and the per-engine metrics say so
        let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
        cfg.prefix_sharing = true;
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let engines = (0..1).map(|_| roomy_engine(&cfg, &spec, &hw)).collect();
        let cost = CostModel::new(spec.clone(), hw);
        let system: Vec<i32> = (0..4096).map(|t| (t % 8191) as i32).collect();
        let trace: Vec<Request> = (1..=10u32)
            .map(|id| {
                let mut prompt = system.clone();
                prompt.extend((0..256).map(|t| (id as i32) * 10_000 + t));
                Request::with_prompt(id, prompt, 8, 0.1 * id as f64)
            })
            .collect();
        let rep = ClusterServer::new(engines, cost, ClusterConfig::default())
            .run_trace(trace, 1e7)
            .unwrap();
        assert_eq!(rep.requests_finished(), 10);
        assert!(rep.rejected.is_empty());
        let hits: u64 = rep.engines.iter().map(|r| r.metrics.prefix_hits).sum();
        let matched: u64 =
            rep.engines.iter().map(|r| r.metrics.prefix_matched_tokens).sum();
        assert!(hits >= 9, "every follower must hit the shared path: {hits}");
        assert!(matched >= 9 * 4096, "block-aligned system prompt adopted: {matched}");
    }

    #[test]
    fn oversized_request_is_rejected_with_a_typed_error() {
        let cfg = ServingConfig::sparseserve(2048, 2048, 32);
        let spec = ModelSpec::lwm_7b();
        let hw = HardwareSpec::a100_40gb();
        let mk = || {
            let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
            let sched = Scheduler::new(cfg.clone(), spec.clone(), hw.hbm_kv_bytes)
                .with_dram_capacity(1 << 20);
            EngineCore::new(sched, Box::new(backend))
        };
        let cost = CostModel::new(spec.clone(), hw.clone());
        let cluster = ClusterServer::new(vec![mk(), mk()], cost, ClusterConfig::default());
        let trace = vec![crate::scheduler::Request::new(1, 8192, 64, 0.0)];
        let rep = cluster.run_trace(trace, 1e7).unwrap();
        assert_eq!(rep.requests_finished(), 0);
        assert_eq!(rep.rejected.len(), 1);
        let (id, err) = &rep.rejected[0];
        assert_eq!(*id, 1);
        match err {
            ClusterError::AdmissionRejected { demand_bytes, best_headroom_bytes } => {
                assert!(demand_bytes > best_headroom_bytes);
            }
        }
    }
}
