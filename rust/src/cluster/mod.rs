//! Cluster serving: an N-engine routing/admission tier over
//! [`crate::engine::EngineCore`].
//!
//! The subsystem has three pieces:
//!
//! - [`Router`]: working-set-aware placement. Each request's demand is
//!   predicted on both memory tiers — the full-lifetime DRAM
//!   reservation and the `min(seq_len, sparse budget)` HBM working set
//!   that actually contends under DSA — and refined online from each
//!   engine's `mem_stats` feedback. Fresh placements keep a DRAM
//!   watermark (`admit_frac`) in reserve for migrations; a request no
//!   engine can hold fails with a typed
//!   [`ClusterError::AdmissionRejected`].
//! - [`ClusterServer`]: the shared-clock driver. Engines overlap — the
//!   clock always advances to the earliest arrival, migration landing,
//!   or iteration end across the cluster.
//! - KV migration: engines run in [`EngineCore::capture_migrations`]
//!   mode, so memory-exhaustion victims drain into typed
//!   [`crate::engine::MigrationCandidate`]s instead of being evicted.
//!   The driver charges FlashD2H + FlashH2D wire time on the shared
//!   clock and re-admits the victim at a strictly colder engine with
//!   its selection-RNG and working-set state intact; with no colder
//!   engine it falls back to a true eviction — the single-engine
//!   behaviour, which is also what a cluster of one degenerates to.
//!
//! [`EngineCore`]: crate::engine::EngineCore
//! [`EngineCore::capture_migrations`]: crate::engine::EngineCore::capture_migrations

// Serving-path no-panic discipline (satellite of sparselint's
// `no-panic` pass): unwrap/expect in this module tree is a clippy
// warning, denied under CI's `-D warnings`. The few justified
// sites carry fn-level allows next to their sparselint comments.
#![warn(clippy::unwrap_used, clippy::expect_used)]

mod router;
mod server;

pub use router::{ClusterError, Demand, EngineSnapshot, Router, RouterConfig};
pub use server::{ClusterConfig, ClusterReport, ClusterServer};
