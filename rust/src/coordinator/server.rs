//! Threaded serving front-end: an online driver over [`EngineCore`].
//!
//! The engine (scheduler + backend) is constructed *inside* the serving
//! thread by a builder closure — PJRT handles are thread-affine raw
//! pointers and never cross threads. Clients talk to the thread through
//! channels: submissions and cancellations in, per-request token streams
//! out. All batch/emit/release logic lives in [`EngineCore::step`]; this
//! file only owns the wall clock, the message pump and stream fan-out.
//!
//! Clock semantics: the server's serving clock is the wall clock, and
//! `EngineCore::step` stamps emissions at `now + iter_time_s`. With the
//! real backend `iter_time_s` is measured wall time, so timings are
//! coherent. Driving a *modeled* backend (`SimBackend`) online mixes
//! clocks — wall-clock arrivals plus simulated iteration times — which
//! is fine for exercising the lifecycle (tests) but the resulting
//! TTFT/TBT numbers are not meaningful measurements; use
//! [`crate::engine::Engine::run_trace`] for simulated timing studies.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{Backend, EngineCore, ServeError, SubmitRequest};
use crate::memory::ReqId;
use crate::metrics::RunMetrics;
use crate::scheduler::Scheduler;

use super::api::{StreamEvent, SubmitHandle};

struct Submission {
    id: ReqId,
    sub: SubmitRequest,
    events: Sender<StreamEvent>,
}

enum Msg {
    Submit(Submission),
    Cancel(ReqId),
    Shutdown,
}

pub struct Server {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<Result<RunMetrics>>>,
    next_id: AtomicU32,
}

impl Server {
    /// Start the serving thread with an unbounded admission queue.
    /// `build` constructs the scheduler and backend on that thread
    /// (PJRT state stays thread-local).
    pub fn start<F>(build: F) -> Self
    where
        F: FnOnce() -> Result<(Scheduler, Box<dyn Backend>)> + Send + 'static,
    {
        Self::start_with(None, build)
    }

    /// Start with an admission-queue cap: submissions that would exceed
    /// `cap` waiting requests fail fast with `ServeError::QueueFull`.
    #[allow(clippy::expect_used)]
    pub fn start_with<F>(queue_cap: Option<usize>, build: F) -> Self
    where
        F: FnOnce() -> Result<(Scheduler, Box<dyn Backend>)> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::Builder::new()
            .name("sparseserve-engine".into())
            .spawn(move || -> Result<RunMetrics> {
                let (sched, backend) = build()?;
                // online service runs indefinitely: prune completed
                // request records instead of holding them for a report
                let mut core = EngineCore::new(sched, backend).retain_finished(false);
                if let Some(cap) = queue_cap {
                    core = core.with_queue_cap(cap);
                }
                let start = Instant::now();
                let mut streams: HashMap<ReqId, Sender<StreamEvent>> = Default::default();
                let mut open = true;
                // consecutive no-progress iterations (work pending, empty plan)
                let mut stalled = 0u32;

                while open || core.has_work() {
                    // drain the control channel (block briefly when idle)
                    loop {
                        // all senders gone (Server dropped without
                        // shutdown) => finish in-flight work and exit
                        // instead of spinning on a dead channel
                        use std::sync::mpsc::{RecvTimeoutError, TryRecvError};
                        let msg = if core.has_work() {
                            match rx.try_recv() {
                                Ok(m) => m,
                                Err(TryRecvError::Disconnected) => {
                                    open = false;
                                    break;
                                }
                                Err(TryRecvError::Empty) => break,
                            }
                        } else {
                            match rx.recv_timeout(Duration::from_millis(50)) {
                                Ok(m) => m,
                                Err(RecvTimeoutError::Disconnected) => {
                                    open = false;
                                    break;
                                }
                                Err(RecvTimeoutError::Timeout) => break,
                            }
                        };
                        match msg {
                            Msg::Shutdown => {
                                open = false;
                                break;
                            }
                            Msg::Cancel(id) => {
                                if core.cancel(id) {
                                    if let Some(s) = streams.remove(&id) {
                                        let _ = s.send(StreamEvent::Error(ServeError::Cancelled));
                                    }
                                }
                            }
                            Msg::Submit(s) => {
                                let now = start.elapsed().as_secs_f64();
                                match core.submit_with_id(s.id, s.sub, now) {
                                    Ok(()) => {
                                        streams.insert(s.id, s.events);
                                    }
                                    Err(e) => {
                                        let _ = s.events.send(StreamEvent::Error(e));
                                    }
                                }
                            }
                        }
                    }
                    if !core.has_work() {
                        continue;
                    }

                    let now = start.elapsed().as_secs_f64();
                    let outcome = match core.step(now) {
                        Ok(o) => o,
                        Err(e) => {
                            // engine is dead: fail every live stream
                            for (_, s) in streams.drain() {
                                let _ = s.send(StreamEvent::Error(e.clone()));
                            }
                            return Err(anyhow::Error::new(e));
                        }
                    };
                    // typed per-request failures: fail those streams,
                    // the server keeps serving everyone else
                    for (id, err) in outcome.rejected.iter().chain(outcome.evicted.iter()) {
                        if let Some(s) = streams.remove(id) {
                            let _ = s.send(StreamEvent::Error(err.clone()));
                        }
                    }
                    if !outcome.ran_batch {
                        if !outcome.rejected.is_empty() || !outcome.evicted.is_empty() {
                            continue; // requests left the system: progress
                        }
                        // Work is pending but the planner produced nothing.
                        // Two permanently-stuck shapes exist (the offline
                        // driver bails on them; an online server must stay
                        // up and fail only the doomed request):
                        //  - nothing active: the head-of-queue reservation
                        //    exceeds HBM capacity — provably permanent,
                        //    reject immediately;
                        //  - something active but every candidate is
                        //    working-set-rejected (a single request's
                        //    demand exceeds M_avl) — give it a grace
                        //    period (a cancel could unstick it), then
                        //    reject the prefill-slot holder (the WS hog)
                        //    or the first stuck decode.
                        if core.n_active() == 0 {
                            if let Some(head) = core.sched().queued_ids().first().copied() {
                                core.reject(head);
                                if let Some(s) = streams.remove(&head) {
                                    let _ = s.send(StreamEvent::Error(ServeError::rejected(
                                        "cannot be admitted: memory demand exceeds HBM capacity",
                                    )));
                                }
                                continue;
                            }
                        } else {
                            stalled += 1;
                            if stalled >= 1000 {
                                stalled = 0;
                                let victim = core
                                    .sched()
                                    .prefilling_id()
                                    .or_else(|| core.sched().decoding().first().copied());
                                if let Some(v) = victim {
                                    core.reject(v);
                                    if let Some(s) = streams.remove(&v) {
                                        let _ = s.send(StreamEvent::Error(ServeError::Evicted {
                                            reason: "working set exceeds available HBM".into(),
                                        }));
                                    }
                                    continue;
                                }
                            }
                        }
                        std::thread::sleep(Duration::from_millis(1));
                        continue;
                    }
                    stalled = 0;
                    for ev in &outcome.emitted {
                        // prefill-only steps carry no payload token; only
                        // actually emitted tokens reach the stream (and
                        // only they advance `index`)
                        if let Some(tok) = ev.token {
                            if let Some(s) = streams.get(&ev.req) {
                                let _ = s.send(StreamEvent::Token { token: tok, index: ev.index });
                            }
                        }
                    }
                    for (id, timing) in &outcome.finished {
                        if let Some(s) = streams.remove(id) {
                            let _ = s.send(StreamEvent::Done { timing: *timing });
                        }
                    }
                }
                Ok(core.into_report(start.elapsed().as_secs_f64()).metrics)
            })
            // sparselint: allow(no-panic) -- process bring-up, before any request is accepted: a host that cannot spawn one thread cannot serve at all
            .expect("spawn engine thread");
        Self { tx, handle: Some(handle), next_id: AtomicU32::new(1) }
    }

    /// Submit a request; returns a token stream handle. If the engine
    /// thread already exited (failed bring-up or a fatal backend error),
    /// the stream yields `ServeError::Disconnected` instead of panicking.
    pub fn submit(&self, sub: SubmitRequest) -> SubmitHandle {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        if self
            .tx
            .send(Msg::Submit(Submission { id, sub, events: tx.clone() }))
            .is_err()
        {
            let _ = tx.send(StreamEvent::Error(ServeError::Disconnected));
        }
        SubmitHandle { id, events: rx }
    }

    /// Cancel an in-flight request. Its stream receives
    /// `StreamEvent::Error(ServeError::Cancelled)` and its KV state is
    /// released; a no-op if the request already finished.
    pub fn cancel(&self, id: ReqId) {
        let _ = self.tx.send(Msg::Cancel(id));
    }

    /// Finish in-flight work, stop the engine thread and return the
    /// run's aggregated serving metrics.
    pub fn shutdown(mut self) -> Result<RunMetrics> {
        let _ = self.tx.send(Msg::Shutdown);
        let h = self
            .handle
            .take()
            .ok_or_else(|| anyhow::anyhow!("engine thread already shut down"))?;
        h.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::{HardwareSpec, ModelSpec, ServingConfig};
    use crate::engine::SimBackend;

    /// Online smoke for the pipelined executor: a depth-2 server streams
    /// concurrent requests to completion, and the steady decode stretch
    /// primes the speculative plan (hiding plan/stage time) at least once.
    #[test]
    fn pipelined_server_streams_to_completion() {
        let server = Server::start(|| {
            let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
            cfg.pipeline_depth = 2;
            let spec = ModelSpec::lwm_7b();
            let hw = HardwareSpec::a100_40gb();
            let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
            let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
            Ok((sched, Box::new(backend) as Box<dyn Backend>))
        });
        let h1 = server.submit(SubmitRequest::synthetic(8_000).max_new(16));
        let h2 = server.submit(SubmitRequest::synthetic(6_000).max_new(12));
        let (t1, timing1) = h1.collect().expect("stream 1");
        let (t2, timing2) = h2.collect().expect("stream 2");
        // the sim backend emits count-only token events (no payload), so
        // the streams carry no Token frames — completion and the decode
        // count arrive through the Done timing
        assert!(t1.is_empty() && t2.is_empty());
        assert_eq!(timing1.n_tokens, 16);
        assert_eq!(timing2.n_tokens, 12);
        let metrics = server.shutdown().unwrap();
        assert_eq!(metrics.requests_finished, 2);
        assert!(metrics.pipeline_spec_used > 0, "steady decode must prime the pipeline");
        assert!(metrics.plan_stage_hidden_s > 0.0, "primed steps must hide plan/stage time");
    }
}
