//! Threaded serving front-end.
//!
//! The engine (scheduler + backend) is constructed *inside* the serving
//! thread by a builder closure — PJRT handles are thread-affine raw
//! pointers and never cross threads. Clients talk to the thread through
//! channels: submissions in, per-request token streams out.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::Backend;
use crate::scheduler::{Request, Scheduler};

use super::api::{StreamEvent, SubmitHandle};

struct Submission {
    prompt: Vec<i32>,
    max_new: usize,
    id: u32,
    events: Sender<StreamEvent>,
}

enum Msg {
    Submit(Submission),
    Shutdown,
}

pub struct Server {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<Result<()>>>,
    next_id: AtomicU32,
}

impl Server {
    /// Start the serving thread. `build` constructs the scheduler and
    /// backend on that thread (PJRT state stays thread-local).
    pub fn start<F>(build: F) -> Self
    where
        F: FnOnce() -> Result<(Scheduler, Box<dyn Backend>)> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::Builder::new()
            .name("sparseserve-engine".into())
            .spawn(move || -> Result<()> {
                let (mut sched, mut backend) = build()?;
                let start = Instant::now();
                let mut streams: std::collections::HashMap<u32, Sender<StreamEvent>> =
                    Default::default();
                let mut emitted: std::collections::HashMap<u32, usize> = Default::default();
                let mut open = true;

                while open || sched.has_work() {
                    // drain the submission channel (block briefly when idle)
                    loop {
                        let msg = if sched.has_work() {
                            match rx.try_recv() {
                                Ok(m) => m,
                                Err(_) => break,
                            }
                        } else {
                            match rx.recv_timeout(Duration::from_millis(50)) {
                                Ok(m) => m,
                                Err(_) => break,
                            }
                        };
                        match msg {
                            Msg::Shutdown => {
                                open = false;
                                break;
                            }
                            Msg::Submit(sub) => {
                                let now = start.elapsed().as_secs_f64();
                                let req =
                                    Request::with_prompt(sub.id, sub.prompt, sub.max_new, now);
                                backend.register(&req)?;
                                streams.insert(sub.id, sub.events);
                                emitted.insert(sub.id, 0);
                                sched.submit(req);
                            }
                        }
                    }
                    if !sched.has_work() {
                        continue;
                    }

                    let now = start.elapsed().as_secs_f64();
                    let mut ws = |id| backend.decode_ws_bytes(id);
                    let batch = sched.plan(now, &mut ws);
                    if batch.is_empty() {
                        std::thread::sleep(Duration::from_millis(1));
                        continue;
                    }
                    let outcome = match backend.run_batch(&batch, &sched.requests) {
                        Ok(o) => o,
                        Err(e) => {
                            // fail every involved request
                            for id in batch
                                .decodes
                                .iter()
                                .copied()
                                .chain(batch.prefill.iter().map(|w| w.req()))
                            {
                                if let Some(s) = streams.remove(&id) {
                                    let _ = s.send(StreamEvent::Error(e.to_string()));
                                }
                            }
                            return Err(e);
                        }
                    };
                    if let Some(work) = &batch.prefill {
                        sched.advance_prefill(work);
                    }
                    let done_at = start.elapsed().as_secs_f64();
                    for (id, tok) in &outcome.tokens {
                        let finished = sched.emit_token(*id, *tok, done_at);
                        let idx = emitted.entry(*id).or_insert(0);
                        if let (Some(stream), Some(t)) = (streams.get(id), tok) {
                            let _ = stream.send(StreamEvent::Token { token: *t, index: *idx });
                        }
                        *idx += 1;
                        if finished {
                            backend.release(*id);
                            if let Some(stream) = streams.remove(id) {
                                let _ = stream.send(StreamEvent::Done { n_tokens: *idx });
                            }
                        }
                    }
                }
                Ok(())
            })
            .expect("spawn engine thread");
        Self { tx, handle: Some(handle), next_id: AtomicU32::new(1) }
    }

    /// Submit a prompt; returns a token stream handle.
    pub fn submit(&self, prompt: Vec<i32>, max_new: usize) -> SubmitHandle {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Submit(Submission { prompt, max_new, id, events: tx }))
            .expect("engine thread alive");
        SubmitHandle { id, events: rx }
    }

    /// Finish in-flight work and stop the engine thread.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
