//! The serving coordinator: a threaded front-end around the engine.
//!
//! `Server` owns the serving thread (scheduler + backend event loop) and
//! exposes a submit/stream API over std channels — the std-thread
//! equivalent of the async request loop in vLLM's router (tokio is not
//! vendored in this offline build; the event loop is single-owner and
//! channel-driven, so threads map 1:1).

pub mod api;
pub mod server;

pub use api::{StreamEvent, SubmitHandle};
pub use server::Server;
