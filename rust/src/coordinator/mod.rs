//! The serving coordinator: a threaded front-end around the engine.
//!
//! `Server` owns the serving thread (an [`crate::engine::EngineCore`]
//! event loop) and exposes a submit/stream/cancel API over std channels —
//! the std-thread equivalent of the async request loop in vLLM's router
//! (tokio is not vendored in this offline build; the event loop is
//! single-owner and channel-driven, so threads map 1:1).
//!
//! Requests are built with [`SubmitRequest`] (priority class, stop
//! tokens, TTFT SLO, sparse-budget override), stream back
//! [`StreamEvent`]s, and fail with typed [`ServeError`]s.
//!
//! `Server` fronts exactly one engine. Scale-out lives one tier up in
//! [`crate::cluster`]: [`crate::cluster::ClusterServer`] routes across
//! N engines with working-set-aware placement and drains
//! memory-exhaustion victims across engines as typed KV migrations;
//! its admission failures surface as
//! [`crate::cluster::ClusterError::AdmissionRejected`], the
//! cluster-level analogue of this module's `AdmissionRejected`
//! [`ServeError`].

// Serving-path no-panic discipline (satellite of sparselint's
// `no-panic` pass): unwrap/expect in this module tree is a clippy
// warning, denied under CI's `-D warnings`. The few justified
// sites carry fn-level allows next to their sparselint comments.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod api;
pub mod server;

pub use api::{ServeError, StreamEvent, SubmitHandle, SubmitRequest};
pub use server::Server;
