//! Client-facing request/response types.
//!
//! Submission is expressed with the [`SubmitRequest`] builder
//! (re-exported here); results stream back as [`StreamEvent`]s and
//! failures are the typed [`ServeError`] taxonomy.

use std::sync::mpsc::Receiver;

pub use crate::engine::{ServeError, SubmitRequest};
use crate::memory::ReqId;
pub use crate::scheduler::RequestTiming;

/// Events streamed back to a submitting client.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// A generated token (the first one marks end of prefill). `index`
    /// counts actually emitted tokens, starting at 0.
    Token { token: i32, index: usize },
    /// Generation finished, with the request's timing summary
    /// (`n_tokens` counts every produced token, `ttft_s` / `tbt_mean_s`
    /// are on the server's wall clock).
    Done { timing: RequestTiming },
    /// The request failed (cancelled, backend failure, backpressure, …).
    Error(ServeError),
}

/// Handle returned on submit: stream of events for one request. Pass
/// [`Self::id`] to `Server::cancel` to abort the request.
pub struct SubmitHandle {
    pub id: ReqId,
    pub events: Receiver<StreamEvent>,
}

impl SubmitHandle {
    /// Drain the stream to completion, returning all tokens.
    pub fn collect_tokens(self) -> Result<Vec<i32>, ServeError> {
        self.collect().map(|(toks, _)| toks)
    }

    /// Drain the stream to completion, returning tokens + timing.
    pub fn collect(self) -> Result<(Vec<i32>, RequestTiming), ServeError> {
        let mut toks = Vec::new();
        for ev in self.events.iter() {
            match ev {
                StreamEvent::Token { token, index } => {
                    debug_assert_eq!(index, toks.len(), "token stream out of order");
                    toks.push(token);
                }
                StreamEvent::Done { timing } => return Ok((toks, timing)),
                StreamEvent::Error(e) => return Err(e),
            }
        }
        Err(ServeError::Disconnected)
    }
}
