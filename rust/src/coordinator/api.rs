//! Client-facing request/response types.

use std::sync::mpsc::Receiver;

/// Events streamed back to a submitting client.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// A generated token (first one marks end of prefill).
    Token { token: i32, index: usize },
    /// Generation finished; total tokens produced.
    Done { n_tokens: usize },
    /// The request failed.
    Error(String),
}

/// Handle returned on submit: stream of events for one request.
pub struct SubmitHandle {
    pub id: u32,
    pub events: Receiver<StreamEvent>,
}

impl SubmitHandle {
    /// Drain the stream to completion, returning all tokens.
    pub fn collect_tokens(self) -> Result<Vec<i32>, String> {
        let mut toks = Vec::new();
        for ev in self.events.iter() {
            match ev {
                StreamEvent::Token { token, .. } => toks.push(token),
                StreamEvent::Done { .. } => return Ok(toks),
                StreamEvent::Error(e) => return Err(e),
            }
        }
        Err("stream closed before Done".into())
    }
}
