//! Serving metrics: TTFT, TBT, throughput, goodput (paper §4 metrics),
//! plus the prefetch/overlap accounting of the two-stream iteration
//! model (stall time, staged blocks, hit/waste counters).

use crate::engine::{BatchOutcome, PhaseEvent};
use crate::scheduler::Request;
use crate::util::stats::Series;

/// Per-layer compute-vs-transfer-wait profile, accumulated from the
/// [`PhaseEvent`]s each committed iteration carries (`BatchOutcome::
/// phases`). This is the observability lens ROADMAP item 5 names as a
/// prerequisite for adaptive policies: the real backend's *measured*
/// `PhaseEvent::compute_s` and the simulator's modeled one both land
/// here, layer by layer, instead of being discarded by `drive_step`.
#[derive(Debug, Default, Clone)]
pub struct LayerProfile {
    /// GPU compute attributed to each layer, seconds (prefill segments
    /// and decode layers both fold into the layer they ran).
    pub compute_s: Vec<f64>,
    /// Demand PCIe bytes each layer moved.
    pub bytes_moved: Vec<u64>,
    /// Demand misses discovered at each layer (per-head blocks).
    pub miss_blocks: Vec<u64>,
    /// Transfer wait attributed to each layer: the iteration's unhidden
    /// copy time (`BatchOutcome::stall_time_s`) apportioned over layers
    /// by their share of the iteration's demand bytes. An attribution,
    /// not a measurement — the event models overlap copies across layer
    /// boundaries, so a single layer's true wait is not separable; the
    /// byte-weighted split conserves total stall while still showing
    /// WHERE the traffic that caused it was discovered.
    pub transfer_wait_s: Vec<f64>,
    /// Phase events folded in.
    pub phases: u64,
}

impl LayerProfile {
    fn ensure(&mut self, n_layers: usize) {
        if self.compute_s.len() < n_layers {
            self.compute_s.resize(n_layers, 0.0);
            self.bytes_moved.resize(n_layers, 0);
            self.miss_blocks.resize(n_layers, 0);
            self.transfer_wait_s.resize(n_layers, 0.0);
        }
    }

    /// Fold one committed iteration's phase events in.
    pub fn record_outcome(&mut self, out: &BatchOutcome) {
        if out.phases.is_empty() {
            return;
        }
        let total_bytes: u64 = out.phases.iter().map(|e| e.bytes_moved as u64).sum();
        for ev in &out.phases {
            self.record_event(ev, out.stall_time_s, total_bytes);
        }
    }

    fn record_event(&mut self, ev: &PhaseEvent, iter_stall_s: f64, total_bytes: u64) {
        // phases are driven one layer at a time; a multi-layer event is
        // attributed to its first layer
        let layer = ev.layer_start;
        self.ensure(layer + 1);
        self.compute_s[layer] += ev.compute_s;
        self.bytes_moved[layer] += ev.bytes_moved as u64;
        self.miss_blocks[layer] += ev.miss_blocks as u64;
        if total_bytes > 0 {
            self.transfer_wait_s[layer] +=
                iter_stall_s * ev.bytes_moved as f64 / total_bytes as f64;
        }
        self.phases += 1;
    }

    /// Layers observed so far.
    pub fn n_layers(&self) -> usize {
        self.compute_s.len()
    }

    pub fn total_compute_s(&self) -> f64 {
        self.compute_s.iter().sum()
    }

    pub fn total_transfer_wait_s(&self) -> f64 {
        self.transfer_wait_s.iter().sum()
    }

    /// Compact per-run rendering: totals plus the most compute- and most
    /// transfer-bound layers (the signal the router and the adaptive
    /// policies of ROADMAP item 5 read).
    pub fn summary(&self) -> String {
        if self.phases == 0 {
            return "layer profile: no phase events recorded".into();
        }
        let argmax = |v: &[f64]| -> usize {
            v.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        };
        let lc = argmax(&self.compute_s);
        let lw = argmax(&self.transfer_wait_s);
        format!(
            "layer profile: {} layers, compute {:.4}s vs transfer wait {:.4}s \
             | hottest compute layer {} ({:.4}s) | hottest wait layer {} \
             ({:.4}s, {} miss blocks)",
            self.n_layers(),
            self.total_compute_s(),
            self.total_transfer_wait_s(),
            lc,
            self.compute_s.get(lc).copied().unwrap_or(0.0),
            lw,
            self.transfer_wait_s.get(lw).copied().unwrap_or(0.0),
            self.miss_blocks.get(lw).copied().unwrap_or(0),
        )
    }
}

/// Aggregated metrics for one serving run.
#[derive(Debug, Default)]
pub struct RunMetrics {
    pub ttft: Series,
    pub tbt: Series,
    pub queue_delay: Series,
    /// Generated tokens (all requests).
    pub tokens_generated: usize,
    pub requests_finished: usize,
    /// Requests cancelled by the client before finishing.
    pub requests_cancelled: usize,
    /// Requests rejected by the engine (inadmissible memory demand).
    pub requests_rejected: usize,
    /// Requests evicted mid-run by typed memory-tier exhaustion.
    pub requests_evicted: usize,
    /// Requests drained off this engine by the cluster tier's KV
    /// migration instead of being evicted (counted at the source).
    pub requests_migrated: usize,
    /// Serving-clock time this engine's migrations spent on the wire
    /// (FlashD2H drain at the source + FlashH2D fill at the target).
    pub migration_transfer_total_s: f64,
    /// DRAM-tier KV bytes serialized across engines by migrations.
    pub migration_bytes_total: u64,
    /// Requests whose achieved TTFT exceeded their per-request SLO.
    pub ttft_slo_violations: usize,
    /// Serving-clock makespan, seconds.
    pub makespan_s: f64,
    /// Per-iteration KV blocks loaded from DRAM (Fig. 1 / Fig. 15 series).
    pub blocks_loaded_per_iter: Series,
    /// Per-iteration latency.
    pub iter_time: Series,
    /// Modeled PCIe busy time per iteration (demand + prefetch streams).
    pub load_time: Series,
    /// Per-iteration stall: PCIe time compute could not hide (under the
    /// configured iteration event model).
    pub stall_time: Series,
    /// Per-iteration copy time hidden under compute (overlap earned by
    /// the per-layer event model and the prefetcher).
    pub hidden_time: Series,
    /// Per-iteration stall the coarse two-stream model would have
    /// charged for the same traffic (`bench` compares the two models).
    pub coarse_stall_time: Series,
    /// Compute burnt on rolled-back (aborted) step attempts, charged to
    /// the serving clock on top of the committed iteration time —
    /// eviction-heavy workloads no longer under-report latency. Sampled
    /// per committed iteration, plus one sample per fully-abandoned
    /// iteration.
    pub abort_time: Series,
    /// Total serving-clock time spent on rolled-back attempts.
    pub abort_time_total_s: f64,
    /// Blocks staged ahead of need by the working-set prefetcher.
    pub prefetch_blocks: u64,
    /// Staged blocks consumed by a gather (earned overlap).
    pub prefetch_hits: u64,
    /// Staged blocks their iteration never touched.
    pub prefetch_wasted: u64,
    /// Blocks staged for the following iteration (cross-iteration
    /// staging hints issued under the current batch's compute).
    pub prefetch_deferred: u64,
    pub iterations: usize,
    /// Serving-clock time the pipelined executor hid: plan/stage work
    /// for iteration N+1 that ran under iteration N's compute instead of
    /// serializing before N+1 (`BatchOutcome::plan_stage_hidden_s`
    /// totals; zero at `pipeline_depth` 1).
    pub plan_stage_hidden_s: f64,
    /// Plan/stage time the pipeline could NOT hide — overhang past the
    /// predecessor's execution window, paid as a stall
    /// (`BatchOutcome::pipeline_bubble_s` totals).
    pub pipeline_bubble_s: f64,
    /// Iterations that consumed a still-valid speculative plan
    /// (pipelined pricing applied).
    pub pipeline_spec_used: usize,
    /// Iterations whose speculative plan went stale (eviction, finish,
    /// prefill graduation, migration) and was re-planned synchronously
    /// instead of executed.
    pub pipeline_replans: usize,
    /// Per-layer compute-vs-transfer-wait profile (see [`LayerProfile`]).
    pub layer_profile: LayerProfile,
    /// Admissions that matched a non-empty shared KV prefix (the
    /// cross-request prefix index; zero with `prefix_sharing` off).
    pub prefix_hits: u64,
    /// Prompt tokens whose prefill was skipped because their KV was
    /// adopted from a shared prefix path.
    pub prefix_matched_tokens: u64,
    /// Shared prefix pool charge at run end (live + cached path blocks),
    /// bytes.
    pub prefix_resident_bytes: u64,
}

impl RunMetrics {
    /// Cap on per-sample series growth so a long-running online server
    /// doesn't accumulate samples without bound. Far above any offline
    /// replay's iteration count; aggregate counters (`iterations`,
    /// `tokens_generated`, `requests_*`) stay exact past the cap —
    /// only distribution samples stop being collected.
    pub const MAX_SAMPLES: usize = 1 << 20;

    pub fn new() -> Self {
        Self::default()
    }

    /// Fold a finished (or partially served) request in.
    pub fn record_request(&mut self, r: &Request) {
        if let Some(t) = r.ttft() {
            if self.ttft.len() < Self::MAX_SAMPLES {
                self.ttft.push(t);
            }
        }
        if let Some(d) = r.queue_delay() {
            if self.queue_delay.len() < Self::MAX_SAMPLES {
                self.queue_delay.push(d);
            }
        }
        let room = Self::MAX_SAMPLES.saturating_sub(self.tbt.len());
        self.tbt.extend(&r.tbt[..r.tbt.len().min(room)]);
        self.tokens_generated += r.n_generated;
        if r.is_done() {
            self.requests_finished += 1;
        }
        if r.is_cancelled() {
            self.requests_cancelled += 1;
        }
        if let (Some(slo), Some(ttft)) = (r.ttft_slo_s, r.ttft()) {
            if ttft > slo {
                self.ttft_slo_violations += 1;
            }
        }
    }

    pub fn record_iteration(&mut self, out: &BatchOutcome) {
        self.iterations += 1;
        self.prefetch_blocks += out.prefetch_blocks as u64;
        self.prefetch_hits += out.prefetch_hits as u64;
        self.prefetch_wasted += out.prefetch_wasted as u64;
        self.prefetch_deferred += out.prefetch_deferred as u64;
        self.abort_time_total_s += out.abort_time_s;
        self.plan_stage_hidden_s += out.plan_stage_hidden_s;
        self.pipeline_bubble_s += out.pipeline_bubble_s;
        self.layer_profile.record_outcome(out);
        if self.iter_time.len() < Self::MAX_SAMPLES {
            self.iter_time.push(out.iter_time_s);
            self.blocks_loaded_per_iter.push(out.blocks_loaded as f64);
            self.load_time.push(out.load_time_s);
            self.stall_time.push(out.stall_time_s);
            self.hidden_time.push(out.hidden_time_s);
            self.coarse_stall_time.push(out.coarse_stall_time_s);
            self.abort_time.push(out.abort_time_s);
        }
    }

    /// Record an iteration the engine abandoned entirely (every
    /// batch-mate evicted before a commit): nothing ran, but the aborted
    /// attempts' burnt time still advances the serving clock.
    pub fn record_abandoned_iteration(&mut self, aborted_s: f64) {
        self.abort_time_total_s += aborted_s;
        if aborted_s > 0.0 && self.abort_time.len() < Self::MAX_SAMPLES {
            self.abort_time.push(aborted_s);
        }
    }

    /// Record one KV migration drained off this engine: `transfer_s` is
    /// the FlashD2H + FlashH2D wire time the shared cluster clock was
    /// charged, `bytes` the serialized DRAM-tier footprint.
    pub fn record_migration(&mut self, transfer_s: f64, bytes: usize) {
        self.requests_migrated += 1;
        self.migration_transfer_total_s += transfer_s;
        self.migration_bytes_total += bytes as u64;
    }

    /// Fraction of staged blocks that were consumed (0 when none staged).
    pub fn prefetch_hit_rate(&self) -> f64 {
        if self.prefetch_blocks == 0 {
            0.0
        } else {
            self.prefetch_hits as f64 / self.prefetch_blocks as f64
        }
    }

    /// Token generation throughput (tokens/s over the makespan).
    pub fn throughput(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.makespan_s
        }
    }

    /// The paper's SLO check (Fig. 13): P99 TBT <= factor x the reference
    /// decode-iteration time AND mean queueing delay <= the bound.
    pub fn meets_slo(&self, decode_iter_ref_s: f64, tbt_factor: f64, queue_bound_s: f64) -> bool {
        let p99_ok = self.tbt.is_empty() || self.tbt.p99() <= tbt_factor * decode_iter_ref_s;
        let queue_ok =
            self.queue_delay.is_empty() || self.queue_delay.mean() <= queue_bound_s;
        p99_ok && queue_ok
    }

    pub fn summary(&self) -> String {
        let mut extra = String::new();
        if self.requests_cancelled > 0 {
            extra.push_str(&format!(" (cancelled={})", self.requests_cancelled));
        }
        if self.requests_rejected > 0 {
            extra.push_str(&format!(" (rejected={})", self.requests_rejected));
        }
        if self.requests_evicted > 0 {
            extra.push_str(&format!(" (evicted={})", self.requests_evicted));
        }
        if self.requests_migrated > 0 {
            extra.push_str(&format!(
                " (migrated={} transfer {:.4}s)",
                self.requests_migrated, self.migration_transfer_total_s
            ));
        }
        let prefetch = if self.prefetch_blocks > 0 {
            format!(
                " | prefetch staged={} hit={:.0}% wasted={} deferred={}",
                self.prefetch_blocks,
                100.0 * self.prefetch_hit_rate(),
                self.prefetch_wasted,
                self.prefetch_deferred,
            )
        } else {
            String::new()
        };
        let abort = if self.abort_time_total_s > 0.0 {
            format!(
                " | aborted-attempt time {:.4}s (mean {:.4}s/iter)",
                self.abort_time_total_s,
                self.abort_time.mean()
            )
        } else {
            String::new()
        };
        let overlap = if self.hidden_time.mean() > 0.0 {
            format!(
                " | overlap hidden mean={:.4}s (coarse stall {:.4}s)",
                self.hidden_time.mean(),
                self.coarse_stall_time.mean(),
            )
        } else {
            String::new()
        };
        let prefix = if self.prefix_hits > 0 {
            format!(
                " | prefix hits={} matched_tokens={} shared {:.1} MiB",
                self.prefix_hits,
                self.prefix_matched_tokens,
                self.prefix_resident_bytes as f64 / (1 << 20) as f64,
            )
        } else {
            String::new()
        };
        let pipeline = if self.pipeline_spec_used + self.pipeline_replans > 0 {
            format!(
                " | pipeline primed={} replans={} hidden {:.4}s bubble {:.4}s",
                self.pipeline_spec_used,
                self.pipeline_replans,
                self.plan_stage_hidden_s,
                self.pipeline_bubble_s,
            )
        } else {
            String::new()
        };
        format!(
            "reqs={}{} tokens={} makespan={:.1}s iters={} thpt={:.2} tok/s | \
             TTFT mean={:.3}s p99={:.3}s | TBT mean={:.4}s p99={:.4}s | \
             queue mean={:.3}s | loads/iter mean={:.1} load mean={:.4}s \
             stall mean={:.4}s{}",
            self.requests_finished,
            extra,
            self.tokens_generated,
            self.makespan_s,
            self.iterations,
            self.throughput(),
            self.ttft.mean(),
            self.ttft.p99(),
            self.tbt.mean(),
            self.tbt.p99(),
            self.queue_delay.mean(),
            self.blocks_loaded_per_iter.mean(),
            self.load_time.mean(),
            self.stall_time.mean(),
            prefetch,
        ) + &abort
            + &overlap
            + &prefix
            + &pipeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = RunMetrics::new();
        let mut r = Request::new(1, 100, 3, 0.0);
        r.admitted_s = Some(0.5);
        r.push_token(None, 1.0);
        r.push_token(None, 1.2);
        r.push_token(None, 1.5);
        m.record_request(&r);
        m.makespan_s = 2.0;
        assert_eq!(m.requests_finished, 1);
        assert_eq!(m.tokens_generated, 3);
        assert!((m.throughput() - 1.5).abs() < 1e-9);
        assert!((m.ttft.mean() - 1.0).abs() < 1e-9);
        assert_eq!(m.tbt.len(), 2);
    }

    #[test]
    fn iteration_records_prefetch_counters() {
        let mut m = RunMetrics::new();
        let out = BatchOutcome {
            iter_time_s: 0.1,
            blocks_loaded: 10,
            load_time_s: 0.05,
            stall_time_s: 0.02,
            hidden_time_s: 0.03,
            coarse_stall_time_s: 0.05,
            prefetch_blocks: 8,
            prefetch_hits: 6,
            prefetch_wasted: 2,
            prefetch_deferred: 3,
            abort_time_s: 0.04,
            ..Default::default()
        };
        m.record_iteration(&out);
        assert_eq!(m.iterations, 1);
        assert!((m.abort_time_total_s - 0.04).abs() < 1e-12);
        m.record_abandoned_iteration(0.06);
        assert!((m.abort_time_total_s - 0.10).abs() < 1e-12);
        assert_eq!(m.abort_time.len(), 2);
        assert!(m.summary().contains("aborted-attempt time"));
        assert_eq!(m.prefetch_blocks, 8);
        assert_eq!(m.prefetch_deferred, 3);
        assert!((m.prefetch_hit_rate() - 0.75).abs() < 1e-12);
        assert!((m.stall_time.mean() - 0.02).abs() < 1e-12);
        assert!((m.hidden_time.mean() - 0.03).abs() < 1e-12);
        assert!((m.coarse_stall_time.mean() - 0.05).abs() < 1e-12);
        assert!(m.summary().contains("prefetch staged=8"));
        assert!(m.summary().contains("overlap hidden"));
    }

    #[test]
    fn pipeline_counters_recorded_and_summarized() {
        let mut m = RunMetrics::new();
        // a synchronous iteration reports no pipeline segment at all
        m.record_iteration(&BatchOutcome { iter_time_s: 0.1, ..Default::default() });
        assert!(!m.summary().contains("pipeline"));
        let out = BatchOutcome {
            iter_time_s: 0.08,
            plan_stage_hidden_s: 0.02,
            pipeline_bubble_s: 0.005,
            ..Default::default()
        };
        m.record_iteration(&out);
        m.record_iteration(&out);
        m.pipeline_spec_used = 2;
        m.pipeline_replans = 1;
        assert!((m.plan_stage_hidden_s - 0.04).abs() < 1e-12);
        assert!((m.pipeline_bubble_s - 0.01).abs() < 1e-12);
        assert!(m.summary().contains("pipeline primed=2 replans=1"));
    }

    #[test]
    fn layer_profile_accumulates_phase_events() {
        let mut m = RunMetrics::new();
        let out = BatchOutcome {
            iter_time_s: 0.1,
            stall_time_s: 0.03,
            phases: vec![
                PhaseEvent {
                    layer_start: 0,
                    layer_end: 1,
                    compute_s: 0.01,
                    miss_blocks: 2,
                    bytes_moved: 100,
                },
                PhaseEvent {
                    layer_start: 1,
                    layer_end: 2,
                    compute_s: 0.02,
                    miss_blocks: 6,
                    bytes_moved: 300,
                },
            ],
            ..Default::default()
        };
        m.record_iteration(&out);
        let p = &m.layer_profile;
        assert_eq!(p.n_layers(), 2);
        assert_eq!(p.phases, 2);
        assert!((p.compute_s[0] - 0.01).abs() < 1e-12);
        assert!((p.compute_s[1] - 0.02).abs() < 1e-12);
        assert_eq!(p.bytes_moved, vec![100, 300]);
        assert_eq!(p.miss_blocks, vec![2, 6]);
        // stall apportioned by byte share: 25% / 75%
        assert!((p.transfer_wait_s[0] - 0.03 * 0.25).abs() < 1e-12);
        assert!((p.transfer_wait_s[1] - 0.03 * 0.75).abs() < 1e-12);
        // total stall is conserved across the attribution
        assert!((p.total_transfer_wait_s() - 0.03).abs() < 1e-12);
        assert!(p.summary().contains("2 layers"));
    }

    #[test]
    fn prefix_counters_surface_in_summary() {
        let mut m = RunMetrics::new();
        assert!(!m.summary().contains("prefix hits"));
        m.prefix_hits = 3;
        m.prefix_matched_tokens = 1536;
        m.prefix_resident_bytes = 4 << 20;
        assert!(m.summary().contains("prefix hits=3 matched_tokens=1536 shared 4.0 MiB"));
    }

    #[test]
    fn migration_counters_recorded_and_summarized() {
        let mut m = RunMetrics::new();
        m.record_migration(0.25, 1 << 20);
        m.record_migration(0.50, 1 << 20);
        assert_eq!(m.requests_migrated, 2);
        assert!((m.migration_transfer_total_s - 0.75).abs() < 1e-12);
        assert_eq!(m.migration_bytes_total, 2 << 20);
        assert!(m.summary().contains("migrated=2"));
    }

    #[test]
    fn slo_check() {
        let mut m = RunMetrics::new();
        let mut r = Request::new(1, 10, 3, 0.0);
        r.admitted_s = Some(0.1);
        r.push_token(None, 0.2);
        r.push_token(None, 0.3);
        r.push_token(None, 0.4);
        m.record_request(&r);
        // p99 tbt ~= 0.1; ref iter 0.01 -> 25x = 0.25 OK; queue 0.1 <= 2 OK
        assert!(m.meets_slo(0.01, 25.0, 2.0));
        // tighter: 5x ref = 0.05 < 0.1 -> violated
        assert!(!m.meets_slo(0.01, 5.0, 2.0));
    }
}
