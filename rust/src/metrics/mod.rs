//! Serving metrics: TTFT, TBT, throughput, goodput (paper §4 metrics),
//! plus the prefetch/overlap accounting of the two-stream iteration
//! model (stall time, staged blocks, hit/waste counters).

use crate::engine::BatchOutcome;
use crate::scheduler::Request;
use crate::util::stats::Series;

/// Aggregated metrics for one serving run.
#[derive(Debug, Default)]
pub struct RunMetrics {
    pub ttft: Series,
    pub tbt: Series,
    pub queue_delay: Series,
    /// Generated tokens (all requests).
    pub tokens_generated: usize,
    pub requests_finished: usize,
    /// Requests cancelled by the client before finishing.
    pub requests_cancelled: usize,
    /// Requests rejected by the engine (inadmissible memory demand).
    pub requests_rejected: usize,
    /// Requests evicted mid-run by typed memory-tier exhaustion.
    pub requests_evicted: usize,
    /// Requests whose achieved TTFT exceeded their per-request SLO.
    pub ttft_slo_violations: usize,
    /// Serving-clock makespan, seconds.
    pub makespan_s: f64,
    /// Per-iteration KV blocks loaded from DRAM (Fig. 1 / Fig. 15 series).
    pub blocks_loaded_per_iter: Series,
    /// Per-iteration latency.
    pub iter_time: Series,
    /// Modeled PCIe busy time per iteration (demand + prefetch streams).
    pub load_time: Series,
    /// Per-iteration stall: PCIe time compute could not hide (under the
    /// configured iteration event model).
    pub stall_time: Series,
    /// Per-iteration copy time hidden under compute (overlap earned by
    /// the per-layer event model and the prefetcher).
    pub hidden_time: Series,
    /// Per-iteration stall the coarse two-stream model would have
    /// charged for the same traffic (`bench` compares the two models).
    pub coarse_stall_time: Series,
    /// Compute burnt on rolled-back (aborted) step attempts, charged to
    /// the serving clock on top of the committed iteration time —
    /// eviction-heavy workloads no longer under-report latency. Sampled
    /// per committed iteration, plus one sample per fully-abandoned
    /// iteration.
    pub abort_time: Series,
    /// Total serving-clock time spent on rolled-back attempts.
    pub abort_time_total_s: f64,
    /// Blocks staged ahead of need by the working-set prefetcher.
    pub prefetch_blocks: u64,
    /// Staged blocks consumed by a gather (earned overlap).
    pub prefetch_hits: u64,
    /// Staged blocks their iteration never touched.
    pub prefetch_wasted: u64,
    /// Blocks staged for the following iteration (cross-iteration
    /// staging hints issued under the current batch's compute).
    pub prefetch_deferred: u64,
    pub iterations: usize,
}

impl RunMetrics {
    /// Cap on per-sample series growth so a long-running online server
    /// doesn't accumulate samples without bound. Far above any offline
    /// replay's iteration count; aggregate counters (`iterations`,
    /// `tokens_generated`, `requests_*`) stay exact past the cap —
    /// only distribution samples stop being collected.
    pub const MAX_SAMPLES: usize = 1 << 20;

    pub fn new() -> Self {
        Self::default()
    }

    /// Fold a finished (or partially served) request in.
    pub fn record_request(&mut self, r: &Request) {
        if let Some(t) = r.ttft() {
            if self.ttft.len() < Self::MAX_SAMPLES {
                self.ttft.push(t);
            }
        }
        if let Some(d) = r.queue_delay() {
            if self.queue_delay.len() < Self::MAX_SAMPLES {
                self.queue_delay.push(d);
            }
        }
        let room = Self::MAX_SAMPLES.saturating_sub(self.tbt.len());
        self.tbt.extend(&r.tbt[..r.tbt.len().min(room)]);
        self.tokens_generated += r.n_generated;
        if r.is_done() {
            self.requests_finished += 1;
        }
        if r.is_cancelled() {
            self.requests_cancelled += 1;
        }
        if let (Some(slo), Some(ttft)) = (r.ttft_slo_s, r.ttft()) {
            if ttft > slo {
                self.ttft_slo_violations += 1;
            }
        }
    }

    pub fn record_iteration(&mut self, out: &BatchOutcome) {
        self.iterations += 1;
        self.prefetch_blocks += out.prefetch_blocks as u64;
        self.prefetch_hits += out.prefetch_hits as u64;
        self.prefetch_wasted += out.prefetch_wasted as u64;
        self.prefetch_deferred += out.prefetch_deferred as u64;
        self.abort_time_total_s += out.abort_time_s;
        if self.iter_time.len() < Self::MAX_SAMPLES {
            self.iter_time.push(out.iter_time_s);
            self.blocks_loaded_per_iter.push(out.blocks_loaded as f64);
            self.load_time.push(out.load_time_s);
            self.stall_time.push(out.stall_time_s);
            self.hidden_time.push(out.hidden_time_s);
            self.coarse_stall_time.push(out.coarse_stall_time_s);
            self.abort_time.push(out.abort_time_s);
        }
    }

    /// Record an iteration the engine abandoned entirely (every
    /// batch-mate evicted before a commit): nothing ran, but the aborted
    /// attempts' burnt time still advances the serving clock.
    pub fn record_abandoned_iteration(&mut self, aborted_s: f64) {
        self.abort_time_total_s += aborted_s;
        if aborted_s > 0.0 && self.abort_time.len() < Self::MAX_SAMPLES {
            self.abort_time.push(aborted_s);
        }
    }

    /// Fraction of staged blocks that were consumed (0 when none staged).
    pub fn prefetch_hit_rate(&self) -> f64 {
        if self.prefetch_blocks == 0 {
            0.0
        } else {
            self.prefetch_hits as f64 / self.prefetch_blocks as f64
        }
    }

    /// Token generation throughput (tokens/s over the makespan).
    pub fn throughput(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.makespan_s
        }
    }

    /// The paper's SLO check (Fig. 13): P99 TBT <= factor x the reference
    /// decode-iteration time AND mean queueing delay <= the bound.
    pub fn meets_slo(&self, decode_iter_ref_s: f64, tbt_factor: f64, queue_bound_s: f64) -> bool {
        let p99_ok = self.tbt.is_empty() || self.tbt.p99() <= tbt_factor * decode_iter_ref_s;
        let queue_ok =
            self.queue_delay.is_empty() || self.queue_delay.mean() <= queue_bound_s;
        p99_ok && queue_ok
    }

    pub fn summary(&self) -> String {
        let mut extra = String::new();
        if self.requests_cancelled > 0 {
            extra.push_str(&format!(" (cancelled={})", self.requests_cancelled));
        }
        if self.requests_rejected > 0 {
            extra.push_str(&format!(" (rejected={})", self.requests_rejected));
        }
        if self.requests_evicted > 0 {
            extra.push_str(&format!(" (evicted={})", self.requests_evicted));
        }
        let prefetch = if self.prefetch_blocks > 0 {
            format!(
                " | prefetch staged={} hit={:.0}% wasted={} deferred={}",
                self.prefetch_blocks,
                100.0 * self.prefetch_hit_rate(),
                self.prefetch_wasted,
                self.prefetch_deferred,
            )
        } else {
            String::new()
        };
        let abort = if self.abort_time_total_s > 0.0 {
            format!(" | aborted-attempt time {:.4}s", self.abort_time_total_s)
        } else {
            String::new()
        };
        let overlap = if self.hidden_time.mean() > 0.0 {
            format!(
                " | overlap hidden mean={:.4}s (coarse stall {:.4}s)",
                self.hidden_time.mean(),
                self.coarse_stall_time.mean(),
            )
        } else {
            String::new()
        };
        format!(
            "reqs={}{} tokens={} makespan={:.1}s thpt={:.2} tok/s | \
             TTFT mean={:.3}s p99={:.3}s | TBT mean={:.4}s p99={:.4}s | \
             queue mean={:.3}s | loads/iter mean={:.1} stall mean={:.4}s{}",
            self.requests_finished,
            extra,
            self.tokens_generated,
            self.makespan_s,
            self.throughput(),
            self.ttft.mean(),
            self.ttft.p99(),
            self.tbt.mean(),
            self.tbt.p99(),
            self.queue_delay.mean(),
            self.blocks_loaded_per_iter.mean(),
            self.stall_time.mean(),
            prefetch,
        ) + &abort
            + &overlap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = RunMetrics::new();
        let mut r = Request::new(1, 100, 3, 0.0);
        r.admitted_s = Some(0.5);
        r.push_token(None, 1.0);
        r.push_token(None, 1.2);
        r.push_token(None, 1.5);
        m.record_request(&r);
        m.makespan_s = 2.0;
        assert_eq!(m.requests_finished, 1);
        assert_eq!(m.tokens_generated, 3);
        assert!((m.throughput() - 1.5).abs() < 1e-9);
        assert!((m.ttft.mean() - 1.0).abs() < 1e-9);
        assert_eq!(m.tbt.len(), 2);
    }

    #[test]
    fn iteration_records_prefetch_counters() {
        let mut m = RunMetrics::new();
        let out = BatchOutcome {
            iter_time_s: 0.1,
            blocks_loaded: 10,
            load_time_s: 0.05,
            stall_time_s: 0.02,
            hidden_time_s: 0.03,
            coarse_stall_time_s: 0.05,
            prefetch_blocks: 8,
            prefetch_hits: 6,
            prefetch_wasted: 2,
            prefetch_deferred: 3,
            abort_time_s: 0.04,
            ..Default::default()
        };
        m.record_iteration(&out);
        assert_eq!(m.iterations, 1);
        assert!((m.abort_time_total_s - 0.04).abs() < 1e-12);
        m.record_abandoned_iteration(0.06);
        assert!((m.abort_time_total_s - 0.10).abs() < 1e-12);
        assert_eq!(m.abort_time.len(), 2);
        assert!(m.summary().contains("aborted-attempt time"));
        assert_eq!(m.prefetch_blocks, 8);
        assert_eq!(m.prefetch_deferred, 3);
        assert!((m.prefetch_hit_rate() - 0.75).abs() < 1e-12);
        assert!((m.stall_time.mean() - 0.02).abs() < 1e-12);
        assert!((m.hidden_time.mean() - 0.03).abs() < 1e-12);
        assert!((m.coarse_stall_time.mean() - 0.05).abs() < 1e-12);
        assert!(m.summary().contains("prefetch staged=8"));
        assert!(m.summary().contains("overlap hidden"));
    }

    #[test]
    fn slo_check() {
        let mut m = RunMetrics::new();
        let mut r = Request::new(1, 10, 3, 0.0);
        r.admitted_s = Some(0.1);
        r.push_token(None, 0.2);
        r.push_token(None, 0.3);
        r.push_token(None, 0.4);
        m.record_request(&r);
        // p99 tbt ~= 0.1; ref iter 0.01 -> 25x = 0.25 OK; queue 0.1 <= 2 OK
        assert!(m.meets_slo(0.01, 25.0, 2.0));
        // tighter: 5x ref = 0.05 < 0.1 -> violated
        assert!(!m.meets_slo(0.01, 5.0, 2.0));
    }
}
