//! # SparseServe
//!
//! Reproduction of *"SparseServe: Unlocking Parallelism for Dynamic Sparse
//! Attention in Long-Context LLM Serving"* (CS.DC 2025) as a three-layer
//! rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the serving system — a single per-iteration
//!   [`engine::EngineCore`] (submit / step / cancel, typed
//!   [`engine::ServeError`]s, priority-aware admission) driving FCFS
//!   continuous batching with working-set-aware batch size control
//!   (Alg. 1), hierarchical HBM/DRAM KV-cache management with
//!   fragmentation-aware transfer engines (FlashH2D / FlashD2H), and
//!   layer-segmented prefill; the [`cluster`] tier routes across N
//!   engines with working-set-aware placement and typed KV migration.
//!   See `rust/README.md` for the serving API.
//! - **L2 (python/compile/model.py)**: llama-style model split into
//!   per-layer/per-phase entry points, AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels/)**: pallas kernels (block metadata,
//!   block scoring, sparse decode attention, tiled causal prefill).
//!
//! Python never runs on the request path: artifacts are built once by
//! `make artifacts` and executed from rust via PJRT (`runtime`).
//!
//! See DESIGN.md for the architecture and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod figures;
pub mod lint;
pub mod memory;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod sparse;
pub mod util;
pub mod workload;
pub mod xla;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
