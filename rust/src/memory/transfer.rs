//! Fragmentation-aware KV cache transfer engines (paper §3.2).
//!
//! Three ways to move per-head KV blocks across the HBM<->DRAM boundary:
//!
//! - [`MemcpyEngine`] — the baseline: one `cudaMemcpy` per block. Each
//!   call pays the driver overhead, capping effective bandwidth below
//!   5-6 GB/s for 16 KB blocks (Fig. 4, grey bars).
//! - [`FlashEngine`] — the paper's design. Loading (FlashH2D) fuses all
//!   block reads into a single GPU kernel using UVA: one launch, then the
//!   whole burst streams at ~0.7x PCIe peak (> 20 GB/s). Saving
//!   (FlashD2H) copies the *contiguous* freshly-projected KV tensor
//!   host-ward with one memcpy, then CPU worker threads scatter rows into
//!   their DRAM blocks off the GPU's critical path (> 23 GB/s, zero GPU
//!   interference).
//! - [`GpuDirectSaveEngine`] — the strawman of Fig. 14b: saving with a
//!   fused GPU kernel is fast on PCIe but steals SMs, multiplying
//!   overlapped compute time by `gpu_save_interference` (1.28x measured).
//!
//! Engines perform *real* f32 copies between the host-memory pools (so
//! numerics flow through the exact path) and report *modeled* PCIe time
//! from the calibrated [`HardwareSpec`] cost model — the testbed
//! substitute described in DESIGN.md.

use crate::config::serving::TransferKind;
use crate::config::HardwareSpec;

use super::pool::{BlockPool, SlotId};

/// One scatter copy: `src[src_off .. src_off+len]` ->
/// `dram[dst_slot][dst_off .. dst_off+len]` (float offsets).
#[derive(Debug, Clone, Copy)]
pub struct ScatterEntry {
    pub src_off: usize,
    pub len: usize,
    pub dst_slot: SlotId,
    pub dst_off: usize,
}

/// Outcome of one transfer burst.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TransferStats {
    pub blocks: usize,
    pub bytes: usize,
    /// Number of memcpy calls / kernel launches issued.
    pub calls: usize,
    /// Modeled PCIe critical-path time on the paper's testbed, seconds.
    pub modeled_s: f64,
    /// Multiplier applied to model compute that overlaps this transfer
    /// (1.0 = no interference; GPU-direct saving: 1.28).
    pub gpu_interference: f64,
}

impl TransferStats {
    pub fn merge(&mut self, other: &TransferStats) {
        self.blocks += other.blocks;
        self.bytes += other.bytes;
        self.calls += other.calls;
        self.modeled_s += other.modeled_s;
        self.gpu_interference = self.gpu_interference.max(other.gpu_interference);
    }

    pub fn effective_bandwidth(&self) -> f64 {
        if self.modeled_s == 0.0 {
            0.0
        } else {
            self.bytes as f64 / self.modeled_s
        }
    }
}

pub trait TransferEngine: Send + Sync {
    fn name(&self) -> &'static str;

    /// H2D gather (decode loading): copy the DRAM slots into HBM slots.
    fn load(
        &self,
        dram: &BlockPool,
        hbm: &mut BlockPool,
        pairs: &[(SlotId, SlotId)],
    ) -> TransferStats;

    /// D2H save (prefill/decode KV write-back): scatter the contiguous
    /// `src` tensor into DRAM block slots.
    fn save(&self, src: &[f32], dram: &mut BlockPool, entries: &[ScatterEntry]) -> TransferStats;

    /// Modeled PCIe time to load `n_blocks` blocks of `block_bytes` with
    /// this engine, without moving any bytes. Used by the prefetcher,
    /// whose copies run asynchronously outside the `load` path.
    fn load_time_model(&self, n_blocks: usize, block_bytes: usize) -> f64 {
        if n_blocks == 0 {
            return 0.0;
        }
        self.hw().flash_h2d_time(n_blocks, block_bytes)
    }

    fn hw(&self) -> &HardwareSpec;
}

/// Construct the engine for a config choice.
pub fn engine_for(kind: TransferKind, hw: HardwareSpec) -> Box<dyn TransferEngine> {
    match kind {
        TransferKind::Memcpy => Box::new(MemcpyEngine::new(hw)),
        TransferKind::Flash => Box::new(FlashEngine::new(hw)),
        TransferKind::GpuDirectSave => Box::new(GpuDirectSaveEngine::new(hw)),
    }
}

fn do_copy(dram: &BlockPool, hbm: &mut BlockPool, pairs: &[(SlotId, SlotId)]) -> usize {
    let mut bytes = 0;
    for &(src, dst) in pairs {
        // dram and hbm are distinct pools, so the borrows are disjoint;
        // copy slice-to-slice without a staging allocation (§Perf).
        let data = dram.slot(src);
        hbm.slot_mut(dst).copy_from_slice(data);
        bytes += data.len() * 4;
    }
    bytes
}

fn do_scatter(src: &[f32], dram: &mut BlockPool, entries: &[ScatterEntry]) -> usize {
    let mut bytes = 0;
    for e in entries {
        dram.slot_mut(e.dst_slot)[e.dst_off..e.dst_off + e.len]
            .copy_from_slice(&src[e.src_off..e.src_off + e.len]);
        bytes += e.len * 4;
    }
    bytes
}

// ------------------------------------------------------------- MemcpyEngine

pub struct MemcpyEngine {
    hw: HardwareSpec,
}

impl MemcpyEngine {
    pub fn new(hw: HardwareSpec) -> Self {
        Self { hw }
    }
}

impl TransferEngine for MemcpyEngine {
    fn name(&self) -> &'static str {
        "memcpy"
    }

    fn load(
        &self,
        dram: &BlockPool,
        hbm: &mut BlockPool,
        pairs: &[(SlotId, SlotId)],
    ) -> TransferStats {
        let bytes = do_copy(dram, hbm, pairs);
        TransferStats {
            blocks: pairs.len(),
            bytes,
            calls: pairs.len(),
            modeled_s: self.hw.memcpy_time(pairs.len(), dram.slot_bytes()),
            gpu_interference: 1.0,
        }
    }

    fn save(&self, src: &[f32], dram: &mut BlockPool, entries: &[ScatterEntry]) -> TransferStats {
        let bytes = do_scatter(src, dram, entries);
        // one cudaMemcpy per fragment, each paying the call overhead
        let modeled_s: f64 = entries
            .iter()
            .map(|e| self.hw.memcpy_overhead_s + (e.len * 4) as f64 / self.hw.pcie_peak)
            .sum();
        TransferStats {
            blocks: entries.len(),
            bytes,
            calls: entries.len(),
            modeled_s,
            gpu_interference: 1.0,
        }
    }

    fn load_time_model(&self, n_blocks: usize, block_bytes: usize) -> f64 {
        if n_blocks == 0 {
            return 0.0;
        }
        self.hw.memcpy_time(n_blocks, block_bytes)
    }

    fn hw(&self) -> &HardwareSpec {
        &self.hw
    }
}

// -------------------------------------------------------------- FlashEngine

pub struct FlashEngine {
    hw: HardwareSpec,
    scatter_workers: usize,
}

impl FlashEngine {
    pub fn new(hw: HardwareSpec) -> Self {
        Self { hw, scatter_workers: 2 }
    }
}

/// Raw-pointer wrapper for the disjoint-slot parallel scatter.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}

impl TransferEngine for FlashEngine {
    fn name(&self) -> &'static str {
        "flash"
    }

    /// FlashH2D: one fused UVA gather kernel for the whole burst.
    fn load(
        &self,
        dram: &BlockPool,
        hbm: &mut BlockPool,
        pairs: &[(SlotId, SlotId)],
    ) -> TransferStats {
        let bytes = do_copy(dram, hbm, pairs);
        TransferStats {
            blocks: pairs.len(),
            bytes,
            calls: if pairs.is_empty() { 0 } else { 1 },
            modeled_s: if pairs.is_empty() {
                0.0
            } else {
                self.hw.flash_h2d_time(pairs.len(), dram.slot_bytes())
            },
            gpu_interference: 1.0,
        }
    }

    /// FlashD2H: stage the contiguous tensor with ONE copy (the only part
    /// on the PCIe critical path), then scatter on CPU worker threads.
    fn save(&self, src: &[f32], dram: &mut BlockPool, entries: &[ScatterEntry]) -> TransferStats {
        // (1) contiguous D2H copy into the staging buffer
        let staging: Vec<f32> = src.to_vec();
        let total_bytes = staging.len() * 4;

        // (2) CPU-thread scatter into DRAM blocks (off the critical path).
        // Safety: entries write disjoint (slot, range) destinations — the
        // KV manager builds one entry per (head, block, plane).
        // §Perf: thread spawn costs ~50 µs; below 256 KiB a serial scatter
        // is faster than fanning out (decode saves are ~1-4 KiB).
        debug_assert!(ranges_disjoint(entries));
        const PARALLEL_THRESHOLD_BYTES: usize = 256 << 10;
        if total_bytes < PARALLEL_THRESHOLD_BYTES || self.scatter_workers < 2 {
            do_scatter(&staging, dram, entries);
        } else {
            let n_workers = self.scatter_workers.min(entries.len()).max(1);
            let chunk = entries.len().div_ceil(n_workers);
            std::thread::scope(|s| {
                for ch in entries.chunks(chunk.max(1)) {
                    let ptrs: Vec<(SendPtr, usize, usize, usize)> = ch
                        .iter()
                        .map(|e| (SendPtr(dram.slot_ptr(e.dst_slot)), e.dst_off, e.src_off, e.len))
                        .collect();
                    let staging = &staging;
                    s.spawn(move || {
                        for (ptr, dst_off, src_off, len) in ptrs {
                            unsafe {
                                std::ptr::copy_nonoverlapping(
                                    staging.as_ptr().add(src_off),
                                    ptr.0.add(dst_off),
                                    len,
                                );
                            }
                        }
                    });
                }
            });
        }

        TransferStats {
            blocks: entries.len(),
            bytes: total_bytes,
            calls: 1,
            modeled_s: if entries.is_empty() {
                0.0
            } else {
                self.hw.flash_d2h_time(total_bytes)
            },
            gpu_interference: 1.0,
        }
    }

    fn hw(&self) -> &HardwareSpec {
        &self.hw
    }
}

fn ranges_disjoint(entries: &[ScatterEntry]) -> bool {
    let mut spans: Vec<(u32, usize, usize)> = entries
        .iter()
        .map(|e| (e.dst_slot.0, e.dst_off, e.dst_off + e.len))
        .collect();
    spans.sort_unstable();
    spans.windows(2).all(|w| match w {
        [a, b] => a.0 != b.0 || a.2 <= b.1,
        _ => true,
    })
}

// ---------------------------------------------------- GpuDirectSaveEngine

/// Fig. 14b strawman: fused-gather loading like FlashH2D, but *saving*
/// also runs as a GPU kernel — fast on the wire, slow overall because it
/// contends with model compute for SMs.
pub struct GpuDirectSaveEngine {
    inner: FlashEngine,
}

impl GpuDirectSaveEngine {
    pub fn new(hw: HardwareSpec) -> Self {
        Self { inner: FlashEngine::new(hw) }
    }
}

impl TransferEngine for GpuDirectSaveEngine {
    fn name(&self) -> &'static str {
        "gpu-direct-save"
    }

    fn load(
        &self,
        dram: &BlockPool,
        hbm: &mut BlockPool,
        pairs: &[(SlotId, SlotId)],
    ) -> TransferStats {
        self.inner.load(dram, hbm, pairs)
    }

    fn save(&self, src: &[f32], dram: &mut BlockPool, entries: &[ScatterEntry]) -> TransferStats {
        let hw = self.inner.hw();
        let bytes = do_scatter(src, dram, entries);
        TransferStats {
            blocks: entries.len(),
            bytes,
            calls: 1,
            modeled_s: if entries.is_empty() {
                0.0
            } else {
                hw.kernel_launch_s + bytes as f64 / (hw.pcie_peak * hw.fused_h2d_eff)
            },
            gpu_interference: hw.gpu_save_interference,
        }
    }

    fn hw(&self) -> &HardwareSpec {
        self.inner.hw()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn pools() -> (BlockPool, BlockPool) {
        (BlockPool::new(8, 4, 2), BlockPool::new(4, 4, 2)) // dram, hbm
    }

    fn fill(pool: &mut BlockPool, slot: SlotId, base: f32) {
        let _n = pool.slot_floats();
        pool.slot_mut(slot)
            .iter_mut()
            .enumerate()
            .for_each(|(i, x)| *x = base + i as f32);
    }

    #[test]
    fn load_copies_bytes_exactly_all_engines() {
        for kind in [TransferKind::Memcpy, TransferKind::Flash, TransferKind::GpuDirectSave] {
            let (mut dram, mut hbm) = pools();
            let engine = engine_for(kind, HardwareSpec::a100_40gb());
            let d0 = dram.alloc().unwrap();
            let d1 = dram.alloc().unwrap();
            fill(&mut dram, d0, 100.0);
            fill(&mut dram, d1, 200.0);
            let h0 = hbm.alloc().unwrap();
            let h1 = hbm.alloc().unwrap();
            let stats = engine.load(&dram, &mut hbm, &[(d0, h0), (d1, h1)]);
            assert_eq!(hbm.slot(h0), dram.slot(d0), "{kind:?}");
            assert_eq!(hbm.slot(h1), dram.slot(d1), "{kind:?}");
            assert_eq!(stats.blocks, 2);
            assert_eq!(stats.bytes, 2 * dram.slot_bytes());
            assert!(stats.modeled_s > 0.0);
        }
    }

    #[test]
    fn save_scatters_exactly_all_engines() {
        for kind in [TransferKind::Memcpy, TransferKind::Flash, TransferKind::GpuDirectSave] {
            let (mut dram, _) = pools();
            let engine = engine_for(kind, HardwareSpec::a100_40gb());
            let s0 = dram.alloc().unwrap();
            let s1 = dram.alloc().unwrap();
            let src: Vec<f32> = (0..16).map(|i| i as f32).collect();
            let entries = [
                ScatterEntry { src_off: 0, len: 8, dst_slot: s0, dst_off: 0 },
                ScatterEntry { src_off: 8, len: 4, dst_slot: s1, dst_off: 4 },
            ];
            engine.save(&src, &mut dram, &entries);
            assert_eq!(&dram.slot(s0)[..8], &src[..8], "{kind:?}");
            assert_eq!(&dram.slot(s1)[4..8], &src[8..12], "{kind:?}");
        }
    }

    #[test]
    fn fused_load_is_one_call_memcpy_is_n() {
        // paper-scale: 16 KB blocks (32 tok x 128 dim), a decode burst of 64
        let mut dram = BlockPool::new(64, 32, 128);
        let mut hbm = BlockPool::new(64, 32, 128);
        let pairs: Vec<_> = (0..64)
            .map(|_| (dram.alloc().unwrap(), hbm.alloc().unwrap()))
            .collect();
        let hw = HardwareSpec::a100_40gb();
        let m = MemcpyEngine::new(hw.clone()).load(&dram, &mut hbm, &pairs);
        let f = FlashEngine::new(hw).load(&dram, &mut hbm, &pairs);
        assert_eq!(m.calls, 64);
        assert_eq!(f.calls, 1);
        assert!(f.modeled_s < m.modeled_s, "fused must be faster at scale");
    }

    #[test]
    fn flash_save_critical_path_beats_memcpy_save() {
        let hw = HardwareSpec::a100_40gb();
        let mut dram = BlockPool::new(64, 32, 128); // paper-scale 16KB K-plane blocks
        let slots: Vec<SlotId> = (0..32).map(|_| dram.alloc().unwrap()).collect();
        let src = vec![0.5f32; 32 * dram.slot_floats()];
        let entries: Vec<ScatterEntry> = slots
            .iter()
            .enumerate()
            .map(|(i, &s)| ScatterEntry {
                src_off: i * dram.slot_floats(),
                len: dram.slot_floats(),
                dst_slot: s,
                dst_off: 0,
            })
            .collect();
        let m = MemcpyEngine::new(hw.clone()).save(&src, &mut dram, &entries);
        let f = FlashEngine::new(hw.clone()).save(&src, &mut dram, &entries);
        let g = GpuDirectSaveEngine::new(hw).save(&src, &mut dram, &entries);
        assert!(f.modeled_s < m.modeled_s);
        assert_eq!(f.gpu_interference, 1.0);
        assert!(g.gpu_interference > 1.2, "gpu-direct save must interfere");
    }

    #[test]
    fn stats_merge_accumulates() {
        let mut a = TransferStats {
            blocks: 1, bytes: 10, calls: 1, modeled_s: 0.5, gpu_interference: 1.0,
        };
        let b = TransferStats {
            blocks: 2, bytes: 20, calls: 1, modeled_s: 0.25, gpu_interference: 1.28,
        };
        a.merge(&b);
        assert_eq!(a.blocks, 3);
        assert_eq!(a.bytes, 30);
        assert_eq!(a.modeled_s, 0.75);
        assert_eq!(a.gpu_interference, 1.28);
    }

    #[test]
    fn empty_bursts_are_free() {
        let (dram, mut hbm) = pools();
        let e = FlashEngine::new(HardwareSpec::a100_40gb());
        let stats = e.load(&dram, &mut hbm, &[]);
        assert_eq!(stats.modeled_s, 0.0);
        assert_eq!(stats.calls, 0);
    }
}
