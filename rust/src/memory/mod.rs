//! Hierarchical HBM-DRAM KV cache substrate (paper §3.1-§3.2).
//!
//! - [`pool`]: fixed-size block arenas standing in for HBM and DRAM
//!   (PagedAttention-style allocation; DESIGN.md substitution table)
//! - [`cache`]: LRU residency cache of DRAM blocks in the HBM pool
//! - [`transfer`]: the paper's transfer engines — per-block memcpy
//!   baseline, FlashH2D (GPU-direct fused gather), FlashD2H
//!   (CPU-assisted save), GPU-direct save — real copies plus the
//!   calibrated PCIe cost model
//! - [`metadata`]: per-block cuboid metadata (ArkVale default)
//! - [`manager`]: the KV cache manager tying it together per request

// Serving-path no-panic discipline (satellite of sparselint's
// `no-panic` pass): unwrap/expect in this module tree is a clippy
// warning, denied under CI's `-D warnings`. The few justified
// sites carry fn-level allows next to their sparselint comments.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod cache;
pub mod manager;
pub mod metadata;
pub mod pool;
pub mod prefetch;
pub mod prefix;
pub mod staging_policy;
pub mod transfer;

pub use cache::LruCache;
pub use manager::{KvManager, ReqId};
pub use metadata::Cuboid;
pub use pool::{BlockPool, SlotId};
pub use prefetch::{PrefetchEngine, PrefetchStats};
pub use prefix::{block_hashes, AcquiredPath, PrefixIndex, PREFIX_NS};
pub use staging_policy::{StageAdmission, StagingPolicy};
pub use transfer::{engine_for, TransferEngine, TransferStats};

/// Typed memory-tier exhaustion. Replaces the old `expect("DRAM
/// exhausted")` panics: oversubscription now surfaces to the engine,
/// which evicts the offending request with a `ServeError::Evicted`
/// instead of crashing the server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryError {
    /// The DRAM pool ran out of block slots while storing `req`'s KV.
    DramExhausted { req: ReqId },
    /// HBM is full of pinned blocks (a single gather's working set
    /// exceeds the cache — the batch-control invariant was violated).
    HbmExhausted { req: ReqId },
    /// An append referenced a request id with no registered KV state
    /// (stale id after release/eviction). A driver-level bug surfaced
    /// as a typed error: the engine evicts the phantom instead of
    /// panicking mid-batch.
    Unregistered { req: ReqId },
}

impl MemoryError {
    /// The request whose allocation hit the wall (the eviction victim).
    pub fn req(&self) -> ReqId {
        match self {
            MemoryError::DramExhausted { req }
            | MemoryError::HbmExhausted { req }
            | MemoryError::Unregistered { req } => *req,
        }
    }
}

impl std::fmt::Display for MemoryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemoryError::DramExhausted { req } => {
                write!(f, "DRAM exhausted storing KV for request {req}")
            }
            MemoryError::HbmExhausted { req } => write!(
                f,
                "HBM exhausted with everything pinned gathering request {req} \
                 (working set exceeds HBM)"
            ),
            MemoryError::Unregistered { req } => {
                write!(f, "KV append for unregistered request {req}")
            }
        }
    }
}

impl std::error::Error for MemoryError {}

/// Identifies one logical KV block: (request, layer, kv-head, block index).
/// DSAs select and transfer at this granularity (per-head blocks,
/// paper §3.2: "(H, N, D) layout ... selected at the head level").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockKey {
    pub req: u32,
    pub layer: u16,
    pub head: u16,
    pub block: u32,
}

impl BlockKey {
    pub fn new(req: u32, layer: u16, head: u16, block: u32) -> Self {
        Self { req, layer, head, block }
    }
}
