//! Hierarchical HBM-DRAM KV cache substrate (paper §3.1-§3.2).
//!
//! - [`pool`]: fixed-size block arenas standing in for HBM and DRAM
//!   (PagedAttention-style allocation; DESIGN.md substitution table)
//! - [`cache`]: LRU residency cache of DRAM blocks in the HBM pool
//! - [`transfer`]: the paper's transfer engines — per-block memcpy
//!   baseline, FlashH2D (GPU-direct fused gather), FlashD2H
//!   (CPU-assisted save), GPU-direct save — real copies plus the
//!   calibrated PCIe cost model
//! - [`metadata`]: per-block cuboid metadata (ArkVale default)
//! - [`manager`]: the KV cache manager tying it together per request

pub mod cache;
pub mod manager;
pub mod metadata;
pub mod pool;
pub mod transfer;

pub use cache::LruCache;
pub use manager::{KvManager, ReqId};
pub use metadata::Cuboid;
pub use pool::{BlockPool, SlotId};
pub use transfer::{engine_for, TransferEngine, TransferStats};

/// Identifies one logical KV block: (request, layer, kv-head, block index).
/// DSAs select and transfer at this granularity (per-head blocks,
/// paper §3.2: "(H, N, D) layout ... selected at the head level").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockKey {
    pub req: u32,
    pub layer: u16,
    pub head: u16,
    pub block: u32,
}

impl BlockKey {
    pub fn new(req: u32, layer: u16, head: u16, block: u32) -> Self {
        Self { req, layer, head, block }
    }
}
