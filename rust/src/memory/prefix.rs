//! Cross-request KV prefix sharing: a radix (longest-common-prefix)
//! index over block-aligned prompt hashes, with per-node refcounts.
//!
//! Each node is one *sealed, block-aligned* prompt block, keyed by
//! `(parent node, chained block hash)` — the chain folds the parent's
//! hash into every block hash, so a node id identifies the full prefix
//! *content* up to and including its block, not just the block itself.
//! Admission consults [`PrefixIndex::lookup`] (read-only, alloc-free —
//! the serving hot path), then [`PrefixIndex::acquire_path`] publishes
//! the request's own block-aligned prefix and takes a reference on
//! every node along the path.
//!
//! Ownership invariant (see DESIGN.md "Prefix sharing"):
//!
//! - a node's `refs` counts **live sharers** — admitted requests whose
//!   acquired path passes through it;
//! - `refs == 0` nodes are *cached*: their KV stays resident in the
//!   DRAM tier so the next conversation turn re-enters warm, but they
//!   are evictable (leaf-first LRU, [`PrefixIndex::evict_unreferenced`])
//!   whenever admission needs the bytes back;
//! - a node with `refs > 0` is never evicted — that is the "shared
//!   block evictable only when the last reference drops" rule;
//! - the open (partially filled) tail block is **never** published:
//!   paths cover whole blocks only, so every write lands in private
//!   blocks (copy-on-write at the open tail by construction; the
//!   `KvManager` additionally COWs adopted open tails defensively).
//!
//! Refcount conservation (checked by sparselint's pin-conservation
//! pass over this file): every `acquire_path` is balanced by exactly
//! one `release_path` (finish/cancel/migrate), and eviction only ever
//! removes zero-ref nodes.

use std::collections::HashMap;

/// Namespace bit for shared-prefix residency keys: cache entries for a
/// shared prefix block are keyed under `PREFIX_NS | chain id` instead
/// of the sharer's request id, so one sharer's stage or demand load is
/// every sharer's hit and the entry survives any individual sharer's
/// release. Real request ids stay below this bit (u32 ids assigned
/// sequentially); the namespace cannot collide with a live request.
pub const PREFIX_NS: u32 = 0x8000_0000;

const NO_PARENT: u32 = u32::MAX;

#[derive(Debug, Clone)]
struct Node {
    parent: u32,
    hash: u64,
    /// Live sharers whose acquired path includes this node.
    refs: u32,
    /// Child nodes (eviction is leaf-first).
    children: u32,
    /// LRU recency for cached (zero-ref) eviction.
    tick: u64,
}

/// Result of publishing one request's prefix path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AcquiredPath {
    /// Deepest node of the path (the request's chain id); `NO_PARENT`
    /// sentinel never escapes — an empty path returns `None` upstream.
    pub tail: u32,
    /// Blocks that already existed (the longest-common-prefix match).
    pub matched_blocks: usize,
    /// Blocks newly created (the request's published suffix).
    pub new_blocks: usize,
}

/// Radix/LCP index over block-aligned prompt hashes with per-node
/// refcounts. Owns no KV bytes itself — it is the *naming* layer: the
/// scheduler charges `blocks * per-block KV bytes` for resident nodes
/// and the backends key shared HBM residency by chain id.
#[derive(Debug, Default)]
pub struct PrefixIndex {
    nodes: Vec<Option<Node>>,
    /// `(parent, chained hash) -> node`.
    map: HashMap<(u32, u64), u32>,
    free: Vec<u32>,
    tick: u64,
    /// Resident nodes (live + cached).
    n_nodes: usize,
    /// Nodes with `refs > 0`.
    n_live: usize,
}

/// Chain-hash a prompt into per-block prefix hashes: `out[i]` digests
/// tokens `[0, (i+1) * block)` (FNV-1a folded over the previous block's
/// hash). Only whole blocks are hashed — the partial tail is private.
pub fn block_hashes(tokens: &[i32], block: usize, out: &mut Vec<u64>) {
    out.clear();
    if block == 0 {
        return;
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut in_block = 0usize;
    for &t in tokens {
        for b in (t as u32).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        in_block += 1;
        if in_block == block {
            out.push(h);
            in_block = 0;
        }
    }
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resident prefix blocks (live + cached) — each occupies one
    /// DRAM-tier block column in the scheduler's accounting.
    pub fn total_blocks(&self) -> usize {
        self.n_nodes
    }

    /// Blocks referenced by at least one live sharer (not evictable).
    pub fn live_blocks(&self) -> usize {
        self.n_live
    }

    /// Cached blocks reclaimable on demand.
    pub fn evictable_blocks(&self) -> usize {
        self.n_nodes - self.n_live
    }

    /// Reference count of one node (tests / conservation checks).
    pub fn node_refs(&self, id: u32) -> u32 {
        self.nodes
            .get(id as usize)
            .and_then(|n| n.as_ref())
            .map(|n| n.refs)
            .unwrap_or(0)
    }

    /// Longest-common-prefix match: how many leading block hashes are
    /// already resident. Read-only; the admission fast path.
    // sparselint: hot
    pub fn lookup(&self, hashes: &[u64]) -> usize {
        let mut parent = NO_PARENT;
        let mut matched = 0usize;
        for &h in hashes {
            match self.map.get(&(parent, h)) {
                Some(&id) => {
                    parent = id;
                    matched += 1;
                }
                None => break,
            }
        }
        matched
    }

    /// Publish a request's block-aligned prefix: walk the chain,
    /// creating nodes for the unmatched suffix, and take one reference
    /// on every node along the path. Balanced by [`Self::release_path`].
    pub fn acquire_path(&mut self, hashes: &[u64]) -> Option<AcquiredPath> {
        if hashes.is_empty() {
            return None;
        }
        self.tick += 1;
        let mut parent = NO_PARENT;
        let mut created = 0usize;
        for &h in hashes {
            let id = match self.map.get(&(parent, h)) {
                Some(&id) => id,
                None => {
                    let id = self.alloc_node(Node {
                        parent,
                        hash: h,
                        refs: 0,
                        children: 0,
                        tick: self.tick,
                    });
                    self.map.insert((parent, h), id);
                    if parent != NO_PARENT {
                        if let Some(p) = self.nodes[parent as usize].as_mut() {
                            p.children += 1;
                        }
                    }
                    self.n_nodes += 1;
                    created += 1;
                    id
                }
            };
            if let Some(n) = self.nodes[id as usize].as_mut() {
                if n.refs == 0 {
                    self.n_live += 1;
                }
                n.refs += 1;
                n.tick = self.tick;
            }
            parent = id;
        }
        let matched_blocks = hashes.len() - created;
        Some(AcquiredPath { tail: parent, matched_blocks, new_blocks: created })
    }

    /// Drop one sharer's references along the chain ending at `tail`
    /// (walks parent links). Nodes stay resident as cached entries —
    /// eviction reclaims them only under admission pressure.
    pub fn release_path(&mut self, tail: u32) {
        let mut cur = tail;
        while cur != NO_PARENT {
            let Some(n) = self.nodes.get_mut(cur as usize).and_then(|n| n.as_mut()) else {
                debug_assert!(false, "release_path hit a freed node {cur}");
                return;
            };
            debug_assert!(n.refs > 0, "release of unreferenced prefix node {cur}");
            n.refs = n.refs.saturating_sub(1);
            if n.refs == 0 {
                self.n_live -= 1;
            }
            cur = n.parent;
        }
    }

    /// Undo the node creation of a just-released [`Self::acquire_path`]:
    /// remove up to `created` zero-ref, childless nodes walking up from
    /// `tail`. Used when admission acquires a path and then fails the
    /// capacity check — the newly published suffix has no KV behind it
    /// and must not linger as a phantom match. Returns nodes removed
    /// (stops early at a node another request still references or has
    /// extended past).
    pub fn rollback_path(&mut self, tail: u32, created: usize) -> usize {
        let mut cur = tail;
        let mut removed = 0usize;
        while removed < created && cur != NO_PARENT {
            let Some(n) = self.nodes.get(cur as usize).and_then(|n| n.as_ref()) else {
                break;
            };
            if n.refs != 0 || n.children != 0 {
                break;
            }
            let (parent, hash) = (n.parent, n.hash);
            self.nodes[cur as usize] = None;
            self.map.remove(&(parent, hash));
            if parent != NO_PARENT {
                if let Some(p) = self.nodes[parent as usize].as_mut() {
                    p.children -= 1;
                }
            }
            self.free.push(cur);
            self.n_nodes -= 1;
            removed += 1;
            cur = parent;
        }
        removed
    }

    /// Depth (blocks) of the chain ending at `tail`.
    pub fn path_blocks(&self, tail: u32) -> usize {
        let mut cur = tail;
        let mut depth = 0usize;
        while cur != NO_PARENT {
            let Some(n) = self.nodes.get(cur as usize).and_then(|n| n.as_ref()) else {
                break;
            };
            depth += 1;
            cur = n.parent;
        }
        depth
    }

    /// Evict up to `max_blocks` zero-ref nodes, leaf-first in LRU
    /// order. Returns blocks actually reclaimed. A zero-ref node's
    /// whole subtree is zero-ref (a parent carries every reference its
    /// children do), so repeated leaf eviction drains entire cached
    /// chains.
    pub fn evict_unreferenced(&mut self, max_blocks: usize) -> usize {
        let mut evicted = 0usize;
        while evicted < max_blocks {
            let mut victim: Option<(u64, u32)> = None;
            for (i, slot) in self.nodes.iter().enumerate() {
                if let Some(n) = slot {
                    if n.refs == 0 && n.children == 0 {
                        let cand = (n.tick, i as u32);
                        if victim.map(|v| cand < v).unwrap_or(true) {
                            victim = Some(cand);
                        }
                    }
                }
            }
            let Some((_, id)) = victim else { break };
            let Some(n) = self.nodes[id as usize].take() else { break };
            self.map.remove(&(n.parent, n.hash));
            if n.parent != NO_PARENT {
                if let Some(p) = self.nodes[n.parent as usize].as_mut() {
                    p.children -= 1;
                }
            }
            self.free.push(id);
            self.n_nodes -= 1;
            evicted += 1;
        }
        evicted
    }

    fn alloc_node(&mut self, node: Node) -> u32 {
        match self.free.pop() {
            Some(id) => {
                self.nodes[id as usize] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                (self.nodes.len() - 1) as u32
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn hashes(tokens: &[i32], block: usize) -> Vec<u64> {
        let mut out = Vec::new();
        block_hashes(tokens, block, &mut out);
        out
    }

    #[test]
    fn block_hashes_cover_whole_blocks_only() {
        let t: Vec<i32> = (0..10).collect();
        assert_eq!(hashes(&t, 4).len(), 2, "partial tail block is private");
        assert_eq!(hashes(&t, 16).len(), 0);
        // chained: a different first block changes every later hash
        let mut t2 = t.clone();
        t2[0] = 99;
        let (a, b) = (hashes(&t, 4), hashes(&t2, 4));
        assert_ne!(a[0], b[0]);
        assert_ne!(a[1], b[1], "chain must fold the past in");
        // identical prefixes hash identically
        assert_eq!(a, hashes(&t, 4));
    }

    #[test]
    fn lookup_matches_longest_common_prefix() {
        let mut ix = PrefixIndex::new();
        let sys: Vec<i32> = (0..16).collect();
        let a = ix.acquire_path(&hashes(&sys, 4)).unwrap();
        assert_eq!(a.matched_blocks, 0);
        assert_eq!(a.new_blocks, 4);
        // same prompt: full match
        assert_eq!(ix.lookup(&hashes(&sys, 4)), 4);
        // shared first 8 tokens, divergent tail: LCP = 2 blocks
        let mut other = sys.clone();
        other[9] = -1;
        assert_eq!(ix.lookup(&hashes(&other, 4)), 2);
        // disjoint prompt: no match
        let cold: Vec<i32> = (100..116).collect();
        assert_eq!(ix.lookup(&hashes(&cold, 4)), 0);
    }

    #[test]
    fn refcount_equals_live_sharers_across_interleavings() {
        let mut ix = PrefixIndex::new();
        let sys: Vec<i32> = (0..16).collect();
        let h = hashes(&sys, 4);
        let a = ix.acquire_path(&h).unwrap();
        let b = ix.acquire_path(&h).unwrap();
        assert_eq!(a.tail, b.tail, "identical prefixes share the chain");
        assert_eq!(b.matched_blocks, 4);
        assert_eq!(b.new_blocks, 0);
        assert_eq!(ix.node_refs(a.tail), 2);
        assert_eq!(ix.live_blocks(), 4);
        // a third sharer with a longer prompt extends the chain
        let mut long = sys.clone();
        long.extend(16..24);
        let c = ix.acquire_path(&hashes(&long, 4)).unwrap();
        assert_eq!(c.matched_blocks, 4);
        assert_eq!(c.new_blocks, 2);
        assert_eq!(ix.node_refs(a.tail), 3, "shared part carries all sharers");
        assert_eq!(ix.node_refs(c.tail), 1);
        // releases drop exactly one sharer each; nodes become cached
        ix.release_path(a.tail);
        ix.release_path(b.tail);
        assert_eq!(ix.node_refs(a.tail), 1, "c still passes through");
        ix.release_path(c.tail);
        assert_eq!(ix.node_refs(a.tail), 0);
        assert_eq!(ix.live_blocks(), 0);
        assert_eq!(ix.total_blocks(), 6, "cached chains stay resident");
        assert_eq!(ix.evictable_blocks(), 6);
    }

    #[test]
    fn eviction_is_leaf_first_and_never_touches_live_nodes() {
        let mut ix = PrefixIndex::new();
        let sys: Vec<i32> = (0..16).collect();
        let h = hashes(&sys, 4);
        let a = ix.acquire_path(&h).unwrap();
        // a live chain cannot be evicted at all
        assert_eq!(ix.evict_unreferenced(100), 0);
        ix.release_path(a.tail);
        // partial eviction removes leaves only; the surviving ancestor
        // chain still matches shorter prefixes
        assert_eq!(ix.evict_unreferenced(2), 2);
        assert_eq!(ix.total_blocks(), 2);
        assert_eq!(ix.lookup(&h), 2);
        // re-entry re-acquires the cached stem and republishes the rest
        let again = ix.acquire_path(&h).unwrap();
        assert_eq!(again.matched_blocks, 2);
        assert_eq!(again.new_blocks, 2);
        ix.release_path(again.tail);
        assert_eq!(ix.evict_unreferenced(100), 4);
        assert_eq!(ix.total_blocks(), 0);
    }

    #[test]
    fn cached_reentry_counts_as_match() {
        // the multi-turn conversation pattern: finish, then re-enter
        // with the same history — the cached chain must be a warm hit
        let mut ix = PrefixIndex::new();
        let turn1: Vec<i32> = (0..32).collect();
        let h1 = hashes(&turn1, 4);
        let p1 = ix.acquire_path(&h1).unwrap();
        ix.release_path(p1.tail);
        let mut turn2 = turn1.clone();
        turn2.extend(32..48);
        let p2 = ix.acquire_path(&hashes(&turn2, 4)).unwrap();
        assert_eq!(p2.matched_blocks, 8, "warm history must match in full");
        assert_eq!(p2.new_blocks, 4);
        assert_eq!(ix.node_refs(p1.tail), 1, "turn 2 revives the cached chain");
    }

    #[test]
    fn rollback_path_undoes_a_failed_admissions_publication() {
        let mut ix = PrefixIndex::new();
        let sys: Vec<i32> = (0..8).collect();
        let h = hashes(&sys, 4);
        let a = ix.acquire_path(&h).unwrap();
        // extend with a new suffix, then roll the extension back
        let mut long = sys.clone();
        long.extend(8..16);
        let b = ix.acquire_path(&hashes(&long, 4)).unwrap();
        assert_eq!(b.new_blocks, 2);
        ix.release_path(b.tail);
        assert_eq!(ix.rollback_path(b.tail, b.new_blocks), 2);
        assert_eq!(ix.total_blocks(), 2, "only the original chain remains");
        assert_eq!(ix.node_refs(a.tail), 1, "sharer a is untouched");
        // rollback stops at a node someone else references
        ix.release_path(a.tail);
        let c = ix.acquire_path(&h).unwrap();
        ix.release_path(c.tail);
        assert_eq!(c.new_blocks, 0);
        assert_eq!(ix.rollback_path(c.tail, 0), 0, "nothing was created");
    }

    #[test]
    fn path_blocks_walks_the_chain() {
        let mut ix = PrefixIndex::new();
        let t: Vec<i32> = (0..20).collect();
        let p = ix.acquire_path(&hashes(&t, 4)).unwrap();
        assert_eq!(ix.path_blocks(p.tail), 5);
    }
}
