//! The KV cache manager (paper §3.1, right box of Fig. 3).
//!
//! Owns the hierarchical HBM/DRAM block storage for every live request:
//!
//! - **save path**: newly generated KV (the contiguous projection output)
//!   is scattered into per-head DRAM blocks through the configured
//!   transfer engine (FlashD2H by default); blocks that fill up are
//!   *sealed* and get cuboid metadata.
//! - **load path**: before sparse attention, the blocks the DSA selected
//!   are gathered into the attention staging tensor. With offloading,
//!   misses are fetched DRAM -> HBM through the engine (FlashH2D) and
//!   tracked in the LRU residency cache; hits cost nothing on PCIe.
//! - the *open* (partially filled) block is always gathered directly —
//!   it was just written by the model and is still device-resident.
//!
//! The gather layout mirrors `python/compile/pipeline.py::gather_blocks`
//! exactly (slot order, open-block-last, additive masks) so greedy decode
//! is bit-identical to the python goldens.

use std::collections::HashMap;

use crate::config::ModelSpec;

use super::cache::LruCache;
use super::metadata::Cuboid;
use super::pool::{BlockPool, SlotId};
use super::prefetch::{PrefetchEngine, PrefetchStats, SendConst, SendMut};
use super::prefix::PREFIX_NS;
use super::staging_policy::{stage_block, StageAdmission, StagingPolicy};
use super::transfer::{ScatterEntry, TransferEngine, TransferStats};
use super::{BlockKey, MemoryError};

pub type ReqId = u32;

pub const NEG_INF: f32 = -1e30;

/// Copy workers for asynchronous prefetch staging (FlashH2D runs on its
/// own stream; here, on its own threads).
const PREFETCH_COPY_WORKERS: usize = 2;

/// Per-request block state. During a decode step layers are appended in
/// order, so per-layer token counts may transiently differ by one; every
/// query below is therefore layer-indexed.
struct RequestKv {
    /// Completed tokens (all layers stored).
    len: usize,
    /// Tokens stored per layer.
    layer_len: Vec<usize>,
    /// `[layer][head][block] -> DRAM slot`.
    blocks: Vec<Vec<Vec<SlotId>>>,
    /// Cuboid metadata for sealed blocks: `[layer][head][block]`.
    meta: Vec<Vec<Vec<Cuboid>>>,
}

/// Per-iteration transfer accounting (Fig. 1 right axis, Fig. 15).
#[derive(Debug, Clone, Copy, Default)]
pub struct IterStats {
    /// Blocks loaded on demand from DRAM (cache misses) this iteration.
    pub blocks_loaded: usize,
    pub load: TransferStats,
    pub save: TransferStats,
    /// Blocks staged ahead of need (overlapped with compute).
    pub prefetch_blocks: usize,
    /// Modeled PCIe time of the staged bytes.
    pub prefetch: TransferStats,
    /// Staged blocks consumed by a gather this iteration.
    pub prefetch_hits: usize,
    /// Staged blocks this iteration never touched.
    pub prefetch_wasted: usize,
    /// Blocks staged for the NEXT iteration (cross-iteration hints),
    /// issued under this iteration's compute.
    pub prefetch_deferred: usize,
}

/// Undo log of one step transaction ([`KvManager::begin_txn`]): enough
/// state to put every batch participant's KV back exactly where it was
/// if the step has to roll back mid-batch.
#[derive(Default)]
struct TxnLog {
    /// Pre-transaction (len, per-layer lens) of every request mutated so
    /// far, captured lazily on first touch.
    touched: HashMap<ReqId, (usize, Vec<usize>)>,
    /// Residency-cache entries inserted by gathers during the
    /// transaction (stage inserts are NOT logged — staged blocks are
    /// pre-existing sealed blocks that survive a rollback and feed the
    /// retry).
    cache_inserts: Vec<BlockKey>,
    /// Copy-on-write journal: `(req, layer, head, block, old_slot,
    /// fresh_slot)` for every shared tail block this transaction
    /// privatized before writing. Commit derefs the old (shared) slot;
    /// rollback restores it in the block table and frees the fresh copy
    /// — so refcounts and block tables are byte-identical to the
    /// pre-step state after a mid-batch rollback.
    cow: Vec<(ReqId, usize, usize, usize, SlotId, SlotId)>,
}

/// Recycled gather/scatter plan buffers: the save path's contiguous
/// source + scatter entries and the load path's miss plan are rebuilt
/// every layer of every step, so they are taken from (and returned to)
/// these slots instead of being reallocated — the decode hot loop
/// allocates nothing once they are warm.
#[derive(Default)]
struct KvScratch {
    src: Vec<f32>,
    entries: Vec<ScatterEntry>,
    to_load: Vec<(SlotId, SlotId)>,
    miss_keys: Vec<BlockKey>,
}

/// A request's serialized DRAM-tier KV state — the real-backend
/// migration seam. [`KvManager::drain_request`] copies every stored
/// block's plane out of the source pools (then frees them);
/// [`KvManager::import_request`] re-allocates and fills pool slots on
/// the target. The cluster prices [`DrainedKv::total_bytes`] as
/// FlashD2H at the source plus FlashH2D at the target.
pub struct DrainedKv {
    pub req: ReqId,
    len: usize,
    layer_len: Vec<usize>,
    /// `[layer][head][block]` -> the block's full K+V plane floats.
    planes: Vec<Vec<Vec<Vec<f32>>>>,
    /// Sealed-block cuboid metadata, moved wholesale (rebuilding it on
    /// the target would re-read every K plane for nothing).
    meta: Vec<Vec<Vec<Cuboid>>>,
    block_bytes: usize,
}

impl DrainedKv {
    /// Completed tokens at drain time.
    pub fn seq_len(&self) -> usize {
        self.len
    }

    /// DRAM-tier bytes on the wire (every stored block, all layers).
    pub fn total_bytes(&self) -> usize {
        self.n_blocks() * self.block_bytes
    }

    fn n_blocks(&self) -> usize {
        self.planes
            .iter()
            .map(|l| l.iter().map(|h| h.len()).sum::<usize>())
            .sum()
    }
}

pub struct KvManager {
    spec: ModelSpec,
    /// Offloading on: DRAM is home, HBM is an LRU cache.
    /// Off: blocks count against HBM capacity directly (vLLM mode).
    offload: bool,
    dram: BlockPool,
    hbm: BlockPool,
    cache: LruCache<SlotId>,
    engine: Box<dyn TransferEngine>,
    requests: HashMap<ReqId, RequestKv>,
    /// Refcounts of ever-shared DRAM block slots ([`Self::adopt_prefix`]).
    /// ABSENT means exclusive-from-birth (the common case — freeing is
    /// unconditional); PRESENT means the slot appeared in more than one
    /// request's block table at some point, and the count is the number
    /// of tables currently holding it. Every free site routes through
    /// [`Self::free_dram_slot`], which frees the slot (and drops its
    /// shared HBM residency) only when the count reaches zero.
    slot_refs: HashMap<SlotId, u32>,
    iter: IterStats,
    pinned: Vec<BlockKey>,
    prefetch: PrefetchEngine,
    /// Open step transaction, if any (see [`Self::begin_txn`]).
    txn: Option<TxnLog>,
    /// Recycled plan-builder buffers (see [`KvScratch`]).
    scratch: KvScratch,
}

impl KvManager {
    pub fn new(
        spec: ModelSpec,
        hbm_kv_bytes: usize,
        dram_bytes: usize,
        offload: bool,
        engine: Box<dyn TransferEngine>,
    ) -> Self {
        let bs = spec.block_size;
        let dh = spec.head_dim;
        let hbm = BlockPool::with_capacity_bytes(hbm_kv_bytes, bs, dh);
        let dram = BlockPool::with_capacity_bytes(dram_bytes, bs, dh);
        let cache = LruCache::new(hbm.n_slots().max(1));
        Self {
            spec,
            offload,
            dram,
            hbm,
            cache,
            engine,
            requests: HashMap::new(),
            slot_refs: HashMap::new(),
            iter: IterStats::default(),
            pinned: Vec::new(),
            prefetch: PrefetchEngine::new(PREFETCH_COPY_WORKERS),
            txn: None,
            scratch: KvScratch::default(),
        }
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn offload(&self) -> bool {
        self.offload
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    // ------------------------------------------------------------ lifecycle

    pub fn register(&mut self, req: ReqId) {
        let l = self.spec.n_layers;
        let h = self.spec.n_kv_heads;
        self.requests.insert(
            req,
            RequestKv {
                len: 0,
                layer_len: vec![0; l],
                blocks: vec![vec![Vec::new(); h]; l],
                meta: vec![vec![Vec::new(); h]; l],
            },
        );
    }

    pub fn release(&mut self, req: ReqId) {
        // land in-flight staging copies before freeing their source
        // (DRAM) and destination (HBM) slots
        self.prefetch.wait_staged();
        for key in self.prefetch.cancel_request(req) {
            self.cache.unpin(&key);
        }
        // a request released mid-transaction has nothing left to undo
        if let Some(txn) = &mut self.txn {
            txn.touched.remove(&req);
        }
        if let Some(r) = self.requests.remove(&req) {
            for layer in r.blocks {
                for head in layer {
                    for slot in head {
                        // refcounted: a slot shared with a live sharer
                        // stays allocated (and HBM-resident) for them
                        self.free_dram_slot(slot);
                    }
                }
            }
        }
        for slot in self.cache.remove_request(req) {
            self.hbm.free(slot);
        }
    }

    pub fn is_registered(&self, req: ReqId) -> bool {
        self.requests.contains_key(&req)
    }

    // ------------------------------------------------- shared block refs

    /// Canonical residency key of an ever-shared DRAM slot: HBM entries
    /// for shared blocks are keyed by the slot itself under the
    /// [`PREFIX_NS`] namespace instead of any one sharer's request id,
    /// so one sharer's demand load or stage is every sharer's hit and
    /// the entry outlives any individual sharer's release.
    fn shared_key(slot: SlotId) -> BlockKey {
        BlockKey::new(PREFIX_NS, 0, 0, slot.0)
    }

    /// Take one additional ownership reference on a DRAM slot that is
    /// entering a second (or later) request's block table. An
    /// exclusive-from-birth slot implicitly holds one reference; the
    /// first retain materializes the map entry at 2 (creator + adopter).
    /// Balanced by [`Self::free_dram_slot`] at every table-removal site.
    fn retain_slot(&mut self, slot: SlotId) {
        *self.slot_refs.entry(slot).or_insert(1) += 1;
    }

    /// Drop one ownership reference and free the slot when the last
    /// reference goes: the single funnel every DRAM free routes through
    /// (release / drain / rollback / COW commit). On the final free of
    /// an ever-shared slot its shared HBM residency is torn down too —
    /// the stage is cancelled (stage pin returned), the cache entry
    /// removed and the HBM slot freed. Never called twice for one
    /// table-removal: refcount conservation is `map count == number of
    /// block tables holding the slot`.
    fn free_dram_slot(&mut self, slot: SlotId) {
        match self.slot_refs.get_mut(&slot) {
            None => self.dram.free(slot),
            Some(c) => {
                *c -= 1;
                if *c == 0 {
                    self.slot_refs.remove(&slot);
                    self.dram.free(slot);
                    let skey = Self::shared_key(slot);
                    if self.prefetch.cancel_key(&skey) {
                        self.cache.unpin(&skey);
                    }
                    if let Some(hs) = self.cache.remove(&skey) {
                        self.hbm.free(hs);
                    }
                }
            }
        }
    }

    /// Current ownership references on a slot (1 for exclusive slots).
    /// Test/diagnostic accessor for refcount-conservation checks.
    pub fn slot_ref_count(&self, slot: SlotId) -> u32 {
        self.slot_refs.get(&slot).copied().unwrap_or(1)
    }

    /// Number of ever-shared slots currently tracked.
    pub fn n_shared_slots(&self) -> usize {
        self.slot_refs.len()
    }

    /// Adopt the first `n_tokens` of `src`'s stored KV into the freshly
    /// registered, still-empty request `dst` by SHARING the underlying
    /// DRAM slots (refcounted) instead of copying — the cross-request
    /// prefix-sharing seam. Covers every layer and head, including a
    /// partially filled tail block when `n_tokens` is not block-aligned:
    /// writes into that open tail (by either sharer) privatize it first
    /// via copy-on-write ([`Self::cow_unshare_tail`]); fully sealed
    /// shared blocks are immutable by construction, since appends only
    /// ever extend past them. Sealed-block cuboid metadata is copied
    /// (cheap, per-block min/max corners). Journaled through the open
    /// step transaction when one is active, so a rollback returns every
    /// refcount exactly.
    ///
    /// Errors: `Unregistered{dst}` when `dst` is unknown or non-empty,
    /// `Unregistered{src}` when `src` is unknown or holds fewer than
    /// `n_tokens` tokens on any layer.
    pub fn adopt_prefix(
        &mut self,
        dst: ReqId,
        src: ReqId,
        n_tokens: usize,
    ) -> Result<(), MemoryError> {
        if n_tokens == 0 || dst == src {
            return Ok(());
        }
        let bs = self.spec.block_size;
        let hkv = self.spec.n_kv_heads;
        let n_layers = self.spec.n_layers;
        let n_blocks = n_tokens.div_ceil(bs);
        let sealed = n_tokens / bs;
        match self.requests.get(&dst) {
            None => return Err(MemoryError::Unregistered { req: dst }),
            Some(d) => {
                if d.len != 0 || d.layer_len.iter().any(|&l| l != 0) {
                    debug_assert!(false, "adopt_prefix into non-empty request {dst}");
                    return Err(MemoryError::Unregistered { req: dst });
                }
            }
        }
        // collect the slots + metadata to adopt (src borrowed immutably)
        let mut adopted: Vec<Vec<(Vec<SlotId>, Vec<Cuboid>)>> = Vec::with_capacity(n_layers);
        {
            let Some(s) = self.requests.get(&src) else {
                return Err(MemoryError::Unregistered { req: src });
            };
            if s.layer_len.iter().any(|&l| l < n_tokens) {
                return Err(MemoryError::Unregistered { req: src });
            }
            for layer in 0..n_layers {
                let mut heads = Vec::with_capacity(hkv);
                for h in 0..hkv {
                    let slots: Vec<SlotId> = s.blocks[layer][h][..n_blocks].to_vec();
                    let meta: Vec<Cuboid> = s.meta[layer][h][..sealed].to_vec();
                    heads.push((slots, meta));
                }
                adopted.push(heads);
            }
        }
        // capture dst's (empty) pre-txn state so a rollback pops every
        // adopted slot back out through the refcounted free path
        self.txn_touch(dst);
        for heads in &adopted {
            for (slots, _) in heads {
                for &slot in slots {
                    self.retain_slot(slot);
                }
            }
        }
        let Some(d) = self.requests.get_mut(&dst) else {
            debug_assert!(false, "dst vanished mid-adopt");
            return Err(MemoryError::Unregistered { req: dst });
        };
        for (layer, heads) in adopted.into_iter().enumerate() {
            for (h, (slots, meta)) in heads.into_iter().enumerate() {
                d.blocks[layer][h] = slots;
                d.meta[layer][h] = meta;
            }
            d.layer_len[layer] = n_tokens;
        }
        d.len = n_tokens;
        Ok(())
    }

    /// Privatize `req`'s partially filled tail block of `layer` before a
    /// write, if it is shared: allocate a fresh slot, copy the plane,
    /// swap it into the block table. The old slot's reference is dropped
    /// at commit (journaled when a transaction is open) so rollback can
    /// restore the exact pre-step sharing. No-op in the common cases
    /// (nothing shared anywhere, block-aligned position, or an already
    /// private tail) — the zero-sharing decode hot loop sees one empty
    /// map check.
    fn cow_unshare_tail(&mut self, req: ReqId, layer: usize) -> Result<(), MemoryError> {
        if self.slot_refs.is_empty() {
            return Ok(());
        }
        let bs = self.spec.block_size;
        let pos = self.layer_len(req, layer);
        if pos % bs == 0 {
            return Ok(()); // appends land in a fresh block, not a shared one
        }
        let blk = pos / bs;
        let hkv = self.spec.n_kv_heads;
        for h in 0..hkv {
            let old = match self.requests.get(&req).and_then(|r| r.blocks[layer][h].get(blk)) {
                Some(&s) => s,
                None => continue,
            };
            if !self.slot_refs.contains_key(&old) {
                continue;
            }
            let Some(fresh) = self.dram.alloc() else {
                return Err(MemoryError::DramExhausted { req });
            };
            // plane copy through the recycled scratch buffer (COW is a
            // once-per-shared-tail event, but keep it allocation-free)
            let mut buf = std::mem::take(&mut self.scratch.src);
            buf.clear();
            buf.extend_from_slice(self.dram.slot(old));
            self.dram.slot_mut(fresh).copy_from_slice(&buf);
            self.scratch.src = buf;
            if let Some(r) = self.requests.get_mut(&req) {
                r.blocks[layer][h][blk] = fresh;
            }
            if let Some(txn) = &mut self.txn {
                txn.cow.push((req, layer, h, blk, old, fresh));
            } else {
                // no transaction to defer to: drop the reference now
                self.free_dram_slot(old);
            }
        }
        Ok(())
    }

    /// Drain a request for migration: copy every DRAM-tier block plane
    /// (and move the sealed-block metadata) into a [`DrainedKv`], then
    /// free all of its local state exactly like [`Self::release`] — HBM
    /// residency and stage pins do not travel. A between-steps
    /// operation: must not run inside an open step transaction.
    pub fn drain_request(&mut self, req: ReqId) -> Option<DrainedKv> {
        debug_assert!(self.txn.is_none(), "drain inside a step transaction");
        // land in-flight staging copies before freeing their slots, and
        // drop the victim's stage pins (pin conservation across drains)
        self.prefetch.wait_staged();
        for key in self.prefetch.cancel_request(req) {
            self.cache.unpin(&key);
        }
        let r = self.requests.remove(&req)?;
        let mut planes = Vec::with_capacity(r.blocks.len());
        for layer in &r.blocks {
            let mut heads = Vec::with_capacity(layer.len());
            for head in layer {
                heads.push(
                    head.iter().map(|&slot| self.dram.slot(slot).to_vec()).collect::<Vec<_>>(),
                );
            }
            planes.push(heads);
        }
        for layer in r.blocks {
            for head in layer {
                for slot in head {
                    // sharing is dropped at the migration boundary: the
                    // planes above are deep copies, so the payload is
                    // self-contained regardless of refcounts; slots a
                    // live sharer still references stay allocated here
                    self.free_dram_slot(slot);
                }
            }
        }
        for slot in self.cache.remove_request(req) {
            self.hbm.free(slot);
        }
        Some(DrainedKv {
            req,
            len: r.len,
            layer_len: r.layer_len,
            planes,
            meta: r.meta,
            block_bytes: self.dram.slot_bytes(),
        })
    }

    /// Land a drained request on this manager: allocate DRAM slots for
    /// every block and copy the planes in (the inverse of
    /// [`Self::drain_request`]). Preflighted against the free-slot
    /// count, so a typed [`MemoryError::DramExhausted`] allocates
    /// nothing. Panics on an id collision — cluster sequencing must
    /// never import over a live request.
    #[allow(clippy::expect_used)]
    pub fn import_request(&mut self, kv: DrainedKv) -> Result<(), MemoryError> {
        assert!(
            !self.requests.contains_key(&kv.req),
            "migration import collides with live request {}",
            kv.req
        );
        if kv.n_blocks() > self.dram.n_free() {
            return Err(MemoryError::DramExhausted { req: kv.req });
        }
        let mut blocks = Vec::with_capacity(kv.planes.len());
        for layer in &kv.planes {
            let mut heads = Vec::with_capacity(layer.len());
            for head in layer {
                let mut slots = Vec::with_capacity(head.len());
                for plane in head {
                    // sparselint: allow(no-panic) -- the preflight above counted free slots; failing mid-loop would leak a partially imported request, so a broken pool invariant must fail fast
                    let slot = self.dram.alloc().expect("preflight counted free slots");
                    self.dram.slot_mut(slot).copy_from_slice(plane);
                    slots.push(slot);
                }
                heads.push(slots);
            }
            blocks.push(heads);
        }
        self.requests.insert(
            kv.req,
            RequestKv { len: kv.len, layer_len: kv.layer_len, blocks, meta: kv.meta },
        );
        Ok(())
    }

    /// Completed tokens (all layers stored).
    pub fn seq_len(&self, req: ReqId) -> usize {
        self.requests.get(&req).map(|r| r.len).unwrap_or(0)
    }

    pub fn layer_len(&self, req: ReqId, layer: usize) -> usize {
        self.requests
            .get(&req)
            .map(|r| r.layer_len[layer])
            .unwrap_or(0)
    }

    pub fn n_sealed(&self, req: ReqId, layer: usize) -> usize {
        self.layer_len(req, layer) / self.spec.block_size
    }

    pub fn open_fill(&self, req: ReqId, layer: usize) -> usize {
        self.layer_len(req, layer) % self.spec.block_size
    }

    pub fn n_blocks(&self, req: ReqId) -> usize {
        self.seq_len(req).div_ceil(self.spec.block_size)
    }

    /// Bytes a request's KV occupies across all layers/heads.
    pub fn request_kv_bytes(&self, req: ReqId) -> usize {
        self.n_blocks(req) * self.spec.n_layers * self.spec.n_kv_heads * self.dram.slot_bytes()
    }

    /// HBM bytes in use: with offloading, the cache population; without,
    /// every stored block (vLLM semantics — everything pinned in HBM).
    pub fn hbm_bytes_used(&self) -> usize {
        if self.offload {
            self.cache.len() * self.hbm.slot_bytes()
        } else {
            self.dram.n_used() * self.dram.slot_bytes()
        }
    }

    pub fn hbm_bytes_capacity(&self) -> usize {
        self.hbm.n_slots() * self.hbm.slot_bytes()
    }

    pub fn dram_bytes_used(&self) -> usize {
        self.dram.n_used() * self.dram.slot_bytes()
    }

    pub fn block_bytes(&self) -> usize {
        self.dram.slot_bytes()
    }

    /// (hits, misses, evictions) of the HBM residency cache.
    pub fn cache_stats(&self) -> (u64, u64, u64) {
        (self.cache.hits, self.cache.misses, self.cache.evictions)
    }

    /// Cumulative prefetch accounting.
    pub fn prefetch_stats(&self) -> PrefetchStats {
        self.prefetch.stats
    }

    /// Free DRAM block slots (pre-flight admission check input).
    pub fn dram_free_slots(&self) -> usize {
        self.dram.n_free()
    }

    /// HBM residency-cache capacity in block slots (prefetch headroom
    /// sizing input).
    pub fn cache_capacity_slots(&self) -> usize {
        self.cache.capacity()
    }

    /// DRAM slots one decode step will allocate for `req`: a step adds
    /// exactly one token per layer, so new blocks are needed only when
    /// the request's length sits on a block boundary.
    pub fn decode_slots_needed(&self, req: ReqId) -> usize {
        let len = self.seq_len(req);
        if len % self.spec.block_size == 0 {
            self.spec.n_layers * self.spec.n_kv_heads
        } else {
            0
        }
    }

    // ------------------------------------------------------- transactions

    /// Begin a step transaction: every KV append and every gather-driven
    /// residency insert from here on is recorded in an undo log until
    /// [`Self::commit_txn`] (drop the log) or [`Self::rollback_txn`]
    /// (undo everything). One transaction per backend step; the engine's
    /// partial-batch retry relies on rollback leaving every batch-mate's
    /// KV byte-identical to its pre-step state.
    pub fn begin_txn(&mut self) {
        debug_assert!(self.txn.is_none(), "nested KV step transaction");
        self.txn = Some(TxnLog::default());
    }

    /// Keep everything the transaction did and close it. Copy-on-write
    /// journal entries settle here: the step is final, so the writer's
    /// reference on each privatized-away shared tail slot drops now
    /// (deferring the deref to commit is what lets rollback restore the
    /// old slot — it is guaranteed still allocated while the journal
    /// holds it).
    pub fn commit_txn(&mut self) {
        if let Some(log) = self.txn.take() {
            for (_, _, _, _, old, _) in log.cow {
                self.free_dram_slot(old);
            }
        }
    }

    /// Whether a step transaction is currently open.
    pub fn txn_open(&self) -> bool {
        self.txn.is_some()
    }

    /// Undo the open transaction: truncate every touched request's KV
    /// back to its pre-transaction per-layer lengths (freeing the DRAM
    /// blocks the step allocated and the cuboids it sealed) and purge
    /// residency-cache entries whose backing block the rollback unsealed
    /// or freed (stale copies must not survive into the retry). Entries
    /// for blocks that remain sealed stay resident — the retry hits them
    /// instead of re-paying PCIe. Prefetch stages are untouched: they
    /// reference pre-existing sealed blocks and keep feeding the retry.
    pub fn rollback_txn(&mut self) {
        let Some(log) = self.txn.take() else { return };
        // in-flight staging copies target disjoint, still-live slots;
        // land them before any bookkeeping below frees a source slot
        self.prefetch.wait_staged();
        // a mid-gather failure path already unwinds its pins, but a
        // failure between gathers must not leak any either
        for key in self.pinned.drain(..) {
            self.cache.unpin(&key);
        }
        let bs = self.spec.block_size;
        let hkv = self.spec.n_kv_heads;
        // 1. copy-on-write undo: put the shared slot back in the
        // writer's block table and free the private copy. The old slot
        // is guaranteed still allocated — the journal's deferred
        // reference (dropped only at commit) kept it alive.
        for (req, layer, h, blk, old, fresh) in log.cow.into_iter().rev() {
            let restored = match self.requests.get_mut(&req) {
                Some(r) => {
                    r.blocks[layer][h][blk] = old;
                    true
                }
                None => false,
            };
            if restored {
                self.free_dram_slot(fresh);
            } else {
                // writer released mid-transaction: its table (holding
                // `fresh`) was already freed; only the journal's
                // deferred reference on the shared slot remains to drop
                self.free_dram_slot(old);
            }
        }
        // 2. truncate every touched request to its pre-step lengths;
        // frees route through the refcounted funnel so adopted prefix
        // slots return their references instead of being double-freed
        let mut to_free: Vec<SlotId> = Vec::new();
        for (req, (len, layer_len)) in log.touched {
            // a request released mid-transaction already freed everything
            let Some(r) = self.requests.get_mut(&req) else { continue };
            for layer in 0..layer_len.len() {
                let keep_blocks = layer_len[layer].div_ceil(bs);
                let keep_sealed = layer_len[layer] / bs;
                for h in 0..hkv {
                    while r.blocks[layer][h].len() > keep_blocks {
                        let Some(slot) = r.blocks[layer][h].pop() else { break };
                        to_free.push(slot);
                    }
                    r.meta[layer][h].truncate(keep_sealed);
                }
                r.layer_len[layer] = layer_len[layer];
            }
            r.len = len;
        }
        for slot in to_free {
            self.free_dram_slot(slot);
        }
        for key in log.cache_inserts {
            let keep = if key.req == PREFIX_NS {
                // shared entry: keyed by slot, valid while the backing
                // slot is still shared (a last-reference free above
                // already tore its entry down)
                self.slot_refs.contains_key(&SlotId(key.block))
            } else {
                let sealed = self
                    .requests
                    .get(&key.req)
                    .map(|r| r.layer_len[key.layer as usize] / bs)
                    .unwrap_or(0);
                (key.block as usize) < sealed
            };
            if !keep {
                if let Some(slot) = self.cache.remove(&key) {
                    self.hbm.free(slot);
                }
            }
        }
    }

    /// Lazily capture a request's pre-transaction lengths before its
    /// first mutation of the step.
    fn txn_touch(&mut self, req: ReqId) {
        let Some(txn) = &mut self.txn else { return };
        if txn.touched.contains_key(&req) {
            return;
        }
        if let Some(r) = self.requests.get(&req) {
            txn.touched.insert(req, (r.len, r.layer_len.clone()));
        }
    }

    /// Snapshot of the in-progress iteration's transfer stats (per-layer
    /// `PhaseEvent` deltas; reset only by [`Self::end_iteration`]).
    pub fn iter_so_far(&self) -> IterStats {
        self.iter
    }

    // ------------------------------------------------------------ save path

    /// Store one layer's prefill KV. `k`/`v` are `[Hkv, T_pad, Dh]`
    /// row-major with `t_real <= t_pad` valid tokens.
    ///
    /// Errors with [`MemoryError::DramExhausted`] when the DRAM pool runs
    /// out of slots; the engine evicts the request instead of panicking.
    pub fn append_prefill_layer(
        &mut self,
        req: ReqId,
        layer: usize,
        k: &[f32],
        v: &[f32],
        t_pad: usize,
        t_real: usize,
    ) -> Result<(), MemoryError> {
        let (bs, dh, hkv) = (self.spec.block_size, self.spec.head_dim, self.spec.n_kv_heads);
        debug_assert_eq!(k.len(), hkv * t_pad * dh);
        debug_assert_eq!(v.len(), hkv * t_pad * dh);
        self.txn_touch(req);
        // writing into a shared (adopted) open tail block must not be
        // visible to other sharers: unshare it first (copy-on-write)
        self.cow_unshare_tail(req, layer)?;
        let base_len = self.layer_len(req, layer);

        // contiguous source tensor (K planes then V planes) + scatter
        // plan, both built in recycled buffers
        let mut src = std::mem::take(&mut self.scratch.src);
        let mut entries = std::mem::take(&mut self.scratch.entries);
        src.clear();
        entries.clear();
        src.extend_from_slice(k);
        src.extend_from_slice(v);
        let v_base = hkv * t_pad * dh;
        let slot_floats = self.dram.slot_floats();

        let mut exhausted = false;
        {
            let spec_layers = self.spec.n_layers;
            debug_assert!(layer < spec_layers);
            let dram = &mut self.dram;
            let Some(r) = self.requests.get_mut(&req) else {
                self.scratch.src = src;
                self.scratch.entries = entries;
                return Err(MemoryError::Unregistered { req });
            };
            'build: for h in 0..hkv {
                let mut tok = 0;
                while tok < t_real {
                    let abs = base_len + tok;
                    let blk = abs / bs;
                    let off = abs % bs;
                    let run = (bs - off).min(t_real - tok);
                    while r.blocks[layer][h].len() <= blk {
                        let Some(slot) = dram.alloc() else {
                            exhausted = true;
                            break 'build;
                        };
                        r.blocks[layer][h].push(slot);
                    }
                    let slot = r.blocks[layer][h][blk];
                    let src_k = h * t_pad * dh + tok * dh;
                    entries.push(ScatterEntry {
                        src_off: src_k,
                        len: run * dh,
                        dst_slot: slot,
                        dst_off: off * dh,
                    });
                    entries.push(ScatterEntry {
                        src_off: v_base + src_k,
                        len: run * dh,
                        dst_slot: slot,
                        dst_off: slot_floats / 2 + off * dh,
                    });
                    tok += run;
                }
            }
        }
        if exhausted {
            self.scratch.src = src;
            self.scratch.entries = entries;
            return Err(MemoryError::DramExhausted { req });
        }
        let stats = self.engine.save(&src, &mut self.dram, &entries);
        self.iter.save.merge(&stats);
        self.scratch.src = src;
        self.scratch.entries = entries;

        self.advance_layer(req, layer, t_real);
        Ok(())
    }

    /// Store one decode step's KV for one request+layer.
    /// `k_row`/`v_row`: `[Hkv, Dh]`.
    ///
    /// Errors with [`MemoryError::DramExhausted`] when the DRAM pool runs
    /// out of slots; the engine evicts the request instead of panicking.
    pub fn append_decode_token(
        &mut self,
        req: ReqId,
        layer: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<(), MemoryError> {
        let (bs, dh, hkv) = (self.spec.block_size, self.spec.head_dim, self.spec.n_kv_heads);
        debug_assert_eq!(k_row.len(), hkv * dh);
        self.txn_touch(req);
        // copy-on-write before appending into a shared open tail
        self.cow_unshare_tail(req, layer)?;
        let pos = self.layer_len(req, layer);
        let blk = pos / bs;
        let off = pos % bs;

        // recycled source + scatter-plan buffers (decode hot loop)
        let mut src = std::mem::take(&mut self.scratch.src);
        let mut entries = std::mem::take(&mut self.scratch.entries);
        src.clear();
        entries.clear();
        src.extend_from_slice(k_row);
        src.extend_from_slice(v_row);
        let slot_floats = self.dram.slot_floats();
        let mut exhausted = false;
        {
            let dram = &mut self.dram;
            let Some(r) = self.requests.get_mut(&req) else {
                self.scratch.src = src;
                self.scratch.entries = entries;
                return Err(MemoryError::Unregistered { req });
            };
            'build: for h in 0..hkv {
                while r.blocks[layer][h].len() <= blk {
                    let Some(slot) = dram.alloc() else {
                        exhausted = true;
                        break 'build;
                    };
                    r.blocks[layer][h].push(slot);
                }
                let slot = r.blocks[layer][h][blk];
                entries.push(ScatterEntry {
                    src_off: h * dh,
                    len: dh,
                    dst_slot: slot,
                    dst_off: off * dh,
                });
                entries.push(ScatterEntry {
                    src_off: hkv * dh + h * dh,
                    len: dh,
                    dst_slot: slot,
                    dst_off: slot_floats / 2 + off * dh,
                });
            }
        }
        if exhausted {
            self.scratch.src = src;
            self.scratch.entries = entries;
            return Err(MemoryError::DramExhausted { req });
        }
        let stats = self.engine.save(&src, &mut self.dram, &entries);
        self.iter.save.merge(&stats);
        self.scratch.src = src;
        self.scratch.entries = entries;

        self.advance_layer(req, layer, 1);
        Ok(())
    }

    /// Advance a layer's token count, sealing metadata for every newly
    /// complete block, and fold into the request-level `len`.
    fn advance_layer(&mut self, req: ReqId, layer: usize, n_new: usize) {
        let (bs, dh, hkv) = (self.spec.block_size, self.spec.head_dim, self.spec.n_kv_heads);
        let new_len = self.layer_len(req, layer) + n_new;
        let sealed = new_len / bs;
        // build cuboids (reads DRAM K planes; CPU-side, matches the device
        // block_meta kernel exactly — both are exact min/max)
        let mut new_meta: Vec<Vec<Cuboid>> = Vec::with_capacity(hkv);
        {
            let r = &self.requests[&req];
            for h in 0..hkv {
                let mut ms = Vec::new();
                for b in r.meta[layer][h].len()..sealed {
                    let slot = r.blocks[layer][h][b];
                    ms.push(Cuboid::from_k_plane(self.dram.k_plane(slot), dh, bs));
                }
                new_meta.push(ms);
            }
        }
        let n_layers = self.spec.n_layers;
        let Some(r) = self.requests.get_mut(&req) else {
            debug_assert!(false, "advance_layer for unregistered request {req}");
            return;
        };
        for (h, ms) in new_meta.into_iter().enumerate() {
            r.meta[layer][h].extend(ms);
        }
        r.layer_len[layer] = new_len;
        r.len = (0..n_layers).map(|l| r.layer_len[l]).min().unwrap_or(0);
    }

    // ------------------------------------------------------- metadata path

    /// Fill the decode_qkv metadata tensors for one request+layer:
    /// `lo`/`hi` `[Hkv, NB, Dh]` and additive `mask` `[Hkv, NB]`
    /// (NEG_INF for blocks without metadata).
    pub fn metadata_into(
        &self,
        req: ReqId,
        layer: usize,
        nb_max: usize,
        lo: &mut [f32],
        hi: &mut [f32],
        mask: &mut [f32],
    ) {
        let (dh, hkv) = (self.spec.head_dim, self.spec.n_kv_heads);
        debug_assert_eq!(lo.len(), hkv * nb_max * dh);
        debug_assert_eq!(mask.len(), hkv * nb_max);
        mask.fill(NEG_INF);
        let r = &self.requests[&req];
        for h in 0..hkv {
            for (b, cuboid) in r.meta[layer][h].iter().enumerate() {
                let base = (h * nb_max + b) * dh;
                lo[base..base + dh].copy_from_slice(&cuboid.lo);
                hi[base..base + dh].copy_from_slice(&cuboid.hi);
                mask[h * nb_max + b] = 0.0;
            }
        }
    }

    /// Export a layer's whole stored KV as contiguous `[Hkv, P, Dh]`
    /// tensors plus an additive mask (NEG_INF on unused tail slots).
    /// Used by the chunked-prefill baseline, which re-feeds the
    /// accumulated past KV to every chunk (the paper's Fig. 16b overhead
    /// made concrete).
    pub fn export_past(
        &self,
        req: ReqId,
        layer: usize,
        p_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        mask_out: &mut [f32],
    ) {
        let (bs, dh, hkv) = (self.spec.block_size, self.spec.head_dim, self.spec.n_kv_heads);
        debug_assert_eq!(k_out.len(), hkv * p_max * dh);
        debug_assert_eq!(mask_out.len(), p_max);
        let len = self.layer_len(req, layer).min(p_max);
        for (i, m) in mask_out.iter_mut().enumerate() {
            *m = if i < len { 0.0 } else { NEG_INF };
        }
        let r = &self.requests[&req];
        for h in 0..hkv {
            let mut tok = 0;
            while tok < len {
                let blk = tok / bs;
                let off = tok % bs;
                let run = (bs - off).min(len - tok);
                let slot = r.blocks[layer][h][blk];
                let plane = self.dram.slot(slot);
                let half = plane.len() / 2;
                let dst = (h * p_max + tok) * dh;
                k_out[dst..dst + run * dh]
                    .copy_from_slice(&plane[off * dh..(off + run) * dh]);
                v_out[dst..dst + run * dh]
                    .copy_from_slice(&plane[half + off * dh..half + (off + run) * dh]);
                tok += run;
            }
        }
    }

    // --------------------------------------------------------- gather path

    /// Gather the selected sealed blocks (plus the open block, always) into
    /// the attention staging tensors for one request+layer.
    ///
    /// `sealed_sel[h]` lists sealed block ids in slot order (score-desc,
    /// ties by id — computed by the executor from device scores).
    /// `k_out`/`v_out`: `[Hkv, S, Dh]`, `mask_out`: `[Hkv, S]` with
    /// `S = budget_blocks * block_size`. Returns sealed blocks gathered.
    ///
    /// Errors with [`MemoryError::HbmExhausted`] when a miss cannot get
    /// an HBM slot (everything pinned — the batch-control invariant was
    /// violated); the engine evicts the request instead of panicking.
    #[allow(clippy::expect_used)]
    pub fn gather_into(
        &mut self,
        req: ReqId,
        layer: usize,
        sealed_sel: &[Vec<u32>],
        budget_blocks: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        mask_out: &mut [f32],
    ) -> Result<usize, MemoryError> {
        let (bs, dh, hkv) = (self.spec.block_size, self.spec.head_dim, self.spec.n_kv_heads);
        let s_len = budget_blocks * bs;
        debug_assert_eq!(sealed_sel.len(), hkv);
        debug_assert_eq!(k_out.len(), hkv * s_len * dh);
        debug_assert_eq!(mask_out.len(), hkv * s_len);
        mask_out.fill(NEG_INF);

        let open_fill = self.open_fill(req, layer);
        let open_blk = self.n_sealed(req, layer) as u32;

        // Phase 1: residency — batch all misses into ONE engine burst
        // (what FlashH2D's fused kernel exploits).
        if self.offload {
            // staged bytes must have landed before we read them
            self.prefetch.wait_staged();
            // recycled miss-plan buffers (the gather hot loop rebuilds
            // these every layer)
            let mut to_load = std::mem::take(&mut self.scratch.to_load);
            let mut miss_keys = std::mem::take(&mut self.scratch.miss_keys);
            to_load.clear();
            miss_keys.clear();
            let mut alloc_err = None;
            'heads: for (h, sel) in sealed_sel.iter().enumerate() {
                for &b in sel {
                    let dram_slot = self.requests[&req].blocks[layer][h][b as usize];
                    // shared (prefix-adopted) blocks carry ONE residency
                    // entry keyed by slot: any sharer's load or stage is
                    // every sharer's hit
                    let key = if self.slot_refs.contains_key(&dram_slot) {
                        Self::shared_key(dram_slot)
                    } else {
                        BlockKey::new(req, layer as u16, h as u16, b)
                    };
                    if self.cache.get(&key).is_some() {
                        if self.prefetch.note_access(&key) {
                            // consume the stage pin: the prefetcher earned
                            // this hit, the gather re-pins below
                            self.cache.unpin(&key);
                            self.iter.prefetch_hits += 1;
                        }
                        self.cache.pin(&key);
                        self.pinned.push(key);
                    } else {
                        let hbm_slot = match self.alloc_hbm_slot(req) {
                            Ok(s) => s,
                            Err(e) => {
                                alloc_err = Some(e);
                                break 'heads;
                            }
                        };
                        to_load.push((dram_slot, hbm_slot));
                        miss_keys.push(key);
                    }
                }
            }
            if let Some(e) = alloc_err {
                // unwind without leaking: free unused HBM slots, drop pins
                for &(_, hbm_slot) in &to_load {
                    self.hbm.free(hbm_slot);
                }
                for key in self.pinned.drain(..) {
                    self.cache.unpin(&key);
                }
                self.scratch.to_load = to_load;
                self.scratch.miss_keys = miss_keys;
                return Err(e);
            }
            if !to_load.is_empty() {
                let stats = self.engine.load(&self.dram, &mut self.hbm, &to_load);
                self.iter.load.merge(&stats);
                self.iter.blocks_loaded += to_load.len();
                for (key, &(_, hbm_slot)) in miss_keys.iter().zip(&to_load) {
                    if let Some((_, freed)) = self.cache.insert(*key, hbm_slot) {
                        self.hbm.free(freed);
                    }
                    if let Some(txn) = &mut self.txn {
                        txn.cache_inserts.push(*key);
                    }
                    self.cache.pin(key);
                    self.pinned.push(*key);
                }
            }
            self.scratch.to_load = to_load;
            self.scratch.miss_keys = miss_keys;
        }

        // Phase 2: copy into the staging tensors (HBM-local, not PCIe).
        let mut gathered = 0;
        for (h, sel) in sealed_sel.iter().enumerate() {
            debug_assert!(sel.len() + 1 <= budget_blocks, "selection exceeds budget");
            for (slot_idx, &b) in sel.iter().enumerate() {
                let plane: &[f32] = if self.offload {
                    let dram_slot = self.requests[&req].blocks[layer][h][b as usize];
                    let key = if self.slot_refs.contains_key(&dram_slot) {
                        Self::shared_key(dram_slot)
                    } else {
                        BlockKey::new(req, layer as u16, h as u16, b)
                    };
                    // sparselint: allow(no-panic) -- phase 1 of this gather loaded and PINNED every selected block; a pinned entry cannot be evicted, so absence here is a cache-accounting bug that must fail fast
                    let hbm_slot = *self.cache.peek(&key).expect("resident after load");
                    self.hbm.slot(hbm_slot)
                } else {
                    let dram_slot = self.requests[&req].blocks[layer][h][b as usize];
                    self.dram.slot(dram_slot)
                };
                let half = plane.len() / 2;
                let dst = (h * s_len + slot_idx * bs) * dh;
                k_out[dst..dst + bs * dh].copy_from_slice(&plane[..half]);
                v_out[dst..dst + bs * dh].copy_from_slice(&plane[half..]);
                mask_out[h * s_len + slot_idx * bs..h * s_len + (slot_idx + 1) * bs].fill(0.0);
            }
            // open block last (always included; in-block padding masked)
            if open_fill > 0 {
                let slot_idx = budget_blocks - 1;
                let dram_slot = self.requests[&req].blocks[layer][h][open_blk as usize];
                let plane = self.dram.slot(dram_slot);
                let half = plane.len() / 2;
                let dst = (h * s_len + slot_idx * bs) * dh;
                k_out[dst..dst + open_fill * dh].copy_from_slice(&plane[..open_fill * dh]);
                v_out[dst..dst + open_fill * dh]
                    .copy_from_slice(&plane[half..half + open_fill * dh]);
                mask_out[h * s_len + slot_idx * bs..h * s_len + slot_idx * bs + open_fill]
                    .fill(0.0);
            }
            gathered += sel.len();
        }

        // Copies into staging are done; the blocks no longer need to be
        // HBM-resident (pins only protect residency across the two phases
        // of this gather; a *single* gather's selection must fit in HBM —
        // that is the batch-control invariant of Alg. 1).
        for key in self.pinned.drain(..) {
            self.cache.unpin(&key);
        }
        Ok(gathered)
    }

    fn alloc_hbm_slot(&mut self, req: ReqId) -> Result<SlotId, MemoryError> {
        if let Some(slot) = self.hbm.alloc() {
            return Ok(slot);
        }
        // HBM full: evict the LRU unpinned resident block, reuse its slot.
        // With everything pinned the tier is truly exhausted — a typed
        // error the engine turns into an eviction, not a panic.
        match self.cache.evict_lru() {
            Some((_, slot)) => Ok(slot),
            None => Err(MemoryError::HbmExhausted { req }),
        }
    }

    // ----------------------------------------------------- prefetch path

    /// Stage `plan` (recency-ranked working-set blocks, highest priority
    /// first) into the HBM cache ahead of the next batch, up to
    /// `max_blocks`. Slots are reserved and cache entries pinned
    /// synchronously; the byte movement runs on the prefetch engine's
    /// copy workers and is awaited before any gather reads it. Returns
    /// blocks staged. Skips blocks that are already resident, not yet
    /// sealed, or unknown; admission (skip-resident, headroom,
    /// pin+mark) is the shared [`StagingPolicy`], so the simulator
    /// cannot drift from this path. With `defer` the stages are
    /// cross-iteration hints: issued under the current batch's compute,
    /// retired only at the end of the *next* iteration.
    pub fn prefetch_working_set(
        &mut self,
        plan: &[BlockKey],
        max_blocks: usize,
        headroom: usize,
        defer: bool,
    ) -> usize {
        if !self.offload || max_blocks == 0 {
            return 0;
        }
        let bs = self.spec.block_size;
        let slot_floats = self.hbm.slot_floats();
        let policy = StagingPolicy { max_blocks, headroom };
        let mut staged = 0usize;
        for key in plan {
            let (layer, head, blk) =
                (key.layer as usize, key.head as usize, key.block as usize);
            let Some(r) = self.requests.get(&key.req) else { continue };
            if layer >= r.blocks.len() || head >= r.blocks[layer].len() {
                continue;
            }
            // only sealed blocks live in DRAM; the open block is gathered
            // directly from its device-resident slot
            if blk >= r.layer_len[layer] / bs {
                continue;
            }
            let Some(&dram_slot) = r.blocks[layer][head].get(blk) else { continue };
            // shared blocks stage under their slot-keyed residency
            // entry, so skip-resident sees other sharers' stages
            let key = if self.slot_refs.contains_key(&dram_slot) {
                Self::shared_key(dram_slot)
            } else {
                *key
            };
            match policy.admit(&self.cache, &key, staged) {
                StageAdmission::Stop => break,
                StageAdmission::SkipResident => continue,
                StageAdmission::Admit => {}
            }
            let hbm_slot = match self.alloc_hbm_slot(key.req) {
                Ok(s) => s,
                Err(_) => break,
            };
            // async FlashH2D stage: disjoint slots, awaited by
            // `wait_staged` before any read (see PrefetchEngine docs)
            let src = SendConst(self.dram.slot(dram_slot).as_ptr());
            let dst = SendMut(self.hbm.slot_mut(hbm_slot).as_mut_ptr());
            self.prefetch.submit_copy(move || unsafe {
                std::ptr::copy_nonoverlapping(src.0, dst.0, slot_floats);
            });
            if let Some((_, freed)) = stage_block(
                &mut self.cache,
                &mut self.prefetch,
                key,
                hbm_slot,
                slot_floats * 4,
                defer,
            ) {
                self.hbm.free(freed);
            }
            staged += 1;
        }
        if staged > 0 {
            self.iter.prefetch_blocks += staged;
            if defer {
                self.iter.prefetch_deferred += staged;
            }
            self.iter.prefetch.merge(&TransferStats {
                blocks: staged,
                bytes: staged * slot_floats * 4,
                calls: 1,
                modeled_s: self.engine.load_time_model(staged, slot_floats * 4),
                gpu_interference: 1.0,
            });
        }
        staged
    }

    /// Finish an iteration: retire unconsumed stages (wasted prefetch,
    /// blocks stay resident but unpinned) and return (and reset) the
    /// iteration's transfer stats.
    pub fn end_iteration(&mut self) -> IterStats {
        debug_assert!(self.pinned.is_empty(), "gather left pins behind");
        self.prefetch.wait_staged();
        let wasted = self.prefetch.end_iteration();
        self.iter.prefetch_wasted += wasted.len();
        for key in &wasted {
            self.cache.unpin(key);
        }
        std::mem::take(&mut self.iter)
    }
}

impl Drop for KvManager {
    fn drop(&mut self) {
        // in-flight staging copies hold raw pointers into the pools;
        // they must land before the pool buffers are freed
        self.prefetch.wait_staged();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::serving::TransferKind;
    use crate::config::HardwareSpec;
    use crate::memory::transfer::engine_for;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "test".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            head_dim: 4,
            ffn_dim: 16,
            block_size: 4,
            max_ctx: 64,
            rope_theta: 10000.0,
            kv_dtype_bytes: 4,
        }
    }

    fn mk_manager(offload: bool, hbm_blocks: usize) -> KvManager {
        let spec = tiny_spec();
        let slot_bytes = 2 * spec.block_size * spec.head_dim * 4;
        KvManager::new(
            spec,
            hbm_blocks * slot_bytes,
            1024 * slot_bytes,
            offload,
            engine_for(TransferKind::Flash, HardwareSpec::a100_40gb()),
        )
    }

    /// k/v rows with recognizable values: k[h][t][d] = 100h + t + d/10
    fn prefill_kv(hkv: usize, t: usize, dh: usize) -> (Vec<f32>, Vec<f32>) {
        let mut k = vec![0.0; hkv * t * dh];
        let mut v = vec![0.0; hkv * t * dh];
        for h in 0..hkv {
            for tok in 0..t {
                for d in 0..dh {
                    k[(h * t + tok) * dh + d] = 100.0 * h as f32 + tok as f32 + d as f32 / 10.0;
                    v[(h * t + tok) * dh + d] = -(100.0 * h as f32 + tok as f32) - d as f32 / 10.0;
                }
            }
        }
        (k, v)
    }

    #[test]
    fn prefill_then_gather_round_trips() {
        let mut m = mk_manager(true, 64);
        m.register(1);
        let (k, v) = prefill_kv(2, 12, 4); // 3 blocks of 4
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 12, 12).unwrap();
        }
        assert_eq!(m.seq_len(1), 12);
        assert_eq!(m.n_sealed(1, 0), 3);
        assert_eq!(m.open_fill(1, 0), 0);

        // gather blocks [2, 0] for both heads with budget 4
        let budget = 4;
        let s = budget * 4;
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        let sel = vec![vec![2u32, 0u32], vec![2u32, 0u32]];
        let gathered = m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        assert_eq!(gathered, 4);
        // head 0, slot 0 = block 2 -> tokens 8..12
        for tok in 0..4 {
            for d in 0..4 {
                assert_eq!(ko[(tok) * 4 + d], (8 + tok) as f32 + d as f32 / 10.0);
            }
        }
        // head 0, slot 1 = block 0 -> tokens 0..4
        assert_eq!(ko[(4) * 4], 0.0);
        assert_eq!(ko[(5) * 4], 1.0);
        // masks: slots 0,1 valid; 2,3 masked (no open block)
        assert!(mo[..8].iter().all(|&x| x == 0.0));
        assert!(mo[8..16].iter().all(|&x| x == NEG_INF));
        m.end_iteration();
    }

    #[test]
    fn decode_appends_seal_blocks_and_metadata() {
        let mut m = mk_manager(true, 64);
        m.register(7);
        let dh = 4;
        for t in 0..5 {
            // one decode step = both layers
            for layer in 0..2 {
                let k: Vec<f32> = (0..2 * dh).map(|i| (t * 10 + i) as f32).collect();
                let v = vec![t as f32; 2 * dh];
                m.append_decode_token(7, layer, &k, &v).unwrap();
            }
            assert_eq!(m.seq_len(7), t + 1);
        }
        assert_eq!(m.n_sealed(7, 0), 1);
        assert_eq!(m.open_fill(7, 0), 1);
        // metadata exists for the sealed block only
        let nb = 8;
        let mut lo = vec![0.0; 2 * nb * dh];
        let mut hi = vec![0.0; 2 * nb * dh];
        let mut mask = vec![0.0; 2 * nb];
        m.metadata_into(7, 0, nb, &mut lo, &mut hi, &mut mask);
        assert_eq!(mask[0], 0.0);
        assert_eq!(mask[1], NEG_INF);
        // head 0 sealed block tokens t=0..4, k value at d=0 is t*10
        assert_eq!(lo[0], 0.0);
        assert_eq!(hi[0], 30.0);
    }

    #[test]
    fn open_block_always_gathered_with_mask() {
        let mut m = mk_manager(true, 64);
        m.register(3);
        for layer in 0..2 {
            let k = vec![1.5; 2 * 4];
            let v = vec![2.5; 2 * 4];
            m.append_decode_token(3, layer, &k, &v).unwrap();
        }
        let budget = 2;
        let s = budget * 4;
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        let sel = vec![vec![], vec![]];
        m.gather_into(3, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        // open block in last slot: first token valid, rest masked
        assert_eq!(mo[4], 0.0); // head 0, slot 1, token 0
        assert_eq!(mo[5], NEG_INF);
        assert_eq!(ko[4 * 4], 1.5);
        m.end_iteration();
    }

    #[test]
    fn cache_hits_avoid_reloads() {
        let mut m = mk_manager(true, 64);
        m.register(1);
        let (k, v) = prefill_kv(2, 8, 4);
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 8, 8).unwrap();
        }
        let budget = 3;
        let s = budget * 4;
        let sel = vec![vec![0u32, 1u32], vec![0u32, 1u32]];
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        let s1 = m.end_iteration();
        assert_eq!(s1.blocks_loaded, 4); // cold: all misses
        m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        let s2 = m.end_iteration();
        assert_eq!(s2.blocks_loaded, 0); // warm: all hits
        assert_eq!(s2.load.modeled_s, 0.0);
    }

    #[test]
    fn tight_hbm_causes_thrashing() {
        // HBM cache of 2 blocks; the per-iteration selection alternates
        // between blocks {0} and {1} on both heads, so a 2-slot cache
        // thrashes: every iteration evicts and reloads.
        let mut m = mk_manager(true, 2);
        m.register(1);
        let (k, v) = prefill_kv(2, 8, 4);
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 8, 8).unwrap();
        }
        let budget = 3;
        let s = budget * 4;
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        for it in 0..4 {
            let b = (it % 2) as u32;
            let sel = vec![vec![b], vec![b]];
            m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
            let st = m.end_iteration();
            assert_eq!(st.blocks_loaded, 2, "thrash must keep loading (iter {it})");
        }
        let (_, _, evictions) = m.cache_stats();
        assert!(evictions >= 4, "evictions={evictions}");
    }

    #[test]
    fn release_frees_everything() {
        let mut m = mk_manager(true, 8);
        m.register(1);
        let (k, v) = prefill_kv(2, 8, 4);
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 8, 8).unwrap();
        }
        let used = m.dram_bytes_used();
        assert!(used > 0);
        // touch cache
        let budget = 3;
        let s = budget * 4;
        let sel = vec![vec![0u32], vec![0u32]];
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        m.end_iteration();
        m.release(1);
        assert_eq!(m.dram_bytes_used(), 0);
        assert_eq!(m.hbm_bytes_used(), 0);
    }

    #[test]
    fn non_offload_counts_hbm_directly() {
        let mut m = mk_manager(false, 8);
        m.register(1);
        let (k, v) = prefill_kv(2, 8, 4);
        m.append_prefill_layer(1, 0, &k, &v, 8, 8).unwrap();
        // 2 heads x 2 blocks x 1 layer
        assert_eq!(m.hbm_bytes_used(), 4 * m.block_bytes());
        // gather costs no PCIe
        let budget = 3;
        let s = budget * 4;
        let sel = vec![vec![0u32, 1u32], vec![0u32, 1u32]];
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        let st = m.end_iteration();
        assert_eq!(st.blocks_loaded, 0);
        assert_eq!(st.load.modeled_s, 0.0);
    }

    #[test]
    fn dram_exhaustion_is_a_typed_error_not_a_panic() {
        // 2 layers x 2 heads x N blocks: a 4-slot DRAM pool fills after
        // one block per (layer, head)
        let spec = tiny_spec();
        let slot_bytes = 2 * spec.block_size * spec.head_dim * 4;
        let mut m = KvManager::new(
            spec,
            8 * slot_bytes,
            4 * slot_bytes,
            true,
            engine_for(TransferKind::Flash, HardwareSpec::a100_40gb()),
        );
        m.register(1);
        let (k, v) = prefill_kv(2, 4, 4); // 1 block/head/layer = 4 slots
        m.append_prefill_layer(1, 0, &k, &v, 4, 4).unwrap();
        m.append_prefill_layer(1, 1, &k, &v, 4, 4).unwrap();
        // the 5th slot does not exist: typed error, no panic
        let err = m.append_decode_token(1, 0, &[0.0; 8], &[0.0; 8]).unwrap_err();
        assert_eq!(err, MemoryError::DramExhausted { req: 1 });
        assert_eq!(err.req(), 1);
        assert!(err.to_string().contains("DRAM exhausted"));
        // release still cleans up after the failure
        m.release(1);
        assert_eq!(m.dram_bytes_used(), 0);
    }

    #[test]
    fn prefetched_blocks_are_staged_then_hit_on_gather() {
        let mut m = mk_manager(true, 64);
        m.register(1);
        let (k, v) = prefill_kv(2, 12, 4); // 3 sealed blocks/head/layer
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 12, 12).unwrap();
        }
        // stage blocks 0 and 2 of layer 0 on both heads
        let plan = [
            BlockKey::new(1, 0, 0, 0),
            BlockKey::new(1, 0, 1, 0),
            BlockKey::new(1, 0, 0, 2),
            BlockKey::new(1, 0, 1, 2),
        ];
        let staged = m.prefetch_working_set(&plan, 64, 0, false);
        assert_eq!(staged, 4);
        // open block / unknown blocks are skipped, residents not re-staged
        assert_eq!(m.prefetch_working_set(&plan, 64, 0, false), 0);
        // gather the staged selection: all hits, zero demand loads,
        // bytes identical to the DRAM source
        let budget = 4;
        let s = budget * 4;
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        let sel = vec![vec![2u32, 0u32], vec![2u32, 0u32]];
        let g = m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        assert_eq!(g, 4);
        for tok in 0..4 {
            for d in 0..4 {
                assert_eq!(ko[tok * 4 + d], (8 + tok) as f32 + d as f32 / 10.0);
            }
        }
        let iter = m.end_iteration();
        assert_eq!(iter.blocks_loaded, 0, "staged blocks must be hits");
        assert_eq!(iter.prefetch_blocks, 4);
        assert_eq!(iter.prefetch_hits, 4);
        assert_eq!(iter.prefetch_wasted, 0);
        assert!(iter.prefetch.modeled_s > 0.0);
        assert_eq!(m.prefetch_stats().hits, 4);
    }

    #[test]
    fn unused_prefetch_is_wasted_and_unpinned() {
        let mut m = mk_manager(true, 64);
        m.register(1);
        let (k, v) = prefill_kv(2, 8, 4);
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 8, 8).unwrap();
        }
        let plan = [BlockKey::new(1, 0, 0, 0), BlockKey::new(1, 0, 1, 1)];
        assert_eq!(m.prefetch_working_set(&plan, 64, 0, false), 2);
        let iter = m.end_iteration(); // nothing gathered
        assert_eq!(iter.prefetch_wasted, 2);
        assert_eq!(iter.prefetch_hits, 0);
        assert_eq!(m.prefetch_stats().wasted, 2);
        // wasted stages stay resident but unpinned — release frees them
        m.release(1);
        assert_eq!(m.hbm_bytes_used(), 0);
    }

    #[test]
    fn release_cancels_staged_blocks() {
        let mut m = mk_manager(true, 64);
        m.register(1);
        let (k, v) = prefill_kv(2, 8, 4);
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 8, 8).unwrap();
        }
        let plan = [BlockKey::new(1, 0, 0, 0), BlockKey::new(1, 1, 0, 0)];
        assert_eq!(m.prefetch_working_set(&plan, 64, 0, false), 2);
        // release mid-flight: stage pins must not outlive the request
        m.release(1);
        assert_eq!(m.prefetch_stats().cancelled, 2);
        assert_eq!(m.hbm_bytes_used(), 0);
        assert_eq!(m.dram_bytes_used(), 0);
        let iter = m.end_iteration();
        assert_eq!(iter.prefetch_wasted, 0, "cancelled stages are not wasted");
    }

    #[test]
    fn prefetch_cap_and_capacity_bound_staging() {
        // HBM cache of 2 slots: staging must stop at capacity, not panic
        let mut m = mk_manager(true, 2);
        m.register(1);
        let (k, v) = prefill_kv(2, 12, 4);
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 12, 12).unwrap();
        }
        let plan: Vec<BlockKey> = (0..3u32)
            .flat_map(|b| (0..2u16).map(move |h| BlockKey::new(1, 0, h, b)))
            .collect();
        let staged = m.prefetch_working_set(&plan, 64, 0, false);
        assert_eq!(staged, 2, "staging capped by HBM capacity");
        // per-iteration cap is honored too
        let mut m2 = mk_manager(true, 64);
        m2.register(1);
        for layer in 0..2 {
            m2.append_prefill_layer(1, layer, &k, &v, 12, 12).unwrap();
        }
        assert_eq!(m2.prefetch_working_set(&plan, 3, 0, false), 3);
        // headroom reserves demand-miss room: 2-slot cache, headroom 1
        // -> only 1 slot may be pinned by stages
        let mut m3 = mk_manager(true, 2);
        m3.register(1);
        for layer in 0..2 {
            m3.append_prefill_layer(1, layer, &k, &v, 12, 12).unwrap();
        }
        assert_eq!(m3.prefetch_working_set(&plan, 64, 1, false), 1);
        m.end_iteration();
        m2.end_iteration();
        m3.end_iteration();
    }

    #[test]
    fn decode_preflight_accounting() {
        let mut m = mk_manager(true, 8);
        m.register(1);
        // fresh request: the first token opens a block on every layer/head
        assert_eq!(m.decode_slots_needed(1), 2 * 2);
        let (k, v) = prefill_kv(2, 4, 4); // exactly one full block
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 4, 4).unwrap();
        }
        assert_eq!(m.decode_slots_needed(1), 4, "boundary opens new blocks");
        for layer in 0..2 {
            m.append_decode_token(1, layer, &[0.0; 8], &[0.0; 8]).unwrap();
        }
        assert_eq!(m.decode_slots_needed(1), 0, "mid-block needs no slots");
        assert_eq!(m.dram_free_slots(), 1024 - 8);
    }

    #[test]
    fn txn_rollback_restores_kv_and_mem_stats() {
        let mut m = mk_manager(true, 64);
        m.register(1);
        let (k, v) = prefill_kv(2, 8, 4); // 2 sealed blocks/head/layer
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 8, 8).unwrap();
        }
        let dram_before = m.dram_bytes_used();
        let hbm_before = m.hbm_bytes_used();
        let len_before = m.seq_len(1);

        m.begin_txn();
        assert!(m.txn_open());
        // a decode step: appends on both layers (opens a new block per head)
        for layer in 0..2 {
            m.append_decode_token(1, layer, &[9.0; 8], &[9.0; 8]).unwrap();
        }
        assert_eq!(m.seq_len(1), len_before + 1);
        assert!(m.dram_bytes_used() > dram_before, "step must allocate");
        m.rollback_txn();

        assert_eq!(m.seq_len(1), len_before, "length must roll back");
        assert_eq!(m.dram_bytes_used(), dram_before, "DRAM must roll back");
        assert_eq!(m.hbm_bytes_used(), hbm_before, "no gather ran: HBM unchanged");
        assert_eq!(m.layer_len(1, 0), 8);
        assert_eq!(m.n_sealed(1, 0), 2);
        assert_eq!(m.open_fill(1, 0), 0);
        // the manager is fully usable afterwards: same step re-runs clean
        m.begin_txn();
        for layer in 0..2 {
            m.append_decode_token(1, layer, &[9.0; 8], &[9.0; 8]).unwrap();
        }
        m.commit_txn();
        assert_eq!(m.seq_len(1), len_before + 1);
    }

    #[test]
    fn txn_rollback_purges_unsealed_cache_entries_keeps_valid_ones() {
        let mut m = mk_manager(true, 64);
        m.register(1);
        let (k, v) = prefill_kv(2, 7, 4); // 1 sealed block + 3 open tokens
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 7, 7).unwrap();
        }
        let budget = 3;
        let s = budget * 4;
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];

        m.begin_txn();
        // seal block 1 (tokens 4..8) on both layers...
        for layer in 0..2 {
            m.append_decode_token(1, layer, &[5.0; 8], &[5.0; 8]).unwrap();
        }
        assert_eq!(m.n_sealed(1, 0), 2);
        // ...and gather both block 0 (pre-txn, stays valid) and block 1
        // (sealed inside the txn: must be purged on rollback)
        let sel = vec![vec![0u32, 1u32], vec![0u32, 1u32]];
        m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        m.rollback_txn();

        assert_eq!(m.n_sealed(1, 0), 1, "block 1 unsealed by rollback");
        assert_eq!(m.open_fill(1, 0), 3);
        // block 0 stays resident (the retry hits it for free); block 1's
        // stale copy must be gone
        let resident0 = BlockKey::new(1, 0, 0, 0);
        let stale1 = BlockKey::new(1, 0, 0, 1);
        assert!(m.cache.contains(&resident0), "valid entry survives rollback");
        assert!(!m.cache.contains(&stale1), "unsealed entry must be purged");
        // retry of the surviving selection: block 0 is a hit, no load
        let sel0 = vec![vec![0u32], vec![0u32]];
        m.gather_into(1, 0, &sel0, budget, &mut ko, &mut vo, &mut mo).unwrap();
        let iter = m.end_iteration();
        // first gather loaded 4 (2 heads x blocks 0,1); retry loaded 0
        assert_eq!(iter.blocks_loaded, 4, "retry must hit the kept residency");
    }

    #[test]
    fn txn_commit_keeps_appends() {
        let mut m = mk_manager(true, 16);
        m.register(1);
        m.begin_txn();
        let (k, v) = prefill_kv(2, 4, 4);
        m.append_prefill_layer(1, 0, &k, &v, 4, 4).unwrap();
        m.commit_txn();
        assert!(!m.txn_open());
        assert_eq!(m.layer_len(1, 0), 4);
        // rollback with no open txn is a no-op
        m.rollback_txn();
        assert_eq!(m.layer_len(1, 0), 4);
    }

    /// Satellite: the PJRT prefetch path under multi-request decode load
    /// — three concurrent decodes staging through `prefetch_working_set`
    /// with FCFS plan priority, then gathering with hits.
    #[test]
    fn prefetch_three_concurrent_decodes_fcfs_priority_and_hits() {
        let mut m = mk_manager(true, 64);
        let (k, v) = prefill_kv(2, 12, 4); // 3 sealed blocks/head/layer
        for req in 1..=3u32 {
            m.register(req);
            for layer in 0..2 {
                m.append_prefill_layer(req, layer, &k, &v, 12, 12).unwrap();
            }
        }
        // FCFS plan: request 1's working set first, then 2, then 3
        let mut plan = Vec::new();
        for req in 1..=3u32 {
            for b in 0..3u32 {
                plan.push(BlockKey::new(req, 0, 0, b));
                plan.push(BlockKey::new(req, 0, 1, b));
            }
        }
        // budget covers only the first two requests' plans (12 blocks):
        // the earliest requests get the staging budget
        let staged = m.prefetch_working_set(&plan, 12, 0, false);
        assert_eq!(staged, 12);
        for b in 0..3u32 {
            assert!(m.cache.contains(&BlockKey::new(1, 0, 0, b)), "req 1 staged");
            assert!(m.cache.contains(&BlockKey::new(2, 0, 0, b)), "req 2 staged");
            assert!(!m.cache.contains(&BlockKey::new(3, 0, 0, b)), "req 3 beyond cap");
        }
        // all three decode: 1 and 2 all-hit, 3 pays demand loads
        let budget = 4;
        let s = budget * 4;
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        let sel = vec![vec![0u32, 1, 2], vec![0u32, 1, 2]];
        for req in 1..=3u32 {
            m.gather_into(req, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        }
        let iter = m.end_iteration();
        assert_eq!(iter.prefetch_hits, 12, "both staged requests must hit");
        assert_eq!(iter.blocks_loaded, 6, "request 3 pays its own misses");
        // releases under load leave nothing behind
        for req in 1..=3u32 {
            m.release(req);
        }
        assert_eq!(m.dram_bytes_used(), 0);
        assert_eq!(m.hbm_bytes_used(), 0);
    }

    #[test]
    fn deferred_prefetch_survives_into_next_iteration() {
        let mut m = mk_manager(true, 64);
        m.register(1);
        let (k, v) = prefill_kv(2, 8, 4);
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k, &v, 8, 8).unwrap();
        }
        let plan = [BlockKey::new(1, 0, 0, 0), BlockKey::new(1, 0, 1, 0)];
        assert_eq!(m.prefetch_working_set(&plan, 64, 0, true), 2);
        let iter = m.end_iteration(); // the CURRENT iteration ends...
        assert_eq!(iter.prefetch_deferred, 2);
        assert_eq!(iter.prefetch_wasted, 0, "deferred stages are not wasted yet");
        // ...and the next iteration's gather consumes them as hits
        let budget = 3;
        let s = budget * 4;
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        let sel = vec![vec![0u32], vec![0u32]];
        m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        let iter = m.end_iteration();
        assert_eq!(iter.blocks_loaded, 0);
        assert_eq!(iter.prefetch_hits, 2, "cross-iteration stage must hit");
    }

    #[test]
    fn drain_then_import_round_trips_gathers_byte_identically() {
        let mut src = mk_manager(true, 64);
        src.register(1);
        let (k, v) = prefill_kv(2, 12, 4); // 3 sealed blocks/head/layer
        for layer in 0..2 {
            src.append_prefill_layer(1, layer, &k, &v, 12, 12).unwrap();
        }
        // warm the source cache + stage pins so the drain has residency
        // state to clean up
        let plan = [BlockKey::new(1, 0, 0, 0), BlockKey::new(1, 0, 1, 0)];
        assert_eq!(src.prefetch_working_set(&plan, 64, 0, false), 2);
        // the reference gather (what an unmigrated run would read)
        let budget = 4;
        let s = budget * 4;
        let sel = vec![vec![2u32, 0u32], vec![2u32, 0u32]];
        let (mut kr, mut vr, mut mr) =
            (vec![0.0; 2 * s * 4], vec![0.0; 2 * s * 4], vec![0.0; 2 * s]);
        src.gather_into(1, 0, &sel, budget, &mut kr, &mut vr, &mut mr).unwrap();
        src.end_iteration();

        let drained = src.drain_request(1).expect("live request must drain");
        assert_eq!(drained.seq_len(), 12);
        // 2 layers x 2 heads x 3 blocks
        assert_eq!(drained.total_bytes(), 12 * src.block_bytes());
        assert_eq!(src.dram_bytes_used(), 0, "drain frees the source DRAM");
        assert_eq!(src.hbm_bytes_used(), 0, "residency does not travel");
        assert!(src.drain_request(1).is_none(), "double drain refused");

        let mut dst = mk_manager(true, 64);
        dst.import_request(drained).unwrap();
        assert_eq!(dst.seq_len(1), 12);
        assert_eq!(dst.n_sealed(1, 0), 3);
        // the migrated gather reads byte-identical planes AND identical
        // sealed-block metadata
        let (mut kd, mut vd, mut md) =
            (vec![0.0; 2 * s * 4], vec![0.0; 2 * s * 4], vec![0.0; 2 * s]);
        dst.gather_into(1, 0, &sel, budget, &mut kd, &mut vd, &mut md).unwrap();
        dst.end_iteration();
        assert_eq!(kd, kr, "migrated K planes must be byte-identical");
        assert_eq!(vd, vr, "migrated V planes must be byte-identical");
        assert_eq!(md, mr);
        let dh = 4;
        let nb = 8;
        let (mut lo, mut hi, mut mask) =
            (vec![0.0; 2 * nb * dh], vec![0.0; 2 * nb * dh], vec![0.0; 2 * nb]);
        dst.metadata_into(1, 0, nb, &mut lo, &mut hi, &mut mask);
        assert_eq!(mask[..3], [0.0, 0.0, 0.0], "sealed meta moved with the KV");
        // decode continues where the source stopped
        for layer in 0..2 {
            dst.append_decode_token(1, layer, &[0.5; 8], &[0.5; 8]).unwrap();
        }
        assert_eq!(dst.seq_len(1), 13);
        dst.release(1);
        assert_eq!(dst.dram_bytes_used(), 0);
    }

    #[test]
    fn import_into_exhausted_dram_is_typed_and_allocates_nothing() {
        let mut src = mk_manager(true, 8);
        src.register(1);
        let (k, v) = prefill_kv(2, 8, 4); // 2 blocks/head/layer = 8 slots
        for layer in 0..2 {
            src.append_prefill_layer(1, layer, &k, &v, 8, 8).unwrap();
        }
        let drained = src.drain_request(1).unwrap();
        // a target with only 4 DRAM slots cannot take 8 blocks
        let spec = tiny_spec();
        let slot_bytes = 2 * spec.block_size * spec.head_dim * 4;
        let mut dst = KvManager::new(
            spec,
            8 * slot_bytes,
            4 * slot_bytes,
            true,
            engine_for(TransferKind::Flash, HardwareSpec::a100_40gb()),
        );
        let err = dst.import_request(drained).unwrap_err();
        assert_eq!(err, MemoryError::DramExhausted { req: 1 });
        assert_eq!(dst.dram_bytes_used(), 0, "failed import must allocate nothing");
        assert!(!dst.is_registered(1));
    }

    #[test]
    fn chunked_prefill_appends_across_segments() {
        let mut m = mk_manager(true, 64);
        m.register(1);
        let (k1, v1) = prefill_kv(2, 6, 4); // 1.5 blocks
        let (k2, v2) = prefill_kv(2, 6, 4);
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k1, &v1, 6, 6).unwrap();
        }
        assert_eq!(m.seq_len(1), 6);
        assert_eq!(m.open_fill(1, 0), 2);
        for layer in 0..2 {
            m.append_prefill_layer(1, layer, &k2, &v2, 6, 6).unwrap();
        }
        assert_eq!(m.seq_len(1), 12);
        assert_eq!(m.n_sealed(1, 0), 3);
        assert_eq!(m.open_fill(1, 0), 0);
    }

    // ------------------------------------------ cross-request prefix sharing

    /// Prefill `req` with `t` tokens of the standard pattern on both layers.
    fn prefill_req(m: &mut KvManager, req: ReqId, t: usize) {
        m.register(req);
        let (k, v) = prefill_kv(2, t, 4);
        for layer in 0..2 {
            m.append_prefill_layer(req, layer, &k, &v, t, t).unwrap();
        }
    }

    #[test]
    fn adopt_prefix_shares_slots_without_copying() {
        let mut m = mk_manager(true, 64);
        prefill_req(&mut m, 1, 12); // 3 blocks/head/layer
        let used = m.dram_bytes_used();
        m.register(2);
        m.adopt_prefix(2, 1, 8).unwrap(); // share the first 2 blocks
        assert_eq!(m.dram_bytes_used(), used, "adoption must not allocate");
        assert_eq!(m.seq_len(2), 8);
        assert_eq!(m.n_sealed(2, 0), 2);
        // refcount == live sharers on every adopted slot
        assert_eq!(m.n_shared_slots(), 2 * 2 * 2, "2 layers x 2 heads x 2 blocks");
        let slot = m.requests[&2].blocks[0][0][0];
        assert_eq!(slot, m.requests[&1].blocks[0][0][0], "same physical slot");
        assert_eq!(m.slot_ref_count(slot), 2);
        // block-aligned append: a fresh exclusive block, no COW, donor intact
        for layer in 0..2 {
            m.append_decode_token(2, layer, &[7.0; 8], &[7.0; 8]).unwrap();
        }
        assert_eq!(m.n_shared_slots(), 8, "aligned append never privatizes");
        assert_eq!(m.seq_len(1), 12);
        // donor finishes first: shared slots survive on the sharer's refs
        m.release(1);
        assert_eq!(m.slot_ref_count(slot), 1);
        assert!(m.dram_bytes_used() > 0);
        m.release(2);
        assert_eq!(m.dram_bytes_used(), 0, "last release frees everything");
        assert_eq!(m.n_shared_slots(), 0);
    }

    #[test]
    fn write_into_shared_open_tail_copies_on_write() {
        let mut m = mk_manager(true, 64);
        prefill_req(&mut m, 1, 6); // 1 sealed block + 2 open tokens
        m.register(2);
        m.adopt_prefix(2, 1, 6).unwrap(); // includes the partial tail block
        let shared_tail = m.requests[&1].blocks[0][0][1];
        assert_eq!(m.slot_ref_count(shared_tail), 2);
        // sharer appends: the tail must privatize, the donor keeps its slot
        for layer in 0..2 {
            m.append_decode_token(2, layer, &[9.0; 8], &[9.0; 8]).unwrap();
        }
        let tail2 = m.requests[&2].blocks[0][0][1];
        assert_ne!(tail2, shared_tail, "tail privatized before the write");
        assert_eq!(m.requests[&1].blocks[0][0][1], shared_tail, "donor untouched");
        assert_eq!(m.slot_ref_count(shared_tail), 1, "sharer's ref moved off");
        // the copied plane carries the donor's bytes: token 4 (tail, off 0)
        // of head 0 has k[d=0] = 4.0 from the prefill pattern
        assert_eq!(m.dram.slot(tail2)[0], 4.0, "COW copied the donor bytes");
        assert_eq!(m.seq_len(2), 7);
        assert_eq!(m.seq_len(1), 6, "donor length unchanged");
        m.release(1);
        m.release(2);
        assert_eq!(m.dram_bytes_used(), 0);
    }

    #[test]
    fn txn_rollback_returns_adopted_refs_and_undoes_cow() {
        let mut m = mk_manager(true, 64);
        prefill_req(&mut m, 1, 6);
        let shared_tail = m.requests[&1].blocks[0][0][1];
        let dram_before = m.dram_bytes_used();

        // adoption inside a rolled-back step: every refcount returns
        m.register(2);
        m.begin_txn();
        m.adopt_prefix(2, 1, 6).unwrap();
        assert_eq!(m.slot_ref_count(shared_tail), 2);
        m.rollback_txn();
        assert_eq!(m.slot_ref_count(shared_tail), 1, "rollback returned the ref");
        assert_eq!(m.seq_len(2), 0);
        assert_eq!(m.dram_bytes_used(), dram_before);

        // COW inside a rolled-back step: the shared slot returns to the
        // table and the private copy is freed — byte-identical state
        m.adopt_prefix(2, 1, 6).unwrap();
        let dram_shared = m.dram_bytes_used();
        m.begin_txn();
        for layer in 0..2 {
            m.append_decode_token(2, layer, &[9.0; 8], &[9.0; 8]).unwrap();
        }
        assert_ne!(m.requests[&2].blocks[0][0][1], shared_tail);
        m.rollback_txn();
        assert_eq!(m.requests[&2].blocks[0][0][1], shared_tail, "COW undone");
        assert_eq!(m.slot_ref_count(shared_tail), 2, "both sharers again");
        assert_eq!(m.seq_len(2), 6);
        assert_eq!(m.dram_bytes_used(), dram_shared, "private copies freed");
        // the same step re-runs clean and commits: the old shared slot's
        // reference settles at commit (donor keeps it; sharer owns a copy)
        m.begin_txn();
        for layer in 0..2 {
            m.append_decode_token(2, layer, &[9.0; 8], &[9.0; 8]).unwrap();
        }
        m.commit_txn();
        assert_eq!(m.slot_ref_count(shared_tail), 1);
        assert_eq!(m.requests[&1].blocks[0][0][1], shared_tail);
        m.release(1);
        m.release(2);
        assert_eq!(m.dram_bytes_used(), 0);
        assert_eq!(m.n_shared_slots(), 0);
    }

    #[test]
    fn shared_block_residency_one_load_serves_every_sharer() {
        let mut m = mk_manager(true, 64);
        prefill_req(&mut m, 1, 8); // 2 sealed blocks/head/layer
        m.register(2);
        m.adopt_prefix(2, 1, 8).unwrap();
        let budget = 3;
        let s = budget * 4;
        let sel = vec![vec![0u32, 1u32], vec![0u32, 1u32]];
        let mut ko = vec![0.0; 2 * s * 4];
        let mut vo = vec![0.0; 2 * s * 4];
        let mut mo = vec![0.0; 2 * s];
        // donor's gather pays the loads under the slot-keyed entries...
        m.gather_into(1, 0, &sel, budget, &mut ko, &mut vo, &mut mo).unwrap();
        let it1 = m.end_iteration();
        assert_eq!(it1.blocks_loaded, 4);
        // ...and the sharer's gather of the SAME blocks is all hits
        let mut ko2 = vec![0.0; 2 * s * 4];
        let mut vo2 = vec![0.0; 2 * s * 4];
        let mut mo2 = vec![0.0; 2 * s];
        m.gather_into(2, 0, &sel, budget, &mut ko2, &mut vo2, &mut mo2).unwrap();
        let it2 = m.end_iteration();
        assert_eq!(it2.blocks_loaded, 0, "one sharer's load is every sharer's hit");
        assert_eq!(ko2, ko, "shared residency reads the same bytes");
        // donor finishing does not evict the shared residency...
        m.release(1);
        m.gather_into(2, 0, &sel, budget, &mut ko2, &mut vo2, &mut mo2).unwrap();
        let it3 = m.end_iteration();
        assert_eq!(it3.blocks_loaded, 0, "residency outlives the donor");
        // ...but the LAST release tears it down
        m.release(2);
        assert_eq!(m.hbm_bytes_used(), 0);
        assert_eq!(m.dram_bytes_used(), 0);
    }

    #[test]
    fn shared_prefetch_stage_is_cancelled_at_last_release() {
        let mut m = mk_manager(true, 64);
        prefill_req(&mut m, 1, 8);
        m.register(2);
        m.adopt_prefix(2, 1, 8).unwrap();
        // stage a shared block: the plan key is per-request, the stage
        // lands under the slot-keyed shared entry
        let plan = [BlockKey::new(1, 0, 0, 0), BlockKey::new(2, 0, 1, 0)];
        assert_eq!(m.prefetch_working_set(&plan, 64, 0, false), 2);
        // the same blocks named through the OTHER sharer are already
        // resident — skip-resident sees the shared entry
        let plan2 = [BlockKey::new(2, 0, 0, 0), BlockKey::new(1, 0, 1, 0)];
        assert_eq!(m.prefetch_working_set(&plan2, 64, 0, false), 0);
        // releasing one sharer cancels nothing (its id keys no stages)...
        m.release(1);
        assert_eq!(m.prefetch_stats().cancelled, 0);
        // ...the last sharer's release cancels the orphaned stages and
        // returns their pins (pin conservation at shared teardown)
        m.release(2);
        assert_eq!(m.prefetch_stats().cancelled, 2);
        assert_eq!(m.hbm_bytes_used(), 0);
        let iter = m.end_iteration();
        assert_eq!(iter.prefetch_wasted, 0, "cancelled stages are not wasted");
    }

    #[test]
    fn drain_of_a_sharer_deep_copies_and_leaves_the_donor_whole() {
        let mut m = mk_manager(true, 64);
        prefill_req(&mut m, 1, 8);
        m.register(2);
        m.adopt_prefix(2, 1, 8).unwrap();
        let used_shared = m.dram_bytes_used();
        // the payload carries FULL bytes: sharing never crosses the
        // migration boundary
        let drained = m.drain_request(2).expect("sharer must drain");
        assert_eq!(drained.total_bytes(), 8 * m.block_bytes());
        let donor_slot = m.requests[&1].blocks[0][0][0];
        assert_eq!(m.slot_ref_count(donor_slot), 1, "drain returned its refs");
        assert_eq!(m.dram_bytes_used(), used_shared, "donor keeps its slots");
        assert_eq!(m.seq_len(1), 8);
        // import on the far side is fully private KV
        let mut dst = mk_manager(true, 64);
        dst.import_request(drained).unwrap();
        assert_eq!(dst.seq_len(2), 8);
        assert_eq!(dst.n_shared_slots(), 0);
        m.release(1);
        assert_eq!(m.dram_bytes_used(), 0);
    }
}
