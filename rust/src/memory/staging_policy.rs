//! The shared staging-admission policy.
//!
//! Both staging paths — `KvManager::prefetch_working_set` (real backend,
//! per-head blocks, async FlashH2D copies) and the simulator's
//! group-granular staging — used to duplicate the same three rules and
//! had already started to drift. The policy now lives here, once:
//!
//! 1. **skip-resident**: a block already in the HBM cache costs nothing
//!    to "stage" — skip it without consuming the staging budget;
//! 2. **headroom**: stop as soon as staging one more block would leave
//!    fewer than `headroom` free-or-evictable slots, so a burst of
//!    speculative stages can never pin HBM shut and turn an unpredicted
//!    demand miss into a spurious `HbmExhausted` eviction;
//! 3. **pin + mark**: a staged block is inserted, pinned until consumed
//!    (hit) or retired (wasted), and registered with the
//!    [`PrefetchEngine`] — for this iteration or, for cross-iteration
//!    hints, deferred to the next one.

use super::cache::LruCache;
use super::prefetch::PrefetchEngine;
use super::BlockKey;

/// Per-call staging limits (rule 2 plus the per-iteration cap).
#[derive(Debug, Clone, Copy)]
pub struct StagingPolicy {
    /// Cap on blocks staged by this staging pass.
    pub max_blocks: usize,
    /// Free-or-evictable slots that must remain for demand misses.
    pub headroom: usize,
}

/// What the policy decided for one candidate block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageAdmission {
    /// Stage it (insert + pin + mark).
    Admit,
    /// Already resident: skip for free, keep going.
    SkipResident,
    /// Budget or headroom exhausted: stop staging entirely.
    Stop,
}

impl StagingPolicy {
    /// Decide one candidate given `staged` blocks already admitted by
    /// this pass.
    pub fn admit<V>(&self, cache: &LruCache<V>, key: &BlockKey, staged: usize) -> StageAdmission {
        if staged >= self.max_blocks {
            return StageAdmission::Stop;
        }
        if cache.contains(key) {
            return StageAdmission::SkipResident;
        }
        let free_after = cache.capacity().saturating_sub(cache.pinned_len() + 1);
        if !cache.can_accept() || free_after < self.headroom {
            return StageAdmission::Stop; // would squeeze out demand misses
        }
        StageAdmission::Admit
    }
}

/// Rule 3, shared verbatim by both backends: insert the entry, pin it
/// until consumed/retired, and register it with the prefetch engine
/// (`defer` = cross-iteration hint, retired one iteration later).
/// Returns the entry the insert evicted, if any (the caller frees its
/// HBM slot; the simulator's `()` values need nothing).
pub fn stage_block<V>(
    cache: &mut LruCache<V>,
    prefetcher: &mut PrefetchEngine,
    key: BlockKey,
    value: V,
    bytes: usize,
    defer: bool,
) -> Option<(BlockKey, V)> {
    let evicted = cache.insert(key, value);
    cache.pin(&key);
    if defer {
        prefetcher.mark_staged_deferred(key, bytes);
    } else {
        prefetcher.mark_staged(key, bytes);
    }
    evicted
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn key(b: u32) -> BlockKey {
        BlockKey::new(1, 0, 0, b)
    }

    #[test]
    fn admission_rules_in_order() {
        let mut cache: LruCache<u32> = LruCache::new(4);
        let policy = StagingPolicy { max_blocks: 2, headroom: 1 };
        assert_eq!(policy.admit(&cache, &key(0), 0), StageAdmission::Admit);
        cache.insert(key(0), 0);
        // resident blocks are free skips
        assert_eq!(policy.admit(&cache, &key(0), 1), StageAdmission::SkipResident);
        // budget cap stops the pass
        assert_eq!(policy.admit(&cache, &key(1), 2), StageAdmission::Stop);
        // headroom: 4 slots, 3 pinned -> staging one more leaves 0 free
        for b in 1..4u32 {
            cache.insert(key(b), b);
        }
        for b in 0..3u32 {
            cache.pin(&key(b));
        }
        assert_eq!(policy.admit(&cache, &key(9), 0), StageAdmission::Stop);
        cache.unpin(&key(0));
        cache.unpin(&key(1));
        assert_eq!(policy.admit(&cache, &key(9), 0), StageAdmission::Admit);
    }

    #[test]
    fn stage_block_pins_and_marks() {
        let mut cache: LruCache<u32> = LruCache::new(2);
        let mut pf = PrefetchEngine::new(0);
        assert!(stage_block(&mut cache, &mut pf, key(0), 7, 100, false).is_none());
        assert_eq!(cache.pinned_len(), 1);
        assert!(pf.is_staged(&key(0)));
        assert_eq!(pf.stats.issued_blocks, 1);
        // deferred marking goes through the same path
        stage_block(&mut cache, &mut pf, key(1), 8, 100, true);
        assert_eq!(pf.stats.deferred, 1);
        assert_eq!(cache.pinned_len(), 2);
    }
}
