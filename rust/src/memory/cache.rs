//! LRU residency cache: which DRAM blocks currently have an HBM copy.
//!
//! Paper §3.1: "The remaining HBM is used to cache frequently accessed KV
//! blocks and we employ the least recently used (LRU) cache eviction
//! policy", justified by the temporal locality of block selection
//! (consecutive query tokens select similar blocks, Fig. 8).
//!
//! Pinned entries (in use by the current iteration's gather) are never
//! evicted. Generic over the cached value (an HBM `SlotId` for the real
//! backend; `()` for the simulator, which only tracks residency).

use std::collections::{BTreeSet, HashMap, HashSet};

use super::BlockKey;

#[derive(Debug)]
struct Entry<V> {
    value: V,
    last_use: u64,
    pins: u32,
}

/// §Perf note: recency is indexed by a `BTreeSet<(last_use, key)>` so
/// get/insert/evict are O(log n) instead of the original O(n)
/// min-scan per eviction (8.8 µs -> ~0.6 µs per op at 1k residents,
/// see EXPERIMENTS.md §Perf). `remove_request` is likewise indexed by a
/// per-request key set instead of scanning the whole map.
#[derive(Debug)]
pub struct LruCache<V> {
    capacity: usize,
    map: HashMap<BlockKey, Entry<V>>,
    /// (last_use, key) ordered oldest-first.
    order: BTreeSet<(u64, BlockKey)>,
    /// Per-request resident keys (O(request) removal on release).
    by_req: HashMap<u32, HashSet<BlockKey>>,
    /// Entries with `pins > 0` (cheap `can_accept` check).
    pinned_entries: usize,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl<V> LruCache<V> {
    /// A capacity of 0 is clamped to 1: a zero-slot cache would have to
    /// evict from an empty order set on the first insert and violate the
    /// `len <= capacity` invariant.
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            capacity,
            map: HashMap::with_capacity(capacity.min(1 << 20)),
            order: BTreeSet::new(),
            by_req: HashMap::new(),
            pinned_entries: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: &BlockKey) -> bool {
        self.map.contains_key(key)
    }

    /// Look up a block, refreshing recency and counting hit/miss.
    pub fn get(&mut self, key: &BlockKey) -> Option<&V> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some(e) => {
                self.order.remove(&(e.last_use, *key));
                e.last_use = self.tick;
                self.order.insert((e.last_use, *key));
                self.hits += 1;
                Some(&e.value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Peek without touching recency or stats.
    pub fn peek(&self, key: &BlockKey) -> Option<&V> {
        self.map.get(key).map(|e| &e.value)
    }

    /// Insert a block. If at capacity, evicts the least recently used
    /// unpinned entry first and returns it as `(key, value)`.
    /// Panics if full of pinned entries (the batch-control invariant
    /// guarantees the working set fits; violating it is a scheduler bug).
    #[allow(clippy::expect_used)]
    pub fn insert(&mut self, key: BlockKey, value: V) -> Option<(BlockKey, V)> {
        debug_assert!(!self.map.contains_key(&key), "re-inserting resident {key:?}");
        self.tick += 1;
        let mut evicted = None;
        if self.map.len() >= self.capacity {
            let (victim, v) = self
                .evict_lru()
                // sparselint: allow(no-panic) -- documented panic invariant: batch control guarantees the working set fits; a pinned-full cache is a scheduler bug, and the exact message is pinned by a should_panic test
                .expect("LRU cache full of pinned entries (working set exceeds HBM)");
            evicted = Some((victim, v));
        }
        self.map.insert(key, Entry { value, last_use: self.tick, pins: 0 });
        self.order.insert((self.tick, key));
        self.by_req.entry(key.req).or_default().insert(key);
        evicted
    }

    /// Whether an insert can succeed without panicking: either there is a
    /// free slot, or at least one resident entry is unpinned (evictable).
    /// Prefetch staging checks this so it never stages past what the
    /// cache can hold.
    pub fn can_accept(&self) -> bool {
        self.map.len() < self.capacity || self.pinned_entries < self.map.len()
    }

    /// Resident entries currently pinned (prefetch staging headroom
    /// accounting: `capacity - pinned_len` slots remain free or
    /// evictable for demand misses).
    pub fn pinned_len(&self) -> usize {
        self.pinned_entries
    }

    /// Remove a specific block (e.g. on request completion).
    pub fn remove(&mut self, key: &BlockKey) -> Option<V> {
        let e = self.map.remove(key)?;
        self.order.remove(&(e.last_use, *key));
        if e.pins > 0 {
            self.pinned_entries -= 1;
        }
        if let Some(set) = self.by_req.get_mut(&key.req) {
            set.remove(key);
            if set.is_empty() {
                self.by_req.remove(&key.req);
            }
        }
        Some(e.value)
    }

    /// Remove every block of a request; returns the values (HBM slots to
    /// free). O(blocks of the request) via the per-request index.
    pub fn remove_request(&mut self, req: u32) -> Vec<V> {
        let keys: Vec<BlockKey> = self
            .by_req
            .remove(&req)
            .map(|set| set.into_iter().collect())
            .unwrap_or_default();
        keys.iter().filter_map(|k| self.remove(k)).collect()
    }

    /// Evict the least recently used *unpinned* entry, returning it.
    /// O(log n) plus a skip over currently pinned entries (few: only the
    /// in-flight gather and prefetch stages pin).
    pub fn evict_lru(&mut self) -> Option<(BlockKey, V)> {
        let victim = self
            .order
            .iter()
            .map(|(_, k)| *k)
            .find(|k| self.map.get(k).map(|e| e.pins == 0).unwrap_or(false))?;
        self.evictions += 1;
        let value = self.remove(&victim)?;
        Some((victim, value))
    }

    pub fn pin(&mut self, key: &BlockKey) {
        if let Some(e) = self.map.get_mut(key) {
            if e.pins == 0 {
                self.pinned_entries += 1;
            }
            e.pins += 1;
        }
    }

    pub fn unpin(&mut self, key: &BlockKey) {
        if let Some(e) = self.map.get_mut(key) {
            debug_assert!(e.pins > 0, "unpin of unpinned {key:?}");
            if e.pins > 0 {
                e.pins -= 1;
                if e.pins == 0 {
                    self.pinned_entries -= 1;
                }
            }
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn key(b: u32) -> BlockKey {
        BlockKey::new(0, 0, 0, b)
    }

    #[test]
    fn hit_miss_counting() {
        let mut c = LruCache::new(2);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), 10);
        assert_eq!(c.get(&key(1)), Some(&10));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn evicts_lru_order() {
        let mut c = LruCache::new(2);
        c.insert(key(1), 1);
        c.insert(key(2), 2);
        c.get(&key(1)); // 2 is now LRU
        let ev = c.insert(key(3), 3).unwrap();
        assert_eq!(ev, (key(2), 2));
        assert!(c.contains(&key(1)) && c.contains(&key(3)));
    }

    #[test]
    fn pinned_entries_survive_eviction() {
        let mut c = LruCache::new(2);
        c.insert(key(1), 1);
        c.insert(key(2), 2);
        c.pin(&key(1)); // 1 is LRU but pinned
        let ev = c.insert(key(3), 3).unwrap();
        assert_eq!(ev.0, key(2));
        c.unpin(&key(1));
        let ev = c.insert(key(4), 4).unwrap();
        assert_eq!(ev.0, key(1)); // unpinned now evictable
    }

    #[test]
    #[should_panic(expected = "pinned")]
    fn all_pinned_panics() {
        let mut c = LruCache::new(1);
        c.insert(key(1), 1);
        c.pin(&key(1));
        c.insert(key(2), 2);
    }

    #[test]
    fn capacity_zero_is_clamped_and_len_stays_bounded() {
        // A 0-capacity cache used to evict from an empty order set and
        // still insert, letting len > capacity. It now clamps to 1.
        let mut c = LruCache::new(0);
        assert_eq!(c.capacity(), 1);
        c.insert(key(1), 1);
        assert_eq!(c.len(), 1);
        let ev = c.insert(key(2), 2).unwrap();
        assert_eq!(ev, (key(1), 1));
        assert_eq!(c.len(), 1);
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn can_accept_tracks_pinned_saturation() {
        let mut c = LruCache::new(2);
        assert!(c.can_accept());
        c.insert(key(1), 1);
        c.insert(key(2), 2);
        c.pin(&key(1));
        assert!(c.can_accept(), "one unpinned entry remains evictable");
        c.pin(&key(2));
        assert!(!c.can_accept(), "full of pinned entries");
        c.unpin(&key(2));
        assert!(c.can_accept());
        // double-pin keeps the entry counted once
        c.pin(&key(1));
        c.unpin(&key(1));
        assert!(!c.evict_lru().map(|(k, _)| k == key(1)).unwrap_or(false));
    }

    #[test]
    fn remove_request_clears_only_that_request() {
        let mut c = LruCache::new(8);
        c.insert(BlockKey::new(1, 0, 0, 0), 10);
        c.insert(BlockKey::new(1, 1, 0, 0), 11);
        c.insert(BlockKey::new(2, 0, 0, 0), 20);
        let freed = c.remove_request(1);
        assert_eq!(freed.len(), 2);
        assert_eq!(c.len(), 1);
        assert!(c.contains(&BlockKey::new(2, 0, 0, 0)));
    }

    #[test]
    fn prop_never_exceeds_capacity() {
        prop::check("lru capacity bound", 50, |rng: &mut Rng| {
            let cap = 1 + rng.below(8);
            let mut c = LruCache::new(cap);
            for i in 0..100u32 {
                let k = key(rng.below(20) as u32);
                if c.get(&k).is_none() && !c.contains(&k) {
                    c.insert(k, i);
                }
                prop::assert_prop(c.len() <= cap, "len > capacity")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_eviction_is_least_recent_unpinned() {
        prop::check("lru order", 30, |rng: &mut Rng| {
            let mut c = LruCache::new(4);
            let mut uses: std::collections::HashMap<u32, u64> = Default::default();
            let mut t = 0u64;
            for _ in 0..60 {
                let b = rng.below(10) as u32;
                t += 1;
                if c.get(&key(b)).is_some() {
                    uses.insert(b, t);
                } else {
                    if let Some((ev, _)) = c.insert(key(b), 0) {
                        // evicted block must be the min-last-use among residents+victim
                        let ev_use = uses.get(&ev.block).copied().unwrap_or(0);
                        // (skip the just-inserted block: its `uses` entry, if
                        // any, is stale from a previous residency)
                        for k in uses.keys().filter(|k| **k != b) {
                            if c.contains(&key(*k)) {
                                prop::assert_prop(
                                    uses[k] >= ev_use,
                                    "evicted a more recently used block",
                                )?;
                            }
                        }
                    }
                    uses.insert(b, t);
                }
            }
            Ok(())
        });
    }
}
