//! Working-set prefetch engine: asynchronous HBM staging of predicted
//! KV blocks.
//!
//! Between iterations, a prefetch planner ranks each scheduled decode
//! request's `WorkingSetTracker` union (most recent step first) and
//! stages non-resident blocks into the HBM cache ahead of the batch.
//! Staged blocks are *pinned* in the [`super::LruCache`] until they are
//! consumed by a gather (a prefetch **hit**) or the iteration ends
//! without touching them (a **wasted** prefetch — the block stays
//! resident but unpinned). This is what converts selection-time cache
//! misses into hits and lets HBM↔DRAM traffic overlap compute instead
//! of stalling it (the copy stream of the iteration event models in
//! `sim::cost` — `layered_iter` / `two_stream_iter`). Cross-iteration
//! staging hints are marked *deferred*: issued under the current batch's
//! compute for the NEXT iteration's gathers, retired one iteration
//! later.
//!
//! The engine itself is cache-agnostic bookkeeping plus an optional
//! [`ThreadPool`] for the real backend's asynchronous FlashH2D copies:
//! the owner (the `KvManager` or the simulator) reserves HBM slots and
//! pins cache entries synchronously, then hands the byte movement to the
//! pool and calls [`PrefetchEngine::wait_staged`] before anything reads
//! the staged slots.

use std::collections::HashSet;

use crate::util::threadpool::ThreadPool;

use super::BlockKey;

/// Cumulative prefetch accounting (surfaced in `RunMetrics`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefetchStats {
    /// Blocks staged ahead of need.
    pub issued_blocks: u64,
    /// Bytes staged ahead of need.
    pub issued_bytes: u64,
    /// Staged blocks consumed by a gather before their iteration ended.
    pub hits: u64,
    /// Staged blocks their iteration never touched (misprediction).
    pub wasted: u64,
    /// Staged blocks dropped because their request was released first.
    pub cancelled: u64,
    /// Blocks staged for the *next* iteration (cross-iteration staging
    /// hints): issued under the current batch's compute, retired only at
    /// the end of the iteration they were staged for.
    pub deferred: u64,
}

impl PrefetchStats {
    /// Fraction of issued blocks that were consumed (0 when none issued).
    pub fn hit_rate(&self) -> f64 {
        if self.issued_blocks == 0 {
            0.0
        } else {
            self.hits as f64 / self.issued_blocks as f64
        }
    }
}

/// Raw-pointer wrappers for the disjoint-slot async copies (same pattern
/// as FlashD2H's parallel scatter). Safety contract: the owner guarantees
/// every in-flight job reads/writes slots no other thread touches until
/// [`PrefetchEngine::wait_staged`] returns.
pub struct SendConst(pub *const f32);
unsafe impl Send for SendConst {}
pub struct SendMut(pub *mut f32);
unsafe impl Send for SendMut {}

pub struct PrefetchEngine {
    /// Copy workers for the real backend; `None` = bookkeeping only
    /// (the simulator moves no real bytes).
    pool: Option<ThreadPool>,
    /// Blocks staged (and pinned by the owner) but not yet consumed.
    staged: HashSet<BlockKey>,
    /// Blocks staged for the NEXT iteration (cross-iteration hints):
    /// promoted into `staged` at `end_iteration` instead of being retired
    /// as wasted, so a hint issued under batch N's compute can earn its
    /// hit in batch N+1.
    staged_next: HashSet<BlockKey>,
    pub stats: PrefetchStats,
}

impl PrefetchEngine {
    /// `copy_workers == 0` disables the thread pool (simulator mode).
    pub fn new(copy_workers: usize) -> Self {
        Self {
            pool: (copy_workers > 0).then(|| ThreadPool::new(copy_workers)),
            staged: HashSet::new(),
            staged_next: HashSet::new(),
            stats: PrefetchStats::default(),
        }
    }

    pub fn n_staged(&self) -> usize {
        self.staged.len() + self.staged_next.len()
    }

    pub fn is_staged(&self, key: &BlockKey) -> bool {
        self.staged.contains(key) || self.staged_next.contains(key)
    }

    /// Record a block as staged. Returns false (and counts nothing) if it
    /// was already staged.
    pub fn mark_staged(&mut self, key: BlockKey, bytes: usize) -> bool {
        if self.staged_next.contains(&key) || !self.staged.insert(key) {
            return false;
        }
        self.stats.issued_blocks += 1;
        self.stats.issued_bytes += bytes as u64;
        true
    }

    /// Record a block as staged for the *next* iteration (cross-iteration
    /// staging hint). It survives one `end_iteration` (promoted, not
    /// wasted) and is retired at the end of the iteration after that.
    pub fn mark_staged_deferred(&mut self, key: BlockKey, bytes: usize) -> bool {
        if self.staged.contains(&key) || !self.staged_next.insert(key) {
            return false;
        }
        self.stats.issued_blocks += 1;
        self.stats.issued_bytes += bytes as u64;
        self.stats.deferred += 1;
        true
    }

    /// Run a copy job: on the pool when one exists, inline otherwise.
    pub fn submit_copy<F: FnOnce() + Send + 'static>(&self, job: F) {
        match &self.pool {
            Some(pool) => pool.submit(job),
            None => job(),
        }
    }

    /// Block until every in-flight staging copy has landed. Must be
    /// called before reading a staged slot or freeing a source slot.
    pub fn wait_staged(&self) {
        if let Some(pool) = &self.pool {
            pool.wait_idle();
        }
    }

    /// A gather touched `key`: if it was staged (for this iteration or
    /// deferred for the next), count the hit and stop tracking it (the
    /// owner drops the stage pin). Returns whether the access consumed a
    /// staged block.
    pub fn note_access(&mut self, key: &BlockKey) -> bool {
        if self.staged.remove(key) || self.staged_next.remove(key) {
            self.stats.hits += 1;
            true
        } else {
            false
        }
    }

    /// End the iteration: every still-staged block of THIS iteration was
    /// mispredicted; deferred (next-iteration) stages are promoted and
    /// get one more iteration to earn their hit. Returns the wasted keys
    /// so the owner can drop their stage pins (they stay resident as
    /// ordinary LRU entries).
    ///
    /// Under the pipelined executor this is the seam where deferred
    /// stages cross the pipeline boundary: a hint staged for a
    /// speculatively-planned batch is promoted here even if that
    /// speculation is later invalidated — the bytes are already resident
    /// and the re-planned batch consumes them (hit) or retires them as
    /// wasted one iteration later. Only `cancel_request` (eviction of
    /// the hinted request) removes them early.
    pub fn end_iteration(&mut self) -> Vec<BlockKey> {
        let wasted: Vec<BlockKey> = self.staged.drain().collect();
        self.stats.wasted += wasted.len() as u64;
        self.staged = std::mem::take(&mut self.staged_next);
        self.debug_assert_conserved();
        wasted
    }

    /// Drop every staged block of a released/cancelled request. Returns
    /// the keys so the owner can release their stage pins.
    pub fn cancel_request(&mut self, req: u32) -> Vec<BlockKey> {
        let mut dropped: Vec<BlockKey> =
            self.staged.iter().filter(|k| k.req == req).copied().collect();
        dropped.extend(self.staged_next.iter().filter(|k| k.req == req).copied());
        for k in &dropped {
            self.staged.remove(k);
            self.staged_next.remove(k);
        }
        self.stats.cancelled += dropped.len() as u64;
        self.debug_assert_conserved();
        dropped
    }

    /// Drop one staged block (shared-prefix teardown: a slot-keyed
    /// residency entry dies when its LAST sharer releases, which
    /// `cancel_request` — keyed by request id — cannot see). Returns
    /// whether the key was staged, so the owner can drop its stage pin.
    pub fn cancel_key(&mut self, key: &BlockKey) -> bool {
        let was = self.staged.remove(key) || self.staged_next.remove(key);
        if was {
            self.stats.cancelled += 1;
        }
        self.debug_assert_conserved();
        was
    }

    /// Counter conservation: every issued block is, at any instant,
    /// exactly one of still-staged / hit / wasted / cancelled. The
    /// pipelined executor makes this load-bearing: deferred stages
    /// issued for a speculatively-planned batch retire one iteration
    /// AFTER the one that issued them, and a mid-pipeline eviction must
    /// route them through `cancel_request` — never strand them staged
    /// forever nor count them both wasted and cancelled.
    fn debug_assert_conserved(&self) {
        debug_assert_eq!(
            self.stats.issued_blocks,
            self.stats.hits + self.stats.wasted + self.stats.cancelled + self.n_staged() as u64,
            "prefetch counter conservation violated"
        );
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn key(req: u32, b: u32) -> BlockKey {
        BlockKey::new(req, 0, 0, b)
    }

    #[test]
    fn staged_blocks_become_hits() {
        let mut e = PrefetchEngine::new(0);
        assert!(e.mark_staged(key(1, 0), 100));
        assert!(e.mark_staged(key(1, 1), 100));
        assert!(!e.mark_staged(key(1, 0), 100), "double-stage is a no-op");
        assert_eq!(e.stats.issued_blocks, 2);
        assert_eq!(e.stats.issued_bytes, 200);
        assert!(e.note_access(&key(1, 0)), "staged access is a hit");
        assert!(!e.note_access(&key(1, 0)), "hit consumed the staging");
        assert!(!e.note_access(&key(2, 7)), "unstaged access is not a hit");
        assert_eq!(e.stats.hits, 1);
        assert!((e.stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn unconsumed_stages_count_as_wasted() {
        let mut e = PrefetchEngine::new(0);
        e.mark_staged(key(1, 0), 10);
        e.mark_staged(key(1, 1), 10);
        e.note_access(&key(1, 0));
        let wasted = e.end_iteration();
        assert_eq!(wasted, vec![key(1, 1)]);
        assert_eq!(e.stats.wasted, 1);
        assert_eq!(e.n_staged(), 0);
    }

    #[test]
    fn deferred_stages_survive_one_iteration_then_waste() {
        let mut e = PrefetchEngine::new(0);
        assert!(e.mark_staged_deferred(key(1, 0), 10));
        assert!(!e.mark_staged_deferred(key(1, 0), 10), "double-defer is a no-op");
        assert!(!e.mark_staged(key(1, 0), 10), "already deferred");
        assert_eq!(e.stats.deferred, 1);
        // first end: promoted, NOT wasted
        assert!(e.end_iteration().is_empty());
        assert_eq!(e.stats.wasted, 0);
        assert!(e.is_staged(&key(1, 0)));
        // second end without a touch: now it is a misprediction
        assert_eq!(e.end_iteration(), vec![key(1, 0)]);
        assert_eq!(e.stats.wasted, 1);
    }

    #[test]
    fn deferred_stage_hit_next_iteration() {
        let mut e = PrefetchEngine::new(0);
        e.mark_staged_deferred(key(1, 0), 10);
        // hit before promotion also counts (the current batch used it)
        e.mark_staged_deferred(key(1, 1), 10);
        assert!(e.note_access(&key(1, 1)));
        e.end_iteration();
        assert!(e.note_access(&key(1, 0)), "promoted stage must hit");
        assert_eq!(e.stats.hits, 2);
        assert!(e.end_iteration().is_empty());
    }

    #[test]
    fn cancel_drops_deferred_stages_too() {
        let mut e = PrefetchEngine::new(0);
        e.mark_staged(key(1, 0), 10);
        e.mark_staged_deferred(key(1, 1), 10);
        e.mark_staged_deferred(key(2, 0), 10);
        let dropped = e.cancel_request(1);
        assert_eq!(dropped.len(), 2);
        assert_eq!(e.stats.cancelled, 2);
        assert_eq!(e.n_staged(), 1);
    }

    #[test]
    fn cancel_drops_only_that_requests_stages() {
        let mut e = PrefetchEngine::new(0);
        e.mark_staged(key(1, 0), 10);
        e.mark_staged(key(1, 1), 10);
        e.mark_staged(key(2, 0), 10);
        let dropped = e.cancel_request(1);
        assert_eq!(dropped.len(), 2);
        assert_eq!(e.stats.cancelled, 2);
        assert_eq!(e.n_staged(), 1);
        assert!(e.is_staged(&key(2, 0)));
    }

    #[test]
    fn cancel_key_drops_one_stage_and_counts_it() {
        let mut e = PrefetchEngine::new(0);
        e.mark_staged(key(1, 0), 10);
        e.mark_staged_deferred(key(1, 1), 10);
        assert!(e.cancel_key(&key(1, 1)), "deferred stage is cancellable");
        assert!(!e.cancel_key(&key(9, 9)), "unstaged key is a no-op");
        assert_eq!(e.stats.cancelled, 1);
        assert_eq!(e.n_staged(), 1);
        assert!(e.note_access(&key(1, 0)));
        assert_eq!(
            e.stats.issued_blocks,
            e.stats.hits + e.stats.wasted + e.stats.cancelled
        );
    }

    #[test]
    fn counters_conserve_across_the_pipeline_boundary() {
        let mut e = PrefetchEngine::new(0);
        e.mark_staged(key(1, 0), 10);
        e.mark_staged_deferred(key(1, 1), 10); // crosses the boundary
        e.mark_staged_deferred(key(2, 0), 10);
        e.end_iteration(); // retires key(1,0), promotes both deferred
        // mid-pipeline eviction: request 1's surviving stage must be
        // cancelled, not stranded staged or double-counted
        e.cancel_request(1);
        assert!(e.note_access(&key(2, 0)));
        let s = e.stats;
        assert_eq!(s.wasted, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.hits, 1);
        assert_eq!(
            s.issued_blocks,
            s.hits + s.wasted + s.cancelled + e.n_staged() as u64
        );
    }

    #[test]
    fn pool_runs_copies_and_wait_joins() {
        let e = PrefetchEngine::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            e.submit_copy(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        e.wait_staged();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn no_pool_runs_inline() {
        let e = PrefetchEngine::new(0);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        e.submit_copy(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }
}
