//! Fixed-size block arenas: the HBM and DRAM stand-ins.
//!
//! Both tiers are "organized into fixed-size blocks to mitigate memory
//! fragmentation" (paper §3.1, after PagedAttention). A slot holds one
//! per-head KV block: the K plane `[Bs, Dh]` followed by the V plane
//! `[Bs, Dh]`, row-major f32.

/// Index of a block slot within one pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlotId(pub u32);

#[derive(Debug)]
pub struct BlockPool {
    data: Vec<f32>,
    /// Floats per slot (= 2 * block_size * head_dim).
    slot_floats: usize,
    free: Vec<SlotId>,
    n_slots: usize,
}

impl BlockPool {
    /// A pool of `n_slots` blocks of `block_size x head_dim` KV each.
    pub fn new(n_slots: usize, block_size: usize, head_dim: usize) -> Self {
        let slot_floats = 2 * block_size * head_dim;
        Self {
            data: vec![0.0; n_slots * slot_floats],
            slot_floats,
            free: (0..n_slots as u32).rev().map(SlotId).collect(),
            n_slots,
        }
    }

    /// Pool sized by a byte budget (HBM/DRAM capacity).
    pub fn with_capacity_bytes(bytes: usize, block_size: usize, head_dim: usize) -> Self {
        let slot_bytes = 2 * block_size * head_dim * 4;
        Self::new(bytes / slot_bytes, block_size, head_dim)
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_used(&self) -> usize {
        self.n_slots - self.free.len()
    }

    pub fn slot_floats(&self) -> usize {
        self.slot_floats
    }

    pub fn slot_bytes(&self) -> usize {
        self.slot_floats * 4
    }

    pub fn alloc(&mut self) -> Option<SlotId> {
        self.free.pop()
    }

    /// Return a slot to the free list. Double frees are a logic error.
    pub fn free(&mut self, slot: SlotId) {
        debug_assert!(
            !self.free.contains(&slot),
            "double free of slot {slot:?}"
        );
        debug_assert!((slot.0 as usize) < self.n_slots);
        self.free.push(slot);
    }

    #[inline]
    fn base(&self, slot: SlotId) -> usize {
        debug_assert!((slot.0 as usize) < self.n_slots);
        slot.0 as usize * self.slot_floats
    }

    /// Whole slot (K plane then V plane).
    pub fn slot(&self, slot: SlotId) -> &[f32] {
        let b = self.base(slot);
        &self.data[b..b + self.slot_floats]
    }

    pub fn slot_mut(&mut self, slot: SlotId) -> &mut [f32] {
        let b = self.base(slot);
        &mut self.data[b..b + self.slot_floats]
    }

    /// K plane of a slot: `[Bs * Dh]` floats.
    pub fn k_plane(&self, slot: SlotId) -> &[f32] {
        let b = self.base(slot);
        &self.data[b..b + self.slot_floats / 2]
    }

    /// V plane of a slot.
    pub fn v_plane(&self, slot: SlotId) -> &[f32] {
        let b = self.base(slot) + self.slot_floats / 2;
        &self.data[b..b + self.slot_floats / 2]
    }

    /// Raw pointer to a slot for the (disjoint-slot) parallel scatter in
    /// FlashD2H. Safety: callers must guarantee slots are distinct.
    pub(crate) fn slot_ptr(&self, slot: SlotId) -> *mut f32 {
        let b = self.base(slot);
        self.data[b..].as_ptr() as *mut f32
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn alloc_free_cycle() {
        let mut p = BlockPool::new(4, 2, 3);
        assert_eq!(p.slot_floats(), 12);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.n_used(), 2);
        p.free(a);
        assert_eq!(p.n_free(), 3);
        // exhaust
        let mut got = vec![b];
        while let Some(s) = p.alloc() {
            got.push(s);
        }
        assert_eq!(got.len(), 4);
        assert!(p.alloc().is_none());
    }

    #[test]
    fn capacity_bytes_rounds_down() {
        // slot = 2*16*32*4 = 4096 B
        let p = BlockPool::with_capacity_bytes(10_000, 16, 32);
        assert_eq!(p.n_slots(), 2);
    }

    #[test]
    fn planes_are_disjoint_halves() {
        let mut p = BlockPool::new(2, 2, 2);
        let s = p.alloc().unwrap();
        p.slot_mut(s).copy_from_slice(&[1., 2., 3., 4., 5., 6., 7., 8.]);
        assert_eq!(p.k_plane(s), &[1., 2., 3., 4.]);
        assert_eq!(p.v_plane(s), &[5., 6., 7., 8.]);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn double_free_panics_in_debug() {
        let mut p = BlockPool::new(2, 2, 2);
        let s = p.alloc().unwrap();
        p.free(s);
        p.free(s);
    }

    #[test]
    fn prop_allocator_never_hands_out_duplicates() {
        prop::check("unique live slots", 50, |rng: &mut Rng| {
            let mut p = BlockPool::new(16, 2, 2);
            let mut live: Vec<SlotId> = Vec::new();
            for _ in 0..200 {
                if !live.is_empty() && rng.f64() < 0.45 {
                    let i = rng.below(live.len());
                    let s = live.swap_remove(i);
                    p.free(s);
                } else if let Some(s) = p.alloc() {
                    prop::assert_prop(!live.contains(&s), "duplicate live slot")?;
                    live.push(s);
                }
                prop::assert_eq_prop(p.n_used(), live.len(), "used count")?;
            }
            Ok(())
        });
    }
}
