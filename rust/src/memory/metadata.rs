//! Per-block cuboid metadata (ArkVale's bounding box, the paper's default).
//!
//! Metadata is built on-device for bulk prefill (the `block_meta_*`
//! artifact, an L1 pallas kernel) and incrementally on the host as decode
//! seals blocks — both produce the exact elementwise min/max, asserted by
//! the parity test in `rust/tests/pjrt_parity.rs`.

/// Bounding cuboid of a block's (roped) keys: per-dim min and max.
#[derive(Debug, Clone, PartialEq)]
pub struct Cuboid {
    pub lo: Vec<f32>,
    pub hi: Vec<f32>,
}

impl Cuboid {
    /// Identity element for running updates.
    pub fn empty(dim: usize) -> Self {
        Self { lo: vec![f32::INFINITY; dim], hi: vec![f32::NEG_INFINITY; dim] }
    }

    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    /// Fold one key row into the running bounds (decode-time open block).
    pub fn update(&mut self, key_row: &[f32]) {
        debug_assert_eq!(key_row.len(), self.lo.len());
        for (i, &x) in key_row.iter().enumerate() {
            self.lo[i] = self.lo[i].min(x);
            self.hi[i] = self.hi[i].max(x);
        }
    }

    /// Build from a sealed block's K plane `[n_tokens, dim]`.
    pub fn from_k_plane(k_plane: &[f32], dim: usize, n_tokens: usize) -> Self {
        debug_assert!(k_plane.len() >= n_tokens * dim);
        let mut c = Self::empty(dim);
        for t in 0..n_tokens {
            c.update(&k_plane[t * dim..(t + 1) * dim]);
        }
        c
    }

    /// The upper bound of q.k over the cuboid (host-side mirror of the L1
    /// scoring kernel; used by tests and the simulator's selection model).
    pub fn score(&self, q: &[f32]) -> f32 {
        debug_assert_eq!(q.len(), self.lo.len());
        q.iter()
            .zip(self.lo.iter().zip(self.hi.iter()))
            .map(|(&qd, (&lo, &hi))| (qd * lo).max(qd * hi))
            .sum()
    }

    /// Does the cuboid contain the key?
    pub fn contains(&self, key: &[f32]) -> bool {
        key.iter()
            .enumerate()
            .all(|(i, &x)| self.lo[i] <= x && x <= self.hi[i])
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn from_plane_matches_running_updates() {
        let dim = 4;
        let rows: Vec<f32> = (0..12).map(|i| (i as f32) * 0.7 - 3.0).collect();
        let built = Cuboid::from_k_plane(&rows, dim, 3);
        let mut run = Cuboid::empty(dim);
        for t in 0..3 {
            run.update(&rows[t * dim..(t + 1) * dim]);
        }
        assert_eq!(built, run);
    }

    #[test]
    fn prop_score_upper_bounds_exact_dot() {
        prop::check("cuboid score bound", 100, |rng: &mut Rng| {
            let dim = 8;
            let n = 1 + rng.below(16);
            let rows: Vec<f32> =
                (0..n * dim).map(|_| rng.normal() as f32).collect();
            let c = Cuboid::from_k_plane(&rows, dim, n);
            let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let bound = c.score(&q);
            for t in 0..n {
                let dot: f32 = q
                    .iter()
                    .zip(&rows[t * dim..(t + 1) * dim])
                    .map(|(a, b)| a * b)
                    .sum();
                prop::assert_prop(bound >= dot - 1e-4, "score below exact dot")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_contains_all_source_keys() {
        prop::check("cuboid containment", 50, |rng: &mut Rng| {
            let dim = 4;
            let n = 1 + rng.below(8);
            let rows: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
            let c = Cuboid::from_k_plane(&rows, dim, n);
            for t in 0..n {
                prop::assert_prop(
                    c.contains(&rows[t * dim..(t + 1) * dim]),
                    "key outside cuboid",
                )?;
            }
            Ok(())
        });
    }
}
