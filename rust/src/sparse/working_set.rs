//! Decode working-set estimation (paper §3.3, Fig. 8).
//!
//! The blocks a decode step selects cannot be known in advance, but
//! consecutive query tokens select highly overlapping sets (temporal
//! locality). The paper therefore estimates a request's working set as
//! the union of the blocks selected over the last `w` decode steps
//! (w = 12 by default: Fig. 8 shows the overlap gain saturates there —
//! +10.68% from w=1 to 12, +0.31% from 12 to 16).

use std::collections::{HashSet, VecDeque};

/// A (layer, head, block) selection item within one request.
pub type SelItem = (u16, u16, u32);

#[derive(Debug, Clone)]
pub struct WorkingSetTracker {
    window: usize,
    history: VecDeque<Vec<SelItem>>,
    /// Cached union (rebuilt lazily after updates).
    union: HashSet<SelItem>,
    dirty: bool,
}

impl WorkingSetTracker {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        Self {
            window,
            history: VecDeque::with_capacity(window + 1),
            union: HashSet::new(),
            dirty: false,
        }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Record one decode step's full selection (all layers/heads).
    pub fn record_step(&mut self, items: Vec<SelItem>) {
        self.history.push_back(items);
        while self.history.len() > self.window {
            self.history.pop_front();
        }
        self.dirty = true;
    }

    fn rebuild(&mut self) {
        if self.dirty {
            self.union.clear();
            for step in &self.history {
                self.union.extend(step.iter().copied());
            }
            self.dirty = false;
        }
    }

    /// Working-set size in blocks (union over the window).
    pub fn ws_blocks(&mut self) -> usize {
        self.rebuild();
        self.union.len()
    }

    /// Working-set bytes given the per-head block size.
    pub fn ws_bytes(&mut self, block_bytes: usize) -> usize {
        self.ws_blocks() * block_bytes
    }

    /// The window union ranked for prefetch: recency-weighted — blocks
    /// from the most recent step first (they have the highest re-selection
    /// probability, Fig. 8), then progressively older steps, deduplicated
    /// in first-seen order. A truncation of this list is the best
    /// prediction of the next step's selection under the paper's
    /// temporal-locality model.
    pub fn ranked_blocks(&self) -> Vec<SelItem> {
        self.ranked_blocks_capped(usize::MAX)
    }

    /// [`Self::ranked_blocks`] truncated to the first `cap` entries —
    /// the prefetch hot path consumes only a staging budget's worth, so
    /// stop ranking once it is filled.
    pub fn ranked_blocks_capped(&self, cap: usize) -> Vec<SelItem> {
        let mut seen: HashSet<SelItem> = HashSet::new();
        let mut out = Vec::new();
        'steps: for step in self.history.iter().rev() {
            for &item in step {
                if out.len() >= cap {
                    break 'steps;
                }
                if seen.insert(item) {
                    out.push(item);
                }
            }
        }
        out
    }

    /// Overlap ratio between the last recorded step and the union of the
    /// `w` steps before it (the Fig. 8 measurement).
    pub fn last_overlap(&self, w: usize) -> Option<f64> {
        if self.history.len() < 2 {
            return None;
        }
        let cur = self.history.back().unwrap();
        if cur.is_empty() {
            return None;
        }
        let mut prev: HashSet<SelItem> = HashSet::new();
        let n = self.history.len();
        let lo = n.saturating_sub(1 + w);
        for step in self.history.iter().skip(lo).take(n - 1 - lo) {
            prev.extend(step.iter().copied());
        }
        let inter = cur.iter().filter(|i| prev.contains(*i)).count();
        Some(inter as f64 / cur.len() as f64)
    }

    pub fn steps_recorded(&self) -> usize {
        self.history.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn items(blocks: &[u32]) -> Vec<SelItem> {
        blocks.iter().map(|&b| (0, 0, b)).collect()
    }

    #[test]
    fn union_over_window() {
        let mut t = WorkingSetTracker::new(3);
        t.record_step(items(&[0, 1]));
        t.record_step(items(&[1, 2]));
        assert_eq!(t.ws_blocks(), 3);
        t.record_step(items(&[2, 3]));
        assert_eq!(t.ws_blocks(), 4);
        // window slides: step {0,1} falls out
        t.record_step(items(&[2]));
        assert_eq!(t.ws_blocks(), 3); // {1,2,3} ∪ {2} minus {0,1}... = {1,2,3}
    }

    #[test]
    fn ranked_blocks_put_recent_steps_first() {
        let mut t = WorkingSetTracker::new(4);
        t.record_step(items(&[7, 8]));
        t.record_step(items(&[1, 2]));
        t.record_step(items(&[2, 3]));
        let ranked = t.ranked_blocks();
        // newest step {2,3} leads, then {1}, then the oldest {7,8}
        assert_eq!(ranked, items(&[2, 3, 1, 7, 8]));
        // dedup: union size matches ws_blocks
        assert_eq!(ranked.len(), t.ws_blocks());
        // capping truncates in rank order
        assert_eq!(t.ranked_blocks_capped(2), items(&[2, 3]));
        assert!(t.ranked_blocks_capped(0).is_empty());
    }

    #[test]
    fn ws_bytes_scales() {
        let mut t = WorkingSetTracker::new(2);
        t.record_step(items(&[0, 1, 2]));
        assert_eq!(t.ws_bytes(1024), 3 * 1024);
    }

    #[test]
    fn overlap_measures_locality() {
        let mut t = WorkingSetTracker::new(16);
        t.record_step(items(&[0, 1, 2, 3]));
        t.record_step(items(&[0, 1, 2, 9]));
        assert_eq!(t.last_overlap(1), Some(0.75));
        // wider window can only increase overlap
        t.record_step(items(&[3, 9]));
        assert_eq!(t.last_overlap(1), Some(0.5)); // {0,1,2,9} ∩ {3,9}
        assert_eq!(t.last_overlap(2), Some(1.0)); // {0..3,9} ∩ {3,9}
    }

    #[test]
    fn prop_ws_superset_of_latest_step_and_monotone_in_window() {
        prop::check("ws invariants", 60, |rng: &mut Rng| {
            let w = 1 + rng.below(8);
            let mut t = WorkingSetTracker::new(w);
            let mut last: Vec<SelItem> = Vec::new();
            for _ in 0..20 {
                let n = rng.below(6);
                last = (0..n).map(|_| (0u16, 0u16, rng.below(10) as u32)).collect();
                t.record_step(last.clone());
            }
            let ws = {
                t.rebuild();
                t.union.clone()
            };
            for item in &last {
                prop::assert_prop(ws.contains(item), "ws must contain latest step")?;
            }
            prop::assert_prop(
                t.history.len() <= w,
                "history exceeds window",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_overlap_monotone_in_window() {
        prop::check("overlap monotone", 40, |rng: &mut Rng| {
            let mut t = WorkingSetTracker::new(16);
            for _ in 0..10 {
                let n = 1 + rng.below(5);
                t.record_step((0..n).map(|_| (0, 0, rng.below(12) as u32)).collect());
            }
            let mut prev = 0.0;
            for w in 1..=8 {
                if let Some(o) = t.last_overlap(w) {
                    prop::assert_prop(o + 1e-12 >= prev, "overlap decreased with window")?;
                    prev = o;
                }
            }
            Ok(())
        });
    }
}
