//! Decode working-set estimation (paper §3.3, Fig. 8).
//!
//! The blocks a decode step selects cannot be known in advance, but
//! consecutive query tokens select highly overlapping sets (temporal
//! locality). The paper therefore estimates a request's working set as
//! the union of the blocks selected over the last `w` decode steps
//! (w = 12 by default: Fig. 8 shows the overlap gain saturates there —
//! +10.68% from w=1 to 12, +0.31% from 12 to 16).
//!
//! ## Hot-path contract (zero-clone step pipeline)
//!
//! The tracker sits on the per-iteration decode critical path, so it
//! supports allocation-free steady-state operation:
//!
//! - [`WorkingSetTracker::record_step_from`] copies a step into recycled
//!   storage (evicted window entries are reused, not freed);
//! - [`WorkingSetTracker::ranked_blocks_capped_into`] ranks into a
//!   caller-owned buffer using an internal, reused dedup set;
//! - `begin_txn` / `commit_txn` / `rollback_txn` form an incremental
//!   undo log (record-and-revert, mirroring
//!   `KvManager::{begin,commit,rollback}_txn`): a rolled-back step pops
//!   the recorded entries and restores the window-evicted ones instead
//!   of the old clone-the-whole-tracker snapshot.
//!
//! ## Prefetch ranking
//!
//! With [`Self::with_freq_ranking`] enabled the union is ordered
//! recency-first, then by each block's hit EWMA *within* the same
//! recency tier — a block selected in 10 of the last 12 steps outranks a
//! one-off from the same step (`ServingConfig::prefetch_freq_ranking`;
//! on for the `sparseserve` preset, off for the `+PF` ablation rung so
//! the ladder isolates plain recency prefetch).

use std::cell::Cell;
use std::collections::{HashMap, HashSet, VecDeque};

/// A (layer, head, block) selection item within one request. The
/// simulator records at layer-BAND granularity — its items are
/// `(band, 0, block)`, one per band of `ServingConfig::
/// sim_selection_bands` — while the real backend records true
/// `(layer, head, block)` triples; the tracker is granularity-agnostic
/// (union, ranking and frequency all key on the full item).
pub type SelItem = (u16, u16, u32);

/// EWMA smoothing for the per-block hit frequency (selected = 1.0,
/// skipped = 0.0 per decode step).
const FREQ_ALPHA: f32 = 0.2;
/// Frequency entries unseen for this many windows are pruned.
const FREQ_PRUNE_WINDOWS: u64 = 4;
/// Recycled step buffers kept for reuse.
const SPARE_CAP: usize = 4;

thread_local! {
    static WS_CLONES: Cell<u64> = const { Cell::new(0) };
}

/// Clones of [`WorkingSetTracker`] performed by the calling thread —
/// the test hook behind the zero-clone steady-state criterion (cloning
/// is counted per thread so parallel tests cannot race the counter).
pub fn ws_clones_this_thread() -> u64 {
    WS_CLONES.with(|c| c.get())
}

/// Per-block selection-frequency state.
#[derive(Debug, Clone, Copy, PartialEq)]
struct FreqStat {
    ewma: f32,
    last_step: u64,
}

#[derive(Debug)]
pub struct WorkingSetTracker {
    window: usize,
    freq_ranking: bool,
    history: VecDeque<Vec<SelItem>>,
    /// Cached union (rebuilt lazily after updates).
    union: HashSet<SelItem>,
    dirty: bool,
    /// Per-block hit EWMA (maintained only with `freq_ranking` on).
    freq: HashMap<SelItem, FreqStat>,
    /// Decode steps recorded over the tracker's lifetime.
    step: u64,
    /// Recycled step storage (window-evicted buffers awaiting reuse).
    spare: Vec<Vec<SelItem>>,
    /// Reused dedup scratch for `ranked_blocks_*_into`.
    rank_seen: HashSet<SelItem>,
    // ---- open undo scope (armed by `begin_txn`); buffers recycled ----
    txn_open: bool,
    /// Steps recorded by this txn that are still in the window (a txn
    /// step evicted by a later txn step is simply recycled — there is
    /// nothing of it to undo).
    txn_pushed: usize,
    /// PRE-txn steps the window evicted during the txn (restored in
    /// order on rollback). Evictions pop the front, and the front stays
    /// pre-txn until all `txn_len_before` of them are gone.
    txn_evicted: Vec<Vec<SelItem>>,
    txn_freq_undo: Vec<(SelItem, Option<FreqStat>)>,
    txn_step_before: u64,
    /// History length at `begin_txn` (how many evictions are pre-txn).
    txn_len_before: usize,
}

impl Clone for WorkingSetTracker {
    /// Deliberately hand-written so the thread-local clone probe counts
    /// every copy: the decode steady state must perform none (scratch
    /// and undo buffers start fresh in the clone).
    fn clone(&self) -> Self {
        WS_CLONES.with(|c| c.set(c.get() + 1));
        debug_assert!(!self.txn_open, "cloning a tracker with an open undo scope");
        Self {
            window: self.window,
            freq_ranking: self.freq_ranking,
            history: self.history.clone(),
            union: self.union.clone(),
            dirty: self.dirty,
            freq: self.freq.clone(),
            step: self.step,
            spare: Vec::new(),
            rank_seen: HashSet::new(),
            txn_open: false,
            txn_pushed: 0,
            txn_evicted: Vec::new(),
            txn_freq_undo: Vec::new(),
            txn_step_before: 0,
            txn_len_before: 0,
        }
    }
}

impl WorkingSetTracker {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        Self {
            window,
            freq_ranking: false,
            history: VecDeque::with_capacity(window + 1),
            union: HashSet::new(),
            dirty: false,
            freq: HashMap::new(),
            step: 0,
            spare: Vec::new(),
            rank_seen: HashSet::new(),
            txn_open: false,
            txn_pushed: 0,
            txn_evicted: Vec::new(),
            txn_freq_undo: Vec::new(),
            txn_step_before: 0,
            txn_len_before: 0,
        }
    }

    /// Enable recency-then-frequency prefetch ranking (per-block hit
    /// EWMA breaks ties within a recency tier).
    pub fn with_freq_ranking(mut self, on: bool) -> Self {
        self.freq_ranking = on;
        self
    }

    pub fn window(&self) -> usize {
        self.window
    }

    // ------------------------------------------------------ undo scope

    /// Begin an undo scope: subsequent [`Self::record_step`]s are
    /// journaled (pushed count, window-evicted steps, frequency
    /// deltas) until `commit_txn` (drop the journal, recycle buffers)
    /// or `rollback_txn` (revert them exactly). Mirrors
    /// `KvManager::begin_txn`; one scope per backend step.
    pub fn begin_txn(&mut self) {
        debug_assert!(!self.txn_open, "nested WorkingSetTracker txn");
        debug_assert!(self.txn_evicted.is_empty() && self.txn_freq_undo.is_empty());
        self.txn_open = true;
        self.txn_pushed = 0;
        self.txn_step_before = self.step;
        self.txn_len_before = self.history.len();
    }

    /// Keep everything recorded since `begin_txn` and close the scope.
    /// No-op without an open scope.
    pub fn commit_txn(&mut self) {
        if !self.txn_open {
            return;
        }
        self.txn_open = false;
        self.txn_pushed = 0;
        while let Some(v) = self.txn_evicted.pop() {
            self.recycle(v);
        }
        self.txn_freq_undo.clear();
        self.maybe_prune_freq();
    }

    /// Revert every `record_step` since `begin_txn`: recorded steps are
    /// popped (their storage recycled), window-evicted steps restored in
    /// order, and frequency stats rolled back — the tracker is restored
    /// exactly, without ever having been cloned. No-op without an open
    /// scope.
    pub fn rollback_txn(&mut self) {
        if !self.txn_open {
            return;
        }
        self.txn_open = false;
        for _ in 0..self.txn_pushed {
            if let Some(v) = self.history.pop_back() {
                self.recycle(v);
            }
        }
        self.txn_pushed = 0;
        // evicted in eviction order (oldest first): restore newest-evicted
        // first so the front ends up in the original order
        while let Some(v) = self.txn_evicted.pop() {
            self.history.push_front(v);
        }
        // undo frequency deltas in reverse so the first-recorded pre-state
        // of a twice-updated block wins
        while let Some((item, prev)) = self.txn_freq_undo.pop() {
            match prev {
                Some(st) => {
                    self.freq.insert(item, st);
                }
                None => {
                    self.freq.remove(&item);
                }
            }
        }
        self.step = self.txn_step_before;
        self.dirty = true;
    }

    fn recycle(&mut self, mut v: Vec<SelItem>) {
        if self.spare.len() < SPARE_CAP {
            v.clear();
            self.spare.push(v);
        }
    }

    // ------------------------------------------------------- recording

    /// Record one decode step's full selection (all layers/heads).
    pub fn record_step(&mut self, items: Vec<SelItem>) {
        if self.freq_ranking {
            self.update_freq(&items);
        } else {
            self.step += 1;
        }
        self.history.push_back(items);
        if self.txn_open {
            self.txn_pushed += 1;
        }
        while self.history.len() > self.window {
            let Some(old) = self.history.pop_front() else { break };
            if self.txn_open {
                if self.txn_evicted.len() < self.txn_len_before {
                    // a pre-txn step fell out: journal it for rollback
                    self.txn_evicted.push(old);
                } else {
                    // every pre-txn step is already gone, so this evicts
                    // a step recorded by THIS txn: there is nothing to
                    // restore — forget it and stop counting it as pushed
                    self.txn_pushed -= 1;
                    self.recycle(old);
                }
            } else {
                self.recycle(old);
            }
        }
        self.dirty = true;
    }

    /// [`Self::record_step`] from a borrowed slice, reusing recycled
    /// step storage — the per-iteration hot path allocates nothing once
    /// the window is warm.
    // sparselint: hot
    pub fn record_step_from(&mut self, items: &[SelItem]) {
        let mut v = self.spare.pop().unwrap_or_default();
        v.clear();
        v.extend_from_slice(items);
        self.record_step(v);
    }

    fn update_freq(&mut self, items: &[SelItem]) {
        self.step += 1;
        for &item in items {
            let prev = self.freq.get(&item).copied();
            if self.txn_open {
                self.txn_freq_undo.push((item, prev));
            }
            let st = match prev {
                Some(mut st) => {
                    // decay the steps this block went unselected, then
                    // fold in the hit
                    let zero_gap = (self.step - st.last_step).saturating_sub(1).min(63) as i32;
                    st.ewma *= (1.0 - FREQ_ALPHA).powi(zero_gap);
                    st.ewma = (1.0 - FREQ_ALPHA) * st.ewma + FREQ_ALPHA;
                    st.last_step = self.step;
                    st
                }
                None => FreqStat { ewma: FREQ_ALPHA, last_step: self.step },
            };
            self.freq.insert(item, st);
        }
        if !self.txn_open {
            self.maybe_prune_freq();
        }
    }

    /// Bound the frequency map: drop entries unseen for several windows
    /// (their EWMA has decayed to noise). Deferred while an undo scope
    /// is open so rollback stays exact.
    fn maybe_prune_freq(&mut self) {
        if self.step % 64 != 0 || self.freq.is_empty() {
            return;
        }
        let horizon = (FREQ_PRUNE_WINDOWS * self.window as u64).max(64);
        let step = self.step;
        self.freq.retain(|_, st| st.last_step + horizon >= step);
    }

    /// A block's decayed hit EWMA as of the current step.
    fn freq_eff(&self, item: &SelItem) -> f32 {
        match self.freq.get(item) {
            Some(st) => {
                let gap = (self.step - st.last_step).min(63) as i32;
                st.ewma * (1.0 - FREQ_ALPHA).powi(gap)
            }
            None => 0.0,
        }
    }

    fn rebuild(&mut self) {
        if self.dirty {
            self.union.clear();
            for step in &self.history {
                self.union.extend(step.iter().copied());
            }
            self.dirty = false;
        }
    }

    /// Working-set size in blocks (union over the window).
    pub fn ws_blocks(&mut self) -> usize {
        self.rebuild();
        self.union.len()
    }

    /// Working-set bytes given the per-head block size.
    pub fn ws_bytes(&mut self, block_bytes: usize) -> usize {
        self.ws_blocks() * block_bytes
    }

    // --------------------------------------------------------- ranking

    /// The window union ranked for prefetch: recency-weighted — blocks
    /// from the most recent step first (they have the highest re-selection
    /// probability, Fig. 8), then progressively older steps, deduplicated
    /// in first-seen order. With frequency ranking on, blocks within the
    /// same recency tier are ordered by their hit EWMA (frequent
    /// re-selections first). A truncation of this list is the best
    /// prediction of the next step's selection under the paper's
    /// temporal-locality model.
    pub fn ranked_blocks(&self) -> Vec<SelItem> {
        self.ranked_blocks_capped(usize::MAX)
    }

    /// [`Self::ranked_blocks`] truncated to the first `cap` entries —
    /// the prefetch hot path consumes only a staging budget's worth, so
    /// stop ranking once it is filled.
    pub fn ranked_blocks_capped(&self, cap: usize) -> Vec<SelItem> {
        let mut seen = HashSet::new();
        let mut out = Vec::new();
        self.rank_core(&mut seen, cap, &mut out);
        out
    }

    /// [`Self::ranked_blocks`] into a caller-owned buffer (cleared
    /// first), reusing the tracker's internal dedup scratch — the
    /// staging hot path allocates nothing once buffers are warm.
    // sparselint: hot
    pub fn ranked_blocks_into(&mut self, out: &mut Vec<SelItem>) {
        self.ranked_blocks_capped_into(usize::MAX, out)
    }

    /// [`Self::ranked_blocks_capped`] into a caller-owned buffer.
    pub fn ranked_blocks_capped_into(&mut self, cap: usize, out: &mut Vec<SelItem>) {
        let mut seen = std::mem::take(&mut self.rank_seen);
        self.rank_core(&mut seen, cap, out);
        self.rank_seen = seen;
    }

    fn rank_core(&self, seen: &mut HashSet<SelItem>, cap: usize, out: &mut Vec<SelItem>) {
        seen.clear();
        out.clear();
        if cap == 0 {
            return;
        }
        'steps: for step in self.history.iter().rev() {
            let tier_start = out.len();
            for &item in step {
                // pure recency order truncates mid-step (first-seen wins);
                // with frequency ranking the whole tier is collected first
                // so the EWMA decides who makes the cut
                if !self.freq_ranking && out.len() >= cap {
                    break 'steps;
                }
                if seen.insert(item) {
                    out.push(item);
                }
            }
            if self.freq_ranking {
                out[tier_start..].sort_unstable_by(|a, b| {
                    self.freq_eff(b)
                        .partial_cmp(&self.freq_eff(a))
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then_with(|| a.cmp(b))
                });
                if out.len() >= cap {
                    break 'steps;
                }
            }
        }
        out.truncate(cap);
    }

    /// Overlap ratio between the last recorded step and the union of the
    /// `w` steps before it (the Fig. 8 measurement).
    pub fn last_overlap(&self, w: usize) -> Option<f64> {
        if self.history.len() < 2 {
            return None;
        }
        let cur = self.history.back().unwrap();
        if cur.is_empty() {
            return None;
        }
        let mut prev: HashSet<SelItem> = HashSet::new();
        let n = self.history.len();
        let lo = n.saturating_sub(1 + w);
        for step in self.history.iter().skip(lo).take(n - 1 - lo) {
            prev.extend(step.iter().copied());
        }
        let inter = cur.iter().filter(|i| prev.contains(*i)).count();
        Some(inter as f64 / cur.len() as f64)
    }

    pub fn steps_recorded(&self) -> usize {
        self.history.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn items(blocks: &[u32]) -> Vec<SelItem> {
        blocks.iter().map(|&b| (0, 0, b)).collect()
    }

    /// Byte-level state equality (undo-log tests): everything observable
    /// plus the frequency stats and step counter.
    fn assert_same_state(a: &WorkingSetTracker, b: &WorkingSetTracker) {
        assert_eq!(a.history, b.history, "history diverged");
        assert_eq!(a.step, b.step, "step counter diverged");
        assert_eq!(a.freq, b.freq, "freq stats diverged");
        assert_eq!(a.window, b.window);
    }

    #[test]
    fn union_over_window() {
        let mut t = WorkingSetTracker::new(3);
        t.record_step(items(&[0, 1]));
        t.record_step(items(&[1, 2]));
        assert_eq!(t.ws_blocks(), 3);
        t.record_step(items(&[2, 3]));
        assert_eq!(t.ws_blocks(), 4);
        // window slides: step {0,1} falls out
        t.record_step(items(&[2]));
        assert_eq!(t.ws_blocks(), 3); // {1,2,3} ∪ {2} minus {0,1}... = {1,2,3}
    }

    #[test]
    fn band_granular_items_union_and_rank_per_band() {
        // the sim's per-band recording: the same block index selected by
        // two different bands is TWO working-set entries (each band's
        // group is a distinct cache resident), and ranking keeps them
        // distinct
        let mut t = WorkingSetTracker::new(4);
        t.record_step(vec![(0, 0, 5), (1, 0, 5), (1, 0, 9)]);
        assert_eq!(t.ws_blocks(), 3, "same block in two bands = two entries");
        t.record_step(vec![(0, 0, 5)]);
        assert_eq!(t.ws_blocks(), 3);
        let ranked = t.ranked_blocks();
        assert_eq!(ranked[0], (0, 0, 5), "most recent step leads");
        assert!(ranked.contains(&(1, 0, 5)) && ranked.contains(&(1, 0, 9)));
    }

    #[test]
    fn ranked_blocks_put_recent_steps_first() {
        let mut t = WorkingSetTracker::new(4);
        t.record_step(items(&[7, 8]));
        t.record_step(items(&[1, 2]));
        t.record_step(items(&[2, 3]));
        let ranked = t.ranked_blocks();
        // newest step {2,3} leads, then {1}, then the oldest {7,8}
        assert_eq!(ranked, items(&[2, 3, 1, 7, 8]));
        // dedup: union size matches ws_blocks
        assert_eq!(ranked.len(), t.ws_blocks());
        // capping truncates in rank order
        assert_eq!(t.ranked_blocks_capped(2), items(&[2, 3]));
        assert!(t.ranked_blocks_capped(0).is_empty());
    }

    #[test]
    fn ranked_into_matches_allocating_variants() {
        prop::check("ranked _into == ranked", 60, |rng: &mut Rng| {
            let freq = rng.below(2) == 1;
            let mut t = WorkingSetTracker::new(1 + rng.below(6)).with_freq_ranking(freq);
            for _ in 0..rng.below(12) {
                let n = rng.below(6);
                t.record_step((0..n).map(|_| (0u16, 0u16, rng.below(10) as u32)).collect());
            }
            let cap = rng.below(8);
            let mut buf = Vec::new();
            t.ranked_blocks_capped_into(cap, &mut buf);
            prop::assert_eq_prop(buf.clone(), t.ranked_blocks_capped(cap), "capped _into")?;
            t.ranked_blocks_into(&mut buf);
            prop::assert_eq_prop(buf, t.ranked_blocks(), "_into")?;
            Ok(())
        });
    }

    #[test]
    fn record_step_from_matches_record_step() {
        prop::check("record_step_from == record_step", 40, |rng: &mut Rng| {
            let w = 1 + rng.below(5);
            let mut a = WorkingSetTracker::new(w).with_freq_ranking(true);
            let mut b = WorkingSetTracker::new(w).with_freq_ranking(true);
            for _ in 0..12 {
                let n = rng.below(5);
                let step: Vec<SelItem> =
                    (0..n).map(|_| (0u16, 0u16, rng.below(9) as u32)).collect();
                a.record_step(step.clone());
                b.record_step_from(&step);
            }
            prop::assert_eq_prop(a.history.clone(), b.history.clone(), "history")?;
            prop::assert_eq_prop(a.ranked_blocks(), b.ranked_blocks(), "ranking")?;
            Ok(())
        });
    }

    #[test]
    fn freq_ranking_orders_frequent_blocks_first_within_a_tier() {
        let mut t = WorkingSetTracker::new(8).with_freq_ranking(true);
        t.record_step(items(&[2]));
        t.record_step(items(&[2, 1]));
        t.record_step(items(&[2, 1]));
        // newest tier has all three fresh: 2 (3 hits) > 1 (2 hits) > 3 (1)
        t.record_step(items(&[3, 1, 2]));
        assert_eq!(t.ranked_blocks(), items(&[2, 1, 3]));
        // the cap cuts the *least frequent* of the tier, not the last seen
        assert_eq!(t.ranked_blocks_capped(2), items(&[2, 1]));
        // recency still dominates: a brand-new step outranks old frequents
        t.record_step(items(&[9]));
        assert_eq!(t.ranked_blocks_capped(1), items(&[9]));
    }

    #[test]
    fn freq_map_is_pruned_and_bounded() {
        let mut t = WorkingSetTracker::new(2).with_freq_ranking(true);
        for s in 0..512u32 {
            t.record_step(items(&[s, s + 1000]));
        }
        // horizon = 4 * window (>= 64): only recently-seen entries survive
        assert!(
            t.freq.len() <= 2 * 64 + 2 * 64,
            "freq map must stay bounded: {}",
            t.freq.len()
        );
    }

    #[test]
    fn txn_rollback_restores_tracker_exactly() {
        let mut t = WorkingSetTracker::new(3).with_freq_ranking(true);
        for s in 0..5u32 {
            t.record_step(items(&[s % 4, (s + 1) % 4]));
        }
        let reference = t.clone();
        t.begin_txn();
        t.record_step(items(&[9, 10]));
        t.record_step_from(&items(&[9]));
        assert_eq!(t.steps_recorded(), 3);
        assert!(t.ranked_blocks()[0] == (0, 0, 9));
        t.rollback_txn();
        assert_same_state(&t, &reference);
        assert_eq!(t.ranked_blocks(), reference.ranked_blocks());
        assert_eq!(t.ws_blocks(), t.union.len());
        // the tracker stays fully usable: same future evolution as the
        // never-touched reference
        let mut r = reference;
        t.record_step(items(&[7]));
        r.record_step(items(&[7]));
        assert_same_state(&t, &r);
    }

    #[test]
    fn txn_commit_keeps_steps_and_recycles() {
        let mut t = WorkingSetTracker::new(2).with_freq_ranking(true);
        t.record_step(items(&[0]));
        t.record_step(items(&[1]));
        t.begin_txn();
        t.record_step(items(&[2])); // evicts step {0} into the journal
        t.commit_txn();
        assert_eq!(t.steps_recorded(), 2);
        assert_eq!(t.ranked_blocks(), items(&[2, 1]));
        assert!(!t.spare.is_empty(), "committed evictions are recycled");
        // txn calls without a scope are harmless no-ops
        t.rollback_txn();
        t.commit_txn();
        assert_eq!(t.steps_recorded(), 2);
    }

    #[test]
    fn txn_rollback_handles_more_steps_than_the_window() {
        // regression: a txn recording MORE steps than the window evicts
        // its own steps — those must be forgotten, not resurrected, and
        // the pre-txn front must come back exactly
        let mut t = WorkingSetTracker::new(1).with_freq_ranking(true);
        t.record_step(items(&[1]));
        let reference = t.clone();
        t.begin_txn();
        t.record_step(items(&[2])); // evicts pre-txn {1}
        t.record_step(items(&[3])); // evicts txn-recorded {2}
        assert_eq!(t.steps_recorded(), 1);
        t.rollback_txn();
        assert_same_state(&t, &reference);
        assert_eq!(t.steps_recorded(), 1);
        assert_eq!(t.ranked_blocks(), items(&[1]));
        // commit path with the same shape keeps only the window's worth
        t.begin_txn();
        t.record_step(items(&[2]));
        t.record_step(items(&[3]));
        t.commit_txn();
        assert_eq!(t.ranked_blocks(), items(&[3]));
    }

    #[test]
    fn prop_txn_rollback_equals_clone_snapshot() {
        prop::check("undo-log == clone snapshot", 50, |rng: &mut Rng| {
            let w = 1 + rng.below(5);
            let mut t = WorkingSetTracker::new(w).with_freq_ranking(rng.below(2) == 1);
            for _ in 0..rng.below(10) {
                let n = rng.below(5);
                t.record_step((0..n).map(|_| (0u16, 0u16, rng.below(8) as u32)).collect());
            }
            let snapshot = t.clone(); // the old, expensive path
            t.begin_txn();
            for _ in 0..1 + rng.below(3) {
                let n = rng.below(5);
                t.record_step_from(
                    &(0..n).map(|_| (0u16, 0u16, rng.below(8) as u32)).collect::<Vec<_>>(),
                );
            }
            t.rollback_txn();
            prop::assert_eq_prop(t.history.clone(), snapshot.history.clone(), "history")?;
            prop::assert_eq_prop(t.step, snapshot.step, "step")?;
            prop::assert_prop(t.freq == snapshot.freq, "freq stats")?;
            Ok(())
        });
    }

    #[test]
    fn clone_probe_counts_thread_local_clones() {
        let t = WorkingSetTracker::new(3);
        let before = ws_clones_this_thread();
        let _c = t.clone();
        assert_eq!(ws_clones_this_thread(), before + 1);
    }

    #[test]
    fn ws_bytes_scales() {
        let mut t = WorkingSetTracker::new(2);
        t.record_step(items(&[0, 1, 2]));
        assert_eq!(t.ws_bytes(1024), 3 * 1024);
    }

    #[test]
    fn overlap_measures_locality() {
        let mut t = WorkingSetTracker::new(16);
        t.record_step(items(&[0, 1, 2, 3]));
        t.record_step(items(&[0, 1, 2, 9]));
        assert_eq!(t.last_overlap(1), Some(0.75));
        // wider window can only increase overlap
        t.record_step(items(&[3, 9]));
        assert_eq!(t.last_overlap(1), Some(0.5)); // {0,1,2,9} ∩ {3,9}
        assert_eq!(t.last_overlap(2), Some(1.0)); // {0..3,9} ∩ {3,9}
    }

    #[test]
    fn prop_ws_superset_of_latest_step_and_monotone_in_window() {
        prop::check("ws invariants", 60, |rng: &mut Rng| {
            let w = 1 + rng.below(8);
            let mut t = WorkingSetTracker::new(w);
            let mut last: Vec<SelItem> = Vec::new();
            for _ in 0..20 {
                let n = rng.below(6);
                last = (0..n).map(|_| (0u16, 0u16, rng.below(10) as u32)).collect();
                t.record_step(last.clone());
            }
            let ws = {
                t.rebuild();
                t.union.clone()
            };
            for item in &last {
                prop::assert_prop(ws.contains(item), "ws must contain latest step")?;
            }
            prop::assert_prop(
                t.history.len() <= w,
                "history exceeds window",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_overlap_monotone_in_window() {
        prop::check("overlap monotone", 40, |rng: &mut Rng| {
            let mut t = WorkingSetTracker::new(16);
            for _ in 0..10 {
                let n = 1 + rng.below(5);
                t.record_step((0..n).map(|_| (0, 0, rng.below(12) as u32)).collect());
            }
            let mut prev = 0.0;
            for w in 1..=8 {
                if let Some(o) = t.last_overlap(w) {
                    prop::assert_prop(o + 1e-12 >= prev, "overlap decreased with window")?;
                    prev = o;
                }
            }
            Ok(())
        });
    }
}
