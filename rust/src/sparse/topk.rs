//! Top-k block selection from device-computed criticality scores.
//!
//! Must be *bit-identical* to the python golden pipeline
//! (`np.argsort(-scores, kind="stable")[:k]`): order by score descending,
//! ties broken by lower block id. Only sealed blocks participate (the
//! open block is always gathered separately, never scored).

/// Select up to `k` block ids from `scores[..n_sealed]`, slot-ordered.
pub fn top_k_blocks(scores: &[f32], n_sealed: usize, k: usize) -> Vec<u32> {
    let n = n_sealed.min(scores.len());
    if k == 0 || n == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    // stable sort by score desc == argsort(-scores, stable)
    idx.sort_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx
}

/// Partial-selection variant used on the hot path: avoids the full sort
/// when k << n via select_nth, then stable-sorts only the prefix.
/// Produces the same result as [`top_k_blocks`].
pub fn top_k_blocks_fast(scores: &[f32], n_sealed: usize, k: usize) -> Vec<u32> {
    let n = n_sealed.min(scores.len());
    if k == 0 || n == 0 {
        return Vec::new();
    }
    if k >= n {
        return top_k_blocks(scores, n_sealed, k);
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    // Partition so the k best (score desc, id asc) are in the prefix;
    // the comparator is a total order, making the result deterministic.
    let cmp = |a: &u32, b: &u32| {
        scores[*b as usize]
            .partial_cmp(&scores[*a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    idx.select_nth_unstable_by(k - 1, cmp);
    idx.truncate(k);
    idx.sort_by(cmp);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn orders_by_score_then_id() {
        let scores = [1.0, 5.0, 5.0, 3.0];
        assert_eq!(top_k_blocks(&scores, 4, 3), vec![1, 2, 3]);
        assert_eq!(top_k_blocks(&scores, 4, 10), vec![1, 2, 3, 0]);
    }

    #[test]
    fn respects_n_sealed() {
        let scores = [1.0, 9.0, 9.0, 9.0];
        assert_eq!(top_k_blocks(&scores, 1, 3), vec![0]);
        assert!(top_k_blocks(&scores, 0, 3).is_empty());
    }

    #[test]
    fn fast_matches_reference() {
        prop::check("topk fast == slow", 200, |rng: &mut Rng| {
            let n = 1 + rng.below(64);
            let scores: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.f64() < 0.2 {
                        -1e30
                    } else {
                        // coarse values to force ties
                        (rng.below(8) as f32) - 4.0
                    }
                })
                .collect();
            let k = rng.below(n + 2);
            prop::assert_eq_prop(
                top_k_blocks_fast(&scores, n, k),
                top_k_blocks(&scores, n, k),
                "fast != reference",
            )
        });
    }
}
