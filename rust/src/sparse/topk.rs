//! Top-k block selection from device-computed criticality scores.
//!
//! Must be *bit-identical* to the python golden pipeline
//! (`np.argsort(-scores, kind="stable")[:k]`): order by score descending,
//! ties broken by lower block id. Only sealed blocks participate (the
//! open block is always gathered separately, never scored).

/// Select up to `k` block ids from `scores[..n_sealed]`, slot-ordered.
pub fn top_k_blocks(scores: &[f32], n_sealed: usize, k: usize) -> Vec<u32> {
    let mut out = Vec::new();
    top_k_blocks_into(scores, n_sealed, k, &mut out);
    out
}

/// [`top_k_blocks`] into a caller-owned buffer (cleared first) — the
/// buffer doubles as the index workspace, so a warm buffer allocates
/// nothing.
pub fn top_k_blocks_into(scores: &[f32], n_sealed: usize, k: usize, out: &mut Vec<u32>) {
    out.clear();
    let n = n_sealed.min(scores.len());
    if k == 0 || n == 0 {
        return;
    }
    out.extend(0..n as u32);
    // stable sort by score desc == argsort(-scores, stable)
    out.sort_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    out.truncate(k);
}

/// Partial-selection variant used on the hot path: avoids the full sort
/// when k << n via select_nth, then stable-sorts only the prefix.
/// Produces the same result as [`top_k_blocks`].
pub fn top_k_blocks_fast(scores: &[f32], n_sealed: usize, k: usize) -> Vec<u32> {
    let mut out = Vec::new();
    top_k_blocks_fast_into(scores, n_sealed, k, &mut out);
    out
}

/// [`top_k_blocks_fast`] into a caller-owned buffer (cleared first) —
/// the per-layer decode hot path holds one buffer per KV head in the
/// session and allocates nothing once they are warm.
pub fn top_k_blocks_fast_into(scores: &[f32], n_sealed: usize, k: usize, out: &mut Vec<u32>) {
    let n = n_sealed.min(scores.len());
    if k >= n {
        return top_k_blocks_into(scores, n_sealed, k, out);
    }
    out.clear();
    if k == 0 || n == 0 {
        return;
    }
    out.extend(0..n as u32);
    // Partition so the k best (score desc, id asc) are in the prefix;
    // the comparator is a total order, making the result deterministic.
    let cmp = |a: &u32, b: &u32| {
        scores[*b as usize]
            .partial_cmp(&scores[*a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    out.select_nth_unstable_by(k - 1, cmp);
    out.truncate(k);
    out.sort_by(cmp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn orders_by_score_then_id() {
        let scores = [1.0, 5.0, 5.0, 3.0];
        assert_eq!(top_k_blocks(&scores, 4, 3), vec![1, 2, 3]);
        assert_eq!(top_k_blocks(&scores, 4, 10), vec![1, 2, 3, 0]);
    }

    #[test]
    fn respects_n_sealed() {
        let scores = [1.0, 9.0, 9.0, 9.0];
        assert_eq!(top_k_blocks(&scores, 1, 3), vec![0]);
        assert!(top_k_blocks(&scores, 0, 3).is_empty());
    }

    #[test]
    fn into_variants_match_allocating_counterparts() {
        prop::check("topk _into == allocating", 120, |rng: &mut Rng| {
            let n = 1 + rng.below(64);
            let scores: Vec<f32> =
                (0..n).map(|_| (rng.below(8) as f32) - 4.0).collect();
            let k = rng.below(n + 2);
            // dirty, pre-warmed buffers must be fully overwritten
            let mut buf = vec![999u32; 7];
            top_k_blocks_into(&scores, n, k, &mut buf);
            prop::assert_eq_prop(buf.clone(), top_k_blocks(&scores, n, k), "sort _into")?;
            top_k_blocks_fast_into(&scores, n, k, &mut buf);
            prop::assert_eq_prop(buf, top_k_blocks_fast(&scores, n, k), "fast _into")?;
            Ok(())
        });
    }

    #[test]
    fn fast_matches_reference() {
        prop::check("topk fast == slow", 200, |rng: &mut Rng| {
            let n = 1 + rng.below(64);
            let scores: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.f64() < 0.2 {
                        -1e30
                    } else {
                        // coarse values to force ties
                        (rng.below(8) as f32) - 4.0
                    }
                })
                .collect();
            let k = rng.below(n + 2);
            prop::assert_eq_prop(
                top_k_blocks_fast(&scores, n, k),
                top_k_blocks(&scores, n, k),
                "fast != reference",
            )
        });
    }
}
