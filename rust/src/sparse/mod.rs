//! Dynamic sparse attention (DSA) support on the coordinator side.
//!
//! The compute-regular parts of a DSA run on-device (L1 kernels: metadata
//! construction, block scoring, sparse attention). What lives here is the
//! control half the paper's system owns:
//!
//! - [`topk`]: select the top-k critical blocks from device scores with
//!   deterministic tie-breaking (bit-identical to the python pipeline)
//! - [`working_set`]: estimate each request's decode working set from the
//!   bounded history window of past selections (paper §3.3, Fig. 8)

pub mod topk;
pub mod working_set;

pub use topk::{top_k_blocks, top_k_blocks_fast, top_k_blocks_fast_into, top_k_blocks_into};
pub use working_set::{ws_clones_this_thread, WorkingSetTracker};
