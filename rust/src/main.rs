//! SparseServe launcher.
//!
//! Subcommands:
//!   info                      — print artifact + model information
//!   serve    [--config tiny-llm] [--system sparseserve] [--rate R] [--requests N]
//!                             — serve a synthetic trace on the REAL PJRT
//!                               backend (tiny-llm artifacts) and report metrics
//!   simulate [--model lwm-7b] [--system sparseserve] [--rate R] [--requests N]
//!                             — paper-scale discrete simulation (A100 testbed
//!                               substitute), reports TTFT/TBT/throughput
//!   bench-transfer            — print the Fig. 4 bandwidth table
//!
//! Examples:
//!   sparseserve simulate --model lwm-7b --system vllm --rate 0.125 --requests 40
//!   sparseserve serve --rate 2 --requests 6

use std::sync::Arc;

use anyhow::{anyhow, Result};

use sparseserve::baselines;
use sparseserve::config::{HardwareSpec, ModelSpec, ServingConfig};
use sparseserve::engine::{Engine, PjrtBackend, SimBackend};
use sparseserve::runtime::Runtime;
use sparseserve::scheduler::Scheduler;
use sparseserve::util::cli::Args;
use sparseserve::util::stats::fmt_bandwidth;
use sparseserve::workload::{generate, generate_with_tokens, WorkloadSpec};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("info") => info(&args),
        Some("serve") => serve(&args),
        Some("simulate") => simulate(&args),
        Some("bench-transfer") => bench_transfer(),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "sparseserve — dynamic-sparse-attention LLM serving (paper reproduction)

USAGE: sparseserve <info|serve|simulate|bench-transfer> [flags]

  serve     --config tiny-llm --system sparseserve --rate 2.0 --requests 6
  simulate  --model lwm-7b    --system sparseserve --rate 0.125 --requests 40
  info      --config tiny-llm
  bench-transfer

Systems: vllm | vllm-s | vllm-so | sparseserve";

fn info(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny-llm");
    let rt = Runtime::load(Runtime::default_dir(&config))?;
    let m = &rt.manifest;
    println!("model: {} ({} params)", m.model.name, m.model.n_params());
    println!(
        "layers={} heads={}/{} head_dim={} block={} tok max_ctx={}",
        m.model.n_layers, m.model.n_heads, m.model.n_kv_heads, m.model.head_dim,
        m.model.block_size, m.model.max_ctx
    );
    println!("artifacts ({}):", m.entries.len());
    for e in &m.entries {
        println!("  {} [{}]", e.name, e.kind);
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny-llm");
    let system = args.get_or("system", "sparseserve");
    let rate = args.f64("rate", 2.0);
    let n = args.usize("requests", 6);
    let seed = args.usize("seed", 7) as u64;

    let rt = Arc::new(Runtime::load(Runtime::default_dir(&config))?);
    let spec = rt.manifest.model.clone();
    let budget = args.usize("budget", 256); // tokens; 16 blocks of 16
    let mut cfg = baselines::by_name(&system, budget, 64, spec.n_layers)
        .ok_or_else(|| anyhow!("unknown system '{system}'"))?;
    cfg.max_inject_tokens = spec.max_ctx * spec.n_layers; // whole-prompt segments
    cfg.chunk_tokens = 64;
    cfg.t_max = 256;

    let hbm = args.usize("hbm-bytes", 8 << 20);
    let dram = 512 << 20;
    let backend = PjrtBackend::new(rt.clone(), cfg.clone(), hbm, dram);
    let sched = Scheduler::new(cfg, spec.clone(), hbm);
    let engine = Engine::new(sched, Box::new(backend));

    let wl = WorkloadSpec::tiny(rate, seed);
    let trace = generate_with_tokens(&wl, n, 1, spec.vocab);
    println!(
        "[serve] {} requests, rate {rate} rps, system {system}, backend pjrt/{}",
        n, spec.name
    );
    let report = engine.run_trace(trace, 1e6)?;
    println!("[serve] {}", report.metrics.summary());
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "lwm-7b");
    let system = args.get_or("system", "sparseserve");
    let rate = args.f64("rate", 0.1);
    let n = args.usize("requests", 40);
    let seed = args.usize("seed", 7) as u64;

    let spec = ModelSpec::by_name(&model).ok_or_else(|| anyhow!("unknown model '{model}'"))?;
    let hw = HardwareSpec::a100_40gb();
    let cfg: ServingConfig = baselines::by_name(&system, 2048, 2048, spec.n_layers)
        .ok_or_else(|| anyhow!("unknown system '{system}'"))?;

    let wl = if model == "llama3-8b" {
        WorkloadSpec::paper_llama3(rate, seed)
    } else {
        WorkloadSpec::paper_lwm(rate, seed)
    };
    let trace = generate(&wl, n, 1);
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
    let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
    let engine = Engine::new(sched, Box::new(backend));
    println!("[simulate] {model} x {system} @ {rate} rps, {n} requests");
    let report = engine.run_trace(trace, 1e7)?;
    println!("[simulate] {}", report.metrics.summary());
    Ok(())
}

fn bench_transfer() -> Result<()> {
    let hw = HardwareSpec::a100_40gb();
    println!("Fig. 4 — PCIe effective bandwidth vs block size (modeled, A100 testbed)");
    println!("{:>8} {:>14} {:>14} {:>14}", "block", "memcpy", "FlashH2D", "FlashD2H");
    for kb in [4usize, 8, 16, 32, 64] {
        let b = kb * 1024;
        println!(
            "{:>6}KB {:>14} {:>14} {:>14}",
            kb,
            fmt_bandwidth(hw.memcpy_bandwidth(b)),
            fmt_bandwidth(hw.flash_h2d_bandwidth(b)),
            fmt_bandwidth(hw.flash_d2h_bandwidth(b)),
        );
    }
    Ok(())
}
