//! SparseServe launcher.
//!
//! Subcommands:
//!   info                      — print artifact + model information
//!   serve    [--config tiny-llm] [--system sparseserve] [--rate R]
//!            [--requests N] [--queue-cap Q]
//!                             — online serving on the REAL PJRT backend
//!                               through the coordinator (priorities,
//!                               SLOs, backpressure) + RunMetrics report
//!   simulate [--model lwm-7b] [--system sparseserve] [--rate R] [--requests N]
//!                             — paper-scale discrete simulation (A100 testbed
//!                               substitute), reports TTFT/TBT/throughput
//!   bench-transfer            — print the Fig. 4 bandwidth table
//!
//! Examples:
//!   sparseserve simulate --model lwm-7b --system vllm --rate 0.125 --requests 40
//!   sparseserve serve --rate 2 --requests 6 --queue-cap 32

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use sparseserve::baselines;
use sparseserve::config::{HardwareSpec, ModelSpec, ServingConfig};
use sparseserve::coordinator::Server;
use sparseserve::engine::{Engine, PjrtBackend, SimBackend, SubmitRequest};
use sparseserve::runtime::Runtime;
use sparseserve::scheduler::Scheduler;
use sparseserve::util::cli::Args;
use sparseserve::util::stats::fmt_bandwidth;
use sparseserve::workload::{generate, generate_with_tokens, WorkloadSpec};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("info") => info(&args),
        Some("serve") => serve(&args),
        Some("simulate") => simulate(&args),
        Some("bench-transfer") => bench_transfer(),
        Some("bench") => bench(&args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "sparseserve — dynamic-sparse-attention LLM serving (paper reproduction)

USAGE: sparseserve <info|serve|simulate|bench-transfer> [flags]

  serve     online serving on the real PJRT backend (tiny-llm artifacts)
            through the coordinator: every request goes through the
            EngineCore lifecycle (SubmitRequest -> token stream -> Done
            timing), every 3rd request is submitted as Interactive with a
            TTFT SLO, and the run's RunMetrics are printed at shutdown:
            throughput, TTFT/TBT percentiles, queue wait, iteration
            count, per-iteration block loads with mean load and stall
            time, aborted-attempt decode time, and the per-layer-band
            selection profile on its own [serve]/[simulate] line.
      --config tiny-llm     artifact directory (make artifacts)
      --system sparseserve  serving policy (see Systems below)
      --rate 2.0            Poisson arrival rate, requests/s
      --requests 6          number of requests
      --queue-cap 0         admission-queue cap (0 = unbounded); beyond
                            it submissions fail fast with QueueFull
      --budget 256          DSA token budget
      --hbm-bytes 8388608   scaled-down HBM KV-cache size

  simulate  offline clock-driven replay at paper scale (A100 testbed
            substitute; LWM-7B / Llama3-8B cost models)
      --model lwm-7b        lwm-7b | llama3-8b
      --system sparseserve  serving policy
      --rate 0.125          Poisson arrival rate, requests/s
      --requests 40         number of requests

  info      print artifact + model information  [--config tiny-llm]
  bench-transfer            Fig. 4 PCIe bandwidth table
  bench     simulator smoke benchmarks: (1) the same workload with the
            prefetcher on and off, (2) the same workload timed with the
            per-layer iteration event model vs the coarse two-stream
            model, plus a selection layer-skew sweep (misses discovered
            early vs late across the layer bands), (3) the full-step
            hot-path microbench (plan -> stage -> per-layer decode ->
            commit, hybrid, and rollback+retry cases; panics fail CI),
            (4) admission estimates on vs off under a binding DRAM
            budget, (5) cluster goodput vs tenant skew: 1 engine vs 2
            engines with and without typed KV migration, (6) prefix
            sharing on vs off over an identical shared-system-prompt
            trace at pool hit rates 0 / 0.3 / 0.7 (TTFT, modeled
            prefill compute, HBM ingress and DRAM KV-write bytes);
            writes BENCH_prefetch.json + BENCH_layer_model.json +
            BENCH_hotpath.json + BENCH_cluster.json +
            BENCH_prefix.json (the CI perf ratchet compares the
            hot-path steady-decode metric against the previous run)
      --out BENCH_prefetch.json              prefetch output path
      --out-layer BENCH_layer_model.json     layer-model output path
      --out-hotpath BENCH_hotpath.json       hot-path output path
      --out-cluster BENCH_cluster.json       cluster output path
      --out-prefix BENCH_prefix.json         prefix-sharing output path
      --hotpath-budget 0.2                   seconds per hot-path case
      --rates 0.2,0.35                       comma-separated request rates

Systems: vllm | vllm-s | vllm-so | sparseserve | sparseserve-np
         (sparseserve-np = full system with working-set prefetching off)

Request lifecycle (library API): build requests with the SubmitRequest
builder — .max_new(n) .stop_tokens(v) .priority(Interactive|Batch)
.ttft_slo(s) .sparse_budget(tokens) — submit/cancel through
coordinator::Server or drive engine::EngineCore directly
(submit / step / cancel / has_work). Failures are typed ServeErrors:
AdmissionRejected, Cancelled, Evicted, BackendFailed{source},
QueueFull, Disconnected. See rust/README.md for a runnable example.";

fn info(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny-llm");
    let rt = Runtime::load(Runtime::default_dir(&config))?;
    let m = &rt.manifest;
    println!("model: {} ({} params)", m.model.name, m.model.n_params());
    println!(
        "layers={} heads={}/{} head_dim={} block={} tok max_ctx={}",
        m.model.n_layers, m.model.n_heads, m.model.n_kv_heads, m.model.head_dim,
        m.model.block_size, m.model.max_ctx
    );
    println!("artifacts ({}):", m.entries.len());
    for e in &m.entries {
        println!("  {} [{}]", e.name, e.kind);
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny-llm");
    let system = args.get_or("system", "sparseserve");
    let rate = args.f64("rate", 2.0);
    let n = args.usize("requests", 6);
    let seed = args.usize("seed", 7) as u64;
    let queue_cap = args.usize("queue-cap", 0);

    // only the manifest (plain JSON) is needed on the main thread for the
    // workload shapes; all PJRT state is loaded on the engine thread
    // (thread-affine handles, and weights shouldn't be loaded twice).
    let manifest_path = Runtime::default_dir(&config).join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path)
        .map_err(|e| anyhow!("reading {manifest_path:?}: {e} (run `make artifacts`)"))?;
    let spec = sparseserve::runtime::Manifest::parse(&text)?.model;
    let budget = args.usize("budget", 256); // tokens; 16 blocks of 16
    let mut cfg = baselines::by_name(&system, budget, 64, spec.n_layers)
        .ok_or_else(|| anyhow!("unknown system '{system}'"))?;
    cfg.max_inject_tokens = spec.max_ctx * spec.n_layers; // whole-prompt segments
    cfg.chunk_tokens = 64;
    cfg.t_max = 256;
    let hbm = args.usize("hbm-bytes", 8 << 20);
    let dram = 512 << 20;

    let wl = WorkloadSpec::tiny(rate, seed);
    let trace = generate_with_tokens(&wl, n, 1, spec.vocab);
    println!(
        "[serve] {} requests, rate {rate} rps, system {system}, backend pjrt/{} (online)",
        n, spec.name
    );

    let build_cfg = cfg.clone();
    let build_spec = spec.clone();
    let server = Server::start_with(
        if queue_cap == 0 { None } else { Some(queue_cap) },
        move || {
            let rt = Arc::new(Runtime::load(Runtime::default_dir(&config))?);
            let backend = PjrtBackend::new(rt, build_cfg.clone(), hbm, dram);
            // offload admission is bounded by the DRAM pool backing the
            // KV manager — oversubscription backpressures instead of
            // exhausting the pool mid-decode
            let sched = Scheduler::new(build_cfg, build_spec, hbm).with_dram_capacity(dram);
            Ok((sched, Box::new(backend) as Box<dyn sparseserve::engine::Backend>))
        },
    );

    // replay the trace's Poisson arrivals on the wall clock; every 3rd
    // request is Interactive with a 2 s TTFT SLO (queue-jumping demo)
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(trace.len());
    for (i, r) in trace.iter().enumerate() {
        let wait = r.arrival_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
        let mut sub = SubmitRequest::new(r.prompt.clone()).max_new(r.max_new_tokens);
        if i % 3 == 0 {
            sub = sub.interactive().ttft_slo(2.0);
        }
        handles.push(server.submit(sub));
    }
    for h in handles {
        let id = h.id;
        match h.collect() {
            Ok((toks, t)) => println!(
                "[serve] req {id}: {} tokens, ttft {:.3}s, tbt {:.4}s ({} ids)",
                t.n_tokens,
                t.ttft_s.unwrap_or(0.0),
                t.tbt_mean_s,
                toks.len()
            ),
            Err(e) => println!("[serve] req {id} failed: {e}"),
        }
    }
    let metrics = server.shutdown()?;
    println!("[serve] {}", metrics.summary());
    println!("[serve] {}", metrics.layer_profile.summary());
    if metrics.ttft_slo_violations > 0 {
        println!("[serve] TTFT SLO violations: {}", metrics.ttft_slo_violations);
    }
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "lwm-7b");
    let system = args.get_or("system", "sparseserve");
    let rate = args.f64("rate", 0.1);
    let n = args.usize("requests", 40);
    let seed = args.usize("seed", 7) as u64;

    let spec = ModelSpec::by_name(&model).ok_or_else(|| anyhow!("unknown model '{model}'"))?;
    let hw = HardwareSpec::a100_40gb();
    let cfg: ServingConfig = baselines::by_name(&system, 2048, 2048, spec.n_layers)
        .ok_or_else(|| anyhow!("unknown system '{system}'"))?;

    let wl = if model == "llama3-8b" {
        WorkloadSpec::paper_llama3(rate, seed)
    } else {
        WorkloadSpec::paper_lwm(rate, seed)
    };
    let trace = generate(&wl, n, 1);
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
    let sched =
        Scheduler::new(cfg, spec, hw.hbm_kv_bytes).with_dram_capacity(hw.dram_bytes);
    let engine = Engine::new(sched, Box::new(backend));
    println!("[simulate] {model} x {system} @ {rate} rps, {n} requests");
    let report = engine.run_trace(trace, 1e7)?;
    println!("[simulate] {}", report.metrics.summary());
    println!("[simulate] {}", report.metrics.layer_profile.summary());
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    use sparseserve::util::json::Value;
    use std::collections::BTreeMap;

    let out_path = args.get_or("out", "BENCH_prefetch.json");
    let raw = args.get_or("rates", "0.2,0.35");
    let rates: Vec<f64> = raw
        .split(',')
        .map(|r| {
            r.trim()
                .parse::<f64>()
                .map_err(|e| anyhow!("--rates entry '{}': {e}", r.trim()))
        })
        .collect::<Result<_>>()?;
    if rates.is_empty() {
        return Err(anyhow!("--rates must name at least one request rate"));
    }

    println!("== prefetch on/off smoke (LWM-7B, seed 11) ==");
    let mut points = Vec::new();
    for &rate in &rates {
        let (on, off) = sparseserve::figures::prefetch_ablation_metrics(rate, 11);
        println!(
            "rate {rate}: iter {:.2}ms (on) vs {:.2}ms (off) | stall {:.2}ms vs {:.2}ms | \
             prefetch hit {:.0}% wasted {}",
            on.iter_time.mean() * 1e3,
            off.iter_time.mean() * 1e3,
            on.stall_time.mean() * 1e3,
            off.stall_time.mean() * 1e3,
            100.0 * on.prefetch_hit_rate(),
            on.prefetch_wasted,
        );
        let mut p = BTreeMap::new();
        p.insert("rate".into(), Value::Num(rate));
        p.insert("iter_ms_prefetch_on".into(), Value::Num(on.iter_time.mean() * 1e3));
        p.insert("iter_ms_prefetch_off".into(), Value::Num(off.iter_time.mean() * 1e3));
        p.insert("stall_ms_prefetch_on".into(), Value::Num(on.stall_time.mean() * 1e3));
        p.insert("stall_ms_prefetch_off".into(), Value::Num(off.stall_time.mean() * 1e3));
        p.insert("throughput_on".into(), Value::Num(on.throughput()));
        p.insert("throughput_off".into(), Value::Num(off.throughput()));
        p.insert("prefetch_hit_rate".into(), Value::Num(on.prefetch_hit_rate()));
        p.insert("prefetch_staged_blocks".into(), Value::Num(on.prefetch_blocks as f64));
        p.insert("prefetch_wasted_blocks".into(), Value::Num(on.prefetch_wasted as f64));
        points.push(Value::Obj(p));
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Value::Str("prefetch_ablation".into()));
    doc.insert("model".into(), Value::Str("lwm-7b".into()));
    doc.insert("points".into(), Value::Arr(points));
    std::fs::write(&out_path, Value::Obj(doc).to_string())?;
    println!("[bench] wrote {out_path}");

    // ---- iteration event model: per-layer overlap vs coarse ----
    let layer_out_path = args.get_or("out-layer", "BENCH_layer_model.json");
    println!("== iteration model: per-layer vs coarse two-stream (LWM-7B, seed 11) ==");
    let mut points = Vec::new();
    for &rate in &rates {
        let (per, coarse) = sparseserve::figures::layer_model_metrics(rate, 11);
        println!(
            "rate {rate}: iter {:.2}ms (layered) vs {:.2}ms (coarse) | stall {:.2}ms vs {:.2}ms \
             | hidden {:.2}ms",
            per.iter_time.mean() * 1e3,
            coarse.iter_time.mean() * 1e3,
            per.stall_time.mean() * 1e3,
            coarse.stall_time.mean() * 1e3,
            per.hidden_time.mean() * 1e3,
        );
        let mut p = BTreeMap::new();
        p.insert("rate".into(), Value::Num(rate));
        p.insert("iter_ms_per_layer".into(), Value::Num(per.iter_time.mean() * 1e3));
        p.insert("iter_ms_coarse".into(), Value::Num(coarse.iter_time.mean() * 1e3));
        p.insert("stall_ms_per_layer".into(), Value::Num(per.stall_time.mean() * 1e3));
        p.insert("stall_ms_coarse".into(), Value::Num(coarse.stall_time.mean() * 1e3));
        p.insert("hidden_ms_per_layer".into(), Value::Num(per.hidden_time.mean() * 1e3));
        p.insert("throughput_per_layer".into(), Value::Num(per.throughput()));
        p.insert("throughput_coarse".into(), Value::Num(coarse.throughput()));
        points.push(Value::Obj(p));
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Value::Str("iter_model_comparison".into()));
    doc.insert("model".into(), Value::Str("lwm-7b".into()));
    doc.insert("points".into(), Value::Arr(points));

    // ---- layer-skew sweep: where misses are discovered vs stall ----
    println!("== selection layer skew: early vs late miss discovery (LWM-7B, seed 11) ==");
    let skew_rate = *rates.last().expect("non-empty rates");
    let mut skew_points = Vec::new();
    for (skew, m) in sparseserve::figures::layer_skew_metrics(skew_rate, 11) {
        println!(
            "skew {skew:+.1}: iter {:.2}ms | stall {:.2}ms | hidden {:.2}ms | {:.1} loads/iter",
            m.iter_time.mean() * 1e3,
            m.stall_time.mean() * 1e3,
            m.hidden_time.mean() * 1e3,
            m.blocks_loaded_per_iter.mean(),
        );
        let mut p = BTreeMap::new();
        p.insert("skew".into(), Value::Num(skew));
        p.insert("rate".into(), Value::Num(skew_rate));
        p.insert("iter_ms".into(), Value::Num(m.iter_time.mean() * 1e3));
        p.insert("stall_ms".into(), Value::Num(m.stall_time.mean() * 1e3));
        p.insert("hidden_ms".into(), Value::Num(m.hidden_time.mean() * 1e3));
        p.insert("loads_per_iter".into(), Value::Num(m.blocks_loaded_per_iter.mean()));
        skew_points.push(Value::Obj(p));
    }
    doc.insert("layer_skew_sweep".into(), Value::Arr(skew_points));
    std::fs::write(&layer_out_path, Value::Obj(doc).to_string())?;
    println!("[bench] wrote {layer_out_path}");

    // ---- full-step hot path: plan → stage → layers → commit (+ rollback) ----
    // A panic anywhere in here fails the CI job — this is the perf gate
    // for the zero-clone step pipeline.
    let hotpath_out = args.get_or("out-hotpath", "BENCH_hotpath.json");
    let hotpath_budget = args.f64("hotpath-budget", 0.2);
    println!("== full-step hot path (SimBackend, LWM-7B) ==");
    let results = sparseserve::figures::full_step_results(hotpath_budget);
    for r in &results {
        println!("{}", r.line());
    }
    let mut doc = match sparseserve::figures::hotpath_doc(&results) {
        Value::Obj(doc) => doc,
        _ => unreachable!("hotpath_doc returns an object"),
    };

    // ---- admission estimates on/off (simulate path, binding DRAM) ----
    println!("== admission estimates on/off (LWM-7B, constrained DRAM, seed 11) ==");
    let est_rate = *rates.last().expect("non-empty rates");
    let (on, off) = sparseserve::figures::admission_estimates_metrics(est_rate, 11);
    println!(
        "rate {est_rate}: thpt {:.2} tok/s (on) vs {:.2} (off) | TTFT {:.2}s vs {:.2}s | \
         queue {:.2}s vs {:.2}s | finished {} vs {} | evicted {} vs {}",
        on.throughput(),
        off.throughput(),
        on.ttft.mean(),
        off.ttft.mean(),
        on.queue_delay.mean(),
        off.queue_delay.mean(),
        on.requests_finished,
        off.requests_finished,
        on.requests_evicted,
        off.requests_evicted,
    );
    let mut est = BTreeMap::new();
    est.insert("rate".into(), Value::Num(est_rate));
    est.insert("throughput_on".into(), Value::Num(on.throughput()));
    est.insert("throughput_off".into(), Value::Num(off.throughput()));
    est.insert("ttft_mean_on".into(), Value::Num(on.ttft.mean()));
    est.insert("ttft_mean_off".into(), Value::Num(off.ttft.mean()));
    est.insert("queue_mean_on".into(), Value::Num(on.queue_delay.mean()));
    est.insert("queue_mean_off".into(), Value::Num(off.queue_delay.mean()));
    est.insert("evicted_on".into(), Value::Num(on.requests_evicted as f64));
    est.insert("evicted_off".into(), Value::Num(off.requests_evicted as f64));
    doc.insert("admission_estimates".into(), Value::Obj(est));
    std::fs::write(&hotpath_out, Value::Obj(doc).to_string())?;
    println!("[bench] wrote {hotpath_out}");

    // ---- cluster serving: goodput vs tenant skew, ± KV migration ----
    let cluster_out = args.get_or("out-cluster", "BENCH_cluster.json");
    println!("== cluster: 1 engine vs 2 engines +/- KV migration (LWM-7B, seed 7) ==");
    let mut points = Vec::new();
    for &skew in &[0.0, 0.4, 0.8] {
        for (name, rep) in sparseserve::figures::cluster_skew_metrics(skew, 7) {
            println!(
                "skew {skew:.1} {name:>18}: goodput {:.3}/ks | finished {} evicted {} \
                 migrated {} | transfer {:.3}s | makespan {:.0}s",
                rep.goodput_rps() * 1e3,
                rep.requests_finished(),
                rep.requests_evicted(),
                rep.requests_migrated(),
                rep.migration_transfer_s(),
                rep.makespan_s,
            );
            let mut p = BTreeMap::new();
            p.insert("skew".into(), Value::Num(skew));
            p.insert("system".into(), Value::Str(name.into()));
            p.insert("goodput_rps".into(), Value::Num(rep.goodput_rps()));
            p.insert("throughput".into(), Value::Num(rep.throughput()));
            p.insert("finished".into(), Value::Num(rep.requests_finished() as f64));
            p.insert("evicted".into(), Value::Num(rep.requests_evicted() as f64));
            p.insert("migrated".into(), Value::Num(rep.requests_migrated() as f64));
            p.insert("router_rejected".into(), Value::Num(rep.rejected.len() as f64));
            p.insert("migration_transfer_s".into(), Value::Num(rep.migration_transfer_s()));
            p.insert("migration_bytes".into(), Value::Num(rep.migration_bytes() as f64));
            p.insert("makespan_s".into(), Value::Num(rep.makespan_s));
            points.push(Value::Obj(p));
        }
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Value::Str("cluster_goodput_vs_skew".into()));
    doc.insert("model".into(), Value::Str("lwm-7b".into()));
    doc.insert("points".into(), Value::Arr(points));
    std::fs::write(&cluster_out, Value::Obj(doc).to_string())?;
    println!("[bench] wrote {cluster_out}");

    // ---- prefix sharing: TTFT / prefill compute / bytes vs pool hit rate ----
    let prefix_out = args.get_or("out-prefix", "BENCH_prefix.json");
    let prefix_rate = *rates.first().expect("non-empty rates");
    println!("== prefix sharing on/off vs pool hit rate (LWM-7B, seed 11) ==");
    let mut points = Vec::new();
    for &hit in &[0.0, 0.3, 0.7] {
        let (on, off) = sparseserve::figures::prefix_sharing_metrics(prefix_rate, hit, 11);
        println!(
            "hit {hit:.1}: TTFT {:.2}s (on) vs {:.2}s (off) | prefill {:.1}s vs {:.1}s | \
             HBM {:.2}GB vs {:.2}GB | DRAM {:.2}GB vs {:.2}GB | {} hits, {} tok matched",
            on.ttft_mean_s,
            off.ttft_mean_s,
            on.prefill_compute_s,
            off.prefill_compute_s,
            on.hbm_in_bytes as f64 / 1e9,
            off.hbm_in_bytes as f64 / 1e9,
            on.dram_written_bytes as f64 / 1e9,
            off.dram_written_bytes as f64 / 1e9,
            on.prefix_hits,
            on.prefix_matched_tokens,
        );
        let mut p = BTreeMap::new();
        p.insert("hit_rate".into(), Value::Num(hit));
        p.insert("rate".into(), Value::Num(prefix_rate));
        p.insert("ttft_mean_s_on".into(), Value::Num(on.ttft_mean_s));
        p.insert("ttft_mean_s_off".into(), Value::Num(off.ttft_mean_s));
        p.insert("prefill_compute_s_on".into(), Value::Num(on.prefill_compute_s));
        p.insert("prefill_compute_s_off".into(), Value::Num(off.prefill_compute_s));
        p.insert("hbm_in_bytes_on".into(), Value::Num(on.hbm_in_bytes as f64));
        p.insert("hbm_in_bytes_off".into(), Value::Num(off.hbm_in_bytes as f64));
        p.insert("dram_written_bytes_on".into(), Value::Num(on.dram_written_bytes as f64));
        p.insert("dram_written_bytes_off".into(), Value::Num(off.dram_written_bytes as f64));
        p.insert("prefix_hits".into(), Value::Num(on.prefix_hits as f64));
        p.insert("prefix_matched_tokens".into(), Value::Num(on.prefix_matched_tokens as f64));
        p.insert("tokens_generated_on".into(), Value::Num(on.tokens_generated as f64));
        p.insert("tokens_generated_off".into(), Value::Num(off.tokens_generated as f64));
        points.push(Value::Obj(p));
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Value::Str("prefix_sharing_ablation".into()));
    doc.insert("model".into(), Value::Str("lwm-7b".into()));
    doc.insert("points".into(), Value::Arr(points));
    std::fs::write(&prefix_out, Value::Obj(doc).to_string())?;
    println!("[bench] wrote {prefix_out}");
    Ok(())
}

fn bench_transfer() -> Result<()> {
    let hw = HardwareSpec::a100_40gb();
    println!("Fig. 4 — PCIe effective bandwidth vs block size (modeled, A100 testbed)");
    println!("{:>8} {:>14} {:>14} {:>14}", "block", "memcpy", "FlashH2D", "FlashD2H");
    for kb in [4usize, 8, 16, 32, 64] {
        let b = kb * 1024;
        println!(
            "{:>6}KB {:>14} {:>14} {:>14}",
            kb,
            fmt_bandwidth(hw.memcpy_bandwidth(b)),
            fmt_bandwidth(hw.flash_h2d_bandwidth(b)),
            fmt_bandwidth(hw.flash_d2h_bandwidth(b)),
        );
    }
    Ok(())
}
