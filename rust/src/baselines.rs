//! System presets: the paper's four compared systems (§4.1) plus the
//! Fig. 13 ablation ladder.

use crate::config::{PrefillMode, ServingConfig, TransferKind};

/// A named system configuration.
#[derive(Debug, Clone)]
pub struct SystemPreset {
    pub name: &'static str,
    pub cfg: ServingConfig,
}

/// The paper's §4.2 comparison set for a model with `n_layers`.
/// `token_budget`/`chunk` default to the paper's 2048/2048.
pub fn comparison_set(token_budget: usize, chunk: usize, n_layers: usize) -> Vec<SystemPreset> {
    vec![
        SystemPreset { name: "vLLM", cfg: ServingConfig::vllm(chunk) },
        SystemPreset { name: "vLLM-S", cfg: ServingConfig::vllm_s(token_budget, chunk) },
        SystemPreset { name: "vLLM-SO", cfg: ServingConfig::vllm_so(token_budget, chunk) },
        SystemPreset {
            name: "SparseServe",
            cfg: ServingConfig::sparseserve(token_budget, chunk, n_layers),
        },
    ]
}

/// Fig. 13's incremental ladder, extended with the working-set
/// prefetcher, the pipelined step executor and cross-request prefix
/// sharing as their own rungs:
/// vLLM -> +SA -> +Offload -> +FT -> +WC -> +LP -> +PF -> +PIPE -> +PFX.
/// Every rung keeps *pure recency* ranking and conservative admission so
/// each step isolates exactly one mechanism; the full
/// `ServingConfig::sparseserve` system additionally enables
/// frequency-blended ranking (`prefetch_freq_ranking`) and
/// estimate-based admission (`admission_estimates`). Note the
/// no-prefetch preset (`sparseserve-np`) is "full system minus
/// prefetching" — it KEEPS those two knobs, so the
/// [`prefetch_ablation`] pair differs only in `prefetch` (the +LP rung
/// is therefore *not* the same config as `sparseserve-np`).
pub fn ablation_ladder(token_budget: usize, chunk: usize, n_layers: usize) -> Vec<SystemPreset> {
    let base = ServingConfig::vllm(chunk);
    let sa = ServingConfig::vllm_s(token_budget, chunk);
    let offload = ServingConfig::vllm_so(token_budget, chunk);
    let ft = ServingConfig { transfer: TransferKind::Flash, ..offload.clone() };
    let wc = ServingConfig { ws_batch_control: true, ..ft.clone() };
    let lp = ServingConfig {
        prefill_mode: PrefillMode::LayerSegmented,
        max_inject_tokens: chunk * n_layers,
        ..wc.clone()
    };
    let full = ServingConfig::sparseserve(token_budget, chunk, n_layers);
    let pf = ServingConfig {
        prefetch: true,
        max_prefetch_blocks: full.max_prefetch_blocks,
        ..lp.clone()
    };
    // +PIPE: the two-stage pipelined executor (iteration N+1's
    // plan/stage under iteration N's compute) — an engine-structure
    // rung, not a paper mechanism, so it rides on top of the full stack
    let pipe = ServingConfig { pipeline_depth: 2, ..pf.clone() };
    // +PFX: refcounted cross-request KV prefix sharing (radix index at
    // admission, copy-on-write tails). Off on every lower rung, so the
    // whole ladder below this line keeps exclusive per-request block
    // ownership byte-identically.
    let pfx = ServingConfig { prefix_sharing: true, ..pipe.clone() };
    vec![
        SystemPreset { name: "vLLM", cfg: base },
        SystemPreset { name: "+SA", cfg: sa },
        SystemPreset { name: "+Offload", cfg: offload },
        SystemPreset { name: "+FT", cfg: ft },
        SystemPreset { name: "+WC", cfg: wc },
        SystemPreset { name: "+LP", cfg: lp },
        SystemPreset { name: "+PF", cfg: pf },
        SystemPreset { name: "+PIPE", cfg: pipe },
        SystemPreset { name: "+PFX", cfg: pfx },
    ]
}

/// The prefetch ablation pair: full SparseServe vs the identical system
/// with the working-set prefetcher disabled (every miss loads on demand,
/// on the critical path).
pub fn prefetch_ablation(token_budget: usize, chunk: usize, n_layers: usize) -> Vec<SystemPreset> {
    vec![
        SystemPreset {
            name: "SparseServe",
            cfg: ServingConfig::sparseserve(token_budget, chunk, n_layers),
        },
        SystemPreset {
            name: "SparseServe-NP",
            cfg: ServingConfig::sparseserve_np(token_budget, chunk, n_layers),
        },
    ]
}

/// Look a preset up by (case-insensitive) name.
pub fn by_name(name: &str, token_budget: usize, chunk: usize, n_layers: usize) -> Option<ServingConfig> {
    let lower = name.to_lowercase();
    comparison_set(token_budget, chunk, n_layers)
        .into_iter()
        .chain(prefetch_ablation(token_budget, chunk, n_layers))
        .find(|p| p.name.to_lowercase() == lower)
        .map(|p| p.cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_incremental() {
        let l = ablation_ladder(2048, 2048, 32);
        assert_eq!(l.len(), 9);
        assert!(!l[0].cfg.sparse_attention);
        assert!(l[1].cfg.sparse_attention && !l[1].cfg.offload);
        assert!(l[2].cfg.offload && l[2].cfg.transfer == TransferKind::Memcpy);
        assert!(l[3].cfg.transfer == TransferKind::Flash && !l[3].cfg.ws_batch_control);
        assert!(l[4].cfg.ws_batch_control && l[4].cfg.prefill_mode == PrefillMode::Chunked);
        assert!(l[5].cfg.prefill_mode == PrefillMode::LayerSegmented && !l[5].cfg.prefetch);
        assert!(l[6].cfg.prefetch, "final rung adds the prefetcher");
        // +PF isolates plain recency prefetch: no frequency blending, no
        // estimate-based admission
        assert!(!l[6].cfg.prefetch_freq_ranking && !l[6].cfg.admission_estimates);
        // the final rung matches SparseServe's execution shape
        let ss = ServingConfig::sparseserve(2048, 2048, 32);
        assert_eq!(l[6].cfg.prefill_mode, ss.prefill_mode);
        assert_eq!(l[6].cfg.max_inject_tokens, ss.max_inject_tokens);
        assert_eq!(l[6].cfg.max_prefetch_blocks, ss.max_prefetch_blocks);
        assert!(ss.prefetch_freq_ranking, "full system blends frequency");
        // +PIPE differs from +PF only in the executor's pipeline depth
        assert_eq!(l[6].cfg.pipeline_depth, 1);
        assert_eq!(l[7].cfg.pipeline_depth, 2, "+PIPE enables the pipelined executor");
        assert!(l[7].cfg.prefetch);
        assert_eq!(l[7].cfg.prefill_mode, l[6].cfg.prefill_mode);
        // +PFX differs from +PIPE only in prefix sharing; every lower
        // rung keeps exclusive block ownership
        assert!(!l[7].cfg.prefix_sharing);
        assert!(l[8].cfg.prefix_sharing, "+PFX enables cross-request prefix sharing");
        assert_eq!(l[8].cfg.pipeline_depth, l[7].cfg.pipeline_depth);
        assert_eq!(l[8].cfg.prefill_mode, l[7].cfg.prefill_mode);
        assert!(l[..8].iter().all(|p| !p.cfg.prefix_sharing));
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("sparseserve", 2048, 2048, 32).is_some());
        assert!(by_name("vLLM-SO", 2048, 2048, 32).unwrap().offload);
        assert!(by_name("nope", 2048, 2048, 32).is_none());
        let np = by_name("sparseserve-np", 2048, 2048, 32).unwrap();
        assert!(!np.prefetch && np.offload && np.ws_batch_control);
    }

    #[test]
    fn prefetch_ablation_differs_only_in_prefetch() {
        let pair = prefetch_ablation(2048, 2048, 32);
        assert_eq!(pair.len(), 2);
        assert!(pair[0].cfg.prefetch && !pair[1].cfg.prefetch);
        assert_eq!(pair[0].cfg.ws_batch_control, pair[1].cfg.ws_batch_control);
        assert_eq!(pair[0].cfg.prefill_mode, pair[1].cfg.prefill_mode);
    }
}
