//! Hand-rolled token-level Rust lexer for `sparselint`.
//!
//! Deliberately NOT a parser: the repo's invariants (txn pairing, pin
//! conservation, panic bans, allocation bans, struct-field liveness)
//! are all expressible over the token stream plus brace nesting, and a
//! token lexer has no grammar to fall behind as the language or the
//! codebase evolves (see DESIGN.md "What sparselint checks, and why
//! token-level analysis is enough"). The lexer must get exactly four
//! things right so the passes never misfire inside literals:
//! comments, strings (cooked / raw / byte), char-vs-lifetime
//! disambiguation, and line numbers.

/// Token kinds the passes discriminate on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `return`, `begin_txn`, ...).
    Ident,
    /// Numeric literal (`0`, `1e-9`, `0x1F`, `1_000`).
    Num,
    /// Any string literal (`"..."`, `r#"..."#`, `b"..."`). Text dropped.
    Str,
    /// Char literal (`'a'`, `'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Single punctuation character (`.`, `(`, `?`, `!`, ...).
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.as_bytes()[0] == c as u8
    }
}

/// One comment (line or block) with the 1-based line it starts on.
/// Doc comments are included — the allow grammar does not care.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Lex `src` into (tokens, comments). Never fails: unterminated
/// constructs are consumed to end-of-input (a file that does not parse
/// will fail `cargo build` long before the linter's verdict matters).
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;

    let bump = |c: char, line: &mut u32| {
        if c == '\n' {
            *line += 1;
        }
    };

    while i < n {
        let c = b[i];
        // whitespace
        if c.is_whitespace() {
            bump(c, &mut line);
            i += 1;
            continue;
        }
        // line comment (incl. /// and //!)
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            comments.push(Comment { line, text: b[start..i].iter().collect() });
            continue;
        }
        // block comment, nested
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    bump(b[i], &mut line);
                    i += 1;
                }
            }
            comments.push(Comment { line: start_line, text: b[start..i.min(n)].iter().collect() });
            continue;
        }
        // raw / byte / raw-byte strings: r"..", r#".."#, b"..", br#".."#
        if c == 'r' || c == 'b' {
            let mut j = i;
            if b[j] == 'b' && j + 1 < n && b[j + 1] == 'r' {
                j += 1;
            }
            if b[j] == 'r' || b[j] == 'b' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while b[j] == 'r' && k < n && b[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && b[k] == '"' && (b[j] == 'r' || hashes == 0) {
                    // raw string (hashes >= 0) or byte string b"..."
                    let raw = b[j] == 'r';
                    let tline = line;
                    k += 1;
                    loop {
                        if k >= n {
                            break;
                        }
                        if raw {
                            if b[k] == '"' {
                                let mut h = 0usize;
                                while h < hashes && k + 1 + h < n && b[k + 1 + h] == '#' {
                                    h += 1;
                                }
                                if h == hashes {
                                    k += 1 + hashes;
                                    break;
                                }
                            }
                        } else {
                            if b[k] == '\\' && k + 1 < n {
                                bump(b[k + 1], &mut line);
                                k += 2;
                                continue;
                            }
                            if b[k] == '"' {
                                k += 1;
                                break;
                            }
                        }
                        bump(b[k], &mut line);
                        k += 1;
                    }
                    toks.push(Tok { kind: TokKind::Str, text: String::new(), line: tline });
                    i = k;
                    continue;
                }
            }
            // plain identifier starting with r/b: fall through
        }
        // cooked string
        if c == '"' {
            let tline = line;
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    bump(b[i + 1], &mut line);
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    i += 1;
                    break;
                }
                bump(b[i], &mut line);
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line: tline });
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            // '\x' escape or 'c' closed by ' -> char; otherwise lifetime
            let is_char = if i + 1 < n && b[i + 1] == '\\' {
                true
            } else {
                i + 2 < n && b[i + 2] == '\''
            };
            if is_char {
                i += 1; // opening quote
                if i < n && b[i] == '\\' {
                    i += 2; // escape head ('\n', '\u{..}' head)
                    while i < n && b[i] != '\'' {
                        i += 1;
                    }
                } else if i < n {
                    i += 1;
                }
                if i < n && b[i] == '\'' {
                    i += 1;
                }
                toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
            } else {
                let start = i;
                i += 1;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[start..i].iter().collect(),
                    line,
                });
            }
            continue;
        }
        // identifier / keyword
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: b[start..i].iter().collect(), line });
            continue;
        }
        // number: digits, then alnum/_ (type suffixes, hex) and `.`
        // only when followed by a digit (so `0..n` stays three tokens)
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                if b[i].is_alphanumeric() || b[i] == '_' {
                    i += 1;
                } else if b[i] == '.' && i + 1 < n && b[i + 1].is_ascii_digit() {
                    i += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok { kind: TokKind::Num, text: b[start..i].iter().collect(), line });
            continue;
        }
        // single punctuation char
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    (toks, comments)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).0.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let ks = kinds("let x = a.unwrap() + 0x1F;");
        assert!(ks.contains(&(TokKind::Ident, "unwrap".into())));
        assert!(ks.contains(&(TokKind::Num, "0x1F".into())));
        assert!(ks.contains(&(TokKind::Punct, ".".into())));
    }

    #[test]
    fn strings_hide_their_contents() {
        let (toks, _) = lex(r#"let s = "a.unwrap() panic!";"#);
        assert!(!toks.iter().any(|t| t.is_ident("unwrap")));
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Str).count(), 1);
    }

    #[test]
    fn raw_strings_and_hashes() {
        let (toks, _) = lex(r##"let s = r#"no "unwrap()" here"#; x.y"##);
        assert!(!toks.iter().any(|t| t.is_ident("unwrap")));
        assert!(toks.iter().any(|t| t.is_ident("y")));
    }

    #[test]
    fn comments_are_collected_not_tokenized() {
        let (toks, comments) = lex("a // sparselint: allow(no-panic) -- reason\nb");
        assert!(toks.iter().any(|t| t.is_ident("a")));
        assert!(toks.iter().any(|t| t.is_ident("b")));
        assert_eq!(comments.len(), 1);
        assert!(comments[0].text.contains("allow(no-panic)"));
        assert_eq!(comments[0].line, 1);
    }

    #[test]
    fn lifetime_vs_char() {
        let (toks, _) = lex("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Lifetime).count(), 2);
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
    }

    #[test]
    fn line_numbers_advance_through_strings_and_comments() {
        let (toks, _) = lex("a\n\"x\ny\"\n/* b\nc */ d");
        let d = toks.iter().find(|t| t.is_ident("d")).unwrap();
        assert_eq!(d.line, 5);
    }

    #[test]
    fn range_numbers_stay_separate() {
        let ks = kinds("0..n");
        assert_eq!(ks[0], (TokKind::Num, "0".into()));
        assert_eq!(ks[1], (TokKind::Punct, ".".into()));
    }
}
